"""AHLA: equivalence of views (paper Thm 6.1, Eq 6.2)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.ahla import (
    AHLAState,
    ahla_chunkwise,
    ahla_naive,
    ahla_scan,
    ahla_serial,
)
from conftest import make_qkv

TOL = dict(atol=1e-9, rtol=1e-8)


@pytest.mark.parametrize("use_gamma", [False, True])
@pytest.mark.parametrize("normalize", [False, True])
def test_all_views_agree(rng, use_gamma, normalize):
    q, k, v, gam = make_qkv(rng)
    gamma = gam if use_gamma else None
    o0 = ahla_naive(q, k, v, gamma, normalize=normalize)
    o1, s1 = ahla_serial(q, k, v, gamma, normalize=normalize)
    o2, s2 = ahla_scan(q, k, v, gamma, normalize=normalize)
    o3, s3 = ahla_chunkwise(q, k, v, gamma, chunk=8, normalize=normalize)
    for o in (o1, o2, o3):
        np.testing.assert_allclose(o, o0, **TOL)
    for s in (s2, s3):
        for f in AHLAState._fields:
            np.testing.assert_allclose(getattr(s, f), getattr(s1, f), **TOL)


def test_matches_masked_matrix_power(rng):
    """Eq. (6.1): o_t = row_t[(A A) V], A = L . (Q K^T)."""
    q, k, v, _ = make_qkv(rng, B=1, H=1, n=16)
    n = q.shape[-2]
    L = jnp.tril(jnp.ones((n, n)))
    A = jnp.einsum("bhtd,bhjd->bhtj", q, k) * L
    AA = jnp.einsum("bhti,bhij->bhtj", A, A)
    o_ref = jnp.einsum("bhtj,bhje->bhte", AA, v)
    o, _ = ahla_serial(q, k, v)
    np.testing.assert_allclose(o, o_ref, **TOL)


def test_carry_continuation(rng):
    q, k, v, gam = make_qkv(rng)
    o_full, s_full = ahla_serial(q, k, v, gam)
    cut = 9
    o_a, st = ahla_chunkwise(
        q[..., :cut, :], k[..., :cut, :], v[..., :cut, :], gam, chunk=4
    )
    o_b, s_b = ahla_chunkwise(
        q[..., cut:, :], k[..., cut:, :], v[..., cut:, :], gam, chunk=5,
        state=st,
    )
    np.testing.assert_allclose(jnp.concatenate([o_a, o_b], -2), o_full, **TOL)
    for f in AHLAState._fields:
        np.testing.assert_allclose(getattr(s_b, f), getattr(s_full, f), **TOL)
