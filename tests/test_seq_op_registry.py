"""SequenceOp registry conformance suite (DESIGN.md §11).

Parametrized over EVERY registered operator — a new op (registered via the
public ``seq_op.register_op``) is automatically held to the same
contracts the trainer, the serving engine, the speculative verifier and
the sharder rely on:

* ``state_axes`` tree matches ``init_state`` leaf-for-leaf (structure AND
  per-leaf rank) — the exact drift that crashed hla3_paper serving;
* ``forward(want_state=True)`` then ``step`` over the tail reproduces
  ``forward`` over the concatenated sequence (the paper's Section-4
  chunkwise == serial identity, required for prefill -> decode hand-off);
* the ``streaming`` capability flag is consistent with ``step``
  availability;
* duplicate / unknown registration fails loudly with the registry listing
  and a closest-match hint.

Plus the end-to-end proof for the registry's worked example: the ``gla``
operator trains, prefills, continuously-batch decodes and (subprocess
lane) serves sharded — with zero edits to lm.py / engine.py / steps.py.
"""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import lm, seq_op
from repro.models.config import MambaConfig
from repro.models.param import init_params, is_axes

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

ALL_OPS = seq_op.registered_op_names()
STREAMING_OPS = seq_op.streaming_op_names()


def _cfg_for(name):
    base = get_config("hla-1b", reduced=True)
    if name == "attn":
        return base.replace(mixer="softmax")
    if name == "mamba":
        return base.replace(
            mixer="mamba", mamba=MambaConfig(d_state=8, d_conv=4, expand=2)
        )
    return base.replace(mixer=name)


def _sub_params(op, cfg, seed=0):
    return init_params(op.specs(cfg), jax.random.key(seed))


# --------------------------------------------------------------------------
# registry mechanics
# --------------------------------------------------------------------------


def test_all_eight_plus_gla_registered():
    """The eight ported operators AND the register_op-only gla."""
    assert set(ALL_OPS) >= {
        "hla2", "ahla", "hla3", "hla3_paper", "linattn",
        "attn", "mamba", "rwkv6", "gla",
    }


def test_duplicate_registration_raises():
    op = seq_op.get_op("hla2")
    with pytest.raises(seq_op.SequenceOpError, match="already registered"):
        seq_op.register_op(op)


def test_unknown_op_lists_registry_and_suggests():
    with pytest.raises(seq_op.SequenceOpError) as ei:
        seq_op.get_op("hla2x")
    msg = str(ei.value)
    assert "hla2" in msg and "registered ops" in msg
    # a config typo fails through the same path with the same hint
    cfg = get_config("hla-1b", reduced=True).replace(mixer="rwkv7")
    with pytest.raises(seq_op.SequenceOpError, match="rwkv6"):
        seq_op.op_for(cfg)


def test_streaming_flag_consistent_with_step():
    for name in ALL_OPS:
        op = seq_op.get_op(name)
        if op.streaming:
            assert op.step is not None, name
    # the built-in KV-cache op is the canonical non-streaming example
    # (user-registered non-streaming ops are equally legitimate)
    assert not seq_op.get_op("attn").streaming


def test_streaming_registration_requires_step():
    with pytest.raises(seq_op.SequenceOpError, match="step"):
        seq_op.SequenceOp(
            name="bogus", specs=lambda cfg: {},
            forward=lambda *a, **k: None,
            init_state=lambda *a, **k: None,
            state_axes=lambda cfg: None,
            streaming=True,
        )


# --------------------------------------------------------------------------
# state-tree contracts
# --------------------------------------------------------------------------


@pytest.mark.parametrize("name", ALL_OPS)
def test_state_axes_match_init_state(name):
    """state_axes tree mirrors init_state leaf-for-leaf: same structure,
    per-leaf axes length == leaf rank (the sharding source-of-truth
    contract ``distributed.steps.state_specs`` and the pool rely on)."""
    op = seq_op.get_op(name)
    cfg = _cfg_for(name)
    axes = op.state_axes(cfg)
    state = jax.eval_shape(lambda: op.init_state(cfg, 2, max_len=16))

    def chk(ax, leaf):
        assert is_axes(ax), (name, ax)
        assert len(ax) == leaf.ndim, (name, tuple(ax), leaf.shape)

    # tree.map raises on structural drift between the two trees
    jax.tree.map(chk, axes, state, is_leaf=is_axes)


@pytest.mark.parametrize("name", ALL_OPS)
def test_state_ndims_match_init_state(name):
    op = seq_op.get_op(name)
    cfg = _cfg_for(name)
    nd = op.resolve_state_ndims(cfg)
    state = jax.eval_shape(lambda: op.init_state(cfg, 2, max_len=16))
    jax.tree.map(
        lambda r, leaf: (_ for _ in ()).throw(
            AssertionError((name, r, leaf.shape))
        ) if r != leaf.ndim else None,
        nd, state,
    )


# --------------------------------------------------------------------------
# forward/step agreement (the streaming identity)
# --------------------------------------------------------------------------


@pytest.mark.parametrize("name", STREAMING_OPS)
def test_forward_then_step_matches_forward(name):
    """prefix forward(want_state=True) + per-token steps == one forward
    over the whole sequence, <= 1e-4."""
    op = seq_op.get_op(name)
    cfg = _cfg_for(name)
    rng = np.random.RandomState(0)
    B, n, t = 2, 16, 7
    x = jnp.asarray(rng.randn(B, n, cfg.d_model) * 0.1, jnp.float32)
    p = _sub_params(op, cfg)

    y_full, _ = op.forward(p, x, cfg, want_state=True)

    y1, st = op.forward(p, x[:, :t], cfg, want_state=True)
    pieces = [np.asarray(y1, np.float32)]
    for j in range(t, n):
        yj, st = op.step(
            p, x[:, j:j + 1], st, cfg,
            positions=jnp.full((B, 1), j, jnp.int32),
        )
        pieces.append(np.asarray(yj, np.float32))
    y_cat = np.concatenate(pieces, axis=1)
    np.testing.assert_allclose(
        y_cat, np.asarray(y_full, np.float32), atol=1e-4, rtol=1e-4,
    )


def test_attn_cache_step_matches_forward():
    """The non-streaming op's cache-based step agrees with the cacheless
    forward (looser tol: the KV cache stores bf16)."""
    op = seq_op.get_op("attn")
    cfg = _cfg_for("attn")
    rng = np.random.RandomState(1)
    B, n, t = 2, 12, 5
    x = jnp.asarray(rng.randn(B, n, cfg.d_model) * 0.1, jnp.float32)
    p = _sub_params(op, cfg)

    y_full, _ = op.forward(p, x, cfg)

    st = op.init_state(cfg, B, max_len=n)
    y1, st = op.forward(
        p, x[:, :t], cfg, state=st, want_state=True,
        positions=jnp.arange(t)[None],
    )
    pieces = [np.asarray(y1, np.float32)]
    for j in range(t, n):
        yj, st = op.step(
            p, x[:, j:j + 1], st, cfg,
            positions=jnp.full((B, 1), j, jnp.int32),
        )
        pieces.append(np.asarray(yj, np.float32))
    y_cat = np.concatenate(pieces, axis=1)
    np.testing.assert_allclose(
        y_cat, np.asarray(y_full, np.float32), atol=2e-2, rtol=2e-2,
    )


@pytest.mark.parametrize("name", STREAMING_OPS)
def test_forward_resumes_from_carry(name):
    """forward(state=mid_carry) == the tail of one full forward — the
    incremental-prefill / speculative-verify contract."""
    op = seq_op.get_op(name)
    cfg = _cfg_for(name)
    rng = np.random.RandomState(2)
    B, n, t = 2, 16, 8
    x = jnp.asarray(rng.randn(B, n, cfg.d_model) * 0.1, jnp.float32)
    p = _sub_params(op, cfg)

    y_full, st_full = op.forward(p, x, cfg, want_state=True)
    _, st1 = op.forward(p, x[:, :t], cfg, want_state=True)
    y2, st2 = op.forward(p, x[:, t:], cfg, state=st1, want_state=True)
    np.testing.assert_allclose(
        np.asarray(y2, np.float32),
        np.asarray(y_full[:, t:], np.float32), atol=1e-4, rtol=1e-4,
    )
    for a, b in zip(jax.tree.leaves(st_full), jax.tree.leaves(st2)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            atol=1e-4, rtol=1e-3,
        )


# --------------------------------------------------------------------------
# gla end-to-end: train / prefill / continuous batching / sharding
# --------------------------------------------------------------------------


def test_gla_trains_with_finite_grads():
    cfg = _cfg_for("gla")
    params = init_params(lm.lm_specs(cfg), jax.random.key(0))
    rng = np.random.RandomState(3)
    toks = jnp.asarray(rng.randint(1, cfg.vocab, (2, 24)))
    labels = jnp.asarray(rng.randint(1, cfg.vocab, (2, 24)))
    (loss, _), grads = jax.value_and_grad(
        lambda p: lm.lm_loss(p, toks, labels, cfg), has_aux=True
    )(params)
    assert np.isfinite(float(loss))
    gnorm = sum(
        float(jnp.sum(g.astype(jnp.float32) ** 2))
        for g in jax.tree.leaves(grads)
    )
    assert np.isfinite(gnorm) and gnorm > 0.0


def test_gla_serving_end_to_end():
    """Engine (prefill admission -> continuous-batching block decode) over
    gla matches token-for-token a reference greedy loop of plain
    lm_prefill + per-token lm_apply decode steps."""
    from repro.serving import Engine, GenRequest

    cfg = _cfg_for("gla")
    params = init_params(lm.lm_specs(cfg), jax.random.key(0))
    rng = np.random.RandomState(4)
    prompts = [rng.randint(2, cfg.vocab, 10) for _ in range(3)]
    max_new = 8

    eng = Engine(cfg, params, slots=2, max_len=40, block=4, seed=0)
    results = eng.run([
        GenRequest(rid=i, prompt=p, max_new=max_new)
        for i, p in enumerate(prompts)
    ])

    for i, prompt in enumerate(prompts):
        toks = jnp.asarray(np.asarray(prompt, np.int32)[None])
        lg, st = lm.lm_prefill(params, toks, cfg)
        out = [int(jnp.argmax(lg[0]))]
        pos = len(prompt)
        while len(out) < max_new:
            lg, st, _ = lm.lm_apply(
                params, jnp.asarray([[out[-1]]], jnp.int32), cfg,
                states=st, positions=jnp.asarray([[pos]]), mode="decode",
            )
            out.append(int(jnp.argmax(lg[0, -1])))
            pos += 1
        assert results[i].tokens == out, (i, results[i].tokens, out)


def test_gla_rejected_nowhere():
    """gla is spec-decodable: the speculative engine path accepts it and
    greedy spec decode equals plain greedy (the §10 exactness contract)."""
    from repro.serving import Engine, GenRequest, SpecConfig

    cfg = _cfg_for("gla")
    params = init_params(lm.lm_specs(cfg), jax.random.key(0))
    rng = np.random.RandomState(5)
    # repetitive prompt so the n-gram drafter gets some acceptance
    prompt = np.tile(rng.randint(2, cfg.vocab, 4), 5)
    reqs = lambda: [GenRequest(rid=0, prompt=prompt, max_new=10)]  # noqa: E731

    plain = Engine(cfg, params, slots=1, max_len=64, block=4, seed=0)
    r_plain = plain.run(reqs())
    spec = Engine(cfg, params, slots=1, max_len=64, block=4, seed=0,
                  spec=SpecConfig(drafter="ngram", k=3))
    r_spec = spec.run(reqs())
    assert r_plain[0].tokens == r_spec[0].tokens


@pytest.mark.subprocess
def test_gla_sharded_serving_matches_single_device():
    """gla serves on a (2, 4) mesh — pool states placed by its registered
    state_axes (slots on data, heads on model) — and samples exactly the
    single-device engine's tokens.  Zero gla-specific code in lm.py,
    engine.py or distributed/steps.py."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("JAX_ENABLE_X64", None)
    body = textwrap.dedent("""
        import functools
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.distributed import sharding as shd
        from repro.launch.mesh import make_mesh
        from repro.models import lm
        from repro.models.param import init_params
        from repro.serving import Engine, GenRequest

        cfg = get_config("hla-1b", reduced=True).replace(mixer="gla")
        specs = lm.lm_specs(cfg)
        mk_reqs = lambda: [
            GenRequest(
                rid=i,
                prompt=np.random.RandomState(70 + i).randint(
                    2, cfg.vocab, 10),
                max_new=8,
            )
            for i in range(4)
        ]

        def run(mesh, use_mesh):
            with mesh:
                ps = shd.param_shardings(specs, mesh)
                params = jax.jit(functools.partial(init_params, specs),
                                 out_shardings=ps)(jax.random.key(0))
                eng = Engine(cfg, params, slots=2, max_len=40, block=4,
                             seed=3, mesh=mesh if use_mesh else None)
                res = eng.run(mk_reqs())
                states = jax.tree.map(np.asarray, eng.pool.states)
            return res, states, eng

        mesh8 = make_mesh((2, 4), ("data", "model"))
        r8, s8, e8 = run(mesh8, True)
        spec = jax.tree.leaves(e8.pool.states)[0].sharding.spec
        assert tuple(spec) == (None, "data", "model"), spec
        r1, s1, _ = run(make_mesh((1, 1), ("data", "model")), False)
        for a, b in zip(r8, r1):
            assert a.tokens == b.tokens, (a.rid, a.tokens, b.tokens)
        for a, b in zip(jax.tree.leaves(s8), jax.tree.leaves(s1)):
            np.testing.assert_allclose(a, b, atol=1e-4)
        print("OK")
    """)
    proc = subprocess.run(
        [sys.executable, "-c", body],
        capture_output=True, text=True, timeout=600, env=env, cwd=REPO,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "OK" in proc.stdout


# --------------------------------------------------------------------------
# engine capability gating
# --------------------------------------------------------------------------


def test_engine_rejects_non_streaming_op():
    from repro.serving import Engine

    cfg = _cfg_for("attn")
    params = init_params(lm.lm_specs(cfg), jax.random.key(0))
    with pytest.raises(ValueError, match="streaming-state ops"):
        Engine(cfg, params, slots=2, max_len=32)
