"""Observability substrate tests (DESIGN.md §13).

Four contracts:

* **Registry semantics** — labeled series, fixed bucket edges, bounded
  reservoirs, quantiles, snapshot/merge, Prometheus exposition.
* **Tracer semantics** — span nesting depth, bounded ring, error spans,
  JSONL write-through.
* **Engine timeline completeness** — under fault injection, every
  terminal ``GenResult`` has a matching ``request.done`` event and the
  status-labeled counters agree with the returned results.
* **Overhead guard** — attaching sinks to a decode run adds ZERO host
  syncs (counted by wrapping ``jax.device_get``): all obs timings ride
  transfers the engine already performs.
"""

import dataclasses
import io
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointError, CheckpointManager
from repro.configs import get_config
from repro.models import lm
from repro.models.param import init_params
from repro.obs import (
    JsonlSink,
    Obs,
    Registry,
    Tracer,
    check_timelines,
    console_summary,
    prometheus_text,
    read_jsonl,
    request_timelines,
    terminal_events,
)
from repro.obs.validate import (
    check_requests,
    counter_total,
    main as validate_main,
    validate_events,
    validate_metrics,
)
from repro.runtime.faults import FaultPlan, FaultSpec
from repro.runtime.ft import FaultTolerantLoop
from repro.serving import Engine, GenRequest


def _cfg():
    base = get_config("hla-1b", reduced=True).replace(mixer="hla2")
    return base.replace(
        n_layers=1, d_model=32, n_heads=2, n_kv_heads=2, d_ff=64, vocab=64,
        hla=dataclasses.replace(base.hla, chunk=16),
    )


@pytest.fixture(scope="module")
def cfg():
    return _cfg()


@pytest.fixture(scope="module")
def params(cfg):
    return init_params(lm.lm_specs(cfg), jax.random.key(0))


def _requests(cfg, lens=(5, 11, 7, 9), max_new=10, **kw):
    return [
        GenRequest(rid=i,
                   prompt=np.random.RandomState(10 + i).randint(
                       2, cfg.vocab, ln),
                   max_new=max_new, **kw)
        for i, ln in enumerate(lens)
    ]


def _engine(cfg, params, **kw):
    kw.setdefault("slots", 2)
    kw.setdefault("max_len", 96)
    kw.setdefault("block", 4)
    return Engine(cfg, params, **kw)


# -- registry ---------------------------------------------------------------


class TestRegistry:
    def test_counter_labels_and_total(self):
        reg = Registry()
        c = reg.counter("reqs_total", "requests")
        c.inc(status="ok")
        c.inc(status="ok")
        c.inc(3, status="error")
        assert c.value(status="ok") == 2
        assert c.value(status="error") == 3
        assert c.value(status="timeout") == 0
        assert c.total() == 5
        with pytest.raises(ValueError):
            c.inc(-1)

    def test_declaration_idempotent_but_kind_checked(self):
        reg = Registry()
        a = reg.counter("x_total")
        assert reg.counter("x_total") is a
        with pytest.raises(ValueError):
            reg.gauge("x_total")

    def test_gauge(self):
        reg = Registry()
        g = reg.gauge("depth")
        g.set(4.0)
        g.inc()
        assert g.value() == 5.0

    def test_histogram_bucket_edges(self):
        reg = Registry()
        h = reg.histogram("lat", buckets=(1.0, 2.0, 4.0))
        for v in (0.5, 1.0, 2.5, 5.0, 100.0):
            h.observe(v)
        (series,) = h.snapshot_series()
        # bisect_left: a value equal to an edge lands in that edge's
        # bucket; values past the last edge go to the overflow bucket
        assert series["bucket_counts"] == [2, 0, 1, 2]
        assert series["count"] == 5
        assert series["min"] == 0.5 and series["max"] == 100.0
        with pytest.raises(ValueError):
            reg.histogram("bad", buckets=(2.0, 1.0))

    def test_histogram_reservoir_bounded(self):
        reg = Registry()
        h = reg.histogram("lat", buckets=(1.0,), sample_cap=64)
        for i in range(5000):
            h.observe(float(i))
        assert len(h.recent()) == 64
        (series,) = h.snapshot_series()
        assert series["count"] == 5000
        # the ring keeps the NEWEST samples
        assert min(h.recent()) >= 5000 - 64

    def test_quantile_exact_under_cap(self):
        reg = Registry()
        h = reg.histogram("lat", buckets=(10.0,))
        for v in range(1, 11):
            h.observe(float(v))
        assert h.quantile(0.0) == 1.0
        assert h.quantile(0.5) == 6.0
        assert h.quantile(1.0) == 10.0
        assert reg.histogram("empty", buckets=(1.0,)).quantile(0.5) is None

    def test_quantile_interpolated_past_cap(self):
        reg = Registry()
        h = reg.histogram("lat", buckets=tuple(float(i) for i in range(1, 10)),
                          sample_cap=8)
        rng = np.random.RandomState(0)
        for v in rng.uniform(0.0, 9.0, 500):
            h.observe(float(v))
        q25, q50, q75 = (h.quantile(q) for q in (0.25, 0.5, 0.75))
        assert 0.0 <= q25 <= q50 <= q75 <= 9.0
        assert abs(q50 - 4.5) < 1.5  # uniform: median near the middle

    def test_snapshot_merge(self):
        a, b = Registry(), Registry()
        a.counter("c_total").inc(2, status="ok")
        a.gauge("g").set(1.0)
        a.histogram("h", buckets=(1.0, 2.0)).observe(0.5)
        b.counter("c_total").inc(3, status="ok")
        b.gauge("g").set(7.0)
        b.histogram("h", buckets=(1.0, 2.0)).observe(1.5)
        a.merge(b.snapshot())
        assert a.get("c_total").value(status="ok") == 5
        assert a.get("g").value() == 7.0  # last-write-wins
        (series,) = a.get("h").snapshot_series()
        assert series["count"] == 2
        assert series["bucket_counts"] == [1, 1, 0]
        with pytest.raises(ValueError):
            a.merge({"schema": "nope"})

    def test_snapshot_validates_and_renders(self):
        reg = Registry()
        reg.counter("c_total", "help text").inc(status="ok")
        reg.histogram("h_seconds", buckets=(0.1, 1.0)).observe(0.05)
        snap = reg.snapshot()
        validate_metrics(snap)  # raises on malformed snapshots
        assert json.loads(json.dumps(snap)) == snap  # JSON-able
        text = prometheus_text(snap)
        assert '# TYPE c_total counter' in text
        assert 'c_total{status="ok"} 1.0' in text
        assert 'h_seconds_bucket{le="0.1"} 1' in text
        assert 'h_seconds_count 1' in text
        assert "c_total" in console_summary(snap)
        assert counter_total(snap, "c_total") == 1.0
        with pytest.raises(ValueError):
            counter_total(snap, "h_seconds")

    def test_reset_keeps_declarations(self):
        reg = Registry()
        c = reg.counter("c_total")
        c.inc(5)
        reg.reset()
        assert reg.get("c_total") is c
        assert c.total() == 0


# -- tracer -----------------------------------------------------------------


class TestTracer:
    def test_span_nesting_depth(self):
        t = Tracer(annotate=False)
        with t.span("outer"):
            with t.span("inner", rid=1):
                pass
        inner, outer = t.events(kind="span")
        assert (inner["name"], inner["depth"], inner["rid"]) == ("inner", 1, 1)
        assert (outer["name"], outer["depth"]) == ("outer", 0)
        assert 0.0 <= inner["dur_s"] <= outer["dur_s"]
        assert inner["seq"] < outer["seq"]  # inner closes first

    def test_ring_bounded(self):
        t = Tracer(ring=8, annotate=False)
        for i in range(50):
            t.event("tick", i=i)
        evs = t.events()
        assert len(evs) == 8
        assert [e["i"] for e in evs] == list(range(42, 50))
        with pytest.raises(ValueError):
            Tracer(ring=0)

    def test_error_span_recorded_and_raises(self):
        t = Tracer(annotate=False)
        with pytest.raises(RuntimeError):
            with t.span("boom"):
                raise RuntimeError("x")
        (rec,) = t.events(kind="span")
        assert rec["error"] is True

    def test_jsonl_write_through_roundtrip(self, tmp_path):
        path = str(tmp_path / "e.jsonl")
        t = Tracer(annotate=False)
        sink = JsonlSink(path)
        t.attach(sink)
        t.event("before.close", rid=1)
        with t.span("work", rid=1):
            pass
        sink.close()
        evs = read_jsonl(path)  # drops + validates the header line
        assert [e["name"] for e in evs] == ["before.close", "work"]
        validate_events(evs)
        with open(path) as f:
            header = json.loads(f.readline())
        assert header["schema"] == "repro.obs.events/v1"
        assert "epoch_offset" in header

    def test_obs_reset_clears_both(self):
        obs = Obs(annotate=False)
        obs.counter("c_total").inc()
        obs.event("e")
        obs.reset()
        assert obs.registry.get("c_total").total() == 0
        assert obs.events() == []


# -- engine integration -----------------------------------------------------


class TestEngineTimelines:
    def test_timeline_completeness_under_faults(self, cfg, params):
        # 3 valid requests + 1 invalid; NaN-poison slot 0 at block hit 1
        eng = _engine(
            cfg, params,
            faults=FaultPlan(FaultSpec("engine.nan_state", at=1, arg=0)),
        )
        reqs = _requests(cfg, lens=(5, 11, 7))
        reqs.append(GenRequest(rid=9, prompt=np.asarray([cfg.vocab + 5]),
                               max_new=4))
        results = eng.run(reqs)
        evs = eng.obs.events()
        # every terminal result has a matching-status request.done event
        check_timelines(evs, results)
        # the lifecycle is complete: queued -> ... -> done for every rid
        tls = request_timelines(evs)
        for r in results:
            names = [e["name"] for e in tls[r.rid]]
            assert names[0] == "request.queued"
            assert names[-1] == "request.done"
            if r.status == "ok":
                assert "request.admitted" in names
                assert "request.first_token" in names
        # status-labeled counters agree with the returned results
        m = eng.obs.registry.get("serving_requests_total")
        import collections
        by_status = collections.Counter(r.status for r in results)
        for status, n in by_status.items():
            assert m.value(status=status) == n
        assert m.total() == len(results)
        assert by_status["error"] == 2  # quarantine + invalid admission
        assert eng.obs.registry.get(
            "serving_quarantined_total").total() == 1
        # the fired injection self-documented through the engine's obs
        assert eng.obs.registry.get("faults_fired_total").value(
            point="engine.nan_state") == 1
        (fired,) = eng.obs.events(name="fault.fired")
        assert fired["point"] == "engine.nan_state"
        # block spans closed with the fields the docs promise
        spans = eng.obs.events(name="engine.decode_block")
        assert spans and all(s["dur_s"] > 0 for s in spans)
        assert eng.obs.registry.get("serving_ttft_seconds").count() == 3

    def test_stats_shim_compat(self, cfg, params):
        eng = _engine(cfg, params)
        results = eng.run(_requests(cfg, lens=(5, 7)))
        st = eng.stats
        gen = sum(len(r.tokens) for r in results)
        assert st["generated_tokens"] == gen
        assert isinstance(st["generated_tokens"], int)
        assert st["errors"] == 0
        assert len(st["ttft_s"]) == 2 and st["decode_s"] > 0
        assert dict(st)["prompt_tokens"] == 5 + 7  # MutableMapping view
        # the legacy post-warmup reset idiom still zeroes the registry
        eng.stats.update(prefill_s=0.0, decode_s=0.0, prompt_tokens=0,
                         generated_tokens=0, ttft_s=[])
        assert st["generated_tokens"] == 0 and st["ttft_s"] == []
        assert eng.obs.registry.get(
            "serving_generated_tokens_total").total() == 0

    def test_engines_do_not_share_obs(self, cfg, params):
        a, b = _engine(cfg, params), _engine(cfg, params)
        assert a.obs is not b.obs
        a.obs.counter("serving_quarantined_total").inc()
        assert b.obs.registry.get("serving_quarantined_total").total() == 0

    def test_sinks_add_zero_host_syncs(self, cfg, params):
        """The overhead contract: obs never adds a device round trip.
        Count ``jax.device_get`` calls for identical traffic with and
        without a write-through sink attached — they must be EQUAL."""
        real = jax.device_get

        def run_once(sink):
            eng = _engine(cfg, params)
            if sink is not None:
                eng.obs.attach(sink)
            n = [0]

            def counting(x):
                n[0] += 1
                return real(x)

            jax.device_get = counting
            try:
                results = eng.run(_requests(cfg))
            finally:
                jax.device_get = real
            return n[0], [r.tokens for r in results]

        bare_syncs, bare_toks = run_once(None)
        sink_syncs, sink_toks = run_once(JsonlSink(io.StringIO()))
        assert bare_syncs > 0
        assert sink_syncs == bare_syncs
        assert sink_toks == bare_toks  # sinks never perturb decode either


# -- checkpoint + training-loop integration ---------------------------------


class TestCkptMetrics:
    def test_save_restore_metrics(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), async_save=False)
        tree = {"w": np.arange(6.0), "b": np.zeros(2)}
        mgr.save(0, tree)
        mgr.restore(tree)
        reg = mgr.obs.registry
        assert reg.get("ckpt_saves_total").total() == 1
        assert reg.get("ckpt_restores_total").total() == 1
        assert reg.get("ckpt_save_seconds").count() == 1
        assert reg.get("ckpt_restore_seconds").count() == 1
        assert reg.get("ckpt_save_failures_total").total() == 0
        names = [e["name"] for e in mgr.obs.events(kind="span")]
        assert names == ["ckpt.save", "ckpt.restore"]

    def test_checksum_failure_counted(self, tmp_path):
        mgr = CheckpointManager(
            str(tmp_path), async_save=False,
            faults=FaultPlan(FaultSpec("ckpt.corrupt", at=0)),
        )
        tree = {"w": np.arange(64.0)}
        mgr.save(0, tree)
        with pytest.raises(CheckpointError, match="checksum"):
            mgr.restore(tree)
        assert mgr.obs.registry.get(
            "ckpt_checksum_failures_total").total() == 1
        assert mgr.obs.registry.get("ckpt_restores_total").total() == 0

    def test_save_failure_counted(self, tmp_path):
        mgr = CheckpointManager(
            str(tmp_path), async_save=False,
            faults=FaultPlan(FaultSpec("ckpt.save", at=0)),
        )
        with pytest.raises(Exception):
            mgr.save(0, {"w": np.zeros(2)})
        assert mgr.obs.registry.get("ckpt_save_failures_total").total() == 1
        assert mgr.obs.registry.get("ckpt_saves_total").total() == 0


class _Stream:
    def batch(self, step):
        return {"tokens": np.ones((2, 8), np.int32),
                "labels": np.ones((2, 8), np.int32)}


def _toy_step(params, opt_state, batch):
    return params, opt_state, {"loss": jnp.asarray(0.5)}


class TestLoopMetrics:
    def test_step_and_restart_metrics(self, tmp_path):
        quiet = lambda *a, **k: None  # noqa: E731
        p, o = {"w": jnp.zeros(2)}, {"m": jnp.zeros(2)}
        loop = FaultTolerantLoop(
            _toy_step, _Stream(), str(tmp_path), ckpt_every=2, log=quiet,
        )
        loop.run(p, o, 4)
        reg = loop.obs.registry
        assert reg.get("train_steps_total").total() == 4
        assert reg.get("train_tokens_total").total() == 4 * 2 * 8
        assert reg.get("train_step_seconds").count() == 4
        assert reg.get("train_loss").value() == 0.5
        assert reg.get("train_restarts_total").total() == 0
        assert reg.get("ckpt_saves_total").total() == 2  # steps 1 and 3
        assert len(loop.obs.events(name="train.step")) == 4

        # a second loop over the same dir auto-resumes: restart counted,
        # and only the remaining steps run
        loop2 = FaultTolerantLoop(
            _toy_step, _Stream(), str(tmp_path), ckpt_every=2, log=quiet,
        )
        loop2.run(p, o, 6)
        reg2 = loop2.obs.registry
        assert reg2.get("train_restarts_total").total() == 1
        assert reg2.get("train_steps_total").total() == 2  # steps 4, 5
        (ev,) = loop2.obs.events(name="train.resumed")
        assert ev["step"] == 3


# -- validator CLI ----------------------------------------------------------


class TestValidateCli:
    def _artifacts(self, tmp_path):
        obs = Obs(annotate=False)
        obs.counter("serving_quarantined_total").inc()
        for rid in (0, 1, 2):
            obs.event("request.queued", rid=rid)
            obs.event("request.done", rid=rid,
                      status="ok" if rid else "error")
        mpath, epath = str(tmp_path / "m.json"), str(tmp_path / "e.jsonl")
        with open(mpath, "w") as f:
            json.dump(obs.snapshot(), f)
        sink = JsonlSink(epath)
        for e in obs.events():
            sink.emit(e)
        sink.close()
        return mpath, epath

    def test_main_ok_and_fail(self, tmp_path, capsys):
        mpath, epath = self._artifacts(tmp_path)
        assert validate_main([
            "--metrics", mpath, "--events", epath,
            "--expect-counter", "serving_quarantined_total=1",
            "--expect-requests", "3",
            "--expect-terminal-statuses", "ok,error",
        ]) == 0
        assert validate_main([
            "--metrics", mpath,
            "--expect-counter", "serving_quarantined_total=7",
        ]) == 1
        assert validate_main([
            "--events", epath, "--expect-requests", "4",
        ]) == 1
        capsys.readouterr()

    def test_vanished_request_detected(self):
        events = [
            {"kind": "event", "name": "request.queued", "rid": 0,
             "ts": 0.0, "seq": 0},
        ]
        with pytest.raises(ValueError, match="vanished"):
            check_requests(events, 0)
        assert terminal_events(events) == {}
