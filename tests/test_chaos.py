"""Chaos suite: deterministic fault injection across the failure domains.

Every test drives a REAL engine/loop through a scheduled fault
(``runtime.faults``) and asserts the blast radius stayed inside one
request/slot: uninjected requests byte-identical to a fault-free run,
injected requests carrying the right non-``ok`` status, and ``run()``
never raising out of its drive loop (DESIGN.md §12).

All engine runs here are GREEDY: quarantine/timeout change admission
timing, and greedy streams are the only ones invariant to when a slot was
(re)admitted — which is exactly what makes byte-identity a valid oracle.
"""

import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import (
    CheckpointError,
    CheckpointManager,
    restore_checkpoint,
    save_checkpoint,
)
from repro.configs import get_config
from repro.models import lm
from repro.models.param import init_params
from repro.runtime.faults import (
    FAULT_POINTS,
    FaultPlan,
    FaultSpec,
    InjectedFault,
    parse_fault,
)
from repro.serving import Engine, GenRequest, SpecConfig

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _cfg():
    base = get_config("hla-1b", reduced=True).replace(mixer="hla2")
    return base.replace(
        n_layers=1, d_model=32, n_heads=2, n_kv_heads=2, d_ff=64, vocab=64,
        hla=dataclasses.replace(base.hla, chunk=16),
    )


@pytest.fixture(scope="module")
def cfg():
    return _cfg()


@pytest.fixture(scope="module")
def params(cfg):
    return init_params(lm.lm_specs(cfg), jax.random.key(0))


def _requests(cfg, lens=(5, 11, 7, 9), max_new=10, **kw):
    return [
        GenRequest(rid=i,
                   prompt=np.random.RandomState(10 + i).randint(
                       2, cfg.vocab, ln),
                   max_new=max_new, **kw)
        for i, ln in enumerate(lens)
    ]


def _engine(cfg, params, **kw):
    kw.setdefault("slots", 2)
    kw.setdefault("max_len", 96)
    kw.setdefault("block", 4)
    return Engine(cfg, params, **kw)


@pytest.fixture(scope="module")
def reference(cfg, params):
    """Fault-free greedy streams: the byte-identity oracle."""
    res = _engine(cfg, params).run(_requests(cfg))
    assert all(r.status == "ok" for r in res)
    return {r.rid: r.tokens for r in res}


# --------------------------------------------------------------------------
# the fault registry itself
# --------------------------------------------------------------------------


def test_fault_registry_basics():
    plan = FaultPlan(FaultSpec("train.step", at=2, times=2))
    fired = [plan.hit("train.step") is not None for _ in range(6)]
    assert fired == [False, False, True, True, False, False]
    assert plan.fired["train.step"] == 2
    assert plan.hits("train.step") == 6

    forever = FaultPlan(FaultSpec("ckpt.save", at=1, times=None))
    assert [forever.hit("ckpt.save") is not None for _ in range(4)] == \
        [False, True, True, True]

    with pytest.raises(InjectedFault, match="drafter.propose"):
        FaultPlan(FaultSpec("drafter.propose")).raise_if("drafter.propose")

    # typos fail loudly on BOTH sides of the contract
    with pytest.raises(ValueError, match="unknown fault point"):
        FaultSpec("engine.nonexistent")
    with pytest.raises(ValueError, match="unregistered"):
        FaultPlan().hit("engine.nonexistent")
    with pytest.raises(ValueError):
        FaultSpec("train.step", at=-1)
    with pytest.raises(ValueError):
        FaultSpec("train.step", times=0)


def test_parse_fault_cli_syntax():
    s = parse_fault("engine.nan_state@1:0")
    assert (s.point, s.at, s.times, s.arg) == ("engine.nan_state", 1, 1, 0.0)
    s = parse_fault("drafter.propose@2+")
    assert (s.point, s.at, s.times) == ("drafter.propose", 2, None)
    s = parse_fault("engine.slow_block:0.2")
    assert (s.point, s.at, s.times, s.arg) == ("engine.slow_block", 0, 1, 0.2)
    assert parse_fault("ckpt.save") == FaultSpec("ckpt.save")
    with pytest.raises(ValueError):
        parse_fault("bogus.point")


# --------------------------------------------------------------------------
# request lifecycle: admission validation, statuses, cancel, deadlines
# --------------------------------------------------------------------------


def test_admission_validation_statuses(cfg, params, reference):
    """Malformed requests get status="error" results; valid neighbours in
    the same run are untouched."""
    good = _requests(cfg)[:2]
    bad = [
        GenRequest(rid=10, prompt=np.array([cfg.vocab + 5, 1]), max_new=4),
        GenRequest(rid=11, prompt=np.array([], np.int64), max_new=4),
        GenRequest(rid=12, prompt=np.array([0.5, 1.5]), max_new=4),
        GenRequest(rid=13, prompt=np.arange(2, 6), max_new=0),
        GenRequest(rid=14, prompt=np.arange(2, 6), max_new=10_000),
    ]
    res = _engine(cfg, params).run(good + bad)
    by = {r.rid: r for r in res}
    for r in good:
        assert by[r.rid].status == "ok"
        assert by[r.rid].tokens == reference[r.rid]
    for r in bad:
        assert by[r.rid].status == "error", r.rid
        assert by[r.rid].tokens == []
    assert "vocab" in by[10].error
    assert "max_new" in by[13].error
    assert "max_len" in by[14].error


def test_admission_token_reaches_commit(cfg, params):
    """The admission-sampled token goes through _commit: a first-token EOS
    or max_new=1 finishes at admission, with zero decode blocks."""
    prompt = _requests(cfg)[0].prompt
    # discover the greedy first token with a plain solo run
    probe = _engine(cfg, params).run(
        [GenRequest(rid=0, prompt=prompt, max_new=2)]
    )[0]
    first = probe.tokens[0]

    eng = _engine(cfg, params)
    res = eng.run([
        GenRequest(rid=0, prompt=prompt, max_new=1),
        GenRequest(rid=1, prompt=prompt, max_new=1, eos_id=first),
    ])
    assert [r.tokens for r in res] == [[first], [first]]
    assert all(r.status == "ok" for r in res)
    assert eng.stats["decode_s"] == 0.0  # no block ever ran


def test_duplicate_rids_still_raise(cfg, params):
    reqs = _requests(cfg)[:2]
    reqs[1] = dataclasses.replace(reqs[1], rid=reqs[0].rid)
    with pytest.raises(ValueError, match="unique"):
        _engine(cfg, params).run(reqs)


def test_cancel_lifecycle(cfg, params):
    eng = _engine(cfg, params)
    reqs = _requests(cfg)
    # pre-cancel a queued rid: rejected at its admission attempt
    assert eng.cancel(reqs[3].rid) is True
    # cancel a live slot mid-stream
    eng.admit(0, reqs[0])
    eng.step_block()
    assert eng.cancel(reqs[0].rid) is True
    r0 = eng.results[reqs[0].rid]
    assert r0.status == "cancelled"
    assert 0 < len(r0.tokens) <= reqs[0].max_new  # partial stream kept
    assert not eng.active[0]  # the slot was freed
    # drain the rest through run(); the pre-cancelled rid never admits
    res = eng.run(reqs[1:])
    by = {r.rid: r.status for r in res}
    assert by[reqs[3].rid] == "cancelled"
    assert by[reqs[1].rid] == by[reqs[2].rid] == "ok"
    # cancelling a finished request is a no-op
    assert eng.cancel(reqs[1].rid) is False
    assert eng.stats["cancelled"] == 2


def test_deadline_expiry_mid_stream(cfg, params):
    """deadline_s=0.0 admitted directly: the first block sweep times the
    slot out with its partial stream; the co-resident slot is unharmed."""
    eng = _engine(cfg, params)
    reqs = _requests(cfg, max_new=20)
    eng.admit(0, dataclasses.replace(reqs[0], deadline_s=0.0))
    eng.admit(1, reqs[1])
    eng.step_block()
    r0 = eng.results[reqs[0].rid]
    assert r0.status == "timeout"
    assert 0 < len(r0.tokens) < 20
    assert "deadline" in r0.error
    assert eng.active[1] and not eng.active[0]
    assert eng.stats["timeouts"] == 1


def test_deadline_expiry_before_admission(cfg, params, reference):
    """A queued request whose budget is already spent never admits; the
    others are byte-identical to the fault-free run."""
    reqs = _requests(cfg)
    reqs[1] = dataclasses.replace(reqs[1], deadline_s=0.0)
    res = _engine(cfg, params).run(reqs)
    by = {r.rid: r for r in res}
    assert by[1].status == "timeout" and by[1].tokens == []
    for rid in (0, 2, 3):
        assert by[rid].status == "ok"
        assert by[rid].tokens == reference[rid]


def test_slow_block_plus_deadline(cfg, params):
    """engine.slow_block makes every block overshoot a small budget: all
    requests finish as timeouts with partial streams, nothing raises."""
    eng = _engine(
        cfg, params,
        faults=FaultPlan(FaultSpec("engine.slow_block", at=0, times=None,
                                   arg=0.05)),
    )
    res = eng.run(_requests(cfg, lens=(5, 11), max_new=50,
                            deadline_s=0.04))
    assert all(r.status == "timeout" for r in res)
    assert all(len(r.tokens) < 50 for r in res)
    # the first request always admits (its budget starts at run() entry)
    # and times out mid-stream with the partial it decoded; later ones may
    # expire while still queued (empty stream) depending on compile time
    assert len(res[0].tokens) > 0


# --------------------------------------------------------------------------
# per-request failure isolation
# --------------------------------------------------------------------------


def test_injected_prefill_failure_isolates(cfg, params, reference):
    """The 2nd admission attempt fails; every other request is
    byte-identical to the fault-free run and run() does not raise."""
    eng = _engine(cfg, params,
                  faults=FaultPlan(FaultSpec("engine.prefill", at=1)))
    res = eng.run(_requests(cfg))
    by = {r.rid: r for r in res}
    failed = [r.rid for r in res if r.status == "error"]
    assert len(failed) == 1
    assert "injected fault" in by[failed[0]].error
    for r in res:
        if r.status == "ok":
            assert r.tokens == reference[r.rid]
    assert eng.stats["errors"] == 1


@pytest.mark.parametrize("spec", [None, SpecConfig(k=3, drafter="ngram")],
                         ids=["plain", "spec"])
def test_nan_quarantine_isolates(cfg, params, reference, spec):
    """Poisoning slot 1's state before the 2nd block quarantines exactly
    that request (status="error", partial stream) while slot 0 and the
    queued requests are byte-identical to the fault-free run — in both
    plain and speculative mode."""
    eng = _engine(cfg, params, spec=spec,
                  faults=FaultPlan(FaultSpec("engine.nan_state", at=1,
                                             arg=1)))
    res = eng.run(_requests(cfg))
    by = {r.rid: r for r in res}
    bad = [r for r in res if r.status == "error"]
    assert len(bad) == 1
    assert "quarantined" in bad[0].error
    assert len(bad[0].tokens) < 10  # the pre-fault partial stream
    for r in res:
        if r.status == "ok":
            assert r.tokens == reference[r.rid], r.rid
    assert eng.stats["quarantined"] == 1
    assert eng.stats["errors"] == 1


def test_decode_block_crash_fails_open(cfg, params, monkeypatch):
    """Even a crash of the jitted decode block itself stays inside run():
    every live request gets a status="error" result, and the engine
    remains usable for the next batch."""
    eng = _engine(cfg, params)
    reqs = _requests(cfg)

    def boom(*a, **k):
        raise RuntimeError("simulated XLA failure")

    orig = eng._decode_block
    monkeypatch.setattr(eng, "_decode_block", boom)
    res = eng.run(reqs[:2])
    assert all(r.status == "error" for r in res)
    assert all("decode block failed" in r.error for r in res)
    # recover the block and serve fresh traffic on the same engine
    monkeypatch.setattr(eng, "_decode_block", orig)
    res2 = eng.run(reqs[2:])
    assert all(r.status == "ok" for r in res2)


# --------------------------------------------------------------------------
# circuit breaker: spec -> plain fallback
# --------------------------------------------------------------------------


def test_drafter_crash_falls_back_to_plain(cfg, params, reference):
    """A permanently-crashing drafter trips the breaker; output is
    token-for-token the plain greedy stream (never a lost token)."""
    eng = _engine(
        cfg, params, spec=SpecConfig(k=3, drafter="ngram"),
        faults=FaultPlan(FaultSpec("drafter.propose", at=0, times=None)),
    )
    res = eng.run(_requests(cfg))
    assert all(r.status == "ok" for r in res)
    for r in res:
        assert r.tokens == reference[r.rid]
    assert eng.stats["breaker_trips"] >= 1
    assert eng.stats["spec_rounds"] == 0  # no round ever completed
    assert eng.breaker["state"] == "open"


def test_breaker_half_open_recovery(cfg, params, reference):
    """One transient drafter crash: trip -> cooldown of plain blocks ->
    half-open probe succeeds -> breaker re-closes and spec resumes.
    Exactness holds across the whole episode."""
    eng = _engine(
        cfg, params,
        spec=SpecConfig(k=3, drafter="ngram", breaker_cooldown_blocks=1,
                        breaker_zero_rounds=100),  # isolate the crash path
        faults=FaultPlan(FaultSpec("drafter.propose", at=0, times=1)),
    )
    res = eng.run(_requests(cfg))
    assert all(r.status == "ok" for r in res)
    for r in res:
        assert r.tokens == reference[r.rid]
    assert eng.stats["breaker_trips"] == 1
    assert eng.stats["spec_rounds"] > 0  # resumed after recovery
    assert eng.breaker["state"] == "closed"


def test_breaker_zero_acceptance_trip(cfg, params, reference):
    """A drafter that is always wrong trips the breaker on repeated
    zero-acceptance rounds (no exception needed) — degradation is by
    uselessness, not just by crash."""
    from repro.serving.spec.drafters import Drafter

    class WrongDrafter(Drafter):
        def admit(self, slot, tokens):
            pass

        def commit(self, slot, tokens):
            pass

        def propose(self, slot_ids, k):
            # token 1 is never the greedy continuation for these prompts
            return np.ones((len(slot_ids), k), np.int32), None

    eng = _engine(
        cfg, params,
        spec=SpecConfig(k=3, drafter=WrongDrafter(),
                        breaker_zero_rounds=2,
                        breaker_cooldown_blocks=100),
    )
    res = eng.run(_requests(cfg))
    assert all(r.status == "ok" for r in res)
    for r in res:
        assert r.tokens == reference[r.rid]
    assert eng.stats["breaker_trips"] >= 1
    assert eng.breaker["state"] == "open"
    # it DID try speculating before giving up
    assert eng.stats["spec_rounds"] >= 2


# --------------------------------------------------------------------------
# combined chaos (the acceptance criterion)
# --------------------------------------------------------------------------


def test_combined_chaos_run(cfg, params, reference):
    """Drafter crash + NaN slot + expired deadline in ONE spec run:
    uninjected requests byte-identical to the fault-free run, injected
    ones get the right non-ok statuses, the engine never raises."""
    reqs = _requests(cfg)
    reqs[0] = dataclasses.replace(reqs[0], deadline_s=0.0)  # expires queued
    eng = _engine(
        cfg, params, spec=SpecConfig(k=3, drafter="ngram"),
        faults=FaultPlan(
            FaultSpec("drafter.propose", at=0, times=None),
            FaultSpec("engine.nan_state", at=2, arg=1),
        ),
    )
    res = eng.run(reqs)
    by = {r.rid: r for r in res}
    assert by[0].status == "timeout" and by[0].tokens == []
    statuses = sorted(r.status for r in res)
    assert statuses.count("error") == 1  # exactly one quarantined
    assert eng.stats["quarantined"] == 1
    assert eng.stats["breaker_trips"] >= 1
    for r in res:
        if r.status == "ok":
            assert r.tokens == reference[r.rid], r.rid
    assert len([r for r in res if r.status == "ok"]) == 2


# --------------------------------------------------------------------------
# checkpoint failure domain
# --------------------------------------------------------------------------


def test_async_save_failure_surfaces_on_wait(tmp_path):
    """An exception in the async save thread is captured and re-raised as
    CheckpointError from the next wait(); the manager stays usable."""
    mgr = CheckpointManager(str(tmp_path), keep=2,
                            faults=FaultPlan(FaultSpec("ckpt.save", at=0)))
    tree = {"w": jnp.arange(3.0)}
    mgr.save(1, tree)
    with pytest.raises(CheckpointError, match="step 1"):
        mgr.wait()
    mgr.save(2, tree)  # the plan only fired once: this save succeeds
    mgr.wait()
    assert mgr.latest_step() == 2


def test_async_save_failure_surfaces_on_next_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2,
                            faults=FaultPlan(FaultSpec("ckpt.save", at=0)))
    mgr.save(1, {"w": jnp.zeros(2)})
    with pytest.raises(CheckpointError, match="async checkpoint save"):
        mgr.save(2, {"w": jnp.zeros(2)})


def test_checksum_roundtrip_and_corruption(tmp_path):
    """Manifests carry per-leaf crc32; a clean save restores, a corrupted
    leaf file fails loudly naming the damage."""
    import json

    tree = {"a": np.arange(12.0).reshape(3, 4), "b": np.int32(7)}
    path = save_checkpoint(str(tmp_path), 3, tree)
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    assert all("crc32" in info for info in manifest["leaves"].values())
    restored, _ = restore_checkpoint(str(tmp_path), tree)
    np.testing.assert_array_equal(np.asarray(restored["a"]), tree["a"])

    # corrupt via the manager's fault point (sync save for determinism)
    mgr = CheckpointManager(
        str(tmp_path / "c"), keep=2,
        faults=FaultPlan(FaultSpec("ckpt.corrupt", at=0)),
        async_save=False,
    )
    mgr.save(5, tree)
    with pytest.raises(CheckpointError, match="checksum mismatch"):
        mgr.restore(tree)


def test_checksum_backcompat_without_crc(tmp_path):
    """Pre-checksum manifests (no crc32 field) still restore."""
    import json

    tree = {"w": np.arange(4.0)}
    path = save_checkpoint(str(tmp_path), 1, tree)
    mpath = os.path.join(path, "manifest.json")
    with open(mpath) as f:
        manifest = json.load(f)
    for info in manifest["leaves"].values():
        info.pop("crc32", None)
    with open(mpath, "w") as f:
        json.dump(manifest, f)
    restored, _ = restore_checkpoint(str(tmp_path), tree)
    np.testing.assert_array_equal(np.asarray(restored["w"]), tree["w"])


def test_ft_loop_restart_via_registry(tmp_path):
    """The FT loop consumes the same registry: train.step at=5 kills the
    first run; a fresh loop resumes from the checkpoint and matches an
    uninterrupted run exactly."""
    from repro.data.pipeline import DataConfig, SyntheticStream
    from repro.runtime.ft import FaultTolerantLoop

    def step_fn(params, opt_state, batch):
        return {"w": params["w"] + batch["tokens"].sum()}, opt_state, \
            {"loss": jnp.zeros(())}

    stream = SyntheticStream(DataConfig(vocab=50, seq_len=4, global_batch=2,
                                        seed=3))
    p0 = {"w": jnp.zeros((), jnp.int64)}
    ref = p0
    for s in range(8):
        ref, _, _ = step_fn(ref, None, stream.batch(s))

    ck = str(tmp_path / "ck")
    loop = FaultTolerantLoop(
        step_fn, stream, ck, ckpt_every=2,
        faults=FaultPlan(FaultSpec("train.step", at=5)),
        log=lambda *_: None,
    )
    with pytest.raises(InjectedFault, match="train.step"):
        loop.run(p0, None, 8)
    loop2 = FaultTolerantLoop(step_fn, stream, ck, ckpt_every=2,
                              log=lambda *_: None)
    params, _, last = loop2.run(p0, None, 8)
    assert last == 7
    assert int(params["w"]) == int(ref["w"])


# --------------------------------------------------------------------------
# doc sync
# --------------------------------------------------------------------------


def test_fault_catalog_documented():
    """Every registered fault point is named in DESIGN.md §12 — the chaos
    catalog is user-facing API, not test plumbing."""
    with open(os.path.join(REPO, "docs", "DESIGN.md")) as f:
        design = f.read()
    for point in FAULT_POINTS:
        assert point in design, f"fault point {point!r} missing in DESIGN.md"


# --------------------------------------------------------------------------
# sharded chaos (subprocess: 8 host devices)
# --------------------------------------------------------------------------


@pytest.mark.subprocess
def test_sharded_nan_quarantine_matches_fault_free():
    """Quarantine under a (2,4) mesh: the poisoned slot fails alone and
    the surviving requests match a fault-free sharded run exactly."""
    body = """
        import dataclasses, functools
        import jax, numpy as np
        from repro.configs import get_config
        from repro.distributed import sharding as shd
        from repro.launch.mesh import make_mesh
        from repro.models import lm
        from repro.models.param import init_params
        from repro.runtime.faults import FaultPlan, FaultSpec
        from repro.serving import Engine, GenRequest

        base = get_config("hla-1b", reduced=True).replace(mixer="hla2")
        cfg = base.replace(
            n_layers=1, d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
            vocab=64,
            hla=dataclasses.replace(base.hla, chunk=16),
        )
        mesh = make_mesh()
        with mesh:
            specs = lm.lm_specs(cfg)
            params = jax.jit(
                functools.partial(init_params, specs),
                out_shardings=shd.param_shardings(specs, mesh),
            )(jax.random.key(0))

            def reqs():
                return [
                    GenRequest(rid=i,
                               prompt=np.random.RandomState(10 + i)
                               .randint(2, 64, ln), max_new=8)
                    for i, ln in enumerate((5, 11, 7))
                ]

            clean = Engine(cfg, params, slots=2, max_len=96, block=4,
                           mesh=mesh)
            ref = {r.rid: r.tokens for r in clean.run(reqs())}

            eng = Engine(cfg, params, slots=2, max_len=96, block=4,
                         mesh=mesh,
                         faults=FaultPlan(FaultSpec("engine.nan_state",
                                                    at=1, arg=1)))
            res = eng.run(reqs())
            bad = [r for r in res if r.status == "error"]
            assert len(bad) == 1, [r.status for r in res]
            assert eng.stats["quarantined"] == 1
            for r in res:
                if r.status == "ok":
                    assert r.tokens == ref[r.rid], r.rid
        print("OK")
    """
    from test_distributed import run_py

    out = run_py(body)
    assert "OK" in out
