"""Property tests (hypothesis): associativity / identity / scan-prefix laws.

Includes the two documented errata: the paper's printed decay-aware
concatenations (HLA2 masked ⊕_γ, AHLA ⊕_AHLA-γ) are NOT associative; the
corrected operators used by this framework are.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (CI installs it)"
)
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.monoid import (
    AHLADecayState,
    HLA2DecayState,
    HLA3ScanState,
    ahla_op_decay,
    ahla_op_decay_paper,
    masked_op_decay,
    masked_op_decay_paper,
    hla3_op,
)

D, DV = 3, 2
SETTINGS = dict(max_examples=25, deadline=None)


def _rand_hla2(rs):
    return HLA2DecayState(
        S=jnp.asarray(rs.randn(D, D)),
        C=jnp.asarray(rs.randn(D, DV)),
        m=jnp.asarray(rs.randn(D)),
        G=jnp.asarray(rs.randn(D, DV)),
        h=jnp.asarray(rs.randn(D)),
        rho=jnp.asarray(rs.uniform(0.5, 0.99)),
    )


def _rand_ahla(rs):
    return AHLADecayState(
        R=jnp.asarray(rs.randn(D, D)),
        P=jnp.asarray(rs.randn(D, DV)),
        m=jnp.asarray(rs.randn(D)),
        E=jnp.asarray(rs.randn(D, DV)),
        n=jnp.asarray(rs.randn(D)),
        rho=jnp.asarray(rs.uniform(0.5, 0.99)),
    )


def _rand_hla3(rs):
    return HLA3ScanState(
        SK=jnp.asarray(rs.randn(D, D)),
        SQ=jnp.asarray(rs.randn(D, D)),
        P=jnp.asarray(rs.randn(D, DV)),
        m=jnp.asarray(rs.randn(D)),
        F=jnp.asarray(rs.randn(D, DV)),
        eta=jnp.asarray(rs.randn(D)),
        RQP=jnp.asarray(rs.randn(D, DV)),
        rQm=jnp.asarray(rs.randn(D)),
        UKQ=jnp.asarray(rs.randn(D, D)),
        W4=jnp.asarray(rs.randn(D, D, D, DV)),
        W3=jnp.asarray(rs.randn(D, D, D)),
    )


def _assert_state_close(a, b, tol=1e-9):
    for f in a._fields:
        np.testing.assert_allclose(getattr(a, f), getattr(b, f), atol=tol, rtol=tol)


def _assert_state_differs(a, b, min_diff=1e-6):
    worst = max(
        float(jnp.max(jnp.abs(getattr(a, f) - getattr(b, f)))) for f in a._fields
    )
    assert worst > min_diff


@given(st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_hla2_masked_decay_op_associative(seed):
    rs = np.random.RandomState(seed)
    x, y, z = _rand_hla2(rs), _rand_hla2(rs), _rand_hla2(rs)
    _assert_state_close(
        masked_op_decay(masked_op_decay(x, y), z),
        masked_op_decay(x, masked_op_decay(y, z)),
    )


@given(st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_hla2_masked_decay_identity(seed):
    rs = np.random.RandomState(seed)
    x = _rand_hla2(rs)
    e = HLA2DecayState(
        S=jnp.zeros((D, D)), C=jnp.zeros((D, DV)), m=jnp.zeros(D),
        G=jnp.zeros((D, DV)), h=jnp.zeros(D), rho=jnp.asarray(1.0),
    )
    _assert_state_close(masked_op_decay(e, x), x)
    _assert_state_close(masked_op_decay(x, e), x)


@given(st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_erratum_paper_hla2_decay_op_not_associative(seed):
    """The paper's printed ⊕_γ (Section 4.2) fails associativity."""
    rs = np.random.RandomState(seed)
    x, y, z = _rand_hla2(rs), _rand_hla2(rs), _rand_hla2(rs)
    _assert_state_differs(
        masked_op_decay_paper(masked_op_decay_paper(x, y), z),
        masked_op_decay_paper(x, masked_op_decay_paper(y, z)),
    )


@given(st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_ahla_decay_op_associative(seed):
    rs = np.random.RandomState(seed)
    x, y, z = _rand_ahla(rs), _rand_ahla(rs), _rand_ahla(rs)
    _assert_state_close(
        ahla_op_decay(ahla_op_decay(x, y), z),
        ahla_op_decay(x, ahla_op_decay(y, z)),
    )


@given(st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_erratum_paper_ahla_decay_op_not_associative(seed):
    rs = np.random.RandomState(seed)
    x, y, z = _rand_ahla(rs), _rand_ahla(rs), _rand_ahla(rs)
    _assert_state_differs(
        ahla_op_decay_paper(ahla_op_decay_paper(x, y), z),
        ahla_op_decay_paper(x, ahla_op_decay_paper(y, z)),
    )


@given(st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_hla3_op_associative(seed):
    """⊗3 (Theorem 7.2) is associative — with materialized segment maps."""
    rs = np.random.RandomState(seed)
    x, y, z = _rand_hla3(rs), _rand_hla3(rs), _rand_hla3(rs)
    _assert_state_close(
        hla3_op(hla3_op(x, y), z), hla3_op(x, hla3_op(y, z)), tol=1e-8
    )


@given(st.integers(0, 2**31 - 1), st.integers(2, 12))
@settings(**SETTINGS)
def test_scan_prefix_equals_serial_fold(seed, n):
    """Exclusive-scan prefixes == left fold (Theorem 4.1 / Remark 4.2)."""
    rs = np.random.RandomState(seed)
    elems = [_rand_hla2(rs) for _ in range(n)]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *elems)
    inc = jax.lax.associative_scan(masked_op_decay, stacked, axis=0)
    acc = elems[0]
    for t in range(1, n):
        acc = masked_op_decay(acc, elems[t])
        got = jax.tree.map(lambda x: x[t], inc)
        _assert_state_close(got, acc, tol=1e-8)
