"""Per-arch smoke tests (deliverable f): reduced configs, one forward +
one train step on CPU, asserting output shapes + no NaNs; decode paths."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models import lm, param, whisper

ALL_ARCHS = [a for a in list_archs() if a != "hla-1b"]


def _finite(x):
    return bool(jnp.all(jnp.isfinite(x)))


def _inputs(cfg, rng, B=2, n=16):
    tokens = jnp.asarray(rng.randint(0, cfg.vocab, (B, n)))
    labels = jnp.asarray(rng.randint(0, cfg.vocab, (B, n)))
    extras = {}
    if cfg.vis_tokens:
        extras["vis_embed"] = jnp.asarray(
            rng.randn(B, cfg.vis_tokens, cfg.d_model) * 0.1, jnp.float32
        )
    if cfg.enc_layers:
        extras["frames"] = jnp.asarray(
            rng.randn(B, cfg.enc_frames, cfg.d_model) * 0.1, jnp.float32
        )
    return tokens, labels, extras


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_arch_forward_and_train_step(rng, arch):
    cfg = get_config(arch, reduced=True)
    B, n = 2, 16
    tokens, labels, extras = _inputs(cfg, rng, B, n)

    if cfg.enc_layers:
        specs = whisper.whisper_specs(cfg)
        params = param.init_params(specs, jax.random.key(0))
        logits, _, _ = whisper.whisper_apply(
            params, tokens, extras["frames"], cfg
        )
        assert logits.shape == (B, n, cfg.vocab)
        assert _finite(logits)
        (loss, _), grads = jax.value_and_grad(
            lambda p: whisper.whisper_loss(
                p, tokens, labels, extras["frames"], cfg
            ),
            has_aux=True,
        )(params)
    else:
        specs = lm.lm_specs(cfg)
        params = param.init_params(specs, jax.random.key(0))
        vis = extras.get("vis_embed")
        logits, _, _ = lm.lm_apply(params, tokens, cfg, vis_embed=vis)
        exp_n = n + (cfg.vis_tokens or 0)
        assert logits.shape == (B, exp_n, cfg.vocab)
        assert _finite(logits)
        (loss, _), grads = jax.value_and_grad(
            lambda p: lm.lm_loss(p, tokens, labels, cfg, vis_embed=vis),
            has_aux=True,
        )(params)
    assert np.isfinite(float(loss))
    for g in jax.tree.leaves(grads):
        assert _finite(g)


@pytest.mark.parametrize(
    "arch,mixer",
    [
        ("qwen2-72b", "hla2"),
        ("deepseek-67b", "ahla"),
        ("nemotron-4-15b", "hla3"),
        ("codeqwen1.5-7b", "linattn"),
        ("granite-moe-3b-a800m", "hla2"),
        ("jamba-1.5-large-398b", "hla2"),
    ],
)
def test_hla_dropin_override(rng, arch, mixer):
    """Paper §5.2: HLA swaps in for the attention sublayer of any arch."""
    cfg = get_config(arch, reduced=True, mixer=mixer)
    tokens, labels, _ = _inputs(cfg, rng)
    specs = lm.lm_specs(cfg)
    params = param.init_params(specs, jax.random.key(0))
    (loss, _), grads = jax.value_and_grad(
        lambda p: lm.lm_loss(p, tokens, labels, cfg), has_aux=True
    )(params)
    assert np.isfinite(float(loss))


def test_rwkv6_rejects_hla_override():
    with pytest.raises(ValueError, match="attention-free"):
        get_config("rwkv6-7b", reduced=True, mixer="hla2")


@pytest.mark.parametrize(
    "arch", ["hla-1b", "rwkv6-7b", "jamba-1.5-large-398b", "codeqwen1.5-7b"]
)
def test_decode_matches_full_forward(rng, arch):
    """serve_step semantics: token-by-token decode == full forward.

    MoE capacity is raised so no tokens drop: capacity-based dropping is
    train-path-only (per-row capacity), while one-token decode never
    drops — an expected, documented divergence otherwise."""
    import dataclasses

    cfg = get_config(arch, reduced=True)
    if cfg.moe is not None:
        cfg = cfg.replace(
            moe=dataclasses.replace(cfg.moe, capacity_factor=16.0)
        )
    B, n = 2, 8
    tokens, _, _ = _inputs(cfg, rng, B, n)
    specs = lm.lm_specs(cfg)
    params = param.init_params(specs, jax.random.key(1))
    logits_full, _, _ = lm.lm_apply(params, tokens, cfg, mode="train")
    states = lm.lm_init_states(cfg, B, n)
    outs = []
    for t in range(n):
        lg, states, _ = lm.lm_apply(
            params, tokens[:, t : t + 1], cfg, states=states,
            positions=jnp.full((B, 1), t), mode="decode",
        )
        outs.append(lg)
    dec = jnp.concatenate(outs, 1)
    np.testing.assert_allclose(
        np.asarray(dec, np.float32), np.asarray(logits_full, np.float32),
        atol=5e-2, rtol=5e-2,
    )


def test_prefill_then_decode_continues(rng):
    """prefill fills state; decode continues identically to full forward."""
    cfg = get_config("hla-1b", reduced=True)
    B, n = 2, 12
    cut = 8
    tokens, _, _ = _inputs(cfg, rng, B, n)
    specs = lm.lm_specs(cfg)
    params = param.init_params(specs, jax.random.key(1))
    logits_full, _, _ = lm.lm_apply(params, tokens, cfg, mode="train")
    _, states, _ = lm.lm_apply(params, tokens[:, :cut], cfg, mode="prefill")
    outs = []
    for t in range(cut, n):
        lg, states, _ = lm.lm_apply(
            params, tokens[:, t : t + 1], cfg, states=states,
            positions=jnp.full((B, 1), t), mode="decode",
        )
        outs.append(lg)
    dec = jnp.concatenate(outs, 1)
    np.testing.assert_allclose(
        np.asarray(dec, np.float32),
        np.asarray(logits_full[:, cut:], np.float32),
        atol=5e-2, rtol=5e-2,
    )


def test_whisper_prefill_decode(rng):
    cfg = get_config("whisper-small", reduced=True)
    B, n = 2, 8
    tokens, _, extras = _inputs(cfg, rng, B, n)
    frames = extras["frames"]
    specs = whisper.whisper_specs(cfg)
    params = param.init_params(specs, jax.random.key(0))
    logits_full, _, _ = whisper.whisper_apply(params, tokens, frames, cfg)
    _, states, _ = whisper.whisper_apply(
        params, tokens[:, :4], frames, cfg, mode="prefill"
    )
    outs = []
    for t in range(4, n):
        lg, states, _ = whisper.whisper_apply(
            params, tokens[:, t : t + 1], None, cfg, states=states,
            positions=jnp.full((B, 1), t), mode="decode",
        )
        outs.append(lg)
    dec = jnp.concatenate(outs, 1)
    np.testing.assert_allclose(
        np.asarray(dec, np.float32),
        np.asarray(logits_full[:, 4:], np.float32),
        atol=5e-2, rtol=5e-2,
    )


def test_moe_dispatch_matches_dense_oracle(rng):
    from repro.models import moe as moe_mod
    from repro.models.config import MoEConfig

    cfg = get_config("granite-moe-3b-a800m", reduced=True).replace(
        moe=MoEConfig(n_experts=4, top_k=2, d_ff=64, capacity_factor=8.0)
    )  # capacity high enough that nothing is dropped
    specs = moe_mod.moe_specs(cfg)
    params = param.init_params(specs, jax.random.key(3))
    x = jnp.asarray(rng.randn(2, 8, cfg.d_model) * 0.3, jnp.float32)
    y, aux = moe_mod.moe_apply(params, x, cfg)
    y_ref = moe_mod.moe_dense_oracle(params, x, cfg)
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(y_ref), atol=1e-4, rtol=1e-4
    )
    assert np.isfinite(float(aux))
