import os

# Tests run single-device (the dry-run sets its own device count in a
# subprocess).  Force deterministic, quiet CPU execution.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", True)  # oracles at fp64 in tests

import numpy as np  # noqa: E402
import pytest  # noqa: E402


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "subprocess: spawns fresh python processes with a multi-device "
        'host mesh (slow lane; skip with -m "not subprocess")',
    )


@pytest.fixture
def rng():
    return np.random.RandomState(0)


def make_qkv(rng, B=2, H=2, n=24, d=6, dv=5, scale=0.5, dtype=np.float64):
    import jax.numpy as jnp

    q = jnp.asarray(rng.randn(B, H, n, d) * scale, dtype)
    k = jnp.asarray(rng.randn(B, H, n, d) * scale, dtype)
    v = jnp.asarray(rng.randn(B, H, n, dv) * scale, dtype)
    gam = jnp.asarray(rng.uniform(0.85, 0.99, (B, H)), dtype)
    return q, k, v, gam
