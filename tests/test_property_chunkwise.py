"""Hypothesis sweep: chunkwise == serial for random shapes/chunks/decay.

Catches ragged-tail padding, carry-state and per-head-decay edge cases
beyond the fixed-shape tests (deliverable c: property tests on the
system's invariants).
"""

import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (CI installs it)"
)
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.ahla import ahla_chunkwise, ahla_serial
from repro.core.hla2 import hla2_chunkwise, hla2_serial
from repro.core.hla3 import hla3_exact_chunkwise, hla3_exact_serial

SETTINGS = dict(max_examples=12, deadline=None)


def _mk(seed, n, d, dv, decay):
    rs = np.random.RandomState(seed)
    q = jnp.asarray(rs.randn(1, 2, n, d) * 0.5)
    k = jnp.asarray(rs.randn(1, 2, n, d) * 0.5)
    v = jnp.asarray(rs.randn(1, 2, n, dv) * 0.5)
    g = jnp.asarray(rs.uniform(0.7, 0.999, (1, 2))) if decay else None
    return q, k, v, g


@given(
    st.integers(0, 2**31 - 1),
    st.integers(1, 33),  # n
    st.sampled_from([1, 2, 3, 5, 8, 16]),  # chunk
    st.sampled_from([2, 5, 8]),  # d
    st.sampled_from([1, 3, 8]),  # dv
    st.booleans(),  # decay
    st.booleans(),  # normalize
)
@settings(**SETTINGS)
def test_hla2_chunkwise_equals_serial(seed, n, chunk, d, dv, decay, norm):
    q, k, v, g = _mk(seed, n, d, dv, decay)
    o_s, st_s = hla2_serial(q, k, v, g, normalize=norm)
    o_c, st_c = hla2_chunkwise(q, k, v, g, chunk=chunk, normalize=norm)
    np.testing.assert_allclose(o_c, o_s, atol=1e-8, rtol=1e-7)
    for a, b in zip(st_c, st_s):
        np.testing.assert_allclose(a, b, atol=1e-8, rtol=1e-7)


@given(
    st.integers(0, 2**31 - 1),
    st.integers(1, 25),
    st.sampled_from([1, 3, 8]),
    st.booleans(),
)
@settings(**SETTINGS)
def test_ahla_chunkwise_equals_serial(seed, n, chunk, decay):
    q, k, v, g = _mk(seed, n, 5, 4, decay)
    o_s, st_s = ahla_serial(q, k, v, g)
    o_c, st_c = ahla_chunkwise(q, k, v, g, chunk=chunk)
    np.testing.assert_allclose(o_c, o_s, atol=1e-8, rtol=1e-7)
    for a, b in zip(st_c, st_s):
        np.testing.assert_allclose(a, b, atol=1e-8, rtol=1e-7)


@given(
    st.integers(0, 2**31 - 1),
    st.integers(1, 20),
    st.sampled_from([1, 4, 7]),
    st.booleans(),
)
@settings(**SETTINGS)
def test_hla3_exact_chunkwise_equals_serial(seed, n, chunk, decay):
    q, k, v, g = _mk(seed, n, 4, 3, decay)
    o_s, _ = hla3_exact_serial(q, k, v, g)
    o_c, _ = hla3_exact_chunkwise(q, k, v, g, chunk=chunk)
    np.testing.assert_allclose(o_c, o_s, atol=1e-8, rtol=1e-7)
