"""Loop-aware HLO analyzer: unit tests on synthetic HLO text + an
end-to-end check that scan trip counts are honored."""

import textwrap

from repro.analysis.hlo_analysis import analyze, parse_hlo

SYNTH = textwrap.dedent("""
    HloModule test

    %body (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
      %p = (s32[], f32[8,16]{1,0}) parameter(0)
      %iv = s32[] get-tuple-element(%p), index=0
      %x = f32[8,16]{1,0} get-tuple-element(%p), index=1
      %w = f32[16,16]{1,0} constant({...})
      %y = f32[8,16]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %ar = f32[8,16]{1,0} all-reduce(%y), replica_groups={}
      %one = s32[] constant(1)
      %niv = s32[] add(%iv, %one)
      ROOT %t = (s32[], f32[8,16]{1,0}) tuple(%niv, %ar)
    }

    %cond (p: (s32[], f32[8,16])) -> pred[] {
      %p = (s32[], f32[8,16]{1,0}) parameter(0)
      %iv = s32[] get-tuple-element(%p), index=0
      %n = s32[] constant(10)
      ROOT %lt = pred[] compare(%iv, %n), direction=LT
    }

    ENTRY %main (a: f32[8,16]) -> f32[8,16] {
      %a = f32[8,16]{1,0} parameter(0)
      %zero = s32[] constant(0)
      %init = (s32[], f32[8,16]{1,0}) tuple(%zero, %a)
      %w = (s32[], f32[8,16]{1,0}) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"10"}}
      ROOT %out = f32[8,16]{1,0} get-tuple-element(%w), index=1
    }
""")


def test_parse_structure():
    comps, entry = parse_hlo(SYNTH)
    assert entry == "%main"
    assert "%body" in comps and "%cond" in comps
    body = comps["%body"]
    kinds = {op.kind for op in body.ops}
    assert "dot" in kinds and "all-reduce" in kinds


def test_trip_count_multiplies_flops_and_collectives():
    res = analyze(SYNTH)
    # dot: 2 * (8*16) * 16 = 4096 flops, x10 trips
    assert res["flops"] == 10 * 2 * 8 * 16 * 16
    # all-reduce: 8*16*4 bytes output, x10
    assert res["collective_bytes"]["all-reduce"] == 10 * 8 * 16 * 4
    assert res["collective_counts"]["all-reduce"] == 10


def test_end_to_end_scan_counts():
    """A jitted lax.scan with L iterations reports ~L x the body flops."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    L, m = 7, 32
    Ws = jnp.asarray(np.random.RandomState(0).randn(L, m, m), jnp.float32)
    x = jnp.ones((4, m), jnp.float32)

    @jax.jit
    def f(x, Ws):
        def body(h, w):
            return jnp.tanh(h @ w), None

        h, _ = jax.lax.scan(body, x, Ws)
        return h

    txt = f.lower(x, Ws).compile().as_text()
    res = analyze(txt)
    expect = L * 2 * 4 * m * m
    assert 0.9 * expect <= res["flops"] <= 1.5 * expect, (
        res["flops"], expect
    )
