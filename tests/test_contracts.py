"""Lowered-HLO trace contracts (repro.analysis.contracts).

Two halves:

* toy-program tests that PLANT each violation (an f64 upcast, a dropped
  donation, a host callback) and assert the contract catches it — the
  detector itself is under test;
* the real thing: all four hot entry points (train step, prefill,
  decode block, spec round) lowered on CPU for the small contract
  config, asserting no-f64 + donation + no-host-transfers +
  zero-collectives + stable-HLO-across-the-padded-length-set.

Everything here is lower-only: no entry point is ever executed.  The
conftest enables x64 for the fp64 test oracles, so the real entry
points lower under ``jax.experimental.disable_x64()`` — exactly the
default runtime configuration the contracts describe.
"""

import jax
import jax.numpy as jnp
import pytest

from repro.analysis.contracts import (
    check_entry_points,
    check_hlo,
    donated_aliases,
    f64_ops,
    hlo_fingerprint,
    host_transfer_ops,
    lower_compiled_text,
    pad_to_bucket,
    prefill_hlo,
    default_config,
)

# --------------------------------------------------------------------------
# planted violations: the detectors must catch what they claim to
# --------------------------------------------------------------------------


def test_planted_f64_is_caught():
    def bad(x):
        # a silent upcast: the exact bug the no-f64 contract exists for
        return (x.astype(jnp.float64) * 2.0).sum()

    hlo = lower_compiled_text(
        bad, (jax.ShapeDtypeStruct((8,), jnp.float32),)
    )
    assert f64_ops(hlo)
    report = check_hlo("planted_f64", hlo)
    assert not report.ok
    assert any("f64" in v for v in report.violations)


def test_planted_dropped_donation_is_caught():
    def shrink(s):
        # output shape != donated input shape: XLA cannot alias it
        return s[:4] * 1.0

    with pytest.warns(UserWarning, match="donated buffers"):
        hlo = lower_compiled_text(
            shrink, (jax.ShapeDtypeStruct((8,), jnp.float32),),
            donate_argnums=(0,),
        )
    assert donated_aliases(hlo) == {}
    report = check_hlo("planted_drop", hlo, expected_donations=1)
    assert not report.ok
    assert any("donation" in v for v in report.violations)


def test_honored_donation_passes():
    def step(s, x):
        return s + x, (x * x).sum()

    hlo = lower_compiled_text(
        step,
        (jax.ShapeDtypeStruct((8, 4), jnp.float32),
         jax.ShapeDtypeStruct((8, 4), jnp.float32)),
        donate_argnums=(0,),
    )
    assert len(donated_aliases(hlo)) == 1
    assert check_hlo("ok_donation", hlo, expected_donations=1).ok


def test_host_transfer_detection_on_synthetic_hlo():
    # detector-level check on a handcrafted module: outfeed + a host
    # callback custom-call are both transfers, a gemm custom-call is not
    hlo = """\
HloModule synthetic, entry_computation_layout={(f32[4]{0})->f32[4]{0}}

ENTRY %main (p0: f32[4]) -> f32[4] {
  %p0 = f32[4]{0} parameter(0)
  %cc = f32[4]{0} custom-call(%p0), custom_call_target="xla_python_cpu_callback"
  %of = token[] outfeed(%p0)
  ROOT %r = f32[4]{0} custom-call(%cc), custom_call_target="__onednn$matmul"
}
"""
    found = host_transfer_ops(hlo)
    assert len(found) == 2
    assert not check_hlo("synthetic", hlo).ok


def test_alias_parsing_multiple_entries():
    hlo = ("HloModule m, input_output_alias={ {0}: (0, {}, may-alias), "
           "{1}: (2, {}, must-alias) }, "
           "entry_computation_layout={()->()}\n")
    assert donated_aliases(hlo) == {0: "0", 2: "1"}


def test_fingerprint_ignores_comments_only():
    a = "ENTRY %m {\n  %x = f32[4] parameter(0)\n}"
    b = "// a comment\nENTRY %m {\n  %x = f32[4] parameter(0)\n}"
    c = "ENTRY %m {\n  %x = f32[8] parameter(0)\n}"
    assert hlo_fingerprint(a) == hlo_fingerprint(b)
    assert hlo_fingerprint(a) != hlo_fingerprint(c)


def test_pad_to_bucket():
    assert pad_to_bucket(1, 16) == 16
    assert pad_to_bucket(16, 16) == 16
    assert pad_to_bucket(17, 16) == 32


# --------------------------------------------------------------------------
# the four hot entry points (lower-only, small config)
# --------------------------------------------------------------------------


@pytest.fixture(scope="module")
def entry_reports():
    with jax.experimental.disable_x64():
        return {r.name: r for r in check_entry_points()}


def test_all_four_entry_points_covered(entry_reports):
    assert sorted(entry_reports) == [
        "decode_block", "prefill", "spec_round", "train_step",
    ]


@pytest.mark.parametrize(
    "name", ["train_step", "prefill", "decode_block", "spec_round"]
)
def test_entry_point_contracts_hold(entry_reports, name):
    r = entry_reports[name]
    assert r.ok, f"{name} violated: {r.violations}"
    assert r.collective_total == 0  # single-device contract config


def test_donations_actually_alias(entry_reports):
    # train step donates params+opt_state; decode/spec donate the decode
    # state (+ tokens/positions); prefill donates nothing
    assert entry_reports["train_step"].n_aliased > 0
    assert entry_reports["decode_block"].n_aliased > 0
    assert entry_reports["spec_round"].n_aliased > 0
    assert entry_reports["prefill"].n_aliased == 0


def test_same_bucket_lowers_identically():
    # the recompilation-hazard detector's core claim, asserted directly:
    # two prefills at the same padded length are byte-identical programs
    cfg = default_config()
    with jax.experimental.disable_x64():
        n = pad_to_bucket(5, cfg.hla.chunk)
        fp1 = hlo_fingerprint(prefill_hlo(cfg, prompt_len=n))
        fp2 = hlo_fingerprint(prefill_hlo(cfg, prompt_len=n))
        fp_other = hlo_fingerprint(
            prefill_hlo(cfg, prompt_len=2 * cfg.hla.chunk)
        )
    assert fp1 == fp2
    assert fp1 != fp_other  # different bucket really is a new program
