"""HLA2: equivalence of all four computation views (paper Thm 3.1 / 4.1)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hla2 import (
    HLA2State,
    hla2_chunkwise,
    hla2_naive,
    hla2_scan,
    hla2_serial,
    hla2_step,
    hla2_init_state,
)
from conftest import make_qkv

TOL = dict(atol=1e-8, rtol=1e-8)


@pytest.mark.parametrize("use_gamma", [False, True])
@pytest.mark.parametrize("normalize", [False, True])
@pytest.mark.parametrize("lam", [0.0, 0.3])
def test_all_views_agree(rng, use_gamma, normalize, lam):
    q, k, v, gam = make_qkv(rng)
    gamma = gam if use_gamma else None
    o0 = hla2_naive(q, k, v, gamma, normalize=normalize, lam=lam)
    o1, s1 = hla2_serial(q, k, v, gamma, normalize=normalize, lam=lam)
    o2, s2 = hla2_scan(q, k, v, gamma, normalize=normalize, lam=lam)
    o3, s3 = hla2_chunkwise(q, k, v, gamma, chunk=8, normalize=normalize, lam=lam)
    o4, _ = hla2_chunkwise(q, k, v, gamma, chunk=7, normalize=normalize, lam=lam)
    for o in (o1, o2, o3, o4):
        np.testing.assert_allclose(o, o0, **TOL)
    for s in (s2, s3):
        for f in HLA2State._fields:
            np.testing.assert_allclose(getattr(s, f), getattr(s1, f), **TOL)


def test_unnormalized_matches_masked_matrix_form(rng):
    """Direct check of Theorem 3.1: o_t = row_t[((W W^T) . L) V]."""
    q, k, v, _ = make_qkv(rng, B=1, H=1, n=16)
    n = q.shape[-2]
    L = jnp.tril(jnp.ones((n, n)))
    W = jnp.einsum("bhtd,bhjd->bhtj", q, k) * L
    T2 = jnp.einsum("bhti,bhji->bhtj", W, W) * L
    o_ref = jnp.einsum("bhtj,bhje->bhte", T2, v)
    o, _ = hla2_serial(q, k, v)
    np.testing.assert_allclose(o, o_ref, **TOL)


def test_carry_state_continuation(rng):
    q, k, v, gam = make_qkv(rng)
    o_full, s_full = hla2_serial(q, k, v, gam)
    cut = 10
    o_a, st = hla2_chunkwise(
        q[..., :cut, :], k[..., :cut, :], v[..., :cut, :], gam, chunk=5
    )
    o_b, s_b = hla2_chunkwise(
        q[..., cut:, :], k[..., cut:, :], v[..., cut:, :], gam, chunk=7,
        state=st,
    )
    np.testing.assert_allclose(
        jnp.concatenate([o_a, o_b], -2), o_full, **TOL
    )
    for f in HLA2State._fields:
        np.testing.assert_allclose(getattr(s_b, f), getattr(s_full, f), **TOL)
    # scan path accepts the same carry
    o_b2, _ = hla2_scan(
        q[..., cut:, :], k[..., cut:, :], v[..., cut:, :], gam, state=st
    )
    np.testing.assert_allclose(o_b2, o_full[..., cut:, :], **TOL)


def test_decode_step_matches_sequence(rng):
    """Streaming one-token decode (view A) reproduces full-sequence rows."""
    q, k, v, gam = make_qkv(rng, n=12)
    o_full, _ = hla2_serial(q, k, v, gam, normalize=True)
    st = hla2_init_state(q.shape[:-2], q.shape[-1], v.shape[-1], jnp.float64)
    outs = []
    for t in range(q.shape[-2]):
        st, o_t = hla2_step(
            st, q[..., t, :], k[..., t, :], v[..., t, :], gam, normalize=True
        )
        outs.append(o_t)
    np.testing.assert_allclose(jnp.stack(outs, -2), o_full, **TOL)


@pytest.mark.parametrize("impl", ["serial", "scan", "chunkwise"])
def test_gradients_agree_with_naive(rng, impl):
    from repro.core.hla2 import hla2

    q, k, v, gam = make_qkv(rng, n=16)

    def loss_with(fn):
        def f(args):
            q_, k_, v_ = args
            out = fn(q_, k_, v_)
            return jnp.sum(out**2)

        return jax.grad(f)((q, k, v))

    g_ref = loss_with(lambda a, b, c: hla2_naive(a, b, c, gam, normalize=True))
    g = loss_with(
        lambda a, b, c: hla2(a, b, c, gam, impl=impl, chunk=8, normalize=True)[0]
    )
    for x, y in zip(g, g_ref):
        np.testing.assert_allclose(x, y, atol=1e-7, rtol=1e-6)


def test_linear_attention_reduction(rng):
    """Paper §3 'Connection with linear attention': S^K = I reduces the
    normalized output to first-order linear attention with kernel q_t.q_i."""
    q, k, v, _ = make_qkv(rng, n=12)
    n, d = q.shape[-2], q.shape[-1]
    # emulate S_t == I by patching the streaming formulas directly:
    # num_t = q_t^T C_t, den_t = q_t^T m_t.
    L = jnp.tril(jnp.ones((n, n)))
    Wqq = jnp.einsum("bhtd,bhjd->bhtj", q, q) * L
    o_ref = jnp.einsum("bhtj,bhje->bhte", Wqq, v) / (
        jnp.sum(Wqq, -1)[..., None] + 1e-6
    )
    # lam-only path (S = 0 via zero keys) with lam = 1 gives exactly that
    o, _ = hla2_serial(q, jnp.zeros_like(k), v, None, normalize=True, lam=1.0)
    np.testing.assert_allclose(o, o_ref, **TOL)


def test_bf16_inputs_fp32_state(rng):
    q, k, v, gam = make_qkv(rng, dtype=np.float32)
    qb, kb, vb = (x.astype(jnp.bfloat16) for x in (q, k, v))
    o_ref, _ = hla2_chunkwise(q, k, v, gam, chunk=8)
    o_b, st = hla2_chunkwise(qb, kb, vb, gam, chunk=8)
    assert o_b.dtype == jnp.bfloat16
    assert st.S.dtype == jnp.float32  # state accumulates in fp32
    # bf16 inputs quantize; just require the result to be close-ish
    np.testing.assert_allclose(
        np.asarray(o_b, np.float32), np.asarray(o_ref), atol=0.2, rtol=0.2
    )
