"""Gradient correctness of the fused Pallas training path (interpret mode).

Deliverables pinned here:

* the fused VJP — forward checkpoints per-chunk incoming states, backward
  walks the chunk axis in reverse — matches ``jax.grad`` of the O(n^2)
  naive oracles to <= 1e-4 (fp32) across the full {gamma, normalize, lam}
  grid, for both HLA2 and AHLA;
* the fused backward matches the chunk-level jnp oracle in ``kernels.ref``
  (same shared per-chunk math, vmapped instead of gridded);
* arbitrary (non-chunk-multiple) sequence lengths work through the public
  API, values and gradients.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.ahla import ahla_naive
from repro.core.hla2 import hla2_naive
from repro.kernels import ref as kref
from repro.kernels.ahla_chunk import ahla_chunk_bwd_pallas, ahla_chunk_pallas
from repro.kernels.hla2_chunk import hla2_chunk_bwd_pallas, hla2_chunk_pallas
from repro.kernels.ops import ahla_attention, hla2_attention

TOL = 1e-4


def _mk(rng, B, H, n, d, dv):
    q = jnp.asarray(rng.randn(B, H, n, d) * 0.5, jnp.float32)
    k = jnp.asarray(rng.randn(B, H, n, d) * 0.5, jnp.float32)
    v = jnp.asarray(rng.randn(B, H, n, dv) * 0.5, jnp.float32)
    g = jnp.asarray(rng.uniform(0.85, 0.99, (B, H)), jnp.float32)
    return q, k, v, g


def _assert_close(got, want, tol=TOL, msg=""):
    got = np.asarray(got, np.float64)
    want = np.asarray(want, np.float64)
    err = np.max(np.abs(got - want)) / (np.max(np.abs(want)) + 1.0)
    assert err <= tol, f"{msg}: rel err {err:.3e} > {tol:.0e}"


@pytest.mark.parametrize("use_gamma", [False, True])
@pytest.mark.parametrize("normalize", [False, True])
@pytest.mark.parametrize("lam", [0.0, 0.2])
def test_hla2_fused_vjp_matches_naive_grad(rng, use_gamma, normalize, lam):
    B, H, n, d = 1, 2, 32, 8
    q, k, v, g = _mk(rng, B, H, n, d, d)
    gamma = g if use_gamma else None
    do = jnp.asarray(rng.randn(B, H, n, d), jnp.float32)

    def loss_fused(q_, k_, v_, g_):
        o = hla2_attention(
            q_, k_, v_, g_, chunk=8, normalize=normalize, lam=lam,
            use_pallas=True, fused_bwd=True,
        )
        return jnp.sum(o * do)

    def loss_naive(q_, k_, v_, g_):
        o = hla2_naive(q_, k_, v_, g_, normalize=normalize, lam=lam)
        return jnp.sum(o * do)

    if gamma is None:
        got = jax.grad(
            lambda a, b, c: loss_fused(a, b, c, None), argnums=(0, 1, 2)
        )(q, k, v)
        want = jax.grad(
            lambda a, b, c: loss_naive(a, b, c, None), argnums=(0, 1, 2)
        )(q, k, v)
        names = ("dq", "dk", "dv")
    else:
        got = jax.grad(loss_fused, argnums=(0, 1, 2, 3))(q, k, v, gamma)
        want = jax.grad(loss_naive, argnums=(0, 1, 2, 3))(q, k, v, gamma)
        names = ("dq", "dk", "dv", "dgamma")
    for a, b, nm in zip(got, want, names):
        _assert_close(a, b, msg=nm)


@pytest.mark.parametrize("use_gamma", [False, True])
@pytest.mark.parametrize("normalize", [False, True])
def test_ahla_fused_vjp_matches_naive_grad(rng, use_gamma, normalize):
    B, H, n, d = 1, 2, 32, 8
    q, k, v, g = _mk(rng, B, H, n, d, d)
    gamma = g if use_gamma else None
    do = jnp.asarray(rng.randn(B, H, n, d), jnp.float32)

    def loss_fused(q_, k_, v_, g_):
        o = ahla_attention(
            q_, k_, v_, g_, chunk=8, normalize=normalize,
            use_pallas=True, fused_bwd=True,
        )
        return jnp.sum(o * do)

    def loss_naive(q_, k_, v_, g_):
        o = ahla_naive(q_, k_, v_, g_, normalize=normalize)
        return jnp.sum(o * do)

    if gamma is None:
        got = jax.grad(
            lambda a, b, c: loss_fused(a, b, c, None), argnums=(0, 1, 2)
        )(q, k, v)
        want = jax.grad(
            lambda a, b, c: loss_naive(a, b, c, None), argnums=(0, 1, 2)
        )(q, k, v)
        names = ("dq", "dk", "dv")
    else:
        got = jax.grad(loss_fused, argnums=(0, 1, 2, 3))(q, k, v, gamma)
        want = jax.grad(loss_naive, argnums=(0, 1, 2, 3))(q, k, v, gamma)
        names = ("dq", "dk", "dv", "dgamma")
    for a, b, nm in zip(got, want, names):
        _assert_close(a, b, msg=nm)


@pytest.mark.parametrize("kernel", ["hla2", "ahla"])
def test_bwd_kernel_matches_chunk_oracle(rng, kernel):
    """Fused bwd kernel vs the chunk-level jnp oracle in kernels.ref."""
    BH, n, d, chunk = 3, 48, 8, 16
    q = jnp.asarray(rng.randn(BH, n, d) * 0.5, jnp.float32)
    k = jnp.asarray(rng.randn(BH, n, d) * 0.5, jnp.float32)
    v = jnp.asarray(rng.randn(BH, n, d) * 0.5, jnp.float32)
    g = jnp.asarray(rng.uniform(0.85, 0.99, (BH,)), jnp.float32)
    do = jnp.asarray(rng.randn(BH, n, d), jnp.float32)
    if kernel == "hla2":
        _, _, cs = hla2_chunk_pallas(
            q, k, v, g, chunk=chunk, interpret=True, save_chunk_states=True
        )
        got = hla2_chunk_bwd_pallas(
            q, k, v, g, do, cs, chunk=chunk, interpret=True
        )
        want = kref.hla2_chunk_bwd_ref(q, k, v, g, do, chunk=chunk)
    else:
        _, _, cs = ahla_chunk_pallas(
            q, k, v, g, chunk=chunk, interpret=True, save_chunk_states=True
        )
        got = ahla_chunk_bwd_pallas(
            q, k, v, g, do, cs, chunk=chunk, interpret=True
        )
        want = kref.ahla_chunk_bwd_ref(q, k, v, g, do, chunk=chunk)
    for a, b, nm in zip(got, want, ("dq", "dk", "dv", "dgamma")):
        _assert_close(a, b, tol=1e-5, msg=nm)


@pytest.mark.parametrize("fn", [hla2_chunk_pallas, ahla_chunk_pallas])
def test_kernel_accepts_arbitrary_length(rng, fn):
    """n not a chunk multiple: wrappers pad + slice; state matches ref."""
    BH, n, d, chunk = 2, 40, 8, 16  # 40 = 2.5 chunks
    q = jnp.asarray(rng.randn(BH, n, d) * 0.5, jnp.float32)
    k = jnp.asarray(rng.randn(BH, n, d) * 0.5, jnp.float32)
    v = jnp.asarray(rng.randn(BH, n, d) * 0.5, jnp.float32)
    g = jnp.asarray(rng.uniform(0.85, 0.99, (BH,)), jnp.float32)
    ref_fn = (
        kref.hla2_chunk_ref if fn is hla2_chunk_pallas else kref.ahla_chunk_ref
    )
    for gamma in (None, g):
        o, st = fn(q, k, v, gamma, chunk=chunk, interpret=True)
        o_ref, st_ref = ref_fn(q, k, v, gamma, chunk=chunk)
        np.testing.assert_allclose(
            np.asarray(o), np.asarray(o_ref), atol=1e-4, rtol=1e-4
        )
        for a, b in zip(st, st_ref):
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), atol=1e-4, rtol=1e-4
            )


def test_public_api_arbitrary_length_grads(rng):
    """Values + gradients through hla2_attention at a ragged length."""
    B, H, n, d = 1, 2, 40, 8  # 40 = 2.5 chunks of 16
    q, k, v, g = _mk(rng, B, H, n, d, d)
    do = jnp.asarray(rng.randn(B, H, n, d), jnp.float32)

    def loss(q_, k_, v_, g_, fused):
        o = hla2_attention(
            q_, k_, v_, g_, chunk=16, use_pallas=fused, fused_bwd=fused
        )
        return jnp.sum(o * do)

    o_fused = hla2_attention(q, k, v, g, chunk=16, use_pallas=True)
    o_ref = hla2_attention(q, k, v, g, chunk=16, use_pallas=False)
    _assert_close(o_fused, o_ref, msg="fwd")
    got = jax.grad(lambda *a: loss(*a, True), argnums=(0, 1, 2, 3))(q, k, v, g)
    want = jax.grad(lambda *a: loss(*a, False), argnums=(0, 1, 2, 3))(
        q, k, v, g
    )
    for a, b, nm in zip(got, want, ("dq", "dk", "dv", "dgamma")):
        _assert_close(a, b, msg=nm)


def test_fused_bwd_off_matches_fused_bwd_on(rng):
    """The legacy recompute-in-backward path stays available and agrees."""
    B, H, n, d = 1, 2, 32, 8
    q, k, v, g = _mk(rng, B, H, n, d, d)

    def loss(q_, k_, v_, g_, fused_bwd):
        o = hla2_attention(
            q_, k_, v_, g_, chunk=8, use_pallas=True, fused_bwd=fused_bwd
        )
        return jnp.sum(o**2)

    got = jax.grad(lambda *a: loss(*a, True), argnums=(0, 1, 2, 3))(q, k, v, g)
    want = jax.grad(lambda *a: loss(*a, False), argnums=(0, 1, 2, 3))(
        q, k, v, g
    )
    for a, b, nm in zip(got, want, ("dq", "dk", "dv", "dgamma")):
        _assert_close(a, b, msg=nm)
