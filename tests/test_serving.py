"""Serving subsystem: state pool, engine, sampling + scan-impl equivalence."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import lm
from repro.models.param import init_params
from repro.serving import Engine, GenRequest, SamplingConfig, StatePool, sample


def _params(cfg, seed=0):
    return init_params(lm.lm_specs(cfg), jax.random.key(seed))


def test_scan_impl_matches_chunkwise(rng):
    """mixer impl='scan' (paper Blelloch path) == impl='chunkwise'."""
    cfg_c = get_config("hla-1b", reduced=True)
    cfg_s = cfg_c.replace(hla=dataclasses.replace(cfg_c.hla, impl="scan"))
    specs = lm.lm_specs(cfg_c)
    params = init_params(specs, jax.random.key(0))
    tokens = jnp.asarray(rng.randint(0, cfg_c.vocab, (2, 16)))
    lc, _, _ = lm.lm_apply(params, tokens, cfg_c)
    ls, _, _ = lm.lm_apply(params, tokens, cfg_s)
    np.testing.assert_allclose(
        np.asarray(lc, np.float32), np.asarray(ls, np.float32),
        atol=1e-3, rtol=1e-3,
    )


def test_lm_prefill_incremental_matches_full(rng):
    """Prefill resumed from a mid-prompt carry == one-shot prefill."""
    cfg = get_config("hla-1b", reduced=True)
    params = _params(cfg)
    toks = jnp.asarray(rng.randint(0, cfg.vocab, (1, 12)))
    lg_full, st_full = lm.lm_prefill(params, toks, cfg)
    _, st1 = lm.lm_prefill(params, toks[:, :7], cfg)
    lg2, st2 = lm.lm_prefill(
        params, toks[:, 7:], cfg, states=st1,
        positions=jnp.arange(7, 12)[None],
    )
    np.testing.assert_allclose(
        np.asarray(lg2, np.float32), np.asarray(lg_full, np.float32),
        atol=1e-3, rtol=1e-3,
    )
    for ref, got in zip(jax.tree.leaves(st_full), jax.tree.leaves(st2)):
        np.testing.assert_allclose(
            np.asarray(ref, np.float32), np.asarray(got, np.float32),
            atol=1e-4, rtol=1e-3,
        )


# --------------------------------------------------------------------------
# StatePool: structural slot-axis detection
# --------------------------------------------------------------------------


def test_state_pool_slot_axis_regression():
    """Regression for the old serve.py restore heuristic.

    The legacy loop restored other slots with ``leaf.shape[1] == slots`` —
    tree surgery keyed on a *coincidence of extents*.  Leaf ``a`` below has
    its slot axis at 0 while its axis-1 extent equals the slot count, so
    the heuristic picks the wrong axis and cross-contaminates slots.  The
    pool derives axes structurally (slots vs slots+1 probe) instead.
    """
    slots = 3

    def make(n):
        return {
            "a": jnp.zeros((n, slots)),  # slot axis 0; shape[1] == slots!
            "b": jnp.zeros((4, n, slots)),  # slot axis 1
            "shared": jnp.zeros((5,)),  # no slot axis
        }

    pool = StatePool(make, slots)
    assert pool.slot_axes == [0, 1, None]

    # the legacy heuristic would have chosen axis 1 for leaf "a"
    legacy_axis = 1 if make(slots)["a"].shape[1] == slots else None
    assert legacy_axis != pool.slot_axes[0]

    ones = jax.tree.map(jnp.ones_like, pool.empty_slot_state())
    pool.write_slot(1, ones)
    a = np.asarray(pool.states["a"])
    b = np.asarray(pool.states["b"])
    # only slot 1's data changed, along the *structural* axis
    assert (a[1] == 1).all() and (a[[0, 2]] == 0).all()
    assert (b[:, 1] == 1).all() and (b[:, [0, 2]] == 0).all()
    assert (np.asarray(pool.states["shared"]) == 0).all()

    # round-trip + eviction
    got = pool.read_slot(1)
    assert (np.asarray(got["a"]) == 1).all()
    pool.reset_slot(1)
    assert (np.asarray(pool.states["a"]) == 0).all()


def test_state_pool_lm_states():
    """Pool over real stacked LM decode states; KV scalar length is shared."""
    cfg = get_config("hla-1b", reduced=True)
    pool = StatePool(lambda n: lm.lm_init_states(cfg, n, 32), slots=4)
    # every HLA2 state leaf is (layers, slot, head, ...) -> slot axis 1
    assert all(ax == 1 for ax in pool.slot_axes)

    cfg_sm = cfg.replace(mixer="softmax")
    pool_sm = StatePool(lambda n: lm.lm_init_states(cfg_sm, n, 32), slots=4)
    # KVCache.length is stacked (layers,) — slot-independent => no slot axis
    assert None in pool_sm.slot_axes and 1 in pool_sm.slot_axes


# --------------------------------------------------------------------------
# Engine: continuous batching
# --------------------------------------------------------------------------


def test_engine_recycled_slot_reproduces(rng):
    """Same prompt re-admitted into a recycled slot regenerates exactly."""
    cfg = get_config("hla-1b", reduced=True)
    engine = Engine(cfg, _params(cfg), slots=1, max_len=32, block=4)
    prompt = rng.randint(2, cfg.vocab, 5)
    reqs = [
        GenRequest(rid=0, prompt=prompt, max_new=6),
        GenRequest(rid=1, prompt=rng.randint(2, cfg.vocab, 5), max_new=6),
        GenRequest(rid=2, prompt=prompt, max_new=6),
    ]
    r0, r1, r2 = engine.run(reqs)
    assert len(r0.tokens) == 6 and len(r1.tokens) == 6
    assert r0.tokens == r2.tokens  # slot reset/overwrite is complete


def test_engine_admission_never_perturbs_live_slots(rng):
    """A mid-stream admission must not change a live slot's continuation."""
    cfg = get_config("hla-1b", reduced=True)
    params = _params(cfg)
    prompt_a = rng.randint(2, cfg.vocab, 5)
    prompt_b = rng.randint(2, cfg.vocab, 5)

    # reference: A decodes alone
    solo = Engine(cfg, params, slots=2, max_len=32, block=4)
    (ra,) = solo.run([GenRequest(rid=0, prompt=prompt_a, max_new=12)])

    # A decodes one block, then B is admitted into the other slot
    eng = Engine(cfg, params, slots=2, max_len=32, block=4)
    eng.admit(0, GenRequest(rid=0, prompt=prompt_a, max_new=12))
    eng.step_block()
    eng.admit(1, GenRequest(rid=1, prompt=prompt_b, max_new=8))
    while eng.active.any():
        eng.step_block()
    assert eng.results[0].tokens == ra.tokens
    assert len(eng.results[1].tokens) == 8


def test_engine_ragged_prompts_and_throughput_stats(rng):
    cfg = get_config("hla-1b", reduced=True)
    engine = Engine(cfg, _params(cfg), slots=2, max_len=64, block=4)
    reqs = [
        GenRequest(rid=i, prompt=rng.randint(2, cfg.vocab, ln), max_new=5)
        for i, ln in enumerate([3, 9, 9])
    ]
    results = engine.run(reqs)
    assert [len(r.tokens) for r in results] == [5, 5, 5]
    assert engine.stats["generated_tokens"] == 15
    assert len(engine.stats["ttft_s"]) == 3
    assert engine.stats["decode_s"] > 0


def test_engine_rejects_kv_cache_archs():
    cfg = get_config("hla-1b", reduced=True).replace(mixer="softmax")
    with pytest.raises(ValueError, match="per-slot lengths"):
        Engine(cfg, None, slots=2, max_len=16)


# --------------------------------------------------------------------------
# Sampling
# --------------------------------------------------------------------------


def test_sampling_greedy_and_seeded(rng):
    logits = jnp.asarray(rng.randn(4, 32), jnp.float32)
    key = jax.random.key(0)
    g = sample(logits, key, SamplingConfig(method="greedy"))
    assert (np.asarray(g) == np.argmax(np.asarray(logits), -1)).all()

    t1 = sample(logits, key, SamplingConfig(method="temperature", temperature=0.8))
    t2 = sample(logits, key, SamplingConfig(method="temperature", temperature=0.8))
    assert (np.asarray(t1) == np.asarray(t2)).all()  # same seed, same draw

    tk = sample(logits, key, SamplingConfig(method="top_k", top_k=2))
    top2 = np.argsort(np.asarray(logits), -1)[:, -2:]
    assert all(int(t) in top2[i] for i, t in enumerate(np.asarray(tk)))

    with pytest.raises(ValueError):
        sample(logits, key, SamplingConfig(method="top_k", top_k=0))
