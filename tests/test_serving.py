"""Serving loop + paper-faithful scan-impl equivalence tests."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import lm
from repro.models.param import init_params


def test_scan_impl_matches_chunkwise(rng):
    """mixer impl='scan' (paper Blelloch path) == impl='chunkwise'."""
    cfg_c = get_config("hla-1b", reduced=True)
    cfg_s = cfg_c.replace(hla=dataclasses.replace(cfg_c.hla, impl="scan"))
    specs = lm.lm_specs(cfg_c)
    params = init_params(specs, jax.random.key(0))
    tokens = jnp.asarray(rng.randint(0, cfg_c.vocab, (2, 16)))
    lc, _, _ = lm.lm_apply(params, tokens, cfg_c)
    ls, _, _ = lm.lm_apply(params, tokens, cfg_s)
    np.testing.assert_allclose(
        np.asarray(lc, np.float32), np.asarray(ls, np.float32),
        atol=1e-3, rtol=1e-3,
    )


def test_server_continuous_batching(rng):
    """Slots admit/recycle; per-slot state reset isolates requests."""
    from repro.launch.serve import Server

    cfg = get_config("hla-1b", reduced=True)
    params = init_params(lm.lm_specs(cfg), jax.random.key(0))
    srv = Server(cfg, params, slots=2, max_len=32)

    prompt_a = rng.randint(2, cfg.vocab, 5)
    prompt_b = rng.randint(2, cfg.vocab, 5)
    srv.admit(0, prompt_a)
    srv.admit(1, prompt_b)
    for _ in range(4):
        srv.step()
    out_a1 = list(srv.outputs[0])

    # recycle slot 0 with the same prompt: outputs must reproduce exactly
    # (state reset works) even though slot 1 keeps decoding
    srv.admit(0, prompt_a)
    for _ in range(4):
        srv.step()
    assert srv.outputs[0] == out_a1
    assert len(srv.outputs[1]) == 8  # slot 1 never stalled
