"""Speculative decoding: exactness, rollback, drafters, sampling laws.

The load-bearing property (DESIGN.md §10): speculative GREEDY decode is
token-for-token identical to plain greedy decode — for every streaming
mixer variant, regardless of what the drafter proposes, where rejections
land, or how ragged the prompt lengths are.  Acceptance only ever changes
*how many* target calls are made, never *which tokens* come out.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import lm
from repro.models import mixer as mixer_mod
from repro.models.param import init_params
from repro.serving import (
    Engine,
    GenRequest,
    SamplingConfig,
    SpecConfig,
    StatePool,
)
from repro.serving.spec import HLADrafter, NGramDrafter
from repro.serving.spec.drafters import Drafter

VARIANTS = ("hla2", "ahla", "hla3", "linattn")


def _cfg(mixer="hla2", decay="learned", normalize=False):
    base = get_config("hla-1b", reduced=True).replace(mixer=mixer)
    return base.replace(
        n_layers=1, d_model=32, n_heads=2, n_kv_heads=2, d_ff=64, vocab=64,
        hla=dataclasses.replace(
            base.hla, decay=decay, normalize=normalize, chunk=16
        ),
    )


def _params(cfg, seed=0):
    return init_params(lm.lm_specs(cfg), jax.random.key(seed))


def _requests(cfg, rng, lens=(5, 11, 7), max_new=10):
    return [
        GenRequest(rid=i, prompt=rng.randint(2, cfg.vocab, ln),
                   max_new=max_new)
        for i, ln in enumerate(lens)
    ]


def _run_pair(cfg, seed, spec):
    """(plain greedy results, speculative greedy results, spec engine)."""
    params = _params(cfg)
    reqs = lambda: _requests(cfg, np.random.RandomState(seed))  # noqa: E731
    plain = Engine(cfg, params, slots=2, max_len=96, block=4)
    rp = plain.run(reqs())
    eng = Engine(cfg, params, slots=2, max_len=96, block=4, spec=spec)
    rs = eng.run(reqs())
    return rp, rs, eng


# --------------------------------------------------------------------------
# exactness: spec greedy == plain greedy
# --------------------------------------------------------------------------


@pytest.mark.parametrize("variant", VARIANTS)
@pytest.mark.parametrize("decay", ["none", "learned"])
@pytest.mark.parametrize("normalize", [False, True])
def test_spec_greedy_exact(variant, decay, normalize):
    """Token-for-token equality across variants x gamma x normalize, with
    ragged prompt lengths and natural mid-stream rejections (the n-gram
    drafter misses until the model's continuation turns repetitive)."""
    rng = np.random.RandomState(0)
    cfg = _cfg(variant, decay, normalize)
    params = _params(cfg)
    reqs = lambda: _requests(cfg, np.random.RandomState(1))  # noqa: E731
    plain = Engine(cfg, params, slots=2, max_len=96, block=4)
    rp = plain.run(reqs())
    eng = Engine(cfg, params, slots=2, max_len=96, block=4,
                 spec=SpecConfig(k=3, drafter="ngram"))
    rs = eng.run(reqs())
    for a, b in zip(rp, rs):
        assert a.tokens == b.tokens, (variant, decay, normalize, a.rid)
    assert eng.stats["spec_rounds"] > 0


class _WrongDrafter(Drafter):
    """Adversarial: always proposes token 1 — near-guaranteed rejections."""

    def admit(self, slot, tokens):
        pass

    def commit(self, slot, tokens):
        pass

    def propose(self, slot_ids, k):
        return np.ones((len(slot_ids), k), np.int32), None


def test_spec_exact_under_constant_rejection():
    """Even a drafter that is (almost) always wrong must leave the output
    stream untouched — every round then exercises snapshot + rollback +
    accepted-prefix replay."""
    cfg = _cfg("hla2")
    rp, rs, eng = _run_pair(cfg, 2, SpecConfig(k=4, drafter=_WrongDrafter()))
    for a, b in zip(rp, rs):
        assert a.tokens == b.tokens
    assert eng.stats["spec_replays"] > 0
    # with drafts this bad, nearly every round rolls back
    assert eng.stats["spec_accepted"] <= eng.stats["spec_drafted"] // 2


def test_spec_exact_lm_drafter_and_self_draft_acceptance():
    """A random draft LM must not perturb outputs; drafting with the
    TARGET's own params accepts everything (q == p pointwise), which also
    pins the accept rule's direction."""
    cfg = _cfg("hla2")
    params = _params(cfg)
    reqs = lambda: _requests(cfg, np.random.RandomState(3), max_new=8)  # noqa: E731
    plain = Engine(cfg, params, slots=2, max_len=96, block=4)
    rp = plain.run(reqs())

    # a draft LM with its own (random) params and pool slots
    drafter = HLADrafter(_cfg("hla2"), None, slots=2, max_len=96, k=3,
                         seed=9)
    eng = Engine(cfg, params, slots=2, max_len=96, block=4,
                 spec=SpecConfig(k=3, drafter=drafter))
    rs = eng.run(reqs())
    for a, b in zip(rp, rs):
        assert a.tokens == b.tokens

    self_draft = HLADrafter(cfg, params, slots=2, max_len=96, k=3)
    eng2 = Engine(cfg, params, slots=2, max_len=96, block=4,
                  spec=SpecConfig(k=3, drafter=self_draft))
    rs2 = eng2.run(reqs())
    for a, b in zip(rp, rs2):
        assert a.tokens == b.tokens
    assert eng2.stats["spec_accepted"] == eng2.stats["spec_drafted"]
    assert eng2.stats["spec_replays"] == 0


def test_spec_greedy_exact_rwkv6():
    """rwkv6 rides the same verify path (jnp chunkwise prefill via the
    layer dispatch).  Also a regression for the init-state dtype bug:
    ``rwkv6_init_state`` hardcoded bf16 token-shift leaves, so ANY
    fp32-activation rwkv6 config crashed the decode scan (carry-in dtype
    != carry-out) — serving never worked for the reduced config."""
    from repro.configs import get_config

    cfg = get_config("rwkv6-7b", reduced=True)
    params = _params(cfg)
    reqs = lambda: _requests(cfg, np.random.RandomState(6), max_new=8)  # noqa: E731
    plain = Engine(cfg, params, slots=2, max_len=96, block=4)
    rp = plain.run(reqs())
    eng = Engine(cfg, params, slots=2, max_len=96, block=4,
                 spec=SpecConfig(k=3, drafter="ngram"))
    rs = eng.run(reqs())
    for a, b in zip(rp, rs):
        assert a.tokens == b.tokens


def test_spec_continuous_batching_mid_admission():
    """A slot admitted mid-stream must not change a live slot's
    speculative continuation (the plain-engine isolation property)."""
    cfg = _cfg("hla2")
    params = _params(cfg)
    rng = np.random.RandomState(4)
    pa, pb = rng.randint(2, cfg.vocab, 6), rng.randint(2, cfg.vocab, 9)
    spec = lambda: SpecConfig(k=3, drafter="ngram")  # noqa: E731

    solo = Engine(cfg, params, slots=2, max_len=96, block=4, spec=spec())
    (ra,) = solo.run([GenRequest(rid=0, prompt=pa, max_new=12)])

    eng = Engine(cfg, params, slots=2, max_len=96, block=4, spec=spec())
    eng.admit(0, GenRequest(rid=0, prompt=pa, max_new=12))
    eng.step_block()
    eng.admit(1, GenRequest(rid=1, prompt=pb, max_new=8))
    while eng.active.any():
        eng.step_block()
    assert eng.results[0].tokens == ra.tokens
    assert len(eng.results[1].tokens) == 8


# --------------------------------------------------------------------------
# speculative sampling (distribution-preserving path)
# --------------------------------------------------------------------------


def test_spec_sampling_seeded_and_committed_are_valid():
    """Non-greedy spec decode: deterministic per seed, commits the right
    counts, and full self-draft acceptance when q == p."""
    cfg = _cfg("hla2")
    params = _params(cfg)
    scfg = SamplingConfig(method="top_p", temperature=0.9, top_p=0.9)
    reqs = lambda: _requests(cfg, np.random.RandomState(5), max_new=8)  # noqa: E731

    def run(seed):
        eng = Engine(cfg, params, slots=2, max_len=96, block=4, seed=seed,
                     sampling=scfg, spec=SpecConfig(k=3, drafter="ngram"))
        return eng.run(reqs())

    r1, r2 = run(11), run(11)
    for a, b in zip(r1, r2):
        assert a.tokens == b.tokens  # same seed, same stream
        assert len(a.tokens) == 8
        assert all(0 <= t < cfg.vocab for t in a.tokens)

    # q == p => min(1, p/q) == 1: acceptance is total even when sampling
    drafter = HLADrafter(cfg, params, slots=2, max_len=96, k=3,
                         sampling=scfg, seed=0)
    assert drafter.emits_probs
    eng = Engine(cfg, params, slots=2, max_len=96, block=4, seed=11,
                 sampling=scfg, spec=SpecConfig(k=3, drafter=drafter))
    eng.run(reqs())
    assert eng.stats["spec_accepted"] == eng.stats["spec_drafted"]


def test_spec_greedy_engine_with_sampling_drafter():
    """A probs-emitting drafter (sampling draft law) under a GREEDY
    engine: q rides along but greedy acceptance ignores it, and the
    output must still equal plain greedy exactly.  Regression: the
    greedy verify closure used to reject the trailing q argument."""
    cfg = _cfg("hla2")
    params = _params(cfg)
    reqs = lambda: _requests(cfg, np.random.RandomState(8), max_new=8)  # noqa: E731
    plain = Engine(cfg, params, slots=2, max_len=96, block=4)
    rp = plain.run(reqs())
    drafter = HLADrafter(
        cfg, params, slots=2, max_len=96, k=3,
        sampling=SamplingConfig(method="temperature", temperature=0.8),
    )
    assert drafter.emits_probs
    eng = Engine(cfg, params, slots=2, max_len=96, block=4,
                 spec=SpecConfig(k=3, drafter=drafter))
    rs = eng.run(reqs())
    for a, b in zip(rp, rs):
        assert a.tokens == b.tokens


def test_spec_rejects_per_request_sampling_override():
    cfg = _cfg("hla2")
    eng = Engine(cfg, _params(cfg), slots=1, max_len=32,
                 spec=SpecConfig(k=2, drafter="ngram"))
    req = GenRequest(rid=0, prompt=np.array([3, 4, 5]), max_new=4,
                     sampling=SamplingConfig(method="temperature"))
    with pytest.raises(ValueError, match="ONE sampling law"):
        eng.admit(0, req)


# --------------------------------------------------------------------------
# n-gram drafter
# --------------------------------------------------------------------------


def test_ngram_drafter_prompt_lookup():
    d = NGramDrafter(max_n=3, min_n=1)
    d.admit(0, [1, 2, 3, 4, 9, 1, 2, 3])
    drafts, q = d.propose([0], 3)
    assert q is None
    # trailing [1,2,3] matched at the start -> continuation [4, 9, 1]
    assert drafts.tolist() == [[4, 9, 1]]
    d.commit(0, [4, 9])
    (drafts2,), _ = d.propose([0], 4)
    # trailing [3,4,9] now matches the earlier [3,4,9] -> [1,2,3,4]
    assert drafts2.tolist() == [1, 2, 3, 4]
    # no match for a fresh unrepeated context: repeat-last fallback
    d.admit(1, [7, 8])
    (drafts3,), _ = d.propose([1], 2)
    assert drafts3.tolist() == [8, 8]
    d.evict(0)
    d.evict(1)


# --------------------------------------------------------------------------
# StatePool snapshot / restore
# --------------------------------------------------------------------------


def test_state_pool_snapshot_restore_roundtrip_property():
    """Property, over random templates: for any slot, restore(snapshot)
    is the identity on the pool — the rollback primitive — and never
    perturbs other slots."""
    rng = np.random.RandomState(0)
    for trial in range(5):
        slots = int(rng.randint(1, 5))
        shapes = [
            (int(rng.randint(1, 4)),) if rng.rand() < 0.3 else ()
            for _ in range(3)
        ]

        def make(n, shapes=shapes):
            return {
                f"leaf{i}": jnp.zeros(sh[:1] + (n,) + sh[1:])
                for i, sh in enumerate(shapes)
            }

        pool = StatePool(make, slots)
        # randomize the pool, then overwrite arbitrary slots
        pool.states = jax.tree.map(
            lambda x: jnp.asarray(rng.randn(*x.shape)), pool.states
        )
        slot = int(rng.randint(slots))
        snap = pool.snapshot_slot(slot)
        before = jax.tree.map(np.asarray, pool.states)
        garbage = jax.tree.map(
            lambda x: jnp.asarray(rng.randn(*x.shape)),
            pool.empty_slot_state(),
        )
        pool.write_slot(slot, garbage)
        pool.restore_slot(slot, snap)
        after = jax.tree.map(np.asarray, pool.states)
        for a, b in zip(jax.tree.leaves(before), jax.tree.leaves(after)):
            np.testing.assert_array_equal(a, b)


def test_state_pool_snapshot_restore_lm_states():
    cfg = _cfg("hla2")
    pool = StatePool(lambda n: lm.lm_init_states(cfg, n, 32), slots=3)
    pool.states = jax.tree.map(
        lambda x: jnp.asarray(np.random.RandomState(0).randn(*x.shape),
                              x.dtype),
        pool.states,
    )
    snap = pool.snapshot_slot(1)
    pool.reset_slot(1)
    pool.restore_slot(1, snap)
    got = pool.snapshot_slot(1)
    for a, b in zip(jax.tree.leaves(snap), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_verify_snapshot_restore_host_level_rollback(rng):
    """The documented subsystem flow, driven by hand through the
    host-level primitives: snapshot_slot -> make_verify (one
    chunk-parallel call) -> on rejection restore_slot + make_replay of
    the accepted prefix.  The rolled-back slot state must equal stepping
    the accepted tokens through plain decode — bit-for-bit."""
    from repro.serving.sampling import SamplingConfig
    from repro.serving.spec import make_replay, make_verify

    cfg = _cfg("hla2")
    params = _params(cfg)
    k, slots = 4, 2
    pool = StatePool(lambda n: lm.lm_init_states(cfg, n, 64), slots)
    prompts = [rng.randint(2, cfg.vocab, 6), rng.randint(2, cfg.vocab, 9)]
    last, pos = [], []
    for s, p in enumerate(prompts):
        lg, st = lm.lm_prefill(params, jnp.asarray(p[None]), cfg)
        pool.write_slot(s, st)
        last.append(int(jnp.argmax(lg[0])))
        pos.append(len(p))
    positions = jnp.asarray(np.asarray(pos)[:, None], jnp.int32)

    verify = jax.jit(make_verify(cfg, SamplingConfig()))
    replay = jax.jit(make_replay(cfg))
    drafts = jnp.asarray(rng.randint(2, cfg.vocab, (slots, k)), jnp.int32)
    tok_block = jnp.concatenate(
        [jnp.asarray(np.asarray(last)[:, None], jnp.int32), drafts], 1
    )
    snaps = [pool.snapshot_slot(s) for s in range(slots)]
    packed, full_states = verify(
        params, pool.states, tok_block, positions, jax.random.key(0)
    )
    packed = np.asarray(packed)
    pool.states = full_states
    for s in range(slots):
        m = int(packed[s, 0])
        if m == k:
            continue
        fixed, _ = replay(
            params, snaps[s], tok_block[s:s + 1], positions[s:s + 1],
            jnp.asarray([m + 1]),
        )
        pool.restore_slot(s, fixed)
        # oracle: plain decode steps over the accepted prefix
        st, p = snaps[s], positions[s:s + 1]
        for j in range(m + 1):
            _, st, _ = lm.lm_apply(
                params, tok_block[s:s + 1, j:j + 1], cfg, states=st,
                positions=p, mode="decode",
            )
            p = p + 1
        got = pool.snapshot_slot(s)
        for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(got)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # random drafts against a random model: rejections must have occurred
    assert any(int(packed[s, 0]) < k for s in range(slots))


# --------------------------------------------------------------------------
# state-axes registry (hla3 / hla3_paper registration)
# --------------------------------------------------------------------------


@pytest.mark.parametrize(
    "variant", ("hla2", "ahla", "hla3", "hla3_paper", "linattn")
)
def test_mixer_state_axes_registered_and_consistent(variant):
    """Every variant has an EXPLICIT state-axes declaration whose tree
    structure and leaf ranks match ``mixer_init_state`` — the contract
    ``distributed.steps.state_specs`` and the serving pool rely on."""
    from repro.models.param import Axes, is_axes

    cfg = _cfg(variant, decay="none")
    axes = mixer_mod.mixer_state_axes(cfg)
    state = jax.eval_shape(lambda: mixer_mod.mixer_init_state(cfg, 2))

    def chk(ax, leaf):
        assert isinstance(ax, Axes)
        assert len(ax) == leaf.ndim, (variant, tuple(ax), leaf.shape)
        assert tuple(ax)[:2] == ("batch", "q_heads")

    # tree.map raises if the declared tree's structure drifts from the
    # init-state tree — the exact failure mode that broke hla3_paper
    jax.tree.map(chk, axes, state, is_leaf=is_axes)


def test_hla3_paper_prefill_decode_state_consistency(rng):
    """hla3_paper decode now runs in chunk-state space: prefill-then-step
    must continue the same stream a pure chunkwise pass produces (this was
    a tree-structure crash before the registration fix)."""
    cfg = _cfg("hla3_paper", decay="none")
    params = _params(cfg)
    toks = jnp.asarray(rng.randint(0, cfg.vocab, (1, 9)))
    # one-shot prefill over all 9 == prefill 6 then 3 decode steps
    lg_full, st_full = lm.lm_prefill(params, toks, cfg)
    _, st = lm.lm_prefill(params, toks[:, :6], cfg)
    for j in range(6, 9):
        lg, st, _ = lm.lm_apply(
            params, toks[:, j:j + 1], cfg, states=st,
            positions=jnp.asarray([[j]]), mode="decode",
        )
    np.testing.assert_allclose(
        np.asarray(lg[:, -1], np.float32), np.asarray(lg_full, np.float32),
        atol=1e-4, rtol=1e-3,
    )
    for a, b in zip(jax.tree.leaves(st_full), jax.tree.leaves(st)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            atol=1e-4, rtol=1e-3,
        )


# --------------------------------------------------------------------------
# nucleus sampling
# --------------------------------------------------------------------------


def test_top_p_sampling_nucleus(rng):
    from repro.serving import probs, sample

    logits = jnp.asarray(rng.randn(4, 32) * 2, jnp.float32)
    key = jax.random.key(0)
    p = probs(logits, SamplingConfig(method="top_p", top_p=0.5))
    pn = np.asarray(p)
    np.testing.assert_allclose(pn.sum(-1), 1.0, atol=1e-5)
    full = np.asarray(probs(logits, SamplingConfig(method="temperature")))
    for row_p, row_f in zip(pn, full):
        kept = row_p > 0
        # the nucleus is a top-probability prefix with mass >= top_p
        assert row_f[kept].min() >= row_f[~kept].max()
        assert row_f[kept].sum() >= 0.5
        # and it is minimal: dropping its least-likely member goes below
        assert row_f[kept].sum() - row_f[kept].min() < 0.5
    # drawn tokens stay inside the nucleus
    toks = np.asarray(sample(logits, key, SamplingConfig(method="top_p",
                                                         top_p=0.5)))
    for i, t in enumerate(toks):
        assert pn[i, t] > 0
    # degenerate p -> argmax-only nucleus
    t1 = sample(logits, key, SamplingConfig(method="top_p", top_p=1e-9))
    assert (np.asarray(t1) == np.asarray(jnp.argmax(logits, -1))).all()
    with pytest.raises(ValueError):
        sample(logits, key, SamplingConfig(method="top_p", top_p=0.0))


def test_per_request_sampling_override_plain_mode(rng):
    """Per-request SamplingConfig in the plain block path: a greedy
    override inside a temperature-default engine reproduces the solo
    greedy stream."""
    cfg = _cfg("hla2")
    params = _params(cfg)
    pa, pb = rng.randint(2, cfg.vocab, 6), rng.randint(2, cfg.vocab, 6)

    solo = Engine(cfg, params, slots=2, max_len=64, block=4)
    (ra,) = solo.run([GenRequest(rid=0, prompt=pa, max_new=8)])

    eng = Engine(cfg, params, slots=2, max_len=64, block=4,
                 sampling=SamplingConfig(method="temperature",
                                         temperature=0.8))
    res = eng.run([
        GenRequest(rid=0, prompt=pa, max_new=8,
                   sampling=SamplingConfig(method="greedy")),
        GenRequest(rid=1, prompt=pb, max_new=8),
    ])
    assert res[0].tokens == ra.tokens
    assert len(res[1].tokens) == 8
