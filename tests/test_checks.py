"""The invariant linter (repro.analysis.checks): every RPR rule against
good/bad fixture trees, suppression + baseline semantics, the CLI
contract, and the repo itself linting clean.

The three rules ported from the retired ci.yml shell guards (RPR001
print, RPR002 dispatch ladder, RPR003 Engine.run no-raise) each carry a
regression test reproducing the exact bad pattern the shell guard was
written to catch.
"""

import json
import os

import pytest

from repro.analysis.checks import (
    Baseline,
    Finding,
    make_baseline,
    run_checks,
)
from repro.analysis.checks.cli import main as cli_main
from repro.analysis.checks.findings import (
    fingerprint,
    line_annotation,
    suppressed_codes,
)

REPO_SRC = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "src", "repro",
)


def lint(tmp_path, tree, rules=None):
    """Write a fixture tree (relpath -> source) and lint it."""
    for rel, text in tree.items():
        path = tmp_path / rel
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(text)
    return run_checks([str(tmp_path)], rules=rules)


def codes(findings):
    return sorted({f.rule for f in findings if not f.baselined})


# --------------------------------------------------------------------------
# RPR001 — bare print (ported shell guard)
# --------------------------------------------------------------------------


def test_rpr001_flags_library_print(tmp_path):
    # the exact pattern the ci.yml grep guard existed for
    fs = lint(tmp_path, {
        "serving/engine2.py": "def f():\n    print('debug')\n",
    }, rules=["RPR001"])
    assert [f.rule for f in fs] == ["RPR001"]
    assert fs[0].line == 2


def test_rpr001_exempts_clis_and_validator(tmp_path):
    fs = lint(tmp_path, {
        "launch/serve2.py": "print('user-facing')\n",
        "analysis/tool.py": "print('cli')\n",
        "obs/validate.py": "print('validator')\n",
    }, rules=["RPR001"])
    assert fs == []


def test_rpr001_allows_log_alias(tmp_path):
    # `log = print` (a reference, not a call) stays legal, as under the
    # old grep exclusion
    fs = lint(tmp_path, {
        "serving/x.py": "def f(log=print):\n    log('ok')\n",
    }, rules=["RPR001"])
    assert fs == []


# --------------------------------------------------------------------------
# RPR002 — dispatch ladders (ported shell guard)
# --------------------------------------------------------------------------


def test_rpr002_flags_ladder_outside_registry(tmp_path):
    fs = lint(tmp_path, {
        "models/other.py":
            "def f(kind, variant):\n"
            "    if kind == 'gla':\n"
            "        return 1\n"
            "    if variant != 'hla2':\n"
            "        return 2\n",
    }, rules=["RPR002"])
    assert [f.line for f in fs] == [2, 4]


def test_rpr002_registry_and_attributes_allowed(tmp_path):
    fs = lint(tmp_path, {
        # seq_op.py is the one sanctioned dispatch site
        "models/seq_op.py": "def f(kind):\n    return kind == 'gla'\n",
        # attribute access is config metadata, not dispatch
        "launch/go.py": "def f(c):\n    return c.kind == 'train'\n",
        # right-operand comparisons (filter style) stay legal
        "obs/trace.py":
            "def f(es, kind):\n"
            "    return [e for e in es if e['kind'] == kind]\n",
    }, rules=["RPR002"])
    assert fs == []


# --------------------------------------------------------------------------
# RPR003 — Engine.run no-raise (ported shell guard)
# --------------------------------------------------------------------------

_ENGINE_BAD = """\
class Engine:
    def run(self):
        while self.pending:
            raise RuntimeError('boom')
"""

_ENGINE_GOOD = """\
class Engine:
    def run(self):
        if not self.ready:
            raise RuntimeError('before the loop is fine')
        while self.pending:
            self.step()
"""


def test_rpr003_flags_raise_in_drive_loop(tmp_path):
    fs = lint(tmp_path, {"serving/engine.py": _ENGINE_BAD},
              rules=["RPR003"])
    assert [f.rule for f in fs] == ["RPR003"]
    assert fs[0].line == 4


def test_rpr003_raise_outside_loop_ok(tmp_path):
    fs = lint(tmp_path, {"serving/engine.py": _ENGINE_GOOD},
              rules=["RPR003"])
    assert fs == []


def test_rpr003_missing_anchor_is_a_finding(tmp_path):
    # if Engine.run is renamed away, the contract must fail loudly, not
    # silently stop checking
    fs = lint(tmp_path, {"serving/engine.py": "class Other:\n    pass\n"},
              rules=["RPR003"])
    assert [f.rule for f in fs] == ["RPR003"]
    assert "not found" in fs[0].message


# --------------------------------------------------------------------------
# RPR004 — host-sync discipline
# --------------------------------------------------------------------------


def test_rpr004_unannotated_device_get(tmp_path):
    fs = lint(tmp_path, {
        "serving/x.py":
            "import jax\n"
            "def f(x):\n"
            "    return jax.device_get(x)\n",
    }, rules=["RPR004"])
    assert [f.rule for f in fs] == ["RPR004"]


def test_rpr004_sync_point_annotation_clears(tmp_path):
    fs = lint(tmp_path, {
        "serving/x.py":
            "import jax\n"
            "def f(x):\n"
            "    return jax.device_get(x)  # sync-point: block endpoint\n",
    }, rules=["RPR004"])
    assert fs == []


def test_rpr004_annotation_needs_a_reason(tmp_path):
    fs = lint(tmp_path, {
        "serving/x.py":
            "import jax\n"
            "def f(x):\n"
            "    return jax.device_get(x)  # sync-point:\n",
    }, rules=["RPR004"])
    assert [f.rule for f in fs] == ["RPR004"]


def test_rpr004_cast_of_device_value(tmp_path):
    fs = lint(tmp_path, {
        "serving/x.py":
            "import jax.numpy as jnp\n"
            "def f(a, b):\n"
            "    v = jnp.dot(a, b)\n"
            "    return int(v)\n",
        "models/y.py":
            "import jax.numpy as jnp\n"
            "def g(s):\n"
            "    return s.item()\n",
    }, rules=["RPR004"])
    assert [(f.path, f.rule) for f in fs] == [
        ("models/y.py", "RPR004"), ("serving/x.py", "RPR004"),
    ]


def test_rpr004_host_values_and_other_dirs_unflagged(tmp_path):
    fs = lint(tmp_path, {
        # the sanctioned pattern: one device_get, casts on the host copy
        "serving/ok.py":
            "import jax\n"
            "import numpy as np\n"
            "def f(v):\n"
            "    h = jax.device_get(v)  # sync-point: block endpoint\n"
            "    h = np.asarray(h)\n"
            "    return int(h[0])\n",
        # runtime/ is not a hot path — no findings there
        "runtime/loop.py":
            "import jax\n"
            "def g(x):\n"
            "    return jax.device_get(x)\n",
    }, rules=["RPR004"])
    assert fs == []


def test_rpr004_taint_is_function_scoped(tmp_path):
    # `key = jax.random...` in one method must not poison the name `key`
    # in a sibling method that only handles host values
    fs = lint(tmp_path, {
        "serving/x.py":
            "import jax\n"
            "class C:\n"
            "    def a(self):\n"
            "        key = jax.random.PRNGKey(0)\n"
            "        self.key = key\n"
            "    def b(self, key):\n"
            "        return int(key)\n",
    }, rules=["RPR004"])
    assert fs == []


# --------------------------------------------------------------------------
# RPR005 — jit purity
# --------------------------------------------------------------------------


def test_rpr005_time_in_jitted_fn(tmp_path):
    fs = lint(tmp_path, {
        "runtime/x.py":
            "import jax, time\n"
            "@jax.jit\n"
            "def f(x):\n"
            "    t = time.time()\n"
            "    return x + t\n",
    }, rules=["RPR005"])
    assert [f.rule for f in fs] == ["RPR005"]
    assert "time.time" in fs[0].message


def test_rpr005_np_random_in_scan_body(tmp_path):
    # traced by reference: body is passed by name to lax.scan
    fs = lint(tmp_path, {
        "models/x.py":
            "import jax, numpy as np\n"
            "def body(c, x):\n"
            "    return c, x + np.random.randn()\n"
            "def f(xs):\n"
            "    return jax.lax.scan(body, 0.0, xs)\n",
    }, rules=["RPR005"])
    assert [f.rule for f in fs] == ["RPR005"]


def test_rpr005_host_time_and_jax_random_ok(tmp_path):
    fs = lint(tmp_path, {
        "runtime/x.py":
            "import jax, time\n"
            "@jax.jit\n"
            "def f(x, key):\n"
            "    return x + jax.random.uniform(key)\n"
            "def loop(x, key):\n"
            "    t0 = time.time()\n"  # host-side timing is fine
            "    return f(x, key), time.time() - t0\n",
    }, rules=["RPR005"])
    assert fs == []


def test_rpr005_nested_def_inside_jitted_fn(tmp_path):
    fs = lint(tmp_path, {
        "models/x.py":
            "import jax, random\n"
            "@jax.jit\n"
            "def f(x):\n"
            "    def inner(y):\n"
            "        return y * random.random()\n"
            "    return inner(x)\n",
    }, rules=["RPR005"])
    assert [f.rule for f in fs] == ["RPR005"]


# --------------------------------------------------------------------------
# RPR006 — fault-point cross-check
# --------------------------------------------------------------------------

_CATALOG = (
    "from typing import Dict\n"
    "FAULT_POINTS: Dict[str, str] = {\n"
    "    'engine.boom': 'a fired point',\n"
    "    'dead.point': 'never fired anywhere',\n"
    "}\n"
)


def test_rpr006_dead_entry_and_unregistered_site(tmp_path):
    fs = lint(tmp_path, {
        "runtime/faults.py": _CATALOG,
        "serving/engine.py":
            "def f(plan):\n"
            "    plan.raise_if('engine.boom')\n"
            "    plan.hit('typo.point')\n",
    }, rules=["RPR006"])
    msgs = sorted(f.message for f in fs)
    assert len(fs) == 2
    assert "dead.point" in msgs[0] and "no live firing site" in msgs[0]
    assert "typo.point" in msgs[1] and "unregistered" in msgs[1]


def test_rpr006_clean_when_catalog_matches(tmp_path):
    fs = lint(tmp_path, {
        "runtime/faults.py":
            "FAULT_POINTS = {'engine.boom': 'doc'}\n",
        "serving/engine.py":
            "def f(self):\n"
            "    self._raise_fault('engine.boom')\n",
    }, rules=["RPR006"])
    assert fs == []


def test_rpr006_skips_trees_without_catalog(tmp_path):
    # linting a subtree (no runtime/faults.py) must not spray findings
    fs = lint(tmp_path, {
        "serving/engine.py": "def f(p):\n    p.hit('whatever.point')\n",
    }, rules=["RPR006"])
    assert fs == []


# --------------------------------------------------------------------------
# RPR007 — obs naming schema
# --------------------------------------------------------------------------


def test_rpr007_metric_shapes(tmp_path):
    fs = lint(tmp_path, {
        "serving/m.py":
            "def f(m):\n"
            "    m.counter('serving_requests', 'h')\n"       # no _total
            "    m.gauge('serving_queue_total', 'h')\n"      # _total on gauge
            "    m.histogram('serving_ttft', 'h')\n"         # no unit
            "    m.histogram('BadName', 'h')\n"              # not snake
            "    m.event('FooBar')\n",                       # not dotted
    }, rules=["RPR007"])
    assert [f.line for f in fs] == [2, 3, 4, 5, 6]


def test_rpr007_bench_row_names(tmp_path):
    """bench history rows must be slash-separated snake_case paths."""
    fs = lint(tmp_path, {
        "benchmarks/b.py":
            "def f(h):\n"
            "    h.bench_row('ops/gla/decode_tok_per_s', 1.0, unit='x')\n"
            "    h.bench_row('kernels/hla2_fwd', 1.0, unit='x')\n"
            "    h.bench_row('BadName/row', 1.0, unit='x')\n"   # not snake
            "    h.bench_row('single_segment', 1.0, unit='x')\n"  # no slash
            "    h.bench_row('ops//empty', 1.0, unit='x')\n",     # empty seg
    }, rules=["RPR007"])
    assert [f.line for f in fs] == [4, 5, 6]


def test_rpr007_schema_conformant_names_pass(tmp_path):
    fs = lint(tmp_path, {
        "serving/m.py":
            "def f(m, obs):\n"
            "    m.counter('serving_requests_total', 'h')\n"
            "    m.gauge('serving_queue_depth', 'h')\n"
            "    m.histogram('serving_ttft_seconds', 'h')\n"
            "    obs.event('request.first_token')\n"
            "    obs.span('engine.decode_block')\n"
            "    obs.timer('engine.spec_round')\n",
    }, rules=["RPR007"])
    assert fs == []


# --------------------------------------------------------------------------
# suppressions, annotations, baseline
# --------------------------------------------------------------------------


def test_noqa_suppresses_named_code_only(tmp_path):
    fs = lint(tmp_path, {
        "serving/x.py":
            "def f():\n"
            "    print('one')  # noqa: RPR001\n"
            "    print('two')  # noqa: RPR002\n"  # wrong code: still flagged
            "    print('three')  # noqa\n",       # bare noqa: not honored
    }, rules=["RPR001"])
    assert [f.line for f in fs] == [3, 4]


def test_suppressed_codes_parsing():
    assert suppressed_codes("x = 1  # noqa: RPR001") == ["RPR001"]
    assert suppressed_codes("x  # noqa: RPR001, RPR004") == \
        ["RPR001", "RPR004"]
    assert suppressed_codes("x  # noqa") == []
    assert line_annotation("y  # sync-point: ttft endpoint",
                           "sync-point") == "ttft endpoint"
    assert line_annotation("y  # sync-point:", "sync-point") is None


def test_baseline_accepts_old_findings_not_new(tmp_path):
    tree = {"serving/x.py": "def f():\n    print('legacy')\n"}
    for rel, text in tree.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True)
        p.write_text(text)
    bl = make_baseline([str(tmp_path)], rules=["RPR001"])
    assert len(bl.fingerprints) == 1

    # baselined finding: reported, stamped, does not count as new
    fs = run_checks([str(tmp_path)], rules=["RPR001"], baseline=bl)
    assert len(fs) == 1 and fs[0].baselined

    # a NEW copy of the same pattern is a new finding
    (tmp_path / "serving" / "x.py").write_text(
        "def f():\n    print('legacy')\n    print('new')\n"
    )
    fs = run_checks([str(tmp_path)], rules=["RPR001"], baseline=bl)
    assert [f.baselined for f in sorted(fs, key=lambda f: f.line)] == \
        [True, False]


def test_baseline_survives_line_renumbering(tmp_path):
    p = tmp_path / "serving" / "x.py"
    p.parent.mkdir(parents=True)
    p.write_text("def f():\n    print('legacy')\n")
    bl = make_baseline([str(tmp_path)], rules=["RPR001"])
    # shift the finding down two lines: content-hash fingerprint holds
    p.write_text("import os\n\ndef f():\n    print('legacy')\n")
    fs = run_checks([str(tmp_path)], rules=["RPR001"], baseline=bl)
    assert len(fs) == 1 and fs[0].baselined


def test_baseline_roundtrip(tmp_path):
    bl = Baseline(["aaaa", "bbbb"])
    path = str(tmp_path / "baseline.json")
    bl.save(path)
    loaded = Baseline.load(path)
    assert loaded.fingerprints == {"aaaa", "bbbb"}
    with pytest.raises(ValueError):
        (tmp_path / "bad.json").write_text('{"schema": "nope"}')
        Baseline.load(str(tmp_path / "bad.json"))


def test_fingerprint_distinguishes_occurrences():
    lines = ["print('x')", "print('x')"]
    a = fingerprint(Finding("RPR001", "p.py", 1, 0, "m"), lines)
    b = fingerprint(Finding("RPR001", "p.py", 2, 0, "m"), lines)
    assert a != b


# --------------------------------------------------------------------------
# CLI contract
# --------------------------------------------------------------------------


def _write_bad_tree(tmp_path):
    p = tmp_path / "serving" / "x.py"
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text("def f():\n    print('nope')\n")


def test_cli_exit_codes_and_json(tmp_path, capsys):
    _write_bad_tree(tmp_path)
    rc = cli_main([str(tmp_path), "--rules", "RPR001", "--format", "json"])
    out = json.loads(capsys.readouterr().out)
    assert rc == 1
    assert out["schema"] == "repro.checks.findings/v1"
    assert out["counts"] == {"RPR001": 1}
    assert out["findings"][0]["path"] == "serving/x.py"


def test_cli_baseline_workflow(tmp_path, capsys):
    _write_bad_tree(tmp_path)
    bl_path = str(tmp_path / "baseline.json")
    assert cli_main([str(tmp_path), "--rules", "RPR001",
                     "--write-baseline", bl_path]) == 0
    capsys.readouterr()
    # same tree + baseline: clean exit, finding reported as baselined
    rc = cli_main([str(tmp_path), "--rules", "RPR001",
                   "--baseline", bl_path])
    out = capsys.readouterr().out
    assert rc == 0 and "(baselined)" in out and "0 new findings" in out


def test_cli_unknown_rule_is_usage_error(tmp_path):
    assert cli_main([str(tmp_path), "--rules", "RPR999"]) == 2


def test_cli_list_rules(capsys):
    assert cli_main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for code in ("RPR001", "RPR002", "RPR003", "RPR004", "RPR005",
                 "RPR006", "RPR007"):
        assert code in out


def test_syntax_error_becomes_finding(tmp_path):
    (tmp_path / "bad.py").write_text("def f(:\n")
    fs = run_checks([str(tmp_path)])
    assert [f.rule for f in fs] == ["RPR000"]


# --------------------------------------------------------------------------
# the repo itself
# --------------------------------------------------------------------------


def test_repo_lints_clean():
    """The acceptance bar: zero unbaselined findings on src/repro.  Every
    invariant the retired shell guards enforced (and the four new rules)
    holds on the real tree."""
    fs = run_checks([REPO_SRC])
    assert [f.render() for f in fs if not f.baselined] == []
