"""Distributed tests — each spawns a fresh python with 8 host devices
(XLA_FLAGS is locked at jax init, so the main pytest process stays at 1).
"""

import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(body, devices=8, timeout=600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("JAX_ENABLE_X64", None)
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(body)],
        capture_output=True, text=True, timeout=timeout, env=env, cwd=REPO,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    return proc.stdout


pytestmark = pytest.mark.subprocess


def test_pjit_train_matches_single_device():
    """3 training steps on a 2x4 mesh == single-device run (same seeds)."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np, functools
        from repro.configs import get_config
        from repro.distributed import steps as steps_mod, sharding as shd
        from repro.launch.mesh import make_mesh
        from repro.models.param import init_params
        from repro.optim import adamw
        from repro.data.pipeline import DataConfig, SyntheticStream

        cfg = get_config("hla-1b", reduced=True)
        specs = steps_mod.model_specs(cfg)
        oc = adamw.OptConfig(lr=1e-3, warmup_steps=0, total_steps=10)
        stream = SyntheticStream(DataConfig(cfg.vocab, 32, 8, seed=1))

        def run(mesh):
            with mesh:
                ps = shd.param_shardings(specs, mesh)
                params = jax.jit(functools.partial(init_params, specs),
                                 out_shardings=ps)(jax.random.key(0))
                opt = adamw.init_opt_state(params)
                step = jax.jit(steps_mod.make_train_step(cfg, oc))
                losses = []
                for s in range(3):
                    b = {k: jnp.asarray(v) for k, v in stream.batch(s).items()}
                    params, opt, m = step(params, opt, b)
                    losses.append(float(m["loss"]))
            return losses, params

        mesh8 = make_mesh((2, 4), ("data", "model"))
        l8, p8 = run(mesh8)
        mesh1 = make_mesh((1, 1), ("data", "model"))
        l1, p1 = run(mesh1)
        # float reassociation across 8-way DP reductions + contention-dependent
        # XLA scheduling: loose tolerances (exactness is covered by the
        # single-process equivalence tests)
        np.testing.assert_allclose(l8, l1, rtol=5e-3)
        for a, b in zip(jax.tree.leaves(p8), jax.tree.leaves(p1)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=5e-3, rtol=5e-2)
        print("OK")
    """)
    assert "OK" in out


def test_train_grad_agreement_single_step():
    """Tight single-step gradient agreement — catches sharding-dependent
    numerics (RNG partitioning, reduction reassociation, accumulation
    semantics) far below the 3-step-loss level:

    * mesh (2, 4) vs (1, 1) gradients agree to <= 1e-5;
    * microbatched accumulation (4 microbatches, global-count CE
      normalizer) matches the unmicrobatched step to <= 1e-5.
    """
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np, functools
        from repro.configs import get_config
        from repro.distributed import steps as steps_mod, sharding as shd
        from repro.launch.mesh import make_mesh
        from repro.models.param import init_params
        from repro.optim import adamw
        from repro.data.pipeline import DataConfig, SyntheticStream

        cfg = get_config("hla-1b", reduced=True)
        specs = steps_mod.model_specs(cfg)
        stream = SyntheticStream(DataConfig(cfg.vocab, 32, 8, seed=2))
        batch = {k: jnp.asarray(v) for k, v in stream.batch(0).items()}
        # uneven masking across microbatch boundaries: the exactness of
        # the global-count normalizer is what's under test
        lab = np.asarray(batch["labels"]).copy()
        lab[:3, :11] = -1
        batch["labels"] = jnp.asarray(lab)

        def grads_on(mesh):
            with mesh:
                ps = shd.param_shardings(specs, mesh)
                params = jax.jit(functools.partial(init_params, specs),
                                 out_shardings=ps)(jax.random.key(0))
                gfn = jax.jit(lambda p, b: jax.value_and_grad(
                    steps_mod._loss_fn, has_aux=True)(p, b, cfg)[1])
                return jax.tree.map(np.asarray, gfn(params, batch))

        g8 = grads_on(make_mesh((2, 4), ("data", "model")))
        g1 = grads_on(make_mesh((1, 1), ("data", "model")))
        for a, b in zip(jax.tree.leaves(g8), jax.tree.leaves(g1)):
            np.testing.assert_allclose(a, b, atol=1e-5, rtol=1e-4)

        # microbatch accumulation == single batch (same mesh)
        oc = adamw.OptConfig(lr=1e-3, warmup_steps=0, total_steps=10)
        mesh = make_mesh((2, 4), ("data", "model"))
        def one_step(microbatches):
            with mesh:
                ps = shd.param_shardings(specs, mesh)
                params = jax.jit(functools.partial(init_params, specs),
                                 out_shardings=ps)(jax.random.key(0))
                opt = adamw.init_opt_state(params)
                step = jax.jit(steps_mod.make_train_step(
                    cfg, oc, microbatches=microbatches, grad_shardings=ps))
                params, opt, m = step(params, opt, batch)
                return float(m["loss"]), jax.tree.map(np.asarray, params)
        l1_, p1_ = one_step(1)
        l4_, p4_ = one_step(4)
        assert abs(l1_ - l4_) < 1e-5, (l1_, l4_)
        for a, b in zip(jax.tree.leaves(p4_), jax.tree.leaves(p1_)):
            np.testing.assert_allclose(a, b, atol=1e-5, rtol=1e-4)
        print("OK")
    """)
    assert "OK" in out


def test_sharded_train_step_runs_fused_kernels():
    """With use_pallas (forced into interpret mode off-TPU) the sharded
    train step traces the fused Pallas forward AND backward — not the jnp
    fallback — and matches the jnp path numerically."""
    out = run_py("""
        import dataclasses, functools
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.distributed import steps as steps_mod, sharding as shd
        from repro.kernels import ops as kops
        from repro.launch.mesh import make_mesh
        from repro.models.param import init_params
        from repro.optim import adamw
        from repro.data.pipeline import DataConfig, SyntheticStream

        cfg = get_config("hla-1b", reduced=True)
        cfgp = cfg.replace(
            hla=dataclasses.replace(cfg.hla, force_pallas=True, chunk=16)
        )
        oc = adamw.OptConfig(lr=1e-3, warmup_steps=0, total_steps=10)
        stream = SyntheticStream(DataConfig(cfg.vocab, 32, 8, seed=1))
        batch = {k: jnp.asarray(v) for k, v in stream.batch(0).items()}
        mesh = make_mesh((2, 4), ("data", "model"))

        def one_step(c):
            specs = steps_mod.model_specs(c)
            with mesh:
                ps = shd.param_shardings(specs, mesh)
                params = jax.jit(functools.partial(init_params, specs),
                                 out_shardings=ps)(jax.random.key(0))
                opt = adamw.init_opt_state(params)
                step = jax.jit(steps_mod.make_train_step(
                    c, oc, grad_shardings=ps))
                params, opt, m = step(params, opt, batch)
                return float(m["loss"]), jax.tree.map(np.asarray, params)

        kops.TRACE_COUNTS.clear()
        lp, pp = one_step(cfgp)
        assert kops.TRACE_COUNTS["hla2_fwd_fused"] > 0, kops.TRACE_COUNTS
        assert kops.TRACE_COUNTS["hla2_bwd_fused"] > 0, kops.TRACE_COUNTS
        lj, pj = one_step(cfg)  # jnp fallback reference
        assert abs(lp - lj) < 1e-4, (lp, lj)
        for a, b in zip(jax.tree.leaves(pp), jax.tree.leaves(pj)):
            np.testing.assert_allclose(a, b, atol=1e-4, rtol=1e-3)
        print("OK")
    """)
    assert "OK" in out


def test_sharded_serving_matches_single_device():
    """The sharded engine (params + slot states on a (2, 4) mesh, slots on
    "data", heads on "model") samples exactly the tokens the single-device
    engine does, with matching final slot states — and the pool's states
    carry the explicit shardings rather than a replicated tree."""
    out = run_py("""
        import functools
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.configs import get_config
        from repro.distributed import sharding as shd
        from repro.launch.mesh import make_mesh
        from repro.models import lm
        from repro.models.param import init_params
        from repro.serving import Engine, GenRequest, SamplingConfig

        cfg = get_config("hla-1b", reduced=True)
        specs = lm.lm_specs(cfg)
        mk_reqs = lambda: [
            GenRequest(
                rid=i,
                prompt=np.random.RandomState(100 + i).randint(
                    2, cfg.vocab, 12),
                max_new=8,
            )
            for i in range(5)
        ]

        def run(mesh, use_mesh):
            with mesh:
                ps = shd.param_shardings(specs, mesh)
                params = jax.jit(functools.partial(init_params, specs),
                                 out_shardings=ps)(jax.random.key(0))
                eng = Engine(
                    cfg, params, slots=2, max_len=40,
                    sampling=SamplingConfig(method="temperature",
                                            temperature=0.8),
                    block=4, seed=7, mesh=mesh if use_mesh else None,
                )
                res = eng.run(mk_reqs())
                states = jax.tree.map(np.asarray, eng.pool.states)
            return res, states, eng

        mesh8 = make_mesh((2, 4), ("data", "model"))
        r8, s8, e8 = run(mesh8, True)
        spec = jax.tree.leaves(e8.pool.states)[0].sharding.spec
        assert tuple(spec) == (None, "data", "model"), spec
        r1, s1, _ = run(make_mesh((1, 1), ("data", "model")), False)
        for a, b in zip(r8, r1):
            assert a.tokens == b.tokens, (a.rid, a.tokens, b.tokens)
        for a, b in zip(jax.tree.leaves(s8), jax.tree.leaves(s1)):
            np.testing.assert_allclose(a, b, atol=1e-4)
        print("OK")
    """)
    assert "OK" in out


def test_multipod_mesh_axes_and_dryrun_cli():
    """Reduced dry-run through the real CLI on a 2x2x2 pod mesh."""
    env = dict(os.environ)
    env["DRYRUN_DEVICES"] = "8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = os.path.join("/tmp", "dryrun_cli_test.json")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "hla-1b",
         "--shape", "train_4k", "--mesh", "2x2x2", "--json", out],
        capture_output=True, text=True, timeout=900, env=env, cwd=REPO,
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    with open(out) as f:
        res = json.load(f)
    assert res["mesh"] == {"pod": 2, "data": 2, "model": 2}
    assert res["cost"]["flops"] > 0
    assert res["roofline"]["bottleneck"] in (
        "compute_s", "memory_s", "collective_s"
    )


def test_elastic_checkpoint_reshard():
    """Save on a (4, 2) mesh; restore onto (2, 2) — different device count."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np, functools, tempfile
        from repro.configs import get_config
        from repro.distributed import steps as steps_mod, sharding as shd
        from repro.launch.mesh import make_mesh
        from repro.models.param import init_params
        from repro.checkpoint.manager import CheckpointManager

        cfg = get_config("hla-1b", reduced=True)
        specs = steps_mod.model_specs(cfg)
        d = tempfile.mkdtemp()
        mesh_a = make_mesh((4, 2), ("data", "model"))
        with mesh_a:
            ps = shd.param_shardings(specs, mesh_a)
            params = jax.jit(functools.partial(init_params, specs),
                             out_shardings=ps)(jax.random.key(3))
            mgr = CheckpointManager(d, async_save=False)
            mgr.save(5, params, block=True)

        mesh_b = make_mesh((2, 2), ("data", "model"))  # elastic: fewer devices
        with mesh_b:
            ps_b = shd.param_shardings(specs, mesh_b)
            restored, manifest = CheckpointManager(d).restore(
                params, shardings=ps_b
            )
        assert manifest["step"] == 5
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # restored arrays live on the new mesh's devices
        leaf = jax.tree.leaves(restored)[0]
        assert len(leaf.sharding.device_set) <= 4
        print("OK")
    """)
    assert "OK" in out


def test_int8_error_feedback_allreduce():
    """Compressed all-reduce ~ exact mean; error feedback shrinks bias
    across repeated rounds on the same direction."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np, functools
        from jax.sharding import PartitionSpec as P
        from repro.launch.mesh import make_mesh
        from repro.distributed.compat import shard_map
        from repro.distributed.compression import int8_allreduce_mean

        mesh = make_mesh((8,), ("data",))
        x = np.random.RandomState(0).randn(8, 4096).astype(np.float32)

        @functools.partial(shard_map, mesh=mesh,
                           in_specs=(P("data"), P("data")),
                           out_specs=(P("data"), P("data")))
        def run(xs, es):
            red, e = int8_allreduce_mean(xs[0], "data", es[0])
            return red[None], e[None]

        exact = x.mean(0)
        err = jnp.zeros((8, 4096), jnp.float32)
        red, err = run(jnp.asarray(x), err)
        red0 = np.asarray(red[0])
        rel = np.abs(red0 - exact).max() / np.abs(exact).max()
        assert rel < 0.05, rel
        # error feedback: accumulated estimate over rounds converges
        acc = np.zeros_like(exact)
        est = np.zeros_like(exact)
        for r in range(8):
            red, err = run(jnp.asarray(x), err)
            acc += x.mean(0)
            est += np.asarray(red[0])
        rel2 = np.abs(est - acc).max() / np.abs(acc).max()
        assert rel2 < 0.02, rel2
        print("OK", rel, rel2)
    """)
    assert "OK" in out


def test_pipeline_parallel_matches_serial():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_mesh
        from repro.distributed.pipeline_par import pipelined_forward

        mesh = make_mesh((4,), ("pipe",))
        L, M, mb, n, d = 8, 4, 2, 8, 16
        rng = np.random.RandomState(0)
        Ws = jnp.asarray(rng.randn(L, d, d) * (d ** -0.5), jnp.float32)
        xs = jnp.asarray(rng.randn(M, mb, n, d), jnp.float32)

        def layer(w, x):
            return jnp.tanh(x @ w)

        out = pipelined_forward(layer, Ws, xs, mesh)

        ref = xs
        for i in range(L):
            ref = layer(Ws[i], ref)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-5, rtol=1e-5)

        # gradients flow through ppermute (GPipe backward for free)
        def loss_pp(Ws):
            return jnp.sum(pipelined_forward(layer, Ws, xs, mesh) ** 2)

        def loss_ref(Ws):
            h = xs
            for i in range(L):
                h = layer(Ws[i], h)
            return jnp.sum(h ** 2)

        g_pp = jax.grad(loss_pp)(Ws)
        g_ref = jax.grad(loss_ref)(Ws)
        np.testing.assert_allclose(np.asarray(g_pp), np.asarray(g_ref),
                                   atol=1e-4, rtol=1e-4)
        print("OK")
    """)
    assert "OK" in out


def test_train_cli_failure_restart(tmp_path):
    """launch.train with an injected failure, then a restart that resumes."""
    env = dict(os.environ)
    env["HOST_DEVICES"] = "4"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    ck = str(tmp_path / "ck")
    args = [sys.executable, "-m", "repro.launch.train", "--arch", "hla-1b",
            "--reduced", "--steps", "12", "--batch", "4", "--seq", "32",
            "--ckpt-dir", ck, "--ckpt-every", "4"]
    p1 = subprocess.run(args + ["--fail-at-step", "9"], capture_output=True,
                        text=True, timeout=900, env=env, cwd=REPO)
    assert p1.returncode != 0
    assert "injected fault at point 'train.step'" in p1.stderr
    p2 = subprocess.run(args, capture_output=True, text=True, timeout=900,
                        env=env, cwd=REPO)
    assert p2.returncode == 0, p2.stderr[-2000:]
    assert "resumed from step 7" in p2.stdout
    assert "finished at step 11" in p2.stdout


def test_sharded_serving_hla3_matches_single_device():
    """hla3 (exact third-order) serves under a mesh: its composite
    (LinAttn o HLA2) decode state is declared in the per-variant
    state-axes registry, so pool states come up explicitly sharded and the
    sampled tokens match the single-device engine exactly."""
    out = run_py("""
        import functools
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.distributed import sharding as shd
        from repro.launch.mesh import make_mesh
        from repro.models import lm
        from repro.models.param import init_params
        from repro.serving import Engine, GenRequest

        cfg = get_config("hla-1b", reduced=True).replace(mixer="hla3")
        specs = lm.lm_specs(cfg)
        mk_reqs = lambda: [
            GenRequest(
                rid=i,
                prompt=np.random.RandomState(40 + i).randint(
                    2, cfg.vocab, 10),
                max_new=8,
            )
            for i in range(4)
        ]

        def run(mesh, use_mesh):
            with mesh:
                ps = shd.param_shardings(specs, mesh)
                params = jax.jit(functools.partial(init_params, specs),
                                 out_shardings=ps)(jax.random.key(0))
                eng = Engine(cfg, params, slots=2, max_len=40, block=4,
                             seed=5, mesh=mesh if use_mesh else None)
                res = eng.run(mk_reqs())
                states = jax.tree.map(np.asarray, eng.pool.states)
            return res, states, eng

        mesh8 = make_mesh((2, 4), ("data", "model"))
        r8, s8, e8 = run(mesh8, True)
        # every hla3 state leaf is explicitly placed (slots->data,
        # heads->model), incl. the inner LinAttn and outer HLA2 legs
        for leaf in jax.tree.leaves(e8.pool.states):
            assert tuple(leaf.sharding.spec)[:3] == (None, "data", "model"), (
                leaf.shape, leaf.sharding.spec)
        r1, s1, _ = run(make_mesh((1, 1), ("data", "model")), False)
        for a, b in zip(r8, r1):
            assert a.tokens == b.tokens, (a.rid, a.tokens, b.tokens)
        for a, b in zip(jax.tree.leaves(s8), jax.tree.leaves(s1)):
            np.testing.assert_allclose(a, b, atol=1e-4)
        print("OK")
    """)
    assert "OK" in out


def test_sharded_spec_decode_matches_single_device():
    """Speculative serving on a (2, 4) mesh: target pool AND draft-model
    pool states placed via the per-module *_state_axes scheme, the fused
    verify/rollback round shard_map-dispatched — and the greedy streams
    equal (a) the single-device speculative engine's and (b) plain
    non-speculative greedy decode."""
    out = run_py("""
        import functools
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.distributed import sharding as shd
        from repro.launch.mesh import make_mesh
        from repro.models import lm
        from repro.models.param import init_params
        from repro.serving import Engine, GenRequest, SpecConfig

        cfg = get_config("hla-1b", reduced=True)
        specs = lm.lm_specs(cfg)
        mk_reqs = lambda: [
            GenRequest(
                rid=i,
                prompt=np.random.RandomState(60 + i).randint(
                    2, cfg.vocab, 12),
                max_new=10,
            )
            for i in range(4)
        ]

        def run(mesh, use_mesh, spec):
            with mesh:
                ps = shd.param_shardings(specs, mesh)
                params = jax.jit(functools.partial(init_params, specs),
                                 out_shardings=ps)(jax.random.key(0))
                eng = Engine(cfg, params, slots=2, max_len=48, block=4,
                             seed=9, mesh=mesh if use_mesh else None,
                             spec=spec)
                res = eng.run(mk_reqs())
            return res, eng

        mesh1 = make_mesh((1, 1), ("data", "model"))
        mesh8 = make_mesh((2, 4), ("data", "model"))
        spec = lambda: SpecConfig(k=3, drafter="lm", draft_arch="hla-1b")
        r_plain, _ = run(mesh1, False, None)
        r1, _ = run(mesh1, False, spec())
        r8, e8 = run(mesh8, True, spec())
        # draft pool states are explicitly sharded like the target's
        for leaf in jax.tree.leaves(e8.drafter.pool.states):
            assert tuple(leaf.sharding.spec)[:3] == (None, "data", "model"), (
                leaf.shape, leaf.sharding.spec)
        for a, b, c in zip(r8, r1, r_plain):
            assert a.tokens == b.tokens, ("mesh", a.rid, a.tokens, b.tokens)
            assert a.tokens == c.tokens, ("spec", a.rid, a.tokens, c.tokens)
        assert e8.stats["spec_rounds"] > 0
        print("OK")
    """)
    assert "OK" in out
