"""Cost-model conformance suite (DESIGN.md §15).

Parametrized over EVERY registered ``SequenceOp``:

* the analytic forward FLOPs/token land within a factor-of-2 band of
  the XLA-measured dot FLOPs (loop-aware ``cost_analysis`` via
  ``repro.analysis.hlo_analysis``) on small shapes — the calibration
  contract ``benchmarks/run.py``'s utilization numbers rest on;
* streaming ops' decode state is EXACTLY O(1) in sequence length (the
  paper's constant-state claim, measured abstractly via ``eval_shape``);
* the optional ``SequenceOp.cost_model`` hook overrides the family
  state-math term without touching projections or state bytes.
"""

import dataclasses

import pytest

from repro.configs import get_config
from repro.models import seq_op
from repro.models.config import MambaConfig
from repro.obs import costs

ALL_OPS = seq_op.registered_op_names()
STREAMING_OPS = seq_op.streaming_op_names()


def _cfg_for(name):
    base = get_config("hla-1b", reduced=True)
    if name == "attn":
        return base.replace(mixer="softmax")
    if name == "mamba":
        return base.replace(
            mixer="mamba", mamba=MambaConfig(d_state=8, d_conv=4, expand=2)
        )
    return base.replace(mixer=name)


# --------------------------------------------------------------------------
# structure
# --------------------------------------------------------------------------


@pytest.mark.parametrize("name", ALL_OPS)
@pytest.mark.parametrize("mode", costs.MODES)
def test_cost_defined_for_every_mode(name, mode):
    c = costs.op_cost(name, _cfg_for(name), mode=mode, seq_len=64)
    assert c.op == name and c.mode == mode
    assert c.flops_per_token > 0
    assert c.bytes_per_token > 0
    assert c.state_bytes >= 0
    assert set(c.breakdown) >= {"proj_flops", "state_flops",
                                "weight_bytes", "act_bytes",
                                "state_traffic_bytes"}
    d = c.as_dict()
    assert d["flops_per_token"] == c.flops_per_token


@pytest.mark.parametrize("name", ALL_OPS)
def test_backward_costs_more_than_forward(name):
    cfg = _cfg_for(name)
    fwd = costs.op_cost(name, cfg, mode="train_fwd", seq_len=64)
    bwd = costs.op_cost(name, cfg, mode="train_bwd", seq_len=64)
    stp = costs.op_cost(name, cfg, mode="train_step", seq_len=64)
    assert bwd.flops_per_token == pytest.approx(2 * fwd.flops_per_token)
    assert stp.flops_per_token == pytest.approx(3 * fwd.flops_per_token)


def test_unknown_mode_raises():
    with pytest.raises(ValueError, match="mode"):
        costs.op_cost("hla2", _cfg_for("hla2"), mode="inference")


# --------------------------------------------------------------------------
# calibration: analytic vs XLA dot FLOPs, factor-of-2 band
# --------------------------------------------------------------------------


@pytest.mark.parametrize("name", ALL_OPS)
def test_analytic_flops_within_2x_of_xla(name):
    """The tentpole acceptance band: on small shapes the analytic
    forward FLOPs/token must sit within [0.5x, 2x] of what XLA actually
    compiles (loop-aware, so scan-over-chunks bodies count per-trip)."""
    cfg = _cfg_for(name)
    analytic = costs.op_cost(name, cfg, mode="train_fwd", seq_len=64)
    measured = costs.measured_op_flops(name, cfg, seq_len=64)["per_token"]
    assert measured > 0
    ratio = analytic.flops_per_token / measured
    assert 0.5 <= ratio <= 2.0, (
        f"{name}: analytic {analytic.flops_per_token:.0f} vs "
        f"XLA {measured:.0f} FLOPs/token (ratio {ratio:.2f})"
    )


def test_xla_cost_reports_both_accounts():
    """xla_cost carries the raw ``cost_analysis`` numbers alongside the
    loop-aware account.  The two use different bases (raw counts every
    elementwise op once; loop-aware counts dots only but multiplies
    while-bodies by trip count) so they agree to a small factor on an
    unrolled small shape rather than exactly."""
    cfg = _cfg_for("hla2")
    import functools

    import jax
    import jax.numpy as jnp

    from repro.models.param import init_params

    op = seq_op.get_op("hla2")
    params = init_params(op.specs(cfg), jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (1, 64, cfg.d_model),
                          jnp.float32)

    fwd = functools.partial(op.forward, cfg=cfg, state=None,
                            want_state=False, positions=None)
    cost = costs.xla_cost(lambda p, x: fwd(p, x)[0], params, x)
    assert cost["raw_flops"] > 0
    assert cost["flops"] > 0.5 * cost["raw_flops"]


# --------------------------------------------------------------------------
# the paper's constant-state claim: state bytes are O(1) in n
# --------------------------------------------------------------------------


@pytest.mark.parametrize("name", STREAMING_OPS)
def test_streaming_state_bytes_constant_in_n(name):
    cfg = _cfg_for(name)
    op = seq_op.get_op(name)
    sizes = [costs.record_state_bytes(op, cfg, max_len=n)
             for n in (16, 64, 256, 1024)]
    assert sizes[0] > 0
    assert len(set(sizes)) == 1, (
        f"{name}: state bytes vary with max_len: {sizes} "
        "(violates the O(1)-state claim)"
    )


def test_attn_kv_cache_grows_with_n():
    """The contrast case: softmax attention's KV cache is O(n)."""
    cfg = _cfg_for("attn")
    op = seq_op.get_op("attn")
    s64 = costs.record_state_bytes(op, cfg, max_len=64)
    s256 = costs.record_state_bytes(op, cfg, max_len=256)
    # 4x the KV rows plus an O(1) cursor leaf
    assert s64 > 0
    assert s256 == pytest.approx(4 * s64, rel=0.01)


@pytest.mark.parametrize("name", STREAMING_OPS)
def test_streaming_decode_flops_constant_in_context(name):
    cfg = _cfg_for(name)
    short = costs.op_cost(name, cfg, mode="decode_step", seq_len=64)
    long = costs.op_cost(name, cfg, mode="decode_step", seq_len=4096)
    assert short.flops_per_token == pytest.approx(long.flops_per_token)


def test_attn_decode_flops_grow_with_context():
    cfg = _cfg_for("attn")
    short = costs.op_cost("attn", cfg, mode="decode_step", seq_len=64)
    long = costs.op_cost("attn", cfg, mode="decode_step", seq_len=4096)
    assert long.breakdown["state_flops"] > 10 * short.breakdown["state_flops"]


# --------------------------------------------------------------------------
# the cost_model hook
# --------------------------------------------------------------------------


def test_cost_model_hook_overrides_state_terms():
    """An op's cost_model replaces the family state math (and optionally
    state traffic) — projections and state bytes stay record-derived."""
    base_op = seq_op.get_op("linattn")
    cfg = _cfg_for("linattn")
    base = costs.record_cost(base_op, cfg, mode="train_fwd", seq_len=64)

    def hook(cfg, *, mode, seq_len, batch):
        return {"state_flops_per_token": 12345.0,
                "state_bytes_per_token": 777.0}

    hooked_op = dataclasses.replace(base_op, cost_model=hook)
    hooked = costs.record_cost(hooked_op, cfg, mode="train_fwd", seq_len=64)
    assert hooked.breakdown["state_flops"] == 12345.0
    assert hooked.breakdown["state_traffic_bytes"] == 777.0
    assert hooked.breakdown["proj_flops"] == base.breakdown["proj_flops"]
    assert hooked.state_bytes == base.state_bytes


def test_gla_registers_a_cost_model_hook():
    """gla is the worked example: its record carries a cost_model and
    the hook's numbers flow through op_cost."""
    op = seq_op.get_op("gla")
    assert op.cost_model is not None
    cfg = _cfg_for("gla")
    hook = op.cost_model(cfg, mode="decode_step", seq_len=64, batch=1)
    assert hook["state_flops_per_token"] > 0
    c = costs.op_cost("gla", cfg, mode="decode_step", seq_len=64)
    assert c.breakdown["state_flops"] == hook["state_flops_per_token"]


# --------------------------------------------------------------------------
# whole-LM cost (what bench_ops utilization divides by)
# --------------------------------------------------------------------------


@pytest.mark.parametrize("name", ["hla2", "attn", "gla"])
def test_model_cost_exceeds_op_cost(name):
    cfg = _cfg_for(name)
    opc = costs.op_cost(name, cfg, mode="train_fwd", seq_len=64)
    lmc = costs.model_cost(cfg, mode="train_fwd", seq_len=64)
    assert lmc.op == f"lm/{seq_op.op_for(cfg).name}"
    # embeddings + FFNs + unembed + n_layers of mixers dominate one mixer
    assert lmc.flops_per_token > opc.flops_per_token
    assert lmc.state_bytes == opc.state_bytes * cfg.n_layers


def test_model_cost_scales_state_math_by_layers():
    cfg = _cfg_for("hla2")
    opc = costs.op_cost("hla2", cfg, mode="train_fwd", seq_len=64)
    lmc = costs.model_cost(cfg, mode="train_fwd", seq_len=64)
    assert lmc.breakdown["state_flops"] == pytest.approx(
        opc.breakdown["state_flops"] * cfg.n_layers
    )
