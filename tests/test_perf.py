"""Perf-observability suite: bench history, regression gate, roofline,
profiler capture (DESIGN.md §15).

The gate semantics are the load-bearing part: identical runs must pass,
a planted 2x slowdown must fail, and the ``max_rel`` cap must keep a
junk-IQR row fail-able — exactly the three behaviours CI's perf-smoke
job scripts against.
"""

import json
import subprocess
import sys

import pytest

from repro.obs import BenchHistory, env_fingerprint, read_bench
from repro.obs.perf import (
    device_peak,
    profile_capture,
    roofline_utilization,
    validate_bench_record,
)
from repro.obs.perfcheck import compare_rows, compare_runs
from repro.obs.perfcheck import main as perfcheck_main


def _write_run(path, rows, *, env=None, run_id=None):
    h = BenchHistory(path, env=env, run_id=run_id)
    for name, value, kw in rows:
        h.bench_row(name, value, **kw)
    return h


ROWS = [
    ("kernels/hla2_fwd/n1024", 5000.0,
     dict(unit="us", direction="lower", dispersion=50.0, n=9)),
    ("ops/gla/decode_tok_per_s", 2000.0,
     dict(unit="tok/s", direction="higher", dispersion=20.0, n=9)),
]


# --------------------------------------------------------------------------
# history round-trip + schema
# --------------------------------------------------------------------------


def test_history_roundtrip(tmp_path):
    path = tmp_path / "h.jsonl"
    h = _write_run(path, ROWS)
    assert h.rows_written == 2
    runs = read_bench(path)
    assert len(runs) == 1
    run = runs[0]
    assert run["run_id"] == h.run_id
    for key in ("git_sha", "jax_version", "backend", "device_count"):
        assert key in run["env"]
    row = run["rows"]["kernels/hla2_fwd/n1024"]
    assert row["value"] == 5000.0
    assert row["dispersion"] == 50.0
    assert row["direction"] == "lower"


def test_history_appends_runs_oldest_first(tmp_path):
    path = tmp_path / "h.jsonl"
    _write_run(path, ROWS, run_id="aaa")
    _write_run(path, ROWS, run_id="bbb")
    assert [r["run_id"] for r in read_bench(path)] == ["aaa", "bbb"]


def test_history_header_is_lazy(tmp_path):
    path = tmp_path / "h.jsonl"
    BenchHistory(path)  # no rows -> no file
    assert not path.exists()


def test_history_rejects_bad_direction(tmp_path):
    h = BenchHistory(tmp_path / "h.jsonl")
    with pytest.raises(ValueError, match="direction"):
        h.bench_row("a/b", 1.0, unit="us", direction="sideways")


def test_read_bench_rejects_garbage(tmp_path):
    path = tmp_path / "h.jsonl"
    path.write_text("not json\n")
    with pytest.raises(ValueError, match="not JSON"):
        read_bench(path)


def test_read_bench_rejects_orphan_row(tmp_path):
    path = tmp_path / "h.jsonl"
    path.write_text(json.dumps({
        "kind": "row", "run_id": "nope", "name": "a/b", "value": 1.0,
        "unit": "us", "direction": "lower", "dispersion": 0.0, "n": 1,
    }) + "\n")
    with pytest.raises(ValueError, match="unknown run_id"):
        read_bench(path)


@pytest.mark.parametrize("mutate,frag", [
    (lambda r: r.pop("schema"), "schema"),
    (lambda r: r.update(env="x"), "env"),
    (lambda r: r["env"].pop("git_sha"), "git_sha"),
])
def test_validate_run_record_errors(mutate, frag):
    rec = {"kind": "run", "schema": "repro.obs.bench/v1", "run_id": "r1",
           "ts": 0.0, "env": {"git_sha": "x", "jax_version": "x",
                              "backend": "cpu", "device_count": 1}}
    mutate(rec)
    assert frag in validate_bench_record(rec)


@pytest.mark.parametrize("mutate,frag", [
    (lambda r: r.pop("name"), "name"),
    (lambda r: r.update(value="fast"), "value"),
    (lambda r: r.update(value=True), "value"),  # bools are not numbers
    (lambda r: r.update(direction="up"), "direction"),
    (lambda r: r.update(n=1.5), "n"),
])
def test_validate_row_record_errors(mutate, frag):
    rec = {"kind": "row", "run_id": "r1", "name": "a/b", "value": 1.0,
           "unit": "us", "direction": "lower", "dispersion": 0.0, "n": 1}
    mutate(rec)
    assert frag in validate_bench_record(rec)


def test_validate_accepts_good_records():
    assert validate_bench_record({
        "kind": "run", "schema": "repro.obs.bench/v1", "run_id": "r",
        "ts": 1.0, "env": {"git_sha": "x", "jax_version": "x",
                           "backend": "cpu", "device_count": 1}
    }) is None
    assert validate_bench_record({
        "kind": "row", "run_id": "r", "name": "a/b", "value": 2,
        "unit": "us", "direction": "higher", "dispersion": 0, "n": 3,
    }) is None


def test_env_fingerprint_keys():
    fp = env_fingerprint()
    assert set(fp) >= {"git_sha", "jax_version", "backend",
                       "device_count", "device_kind"}
    assert fp["backend"] == "cpu"  # conftest forces JAX_PLATFORMS=cpu
    assert fp["device_count"] >= 1


def test_validate_cli_checks_bench_files(tmp_path):
    from repro.obs import validate as v

    path = tmp_path / "h.jsonl"
    _write_run(path, ROWS)
    assert v.main(["--bench", str(path)]) == 0
    path.write_text("{}\n")
    assert v.main(["--bench", str(path)]) == 1


# --------------------------------------------------------------------------
# the regression gate
# --------------------------------------------------------------------------


def test_identical_runs_pass(tmp_path):
    old, new = tmp_path / "a.jsonl", tmp_path / "b.jsonl"
    _write_run(old, ROWS)
    _write_run(new, ROWS)
    assert perfcheck_main([str(old), str(new)]) == 0


def test_planted_2x_slowdown_fails(tmp_path, capsys):
    old, new = tmp_path / "a.jsonl", tmp_path / "b.jsonl"
    _write_run(old, ROWS)
    slow = [(n, 2 * v if kw["direction"] == "lower" else v / 2, kw)
            for n, v, kw in ROWS]
    _write_run(new, slow)
    assert perfcheck_main([str(old), str(new)]) == 1
    err = capsys.readouterr().err
    assert "2 significant regression(s)" in err


def test_within_noise_change_passes():
    old = {"name": "a/b", "value": 100.0, "unit": "us",
           "direction": "lower", "dispersion": 10.0, "n": 9}
    new = dict(old, value=120.0)  # +20% < tol and < 3*(10+10)
    r = compare_rows(old, new, tol=0.25, noise_mult=3.0)
    assert not r["regressed"]


def test_direction_higher_regresses_on_drop():
    old = {"name": "a/tok_per_s", "value": 1000.0, "unit": "tok/s",
           "direction": "higher", "dispersion": 0.0, "n": 9}
    down = dict(old, value=400.0)
    up = dict(old, value=2000.0)
    assert compare_rows(old, down, tol=0.25, noise_mult=3.0)["regressed"]
    r = compare_rows(old, up, tol=0.25, noise_mult=3.0)
    assert not r["regressed"] and r["improved"]


def test_max_rel_caps_noise_allowance():
    """A junk-IQR row (dispersion ~ value) must STILL fail on a 2x move
    — without the cap the noise term would swallow it."""
    old = {"name": "a/b", "value": 100.0, "unit": "us",
           "direction": "lower", "dispersion": 80.0, "n": 9}
    new = dict(old, value=200.0)
    capped = compare_rows(old, new, tol=0.25, noise_mult=3.0, max_rel=0.75)
    assert capped["regressed"]
    uncapped = compare_rows(old, new, tol=0.25, noise_mult=3.0,
                            max_rel=1e9)
    assert not uncapped["regressed"]


def test_disjoint_rows_never_fail(tmp_path):
    old, new = tmp_path / "a.jsonl", tmp_path / "b.jsonl"
    _write_run(old, [("old/only", 1.0, dict(unit="us"))])
    _write_run(new, [("new/only", 1.0, dict(unit="us"))])
    assert perfcheck_main([str(old), str(new)]) == 0


def test_compare_runs_partitions_rows(tmp_path):
    old, new = tmp_path / "a.jsonl", tmp_path / "b.jsonl"
    _write_run(old, ROWS + [("old/only", 1.0, dict(unit="us"))])
    _write_run(new, ROWS + [("new/only", 1.0, dict(unit="us"))])
    cmp = compare_runs(read_bench(old)[-1], read_bench(new)[-1])
    assert len(cmp["compared"]) == 2
    assert cmp["only_old"] == ["old/only"]
    assert cmp["only_new"] == ["new/only"]


def test_perfcheck_latest_run_wins(tmp_path):
    """The gate compares the LATEST run in each file, not the first."""
    old, new = tmp_path / "a.jsonl", tmp_path / "b.jsonl"
    _write_run(old, ROWS)
    _write_run(new, [(n, 100 * v, kw) for n, v, kw in ROWS])  # stale junk
    _write_run(new, ROWS)  # latest run is clean
    assert perfcheck_main([str(old), str(new)]) == 0


def test_perfcheck_missing_file_exits_2(tmp_path, capsys):
    assert perfcheck_main([str(tmp_path / "no.jsonl"),
                           str(tmp_path / "pe.jsonl")]) == 2


def test_perfcheck_json_output(tmp_path, capsys):
    old, new = tmp_path / "a.jsonl", tmp_path / "b.jsonl"
    _write_run(old, ROWS)
    _write_run(new, ROWS)
    assert perfcheck_main([str(old), str(new), "--json"]) == 0
    raw = capsys.readouterr().out
    out, _ = json.JSONDecoder().raw_decode(raw)  # summary line follows
    assert {r["name"] for r in out["compared"]} == {n for n, _, _ in ROWS}


def test_perfcheck_runs_without_jax(tmp_path):
    """The gate must run on bare CI python: importing perfcheck (and
    perf) cannot pull in jax."""
    code = (
        "import sys\n"
        "sys.modules['jax'] = None\n"  # any import attempt explodes
        "from repro.obs import perfcheck\n"
        "from repro.obs import perf\n"
        "print('ok')\n"
    )
    import os

    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env={"PYTHONPATH": src, "PATH": os.environ.get("PATH", "")},
    )
    assert out.returncode == 0 and "ok" in out.stdout, out.stderr


# --------------------------------------------------------------------------
# roofline + profiler capture
# --------------------------------------------------------------------------


class _Cost:
    def __init__(self, flops, bytes_):
        self.flops_per_token = flops
        self.bytes_per_token = bytes_


def test_roofline_compute_vs_memory_bound():
    peak = {"flops_per_s": 100e9, "bytes_per_s": 10e9,
            "kind": "synthetic", "source": "table"}
    # ridge = 10 FLOPs/byte: intensity 100 -> compute, 1 -> memory
    hot = roofline_utilization(1e6, _Cost(10_000.0, 100.0), peak)
    assert hot["bound"] == "compute"
    assert hot["utilization"] == pytest.approx(1e6 * 1e4 / 100e9)
    cold = roofline_utilization(1e6, _Cost(100.0, 100.0), peak)
    assert cold["bound"] == "memory"
    assert cold["utilization"] == pytest.approx(1e6 * 100.0 / 10e9)


def test_device_peak_on_cpu_is_calibrated():
    peak = device_peak()
    assert peak["source"] in ("table", "calibrated")
    assert peak["flops_per_s"] > 0 and peak["bytes_per_s"] > 0


def test_device_peak_known_table():
    class FakeTPU:
        device_kind = "TPU v4"

    peak = device_peak(FakeTPU())
    assert peak["source"] == "table"
    assert peak["flops_per_s"] == 275e12


def test_profile_capture_noop_when_falsy():
    with profile_capture(None) as p:
        assert p is None
    with profile_capture("") as p:
        assert p is None


def test_profile_capture_writes_trace_and_events(tmp_path):
    import jax.numpy as jnp

    from repro.obs import Obs

    obs = Obs()
    prof = tmp_path / "prof"
    with profile_capture(str(prof), obs=obs) as p:
        assert p == str(prof)
        (jnp.ones((8, 8)) @ jnp.ones((8, 8))).block_until_ready()
    names = [e["name"] for e in obs.events(kind="event")]
    assert "profile.start" in names and "profile.stop" in names
    start = obs.events(name="profile.start")[0]
    assert start["wall_ns"] > 0
    assert any(prof.rglob("*")), "no trace files written"
