"""Serving front-end: prefix/state cache, admission scheduler, async server.

The correctness centerpiece is ``test_cached_prefix_decode_exact``: a
cache-hit admission (resume from an O(1) state snapshot + prefill only
the uncached suffix) must produce token-for-token the same stream as a
cold-start engine, across streaming ops and ragged prefix splits — the
chunkwise carry identity made a serving feature (DESIGN.md §16).
"""

import asyncio
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import lm
from repro.models.param import init_params
from repro.runtime.faults import FaultPlan, FaultSpec
from repro.serving import (
    Engine,
    GenRequest,
    PrefixCache,
    Scheduler,
    SchedulerConfig,
    StatePool,
    state_bytes_for,
)
from repro.serving.cache import rolling_hashes, tree_bytes, tree_checksum
from repro.serving.server import AsyncServer, collect


def _params(cfg, seed=0):
    return init_params(lm.lm_specs(cfg), jax.random.key(seed))


def _tree(nbytes, seed=0):
    """A fake host state snapshot of exactly ``nbytes`` bytes."""
    rng = np.random.RandomState(seed)
    return {"s": rng.randn(nbytes // 8).astype(np.float64)}


def _req(rid, **kw):
    """A scheduler-facing request stub (no prompt needed)."""
    kw.setdefault("deadline_s", None)
    kw.setdefault("priority", 1)
    kw.setdefault("tenant", "default")
    return types.SimpleNamespace(rid=rid, **kw)


class _Clock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


# --------------------------------------------------------------------------
# cache: keying, lookup, eviction, integrity
# --------------------------------------------------------------------------


def test_rolling_hash_prefix_consistency(rng):
    toks = rng.randint(0, 1000, 64)
    one_pass = rolling_hashes(toks, [8, 24, 64])
    for n, h in zip([8, 24, 64], one_pass):
        assert rolling_hashes(toks[:n], [n]) == [h]
    # content-sensitive: flipping one token inside the prefix changes it
    mut = toks.copy()
    mut[3] += 1
    assert rolling_hashes(mut, [8]) != rolling_hashes(toks, [8])


def test_cache_longest_prefix_lookup(rng):
    cache = PrefixCache(granularity=4, budget_bytes=1 << 20)
    toks = rng.randint(0, 100, 16)
    assert cache.lookup(toks) is None  # empty cache: miss
    cache.insert(toks[:4], _tree(64, 1))
    cache.insert(toks[:12], _tree(64, 2))
    n, state = cache.lookup(toks)
    assert n == 12 and state["s"][0] == _tree(64, 2)["s"][0]
    # max_prefix caps the usable length (engine passes len(prompt) - 1)
    n, _ = cache.lookup(toks, max_prefix=11)
    assert n == 4
    # a prompt diverging at token 5 only matches the 4-prefix
    other = toks.copy()
    other[5] += 1
    n, _ = cache.lookup(other)
    assert n == 4
    assert cache.stats()["hits"] == 3


def test_cache_insert_rejects_misaligned_and_oversize():
    cache = PrefixCache(granularity=4, budget_bytes=256)
    assert not cache.insert(np.arange(6), _tree(64))  # 6 % 4 != 0
    assert not cache.insert(np.arange(4), _tree(512))  # > whole budget
    assert len(cache) == 0 and cache.bytes == 0


def test_cache_hash_collision_never_returns_wrong_state(rng):
    """A (length, hash) collision must be caught by the stored-token
    comparison — wrong tokens are a correctness bug, a miss is not."""
    cache = PrefixCache(granularity=4, budget_bytes=1 << 20)
    a = rng.randint(0, 100, 4)
    b = (a + 1) % 100
    cache.insert(a, _tree(64, 1))
    entry = next(iter(cache._entries.values()))
    # forge a collision: register a's entry under b's key as well
    forged_key = (4, (rolling_hashes(b, [4])[0] + cache._ns_seed())
                  % ((1 << 61) - 1))
    cache._entries[forged_key] = entry
    cache._lengths[4] += 1
    assert cache.lookup(b) is None  # token guard rejects the forgery
    n, _ = cache.lookup(a)
    assert n == 4


def test_cache_eviction_respects_byte_budget():
    cache = PrefixCache(granularity=4, budget_bytes=200)
    for i in range(4):  # 80 bytes each: the 4th insert must evict
        cache.insert(np.arange(i * 4, i * 4 + 4), _tree(80, i))
    assert cache.bytes <= 200
    assert len(cache) == 2
    assert cache.stats()["evicted_bytes"] == 160.0
    # LRU: entries 0 and 1 went first; 2 and 3 survive
    assert cache.lookup(np.arange(0, 4)) is None
    assert cache.lookup(np.arange(8, 12)) is not None
    # a lookup refreshes recency: entry 2 now outlives a newer insert
    cache.insert(np.arange(100, 104), _tree(80, 9))
    assert cache.lookup(np.arange(8, 12)) is not None
    assert cache.lookup(np.arange(12, 16)) is None  # 3 was LRU, evicted


def test_cache_namespace_scopes_keys(rng):
    toks = rng.randint(0, 100, 4)
    a = PrefixCache(granularity=4, namespace="model-a")
    b = PrefixCache(granularity=4, namespace="model-b")
    a.insert(toks, _tree(64))
    assert a.lookup(toks) is not None
    assert b.lookup(toks) is None
    # same content, same namespace -> same key (cross-tenant sharing)
    a2 = PrefixCache(granularity=4, namespace="model-a")
    a2.insert(toks, _tree(64))
    assert next(iter(a2._entries)) == next(iter(a._entries))


def test_cache_checksum_drops_corrupt_entry(rng):
    """Injected corruption (``cache.corrupt``) and real bit rot both hit
    the crc32 check: the entry is dropped, the lookup degrades to a miss
    (cold prefill), never to wrong state."""
    plan = FaultPlan(FaultSpec(point="cache.corrupt", at=0))
    cache = PrefixCache(granularity=4, budget_bytes=1 << 20, faults=plan)
    toks = rng.randint(0, 100, 8)
    cache.insert(toks, _tree(64))
    assert cache.lookup(toks) is None  # corrupted on first probe
    assert plan.fired["cache.corrupt"] == 1
    assert len(cache) == 0
    assert cache.stats()["hits"] == 0

    # organic corruption: mutate a leaf behind the cache's back
    cache2 = PrefixCache(granularity=4, budget_bytes=1 << 20)
    cache2.insert(toks, _tree(64))
    next(iter(cache2._entries.values())).state["s"][0] += 1.0
    assert cache2.lookup(toks) is None
    assert len(cache2) == 0


def test_state_bytes_budget_sizing():
    cfg = get_config("hla-1b", reduced=True)
    per_entry = state_bytes_for(cfg)
    assert per_entry > 0
    # the analytic size should be in the ballpark of a real host snapshot
    snap = jax.device_get(lm.lm_init_states(cfg, 1, 32))
    actual = tree_bytes(snap)
    assert 0.1 * actual <= per_entry <= 10 * actual


# --------------------------------------------------------------------------
# scheduler: priority, fairness, expiry, autoscaling
# --------------------------------------------------------------------------


def test_scheduler_fifo_within_class():
    clk = _Clock()
    s = Scheduler(SchedulerConfig(), clock=clk)
    for i in range(3):
        s.submit(_req(i))
    assert [s.pop().rid for _ in range(3)] == [0, 1, 2]
    assert s.pop() is None
    assert s.obs.registry.get("sched_promotions_total").total() == 0


def test_scheduler_priority_classes_and_promotion():
    clk = _Clock()
    s = Scheduler(SchedulerConfig(), clock=clk)
    s.submit(_req(0, priority=2))
    s.submit(_req(1, priority=0))
    s.submit(_req(2, priority=1))
    assert [s.pop().rid for _ in range(3)] == [1, 2, 0]
    # rids 1 and 2 both jumped rid 0 (the oldest live arrival)
    assert s.obs.registry.get("sched_promotions_total").total() == 2
    promos = s.obs.events("sched.promote")
    assert [e["rid"] for e in promos] == [1, 2]


def test_scheduler_deadline_slack_orders_within_class():
    clk = _Clock()
    s = Scheduler(SchedulerConfig(), clock=clk)
    s.submit(_req(0))  # no deadline: ranks last in its class
    s.submit(_req(1, deadline_s=5.0))
    s.submit(_req(2, deadline_s=1.0))
    assert [s.pop().rid for _ in range(3)] == [2, 1, 0]


def test_scheduler_tenant_fair_share():
    clk = _Clock()
    s = Scheduler(SchedulerConfig(), clock=clk)
    for i in range(3):
        s.submit(_req(i, tenant="chatty"))
    s.submit(_req(3, tenant="quiet"))
    first = s.pop()  # arrival order: chatty's first request
    assert first.rid == 0
    # chatty now holds a slot -> quiet's head outranks chatty's
    second = s.pop()
    assert second.rid == 3
    s.release(first)
    s.release(second)
    assert [s.pop().rid for _ in range(2)] == [1, 2]


def test_scheduler_expiry_and_cancel():
    clk = _Clock()
    s = Scheduler(SchedulerConfig(), clock=clk)
    s.submit(_req(0, deadline_s=1.0))
    s.submit(_req(1, deadline_s=10.0))
    s.submit(_req(2))
    assert s.expire() == []  # nothing passed yet
    clk.t = 2.0
    expired = s.expire()
    assert [r.rid for r in expired] == [0]
    assert len(s) == 2
    assert s.cancel(1).rid == 1
    assert s.cancel(1) is None  # idempotent
    assert s.pop().rid == 2
    assert len(s) == 0
    # cancelled/popped entries never resurface through expire
    clk.t = 20.0
    assert s.expire() == []
    assert s.obs.registry.get("sched_expired_total").total() == 1


def test_scheduler_autoscaler_hysteresis():
    clk = _Clock()
    cfg = SchedulerConfig(min_slots=1, max_slots=4, scale_down_ticks=3,
                          quarantine_cap=2)
    s = Scheduler(cfg, clock=clk)
    assert s.target_slots() == 1  # idle: stays at min
    for i in range(8):
        s.submit(_req(i))
    assert s.target_slots() == 4  # queue pressure: immediate scale-up
    for i in range(8):
        s.pop()
    # empty queue: needs scale_down_ticks consecutive idle ticks per step
    assert s.target_slots() == 4
    assert s.target_slots() == 4
    assert s.target_slots() == 3  # 3rd idle tick
    s.submit(_req(99))
    assert s.target_slots() == 4  # burst: back up immediately
    s.pop()
    # quarantine pressure clamps to min_slots regardless of history
    s.note_quarantine(2)
    assert s.target_slots() == 1


def test_scheduler_stall_fault_point():
    plan = FaultPlan(FaultSpec(point="sched.stall", at=1))
    s = Scheduler(SchedulerConfig(), faults=plan)
    assert not s.stalled()  # hit 0: not scheduled
    assert s.stalled()      # hit 1: fires
    assert not s.stalled()
    assert s.obs.registry.get("sched_stall_ticks_total").total() == 1


def test_scheduler_config_validation():
    with pytest.raises(ValueError, match="min_slots"):
        SchedulerConfig(min_slots=3, max_slots=2)
    with pytest.raises(ValueError, match="scale_down_ticks"):
        SchedulerConfig(scale_down_ticks=0)
    s = Scheduler(SchedulerConfig())
    s.submit(_req(7))
    with pytest.raises(ValueError, match="already queued"):
        s.submit(_req(7))


# --------------------------------------------------------------------------
# engine + cache: cached-prefix decode is EXACT
# --------------------------------------------------------------------------


@pytest.mark.parametrize("mixer", ["hla2", "gla", "rwkv6"])
def test_cached_prefix_decode_exact(mixer, rng):
    """Cache-hit decode == cold-start decode, token for token, across
    ragged prefix lengths and chunk-boundary/mid-chunk splits."""
    cfg = get_config("hla-1b", reduced=True, mixer=mixer)
    params = _params(cfg)
    prefix = rng.randint(2, cfg.vocab, 12)

    def prompts():
        out = []
        # suffix lengths 1/2/4 put the resume point at the cached
        # boundary (L=13: suffix of one token), mid-chunk (L=14), and
        # exactly on a granularity multiple (L=16)
        for i, sfx in enumerate([1, 2, 4]):
            out.append(np.concatenate(
                [prefix, rng.randint(2, cfg.vocab, sfx)]))
        # long prompt: hit at 12, then a carry to the NEXT boundary (20)
        # that inserts a new entry before the suffix prefill
        out.append(np.concatenate([prefix,
                                   rng.randint(2, cfg.vocab, 9)]))
        # short prompt (< granularity): stays on the pure cold path
        out.append(rng.randint(2, cfg.vocab, 3))
        return out

    ps = prompts()
    reqs = lambda: [GenRequest(rid=i, prompt=p, max_new=6)  # noqa: E731
                    for i, p in enumerate(ps)]

    cold = Engine(cfg, params, slots=1, max_len=64, block=4, seed=0)
    ref = cold.run(reqs())

    cache = PrefixCache(granularity=4, budget_bytes=1 << 26)
    warm = Engine(cfg, params, slots=1, max_len=64, block=4, seed=0,
                  cache=cache)
    got = warm.run(reqs())

    for r_ref, r_got in zip(ref, got):
        assert r_got.status == "ok"
        assert r_got.tokens == r_ref.tokens, (
            f"{mixer}: cached-prefix stream diverged for rid "
            f"{r_got.rid}: {r_got.tokens} != {r_ref.tokens}"
        )
    st = cache.stats()
    assert st["hits"] >= 3  # rids 1..3 all resume from rid 0's prefix
    admitted = warm.obs.events("request.admitted")
    hits = {e["rid"]: e["cached_prefix"] for e in admitted}
    assert hits[0] == 0 and hits[4] == 0  # cold + short prompt
    assert hits[1] == 12 and hits[2] == 12 and hits[3] == 12


def test_cache_corrupt_falls_back_to_cold_prefill(rng):
    """``cache.corrupt`` on a hit: the entry is dropped and admission
    degrades to cold prefill with an identical stream."""
    cfg = get_config("hla-1b", reduced=True)
    params = _params(cfg)
    prompt = rng.randint(2, cfg.vocab, 13)
    plan = FaultPlan(FaultSpec(point="cache.corrupt", at=0))
    cache = PrefixCache(granularity=4, budget_bytes=1 << 26)
    eng = Engine(cfg, params, slots=1, max_len=64, block=4, seed=0,
                 cache=cache, faults=plan)
    (r0,) = eng.run([GenRequest(rid=0, prompt=prompt, max_new=6)])
    # r1's lookup returns rid 0's entry -> corruption fires -> checksum
    # drops it -> cold prefill (which re-inserts the boundary state)
    (r1,) = eng.run([GenRequest(rid=1, prompt=prompt, max_new=6)])
    (r2,) = eng.run([GenRequest(rid=2, prompt=prompt, max_new=6)])
    assert r1.tokens == r0.tokens == r2.tokens
    assert plan.fired["cache.corrupt"] == 1
    reg = eng.obs.registry
    assert reg.get("cache_corrupt_dropped_total").total() == 1
    assert reg.get("cache_hits_total").total() == 1  # only r2 hits


def test_cache_insertion_gated_on_finite_state(rng):
    """A NaN-poisoned admission must never become a cache entry."""
    cfg = get_config("hla-1b", reduced=True)
    params = jax.tree.map(
        lambda x: jnp.full_like(x, jnp.nan)
        if jnp.issubdtype(x.dtype, jnp.inexact) else x,
        _params(cfg),
    )
    cache = PrefixCache(granularity=4, budget_bytes=1 << 26)
    eng = Engine(cfg, params, slots=1, max_len=64, block=4, cache=cache)
    (r,) = eng.run([GenRequest(rid=0, prompt=rng.randint(2, cfg.vocab, 13),
                               max_new=4)])
    assert r.status == "error"
    assert len(cache) == 0


# --------------------------------------------------------------------------
# engine + scheduler: expiry, priority, cancellation
# --------------------------------------------------------------------------


def test_expired_queued_request_never_spends_a_prefill(rng):
    """Starvation regression: a queued request whose deadline passes is
    finalized as ``timeout`` on the next drive tick — while the only
    slot is still busy — and no slot is ever spent prefilling it."""
    cfg = get_config("hla-1b", reduced=True)
    plan = FaultPlan(  # every decode block sleeps 30ms
        FaultSpec(point="engine.slow_block", at=0, times=None, arg=0.03))
    eng = Engine(cfg, _params(cfg), slots=1, max_len=64, block=4,
                 faults=plan)
    admitted = []
    real_admit = eng.admit
    eng.admit = lambda s, r: (admitted.append(r.rid), real_admit(s, r))[1]
    terminal = []
    eng.on_stream = lambda rid, toks, res: (
        terminal.append(rid) if res is not None else None)
    # the long request outranks the doomed one by priority class —
    # otherwise deadline-slack ordering would (correctly) admit the
    # urgent request first and nothing would starve
    long = GenRequest(rid=0, prompt=rng.randint(2, cfg.vocab, 8),
                      max_new=24, priority=0)
    doomed = GenRequest(rid=1, prompt=rng.randint(2, cfg.vocab, 8),
                        max_new=4, deadline_s=0.05)
    r0, r1 = eng.run([long, doomed])
    assert r0.status == "ok" and len(r0.tokens) == 24
    assert r1.status == "timeout" and r1.tokens == []
    assert admitted == [0]  # the doomed request never touched a slot
    assert terminal[0] == 1  # ...and learned its fate before rid 0 ended
    assert eng.obs.registry.get("sched_expired_total").total() == 1


def test_priority_reorders_single_slot_admissions(rng):
    cfg = get_config("hla-1b", reduced=True)
    eng = Engine(cfg, _params(cfg), slots=1, max_len=64, block=4)
    terminal = []
    eng.on_stream = lambda rid, toks, res: (
        terminal.append(rid) if res is not None else None)
    low = GenRequest(rid=0, prompt=rng.randint(2, cfg.vocab, 6),
                     max_new=4, priority=2)
    high = GenRequest(rid=1, prompt=rng.randint(2, cfg.vocab, 6),
                      max_new=4, priority=0)
    r_low, r_high = eng.run([low, high])
    assert r_low.status == r_high.status == "ok"
    assert terminal == [1, 0]  # high drained first despite arrival order
    assert eng.obs.registry.get("sched_promotions_total").total() == 1


def test_cancel_queued_request_finalizes_immediately(rng):
    cfg = get_config("hla-1b", reduced=True)
    eng = Engine(cfg, _params(cfg), slots=1, max_len=64, block=4)
    eng.submit(GenRequest(rid=5, prompt=rng.randint(2, cfg.vocab, 6),
                          max_new=4))
    assert eng.cancel(5)
    assert eng.results[5].status == "cancelled"
    assert len(eng.scheduler) == 0
    assert not eng.cancel(5)  # already terminal


# --------------------------------------------------------------------------
# host snapshots
# --------------------------------------------------------------------------


def test_host_snapshot_roundtrip():
    cfg = get_config("hla-1b", reduced=True)
    pool = StatePool(lambda n: lm.lm_init_states(cfg, n, 32), slots=2)
    vals = jax.tree.map(
        lambda x: (jnp.arange(x.size, dtype=jnp.float32)
                   .reshape(x.shape).astype(x.dtype)
                   if jnp.issubdtype(x.dtype, jnp.inexact) else x),
        pool.empty_slot_state(),
    )
    pool.write_slot(1, vals)
    snap = pool.snapshot_slot(1, host=True)
    assert all(isinstance(leaf, np.ndarray)
               for leaf in jax.tree.leaves(snap))
    before = tree_checksum(snap)
    pool.reset_slot(1)
    pool.restore_slot(1, snap)
    restored = jax.device_get(pool.read_slot(1))
    assert tree_checksum(restored) == before
    for a, b in zip(jax.tree.leaves(snap), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --------------------------------------------------------------------------
# async streaming server
# --------------------------------------------------------------------------


def test_async_server_streams_match_results(rng):
    cfg = get_config("hla-1b", reduced=True)
    eng = Engine(cfg, _params(cfg), slots=2, max_len=64, block=4)
    reqs = [GenRequest(rid=i, prompt=rng.randint(2, cfg.vocab, 6),
                       max_new=5) for i in range(3)]

    async def main():
        async with AsyncServer(eng) as srv:
            outs = await asyncio.gather(*[collect(srv, r) for r in reqs])
        return outs

    outs = asyncio.run(main())
    for req, (toks, res) in zip(reqs, outs):
        assert res.status == "ok"
        assert toks == res.tokens == eng.results[req.rid].tokens
        assert len(toks) == 5
    reg = eng.obs.registry
    assert reg.get("server_streams_total").total() == 3
    assert reg.get("server_stream_tokens_total").total() == 15
    assert eng.on_stream is None  # drain uninstalled the hook


def test_async_server_drain_refuses_new_streams(rng):
    cfg = get_config("hla-1b", reduced=True)
    eng = Engine(cfg, _params(cfg), slots=1, max_len=64, block=4)

    async def main():
        srv = AsyncServer(eng)
        async with srv:
            toks, res = await collect(
                srv, GenRequest(rid=0, prompt=rng.randint(2, cfg.vocab, 6),
                                max_new=4))
            assert res.status == "ok" and len(toks) == 4
        with pytest.raises(RuntimeError, match="draining"):
            await srv.generate(
                GenRequest(rid=1, prompt=rng.randint(2, cfg.vocab, 6),
                           max_new=4)).__anext__()

    asyncio.run(main())


def test_async_server_backpressure_pauses_drive_loop(rng):
    """A slow consumer must throttle generation: with a tiny buffered-
    token watermark the drive loop pauses instead of growing queues."""
    cfg = get_config("hla-1b", reduced=True)
    eng = Engine(cfg, _params(cfg), slots=1, max_len=64, block=4)
    req = GenRequest(rid=0, prompt=rng.randint(2, cfg.vocab, 6),
                     max_new=12)

    async def main():
        async with AsyncServer(eng, max_buffered_tokens=2) as srv:
            toks = []
            async for t in srv.generate(req):
                toks.append(t)
                await asyncio.sleep(0.005)  # slow reader
            return toks

    toks = asyncio.run(main())
    assert len(toks) == 12
    assert eng.obs.registry.get(
        "server_backpressure_waits_total").total() >= 1
