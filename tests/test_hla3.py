"""Third order: paper Alg 3/4 self-consistency + the corrected exact operator.

Includes the erratum tests (DESIGN.md §7): the paper's Theorem 7.1 operator
differs from its stated target ((W W^T) . L)(W V); both are implemented.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hla3 import (
    hla3_exact_chunkwise,
    hla3_exact_naive,
    hla3_exact_serial,
    hla3_exact_step,
    hla3_exact_init_state,
    hla3_paper_chunkwise,
    hla3_paper_naive,
    hla3_paper_scan,
    hla3_paper_serial,
)
from conftest import make_qkv

TOL = dict(atol=1e-8, rtol=1e-7)


def _wwtw_oracle(q, k, v):
    """The paper's *stated* target: ((W W^T) . L)(W V), W = L.(QK^T)."""
    n = q.shape[-2]
    L = jnp.tril(jnp.ones((n, n)))
    W = jnp.einsum("...td,...jd->...tj", q, k) * L
    WWT = jnp.einsum("...ti,...ji->...tj", W, W) * L
    return jnp.einsum("...tj,...je->...te", WWT, jnp.einsum("...ji,...ie->...je", W, v))


@pytest.mark.parametrize("normalize", [False, True])
def test_paper_alg3_internal_consistency(rng, normalize):
    """Alg 3 == Alg 4 (scan, materialized maps) == chunkwise == region oracle."""
    q, k, v, _ = make_qkv(rng, n=20, d=5, dv=4)
    o0 = hla3_paper_naive(q, k, v, normalize=normalize)
    o1, _ = hla3_paper_serial(q, k, v, None, normalize=normalize)
    o2 = hla3_paper_scan(q, k, v, normalize=normalize)
    o3, _ = hla3_paper_chunkwise(q, k, v, chunk=5, normalize=normalize)
    for o in (o1, o2, o3):
        np.testing.assert_allclose(o, o0, **TOL)


def test_paper_chunk_carry(rng):
    q, k, v, _ = make_qkv(rng, n=20, d=5, dv=4)
    o_full, s_full = hla3_paper_chunkwise(q, k, v, chunk=5)
    o_a, st = hla3_paper_chunkwise(
        q[..., :8, :], k[..., :8, :], v[..., :8, :], chunk=4
    )
    o_b, s_b = hla3_paper_chunkwise(
        q[..., 8:, :], k[..., 8:, :], v[..., 8:, :], chunk=6, state=st
    )
    np.testing.assert_allclose(jnp.concatenate([o_a, o_b], -2), o_full, **TOL)
    for f in s_full._fields:
        np.testing.assert_allclose(getattr(s_b, f), getattr(s_full, f), **TOL)


@pytest.mark.parametrize("use_gamma", [False, True])
@pytest.mark.parametrize("normalize", [False, True])
def test_exact_views_agree(rng, use_gamma, normalize):
    q, k, v, gam = make_qkv(rng, n=20, d=5, dv=4)
    gamma = gam if use_gamma else None
    o0 = hla3_exact_naive(q, k, v, gamma, normalize=normalize)
    o1, s1 = hla3_exact_serial(q, k, v, gamma, normalize=normalize)
    o2, s2 = hla3_exact_chunkwise(q, k, v, gamma, chunk=5, normalize=normalize)
    np.testing.assert_allclose(o1, o0, **TOL)
    np.testing.assert_allclose(o2, o0, **TOL)
    np.testing.assert_allclose(s2.outer.S, s1.outer.S, **TOL)
    np.testing.assert_allclose(s2.inner.P, s1.inner.P, **TOL)


def test_exact_matches_wwtw_target(rng):
    """hla3_exact computes the paper's *stated* Theorem 7.1 target."""
    q, k, v, _ = make_qkv(rng, B=1, H=1, n=14, d=4, dv=3)
    o_ref = _wwtw_oracle(q, k, v)
    o, _ = hla3_exact_serial(q, k, v)
    np.testing.assert_allclose(o, o_ref, **TOL)


def test_erratum_paper_operator_differs_from_stated_target(rng):
    """Erratum (2): Alg 3's output != ((W W^T) . L)(W V).

    Region analysis: the G corrections subtract the three 'one index is the
    strict unique max' regions, not the complement of {i<=u, j<=u}.  If a
    future fix makes these equal this test should be revisited.
    """
    q, k, v, _ = make_qkv(rng, B=1, H=1, n=14, d=4, dv=3)
    o_ref = _wwtw_oracle(q, k, v)
    o_paper, _ = hla3_paper_serial(q, k, v, None)
    assert float(jnp.max(jnp.abs(o_paper - o_ref))) > 1e-3


def test_exact_decode_step(rng):
    q, k, v, gam = make_qkv(rng, n=10, d=5, dv=4)
    o_full, _ = hla3_exact_serial(q, k, v, gam, normalize=True)
    st = hla3_exact_init_state(q.shape[:-2], q.shape[-1], v.shape[-1], jnp.float64)
    outs = []
    for t in range(q.shape[-2]):
        st, o_t = hla3_exact_step(
            st, q[..., t, :], k[..., t, :], v[..., t, :], gam, normalize=True
        )
        outs.append(o_t)
    np.testing.assert_allclose(jnp.stack(outs, -2), o_full, **TOL)
