"""Stateful kernel API: chunk-parallel prefill == serial recurrence.

The serving engine's exactness contract (ISSUE acceptance / DESIGN.md §8):
a whole prompt prefilled through ONE chunk-parallel kernel call must land
on the same streaming state as token-by-token ``hla2_step`` / ``ahla_step``
decode (≤1e-4 in fp32), for ragged prompt lengths, with and without decay
and normalization — including resuming from a mid-stream carry.  Also
covers the fused batched decode-step kernels (interpret mode on CPU).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.ahla import ahla_init_state, ahla_step
from repro.core.hla2 import hla2_init_state, hla2_step
from repro.kernels import ops as kops

STATE_TOL = 1e-4


def _mk(rng, B, H, n, d, dv):
    q = jnp.asarray(rng.randn(B, H, n, d) * 0.5, jnp.float32)
    k = jnp.asarray(rng.randn(B, H, n, d) * 0.5, jnp.float32)
    v = jnp.asarray(rng.randn(B, H, n, dv) * 0.5, jnp.float32)
    g = jnp.asarray(rng.uniform(0.85, 0.99, (B, H)), jnp.float32)
    return q, k, v, g


def _serial_hla2(q, k, v, gamma, n, **kw):
    st = hla2_init_state(q.shape[:2], q.shape[-1], v.shape[-1])
    for t in range(n):
        st, _ = hla2_step(st, q[:, :, t], k[:, :, t], v[:, :, t], gamma, **kw)
    return st


def _serial_ahla(q, k, v, gamma, n, **kw):
    st = ahla_init_state(q.shape[:2], q.shape[-1], v.shape[-1])
    for t in range(n):
        st, _ = ahla_step(st, q[:, :, t], k[:, :, t], v[:, :, t], gamma, **kw)
    return st


@pytest.mark.parametrize("n", [13, 37, 64])
@pytest.mark.parametrize("use_gamma", [False, True])
@pytest.mark.parametrize("normalize", [False, True])
def test_hla2_prefill_state_matches_serial(rng, n, use_gamma, normalize):
    q, k, v, g = _mk(rng, 2, 2, n, 8, 8)
    gamma = g if use_gamma else None
    st_serial = _serial_hla2(q, k, v, gamma, n, normalize=normalize)
    _, st_kernel = kops.hla2_prefill(
        q, k, v, gamma, chunk=16, normalize=normalize, use_pallas=True
    )
    for ref, got, name in zip(st_serial, st_kernel, "SCmGh"):
        err = float(jnp.max(jnp.abs(ref.astype(jnp.float32) - got)))
        assert err <= STATE_TOL, f"{name}: {err}"


@pytest.mark.parametrize("n", [13, 37, 64])
@pytest.mark.parametrize("use_gamma", [False, True])
@pytest.mark.parametrize("normalize", [False, True])
def test_ahla_prefill_state_matches_serial(rng, n, use_gamma, normalize):
    q, k, v, g = _mk(rng, 2, 2, n, 8, 8)
    gamma = g if use_gamma else None
    st_serial = _serial_ahla(q, k, v, gamma, n, normalize=normalize)
    _, st_kernel = kops.ahla_prefill(
        q, k, v, gamma, chunk=16, normalize=normalize, use_pallas=True
    )
    for ref, got, name in zip(st_serial, st_kernel, ["R", "P", "m", "E", "n"]):
        err = float(jnp.max(jnp.abs(ref.astype(jnp.float32) - got)))
        assert err <= 2 * STATE_TOL, f"{name}: {err}"


def test_hla2_prefill_512_token_acceptance(rng):
    """Acceptance: a 512-token prompt prefills via one chunk-parallel call
    (no per-token Python loop) and matches serial hla2_step decode ≤1e-4."""
    n = 512
    q, k, v, g = _mk(rng, 1, 2, n, 8, 8)
    _, st_kernel = kops.hla2_prefill(q, k, v, g, chunk=128, use_pallas=True)
    st_serial = _serial_hla2(q, k, v, g, n)
    for ref, got, name in zip(st_serial, st_kernel, "SCmGh"):
        err = float(jnp.max(jnp.abs(ref.astype(jnp.float32) - got)))
        assert err <= STATE_TOL, f"{name}: {err}"


def test_hla2_prefill_resumes_from_carry(rng):
    """Split prompt: serial first half -> kernel second half == full serial."""
    q, k, v, g = _mk(rng, 2, 2, 37, 8, 8)
    cut = 20
    st_half = _serial_hla2(q[:, :, :cut], k[:, :, :cut], v[:, :, :cut], g, cut)
    _, st_resumed = kops.hla2_prefill(
        q[:, :, cut:], k[:, :, cut:], v[:, :, cut:], g, chunk=16,
        state=st_half, use_pallas=True,
    )
    st_full = _serial_hla2(q, k, v, g, 37)
    for ref, got, name in zip(st_full, st_resumed, "SCmGh"):
        err = float(jnp.max(jnp.abs(ref.astype(jnp.float32) - got)))
        assert err <= STATE_TOL, f"{name}: {err}"


def test_ahla_prefill_resumes_from_carry(rng):
    q, k, v, g = _mk(rng, 2, 2, 37, 8, 8)
    cut = 20
    st_half = _serial_ahla(q[:, :, :cut], k[:, :, :cut], v[:, :, :cut], g, cut)
    _, st_resumed = kops.ahla_prefill(
        q[:, :, cut:], k[:, :, cut:], v[:, :, cut:], g, chunk=16,
        state=st_half, use_pallas=True,
    )
    st_full = _serial_ahla(q, k, v, g, 37)
    for ref, got, name in zip(st_full, st_resumed, ["R", "P", "m", "E", "n"]):
        err = float(jnp.max(jnp.abs(ref.astype(jnp.float32) - got)))
        assert err <= 2 * STATE_TOL, f"{name}: {err}"


# --------------------------------------------------------------------------
# fused batched decode steps
# --------------------------------------------------------------------------


@pytest.mark.parametrize("use_gamma", [False, True])
def test_hla2_fused_decode_step_matches_jnp(rng, use_gamma):
    q, k, v, g = _mk(rng, 2, 3, 6, 8, 8)
    gamma = g.reshape(2, 3) if use_gamma else None
    st_ref = hla2_init_state((2, 3), 8, 8)
    st_ker = st_ref
    for t in range(6):
        args = (q[:, :, t], k[:, :, t], v[:, :, t], gamma)
        st_ref, o_ref = hla2_step(st_ref, *args, lam=0.1)
        st_ker, o_ker = kops.hla2_decode_step(st_ker, *args, lam=0.1)
        assert float(jnp.max(jnp.abs(o_ref - o_ker))) <= STATE_TOL
    for ref, got in zip(st_ref, st_ker):
        assert float(jnp.max(jnp.abs(ref - got))) <= STATE_TOL


@pytest.mark.parametrize("use_gamma", [False, True])
def test_ahla_fused_decode_step_matches_jnp(rng, use_gamma):
    q, k, v, g = _mk(rng, 2, 3, 6, 8, 8)
    gamma = g.reshape(2, 3) if use_gamma else None
    st_ref = ahla_init_state((2, 3), 8, 8)
    st_ker = st_ref
    for t in range(6):
        args = (q[:, :, t], k[:, :, t], v[:, :, t], gamma)
        st_ref, o_ref = ahla_step(st_ref, *args)
        st_ker, o_ker = kops.ahla_decode_step(st_ker, *args)
        assert float(jnp.max(jnp.abs(o_ref - o_ker))) <= STATE_TOL
    for ref, got in zip(st_ref, st_ker):
        assert float(jnp.max(jnp.abs(ref - got))) <= STATE_TOL


def test_decode_step_continues_prefill_state(rng):
    """prefill(prompt) then fused steps == serial steps over prompt+decode."""
    q, k, v, g = _mk(rng, 1, 2, 20, 8, 8)
    _, st = kops.hla2_prefill(
        q[:, :, :16], k[:, :, :16], v[:, :, :16], g, chunk=8, use_pallas=True
    )
    for t in range(16, 20):
        st, _ = kops.hla2_decode_step(
            st, q[:, :, t], k[:, :, t], v[:, :, t], g
        )
    st_serial = _serial_hla2(q, k, v, g, 20)
    for ref, got, name in zip(st_serial, st, "SCmGh"):
        err = float(jnp.max(jnp.abs(ref.astype(jnp.float32) - got)))
        assert err <= STATE_TOL, f"{name}: {err}"
