"""Substrate tests: optimizer, data pipeline, checkpointing, FT loop."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import (
    CheckpointManager,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.data.pipeline import DataConfig, SyntheticStream
from repro.optim import adamw


# ------------------------------- optimizer ---------------------------------


def _np_adamw_step(p, g, m, v, step, cfg):
    g = g.copy()
    norm = np.sqrt(sum(np.sum(x**2) for x in g.values()))
    scale = min(1.0, cfg.grad_clip / max(norm, 1e-9))
    g = {k: x * scale for k, x in g.items()}
    b1, b2 = cfg.betas
    lrs = np.asarray(adamw.cosine_lr(jnp.asarray(step), cfg))
    out_p, out_m, out_v = {}, {}, {}
    for k in p:
        out_m[k] = b1 * m[k] + (1 - b1) * g[k]
        out_v[k] = b2 * v[k] + (1 - b2) * g[k] ** 2
        mhat = out_m[k] / (1 - b1**step)
        vhat = out_v[k] / (1 - b2**step)
        delta = mhat / (np.sqrt(vhat) + cfg.eps)
        if p[k].ndim >= 2:
            delta = delta + cfg.weight_decay * p[k]
        out_p[k] = p[k] - lrs * delta
    return out_p, out_m, out_v


def test_adamw_matches_numpy_reference(rng):
    cfg = adamw.OptConfig(lr=1e-2, warmup_steps=0, total_steps=100)
    p_np = {"a": rng.randn(4, 3), "b": rng.randn(5)}
    params = jax.tree.map(jnp.asarray, p_np)
    state = adamw.init_opt_state(params, jnp.float64)
    m = {k: np.zeros_like(v) for k, v in p_np.items()}
    v = {k: np.zeros_like(x) for k, x in p_np.items()}
    for step in range(1, 4):
        g_np = {k: rng.randn(*x.shape) for k, x in p_np.items()}
        grads = jax.tree.map(jnp.asarray, g_np)
        params, state, metrics = adamw.adamw_update(params, grads, state, cfg)
        p_np, m, v = _np_adamw_step(p_np, g_np, m, v, step, cfg)
        for k in p_np:
            np.testing.assert_allclose(params[k], p_np[k], atol=1e-10)
    assert float(metrics["grad_norm"]) > 0


def test_lr_schedule():
    cfg = adamw.OptConfig(lr=1.0, warmup_steps=10, total_steps=110,
                          min_lr_ratio=0.1)
    assert float(adamw.cosine_lr(jnp.asarray(0), cfg)) == 0.0
    assert abs(float(adamw.cosine_lr(jnp.asarray(10), cfg)) - 1.0) < 1e-6
    assert abs(float(adamw.cosine_lr(jnp.asarray(110), cfg)) - 0.1) < 1e-6


def test_grad_clip():
    g = {"x": jnp.full((10,), 10.0)}
    clipped, norm = adamw.clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(
        float(adamw.global_norm(clipped)), 1.0, rtol=1e-6
    )


# ------------------------------- data --------------------------------------


def test_data_determinism_and_shard_disjointness():
    cfg = DataConfig(vocab=100, seq_len=16, global_batch=8, seed=7)
    s0 = SyntheticStream(cfg)
    b1, b2 = s0.batch(3), s0.batch(3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])  # deterministic
    assert not np.array_equal(s0.batch(3)["tokens"], s0.batch(4)["tokens"])
    # host shards see different slices
    h0 = SyntheticStream(cfg, host_index=0, host_count=2)
    h1 = SyntheticStream(cfg, host_index=1, host_count=2)
    assert h0.batch(0)["tokens"].shape[0] == 4
    assert not np.array_equal(h0.batch(0)["tokens"], h1.batch(0)["tokens"])


def test_recall_task_labels():
    cfg = DataConfig(vocab=64, seq_len=20, global_batch=4, kind="recall")
    b = SyntheticStream(cfg).batch(0)
    assert (b["labels"] >= 0).sum() == 4  # exactly one target per row


# ------------------------------- checkpoint --------------------------------


def test_checkpoint_roundtrip(tmp_path, rng):
    tree = {
        "params": {"w": jnp.asarray(rng.randn(4, 4)), "b": jnp.asarray(rng.randn(4))},
        "opt": adamw.init_opt_state({"w": jnp.zeros((4, 4))}),
    }
    save_checkpoint(str(tmp_path), 17, tree, {"note": "x"})
    assert latest_step(str(tmp_path)) == 17
    restored, manifest = restore_checkpoint(str(tmp_path), tree)
    assert manifest["step"] == 17
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomicity(tmp_path, rng):
    """A stale .tmp dir (simulated crash) is ignored and overwritten."""
    tree = {"w": jnp.asarray(rng.randn(3))}
    save_checkpoint(str(tmp_path), 1, tree)
    # simulate a crashed save at step 2
    os.makedirs(tmp_path / "step_00000002.tmp")
    assert latest_step(str(tmp_path)) == 1
    save_checkpoint(str(tmp_path), 2, tree)
    assert latest_step(str(tmp_path)) == 2


def test_manager_rotation_and_async(tmp_path, rng):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=True)
    tree = {"w": jnp.asarray(rng.randn(3))}
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    mgr.wait()
    from repro.checkpoint.manager import list_steps

    assert list_steps(str(tmp_path)) == [3, 4]


# ------------------------------- FT loop -----------------------------------


def test_ft_loop_failure_and_resume(tmp_path):
    """Inject a failure; restarting resumes from the checkpoint and
    reproduces the exact final state of an uninterrupted run."""
    from repro.runtime.faults import FaultPlan, FaultSpec, InjectedFault
    from repro.runtime.ft import FaultTolerantLoop

    def step_fn(params, opt_state, batch):
        new = {"w": params["w"] + batch["tokens"].sum()}
        return new, opt_state, {"loss": jnp.zeros(())}

    cfg = DataConfig(vocab=50, seq_len=4, global_batch=2, seed=3)
    stream = SyntheticStream(cfg)
    p0 = {"w": jnp.zeros((), jnp.int64)}

    # uninterrupted reference
    ref = p0
    for s in range(10):
        ref, _, _ = step_fn(ref, None, stream.batch(s))

    ck = str(tmp_path / "ck")
    loop = FaultTolerantLoop(
        step_fn, stream, ck, ckpt_every=3,
        faults=FaultPlan(FaultSpec("train.step", at=7)),
        log=lambda *_: None,
    )
    with pytest.raises(InjectedFault, match="train.step"):
        loop.run(p0, None, 10)
    # restart (fresh loop object, as a new process would)
    loop2 = FaultTolerantLoop(step_fn, stream, ck, ckpt_every=3,
                              log=lambda *_: None)
    params, _, last = loop2.run(p0, None, 10)
    assert last == 9
    assert int(params["w"]) == int(ref["w"])


def test_straggler_watchdog_logs():
    from repro.runtime.ft import StragglerWatchdog

    logs = []
    wd = StragglerWatchdog(factor=2.0, log=logs.append)
    wd.observe(0, 1.0)
    wd.observe(1, 1.1)
    wd.observe(2, 10.0)  # straggler
    assert any("straggler" in m for m in logs)


def test_compression_quantize_roundtrip(rng):
    from repro.distributed.compression import quantize_dequantize

    x = jnp.asarray(rng.randn(1000), jnp.float32)
    y = quantize_dequantize(x)
    # int8 EF quantization: bounded relative error vs max magnitude
    assert float(jnp.max(jnp.abs(x - y))) <= float(jnp.max(jnp.abs(x))) / 127 + 1e-6
