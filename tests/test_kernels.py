"""Per-kernel allclose tests: Pallas (interpret mode) vs pure-jnp oracles.

Sweeps shapes / dtypes / decay / normalization per the deliverable (c).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ahla_chunk import ahla_chunk_pallas
from repro.kernels.hla2_chunk import hla2_chunk_pallas
from repro.kernels import ref as kref
from repro.kernels.ops import ahla_attention, hla2_attention


def _mk(rng, BH, n, d, dv, dtype):
    q = jnp.asarray(rng.randn(BH, n, d) * 0.5, dtype)
    k = jnp.asarray(rng.randn(BH, n, d) * 0.5, dtype)
    v = jnp.asarray(rng.randn(BH, n, dv) * 0.5, dtype)
    g = jnp.asarray(rng.uniform(0.85, 0.99, (BH,)), jnp.float32)
    return q, k, v, g


SHAPES = [
    # (BH, n, d, dv, chunk)
    (2, 32, 8, 8, 8),
    (3, 64, 16, 8, 16),
    (1, 128, 32, 32, 32),
    (2, 64, 8, 24, 64),  # single chunk == whole sequence
]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("use_gamma", [False, True])
def test_hla2_kernel_matches_ref(rng, shape, dtype, use_gamma):
    BH, n, d, dv, chunk = shape
    q, k, v, g = _mk(rng, BH, n, d, dv, dtype)
    gamma = g if use_gamma else None
    o, st = hla2_chunk_pallas(q, k, v, gamma, chunk=chunk, interpret=True)
    o_ref, st_ref = kref.hla2_chunk_ref(
        q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
        gamma, chunk=chunk,
    )
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(o, np.float32), np.asarray(o_ref, np.float32),
        atol=tol, rtol=tol,
    )
    for got, want in zip(st, st_ref):
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), atol=tol, rtol=tol
        )


@pytest.mark.parametrize("normalize", [False, True])
@pytest.mark.parametrize("lam", [0.0, 0.2])
def test_hla2_kernel_normalize_ridge(rng, normalize, lam):
    q, k, v, g = _mk(rng, 2, 32, 8, 8, jnp.float32)
    o, _ = hla2_chunk_pallas(
        q, k, v, g, chunk=8, normalize=normalize, lam=lam, interpret=True
    )
    o_ref, _ = kref.hla2_chunk_ref(
        q, k, v, g, chunk=8, normalize=normalize, lam=lam
    )
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("use_gamma", [False, True])
def test_ahla_kernel_matches_ref(rng, shape, dtype, use_gamma):
    BH, n, d, dv, chunk = shape
    q, k, v, g = _mk(rng, BH, n, d, dv, dtype)
    gamma = g if use_gamma else None
    o, st = ahla_chunk_pallas(q, k, v, gamma, chunk=chunk, interpret=True)
    o_ref, st_ref = kref.ahla_chunk_ref(
        q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
        gamma, chunk=chunk,
    )
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(o, np.float32), np.asarray(o_ref, np.float32),
        atol=tol, rtol=tol,
    )
    # P, E states (m, n come packed in the same buffers)
    for got, want in zip(st, st_ref):
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), atol=tol, rtol=tol
        )


def test_ops_wrapper_grads(rng):
    """custom_vjp wrappers: value == kernel forward, grad == jnp reference."""
    B, H, n, d = 2, 2, 32, 8
    q = jnp.asarray(rng.randn(B, H, n, d) * 0.5, jnp.float32)
    k = jnp.asarray(rng.randn(B, H, n, d) * 0.5, jnp.float32)
    v = jnp.asarray(rng.randn(B, H, n, d) * 0.5, jnp.float32)
    g = jnp.asarray(rng.uniform(0.9, 0.99, (B, H)), jnp.float32)

    for fn in (hla2_attention, ahla_attention):
        o_pallas = fn(q, k, v, g, chunk=8, use_pallas=True)
        o_ref = fn(q, k, v, g, chunk=8, use_pallas=False)
        np.testing.assert_allclose(
            np.asarray(o_pallas), np.asarray(o_ref), atol=1e-4, rtol=1e-4
        )

        def loss(args, f=fn, pallas=True):
            return jnp.sum(f(*args, g, chunk=8, use_pallas=pallas) ** 2)

        g_pallas = jax.grad(loss)((q, k, v))
        g_ref = jax.grad(lambda a: loss(a, pallas=False))((q, k, v))
        for x, y in zip(g_pallas, g_ref):
            np.testing.assert_allclose(
                np.asarray(x), np.asarray(y), atol=1e-3, rtol=1e-3
            )


def test_kernel_under_jit_and_vmap(rng):
    q, k, v, g = _mk(rng, 4, 32, 8, 8, jnp.float32)
    f = jax.jit(
        lambda a, b, c: hla2_chunk_pallas(a, b, c, None, chunk=8, interpret=True)[0]
    )
    o = f(q, k, v)
    o_ref, _ = kref.hla2_chunk_ref(q, k, v, None, chunk=8)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), atol=1e-4, rtol=1e-4)
