"""§Perf hillclimb driver: run named dry-run variants of the three chosen
cells and emit a before/after table of roofline terms.

    PYTHONPATH=src python -m benchmarks.perf_iter [--cell qwen2_train] \
        [--out benchmarks/results/perf]

Cells (per the assignment: worst fraction / most collective-bound / most
paper-representative):
  1. qwen2_train   — qwen2-72b x train_4k, single pod
  2. jamba_train   — jamba-1.5-large-398b x train_4k, single pod
  3. hla_long      — qwen2-72b + hla2 x long_500k decode, single pod
  plus paper_vs_opt — paper-faithful token-scan vs chunkwise HLA on the
  qwen2+hla2 train cell (the reproduce-then-beyond comparison).
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

RESULTS = os.path.join(os.path.dirname(__file__), "results", "perf")

# name -> (arch, shape, extra dryrun args)
EXPERIMENTS = {
    "qwen2_train": [
        ("base_mb8", "qwen2-72b", "train_4k", ["--microbatches", "8"]),
        ("A_bf16gather_mb8", "qwen2-72b", "train_4k",
         ["--microbatches", "8", "--gather-dtype", "bfloat16"]),
        ("B_bf16gather_mb4", "qwen2-72b", "train_4k",
         ["--microbatches", "4", "--gather-dtype", "bfloat16"]),
        ("C_bf16gather_mb2", "qwen2-72b", "train_4k",
         ["--microbatches", "2", "--gather-dtype", "bfloat16"]),
    ],
    "jamba_train": [
        ("base_mb16", "jamba-1.5-large-398b", "train_4k",
         ["--microbatches", "16"]),
        ("A_bf16gather_mb16", "jamba-1.5-large-398b", "train_4k",
         ["--microbatches", "16", "--gather-dtype", "bfloat16"]),
        ("B_bf16gather_mb8", "jamba-1.5-large-398b", "train_4k",
         ["--microbatches", "8", "--gather-dtype", "bfloat16"]),
    ],
    "hla_long": [
        ("base", "qwen2-72b", "long_500k", []),
        ("A_bf16gather", "qwen2-72b", "long_500k",
         ["--gather-dtype", "bfloat16"]),
        ("B_chunk64", "qwen2-72b", "long_500k",
         ["--gather-dtype", "bfloat16", "--hla-chunk", "64"]),
    ],
    "paper_vs_opt": [
        ("paper_scan", "qwen2-72b", "train_4k",
         ["--mixer", "hla2", "--hla-impl", "scan", "--microbatches", "8"]),
        ("opt_chunk128", "qwen2-72b", "train_4k",
         ["--mixer", "hla2", "--hla-impl", "chunkwise", "--microbatches", "8"]),
        ("opt_chunk256", "qwen2-72b", "train_4k",
         ["--mixer", "hla2", "--hla-impl", "chunkwise", "--hla-chunk", "256",
          "--microbatches", "8"]),
        ("opt_chunk64", "qwen2-72b", "train_4k",
         ["--mixer", "hla2", "--hla-impl", "chunkwise", "--hla-chunk", "64",
          "--microbatches", "8"]),
    ],
}


def run_variant(name, arch, shape, extra, timeout=2400):
    os.makedirs(RESULTS, exist_ok=True)
    out = os.path.join(RESULTS, f"{name}.json")
    if os.path.exists(out):
        with open(out) as f:
            return json.load(f)
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--json", out, *extra]
    env = dict(os.environ)
    env["PYTHONPATH"] = env.get("PYTHONPATH", "src")
    t0 = time.time()
    proc = subprocess.run(
        cmd, capture_output=True, text=True, timeout=timeout, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    if proc.returncode != 0:
        return {"name": name, "ok": False, "err": proc.stderr[-1500:]}
    with open(out) as f:
        res = json.load(f)
    res["name"] = name
    res["ok"] = True
    res["wall_s"] = round(time.time() - t0, 1)
    with open(out, "w") as f:
        json.dump(res, f, indent=2)
    return res


def fmt(res):
    if not res.get("ok"):
        return f"| {res['name']} | FAILED | | | | |"
    r = res["roofline"]
    return (
        f"| {res['name']} | {r['compute_s']:.2f} | {r['memory_s']:.2f} | "
        f"{r['collective_s']:.2f} | {r['bottleneck'].replace('_s','')} | "
        f"{res['memory']['peak_bytes']/2**30:.2f} |"
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default=None, choices=list(EXPERIMENTS) + [None])
    args = ap.parse_args()
    cells = [args.cell] if args.cell else list(EXPERIMENTS)
    for cell in cells:
        print(f"\n#### {cell}")
        print("| variant | compute (s) | memory (s) | collective (s) | "
              "bottleneck | peak GiB |")
        print("|---|---|---|---|---|---|")
        for name, arch, shape, extra in EXPERIMENTS[cell]:
            res = run_variant(f"{cell}__{name}", arch, shape, extra)
            print(fmt({**res, "name": name}), flush=True)


if __name__ == "__main__":
    main()
