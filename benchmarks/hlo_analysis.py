"""Thin re-export: canonical implementation in repro.analysis.hlo_analysis."""

from repro.analysis.hlo_analysis import analyze, parse_hlo  # noqa: F401

if __name__ == "__main__":
    import json
    import sys

    with open(sys.argv[1]) as f:
        print(json.dumps(analyze(f.read()), indent=2))
