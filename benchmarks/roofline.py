"""Roofline matrix driver: baseline dry-run for every (arch x shape x mesh).

Runs each cell in a fresh subprocess (device count is locked at jax init)
via ``repro.launch.dryrun``, auto-escalating train-cell microbatches until
the per-device peak fits the HBM budget.  Results land in
``benchmarks/results/<arch>__<shape>__<mesh>.json`` and are summarized into
the §Dry-run / §Roofline tables by ``benchmarks/report.py``.

    PYTHONPATH=src python -m benchmarks.roofline [--only arch:shape]
        [--mesh single|multi|both] [--budget-gib 15.0]
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

ARCHS = [
    "jamba-1.5-large-398b",
    "codeqwen1.5-7b",
    "qwen2-72b",
    "nemotron-4-15b",
    "deepseek-67b",
    "whisper-small",
    "granite-moe-3b-a800m",
    "qwen3-moe-30b-a3b",
    "rwkv6-7b",
    "internvl2-2b",
]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
RESULTS = os.path.join(os.path.dirname(__file__), "results")
MB_LADDER = [1, 4, 8, 16, 32]


def run_cell(arch, shape, multi_pod, *, microbatches=1, timeout=3600,
             extra=()):
    tag = f"{arch}__{shape}__{'multi' if multi_pod else 'single'}"
    out = os.path.join(RESULTS, tag + ".json")
    cmd = [
        sys.executable, "-m", "repro.launch.dryrun",
        "--arch", arch, "--shape", shape, "--json", out,
        "--microbatches", str(microbatches), *extra,
    ]
    if multi_pod:
        cmd.append("--multi-pod")
    env = dict(os.environ)
    env["PYTHONPATH"] = env.get("PYTHONPATH", "src")
    t0 = time.time()
    proc = subprocess.run(
        cmd, capture_output=True, text=True, timeout=timeout, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    if proc.returncode != 0:
        return {"tag": tag, "ok": False, "err": proc.stderr[-2000:],
                "wall_s": time.time() - t0}
    with open(out) as f:
        res = json.load(f)
    res["ok"] = True
    res["tag"] = tag
    res["wall_s"] = round(time.time() - t0, 1)
    res["microbatches"] = microbatches
    with open(out, "w") as f:
        json.dump(res, f, indent=2)
    return res


def run_matrix(cells, budget_gib, log):
    done = []
    for arch, shape, multi in cells:
        tag = f"{arch}__{shape}__{'multi' if multi else 'single'}"
        path = os.path.join(RESULTS, tag + ".json")
        if os.path.exists(path):
            with open(path) as f:
                prev = json.load(f)
            if prev.get("ok") and prev["memory"]["peak_bytes"] / 2**30 <= budget_gib:
                log(f"[skip cached] {tag}")
                done.append(prev)
                continue
        res = best = None
        prev_peak = None
        ladder = MB_LADDER if shape.startswith("train") else [1]
        for mb in ladder:
            res = run_cell(arch, shape, multi, microbatches=mb)
            if not res["ok"]:
                log(f"[FAIL] {tag} mb={mb}: {res['err'][-400:]}")
                break
            peak = res["memory"]["peak_bytes"] / 2**30
            log(
                f"[ok] {tag} mb={mb}: peak {peak:.2f} GiB, "
                f"compile {res['compile_s']}s, "
                f"bottleneck {res['roofline']['bottleneck']}"
            )
            if best is None or peak < best["memory"]["peak_bytes"] / 2**30:
                best = res
            if peak <= budget_gib:
                break
            if prev_peak is not None and peak >= prev_peak:
                break  # escalation stopped helping
            prev_peak = peak
        if best is not None:
            path = os.path.join(RESULTS, tag + ".json")
            with open(path, "w") as f:
                json.dump(best, f, indent=2)
        done.append(best if best is not None else res)
    return done


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="arch:shape filter")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--budget-gib", type=float, default=15.0)
    args = ap.parse_args()

    os.makedirs(RESULTS, exist_ok=True)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    cells = []
    for arch in ARCHS:
        for shape in SHAPES:
            if args.only:
                a, s = args.only.split(":")
                if arch != a or shape != s:
                    continue
            for m in meshes:
                cells.append((arch, shape, m))

    def log(msg):
        print(f"{time.strftime('%H:%M:%S')} {msg}", flush=True)

    t0 = time.time()
    results = run_matrix(cells, args.budget_gib, log)
    ok = sum(1 for r in results if r and r.get("ok"))
    log(f"matrix done: {ok}/{len(results)} cells OK in {(time.time()-t0)/60:.1f} min")


if __name__ == "__main__":
    main()
