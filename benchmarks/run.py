"""Benchmark harness — one entry per paper table/figure + system benches.

Prints ``name,us_per_call,iqr_us,derived`` CSV rows.  The paper is
algorithmic (no empirical tables); its claims map to:

* Fig. 1/2 + Thms 3.1/4.1/6.1/7.1/7.2 — `equivalence` (views agree, and
  timing of each view);
* §5 complexity (linear time, O(1) state)  — `complexity` (us/token vs n),
  `statesize` (state bytes vs n, constant);
* §4 chunk-parallel training — `chunkwidth` (throughput vs w), and
  `train_step` (fwd+bwd us/step: fused Pallas VJP with chunk-state
  checkpointing vs recompute-in-backward vs jnp reference);
* serving (continuous batching over the paper's O(1)-state decode) —
  `serving` (TTFT + steady-state decode tok/s from the state-pool engine);
* the multi-pod roofline table is produced by `benchmarks.roofline`
  (separate long-running driver) and summarized by `benchmarks.report`.

Measurement discipline (DESIGN.md §15): every timing is **adaptive** —
samples accumulate until a minimum measured wall time — and reported as
median + IQR, so run-to-run comparisons have a noise scale attached.
Every bench persists ``results/<name>.json`` through ONE shared writer
(`write_results`) stamping the schema version and env fingerprint, and
throughput/latency rows append to the ``repro.obs.bench/v1`` history
(``--history``) consumed by ``python -m repro.obs.perfcheck`` — the CI
regression gate.  ``bench_ops`` additionally computes achieved-vs-
roofline utilization per registered SequenceOp from the analytic cost
model (``repro.obs.costs``), rendered as §Utilization by
``benchmarks.report``.
"""

from __future__ import annotations

import json
import os
import time
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")
RESULTS_SCHEMA = "repro.bench.results/v1"

# adaptive-timing knobs: sample until this much measured time (or the
# iteration cap, whichever first) — overridable for CI smoke runs
MIN_MEASURE_S = float(os.environ.get("BENCH_MIN_MEASURE_S", "0.2"))
MAX_TIME_ITERS = int(os.environ.get("BENCH_MAX_ITERS", "64"))
MIN_TIME_ITERS = 3


class Timing(NamedTuple):
    us: float      # median us per call
    iqr_us: float  # inter-quartile range of the per-call samples, us
    iters: int     # samples actually taken


def _stats(samples) -> Timing:
    q25, q75 = np.percentile(samples, [25, 75])
    return Timing(float(np.median(samples) * 1e6),
                  float((q75 - q25) * 1e6), len(samples))


def _timeit(fn, *args, warmup=2, min_time_s=None) -> Timing:
    """Adaptive timing: block-until-ready per call, accumulate samples
    until ``min_time_s`` of measured time (>= MIN_TIME_ITERS, <=
    MAX_TIME_ITERS samples), report median + IQR."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    budget = MIN_MEASURE_S if min_time_s is None else min_time_s
    samples, total = [], 0.0
    while len(samples) < MIN_TIME_ITERS or (
        total < budget and len(samples) < MAX_TIME_ITERS
    ):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        dt = time.perf_counter() - t0
        samples.append(dt)
        total += dt
    return _stats(samples)


def write_results(name: str, payload: dict) -> str:
    """THE results persistence path: every bench table lands in
    ``results/<name>.json`` with the schema version and env fingerprint
    stamped, so any two artifacts are comparable (and
    ``benchmarks.report`` / ad-hoc tooling parse one format)."""
    from repro.obs.perf import env_fingerprint

    os.makedirs(RESULTS_DIR, exist_ok=True)
    doc = {"schema": RESULTS_SCHEMA, "bench": name,
           "env": env_fingerprint()}
    doc.update(payload)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
    return path


class RowSink(list):
    """The ``rows`` list benches append ``(name, us, iqr_us, derived)``
    to, plus a side-channel for named throughput/latency metrics bound
    for the bench history (tok/s rows carry direction="higher" there —
    the regression gate must know which way is good)."""

    def __init__(self):
        super().__init__()
        self.metrics = []

    def metric(self, name, value, *, unit, direction, dispersion=0.0):
        self.metrics.append({
            "name": name, "value": float(value), "unit": unit,
            "direction": direction, "dispersion": float(dispersion),
        })


def _metric(rows, name, value, **kw):
    """Record a history metric if ``rows`` is a RowSink (no-op for the
    plain lists tests may pass)."""
    m = getattr(rows, "metric", None)
    if m is not None:
        m(name, value, **kw)


def _tps_disp(tok_per_s, t: Timing) -> float:
    """Propagate a timing IQR into tok/s units (first-order)."""
    return tok_per_s * t.iqr_us / max(t.us, 1e-9)


def _mk(rng, B, H, n, d):
    q = jnp.asarray(rng.randn(B, H, n, d) * 0.5, jnp.float32)
    k = jnp.asarray(rng.randn(B, H, n, d) * 0.5, jnp.float32)
    v = jnp.asarray(rng.randn(B, H, n, d) * 0.5, jnp.float32)
    g = jnp.asarray(rng.uniform(0.9, 0.99, (B, H)), jnp.float32)
    return q, k, v, g


def bench_equivalence(rows):
    from repro.core.hla2 import (
        hla2_chunkwise,
        hla2_naive,
        hla2_scan,
        hla2_serial,
    )

    rng = np.random.RandomState(0)
    q, k, v, g = _mk(rng, 2, 2, 256, 32)
    o_ref = hla2_naive(q, k, v, g)
    impls = {
        "hla2_serial": jax.jit(lambda *a: hla2_serial(*a)[0]),
        "hla2_scan": jax.jit(lambda *a: hla2_scan(*a)[0]),
        "hla2_chunkwise": jax.jit(lambda *a: hla2_chunkwise(*a, chunk=64)[0]),
    }
    entries = {}
    for name, fn in impls.items():
        err = float(jnp.max(jnp.abs(fn(q, k, v, g) - o_ref)))
        t = _timeit(fn, q, k, v, g)
        rows.append((f"equivalence/{name}", t.us, t.iqr_us,
                     f"max_err={err:.2e}"))
        entries[name] = {"us": round(t.us, 1), "iqr_us": round(t.iqr_us, 1),
                         "iters": t.iters, "max_err": err}
    write_results("equivalence", {
        "shape": {"B": 2, "H": 2, "n": 256, "d": 32, "chunk": 64},
        "entries": entries,
    })


def bench_complexity(rows):
    """us/token vs n: HLA2 chunkwise is linear; the naive path quadratic."""
    from repro.core.hla2 import hla2_chunkwise, hla2_naive

    rng = np.random.RandomState(1)
    chunked = jax.jit(lambda a, b, c: hla2_chunkwise(a, b, c, chunk=64)[0])
    naive = jax.jit(lambda a, b, c: hla2_naive(a, b, c))
    per_tok = {}
    entries = {}
    for n in (256, 512, 1024, 2048):
        q, k, v, _ = _mk(rng, 1, 2, n, 32)
        t = _timeit(chunked, q, k, v)
        per_tok[n] = t.us / n
        rows.append((f"complexity/hla2_chunk_n{n}", t.us, t.iqr_us,
                     f"us_per_tok={t.us/n:.2f}"))
        entries[f"hla2_chunk_n{n}"] = {
            "us": round(t.us, 1), "iqr_us": round(t.iqr_us, 1),
            "us_per_tok": round(t.us / n, 3),
        }
    for n in (256, 512, 1024):
        q, k, v, _ = _mk(rng, 1, 2, n, 32)
        t = _timeit(naive, q, k, v)
        rows.append((f"complexity/naive_n{n}", t.us, t.iqr_us,
                     f"us_per_tok={t.us/n:.2f}"))
        entries[f"naive_n{n}"] = {
            "us": round(t.us, 1), "iqr_us": round(t.iqr_us, 1),
            "us_per_tok": round(t.us / n, 3),
        }
    growth = per_tok[2048] / per_tok[256]
    rows.append((
        "complexity/linear_check", 0.0, 0.0,
        f"us_per_tok growth 256->2048 = {growth:.2f}x (1.0 = perfectly linear)",
    ))
    write_results("complexity", {
        "shape": {"B": 1, "H": 2, "d": 32, "chunk": 64},
        "growth_256_to_2048": round(growth, 3),
        "entries": entries,
    })


def bench_statesize(rows):
    """Decode state bytes: constant in context length (vs a KV cache)."""
    from repro.configs import get_config
    from repro.models import lm

    cfg = get_config("hla-1b", reduced=True)
    entries = {}
    for n_ctx in (1024, 8192, 65536):
        states = jax.eval_shape(lambda: lm.lm_init_states(cfg, 1, n_ctx))
        hla_bytes = sum(
            int(np.prod(x.shape)) * x.dtype.itemsize
            for x in jax.tree.leaves(states)
        )
        cfg_sm = cfg.replace(mixer="softmax")
        states_sm = jax.eval_shape(
            lambda: lm.lm_init_states(cfg_sm, 1, n_ctx)
        )
        kv_bytes = sum(
            int(np.prod(x.shape)) * x.dtype.itemsize
            for x in jax.tree.leaves(states_sm)
        )
        rows.append((
            f"statesize/ctx{n_ctx}", 0.0, 0.0,
            f"hla_state={hla_bytes/2**20:.2f}MiB kv_cache={kv_bytes/2**20:.2f}MiB",
        ))
        entries[f"ctx{n_ctx}"] = {"hla_state_bytes": hla_bytes,
                                  "kv_cache_bytes": kv_bytes}
    write_results("statesize", {
        "shape": {"arch": "hla-1b-reduced", "B": 1},
        "entries": entries,
    })


def bench_chunkwidth(rows):
    from repro.core.hla2 import hla2_chunkwise

    rng = np.random.RandomState(2)
    q, k, v, g = _mk(rng, 2, 4, 2048, 64)
    entries = {}
    for w in (16, 32, 64, 128, 256):
        fn = jax.jit(
            lambda a, b, c, gg, w=w: hla2_chunkwise(a, b, c, gg, chunk=w)[0]
        )
        t = _timeit(fn, q, k, v, g)
        tok_s = 2048 * 2 / t.us * 1e6
        rows.append((f"chunkwidth/w{w}", t.us, t.iqr_us,
                     f"tok_per_s={tok_s:.0f}"))
        entries[f"w{w}"] = {"us": round(t.us, 1),
                            "iqr_us": round(t.iqr_us, 1),
                            "tok_per_s": round(tok_s)}
    write_results("chunkwidth", {
        "shape": {"B": 2, "H": 4, "n": 2048, "d": 64},
        "entries": entries,
    })


def bench_kernels(rows):
    """Pallas kernel (interpret) correctness + jnp reference timing."""
    from repro.kernels import ref as kref
    from repro.kernels.hla2_chunk import hla2_chunk_pallas

    rng = np.random.RandomState(3)
    q = jnp.asarray(rng.randn(4, 256, 64) * 0.5, jnp.float32)
    k = jnp.asarray(rng.randn(4, 256, 64) * 0.5, jnp.float32)
    v = jnp.asarray(rng.randn(4, 256, 64) * 0.5, jnp.float32)
    o_p, _ = hla2_chunk_pallas(q, k, v, None, chunk=64, interpret=True)
    o_r, _ = kref.hla2_chunk_ref(q, k, v, None, chunk=64)
    err = float(jnp.max(jnp.abs(o_p - o_r)))
    fn = jax.jit(lambda a, b, c: kref.hla2_chunk_ref(a, b, c, None, chunk=64)[0])
    t = _timeit(fn, q, k, v)
    rows.append(("kernels/hla2_chunk_ref", t.us, t.iqr_us,
                 f"pallas_interpret_err={err:.2e}"))
    write_results("kernels", {
        "shape": {"BH": 4, "n": 256, "d": 64, "chunk": 64},
        "entries": {"hla2_chunk_ref": {
            "us": round(t.us, 1), "iqr_us": round(t.iqr_us, 1),
            "pallas_interpret_err": err,
        }},
    })


def bench_train_step(rows):
    """Training-step (fwd+bwd) timing: fused Pallas VJP vs reference paths.

    ``*_fused`` runs the chunkwise Pallas forward with chunk-state
    checkpointing and the fused reverse-chunk-walk backward;
    ``*_recompute`` is the legacy design (fused forward, jnp recompute
    under ``jax.vjp`` in the backward); ``*_ref`` is the pure-jnp chunkwise
    path end to end.  On CPU the kernels execute in interpret mode (Python
    body per grid step), so the XLA-compiled ``*_ref`` row is the relevant
    CPU number — on TPU the same entries time the native kernels.
    """
    from repro.kernels.ops import ahla_attention, hla2_attention

    rng = np.random.RandomState(4)
    B, H, n, d = 1, 2, 512, 32
    q, k, v, g = _mk(rng, B, H, n, d)

    def make_loss(fn, **kw):
        def loss(a, b, c, gg):
            return jnp.sum(fn(a, b, c, gg, chunk=64, **kw) ** 2)

        return loss

    entries = {
        "hla2_fused": make_loss(hla2_attention, use_pallas=True,
                                fused_bwd=True),
        "hla2_recompute": make_loss(hla2_attention, use_pallas=True,
                                    fused_bwd=False),
        "hla2_ref": make_loss(hla2_attention, use_pallas=False),
        "ahla_fused": make_loss(ahla_attention, use_pallas=True,
                                fused_bwd=True),
        "ahla_ref": make_loss(ahla_attention, use_pallas=False),
    }
    backend = jax.default_backend()
    results = {}
    for name, loss in entries.items():
        step = jax.jit(jax.grad(loss, argnums=(0, 1, 2, 3)))
        t = _timeit(step, q, k, v, g, warmup=1)
        tok_s = B * n / t.us * 1e6  # tokens (not head-tokens) per second
        rows.append((
            f"train_step/{name}", t.us, t.iqr_us,
            f"tok_per_s={tok_s:.0f} backend={backend}",
        ))
        _metric(rows, f"train_step/{name}/tok_per_s", tok_s,
                unit="tok/s", direction="higher",
                dispersion=_tps_disp(tok_s, t))
        results[name] = {"us_per_step": round(t.us, 1),
                         "iqr_us": round(t.iqr_us, 1),
                         "iters": t.iters,
                         "tok_per_s": round(tok_s)}
    write_results("train_step", {
        "backend": backend,
        "shape": {"B": B, "H": H, "n": n, "d": d, "chunk": 64},
        "entries": results,
    })


def bench_decode_throughput(rows):
    """Streaming decode (view A): us/token for the reduced paper model."""
    from repro.configs import get_config
    from repro.models import lm
    from repro.models.param import init_params

    cfg = get_config("hla-1b", reduced=True)
    params = init_params(lm.lm_specs(cfg), jax.random.key(0))
    B = 4
    states = lm.lm_init_states(cfg, B, 64)
    tok = jnp.ones((B, 1), jnp.int32)
    pos = jnp.zeros((B, 1), jnp.int32)

    @jax.jit
    def step(params, tok, states, pos):
        lg, st, _ = lm.lm_apply(
            params, tok, cfg, states=states, positions=pos, mode="decode"
        )
        return lg, st

    lg, states = step(params, tok, states, pos)  # compile
    # sequential recurrence: sample per-step times in place (the state
    # advances every call, so the generic _timeit can't replay args)
    samples, total, i = [], 0.0, 0
    while len(samples) < MIN_TIME_ITERS or (
        total < MIN_MEASURE_S and len(samples) < MAX_TIME_ITERS
    ):
        t0 = time.perf_counter()
        lg, states = step(params, tok, states, pos + i)
        jax.block_until_ready(lg)
        samples.append(time.perf_counter() - t0)
        total += samples[-1]
        i += 1
    t = _stats(samples)
    tok_s = B / t.us * 1e6
    rows.append(("decode/hla2_reduced", t.us, t.iqr_us,
                 f"tok_per_s={tok_s:.0f}"))
    _metric(rows, "decode/hla2_reduced/tok_per_s", tok_s,
            unit="tok/s", direction="higher",
            dispersion=_tps_disp(tok_s, t))
    write_results("decode", {
        "backend": jax.default_backend(),
        "shape": {"B": B, "arch": "hla-1b-reduced"},
        "entries": {"hla2_reduced": {
            "us_per_step": round(t.us, 1), "iqr_us": round(t.iqr_us, 1),
            "iters": t.iters, "tok_per_s": round(tok_s),
        }},
    })


def bench_serving(rows):
    """Multi-tenant serving trace: heavy-tailed shared prefixes (Zipf),
    Poisson arrivals, mixed priorities — sustained req/s, TTFT
    cold-vs-hit, cache hit rate (DESIGN.md §16).

    The trace draws each request's prompt as ``shared prefix + unique
    suffix`` where the prefix is picked from a small pool with a Zipf
    popularity law (a few prefixes carry most of the traffic, as system
    prompts do), arrivals follow a Poisson process (exponential
    inter-arrival gaps), and priority classes / tenants are mixed.  The
    engine runs with the content-addressed ``PrefixCache``: the first
    request on each prefix is a cold prefill that inserts the chunk-
    aligned state snapshot, every later one resumes from it and prefills
    only its suffix — TTFT splits into the cold and hit histograms.
    """
    import collections

    from repro.configs import get_config
    from repro.models import lm
    from repro.models.param import init_params
    from repro.serving import Engine, GenRequest, PrefixCache

    cfg = get_config("hla-1b", reduced=True)
    params = init_params(lm.lm_specs(cfg), jax.random.key(0))
    slots, gen_len, block, gran = 4, 16, 8, 16
    prefix_len, n_reqs, n_prefixes = 2 * gran, 24, 4
    suffix_lens = (4, 8, 12)  # few distinct lengths -> few jit signatures
    max_len = prefix_len + max(suffix_lens) + gen_len + 8
    engine = Engine(
        cfg, params, slots=slots, max_len=max_len, block=block,
        cache=PrefixCache(granularity=gran, budget_bytes=1 << 30,
                          namespace=cfg.name),
    )
    rng = np.random.RandomState(5)
    prefixes = [rng.randint(2, cfg.vocab, prefix_len)
                for _ in range(n_prefixes)]
    # Zipf popularity over the prefix pool (p ~ 1/rank^1.2)
    pop = 1.0 / np.arange(1, n_prefixes + 1) ** 1.2
    pop /= pop.sum()

    def make_req(rid, prefix, suffix_len, *, priority=1, tenant="default"):
        prompt = np.concatenate(
            [prefix, rng.randint(2, cfg.vocab, suffix_len)])
        return GenRequest(rid=rid, prompt=prompt, max_new=gen_len,
                          priority=priority, tenant=tenant)

    # -- warmup: trace every jit signature the measured trace will hit
    # (cold full-prompt prefill and cached suffix-resume prefill, per
    # distinct suffix length) against a throwaway prefix, then zero the
    # obs epoch and drop the warmup cache entries
    warm_prefix = rng.randint(2, cfg.vocab, prefix_len)
    wid = -1
    for s in suffix_lens:
        for _ in range(2):  # first = cold + insert, second = hit + resume
            engine.run([make_req(wid, warm_prefix, s)])
            wid -= 1
    engine.cache.clear()
    engine.obs.reset()

    # -- build the trace: Zipf prefix choice, Poisson arrivals, mixed
    # priority classes and tenants
    gaps = rng.exponential(scale=0.003, size=n_reqs)  # ~3 ms mean gap
    arrivals = np.cumsum(gaps)
    trace = []
    for i in range(n_reqs):
        req = make_req(
            i, prefixes[rng.choice(n_prefixes, p=pop)],
            int(rng.choice(suffix_lens)),
            priority=int(rng.choice([0, 1, 2], p=[0.1, 0.6, 0.3])),
            tenant=str(rng.choice(["acme", "beta", "solo"])),
        )
        trace.append((float(arrivals[i]), req))

    # -- drive: submit each request at its arrival time against the
    # wall clock, tick the engine whenever work is pending
    pending = collections.deque(trace)
    t0 = time.perf_counter()
    while pending or len(engine.scheduler) or engine.active.any():
        now = time.perf_counter() - t0
        while pending and pending[0][0] <= now:
            engine.submit(pending.popleft()[1])
        if len(engine.scheduler) or engine.active.any():
            engine._drive_tick()
        elif pending:
            time.sleep(max(0.0, pending[0][0] - (time.perf_counter() - t0)))
    wall_s = time.perf_counter() - t0

    results = [engine.results[i] for i in range(n_reqs)]
    assert all(r.status == "ok" for r in results), \
        [(r.rid, r.status) for r in results if r.status != "ok"]
    reg = engine.obs.registry
    hits = reg.get("cache_hits_total").total()
    misses = reg.get("cache_misses_total").total()
    hit_rate = hits / max(hits + misses, 1)

    def _q(hist_name, q):
        h = reg.get(hist_name)
        return 1e3 * (h.quantile(q) or 0.0)

    cold_p50, cold_p99 = _q("serving_ttft_cold_seconds", 0.5), \
        _q("serving_ttft_cold_seconds", 0.99)
    hit_p50, hit_p99 = _q("serving_ttft_hit_seconds", 0.5), \
        _q("serving_ttft_hit_seconds", 0.99)
    req_per_s = n_reqs / max(wall_s, 1e-9)
    st = engine.stats
    decode_toks = sum(len(r.tokens) - 1 for r in results)
    tok_s = decode_toks / max(st["decode_s"], 1e-9)
    backend = jax.default_backend()
    rows.append((
        "serving/trace", wall_s * 1e6, 0.0,
        f"req_per_s={req_per_s:.1f} hit_rate={hit_rate:.2f} "
        f"ttft_ms cold_p50={cold_p50:.1f}/p99={cold_p99:.1f} "
        f"hit_p50={hit_p50:.1f}/p99={hit_p99:.1f} backend={backend}",
    ))
    rows.append((
        "serving/decode", 0.0, 0.0,
        f"tok_per_s={tok_s:.1f} slots={slots} block={block}",
    ))
    _metric(rows, "serving/req_per_s", req_per_s, unit="req/s",
            direction="higher")
    _metric(rows, "serving/ttft_cold_ms_p50", cold_p50, unit="ms",
            direction="lower", dispersion=max(cold_p99 - cold_p50, 0.0))
    _metric(rows, "serving/ttft_hit_ms_p50", hit_p50, unit="ms",
            direction="lower", dispersion=max(hit_p99 - hit_p50, 0.0))
    _metric(rows, "serving/cache_hit_rate", hit_rate, unit="ratio",
            direction="higher")
    _metric(rows, "serving/decode_tok_per_s", tok_s, unit="tok/s",
            direction="higher")
    write_results("serving", {
        "backend": backend,
        "shape": {"slots": slots, "prefix_len": prefix_len,
                  "suffix_lens": list(suffix_lens), "gen_len": gen_len,
                  "block": block, "granularity": gran,
                  "requests": n_reqs, "prefixes": n_prefixes},
        "req_per_s": round(req_per_s, 2),
        "wall_s": round(wall_s, 4),
        "ttft_cold_ms_p50": round(cold_p50, 2),
        "ttft_cold_ms_p99": round(cold_p99, 2),
        "ttft_hit_ms_p50": round(hit_p50, 2),
        "ttft_hit_ms_p99": round(hit_p99, 2),
        "cache_hit_rate": round(hit_rate, 4),
        "cache_hits": int(hits),
        "cache_misses": int(misses),
        "decode_tok_per_s": round(tok_s, 1),
        "prefill_tok_per_s": round(
            st["prompt_tokens"] / max(st["prefill_s"], 1e-9), 1
        ),
        # the same snapshot schema the serve CLI's --metrics-out dumps,
        # scoped to the bench's engine (report.py and ad-hoc tooling
        # can consume either artifact identically)
        "metrics": engine.obs.snapshot(),
    })


def bench_ops(rows):
    """Per-operator train-forward and decode throughput + roofline
    utilization over EVERY registered ``SequenceOp`` (DESIGN.md §11/§15).

    Same reduced backbone for all ops (only the mixing sublayer differs),
    so the matrix shows the relative cost of each operator AND makes any
    registry-dispatch overhead visible in the perf trajectory: train-fwd
    tok/s is one jitted ``lm_apply`` over (B, n), decode tok/s is a
    jitted ``lax.scan`` of fused single-token steps (the serving block
    path without sampling).  Each measured tok/s is combined with the
    analytic whole-model cost (``repro.obs.costs.model_cost``) and the
    device roofline into achieved-vs-peak utilization — the §Utilization
    table in ``benchmarks.report`` and the number the fused-kernel
    ROADMAP work is judged by.
    """
    import functools

    from repro.configs import get_config
    from repro.models import lm, seq_op
    from repro.models.config import MambaConfig
    from repro.models.param import init_params
    from repro.obs import costs
    from repro.obs.perf import device_peak, roofline_utilization

    base = get_config("hla-1b", reduced=True)
    B, n, steps = 4, 256, 16
    peak = device_peak()
    entries = {}
    for name in seq_op.registered_op_names():
        cfg = base.replace(mixer=("softmax" if name == "attn" else name))
        if name == "mamba":
            cfg = cfg.replace(mamba=MambaConfig(d_state=8))
        params = init_params(lm.lm_specs(cfg), jax.random.key(0))
        rng = np.random.RandomState(7)
        toks = jnp.asarray(rng.randint(1, cfg.vocab, (B, n)), jnp.int32)

        fwd = jax.jit(functools.partial(
            lambda p, t, cfg: lm.lm_apply(p, t, cfg)[0], cfg=cfg
        ))
        t_fwd = _timeit(fwd, params, toks, warmup=1)

        _, states = jax.jit(functools.partial(
            lambda p, t, cfg: lm.lm_prefill(p, t, cfg), cfg=cfg
        ))(params, toks)

        def decode_block(p, st, tok, pos, cfg=cfg):
            def body(carry, _):
                st, tok, pos = carry
                lg, st, _ = lm.lm_apply(
                    p, tok, cfg, states=st, positions=pos, mode="decode"
                )
                nxt = jnp.argmax(lg[:, -1], -1)[:, None].astype(jnp.int32)
                return (st, nxt, pos + 1), ()
            (st, tok, _), _ = jax.lax.scan(
                body, (st, tok, pos), length=steps
            )
            return st, tok

        tok0 = toks[:, -1:]
        pos0 = jnp.full((B, 1), n, jnp.int32)
        t_dec = _timeit(
            jax.jit(decode_block), params, states, tok0, pos0, warmup=1,
        )

        op = seq_op.get_op(name)
        train_tok_s = B * n / (t_fwd.us / 1e6)
        decode_tok_s = B * steps / (t_dec.us / 1e6)

        # achieved-vs-roofline: measured tok/s x analytic whole-model
        # FLOPs/token against the device peak
        cost_f = costs.model_cost(cfg, mode="train_fwd", seq_len=n, batch=B)
        cost_d = costs.model_cost(cfg, mode="decode_step", seq_len=n + steps,
                                  batch=B)
        util_f = roofline_utilization(train_tok_s, cost_f, peak)
        util_d = roofline_utilization(decode_tok_s, cost_d, peak)

        entries[name] = {
            "train_fwd_tok_per_s": round(train_tok_s, 1),
            "train_iqr_us": round(t_fwd.iqr_us, 1),
            "decode_tok_per_s": round(decode_tok_s, 1),
            "decode_iqr_us": round(t_dec.iqr_us, 1),
            "train_flops_per_token": round(cost_f.flops_per_token),
            "decode_flops_per_token": round(cost_d.flops_per_token),
            "train_util": round(util_f["utilization"], 6),
            "train_bound": util_f["bound"],
            "decode_util": round(util_d["utilization"], 6),
            "decode_bound": util_d["bound"],
            "state_bytes": cost_f.state_bytes,
            "streaming": op.streaming,
            "has_fused_kernels": op.has_fused_kernels,
            "spec_decodable": op.spec_decodable,
        }
        rows.append((
            f"ops/{name}", t_fwd.us, t_fwd.iqr_us,
            f"train_fwd_tok_per_s={train_tok_s:.0f} "
            f"decode_tok_per_s={decode_tok_s:.0f} "
            f"train_util={util_f['utilization']:.4f} "
            f"decode_util={util_d['utilization']:.4f}",
        ))
        _metric(rows, f"ops/{name}/train_fwd_tok_per_s", train_tok_s,
                unit="tok/s", direction="higher",
                dispersion=_tps_disp(train_tok_s, t_fwd))
        _metric(rows, f"ops/{name}/decode_tok_per_s", decode_tok_s,
                unit="tok/s", direction="higher",
                dispersion=_tps_disp(decode_tok_s, t_dec))

    write_results("ops", {
        "backend": jax.default_backend(),
        "shape": {"B": B, "n": n, "decode_steps": steps,
                  "arch": "hla-1b-reduced"},
        "peak": peak,
        "entries": entries,
    })


def bench_spec(rows):
    """Speculative decoding vs plain block decode (acceptance + tok/s).

    A meaningful acceptance rate needs a model whose continuations are
    actually predictable, so the bench first TRAINS a small HLA2 LM
    (~120 AdamW steps, seconds on CPU) on a cyclic token language until
    greedy decode reproduces the cycle — the classic repetitive-text
    workload (templated/extractive generation) where prompt-lookup
    drafting shines.  Then, on identical requests:

    * plain block decode (block=8, the §Serving path) is the baseline;
    * speculative decode with the model-free n-gram drafter at
      k in {2, 4, 8} measures end-to-end decode tok/s, acceptance rate,
      and rollback rounds — with the greedy streams asserted
      token-for-token equal to the baseline's (the DESIGN.md §10
      exactness contract, also enforced in tests/test_spec_decode.py).

    The win mechanism: a fully-accepted round commits k+1 tokens for ONE
    chunk-parallel verify call, while plain decode pays k+1 sequential
    full-model steps.
    """
    from repro.configs import get_config
    from repro.models import lm
    from repro.models.param import init_params
    from repro.optim import adamw
    from repro.serving import Engine, GenRequest, SpecConfig

    cfg = get_config("hla-1b", reduced=True).replace(
        n_layers=4, d_model=256, n_heads=4, n_kv_heads=4, d_ff=768,
        vocab=512,
    )
    train_steps, period = 120, 16
    pattern = np.random.RandomState(0).permutation(
        np.arange(2, 2 + period)
    ).astype(np.int64)
    seq = np.tile(pattern, 8)  # the cyclic language

    params = init_params(lm.lm_specs(cfg), jax.random.key(0))
    opt = adamw.init_opt_state(params)
    oc = adamw.OptConfig(lr=3e-3, warmup_steps=10, total_steps=train_steps)

    @jax.jit
    def train_step(params, opt, tokens, labels):
        (l, _), g = jax.value_and_grad(lm.lm_loss, has_aux=True)(
            params, tokens, labels, cfg
        )
        params, opt, _ = adamw.adamw_update(params, g, opt, oc)
        return params, opt, l

    t0 = time.perf_counter()
    for s in range(train_steps):
        offs = np.random.RandomState(s).randint(0, period, 8)
        toks = np.stack([np.roll(seq, -o)[:64] for o in offs])
        params, opt, loss = train_step(
            params, opt, jnp.asarray(toks[:, :-1]), jnp.asarray(toks[:, 1:])
        )
    rows.append((
        "spec/train_workload", 0.0, 0.0,
        f"steps={train_steps} final_loss={float(loss):.1e} "
        f"train_s={time.perf_counter() - t0:.1f}",
    ))

    slots, gen_len = 2, 96
    prompt = np.tile(pattern, 2)
    mk_reqs = lambda: [  # noqa: E731
        GenRequest(rid=i, prompt=np.roll(prompt, -i), max_new=gen_len)
        for i in range(4)
    ]

    def measure(spec):
        eng = Engine(
            cfg, params, slots=slots,
            max_len=len(prompt) + gen_len + 16, block=8, spec=spec,
        )
        eng.run([GenRequest(rid=-1, prompt=prompt, max_new=16)])  # warm jits
        eng.obs.reset()  # fresh metrics epoch for the measured traffic
        eng.reset_breaker()  # warmup zero-acceptance must not leak
        results = eng.run(mk_reqs())
        st = eng.stats
        decode_toks = sum(len(r.tokens) - 1 for r in results)
        return decode_toks / max(st["decode_s"], 1e-9), st, results

    plain_tps, _, plain_res = measure(None)
    rows.append((
        "spec/plain_decode", 0.0, 0.0, f"tok_per_s={plain_tps:.1f} block=8",
    ))
    _metric(rows, "spec/plain_decode/tok_per_s", plain_tps,
            unit="tok/s", direction="higher")
    entries = []
    for k in (2, 4, 8):
        tps, st, res = measure(SpecConfig(k=k, drafter="ngram"))
        # correctness sanity: greedy spec streams must equal plain greedy
        assert [r.tokens for r in res] == [r.tokens for r in plain_res], (
            f"speculative greedy diverged from plain greedy at k={k}"
        )
        acc = st["spec_accepted"] / max(st["spec_drafted"], 1)
        ent = {
            "k": k,
            "tok_per_s": round(tps, 1),
            "speedup": round(tps / max(plain_tps, 1e-9), 2),
            "acceptance": round(acc, 3),
            "rounds": st["spec_rounds"],
            "rollback_rounds": st["spec_replays"],
        }
        entries.append(ent)
        rows.append((
            f"spec/ngram_k{k}", 0.0, 0.0,
            f"tok_per_s={tps:.1f} speedup={ent['speedup']}x "
            f"acceptance={acc:.2f}",
        ))
        _metric(rows, f"spec/ngram_k{k}/tok_per_s", tps,
                unit="tok/s", direction="higher")
    write_results("spec", {
        "backend": jax.default_backend(),
        "shape": {"slots": slots, "prompt_len": len(prompt),
                  "gen_len": gen_len, "requests": 4,
                  "drafter": "ngram", "model": "hla2-4L-256d",
                  "workload": f"cyclic period-{period} (trained)"},
        "plain_tok_per_s": round(plain_tps, 1),
        "entries": entries,
    })


def bench_distributed(rows):
    """Multi-device scaling: train-step tok/s per device, 1 -> 8 host
    devices (each device count runs in a fresh subprocess because XLA
    locks the host platform device count at first init).

    The subprocess drives the REAL sharded train step
    (``distributed.steps.make_train_step`` on a ("data", "model") mesh
    from ``launch.mesh.make_mesh``) over the reduced paper model.  On CPU
    host devices the absolute numbers are smoke-level; the per-device
    ratio tracks sharding overhead.
    """
    import subprocess
    import sys
    import textwrap

    B, n, steps = 8, 64, 6
    # single source for the shape: injected into the subprocess source AND
    # recorded in results/distributed.json below
    body = f"B, n, steps = {B}, {n}, {steps}\n" + textwrap.dedent("""
        import json, time, functools
        import jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.distributed import steps as steps_mod, sharding as shd
        from repro.launch.mesh import make_mesh
        from repro.models.param import init_params
        from repro.optim import adamw
        from repro.data.pipeline import DataConfig, SyntheticStream

        cfg = get_config("hla-1b", reduced=True)
        specs = steps_mod.model_specs(cfg)
        mesh = make_mesh()
        stream = SyntheticStream(DataConfig(cfg.vocab, n, B, seed=0))
        with mesh:
            ps = shd.param_shardings(specs, mesh)
            params = jax.jit(functools.partial(init_params, specs),
                             out_shardings=ps)(jax.random.key(0))
            opt = adamw.init_opt_state(params)
            step = jax.jit(steps_mod.make_train_step(
                cfg, adamw.OptConfig(total_steps=steps),
                grad_shardings=ps))
            place = lambda b: {
                k: jax.device_put(jnp.asarray(v),
                                  shd.batch_sharding(mesh, v.shape))
                for k, v in b.items()}
            params, opt, m = step(params, opt, place(stream.batch(0)))
            jax.block_until_ready(m["loss"])  # compile + warm
            t0 = time.perf_counter()
            for s in range(1, steps + 1):
                params, opt, m = step(params, opt, place(stream.batch(s)))
            jax.block_until_ready(m["loss"])
            dt = (time.perf_counter() - t0) / steps
        ndev = len(jax.devices())
        print(json.dumps({
            "devices": ndev,
            "steps_per_s": round(1.0 / dt, 3),
            "tok_per_s": round(B * n / dt, 1),
            "tok_per_s_per_device": round(B * n / dt / ndev, 1),
        }))
    """)
    entries = []
    for ndev in (1, 8):
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={ndev}"
        env["PYTHONPATH"] = os.path.join(
            os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"
        )
        proc = subprocess.run(
            [sys.executable, "-c", body], capture_output=True, text=True,
            timeout=900, env=env,
        )
        assert proc.returncode == 0, proc.stderr[-2000:]
        r = json.loads(proc.stdout.strip().splitlines()[-1])
        entries.append(r)
        rows.append((
            f"distributed/train_dev{r['devices']}",
            1e6 / r["steps_per_s"], 0.0,
            f"tok_per_s={r['tok_per_s']} per_device={r['tok_per_s_per_device']}",
        ))
        _metric(rows, f"distributed/train_dev{r['devices']}/tok_per_s",
                r["tok_per_s"], unit="tok/s", direction="higher")
    write_results("distributed", {
        "backend": "cpu-host-mesh",
        "shape": {"B": B, "n": n, "arch": "hla-1b-reduced"},
        "entries": entries,
    })


BENCHES = {
    "bench_equivalence": bench_equivalence,
    "bench_complexity": bench_complexity,
    "bench_statesize": bench_statesize,
    "bench_chunkwidth": bench_chunkwidth,
    "bench_kernels": bench_kernels,
    "bench_train_step": bench_train_step,
    "bench_decode_throughput": bench_decode_throughput,
    "bench_serving": bench_serving,
    "bench_ops": bench_ops,
    "bench_spec": bench_spec,
    "bench_distributed": bench_distributed,
}

# bench_distributed spawns its own multi-device subprocesses — too slow
# for the default everything run; select it explicitly.
DEFAULT_BENCHES = [k for k in BENCHES if k != "bench_distributed"]


def main(argv=None) -> None:
    """``python -m benchmarks.run [bench_name ...]`` — no args runs the
    default set (everything except the subprocess-spawning
    ``bench_distributed``)."""
    import argparse

    from repro.obs import Obs
    from repro.obs.perf import BenchHistory, profile_capture

    ap = argparse.ArgumentParser(
        prog="python -m benchmarks.run",
        description="Benchmark harness; no names runs the default set.",
    )
    ap.add_argument("benches", nargs="*", metavar="bench_name",
                    help=f"subset of {list(BENCHES)}")
    ap.add_argument("--history", default=None, metavar="PATH",
                    help="append this run's rows to a repro.obs.bench/v1 "
                         "history JSONL (perfcheck's input)")
    ap.add_argument("--profile-dir", default=None, metavar="DIR",
                    help="capture a jax.profiler trace of the whole run "
                         "into DIR (view with TensorBoard / Perfetto)")
    args = ap.parse_args(argv)

    names = args.benches or list(DEFAULT_BENCHES)
    unknown = [n for n in names if n not in BENCHES]
    if unknown:
        raise SystemExit(f"unknown benches {unknown}; have {list(BENCHES)}")
    rows = RowSink()
    obs = Obs()
    with profile_capture(args.profile_dir, obs=obs):
        for n in names:
            with obs.span("bench.run", bench=n):
                BENCHES[n](rows)
    print("name,us_per_call,iqr_us,derived")
    for name, us, iqr, derived in rows:
        print(f"{name},{us:.1f},{iqr:.1f},{derived}")
    if args.history:
        hist = BenchHistory(args.history)
        for name, us, iqr, derived in rows:
            if us > 0:
                hist.bench_row(name, us, unit="us", direction="lower",
                               dispersion=iqr)
        for m in rows.metrics:
            hist.bench_row(m["name"], m["value"], unit=m["unit"],
                           direction=m["direction"],
                           dispersion=m["dispersion"])
        print(f"# history: {hist.rows_written} rows appended to "
              f"{args.history} (run {hist.run_id})")


if __name__ == "__main__":
    main()
