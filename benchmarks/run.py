"""Benchmark harness — one entry per paper table/figure + system benches.

Prints ``name,us_per_call,derived`` CSV rows.  The paper is algorithmic
(no empirical tables); its claims map to:

* Fig. 1/2 + Thms 3.1/4.1/6.1/7.1/7.2 — `equivalence` (views agree, and
  timing of each view);
* §5 complexity (linear time, O(1) state)  — `complexity` (us/token vs n),
  `statesize` (state bytes vs n, constant);
* §4 chunk-parallel training — `chunkwidth` (throughput vs w), and
  `train_step` (fwd+bwd us/step: fused Pallas VJP with chunk-state
  checkpointing vs recompute-in-backward vs jnp reference; persisted to
  ``results/train_step.json`` for `benchmarks.report`);
* serving (continuous batching over the paper's O(1)-state decode) —
  `serving` (TTFT + steady-state decode tok/s from the state-pool engine;
  persisted to ``results/serving.json``);
* the multi-pod roofline table is produced by `benchmarks.roofline`
  (separate long-running driver) and summarized by `benchmarks.report`.
"""

from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")


def _timeit(fn, *args, iters=5, warmup=2):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6  # us


def _mk(rng, B, H, n, d):
    q = jnp.asarray(rng.randn(B, H, n, d) * 0.5, jnp.float32)
    k = jnp.asarray(rng.randn(B, H, n, d) * 0.5, jnp.float32)
    v = jnp.asarray(rng.randn(B, H, n, d) * 0.5, jnp.float32)
    g = jnp.asarray(rng.uniform(0.9, 0.99, (B, H)), jnp.float32)
    return q, k, v, g


def bench_equivalence(rows):
    from repro.core.hla2 import (
        hla2_chunkwise,
        hla2_naive,
        hla2_scan,
        hla2_serial,
    )

    rng = np.random.RandomState(0)
    q, k, v, g = _mk(rng, 2, 2, 256, 32)
    o_ref = hla2_naive(q, k, v, g)
    impls = {
        "hla2_serial": jax.jit(lambda *a: hla2_serial(*a)[0]),
        "hla2_scan": jax.jit(lambda *a: hla2_scan(*a)[0]),
        "hla2_chunkwise": jax.jit(lambda *a: hla2_chunkwise(*a, chunk=64)[0]),
    }
    for name, fn in impls.items():
        err = float(jnp.max(jnp.abs(fn(q, k, v, g) - o_ref)))
        us = _timeit(fn, q, k, v, g)
        rows.append((f"equivalence/{name}", us, f"max_err={err:.2e}"))


def bench_complexity(rows):
    """us/token vs n: HLA2 chunkwise is linear; the naive path quadratic."""
    from repro.core.hla2 import hla2_chunkwise, hla2_naive

    rng = np.random.RandomState(1)
    chunked = jax.jit(lambda a, b, c: hla2_chunkwise(a, b, c, chunk=64)[0])
    naive = jax.jit(lambda a, b, c: hla2_naive(a, b, c))
    per_tok = {}
    for n in (256, 512, 1024, 2048):
        q, k, v, _ = _mk(rng, 1, 2, n, 32)
        us = _timeit(chunked, q, k, v, iters=3)
        per_tok[n] = us / n
        rows.append((f"complexity/hla2_chunk_n{n}", us, f"us_per_tok={us/n:.2f}"))
    for n in (256, 512, 1024):
        q, k, v, _ = _mk(rng, 1, 2, n, 32)
        us = _timeit(naive, q, k, v, iters=3)
        rows.append((f"complexity/naive_n{n}", us, f"us_per_tok={us/n:.2f}"))
    growth = per_tok[2048] / per_tok[256]
    rows.append((
        "complexity/linear_check", 0.0,
        f"us_per_tok growth 256->2048 = {growth:.2f}x (1.0 = perfectly linear)",
    ))


def bench_statesize(rows):
    """Decode state bytes: constant in context length (vs a KV cache)."""
    from repro.configs import get_config
    from repro.models import lm

    cfg = get_config("hla-1b", reduced=True)
    for n_ctx in (1024, 8192, 65536):
        states = jax.eval_shape(lambda: lm.lm_init_states(cfg, 1, n_ctx))
        hla_bytes = sum(
            int(np.prod(x.shape)) * x.dtype.itemsize
            for x in jax.tree.leaves(states)
        )
        cfg_sm = cfg.replace(mixer="softmax")
        states_sm = jax.eval_shape(
            lambda: lm.lm_init_states(cfg_sm, 1, n_ctx)
        )
        kv_bytes = sum(
            int(np.prod(x.shape)) * x.dtype.itemsize
            for x in jax.tree.leaves(states_sm)
        )
        rows.append((
            f"statesize/ctx{n_ctx}", 0.0,
            f"hla_state={hla_bytes/2**20:.2f}MiB kv_cache={kv_bytes/2**20:.2f}MiB",
        ))


def bench_chunkwidth(rows):
    from repro.core.hla2 import hla2_chunkwise

    rng = np.random.RandomState(2)
    q, k, v, g = _mk(rng, 2, 4, 2048, 64)
    for w in (16, 32, 64, 128, 256):
        fn = jax.jit(
            lambda a, b, c, gg, w=w: hla2_chunkwise(a, b, c, gg, chunk=w)[0]
        )
        us = _timeit(fn, q, k, v, g, iters=3)
        rows.append((f"chunkwidth/w{w}", us, f"tok_per_s={2048*2/us*1e6:.0f}"))


def bench_kernels(rows):
    """Pallas kernel (interpret) correctness + jnp reference timing."""
    from repro.kernels import ref as kref
    from repro.kernels.hla2_chunk import hla2_chunk_pallas

    rng = np.random.RandomState(3)
    q = jnp.asarray(rng.randn(4, 256, 64) * 0.5, jnp.float32)
    k = jnp.asarray(rng.randn(4, 256, 64) * 0.5, jnp.float32)
    v = jnp.asarray(rng.randn(4, 256, 64) * 0.5, jnp.float32)
    o_p, _ = hla2_chunk_pallas(q, k, v, None, chunk=64, interpret=True)
    o_r, _ = kref.hla2_chunk_ref(q, k, v, None, chunk=64)
    err = float(jnp.max(jnp.abs(o_p - o_r)))
    fn = jax.jit(lambda a, b, c: kref.hla2_chunk_ref(a, b, c, None, chunk=64)[0])
    us = _timeit(fn, q, k, v, iters=3)
    rows.append(("kernels/hla2_chunk_ref", us, f"pallas_interpret_err={err:.2e}"))


def bench_train_step(rows):
    """Training-step (fwd+bwd) timing: fused Pallas VJP vs reference paths.

    ``*_fused`` runs the chunkwise Pallas forward with chunk-state
    checkpointing and the fused reverse-chunk-walk backward;
    ``*_recompute`` is the legacy design (fused forward, jnp recompute
    under ``jax.vjp`` in the backward); ``*_ref`` is the pure-jnp chunkwise
    path end to end.  On CPU the kernels execute in interpret mode (Python
    body per grid step), so the XLA-compiled ``*_ref`` row is the relevant
    CPU number — on TPU the same entries time the native kernels.

    Results are also dumped to ``results/train_step.json`` so
    ``benchmarks.report`` can track the training-throughput trajectory.
    """
    from repro.kernels.ops import ahla_attention, hla2_attention

    rng = np.random.RandomState(4)
    B, H, n, d = 1, 2, 512, 32
    q, k, v, g = _mk(rng, B, H, n, d)

    def make_loss(fn, **kw):
        def loss(a, b, c, gg):
            return jnp.sum(fn(a, b, c, gg, chunk=64, **kw) ** 2)

        return loss

    entries = {
        "hla2_fused": make_loss(hla2_attention, use_pallas=True,
                                fused_bwd=True),
        "hla2_recompute": make_loss(hla2_attention, use_pallas=True,
                                    fused_bwd=False),
        "hla2_ref": make_loss(hla2_attention, use_pallas=False),
        "ahla_fused": make_loss(ahla_attention, use_pallas=True,
                                fused_bwd=True),
        "ahla_ref": make_loss(ahla_attention, use_pallas=False),
    }
    backend = jax.default_backend()
    results = {}
    for name, loss in entries.items():
        step = jax.jit(jax.grad(loss, argnums=(0, 1, 2, 3)))
        us = _timeit(step, q, k, v, g, iters=3, warmup=1)
        tok_s = B * n / us * 1e6  # tokens (not head-tokens) per second
        rows.append((
            f"train_step/{name}", us,
            f"tok_per_s={tok_s:.0f} backend={backend}",
        ))
        results[name] = {"us_per_step": round(us, 1),
                         "tok_per_s": round(tok_s)}
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, "train_step.json"), "w") as f:
        json.dump({
            "backend": backend,
            "shape": {"B": B, "H": H, "n": n, "d": d, "chunk": 64},
            "entries": results,
        }, f, indent=1)


def bench_decode_throughput(rows):
    """Streaming decode (view A): us/token for the reduced paper model."""
    from repro.configs import get_config
    from repro.models import lm
    from repro.models.param import init_params

    cfg = get_config("hla-1b", reduced=True)
    params = init_params(lm.lm_specs(cfg), jax.random.key(0))
    B = 4
    states = lm.lm_init_states(cfg, B, 64)
    tok = jnp.ones((B, 1), jnp.int32)
    pos = jnp.zeros((B, 1), jnp.int32)

    @jax.jit
    def step(params, tok, states, pos):
        lg, st, _ = lm.lm_apply(
            params, tok, cfg, states=states, positions=pos, mode="decode"
        )
        return lg, st

    lg, states = step(params, tok, states, pos)  # compile
    t0 = time.perf_counter()
    iters = 20
    for i in range(iters):
        lg, states = step(params, tok, states, pos + i)
    jax.block_until_ready(lg)
    us = (time.perf_counter() - t0) / iters * 1e6
    rows.append(("decode/hla2_reduced", us, f"tok_per_s={B/us*1e6:.0f}"))


def bench_serving(rows):
    """Continuous-batching engine: TTFT + steady-state decode tok/s.

    Chunk-parallel prefill admissions interleaved with block decode over
    the reduced paper model (repro.serving.Engine); TTFT = admission ->
    first sampled token (one prefill call + sample), steady-state tok/s =
    generated tokens / decode wall time.  Dumped to ``results/serving.json``
    for ``benchmarks.report`` (§Serving table).
    """
    from repro.configs import get_config
    from repro.models import lm
    from repro.models.param import init_params
    from repro.serving import Engine, GenRequest

    cfg = get_config("hla-1b", reduced=True)
    params = init_params(lm.lm_specs(cfg), jax.random.key(0))
    slots, prompt_len, gen_len, block = 4, 32, 32, 8
    engine = Engine(
        cfg, params, slots=slots,
        max_len=prompt_len + gen_len + 8, block=block,
    )
    rng = np.random.RandomState(5)
    reqs = [
        GenRequest(rid=i, prompt=rng.randint(2, cfg.vocab, prompt_len),
                   max_new=gen_len)
        for i in range(8)
    ]
    # warm the jits (prefill trace + decode-block trace), then measure
    # from a fresh obs epoch (zeroes every metric series + event ring)
    engine.run([GenRequest(rid=-1, prompt=reqs[0].prompt, max_new=block)])
    engine.obs.reset()
    results = engine.run(reqs)
    st = engine.stats
    ttft_hist = engine.obs.registry.get("serving_ttft_seconds")
    ttft_ms = 1e3 * float(np.mean(st["ttft_s"]))
    ttft_p50 = 1e3 * (ttft_hist.quantile(0.5) or 0.0)
    ttft_p99 = 1e3 * (ttft_hist.quantile(0.99) or 0.0)
    # exclude each request's first token (produced by prefill) from the
    # steady-state decode rate
    decode_toks = sum(len(r.tokens) - 1 for r in results)
    tok_s = decode_toks / max(st["decode_s"], 1e-9)
    backend = jax.default_backend()
    rows.append((
        "serving/ttft", ttft_ms * 1e3,
        f"ttft_ms_p50={ttft_p50:.1f} p99={ttft_p99:.1f} "
        f"prompt_len={prompt_len} backend={backend}",
    ))
    rows.append((
        "serving/decode", 0.0,
        f"tok_per_s={tok_s:.1f} slots={slots} block={block}",
    ))
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, "serving.json"), "w") as f:
        json.dump({
            "backend": backend,
            "shape": {"slots": slots, "prompt_len": prompt_len,
                      "gen_len": gen_len, "block": block,
                      "requests": len(reqs)},
            "ttft_ms_mean": round(ttft_ms, 2),
            "ttft_ms_p50": round(ttft_p50, 2),
            "ttft_ms_p99": round(ttft_p99, 2),
            "decode_tok_per_s": round(tok_s, 1),
            "prefill_tok_per_s": round(
                st["prompt_tokens"] / max(st["prefill_s"], 1e-9), 1
            ),
            # the same snapshot schema the serve CLI's --metrics-out dumps,
            # scoped to the bench's engine (report.py and ad-hoc tooling
            # can consume either artifact identically)
            "metrics": engine.obs.snapshot(),
        }, f, indent=1)


def bench_ops(rows):
    """Per-operator train-forward and decode throughput over EVERY
    registered ``SequenceOp`` (DESIGN.md §11).

    Same reduced backbone for all ops (only the mixing sublayer differs),
    so the matrix shows the relative cost of each operator AND makes any
    registry-dispatch overhead visible in the perf trajectory: train-fwd
    tok/s is one jitted ``lm_apply`` over (B, n), decode tok/s is a
    jitted ``lax.scan`` of fused single-token steps (the serving block
    path without sampling).  Dumped to ``results/ops.json`` for
    ``benchmarks.report`` (§Operator table).
    """
    import functools

    from repro.configs import get_config
    from repro.models import lm, seq_op
    from repro.models.config import MambaConfig
    from repro.models.param import init_params

    base = get_config("hla-1b", reduced=True)
    B, n, steps = 4, 256, 16
    entries = {}
    for name in seq_op.registered_op_names():
        cfg = base.replace(mixer=("softmax" if name == "attn" else name))
        if name == "mamba":
            cfg = cfg.replace(mamba=MambaConfig(d_state=8))
        params = init_params(lm.lm_specs(cfg), jax.random.key(0))
        rng = np.random.RandomState(7)
        toks = jnp.asarray(rng.randint(1, cfg.vocab, (B, n)), jnp.int32)

        fwd = jax.jit(functools.partial(
            lambda p, t, cfg: lm.lm_apply(p, t, cfg)[0], cfg=cfg
        ))
        us_fwd = _timeit(fwd, params, toks, iters=3, warmup=1)

        _, states = jax.jit(functools.partial(
            lambda p, t, cfg: lm.lm_prefill(p, t, cfg), cfg=cfg
        ))(params, toks)

        def decode_block(p, st, tok, pos, cfg=cfg):
            def body(carry, _):
                st, tok, pos = carry
                lg, st, _ = lm.lm_apply(
                    p, tok, cfg, states=st, positions=pos, mode="decode"
                )
                nxt = jnp.argmax(lg[:, -1], -1)[:, None].astype(jnp.int32)
                return (st, nxt, pos + 1), ()
            (st, tok, _), _ = jax.lax.scan(
                body, (st, tok, pos), length=steps
            )
            return st, tok

        tok0 = toks[:, -1:]
        pos0 = jnp.full((B, 1), n, jnp.int32)
        us_dec = _timeit(
            jax.jit(decode_block), params, states, tok0, pos0,
            iters=3, warmup=1,
        )

        op = seq_op.get_op(name)
        train_tok_s = B * n / (us_fwd / 1e6)
        decode_tok_s = B * steps / (us_dec / 1e6)
        entries[name] = {
            "train_fwd_tok_per_s": round(train_tok_s, 1),
            "decode_tok_per_s": round(decode_tok_s, 1),
            "streaming": op.streaming,
            "has_fused_kernels": op.has_fused_kernels,
            "spec_decodable": op.spec_decodable,
        }
        rows.append((
            f"ops/{name}", us_fwd,
            f"train_fwd_tok_per_s={train_tok_s:.0f} "
            f"decode_tok_per_s={decode_tok_s:.0f}",
        ))

    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, "ops.json"), "w") as f:
        json.dump({
            "backend": jax.default_backend(),
            "shape": {"B": B, "n": n, "decode_steps": steps,
                      "arch": "hla-1b-reduced"},
            "entries": entries,
        }, f, indent=1)


def bench_spec(rows):
    """Speculative decoding vs plain block decode (acceptance + tok/s).

    A meaningful acceptance rate needs a model whose continuations are
    actually predictable, so the bench first TRAINS a small HLA2 LM
    (~120 AdamW steps, seconds on CPU) on a cyclic token language until
    greedy decode reproduces the cycle — the classic repetitive-text
    workload (templated/extractive generation) where prompt-lookup
    drafting shines.  Then, on identical requests:

    * plain block decode (block=8, the §Serving path) is the baseline;
    * speculative decode with the model-free n-gram drafter at
      k in {2, 4, 8} measures end-to-end decode tok/s, acceptance rate,
      and rollback rounds — with the greedy streams asserted
      token-for-token equal to the baseline's (the DESIGN.md §10
      exactness contract, also enforced in tests/test_spec_decode.py).

    The win mechanism: a fully-accepted round commits k+1 tokens for ONE
    chunk-parallel verify call, while plain decode pays k+1 sequential
    full-model steps.  Dumped to ``results/spec.json`` for
    ``benchmarks.report`` (§Speculative table).
    """
    from repro.configs import get_config
    from repro.models import lm
    from repro.models.param import init_params
    from repro.optim import adamw
    from repro.serving import Engine, GenRequest, SpecConfig

    cfg = get_config("hla-1b", reduced=True).replace(
        n_layers=4, d_model=256, n_heads=4, n_kv_heads=4, d_ff=768,
        vocab=512,
    )
    train_steps, period = 120, 16
    pattern = np.random.RandomState(0).permutation(
        np.arange(2, 2 + period)
    ).astype(np.int64)
    seq = np.tile(pattern, 8)  # the cyclic language

    params = init_params(lm.lm_specs(cfg), jax.random.key(0))
    opt = adamw.init_opt_state(params)
    oc = adamw.OptConfig(lr=3e-3, warmup_steps=10, total_steps=train_steps)

    @jax.jit
    def train_step(params, opt, tokens, labels):
        (l, _), g = jax.value_and_grad(lm.lm_loss, has_aux=True)(
            params, tokens, labels, cfg
        )
        params, opt, _ = adamw.adamw_update(params, g, opt, oc)
        return params, opt, l

    t0 = time.perf_counter()
    for s in range(train_steps):
        offs = np.random.RandomState(s).randint(0, period, 8)
        toks = np.stack([np.roll(seq, -o)[:64] for o in offs])
        params, opt, loss = train_step(
            params, opt, jnp.asarray(toks[:, :-1]), jnp.asarray(toks[:, 1:])
        )
    rows.append((
        "spec/train_workload", 0.0,
        f"steps={train_steps} final_loss={float(loss):.1e} "
        f"train_s={time.perf_counter() - t0:.1f}",
    ))

    slots, gen_len = 2, 96
    prompt = np.tile(pattern, 2)
    mk_reqs = lambda: [  # noqa: E731
        GenRequest(rid=i, prompt=np.roll(prompt, -i), max_new=gen_len)
        for i in range(4)
    ]

    def measure(spec):
        eng = Engine(
            cfg, params, slots=slots,
            max_len=len(prompt) + gen_len + 16, block=8, spec=spec,
        )
        eng.run([GenRequest(rid=-1, prompt=prompt, max_new=16)])  # warm jits
        eng.obs.reset()  # fresh metrics epoch for the measured traffic
        eng.reset_breaker()  # warmup zero-acceptance must not leak
        results = eng.run(mk_reqs())
        st = eng.stats
        decode_toks = sum(len(r.tokens) - 1 for r in results)
        return decode_toks / max(st["decode_s"], 1e-9), st, results

    plain_tps, _, plain_res = measure(None)
    rows.append((
        "spec/plain_decode", 0.0, f"tok_per_s={plain_tps:.1f} block=8",
    ))
    entries = []
    for k in (2, 4, 8):
        tps, st, res = measure(SpecConfig(k=k, drafter="ngram"))
        # correctness sanity: greedy spec streams must equal plain greedy
        assert [r.tokens for r in res] == [r.tokens for r in plain_res], (
            f"speculative greedy diverged from plain greedy at k={k}"
        )
        acc = st["spec_accepted"] / max(st["spec_drafted"], 1)
        ent = {
            "k": k,
            "tok_per_s": round(tps, 1),
            "speedup": round(tps / max(plain_tps, 1e-9), 2),
            "acceptance": round(acc, 3),
            "rounds": st["spec_rounds"],
            "rollback_rounds": st["spec_replays"],
        }
        entries.append(ent)
        rows.append((
            f"spec/ngram_k{k}", 0.0,
            f"tok_per_s={tps:.1f} speedup={ent['speedup']}x "
            f"acceptance={acc:.2f}",
        ))
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, "spec.json"), "w") as f:
        json.dump({
            "backend": jax.default_backend(),
            "shape": {"slots": slots, "prompt_len": len(prompt),
                      "gen_len": gen_len, "requests": 4,
                      "drafter": "ngram", "model": "hla2-4L-256d",
                      "workload": f"cyclic period-{period} (trained)"},
            "plain_tok_per_s": round(plain_tps, 1),
            "entries": entries,
        }, f, indent=1)


def bench_distributed(rows):
    """Multi-device scaling: train-step tok/s per device, 1 -> 8 host
    devices (each device count runs in a fresh subprocess because XLA
    locks the host platform device count at first init).

    The subprocess drives the REAL sharded train step
    (``distributed.steps.make_train_step`` on a ("data", "model") mesh
    from ``launch.mesh.make_mesh``) over the reduced paper model.  On CPU
    host devices the absolute numbers are smoke-level; the per-device
    ratio tracks sharding overhead.  Dumped to ``results/distributed.json``
    for ``benchmarks.report`` (§Distributed table).
    """
    import subprocess
    import sys
    import textwrap

    B, n, steps = 8, 64, 6
    # single source for the shape: injected into the subprocess source AND
    # recorded in results/distributed.json below
    body = f"B, n, steps = {B}, {n}, {steps}\n" + textwrap.dedent("""
        import json, time, functools
        import jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.distributed import steps as steps_mod, sharding as shd
        from repro.launch.mesh import make_mesh
        from repro.models.param import init_params
        from repro.optim import adamw
        from repro.data.pipeline import DataConfig, SyntheticStream

        cfg = get_config("hla-1b", reduced=True)
        specs = steps_mod.model_specs(cfg)
        mesh = make_mesh()
        stream = SyntheticStream(DataConfig(cfg.vocab, n, B, seed=0))
        with mesh:
            ps = shd.param_shardings(specs, mesh)
            params = jax.jit(functools.partial(init_params, specs),
                             out_shardings=ps)(jax.random.key(0))
            opt = adamw.init_opt_state(params)
            step = jax.jit(steps_mod.make_train_step(
                cfg, adamw.OptConfig(total_steps=steps),
                grad_shardings=ps))
            place = lambda b: {
                k: jax.device_put(jnp.asarray(v),
                                  shd.batch_sharding(mesh, v.shape))
                for k, v in b.items()}
            params, opt, m = step(params, opt, place(stream.batch(0)))
            jax.block_until_ready(m["loss"])  # compile + warm
            t0 = time.perf_counter()
            for s in range(1, steps + 1):
                params, opt, m = step(params, opt, place(stream.batch(s)))
            jax.block_until_ready(m["loss"])
            dt = (time.perf_counter() - t0) / steps
        ndev = len(jax.devices())
        print(json.dumps({
            "devices": ndev,
            "steps_per_s": round(1.0 / dt, 3),
            "tok_per_s": round(B * n / dt, 1),
            "tok_per_s_per_device": round(B * n / dt / ndev, 1),
        }))
    """)
    entries = []
    for ndev in (1, 8):
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={ndev}"
        env["PYTHONPATH"] = os.path.join(
            os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"
        )
        proc = subprocess.run(
            [sys.executable, "-c", body], capture_output=True, text=True,
            timeout=900, env=env,
        )
        assert proc.returncode == 0, proc.stderr[-2000:]
        r = json.loads(proc.stdout.strip().splitlines()[-1])
        entries.append(r)
        rows.append((
            f"distributed/train_dev{r['devices']}",
            1e6 / r["steps_per_s"],
            f"tok_per_s={r['tok_per_s']} per_device={r['tok_per_s_per_device']}",
        ))
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, "distributed.json"), "w") as f:
        json.dump({
            "backend": "cpu-host-mesh",
            "shape": {"B": B, "n": n, "arch": "hla-1b-reduced"},
            "entries": entries,
        }, f, indent=1)


BENCHES = {
    "bench_equivalence": bench_equivalence,
    "bench_complexity": bench_complexity,
    "bench_statesize": bench_statesize,
    "bench_chunkwidth": bench_chunkwidth,
    "bench_kernels": bench_kernels,
    "bench_train_step": bench_train_step,
    "bench_decode_throughput": bench_decode_throughput,
    "bench_serving": bench_serving,
    "bench_ops": bench_ops,
    "bench_spec": bench_spec,
    "bench_distributed": bench_distributed,
}

# bench_distributed spawns its own multi-device subprocesses — too slow
# for the default everything run; select it explicitly.
DEFAULT_BENCHES = [k for k in BENCHES if k != "bench_distributed"]


def main(argv=None) -> None:
    """``python -m benchmarks.run [bench_name ...]`` — no args runs the
    default set (everything except the subprocess-spawning
    ``bench_distributed``)."""
    import sys

    names = list(argv if argv is not None else sys.argv[1:]) or list(
        DEFAULT_BENCHES
    )
    unknown = [n for n in names if n not in BENCHES]
    if unknown:
        raise SystemExit(f"unknown benches {unknown}; have {list(BENCHES)}")
    rows = []
    for n in names:
        BENCHES[n](rows)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
