"""Summarize benchmarks/results/*.json into §Dry-run / §Roofline tables.

    PYTHONPATH=src python -m benchmarks.report [--md EXPERIMENTS_tables.md]

MODEL_FLOPS convention: train = 6*N*D (N = active params for MoE, D =
tokens), prefill = 2*N*D, decode = 2*N*B (one token per sequence);
all divided by device count for the per-device ratio against the
loop-aware HLO FLOPs.
"""

from __future__ import annotations

import argparse
import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "results")

_SUGGEST = {
    "compute_s": "increase arithmetic intensity (bigger chunk width / fuse "
    "HLA terms in the Pallas kernel); compute-bound is the goal state",
    "memory_s": "cut HBM traffic: bf16 residuals, larger fusion regions, "
    "avoid fp32 round-trips of gathered weights",
    "collective_s": "reduce gather/reduce volume: bf16 FSDP gathers, fewer "
    "microbatches, reuse gathered weights across fwd/bwd, overlap via LHS",
}


def _active_params(cfg):
    """Total and active (MoE top-k) parameter counts from the spec tree."""
    from repro.distributed.steps import model_specs
    from repro.models.param import _leaf_paths
    import numpy as np

    specs = model_specs(cfg)
    total = active = 0
    for path, sp in _leaf_paths(specs):
        n = int(np.prod(sp.shape))
        total += n
        if sp.axes and sp.axes[0] == "layers" and len(sp.axes) > 1 and \
                sp.axes[1] == "experts":
            frac = cfg.moe.top_k / cfg.moe.n_experts
            active += int(n * frac)
        elif "experts" in (sp.axes or ()):
            frac = cfg.moe.top_k / cfg.moe.n_experts
            active += int(n * frac)
        else:
            active += n
    return total, active


def model_flops(arch, shape_name, mixer, devices):
    from repro.configs import get_config
    from repro.models.config import get_shape

    cfg = get_config(arch, mixer=None if mixer == "rwkv6" else mixer)
    shape = get_shape(shape_name)
    total, active = _active_params(cfg)
    B, n = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        f = 6.0 * active * B * n
    elif shape.kind == "prefill":
        f = 2.0 * active * B * n
    else:
        f = 2.0 * active * B  # one token per sequence
    return f / devices, total, active


def load_results():
    rows = []
    for p in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        with open(p) as f:
            r = json.load(f)
        if r.get("ok"):
            rows.append(r)
    return rows


def render_train_step():
    """§Train-step table from results/train_step.json (benchmarks.run)."""
    path = os.path.join(RESULTS, "train_step.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        r = json.load(f)
    sh = r["shape"]
    out = [
        "\n### §Train-step — fwd+bwd per step "
        f"(backend={r['backend']}, B={sh['B']} H={sh['H']} n={sh['n']} "
        f"d={sh['d']} chunk={sh['chunk']})\n",
        "| path | us/step | tok/s |",
        "|---|---|---|",
    ]
    for name, e in r["entries"].items():
        out.append(f"| {name} | {e['us_per_step']:.1f} | {e['tok_per_s']} |")
    ent = r["entries"]
    if "hla2_fused" in ent and "hla2_recompute" in ent:
        sp = ent["hla2_recompute"]["us_per_step"] / max(
            ent["hla2_fused"]["us_per_step"], 1e-9
        )
        out.append(
            f"\nhla2 fused-bwd speedup over recompute-in-backward: "
            f"**{sp:.2f}x** (interpret-mode numbers on CPU are not "
            "indicative — compare on TPU)"
        )
    return "\n".join(out)


def render_serving():
    """§Serving-trace table from results/serving.json (benchmarks.run
    bench_serving): the multi-tenant Zipf-prefix / Poisson-arrival trace
    over the cached engine — sustained req/s, TTFT cold vs hit, cache
    hit rate."""
    path = os.path.join(RESULTS, "serving.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        r = json.load(f)
    if "req_per_s" not in r:
        return None  # pre-trace artifact (older bench schema): re-run
    sh = r["shape"]
    return "\n".join([
        "\n### §Serving-trace — multi-tenant Zipf-prefix trace "
        f"(backend={r['backend']}, slots={sh['slots']} "
        f"prefix={sh['prefix_len']} gen={sh['gen_len']} "
        f"block={sh['block']} chunk={sh['granularity']} "
        f"requests={sh['requests']} over {sh['prefixes']} "
        "shared prefixes)\n",
        "| metric | value |",
        "|---|---|",
        f"| sustained throughput | {r['req_per_s']:.1f} req/s |",
        f"| TTFT cold p50 / p99 | {r['ttft_cold_ms_p50']:.1f} / "
        f"{r['ttft_cold_ms_p99']:.1f} ms |",
        f"| TTFT hit p50 / p99 | {r['ttft_hit_ms_p50']:.1f} / "
        f"{r['ttft_hit_ms_p99']:.1f} ms |",
        f"| cache hit rate | {100 * r['cache_hit_rate']:.0f}% "
        f"({r['cache_hits']} hits / {r['cache_misses']} misses) |",
        f"| steady-state decode | {r['decode_tok_per_s']:.1f} tok/s |",
        f"| prefill throughput | {r['prefill_tok_per_s']:.1f} tok/s |",
        "\n(hit-path TTFT resumes the shared prefix from one O(1) "
        "state snapshot and prefills only the suffix — the gap vs cold "
        "p50 is the cache's whole value; interpret-mode numbers on CPU "
        "are not indicative — compare on TPU.)",
    ])


def render_spec():
    """§Speculative table from results/spec.json (benchmarks.run
    bench_spec): n-gram-drafted speculative decode vs plain block decode
    on the trained repetitive-text workload."""
    path = os.path.join(RESULTS, "spec.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        r = json.load(f)
    sh = r["shape"]
    out = [
        "\n### §Speculative — draft/verify/rollback vs plain decode "
        f"(backend={r['backend']}, {sh['model']}, {sh['workload']}, "
        f"slots={sh['slots']} gen={sh['gen_len']} "
        f"drafter={sh['drafter']})\n",
        f"plain block decode baseline: **{r['plain_tok_per_s']:.1f} "
        "tok/s**\n",
        "| k | tok/s | speedup | acceptance | rounds | rollback rounds |",
        "|---|---|---|---|---|---|",
    ]
    for e in r["entries"]:
        out.append(
            f"| {e['k']} | {e['tok_per_s']} | {e['speedup']}x | "
            f"{e['acceptance']} | {e['rounds']} | {e['rollback_rounds']} |"
        )
    out.append(
        "\n(speculative greedy output is asserted token-for-token equal "
        "to plain greedy; interpret-mode numbers on CPU are not "
        "indicative — compare on TPU.)"
    )
    return "\n".join(out)


def render_ops():
    """§Operator table from results/ops.json (benchmarks.run bench_ops):
    train-fwd and decode tok/s for every registered SequenceOp on the
    same reduced backbone — the registry-dispatch perf trajectory."""
    path = os.path.join(RESULTS, "ops.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        r = json.load(f)
    sh = r["shape"]
    out = [
        "\n### §Operator — per-SequenceOp throughput "
        f"(backend={r['backend']}, {sh['arch']}, B={sh['B']} n={sh['n']} "
        f"decode_steps={sh['decode_steps']})\n",
        "| op | train-fwd tok/s | decode tok/s | streaming | fused "
        "kernels | spec-decodable |",
        "|---|---|---|---|---|---|",
    ]
    for name, e in sorted(r["entries"].items()):
        flag = lambda b: "yes" if b else "no"  # noqa: E731
        out.append(
            f"| {name} | {e['train_fwd_tok_per_s']} | "
            f"{e['decode_tok_per_s']} | {flag(e['streaming'])} | "
            f"{flag(e['has_fused_kernels'])} | "
            f"{flag(e['spec_decodable'])} |"
        )
    out.append(
        "\n(all ops run the identical backbone through the SequenceOp "
        "registry — differences are the operators themselves plus any "
        "dispatch overhead; interpret-mode numbers on CPU are not "
        "indicative — compare on TPU.)"
    )
    return "\n".join(out)


def render_utilization():
    """§Utilization from results/ops.json (benchmarks.run bench_ops):
    achieved-vs-roofline for every registered SequenceOp — measured
    tok/s x analytic whole-model FLOPs/token (repro.obs.costs) against
    the device peak (repro.obs.perf.device_peak)."""
    path = os.path.join(RESULTS, "ops.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        r = json.load(f)
    peak = r.get("peak")
    if peak is None:  # pre-§15 ops.json artifact: no cost-model columns
        return None
    sh = r["shape"]
    out = [
        "\n### §Utilization — achieved vs roofline per SequenceOp "
        f"(backend={r['backend']}, {sh['arch']}, B={sh['B']} n={sh['n']}; "
        f"peak {peak['flops_per_s']/1e9:.0f} GFLOP/s / "
        f"{peak['bytes_per_s']/1e9:.0f} GB/s, {peak['source']} "
        f"[{peak['kind']}])\n",
        "| op | train tok/s | train GFLOP/s | train util | decode tok/s "
        "| decode GFLOP/s | decode util | state bytes |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for name, e in sorted(r["entries"].items()):
        if "train_util" not in e:
            continue
        tf = e["train_fwd_tok_per_s"] * e["train_flops_per_token"] / 1e9
        df = e["decode_tok_per_s"] * e["decode_flops_per_token"] / 1e9
        out.append(
            f"| {name} | {e['train_fwd_tok_per_s']:.0f} | {tf:.2f} | "
            f"{100 * e['train_util']:.1f}% ({e['train_bound']}) | "
            f"{e['decode_tok_per_s']:.0f} | {df:.2f} | "
            f"{100 * e['decode_util']:.1f}% ({e['decode_bound']}) | "
            f"{e['state_bytes']} |"
        )
    out.append(
        "\n(utilization = achieved FLOP/s or GB/s over the binding "
        "roofline resource; calibrated CPU ceilings are achievable-not-"
        "peak, so treat CPU percentages as relative — compare on TPU. "
        "The gap to 100% on non-fused ops is the fused-kernel ROADMAP "
        "headroom.)"
    )
    return "\n".join(out)


def render_trend(history_path):
    """§Trend from a repro.obs.bench/v1 history: latest run vs the one
    before it, through the perfcheck significance rule."""
    if not history_path or not os.path.exists(history_path):
        return None
    from repro.obs.perf import read_bench
    from repro.obs.perfcheck import compare_runs

    runs = read_bench(history_path)
    if len(runs) < 2:
        return None
    prev, last = runs[-2], runs[-1]
    cmp = compare_runs(prev, last)
    out = [
        "\n### §Trend — latest bench run vs previous "
        f"({prev['env'].get('git_sha')} -> {last['env'].get('git_sha')}, "
        f"{len(cmp['compared'])} shared rows)\n",
        "| row | previous | latest | ratio | trend |",
        "|---|---|---|---|---|",
    ]
    for c in sorted(cmp["compared"], key=lambda c: c["name"]):
        trend = ("**regressed**" if c["regressed"]
                 else "improved" if c["improved"] else "~")
        out.append(
            f"| {c['name']} | {c['old']:.4g} {c['unit']} | "
            f"{c['new']:.4g} {c['unit']} | x{c['ratio']:.2f} | {trend} |"
        )
    for name in cmp["only_new"]:
        out.append(f"| {name} | — | new | | |")
    out.append(
        "\n(trend = the perfcheck significance rule: a move must clear "
        "both the relative tolerance and the noise allowance from both "
        "runs' IQRs; `~` is within noise.)"
    )
    return "\n".join(out)


def render_distributed():
    """§Distributed table from results/distributed.json (benchmarks.run
    bench_distributed): per-device train tok/s, 1 -> 8 host devices."""
    path = os.path.join(RESULTS, "distributed.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        r = json.load(f)
    sh = r["shape"]
    out = [
        "\n### §Distributed — sharded train step scaling "
        f"({sh['arch']}, B={sh['B']} n={sh['n']}, host-device mesh)\n",
        "| devices | steps/s | tok/s | tok/s/device |",
        "|---|---|---|---|",
    ]
    for e in r["entries"]:
        out.append(
            f"| {e['devices']} | {e['steps_per_s']} | {e['tok_per_s']} | "
            f"{e['tok_per_s_per_device']} |"
        )
    ents = {e["devices"]: e for e in r["entries"]}
    if 1 in ents and 8 in ents:
        eff = ents[8]["tok_per_s"] / max(ents[1]["tok_per_s"], 1e-9) / 8
        out.append(
            f"\n8-device scaling efficiency: **{100 * eff:.0f}%** (host "
            "devices share one CPU, so this tracks sharding/collective "
            "overhead, not real speedup — compare on TPU)"
        )
    return "\n".join(out)


def render(rows):
    out = []
    out.append("### §Dry-run — compile results (every arch x shape x mesh)\n")
    out.append(
        "| cell | mesh | mixer | mb | compile (s) | peak GiB/dev | "
        "AG / AR / A2A / CP (count) |"
    )
    out.append("|---|---|---|---|---|---|---|")
    for r in rows:
        cc = r["collectives"]["counts"]
        mesh = "x".join(str(v) for v in r["mesh"].values())
        out.append(
            f"| {r['arch']} x {r['shape']} | {mesh} | {r['mixer']} | "
            f"{r.get('microbatches', 1)} | {r['compile_s']} | "
            f"{r['memory']['peak_bytes']/2**30:.2f} | "
            f"{cc['all-gather']} / {cc['all-reduce']} / {cc['all-to-all']} / "
            f"{cc['collective-permute']} |"
        )

    out.append("\n### §Roofline — per-device terms (single-pod 16x16)\n")
    out.append(
        "| cell | compute (s) | memory (s) | collective (s) | bottleneck | "
        "MODEL_FLOPS/HLO_FLOPs | next lever |"
    )
    out.append("|---|---|---|---|---|---|---|")
    for r in rows:
        if "pod" in r["mesh"]:
            continue  # roofline table is single-pod per the assignment
        rf = r["roofline"]
        try:
            mf, total, active = model_flops(
                r["arch"], r["shape"], r["mixer"], r["devices"]
            )
            ratio = f"{mf / max(r['cost']['flops'], 1):.2f}"
        except Exception:
            ratio = "n/a"
        out.append(
            f"| {r['arch']} x {r['shape']} | {rf['compute_s']:.2f} | "
            f"{rf['memory_s']:.2f} | {rf['collective_s']:.2f} | "
            f"{rf['bottleneck'].replace('_s','')} | {ratio} | "
            f"{_SUGGEST[rf['bottleneck']][:60]}... |"
        )
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--md", default=None)
    ap.add_argument("--history", default=os.path.join(RESULTS,
                                                      "history.jsonl"),
                    help="repro.obs.bench/v1 history for the §Trend "
                         "section (default results/history.jsonl)")
    args = ap.parse_args()
    rows = load_results()
    text = render(rows)
    for section in (
        render_train_step(),
        render_serving(),
        render_spec(),
        render_ops(),
        render_utilization(),
        render_trend(args.history),
        render_distributed(),
    ):
        if section:
            text = text + "\n" + section
    print(text)
    if args.md:
        with open(args.md, "w") as f:
            f.write(text + "\n")


if __name__ == "__main__":
    main()
