"""Checkpointing: atomic, async, rotation, elastic restore.

Format: a directory per step with one ``.npy`` per pytree leaf plus a
``manifest.json`` (step, leaf paths/shapes/dtypes, user metadata).  Writes
go to ``<dir>.tmp`` then a single atomic ``os.rename`` — a crash mid-save
never corrupts the latest checkpoint.  Restore is *mesh-agnostic*: leaves
are saved as full logical arrays and re-placed with whatever shardings the
new mesh prescribes (elastic rescale).  On a real multi-host pod each
process would write its addressable shards with offsets; the manifest
format already records shapes/dtypes so that extension is local to
``_save_leaf``/``_load_leaf`` (documented production note).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif hasattr(tree, "_fields"):  # NamedTuple
        for k in tree._fields:
            out.update(_flatten(getattr(tree, k), f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix.rstrip("/")] = tree
    return out


def _unflatten_into(template, flat, prefix=""):
    if isinstance(template, dict):
        return {
            k: _unflatten_into(template[k], flat, f"{prefix}{k}/")
            for k in template
        }
    if hasattr(template, "_fields"):
        return type(template)(
            *(
                _unflatten_into(getattr(template, k), flat, f"{prefix}{k}/")
                for k in template._fields
            )
        )
    if isinstance(template, (list, tuple)):
        return type(template)(
            _unflatten_into(v, flat, f"{prefix}{i}/")
            for i, v in enumerate(template)
        )
    return flat[prefix.rstrip("/")]


def save_checkpoint(directory: str, step: int, tree, metadata: Optional[dict] = None):
    """Atomic save of an arbitrary pytree of arrays."""
    path = os.path.join(directory, f"step_{step:08d}")
    tmp = path + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(tree)
    manifest = {"step": step, "time": time.time(), "metadata": metadata or {},
                "leaves": {}}
    for i, (name, leaf) in enumerate(flat.items()):
        if leaf is None:
            manifest["leaves"][name] = {"file": None}
            continue
        arr = np.asarray(jax.device_get(leaf))
        fn = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fn), arr)
        manifest["leaves"][name] = {
            "file": fn, "shape": list(arr.shape), "dtype": str(arr.dtype),
        }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(tmp, path)  # atomic publish
    return path


def list_steps(directory: str):
    if not os.path.isdir(directory):
        return []
    steps = []
    for d in os.listdir(directory):
        if d.startswith("step_") and not d.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, d, "manifest.json")):
                steps.append(int(d.split("_")[1]))
    return sorted(steps)


def latest_step(directory: str) -> Optional[int]:
    steps = list_steps(directory)
    return steps[-1] if steps else None


def restore_checkpoint(
    directory: str, template, step: Optional[int] = None,
    shardings=None,
):
    """Restore into ``template``'s structure.  ``shardings`` (optional
    matching pytree of NamedSharding) re-places leaves for the *current*
    mesh — elastic restore onto a different device count."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    flat_shard = _flatten(shardings) if shardings is not None else {}
    flat = {}
    for name, info in manifest["leaves"].items():
        if info["file"] is None:
            flat[name] = None
            continue
        arr = np.load(os.path.join(path, info["file"]))
        sh = flat_shard.get(name)
        flat[name] = jax.device_put(arr, sh) if sh is not None else jax.numpy.asarray(arr)
    return _unflatten_into(template, flat), manifest


class CheckpointManager:
    """keep-N rotation + optional async save thread."""

    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, tree, metadata=None, block: bool = False):
        self.wait()  # one in-flight save at a time
        tree = jax.tree.map(
            lambda x: np.asarray(jax.device_get(x)), tree,
            is_leaf=lambda x: x is None,
        )
        tree = jax.tree.map(
            lambda x: None if x is None or x.dtype == object else x, tree,
            is_leaf=lambda x: x is None,
        )

        def _work():
            save_checkpoint(self.directory, step, tree, metadata)
            self._rotate()

        if self.async_save and not block:
            self._thread = threading.Thread(target=_work, daemon=False)
            self._thread.start()
        else:
            _work()

    def _rotate(self):
        steps = list_steps(self.directory)
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    def restore(self, template, shardings=None, step=None):
        return restore_checkpoint(
            self.directory, template, step=step, shardings=shardings
        )

    def latest_step(self):
        return latest_step(self.directory)
