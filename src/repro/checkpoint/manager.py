"""Checkpointing: atomic, async, rotation, elastic restore.

Format: a directory per step with one ``.npy`` per pytree leaf plus a
``manifest.json`` (step, leaf paths/shapes/dtypes/crc32, user metadata).
Writes go to ``<dir>.tmp`` then a single atomic ``os.rename`` — a crash
mid-save never corrupts the latest checkpoint.  Restore is
*mesh-agnostic*: leaves are saved as full logical arrays and re-placed
with whatever shardings the new mesh prescribes (elastic rescale).  On a
real multi-host pod each process would write its addressable shards with
offsets; the manifest format already records shapes/dtypes so that
extension is local to ``_save_leaf``/``_load_leaf`` (documented
production note).

Failure domains (DESIGN.md §12):

* every leaf's manifest entry carries a crc32 of its raw bytes, verified
  on restore — silent storage corruption fails loudly as
  ``CheckpointError`` naming the damaged leaf instead of resuming
  training from garbage (atomic rename only protects against *torn*
  saves, not against bit rot after publish);
* the async save thread never swallows exceptions: a failed background
  save is captured and re-raised as ``CheckpointError`` from the next
  ``wait()`` or ``save()``, so the training loop finds out at the
  checkpoint cadence rather than discovering a missing checkpoint at
  restore time;
* ``CheckpointManager(faults=...)`` consumes the ``ckpt.save`` /
  ``ckpt.corrupt`` points of ``runtime.faults`` for deterministic
  chaos tests of both paths.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
import zlib
from typing import Any, Optional

import jax
import numpy as np

from ..obs import Obs


class CheckpointError(RuntimeError):
    """A checkpoint operation failed: an async save raised (surfaced on
    the next ``wait()``/``save()``) or a restore hit a checksum
    mismatch."""


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif hasattr(tree, "_fields"):  # NamedTuple
        for k in tree._fields:
            out.update(_flatten(getattr(tree, k), f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix.rstrip("/")] = tree
    return out


def _unflatten_into(template, flat, prefix=""):
    if isinstance(template, dict):
        return {
            k: _unflatten_into(template[k], flat, f"{prefix}{k}/")
            for k in template
        }
    if hasattr(template, "_fields"):
        return type(template)(
            *(
                _unflatten_into(getattr(template, k), flat, f"{prefix}{k}/")
                for k in template._fields
            )
        )
    if isinstance(template, (list, tuple)):
        return type(template)(
            _unflatten_into(v, flat, f"{prefix}{i}/")
            for i, v in enumerate(template)
        )
    return flat[prefix.rstrip("/")]


def save_checkpoint(directory: str, step: int, tree, metadata: Optional[dict] = None):
    """Atomic save of an arbitrary pytree of arrays."""
    path = os.path.join(directory, f"step_{step:08d}")
    tmp = path + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(tree)
    manifest = {"step": step, "time": time.time(), "metadata": metadata or {},
                "leaves": {}}
    for i, (name, leaf) in enumerate(flat.items()):
        if leaf is None:
            manifest["leaves"][name] = {"file": None}
            continue
        arr = np.asarray(jax.device_get(leaf))
        fn = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fn), arr)
        manifest["leaves"][name] = {
            "file": fn, "shape": list(arr.shape), "dtype": str(arr.dtype),
            # content checksum over the raw array bytes (not the .npy
            # header): restore verifies it so bit rot fails loudly
            "crc32": zlib.crc32(arr.tobytes()),
        }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(tmp, path)  # atomic publish
    return path


def list_steps(directory: str):
    if not os.path.isdir(directory):
        return []
    steps = []
    for d in os.listdir(directory):
        if d.startswith("step_") and not d.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, d, "manifest.json")):
                steps.append(int(d.split("_")[1]))
    return sorted(steps)


def latest_step(directory: str) -> Optional[int]:
    steps = list_steps(directory)
    return steps[-1] if steps else None


def restore_checkpoint(
    directory: str, template, step: Optional[int] = None,
    shardings=None,
):
    """Restore into ``template``'s structure.  ``shardings`` (optional
    matching pytree of NamedSharding) re-places leaves for the *current*
    mesh — elastic restore onto a different device count."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    flat_shard = _flatten(shardings) if shardings is not None else {}
    flat = {}
    for name, info in manifest["leaves"].items():
        if info["file"] is None:
            flat[name] = None
            continue
        arr = np.load(os.path.join(path, info["file"]))
        want = info.get("crc32")  # absent on pre-checksum checkpoints
        if want is not None:
            got = zlib.crc32(arr.tobytes())
            if got != want:
                raise CheckpointError(
                    f"checksum mismatch for leaf {name!r} in {path} "
                    f"(manifest crc32={want}, file crc32={got}): "
                    "checkpoint is corrupt"
                )
        sh = flat_shard.get(name)
        flat[name] = jax.device_put(arr, sh) if sh is not None else jax.numpy.asarray(arr)
    return _unflatten_into(template, flat), manifest


def _corrupt_leaf(path: str) -> None:
    """Flip trailing DATA bytes of the first leaf file under ``path`` (the
    ``ckpt.corrupt`` fault point).  Trailing bytes so the ~128-byte .npy
    header survives and the damage is only detectable by checksum —
    exactly the silent-bit-rot scenario the manifest crc32 guards."""
    leaves = sorted(
        f for f in os.listdir(path) if f.endswith(".npy")
    )
    if not leaves:
        return
    fn = os.path.join(path, leaves[0])
    size = os.path.getsize(fn)
    n = min(8, max(size - 80, 1))
    with open(fn, "r+b") as f:
        f.seek(size - n)
        tail = f.read(n)
        f.seek(size - n)
        f.write(bytes(b ^ 0xFF for b in tail))


class CheckpointManager:
    """keep-N rotation + optional async save thread.

    Async failures are never silent: an exception in the save thread is
    captured and re-raised as ``CheckpointError`` from the next
    ``wait()`` (and hence the next ``save()``, which waits first).
    """

    def __init__(self, directory: str, keep: int = 3, async_save: bool = True,
                 faults=None, obs: Optional[Obs] = None):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self.faults = faults  # runtime.faults.FaultPlan (ckpt.* points)
        # durations observed from the async thread ride the registry's
        # lock; ckpt.save spans record on the thread that runs the save
        self.obs = obs if obs is not None else Obs()
        if faults is not None and getattr(faults, "obs", None) is None:
            faults.obs = self.obs
        self._m_save_s = self.obs.histogram(
            "ckpt_save_seconds", "wall-clock per checkpoint save")
        self._m_restore_s = self.obs.histogram(
            "ckpt_restore_seconds", "wall-clock per checkpoint restore")
        self._m_saves = self.obs.counter(
            "ckpt_saves_total", "published checkpoints")
        self._m_save_fail = self.obs.counter(
            "ckpt_save_failures_total", "saves that raised")
        self._m_restores = self.obs.counter(
            "ckpt_restores_total", "successful restores")
        self._m_crc_fail = self.obs.counter(
            "ckpt_checksum_failures_total",
            "restores rejected on a leaf crc32 mismatch")
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[tuple] = None  # (step, exception)
        os.makedirs(directory, exist_ok=True)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            step, exc = self._error
            self._error = None  # raise-and-clear: the manager stays usable
            raise CheckpointError(
                f"async checkpoint save for step {step} failed: {exc!r}"
            ) from exc

    def save(self, step: int, tree, metadata=None, block: bool = False):
        self.wait()  # one in-flight save at a time; surfaces prior failure
        tree = jax.tree.map(
            lambda x: np.asarray(jax.device_get(x)), tree,
            is_leaf=lambda x: x is None,
        )
        tree = jax.tree.map(
            lambda x: None if x is None or x.dtype == object else x, tree,
            is_leaf=lambda x: x is None,
        )

        def _work():
            try:
                with self.obs.span("ckpt.save", step=step):
                    t0 = time.perf_counter()
                    if self.faults is not None:
                        self.faults.raise_if("ckpt.save")
                    path = save_checkpoint(
                        self.directory, step, tree, metadata
                    )
                    self._rotate()
                    if self.faults is not None and \
                            self.faults.hit("ckpt.corrupt") is not None:
                        _corrupt_leaf(path)
            except Exception:
                self._m_save_fail.inc()
                raise
            self._m_saves.inc()
            self._m_save_s.observe(time.perf_counter() - t0)

        if self.async_save and not block:

            def _work_async():
                try:
                    _work()
                except Exception as e:  # surfaced by the next wait()
                    self._error = (step, e)

            self._thread = threading.Thread(target=_work_async, daemon=False)
            self._thread.start()
        else:
            _work()

    def _rotate(self):
        steps = list_steps(self.directory)
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    def restore(self, template, shardings=None, step=None):
        t0 = time.perf_counter()
        try:
            with self.obs.span("ckpt.restore", step=step):
                out = restore_checkpoint(
                    self.directory, template, step=step, shardings=shardings
                )
        except CheckpointError as e:
            if "checksum mismatch" in str(e):
                self._m_crc_fail.inc()
            raise
        self._m_restores.inc()
        self._m_restore_s.observe(time.perf_counter() - t0)
        return out

    def latest_step(self):
        return latest_step(self.directory)
