import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count="
    + os.environ.get("DRYRUN_DEVICES", "512")
    + " " + os.environ.get("XLA_FLAGS_EXTRA", "")
)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware:

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-72b \
        --shape train_4k [--multi-pod] [--json out.json]

Per the contract, the XLA_FLAGS line above is the FIRST statement — before
any other import — since jax locks the device count on first init.  Set
DRYRUN_DEVICES=8 (with --mesh 2x4) for the reduced CI variant.

Outputs: memory_analysis (fits / per-device bytes), cost_analysis
(FLOPs / bytes for §Roofline), and the collective-bytes breakdown parsed
from the compiled HLO (all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute).
"""

import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from ..analysis.hlo_analysis import analyze as hlo_analyze  # noqa: E402
from ..configs import get_config  # noqa: E402
from ..distributed import steps as steps_mod  # noqa: E402
from ..models.config import get_shape  # noqa: E402
from ..optim import adamw  # noqa: E402
from .mesh import make_mesh, make_production_mesh  # noqa: E402

# v5e-class hardware constants for §Roofline
PEAK_FLOPS = 197e12  # bf16 FLOP/s per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link (~per chip for ring collectives)

_DTYPE_BYTES = {
    "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1, "u8": 1,
    "pred": 1, "s64": 8, "u64": 8, "f64": 8, "s16": 2, "u16": 2, "f8e4m3": 1,
    "f8e5m2": 1,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)


def parse_collective_bytes(hlo_text: str):
    """Sum output-shape bytes of every collective op in the (SPMD) HLO.

    Operand sizes ~ output sizes for these ops (all-gather outputs are the
    gathered size — the honest wire-bytes upper bound per device).
    """
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    shape_re = re.compile(r"(\w+)\[([\d,]*)\]")
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*)", s)
        if not m:
            continue
        rhs = m.group(1)
        op = None
        for c in _COLLECTIVES:
            if re.search(rf"\b{c}(?:-start|-done)?\(", rhs):
                op = c
                break
        if op is None:
            continue
        if re.search(rf"\b{op}-done\(", rhs):
            continue  # counted at -start
        # output shape(s) appear before the op name: "bf16[8,128]{...} all-..."
        head = rhs.split(op)[0]
        nbytes = 0
        for dt, dims in shape_re.findall(head):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        out[op] += nbytes
        counts[op] += 1
    return out, counts


def lower_cell(arch, shape_name, mesh, *, mixer=None, microbatches=1,
               zero1=True, long_ctx_note=None, hla_impl=None, hla_chunk=None,
               gather_dtype=None):
    """Lower + compile one cell.  Returns a result dict for §Dry-run."""
    import dataclasses

    shape_cfg = get_shape(shape_name)
    cfg = get_config(arch, mixer=mixer)
    note = long_ctx_note or ""
    if shape_cfg.name == "long_500k" and cfg.mixer == "softmax" and mixer is None:
        # pure full attention at 524k is infeasible (DESIGN.md §5):
        # run the cell with the paper's HLA2 mixer swapped in.
        cfg = get_config(arch, mixer="hla2")
        note = "HLA2 mixer drop-in (O(1)-state decode); native softmax skipped by design"
    if hla_impl or hla_chunk:
        hla = dataclasses.replace(
            cfg.hla,
            **({"impl": hla_impl} if hla_impl else {}),
            **({"chunk": hla_chunk} if hla_chunk else {}),
        )
        cfg = cfg.replace(hla=hla)
        note = (note + f" hla_impl={hla.impl} chunk={hla.chunk}").strip()
    if gather_dtype:
        cfg = cfg.replace(gather_dtype=gather_dtype)
        note = (note + f" gather_dtype={gather_dtype}").strip()

    with mesh:
        t0 = time.time()
        if shape_cfg.kind == "train":
            specs = steps_mod.model_specs(cfg)
            from ..distributed import sharding as shd

            gshard = shd.param_shardings(specs, mesh)
            step = steps_mod.make_train_step(
                cfg, adamw.OptConfig(), microbatches=microbatches,
                grad_shardings=gshard,
            )
            params, opt_state = steps_mod.abstract_train_args(
                cfg, mesh, zero1=zero1
            )
            batch = steps_mod.input_specs(cfg, shape_cfg, mesh)
            lowered = jax.jit(step).lower(params, opt_state, batch)
        elif shape_cfg.kind == "prefill":
            step = steps_mod.make_prefill_step(cfg)
            params, _ = steps_mod.abstract_train_args(cfg, mesh, zero1=False)
            batch = steps_mod.input_specs(cfg, shape_cfg, mesh)
            lowered = jax.jit(step).lower(params, batch)
        else:  # decode
            step = steps_mod.make_serve_step(cfg)
            params, _ = steps_mod.abstract_train_args(cfg, mesh, zero1=False)
            spec = steps_mod.input_specs(cfg, shape_cfg, mesh)
            lowered = jax.jit(step).lower(params, spec["batch"], spec["states"])
        t_lower = time.time() - t0

        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    # jax version drift: cost_analysis() is a per-device list of dicts on
    # some releases and a flat dict on others
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    # loop-aware per-device account (cost_analysis counts while bodies ONCE
    # — see repro/analysis/hlo_analysis.py); raw numbers kept alongside.
    la = hlo_analyze(hlo)

    n_dev = mesh.devices.size
    flops = float(la["flops"])
    bytes_accessed = float(la["bytes"])
    coll_bytes = {k: int(v) for k, v in la["collective_bytes"].items()}
    coll_counts = la["collective_counts"]
    per_dev_coll = float(la["collective_total"])

    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": dict(zip(mesh.axis_names, (int(s) for s in mesh.devices.shape))),
        "devices": int(n_dev),
        "mixer": cfg.mixer,
        "note": note,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "peak_bytes": int(
                getattr(mem, "temp_size_in_bytes", 0)
                + getattr(mem, "argument_size_in_bytes", 0)
                + getattr(mem, "output_size_in_bytes", 0)
            ),
        },
        "cost": {
            "flops": flops,
            "bytes_accessed": bytes_accessed,
            "raw_cost_analysis": {
                "flops": float(cost.get("flops", 0.0)),
                "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
            },
        },
        "collectives": {"bytes": coll_bytes, "counts": coll_counts},
        "roofline": {
            "compute_s": flops / PEAK_FLOPS,
            "memory_s": bytes_accessed / HBM_BW,
            "collective_s": per_dev_coll / ICI_BW,
        },
    }
    terms = result["roofline"]
    result["roofline"]["bottleneck"] = max(
        ("compute_s", "memory_s", "collective_s"), key=lambda k: terms[k]
    )
    return result


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--mesh", default=None, help="e.g. 2x4 (reduced CI)")
    ap.add_argument("--mixer", default=None, help="HLA mixer override")
    ap.add_argument("--hla-impl", default=None,
                    help="chunkwise | scan (paper-faithful baseline)")
    ap.add_argument("--hla-chunk", type=int, default=None)
    ap.add_argument("--gather-dtype", default=None,
                    help="bfloat16 halves FSDP gather bytes (§Perf lever A)")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--no-zero1", action="store_true")
    ap.add_argument("--json", default=None, help="write result JSON here")
    args = ap.parse_args(argv)

    if args.mesh:
        dims = tuple(int(x) for x in args.mesh.split("x"))
        axes = ("pod", "data", "model")[-len(dims):] if len(dims) == 3 else (
            "data", "model")
        mesh = make_mesh(dims, axes)
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod)

    res = lower_cell(
        args.arch, args.shape, mesh, mixer=args.mixer,
        microbatches=args.microbatches, zero1=not args.no_zero1,
        hla_impl=args.hla_impl, hla_chunk=args.hla_chunk,
        gather_dtype=args.gather_dtype,
    )
    print(json.dumps(res, indent=2))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(res, f, indent=2)
    # prove-it prints required by the deliverable:
    print(
        f"[dryrun] {args.arch} x {args.shape} on {res['mesh']}: "
        f"compile OK in {res['compile_s']}s; "
        f"peak {res['memory']['peak_bytes']/2**30:.2f} GiB/device; "
        f"bottleneck {res['roofline']['bottleneck']}",
        file=sys.stderr,
    )
    return res


if __name__ == "__main__":
    main()
