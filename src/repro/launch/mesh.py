"""Mesh construction.  Functions only — importing this module never touches
jax device state (required by the dry-run contract)."""

from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """The target deployment mesh: 16x16 chips per pod, 2 pods multi-pod.

    DP over ("pod", "data"), TP over "model".  Requires 256 / 512 devices
    (real chips, or host placeholders via
    XLA_FLAGS=--xla_force_host_platform_device_count=...).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape=None, axes=None):
    """General mesh helper (tests / small runs).

    Defaults: all available devices on a ("data", "model") mesh with the
    model axis as large as possible up to 4 (elastic-friendly: recomputed
    from whatever devices exist at launch).
    """
    n = len(jax.devices())
    if shape is None:
        model = 1
        for cand in (4, 2, 1):
            if n % cand == 0:
                model = cand
                break
        shape = (n // model, model)
        axes = ("data", "model")
    return jax.make_mesh(tuple(shape), tuple(axes))


def mesh_summary(mesh) -> str:
    return f"mesh{dict(zip(mesh.axis_names, mesh.devices.shape))}"


# XLA flags recommended for the real-TPU launch (documented here; the
# launcher exports them).  Collective/compute overlap knobs:
TPU_XLA_FLAGS = " ".join(
    [
        "--xla_enable_async_collective_permute=true",
        "--xla_tpu_enable_async_collective_fusion=true",
        "--xla_tpu_enable_async_collective_fusion_fuse_all_gather=true",
        "--xla_tpu_overlap_compute_collective_tc=true",
        "--xla_tpu_data_parallel_opt_different_sized_ops=true",
    ]
)
