"""Batched serving loop: continuous-batching-lite over fixed slots.

HLA/SSM archs decode from O(1) state (the paper's Fig. 1(A) recurrence);
softmax archs from a KV cache.  Requests (prompt token lists) are admitted
into free slots, prefilled, then decoded step-locked with the running
batch; finished slots are recycled without stopping the batch — the
serving pattern that matters at scale, exercised here with synthetic
prompts.

    PYTHONPATH=src python -m repro.launch.serve --arch hla-1b --reduced \
        --slots 4 --requests 8 --gen-len 32
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..models import lm
from ..models.param import init_params
from .mesh import make_mesh


class Server:
    def __init__(self, cfg, params, slots: int, max_len: int):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.states = lm.lm_init_states(cfg, slots, max_len)
        self.positions = jnp.zeros((slots, 1), jnp.int32)
        self.active = np.zeros(slots, bool)
        self.tokens = jnp.ones((slots, 1), jnp.int32)
        self.outputs = [[] for _ in range(slots)]

        self._decode = jax.jit(
            lambda p, t, s, pos: lm.lm_apply(
                p, t, cfg, states=s, positions=pos, mode="decode"
            )[:2]
        )

    def admit(self, slot: int, prompt: np.ndarray):
        """Prefill one slot by streaming the prompt through decode steps.

        Other slots' states are snapshot-restored afterwards: the batched
        decode used for admission must not advance live requests (a real
        bug caught by tests/test_serving.py)."""
        self.active[slot] = True
        self.outputs[slot] = []
        snapshot = self.states

        # reset this slot's state: zero it via tree surgery
        def reset(leaf):
            return leaf.at[:, slot].set(0) if leaf.ndim >= 2 else leaf

        self.states = jax.tree.map(reset, self.states)
        pos = 0
        for t in prompt:
            tok = self.tokens.at[slot, 0].set(int(t))
            posv = self.positions.at[slot, 0].set(pos)
            logits, self.states = self._decode(
                self.params, tok, self.states, posv
            )
            self.tokens = tok
            self.positions = posv
            pos += 1
        self.positions = self.positions.at[slot, 0].set(pos)

        # keep the admitted slot's fresh state; restore everyone else
        def merge(new, old):
            if new.ndim >= 2 and new.shape[1] == self.slots:
                return old.at[:, slot].set(new[:, slot])
            return new

        self.states = jax.tree.map(merge, self.states, snapshot)

    def step(self):
        logits, self.states = self._decode(
            self.params, self.tokens, self.states, self.positions
        )
        nxt = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        self.tokens = nxt
        self.positions = self.positions + 1
        for s in range(self.slots):
            if self.active[s]:
                self.outputs[s].append(int(nxt[s, 0]))
        return nxt


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="hla-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced)
    mesh = make_mesh()
    rng = np.random.RandomState(args.seed)
    with mesh:
        specs = lm.lm_specs(cfg)
        params = init_params(specs, jax.random.key(args.seed))
        srv = Server(cfg, params, args.slots,
                     args.prompt_len + args.gen_len + 8)

        pending = [
            rng.randint(2, cfg.vocab, size=args.prompt_len)
            for _ in range(args.requests)
        ]
        done = 0
        gen_counts = np.zeros(args.slots, int)
        t0 = time.time()
        toks = 0
        while done < args.requests or srv.active.any():
            for s in range(args.slots):
                if not srv.active[s] and pending:
                    srv.admit(s, pending.pop())
                    gen_counts[s] = 0
            srv.step()
            toks += int(srv.active.sum())
            for s in range(args.slots):
                if srv.active[s]:
                    gen_counts[s] += 1
                    if gen_counts[s] >= args.gen_len:
                        srv.active[s] = False
                        done += 1
        dt = time.time() - t0
        print(
            f"[serve] {done} requests, {toks} tokens in {dt:.2f}s "
            f"({toks/dt:.1f} tok/s, state-based decode)"
        )
    return done


if __name__ == "__main__":
    main()
