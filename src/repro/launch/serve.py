"""Serving CLI — a thin shell over ``repro.serving.Engine``.

Continuous batching over fixed slots with chunk-parallel prefill
admission, step-locked block decode, and device-side sampling
(DESIGN.md §8).  Synthetic prompts stand in for traffic.

    PYTHONPATH=src python -m repro.launch.serve --arch hla-1b --reduced \
        --slots 4 --requests 8 --gen-len 32 --block 8 --sampling greedy
"""

import argparse
import time

import jax
import numpy as np

from ..configs import get_config
from ..models import lm
from ..models.param import init_params
from ..serving import Engine, GenRequest, SamplingConfig
from .mesh import make_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="hla-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--block", type=int, default=8)
    ap.add_argument("--sampling", default="greedy",
                    choices=["greedy", "temperature", "top_k"])
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--top-k", type=int, default=40)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced)
    mesh = make_mesh()
    rng = np.random.RandomState(args.seed)
    with mesh:
        params = init_params(lm.lm_specs(cfg), jax.random.key(args.seed))
        engine = Engine(
            cfg, params,
            slots=args.slots,
            max_len=args.prompt_len + args.gen_len + 8,
            sampling=SamplingConfig(
                method=args.sampling, temperature=args.temperature,
                top_k=args.top_k,
            ),
            block=args.block,
            seed=args.seed,
        )
        requests = [
            GenRequest(
                rid=i,
                prompt=rng.randint(2, cfg.vocab, size=args.prompt_len),
                max_new=args.gen_len,
            )
            for i in range(args.requests)
        ]
        # warm the prefill/decode jits so TTFT and tok/s measure steady
        # state, not trace+compile (same protocol as benchmarks.run)
        engine.run([GenRequest(
            rid=-1, prompt=requests[0].prompt, max_new=args.block,
        )])
        engine.stats.update(
            prefill_s=0.0, decode_s=0.0, prompt_tokens=0,
            generated_tokens=0, ttft_s=[],
        )
        t0 = time.time()
        results = engine.run(requests)
        dt = time.time() - t0
        st = engine.stats
        gen = st["generated_tokens"]
        # each request's first token comes from the prefill call; count only
        # decode-block tokens against decode wall time
        decode_toks = gen - len(results)
        ttft_ms = 1e3 * float(np.mean(st["ttft_s"])) if st["ttft_s"] else 0.0
        decode_tps = decode_toks / st["decode_s"] if st["decode_s"] else 0.0
        print(
            f"[serve] {len(results)} requests, {gen} generated tokens in "
            f"{dt:.2f}s | TTFT {ttft_ms:.1f}ms mean | "
            f"decode {decode_tps:.1f} tok/s | "
            f"prefill {st['prompt_tokens']/max(st['prefill_s'],1e-9):.1f} tok/s"
        )
    return len(results)


if __name__ == "__main__":
    main()
