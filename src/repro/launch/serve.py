"""Serving CLI — a thin shell over ``repro.serving.Engine``.

Continuous batching over fixed slots with chunk-parallel prefill
admission, step-locked block decode, and device-side sampling
(DESIGN.md §8).  Synthetic prompts stand in for traffic.

    PYTHONPATH=src python -m repro.launch.serve --arch hla-1b --reduced \
        --slots 4 --requests 8 --gen-len 32 --block 8 --sampling greedy

``--spec ngram|lm`` turns on speculative decoding (DESIGN.md §10): the
drafter proposes ``--spec-k`` tokens per round, one chunk-parallel verify
call scores them, rejections roll back via state snapshots.  ``--spec lm``
drafts with a small HLA LM loaded from the ``--draft-arch`` registry entry
(random weights here — the CLI has no trained draft checkpoint).

``--inject point[@at[+]][:arg]`` (repeatable) schedules deterministic
faults from the ``runtime.faults`` catalog — e.g.
``--inject engine.nan_state@1:0`` poisons slot 0's state before the 2nd
decode block (quarantine), ``--inject drafter.propose@0+`` crashes the
drafter every round (circuit breaker -> plain fallback).  ``--deadline-s``
gives every request a wall-clock budget; expired requests finish with
``status="timeout"``.  The summary line counts terminal statuses.

``HOST_DEVICES=N`` simulates an N-device host mesh (like launch.train);
params and slot states then come up sharded via the same
``distributed.sharding`` / ``distributed.steps`` source of truth the
trainer uses.
"""

import os

# must run at import, before jax initializes its backend: XLA locks the
# host device count on first use (same contract as launch/train.py)
_hd = os.environ.get("HOST_DEVICES")
if _hd:
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={_hd} "
        + os.environ.get("XLA_FLAGS", "")
    )

import argparse  # noqa: E402
import collections  # noqa: E402
import functools  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402

from ..configs import get_config  # noqa: E402
from ..distributed import sharding as shd  # noqa: E402
from ..models import lm  # noqa: E402
from ..models.param import init_params  # noqa: E402
from ..obs import JsonlSink, Obs, profile_capture, write_metrics  # noqa: E402
from ..runtime.faults import FaultPlan, parse_fault  # noqa: E402
from ..serving import (  # noqa: E402
    Engine,
    GenRequest,
    PrefixCache,
    SamplingConfig,
    SpecConfig,
)
from .mesh import make_mesh, mesh_summary  # noqa: E402


def _run_streaming(engine, requests):
    """Serve through the asyncio front-end: every request submitted
    concurrently, each stream consumed by its own task, graceful drain
    on exit.  Results come back in request order (same contract as
    ``engine.run``)."""
    import asyncio

    from ..serving.server import AsyncServer, collect

    async def _main():
        async with AsyncServer(engine) as srv:
            outs = await asyncio.gather(*[collect(srv, r)
                                          for r in requests])
        return [res for _, res in outs]

    return asyncio.run(_main())


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="hla-1b")
    ap.add_argument("--mixer", default=None,
                    help="override the arch's sequence op with any "
                         "registered SequenceOp (e.g. gla, ahla, linattn; "
                         "DESIGN.md §11) — the engine gates on the op's "
                         "streaming capability flag")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--block", type=int, default=8)
    ap.add_argument("--sampling", default="greedy",
                    choices=["greedy", "temperature", "top_k", "top_p"])
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--top-k", type=int, default=40)
    ap.add_argument("--top-p", type=float, default=0.9)
    ap.add_argument("--spec", default="off",
                    choices=["off", "ngram", "lm"],
                    help="speculative decoding drafter (off = plain blocks)")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="draft tokens per speculative round")
    ap.add_argument("--draft-arch", default="hla-1b",
                    help="configs entry for the --spec lm draft model "
                         "(loaded reduced)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--stream", action="store_true",
                    help="serve through the asyncio streaming front-end "
                         "(serving.server.AsyncServer): per-token async "
                         "generators, backpressure, graceful drain")
    ap.add_argument("--cache-mb", type=float, default=0.0,
                    help="prefix/state cache budget in MiB (0 = no "
                         "cache); cache hits resume admission from an "
                         "O(1) state snapshot (DESIGN.md §16)")
    ap.add_argument("--cache-chunk", type=int, default=0,
                    help="cache key granularity in tokens (0 = the "
                         "model's chunk width)")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="tokens shared by every synthetic prompt — "
                         "nonzero exercises prefix-cache hits")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="per-request wall-clock budget; expiry -> "
                         "status=timeout with the partial stream")
    ap.add_argument("--inject", action="append", default=[],
                    metavar="POINT[@AT[+]][:ARG]",
                    help="schedule a deterministic fault "
                         "(runtime.faults catalog; repeatable)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="dump the final metrics registry snapshot "
                         "(repro.obs.metrics/v1 JSON) on exit")
    ap.add_argument("--events-out", default=None, metavar="PATH",
                    help="stream span/event records (repro.obs.events/v1 "
                         "JSONL) for the measured run — request "
                         "lifecycles, decode blocks, fired faults")
    ap.add_argument("--profile-dir", default=None, metavar="DIR",
                    help="capture a jax.profiler trace of the measured "
                         "traffic (not the warmup) into DIR; "
                         "profile.start/stop events carry matching "
                         "wall-clock stamps")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced, mixer=args.mixer)
    mesh = make_mesh()
    print(f"[serve] {cfg.name} on {mesh_summary(mesh)}")
    rng = np.random.RandomState(args.seed)
    with mesh:
        specs = lm.lm_specs(cfg)
        params = jax.jit(
            functools.partial(init_params, specs),
            out_shardings=shd.param_shardings(specs, mesh),
        )(jax.random.key(args.seed))
        spec = None
        if args.spec != "off":
            spec = SpecConfig(
                k=args.spec_k, drafter=args.spec,
                draft_arch=args.draft_arch, draft_reduced=args.reduced,
            )
        obs = Obs()
        engine = Engine(
            cfg, params,
            slots=args.slots,
            max_len=args.prompt_len + args.gen_len + 8,
            sampling=SamplingConfig(
                method=args.sampling, temperature=args.temperature,
                top_k=args.top_k, top_p=args.top_p,
            ),
            block=args.block,
            seed=args.seed,
            mesh=mesh,
            spec=spec,
            obs=obs,
        )
        shared = min(args.shared_prefix, args.prompt_len)
        prefix = rng.randint(2, cfg.vocab, size=shared)
        requests = [
            GenRequest(
                rid=i,
                prompt=np.concatenate([
                    prefix,
                    rng.randint(2, cfg.vocab,
                                size=args.prompt_len - shared),
                ]).astype(np.int64),
                max_new=args.gen_len,
                deadline_s=args.deadline_s,
            )
            for i in range(args.requests)
        ]
        # warm the prefill/decode jits so TTFT and tok/s measure steady
        # state, not trace+compile (same protocol as benchmarks.run).
        # Warmup MUST go through the measured execution mode: the jit
        # cache keys on the ambient mesh-context stack, and the streaming
        # server drives the engine from a worker thread where only the
        # engine's own mesh context is active — a main-thread-only warmup
        # would leave the measured run's first admissions to recompile.
        runner = _run_streaming if args.stream else (
            lambda eng, reqs: eng.run(reqs))
        runner(engine, [GenRequest(
            rid=-1, prompt=requests[0].prompt, max_new=args.block,
        )])
        cache = None
        if args.cache_mb > 0:
            gran = args.cache_chunk if args.cache_chunk else cfg.hla.chunk
            if shared and shared < gran + 1:
                print(f"[serve] note: shared prefix {shared} <= cache "
                      f"granularity {gran}: no cache hits possible")
            # warm the carry/resume jits against a throwaway cache so the
            # measured run's first hit pays a lookup, not a compile
            engine.cache = PrefixCache(
                granularity=gran, budget_bytes=int(args.cache_mb * 2**20))
            for rid in (-2, -3):  # miss + insert, then hit + resume
                runner(engine, [GenRequest(
                    rid=rid, prompt=requests[0].prompt, max_new=2)])
            cache = PrefixCache(
                granularity=gran, budget_bytes=int(args.cache_mb * 2**20),
                namespace=cfg.name, obs=engine.obs,
            )
            engine.cache = cache
        # fresh obs epoch: zero every metric series and drop warmup
        # events, so the artifacts below describe only measured traffic
        engine.obs.reset()
        engine.reset_breaker()  # warmup zero-acceptance must not leak
        sink = None
        if args.events_out:
            sink = JsonlSink(args.events_out)
            engine.obs.attach(sink)
        # attach the fault plan AFTER the warmup run so injection-point
        # hit counts start at the measured traffic, not at trace time
        if args.inject:
            engine.faults = FaultPlan(*[parse_fault(s) for s in args.inject])
        t0 = time.time()
        with profile_capture(args.profile_dir, obs=engine.obs):
            if args.stream:
                results = _run_streaming(engine, requests)
            else:
                results = engine.run(requests)
        dt = time.time() - t0
        st = engine.stats
        gen = st["generated_tokens"]
        # each request's first token comes from the prefill call; count only
        # decode-block tokens against decode wall time
        # (non-ok results may have produced no tokens at all)
        decode_toks = max(gen - len(results), 0)
        ttft = engine.obs.registry.get("serving_ttft_seconds")
        p50 = ttft.quantile(0.5) or 0.0
        p99 = ttft.quantile(0.99) or 0.0
        decode_tps = decode_toks / st["decode_s"] if st["decode_s"] else 0.0
        print(
            f"[serve] {len(results)} requests, {gen} generated tokens in "
            f"{dt:.2f}s | TTFT p50 {1e3 * p50:.1f}ms p99 {1e3 * p99:.1f}ms "
            f"| decode {decode_tps:.1f} tok/s | "
            f"prefill {st['prompt_tokens']/max(st['prefill_s'],1e-9):.1f} tok/s"
        )
        if spec is not None:
            acc = st["spec_accepted"] / max(st["spec_drafted"], 1)
            print(
                f"[serve] spec: {st['spec_rounds']} rounds, "
                f"acceptance {acc:.2f}, {st['spec_replays']} rollbacks, "
                f"{decode_toks/max(st['spec_rounds'],1):.2f} committed "
                "tok/round"
            )
        statuses = collections.Counter(r.status for r in results)
        status_str = " ".join(
            f"{k}={statuses[k]}" for k in ("ok", "error", "timeout",
                                           "cancelled") if statuses[k]
        )
        print(
            f"[serve] statuses: {status_str or 'ok=0'} | "
            f"quarantined={st['quarantined']} "
            f"breaker_trips={st['breaker_trips']}"
        )
        if cache is not None:
            cs = cache.stats()
            print(
                f"[serve] cache: {int(cs['entries'])} entries "
                f"{cs['bytes'] / 2**20:.2f} MiB | hit rate "
                f"{cs['hit_rate']:.2f} ({int(cs['hits'])} hits, "
                f"{int(cs['misses'])} misses, "
                f"{int(cs['evicted_bytes'])} bytes evicted)"
            )
        if sink is not None:
            sink.close()
            print(f"[serve] events -> {args.events_out}")
        if args.metrics_out:
            write_metrics(engine.obs.snapshot(), args.metrics_out)
            print(f"[serve] metrics -> {args.metrics_out}")
    return len(results)


if __name__ == "__main__":
    main()
