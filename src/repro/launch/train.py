"""End-to-end training launcher with fault tolerance.

    PYTHONPATH=src python -m repro.launch.train --arch hla-1b --reduced \
        --steps 200 --batch 8 --seq 256 --ckpt-dir /tmp/ckpt

Uses the real mesh (all visible devices) or ``--host-devices N`` for a CPU
simulation mesh; checkpoints/restarts via runtime.ft (auto-resume), data
from the deterministic synthetic stream.
"""

import os

# must run at import, before jax initializes its backend: XLA locks the
# host device count on first use
_hd = os.environ.get("HOST_DEVICES")
if _hd:
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={_hd} "
        + os.environ.get("XLA_FLAGS", "")
    )

import argparse  # noqa: E402
import functools  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from ..configs import get_config  # noqa: E402
from ..data.pipeline import DataConfig, SyntheticStream  # noqa: E402
from ..distributed import sharding as shd  # noqa: E402
from ..distributed import steps as steps_mod  # noqa: E402
from ..models.param import init_params  # noqa: E402
from ..obs import JsonlSink, Obs, profile_capture, write_metrics  # noqa: E402
from ..optim import adamw  # noqa: E402
from ..runtime.faults import FaultPlan, FaultSpec  # noqa: E402
from ..runtime.ft import FaultTolerantLoop  # noqa: E402
from .mesh import make_mesh, mesh_summary  # noqa: E402


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="hla-1b")
    ap.add_argument("--mixer", default=None)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--data", default="zipf")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--fail-at-step", type=int, default=None,
                    help="inject a train.step fault at this step "
                         "(runtime.faults; exercises restart/resume)")
    ap.add_argument("--metrics", default=None)
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="dump the final metrics registry snapshot "
                         "(repro.obs.metrics/v1 JSON) on exit")
    ap.add_argument("--events-out", default=None, metavar="PATH",
                    help="stream span/event records (repro.obs.events/v1 "
                         "JSONL): train.step spans, ckpt.save spans, "
                         "resume events, fired faults")
    ap.add_argument("--profile-dir", default=None, metavar="DIR",
                    help="capture a jax.profiler trace of the whole run "
                         "into DIR; profile.start/stop events on the obs "
                         "stream carry matching wall-clock stamps")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced, mixer=args.mixer)
    mesh = make_mesh()
    print(f"[train] {cfg.name} on {mesh_summary(mesh)}")

    specs = steps_mod.model_specs(cfg)
    # one sharding source of truth: params + ZeRO-1 optimizer moments from
    # distributed.steps.make_shardings (what dryrun lowers against too)
    pshard, opt_shard = steps_mod.make_shardings(cfg, mesh)
    opt_cfg = adamw.OptConfig(
        lr=args.lr, total_steps=args.steps, warmup_steps=max(args.steps // 20, 5)
    )
    with mesh:
        params = jax.jit(
            functools.partial(init_params, specs), out_shardings=pshard
        )(jax.random.key(args.seed))
        opt_state = jax.jit(
            adamw.init_opt_state, out_shardings=opt_shard
        )(params)
        step_fn = jax.jit(
            steps_mod.make_train_step(
                cfg, opt_cfg, microbatches=args.microbatches,
                grad_shardings=pshard,
            )
        )

        stream = SyntheticStream(
            DataConfig(cfg.vocab, args.seq, args.batch, seed=args.seed,
                       kind=args.data)
        )

        def place(batch):
            return {
                k: jax.device_put(
                    v, shd.batch_sharding(mesh, v.shape)
                )
                for k, v in batch.items()
            }

        faults = None
        if args.fail_at_step is not None:
            faults = FaultPlan(FaultSpec("train.step", at=args.fail_at_step))
        obs = Obs()
        sink = None
        if args.events_out:
            sink = JsonlSink(args.events_out)
            obs.attach(sink)
        loop = FaultTolerantLoop(
            step_fn, stream, args.ckpt_dir, ckpt_every=args.ckpt_every,
            metrics_path=args.metrics, faults=faults,
            place_batch=place, obs=obs,
        )
        with profile_capture(args.profile_dir, obs=obs):
            params, opt_state, last = loop.run(
                params, opt_state, args.steps
            )
    step_s = obs.registry.get("train_step_seconds")
    p50 = step_s.quantile(0.5) or 0.0
    p99 = step_s.quantile(0.99) or 0.0
    toks = obs.registry.get("train_tokens_total").total()
    total_s = step_s.sum() or 1e-9
    print(
        f"[train] finished at step {last} | step p50 {p50:.3f}s "
        f"p99 {p99:.3f}s | {toks / total_s:.0f} tok/s | "
        f"loss {obs.registry.get('train_loss').value():.4f}"
    )
    if sink is not None:
        sink.close()
        print(f"[train] events -> {args.events_out}")
    if args.metrics_out:
        write_metrics(obs.snapshot(), args.metrics_out)
        print(f"[train] metrics -> {args.metrics_out}")
    return last


if __name__ == "__main__":
    main()
