"""Jamba-1.5-Large 398B — hybrid Mamba+Attention 1:7, MoE 16e top-2.

[arXiv:2403.19887; hf]  72L d_model=8192 64H (GQA kv=8) d_ff=24576
vocab=65536.  Groups of 8 layers: attention at in-group index 4, the rest
Mamba; MoE replaces the dense FFN on every 2nd layer.
"""

from ..models.config import HLAConfig, MambaConfig, MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab=65536,
    mixer="softmax",
    mlp="swiglu",
    moe=MoEConfig(n_experts=16, top_k=2, d_ff=24576, every=2),
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
    group_size=8,
    attn_index=4,
    remat="full",
    # 398B: fp32 master+moments = 4.8 TB (> 256 x 16 GiB).  bf16 storage +
    # bf16 moments is the standard trade at this scale (see DESIGN.md §4).
    param_dtype="bfloat16",
    moment_dtype="bfloat16",
    grad_accum_dtype="bfloat16",
)


def reduced():
    return CONFIG.replace(
        n_layers=8, d_model=64, n_heads=4, n_kv_heads=2, d_ff=96, vocab=128,
        moe=MoEConfig(n_experts=4, top_k=2, d_ff=96, every=2),
        mamba=MambaConfig(d_state=4, d_conv=4, expand=2),
        group_size=8, attn_index=4, remat="none", dtype="float32",
    )
