"""The paper's own architecture: an HLA2 LM (~1.3B) for end-to-end runs.

Drop-in replacement of the attention sublayer per §5.2; unnormalized
masked HLA2 (Eq. 3.3) with learned per-head decay, chunk 128.
"""

from ..models.config import HLAConfig, ModelConfig

CONFIG = ModelConfig(
    name="hla-1b",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5504,
    vocab=50304,
    mixer="hla2",
    mlp="swiglu",
    hla=HLAConfig(variant="hla2", chunk=128, decay="learned"),
    remat="full",
)


def reduced():
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=128,
        remat="none", dtype="float32",
    )
