"""Qwen3-MoE-30B-A3B — MoE 128e top-8 (per-expert d_ff=768), head_dim=128.

[hf:Qwen/Qwen3-30B-A3B; hf]  48L d_model=2048 32H (kv=4) vocab=151936.
"""

from ..models.config import MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_head=128,
    d_ff=768,
    vocab=151936,
    mixer="softmax",
    mlp="swiglu",
    moe=MoEConfig(n_experts=128, top_k=8, d_ff=768),
    rope_theta=1e6,
    remat="full",
)


def reduced():
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16, d_ff=64,
        vocab=128, moe=MoEConfig(n_experts=8, top_k=2, d_ff=64), remat="none",
        dtype="float32",
    )
