"""CodeQwen1.5-7B — dense, MHA (kv=32), QKV bias.

[hf:Qwen/CodeQwen1.5-7B; hf]  32L d_model=4096 32H d_ff=13440 vocab=92416.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="codeqwen1.5-7b",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=13440,
    vocab=92416,
    mixer="softmax",
    mlp="swiglu",
    qkv_bias=True,
    rope_theta=1e6,
    remat="full",
)


def reduced():
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=128,
        remat="none", dtype="float32",
    )
