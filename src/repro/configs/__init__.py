"""Assigned architecture configs (public literature) + the paper's own."""

from .registry import get_config, list_archs  # noqa: F401
