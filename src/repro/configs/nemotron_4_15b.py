"""Nemotron-4-15B — dense, GQA kv=8, squared-ReLU MLP.

[arXiv:2402.16819; unverified]  32L d_model=6144 48H (kv=8) d_ff=24576
vocab=256000.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b",
    n_layers=32,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=24576,
    vocab=256000,
    mixer="softmax",
    mlp="squared_relu",
    remat="full",
)


def reduced():
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=128,
        remat="none", dtype="float32",
    )
