"""Whisper-small — enc-dec audio backbone, conv frontend STUB.

[arXiv:2212.04356; unverified]  12L(+12 enc) d_model=768 12H d_ff=3072
vocab=51865.  ``input_specs()`` supplies precomputed frame embeddings
(B, 1500, d) — the conv subsampler is stubbed per the assignment.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab=51865,
    mixer="softmax",
    mlp="gelu",
    enc_layers=12,
    enc_frames=1500,
    remat="full",
)


def reduced():
    return CONFIG.replace(
        n_layers=2, enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=128, enc_frames=16, remat="none", dtype="float32",
    )
