"""InternVL2-2B — InternLM2-1.8B backbone + InternViT STUB frontend.

[arXiv:2404.16821; hf]  24L d_model=2048 16H (kv=8) d_ff=8192 vocab=92553.
``input_specs()`` supplies 256 precomputed patch embeddings per image.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=8192,
    vocab=92553,
    mixer="softmax",
    mlp="swiglu",
    vis_tokens=256,
    remat="full",
)


def reduced():
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=128,
        vis_tokens=8, remat="none", dtype="float32",
    )
