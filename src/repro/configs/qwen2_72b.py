"""Qwen2-72B — dense, GQA kv=8, QKV bias.

[arXiv:2407.10671; hf]  80L d_model=8192 64H (kv=8) d_ff=29568 vocab=152064.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-72b",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab=152064,
    mixer="softmax",
    mlp="swiglu",
    qkv_bias=True,
    rope_theta=1e6,
    remat="full",
)


def reduced():
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=128,
        remat="none", dtype="float32",
    )
