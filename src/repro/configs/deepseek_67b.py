"""DeepSeek-67B — dense llama arch, GQA kv=8.

[arXiv:2401.02954; hf]  95L d_model=8192 64H (kv=8) d_ff=22016 vocab=102400.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-67b",
    n_layers=95,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    vocab=102400,
    mixer="softmax",
    mlp="swiglu",
    remat="full",
)


def reduced():
    return CONFIG.replace(
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=128,
        remat="none", dtype="float32",
    )
