"""Architecture registry: --arch <id> resolution."""

from __future__ import annotations

import importlib

_ARCHS = {
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "codeqwen1.5-7b": "codeqwen1_5_7b",
    "qwen2-72b": "qwen2_72b",
    "nemotron-4-15b": "nemotron_4_15b",
    "deepseek-67b": "deepseek_67b",
    "whisper-small": "whisper_small",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "rwkv6-7b": "rwkv6_7b",
    "internvl2-2b": "internvl2_2b",
    "hla-1b": "hla_1b",
}


def list_archs():
    return sorted(_ARCHS)


def get_config(name: str, *, reduced: bool = False, mixer: str | None = None):
    """Resolve an arch id to its ModelConfig.

    mixer: optional override — swaps the attention sublayer for an HLA
    variant (the paper's drop-in claim, §5.2).  Attention-free archs
    (rwkv6) reject overrides.
    """
    if name not in _ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {list_archs()}")
    mod = importlib.import_module(f".{_ARCHS[name]}", __package__)
    cfg = mod.reduced() if reduced else mod.CONFIG
    if mixer is not None and mixer != cfg.mixer:
        if cfg.mixer == "rwkv6":
            raise ValueError(
                "rwkv6 is attention-free; HLA mixer override is inapplicable "
                "(DESIGN.md §Arch-applicability)"
            )
        cfg = cfg.replace(mixer=mixer)
    return cfg
