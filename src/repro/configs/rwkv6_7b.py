"""RWKV-6 (Finch) 7B — attention-free, data-dependent decay.

[arXiv:2404.05892; hf]  32L d_model=4096 d_ff=14336 vocab=65536,
head size 64.  HLA is not applicable as a drop-in here (no attention
sublayer) — DESIGN.md §Arch-applicability.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    n_layers=32,
    d_model=4096,
    n_heads=64,  # d_model / rwkv_head_dim
    n_kv_heads=64,
    d_ff=14336,
    vocab=65536,
    mixer="rwkv6",
    rwkv_head_dim=64,
    remat="full",
)


def reduced():
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=128,
        rwkv_head_dim=16, remat="none", dtype="float32",
    )
