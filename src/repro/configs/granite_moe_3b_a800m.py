"""Granite-MoE 3B-a800m — MoE 40e top-8 (per-expert d_ff=512).

[hf:ibm-granite/granite-3.0-*-base; hf]  32L d_model=1536 24H (kv=8)
vocab=49155, tied embeddings.
"""

from ..models.config import MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,
    vocab=49155,
    mixer="softmax",
    mlp="swiglu",
    moe=MoEConfig(n_experts=40, top_k=8, d_ff=512),
    tie_embeddings=True,
    remat="full",
)


def reduced():
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=64, vocab=128,
        moe=MoEConfig(n_experts=4, top_k=2, d_ff=64), remat="none",
        dtype="float32",
    )
