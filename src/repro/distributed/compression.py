"""Gradient compression: int8 error-feedback all-reduce (shard_map).

A wire-level compressed all-reduce in two phases, both moving int8:

  1. reduce-scatter phase: each rank quantizes its gradient (after adding
     the error-feedback buffer), ``all_to_all`` ships int8 chunks + fp32
     per-chunk scales, each rank dequantizes and sums its chunk;
  2. all-gather phase: the reduced chunk is re-quantized and
     ``all_gather``-ed with its scale.

Error feedback (residual = x_ef - dequant(q)) keeps SGD convergence
(Karimireddy et al.); the buffer lives in the caller's optimizer state.
Used by the opt-in manual-DP train step; numerics validated on an 8-device
host mesh in tests/test_distributed.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .compat import shard_map


def _quantize(x):
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def int8_allreduce_mean(x, axis_name: str, error=None):
    """Inside shard_map: mean over ``axis_name`` with int8 wire traffic.

    x: fp32 vector (flattened gradient slice), same shape on every rank.
    Returns (mean_estimate fp32, new_error).
    """
    n = jax.lax.psum(1, axis_name)
    size = x.shape[0]
    pad = (-size) % n
    xp = jnp.pad(x if error is None else x + error, (0, pad))
    chunks = xp.reshape(n, -1)  # row r -> destined to rank r

    # per-destination quantization
    qs, scales = jax.vmap(_quantize)(chunks)  # (n, c) int8, (n,) f32
    deq_local = qs.astype(jnp.float32) * scales[:, None]
    new_error = (xp - deq_local.reshape(-1))[: size] if pad else (
        xp - deq_local.reshape(-1)
    )
    if pad:
        new_error = new_error[:size]

    # phase 1: all_to_all int8 chunks + scales; local dequant-sum
    recv_q = jax.lax.all_to_all(qs, axis_name, 0, 0, tiled=False)
    recv_s = jax.lax.all_to_all(
        scales.reshape(n, 1), axis_name, 0, 0, tiled=False
    )
    part = jnp.sum(
        recv_q.astype(jnp.float32) * recv_s.reshape(n, 1), axis=0
    ) / n  # mean

    # phase 2: re-quantize the reduced chunk, all_gather int8
    q2, s2 = _quantize(part)
    gq = jax.lax.all_gather(q2, axis_name, axis=0, tiled=False)
    gs = jax.lax.all_gather(s2, axis_name, axis=0, tiled=False)
    full = (gq.astype(jnp.float32) * gs[:, None]).reshape(-1)
    return full[:size], new_error


def make_compressed_grad_allreduce(mesh, axis_name: str = "data"):
    """Returns f(grads_tree, error_tree) -> (mean_grads, new_error) that
    runs the int8 EF all-reduce per leaf over the data axis.  Leaves are
    expected *unreduced* (per-DP-rank) — use with the manual-DP step."""

    def per_leaf(g, e):
        flat = g.reshape(-1).astype(jnp.float32)
        ef = e.reshape(-1).astype(jnp.float32)
        red, new_e = int8_allreduce_mean(flat, axis_name, ef)
        return red.reshape(g.shape), new_e.reshape(g.shape)

    @functools.partial(
        shard_map,
        mesh=mesh,
        in_specs=(P(axis_name), P(axis_name)),
        out_specs=(P(axis_name), P(axis_name)),
    )
    def _run(gstack, estack):
        # gstack: (n_dp, ...) stacked per-rank grads; inside shard_map each
        # rank sees its (1, ...) slice.
        g = gstack[0]
        e = estack[0]
        red, new_e = per_leaf(g, e)
        return red[None], new_e[None]

    def run_tree(grads, errors):
        outs = jax.tree.map(_run, grads, errors)
        red = jax.tree.map(lambda t: t[0], outs)
        return red

    return _run


def quantize_dequantize(x):
    """Straight int8 round-trip (compression-loss measurement helper)."""
    q, s = _quantize(x.reshape(-1).astype(jnp.float32))
    return (q.astype(jnp.float32) * s).reshape(x.shape)
