"""Version-portable imports for distributed primitives.

``shard_map`` graduated from ``jax.experimental.shard_map`` to
``jax.shard_map`` (and its ``check_rep`` knob was renamed ``check_vma``)
across jax releases; every call site in this repo — and the distributed
tests — goes through this shim so the repo runs on whichever jax the
image bakes in.
"""

from __future__ import annotations

import functools
import inspect

import jax

if hasattr(jax, "shard_map"):  # jax >= 0.6
    _shard_map = jax.shard_map
else:  # jax 0.4.x / 0.5.x
    from jax.experimental.shard_map import shard_map as _shard_map

_PARAMS = set(inspect.signature(_shard_map).parameters)


def shard_map(f=None, **kwargs):
    """``shard_map(f, mesh=..., in_specs=..., out_specs=...)``.

    Accepts both ``check_rep`` (old) and ``check_vma`` (new) and translates
    to whatever the underlying jax exposes.  Usable directly or as a
    ``functools.partial``-style decorator (``shard_map(mesh=...)(f)``).
    """
    if "check_rep" in kwargs and "check_rep" not in _PARAMS:
        kwargs["check_vma"] = kwargs.pop("check_rep")
    if "check_vma" in kwargs and "check_vma" not in _PARAMS:
        kwargs["check_rep"] = kwargs.pop("check_vma")
    if f is None:
        return functools.partial(shard_map, **kwargs)
    return _shard_map(f, **kwargs)
