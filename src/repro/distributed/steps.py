"""Distributed step builders: train / prefill / serve, with shardings.

These produce the functions that ``launch/train.py``, ``launch/serve.py``
and ``launch/dryrun.py`` jit with explicit in/out shardings, plus
``input_specs()`` — ShapeDtypeStruct stand-ins (weak-type-correct,
shardable, no device allocation) for every input of each step.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..models import lm, whisper
from ..models.param import abstract_params, init_params
from ..optim import adamw
from . import sharding as shd


# --------------------------------------------------------------------------
# loss / model dispatch
# --------------------------------------------------------------------------


def _loss_fn(params, batch, cfg, denom=None, aux_weight=1.0):
    if cfg.enc_layers:
        return whisper.whisper_loss(
            params, batch["tokens"], batch["labels"], batch["frames"], cfg,
            denom=denom, aux_weight=aux_weight,
        )
    return lm.lm_loss(
        params, batch["tokens"], batch["labels"], cfg,
        vis_embed=batch.get("vis_embed"), denom=denom, aux_weight=aux_weight,
    )


def model_specs(cfg):
    import dataclasses

    from ..models.param import is_spec

    specs = whisper.whisper_specs(cfg) if cfg.enc_layers else lm.lm_specs(cfg)
    pd = jnp.dtype(getattr(cfg, "param_dtype", "float32"))
    if pd != jnp.float32:
        specs = jax.tree.map(
            lambda sp: dataclasses.replace(sp, dtype=pd), specs,
            is_leaf=is_spec,
        )
    return specs


# --------------------------------------------------------------------------
# steps
# --------------------------------------------------------------------------


def make_train_step(
    cfg, opt_cfg: adamw.OptConfig, *, microbatches: int = 1,
    grad_shardings=None,
):
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    microbatches > 1 accumulates gradients with a lax.scan (memory/overlap
    trade; DP gradient reduction overlaps the next microbatch's compute).
    Accumulation is **exact**: each microbatch loss is normalized by the
    *global* valid-token count (computed from the labels before the scan)
    so the summed gradients equal the full-batch mean-CE gradient — the old
    mean-of-per-microbatch-means drifted whenever label masking left the
    microbatches with uneven token counts.  The MoE aux term stays a mean
    over microbatches (router statistics are not decomposable).
    ``grad_shardings`` (pytree of NamedSharding, like params) pins the
    gradient/accumulator layout — without it GSPMD may replicate the fp32
    buffer or reassociate the reduction differently per step.
    """

    def _pin(tree):
        if grad_shardings is None:
            return tree
        return jax.tree.map(
            lambda x, s: jax.lax.with_sharding_constraint(x, s),
            tree, grad_shardings,
        )

    acc_dtype = jnp.dtype(getattr(cfg, "grad_accum_dtype", "float32"))

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            (loss, (ce, aux)), grads = jax.value_and_grad(
                _loss_fn, has_aux=True
            )(params, batch, cfg)
            grads = _pin(grads)
        else:
            # global CE normalizer, known before any model evaluation
            n_valid = jnp.maximum(
                jnp.sum((batch["labels"] >= 0).astype(jnp.float32)), 1.0
            )

            def micro(carry, mb):
                acc, = carry
                # loss_i = ce_sum_i / n_valid + aux_i / M  =>  sum over
                # microbatches == full-batch loss; gradients accumulate
                # with NO post-hoc rescaling.
                (l, (c, a)), g = jax.value_and_grad(_loss_fn, has_aux=True)(
                    params, mb, cfg, n_valid, 1.0 / microbatches
                )
                acc = _pin(jax.tree.map(
                    lambda x, y: x + y.astype(acc_dtype), acc, g
                ))
                return (acc,), (l, c, a)

            mbs = jax.tree.map(
                lambda x: x.reshape((microbatches, -1) + x.shape[1:]), batch
            )
            zeros = _pin(jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dtype), params
            ))
            (grads,), (ls, cs, aus) = jax.lax.scan(micro, (zeros,), mbs)
            loss, ce, aux = ls.sum(), cs.sum(), aus.mean()
        params, opt_state, om = adamw.adamw_update(
            params, grads, opt_state, opt_cfg
        )
        metrics = {"loss": loss, "ce": ce, "aux": aux, **om}
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg):
    """(params, batch) -> (last_logits, states).

    States (KV caches sized to the prompt / HLA-SSM streaming states) are
    allocated inside the step and filled — decode continues from them.
    """

    def prefill_step(params, batch):
        B, n = batch["tokens"].shape
        if cfg.enc_layers:
            states = whisper.whisper_init_states(cfg, B, n)
            logits, states, _ = whisper.whisper_apply(
                params, batch["tokens"], batch["frames"], cfg,
                states=states, mode="prefill",
            )
        else:
            total = n + (cfg.vis_tokens or 0)  # VLM prepends patch tokens
            states = (
                lm.lm_init_states(cfg, B, total)
                if lm.needs_prealloc_states(cfg)  # SequenceOp capability:
                #   KV-cache/hybrid ops prefill into preallocated state
                else None  # streaming ops build state from scratch
            )
            logits, states, _ = lm.lm_apply(
                params, batch["tokens"], cfg, states=states, mode="prefill",
                vis_embed=batch.get("vis_embed"),
            )
        return logits[:, -1], states

    return prefill_step


def make_serve_step(cfg):
    """(params, batch{tokens, positions}, states) -> (logits, states).

    One new token per sequence against a pre-filled cache/state
    (``decode_*`` / ``long_*`` shapes lower THIS, not train_step).
    """

    def serve_step(params, batch, states):
        if cfg.enc_layers:
            logits, states, _ = whisper.whisper_apply(
                params, batch["tokens"], None, cfg, states=states,
                positions=batch["positions"], mode="decode",
            )
        else:
            logits, states, _ = lm.lm_apply(
                params, batch["tokens"], cfg, states=states,
                positions=batch["positions"], mode="decode",
            )
        return logits[:, -1], states

    return serve_step


# --------------------------------------------------------------------------
# abstract inputs (dry-run) + shardings
# --------------------------------------------------------------------------


def input_specs(cfg, shape_cfg, mesh):
    """ShapeDtypeStruct stand-ins for the step inputs of this cell.

    train/prefill: {tokens, labels?, frames?, vis_embed?}
    decode: ({tokens, positions}, states)
    """
    B, n = shape_cfg.global_batch, shape_cfg.seq_len
    bs = lambda shape, dt=jnp.int32: jax.ShapeDtypeStruct(  # noqa: E731
        shape, dt, sharding=shd.batch_sharding(mesh, shape)
    )
    if shape_cfg.kind in ("train", "prefill"):
        batch = {"tokens": bs((B, n))}
        if shape_cfg.kind == "train":
            batch["labels"] = bs((B, n))
        if cfg.enc_layers:
            batch["frames"] = bs((B, cfg.enc_frames, cfg.d_model), jnp.bfloat16)
        if cfg.vis_tokens:
            batch["vis_embed"] = bs((B, cfg.vis_tokens, cfg.d_model), jnp.bfloat16)
        return batch
    # decode: one token, cache/state sized to seq_len
    batch = {"tokens": bs((B, 1)), "positions": bs((B, 1))}
    states = state_specs(cfg, B, n, mesh)
    return {"batch": batch, "states": states}


def state_axes(cfg):
    """Logical axes for every decode-state leaf — delegated to the model
    modules (``lm.lm_state_axes`` / ``whisper.whisper_state_axes``), which
    read each operator's ``SequenceOp.state_axes`` record: the single
    sharding source of truth.  Replaces the old shape heuristic
    (first dim divisible by the model axis), which mis-sharded any state
    whose feature dim happened to divide the axis size."""
    if cfg.enc_layers:
        return whisper.whisper_state_axes(cfg)
    return lm.lm_state_axes(cfg)


def state_shardings_for(cfg, mesh, states):
    """NamedSharding tree for a concrete/abstract decode-state tree.

    Resolves ``state_axes`` against the mesh with the usual divisibility
    fallback.  Used by the serving state pool so slot states live sharded
    (batch=slots on data, heads on model) instead of replicated.
    """
    return jax.tree.map(
        lambda x, ax: NamedSharding(mesh, shd.spec_for(ax, x.shape, mesh)),
        states, state_axes(cfg),
    )


def state_specs(cfg, B, max_len, mesh):
    """Abstract decode states with shardings (no allocation)."""
    if cfg.enc_layers:
        abstract = jax.eval_shape(
            lambda: whisper.whisper_init_states(cfg, B, max_len)
        )
    else:
        abstract = jax.eval_shape(lambda: lm.lm_init_states(cfg, B, max_len))
    return jax.tree.map(
        lambda x, ax: jax.ShapeDtypeStruct(
            x.shape, x.dtype,
            sharding=NamedSharding(mesh, shd.spec_for(ax, x.shape, mesh)),
        ),
        abstract, state_axes(cfg),
    )


def make_shardings(cfg, mesh, *, zero1: bool = True):
    """(param_shardings, opt_state_shardings) for this config/mesh."""
    specs = model_specs(cfg)
    ps = shd.param_shardings(specs, mesh)
    mom = shd.opt_state_shardings(specs, mesh, zero1=zero1)
    opt = adamw.OptState(
        step=NamedSharding(mesh, P()),
        mu=mom,
        nu=jax.tree.map(lambda s: s, mom),
    )
    return ps, opt


def abstract_train_args(cfg, mesh, *, zero1: bool = True):
    """(params, opt_state) as sharded ShapeDtypeStructs (dry-run)."""
    specs = model_specs(cfg)
    ps, opt_sh = make_shardings(cfg, mesh, zero1=zero1)
    aps = abstract_params(specs)
    params = jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
        aps, ps,
    )
    md = jnp.dtype(getattr(cfg, "moment_dtype", "float32"))
    opt_state = adamw.OptState(
        step=jax.ShapeDtypeStruct((), jnp.int32, sharding=opt_sh.step),
        mu=jax.tree.map(
            lambda a, s: jax.ShapeDtypeStruct(a.shape, md, sharding=s),
            aps, opt_sh.mu,
        ),
        nu=jax.tree.map(
            lambda a, s: jax.ShapeDtypeStruct(a.shape, md, sharding=s),
            aps, opt_sh.nu,
        ),
    )
    return params, opt_state
