"""shard_map dispatch of the fused HLA kernels over a (data, model) mesh.

The chunkwise Pallas kernels run on a ``(BH, n_chunks)`` grid whose rows —
(batch, head) pairs — are completely independent: the chunk scan carries
state only along time, never across rows.  Head-sharding therefore
commutes with the chunk scan (DESIGN.md §9), and the whole training /
prefill / decode family shards the same way:

* batch rows over the ("pod", "data") axes,
* head rows over the "model" axis,
* time and feature dims replicated (the scan is local to a row).

``call_sharded`` wraps any row-major kernel op (every array input/output
has leading ``(B, H)`` dims — q/k/v, gamma, state-tuple leaves) in a
``shard_map`` over the active mesh so each device runs the *fused Pallas
kernel on its local row block*.  Under ``jax.grad`` the kernels' custom
VJPs apply per shard, which is exact: dq/dk/dv/dgamma are row-local, so
no cross-shard reduction is needed inside the op (weight-gradient
reductions happen outside, in GSPMD-land).

Divisibility fallback mirrors ``sharding.spec_for``: axes that do not
divide the row grid are dropped (worst case: direct un-shard_map'd call,
which GSPMD handles as before).
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from .compat import shard_map
from .sharding import _current_mesh


def row_axes(mesh, B: int, H: int):
    """(batch_axes, head_axes) for a (B, H, ...) row grid, or None when no
    present mesh axis divides it (caller should fall back to direct call)."""
    if mesh is None or mesh.empty:
        return None
    names = mesh.axis_names
    batch = tuple(a for a in ("pod", "data") if a in names)
    while batch and B % int(np.prod([mesh.shape[a] for a in batch])) != 0:
        batch = batch[1:]  # drop "pod" first, like sharding._axes_for
    head = ()
    if "model" in names and H % mesh.shape["model"] == 0:
        head = ("model",)
    if not batch and not head:
        return None
    return batch, head


def _row_spec(axes, ndim: int) -> P:
    batch, head = axes
    b = batch if len(batch) > 1 else (batch[0] if batch else None)
    h = head[0] if head else None
    return P(*((b, h) + (None,) * (ndim - 2)))


def call_sharded(fn, *args, mesh=None, out_ndims=None):
    """Run ``fn(*args)`` with (B, H) rows sharded over the active mesh.

    Every array leaf of ``args`` and of ``fn``'s output must carry leading
    ``(B, H)`` dims (scalars/None pass through as pytree non-leaves).
    Outside a mesh — or when neither axis divides the row grid — this is
    exactly ``fn(*args)``.

    ``out_ndims``: pytree matching ``fn``'s output structure with each
    leaf's rank as an int.  Callers that know their output structure pass
    it to skip the ``jax.eval_shape`` fallback, which would trace the
    whole kernel op a second time per compile (and double-count
    ``kernels.ops.TRACE_COUNTS``).
    """
    mesh = mesh if mesh is not None else _current_mesh()
    leaves = jax.tree.leaves(args)
    if not leaves:
        return fn(*args)
    B, H = leaves[0].shape[:2]
    axes = row_axes(mesh, B, H)
    if axes is None:
        return fn(*args)
    in_specs = jax.tree.map(lambda x: _row_spec(axes, x.ndim), args)
    if out_ndims is None:
        out_specs = jax.tree.map(
            lambda x: _row_spec(axes, x.ndim), jax.eval_shape(fn, *args)
        )
    else:
        out_specs = jax.tree.map(lambda nd: _row_spec(axes, nd), out_ndims)
    return shard_map(
        fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=False,
    )(*args)
