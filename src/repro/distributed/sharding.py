"""Logical-axis sharding rules (MaxText-style) with divisibility fallback.

``RULES`` maps logical axis names to mesh axes.  ``spec_for`` resolves a
tuple of logical names into a PartitionSpec against a concrete mesh,
dropping (a) mesh axes that don't exist (single-pod meshes have no "pod")
and (b) assignments whose dimension is not divisible by the axis size
(e.g. 24 heads on a 16-wide model axis -> replicate).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.param import is_spec

# logical axis -> mesh axes (tuple = try in order, use all present)
RULES = {
    "batch": ("pod", "data"),
    "seq": "model",  # sequence parallelism on the residual stream
    "vocab": "model",
    # FSDP: weight-matrix input dims shard over the data axis; GSPMD
    # all-gathers one layer's params inside the layer scan (ZeRO-3 style).
    "embed": "data",
    "embed_out": "model",
    "q_heads": "model",
    "q_heads_flat": "model",
    "kv_heads": "model",
    "kv_heads_flat": "model",
    "head_dim": None,
    "ff": "model",
    "expert_ff": None,
    "experts": "model",
    "inner": "model",  # mamba d_inner
    "state": None,
    "conv": None,
    "layers": None,
    None: None,
}


def _axes_for(name, mesh: Mesh, dim: int, rules=None) -> Optional[Tuple[str, ...]]:
    rules = rules or RULES
    cand = rules.get(name, None)
    if cand is None:
        return None
    if isinstance(cand, str):
        cand = (cand,)
    present = tuple(a for a in cand if a in mesh.axis_names)
    if not present:
        return None
    size = int(np.prod([mesh.shape[a] for a in present]))
    if dim % size != 0:
        # try shrinking from the left (drop "pod" first etc.)
        for i in range(1, len(present)):
            sub = present[i:]
            size = int(np.prod([mesh.shape[a] for a in sub]))
            if dim % size == 0:
                return sub
        return None
    return present


def spec_for(axes, shape, mesh: Mesh, rules=None) -> P:
    parts = []
    used = set()
    for name, dim in zip(axes, shape):
        ax = _axes_for(name, mesh, dim, rules)
        if ax is None or any(a in used for a in ax):
            parts.append(None)
        else:
            used.update(ax)
            parts.append(ax if len(ax) > 1 else ax[0])
    return P(*parts)


def param_shardings(specs, mesh: Mesh, rules=None):
    """Spec pytree -> NamedSharding pytree."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, spec_for(s.axes, s.shape, mesh, rules)),
        specs,
        is_leaf=is_spec,
    )


def opt_state_shardings(specs, mesh: Mesh, *, zero1: bool = True, rules=None):
    """Moment shardings: like params, plus ZeRO-1 over the data axis.

    For each param whose sharding leaves a dimension replicated and
    divisible by the data axis, the first such dim is additionally sharded
    over ("data",) — distributing optimizer memory across DP ranks.
    """

    def one(s):
        p = spec_for(s.axes, s.shape, mesh, rules)
        parts = list(p) + [None] * (len(s.shape) - len(p))
        if zero1 and "data" in mesh.axis_names:
            dsize = mesh.shape["data"]
            used = {a for part in parts if part for a in (
                part if isinstance(part, tuple) else (part,))}
            if "data" not in used:
                for i, (part, dim) in enumerate(zip(parts, s.shape)):
                    if part is None and dim % dsize == 0 and dim >= dsize:
                        parts[i] = "data"
                        break
        return NamedSharding(mesh, P(*parts))

    return jax.tree.map(one, specs, is_leaf=is_spec)


def constrain(x, logical_axes, mesh: Mesh = None, rules=None):
    """with_sharding_constraint by logical names (no-op outside a mesh)."""
    mesh = mesh or _current_mesh()
    if mesh is None or mesh.empty:
        return x
    spec = spec_for(logical_axes, x.shape, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def _current_mesh():
    try:
        from jax._src.mesh import thread_resources

        m = thread_resources.env.physical_mesh
        return None if m.empty else m
    except Exception:
        return None


def batch_sharding(mesh: Mesh, shape) -> NamedSharding:
    """(batch, ...) inputs: batch over pod+data (with divisibility fallback)."""
    axes = ("batch",) + (None,) * (len(shape) - 1)
    return NamedSharding(mesh, spec_for(axes, shape, mesh))
