"""Pipeline parallelism: GPipe-style microbatched pipeline via shard_map +
``ppermute`` over a "pipe" mesh axis.

The uniform decoder stack is split into S contiguous stages (layers
sharded over "pipe"); microbatches stream through with the classic
(M + S - 1)-step schedule.  ``ppermute`` is differentiable — its transpose
is the reverse permute — so ``jax.grad`` through the pipelined forward
yields the standard GPipe backward with no hand-written adjoint schedule.

This is an optional axis (off in the default production mesh); numerics
are validated against the non-pipelined stack on an 8-device host mesh.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .compat import shard_map


def pipelined_forward(
    layer_fn,  # (layer_params, x) -> x   (one layer)
    stage_params,  # pytree, leaves (L, ...) stacked over ALL layers
    x_microbatches,  # (M, mb, n, d)
    mesh,
    *,
    axis_name: str = "pipe",
):
    """Runs the stack over microbatches with pipeline parallelism.

    stage_params leaves must have leading dim L divisible by the pipe
    axis; each stage runs its L/S contiguous layers per tick.
    """
    S = mesh.shape[axis_name]
    M = x_microbatches.shape[0]

    @functools.partial(
        shard_map,
        mesh=mesh,
        in_specs=(P(axis_name), P()),
        out_specs=P(),
        check_rep=False,
    )
    def run(params_local, xs):
        # params_local: (L/S, ...) this stage's layers; xs: (M, mb, n, d)
        stage = jax.lax.axis_index(axis_name)
        n_stage = jax.lax.psum(1, axis_name)
        mb_shape = xs.shape[1:]
        ticks = M + n_stage - 1

        def stage_apply(x):
            def body(h, lp):
                return layer_fn(lp, h), None

            out, _ = jax.lax.scan(body, x, params_local)
            return out

        def tick(carry, t):
            buf, outputs = carry
            # stage 0 ingests microbatch t (if any); others use recv buffer
            mb_idx = jnp.clip(t, 0, M - 1)
            inject = jax.lax.dynamic_index_in_dim(xs, mb_idx, 0, False)
            x_in = jnp.where(stage == 0, inject, buf)
            y = stage_apply(x_in)
            # pass to next stage
            perm = [(i, i + 1) for i in range(n_stage - 1)]
            buf_next = jax.lax.ppermute(y, axis_name, perm)
            # last stage emits microbatch (t - (S-1)) at tick t
            # (jnp.where instead of lax.cond: shard_map varying-axis typing)
            out_idx = jnp.clip(t - (n_stage - 1), 0, M - 1)
            emit = (t >= n_stage - 1) & (stage == n_stage - 1)
            upd = jax.lax.dynamic_update_index_in_dim(outputs, y, out_idx, 0)
            outputs = jnp.where(emit, upd, outputs)
            return (buf_next, outputs), None

        buf0 = jnp.zeros(mb_shape, xs.dtype)
        outs0 = jnp.zeros((M,) + mb_shape, xs.dtype)
        # mark the carries as device-varying over the pipe axis (the loop
        # body mixes in stage-dependent values): shard_map vma typing.
        if hasattr(jax.lax, "pcast"):
            buf0 = jax.lax.pcast(buf0, (axis_name,), to="varying")
            outs0 = jax.lax.pcast(outs0, (axis_name,), to="varying")
        (_, outputs), _ = jax.lax.scan(
            tick, (buf0, outs0), jnp.arange(ticks)
        )
        # broadcast final outputs from the last stage to all (psum of
        # one-hot contribution keeps shard_map output replicated)
        outputs = jnp.where(stage == n_stage - 1, outputs, 0.0)
        return jax.lax.psum(outputs, axis_name)

    return run(stage_params, x_microbatches)
