"""Fused chunkwise masked second-order HLA — Pallas TPU kernels (fwd + bwd).

Design (DESIGN.md §2 / §3, hardware adaptation):

* Grid ``(BH, n_chunks)`` with ``dimension_semantics=("parallel",
  "arbitrary")``: the batch×head axis parallelizes across cores, the chunk
  axis is sequential and carries the running state tuple
  ``(S, C, m, G, h)`` in **VMEM scratch** — the state never round-trips to
  HBM between chunks (the main win over the XLA-scheduled jnp version).
* Every intra-chunk contraction is an MXU-shaped matmul on ``(w, d)`` /
  ``(w, w)`` tiles: choose ``w`` and ``d`` multiples of 128 on real TPUs.
* bf16/fp32 inputs; all accumulation in fp32 via ``preferred_element_type``.
* Per-(batch,head) scalar decay ``gamma``; masks are built in-kernel with
  ``broadcasted_iota`` (no host-side (w, w) constants shipped per head).
* **Training** (``save_chunk_states=True``): the forward additionally spills
  each chunk's *incoming* state to HBM — ``nc ×`` constant-size state, the
  classic checkpointing trade.  ``hla2_chunk_bwd_pallas`` then walks the
  chunk axis in reverse over the same grid, recomputes the intra-chunk
  tiles from ``q/k/v`` + the checkpointed state via ``jax.vjp`` of the
  shared per-chunk math (``chunk_math.py``), and carries the reverse-mode
  state cotangents in VMEM scratch — one fused backward, no second
  XLA-scheduled forward.
* Arbitrary sequence lengths: inputs are zero-padded to a chunk multiple
  in the wrappers and outputs sliced back (final-state decay attenuation
  from the phantom tokens is divided back out).

VMEM budget at d = dv = 128, w = 256, fp32:
  state 3*(128*128) + 2*128 floats ~ 197 KB; blocks q/k/v/o 4*(256*128)
  ~ 512 KB; intra tiles (w,w) 3*(256*256) ~ 768 KB  => well under 16 MB.
The backward adds the 5 cotangent state buffers (~197 KB) and the VJP's
transposed intra tiles — still comfortably inside VMEM.

The container is CPU-only: tests run these kernels with ``interpret=True``
(the kernel body executes in Python) against ``ref.py``; on TPU hardware
the same ``pl.pallas_call`` lowers natively.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .chunk_math import decay_mats, hla2_chunk_math

# Back-compat alias (ahla_chunk and older call sites import it from here).
_decay_mats = decay_mats


def _state_shapes(d: int, dv: int):
    return ((d, d), (d, dv), (1, d), (d, dv), (1, d))


def _compiler_params(interpret: bool):
    if interpret:
        return None
    _CP = getattr(pltpu, "CompilerParams", None) or getattr(
        pltpu, "TPUCompilerParams"
    )
    return _CP(dimension_semantics=("parallel", "arbitrary"))


def _pad_chunk_multiple(n: int, w: int, *arrays):
    """Zero-pad time axis (axis 1) of each (BH, n, ·) array to a multiple of w."""
    pad = (-n) % w
    if pad == 0:
        return arrays
    return tuple(
        jnp.pad(a, ((0, 0), (0, pad), (0, 0))) for a in arrays
    )


def _unscale_padded_state(state, gamma, pad: int):
    """Undo the spurious gamma^pad decay phantom zero-tokens apply to the
    final carry (gamma^2pad on the cross summaries G, h)."""
    if gamma is None or pad == 0:
        return state
    inv = jnp.power(gamma.astype(jnp.float32), -float(pad))
    S, C, m, G, h = state
    return (
        S * inv[:, None, None],
        C * inv[:, None, None],
        m * inv[:, None],
        G * (inv**2)[:, None, None],
        h * (inv**2)[:, None],
    )


# --------------------------------------------------------------------------
# forward
# --------------------------------------------------------------------------


def _hla2_chunk_kernel(
    # inputs: gamma, q/k/v, then the initial carry (5) iff has_init
    gamma_ref,  # (1, 1) f32
    q_ref,  # (1, w, d)
    k_ref,  # (1, w, d)
    v_ref,  # (1, w, dv)
    # outputs: o, final state (5), then per-chunk states (5) iff save_states
    *rest,
    w: int,
    normalize: bool,
    eps: float,
    lam: float,
    has_decay: bool,
    has_init: bool,
    n_chunks: int,
    save_states: bool,
):
    if has_init:
        (S0_in, C0_in, m0_in, G0_in, h0_in) = rest[:5]
        rest = rest[5:]
    o_ref = rest[0]
    rest = rest[1:]
    if save_states:
        (S_out, C_out, m_out, G_out, h_out,
         Sc_out, Cc_out, mc_out, Gc_out, hc_out,
         S, C, m, G, h) = rest
    else:
        (S_out, C_out, m_out, G_out, h_out, S, C, m, G, h) = rest
    c = pl.program_id(1)
    f32 = jnp.float32

    @pl.when(c == 0)
    def _init():
        if has_init:
            S[...] = S0_in[0].astype(f32)
            C[...] = C0_in[0].astype(f32)
            m[...] = m0_in[0].astype(f32)
            G[...] = G0_in[0].astype(f32)
            h[...] = h0_in[0].astype(f32)
        else:
            S[...] = jnp.zeros_like(S)
            C[...] = jnp.zeros_like(C)
            m[...] = jnp.zeros_like(m)
            G[...] = jnp.zeros_like(G)
            h[...] = jnp.zeros_like(h)

    Q = q_ref[0].astype(f32)  # (w, d)
    K = k_ref[0].astype(f32)
    V = v_ref[0].astype(f32)
    if has_decay:
        g = gamma_ref[0, 0].astype(f32)
    else:
        g = jnp.ones((), f32)

    state0 = (S[...], C[...], m[...], G[...], h[...])
    if save_states:
        # checkpoint the *incoming* state — exactly what the reverse walk
        # needs to recompute this chunk.
        Sc_out[0, 0] = state0[0]
        Cc_out[0, 0] = state0[1]
        mc_out[0, 0] = state0[2]
        Gc_out[0, 0] = state0[3]
        hc_out[0, 0] = state0[4]

    o, state1 = hla2_chunk_math(
        Q, K, V, state0, g, normalize=normalize, eps=eps, lam=lam
    )
    o_ref[0, :, :] = o.astype(o_ref.dtype)
    S[...], C[...], m[...], G[...], h[...] = state1

    @pl.when(c == n_chunks - 1)
    def _write_state():
        S_out[0] = S[...].astype(S_out.dtype)
        C_out[0] = C[...].astype(C_out.dtype)
        m_out[0] = m[...].astype(m_out.dtype)
        G_out[0] = G[...].astype(G_out.dtype)
        h_out[0] = h[...].astype(h_out.dtype)


def hla2_chunk_pallas(
    q: jax.Array,  # (BH, n, d)
    k: jax.Array,  # (BH, n, d)
    v: jax.Array,  # (BH, n, dv)
    gamma: jax.Array | None = None,  # (BH,) or None
    *,
    chunk: int = 128,
    normalize: bool = False,
    eps: float = 1e-6,
    lam: float = 0.0,
    interpret: bool | None = None,
    save_chunk_states: bool = False,
    initial_state=None,
):
    """Fused forward.  Returns ``(o, (S, C, m, G, h))`` final state per row,
    plus the per-chunk incoming-state checkpoint tuple (shapes
    ``(BH, nc, ...)``) when ``save_chunk_states=True``.

    ``initial_state`` is an optional ``(S, C, m, G, h)`` carry per row
    (shapes ``(BH, d, d) / (BH, d, dv) / (BH, d) / (BH, d, dv) / (BH, d)``)
    the chunk walk resumes from — this is how a whole prompt prefills in a
    single chunk-parallel call that exactly reproduces the serial
    recurrence (the Section-4 identity; used by the serving engine).

    Arbitrary ``n``: inputs are zero-padded up to a chunk multiple and the
    output sliced back to ``n`` (the checkpoint tuple keeps the padded
    chunk count — feed it unchanged to ``hla2_chunk_bwd_pallas``).
    """
    BH, n, d = q.shape
    dv = v.shape[-1]
    w = min(chunk, n)
    pad = (-n) % w
    if pad:
        q, k, v = _pad_chunk_multiple(n, w, q, k, v)
    np_ = n + pad
    nc = np_ // w
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    has_decay = gamma is not None
    has_init = initial_state is not None
    if gamma is None:
        gamma_in = jnp.ones((BH, 1), jnp.float32)
    else:
        gamma_in = gamma.reshape(BH, 1).astype(jnp.float32)

    kernel = functools.partial(
        _hla2_chunk_kernel,
        w=w,
        normalize=normalize,
        eps=eps,
        lam=lam,
        has_decay=has_decay,
        has_init=has_init,
        n_chunks=nc,
        save_states=save_chunk_states,
    )
    state_shapes = _state_shapes(d, dv)
    out_shape = [
        jax.ShapeDtypeStruct((BH, np_, dv), v.dtype),
    ] + [
        jax.ShapeDtypeStruct((BH,) + s, jnp.float32) for s in state_shapes
    ]
    state_spec = lambda a, b: pl.BlockSpec(  # noqa: E731
        (1, a, b), lambda i, c: (i, 0, 0)
    )
    grid = (BH, nc)
    in_specs = [
            pl.BlockSpec((1, 1), lambda i, c: (i, 0)),  # gamma
            pl.BlockSpec((1, w, d), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, w, d), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, w, dv), lambda i, c: (i, c, 0)),
    ]
    inputs = [gamma_in, q, k, v]
    if has_init:
        S0, C0, m0, G0, h0 = initial_state
        inputs += [
            S0.astype(jnp.float32),
            C0.astype(jnp.float32),
            m0.reshape(BH, 1, d).astype(jnp.float32),
            G0.astype(jnp.float32),
            h0.reshape(BH, 1, d).astype(jnp.float32),
        ]
        in_specs += [state_spec(a, b) for a, b in state_shapes]
    out_specs = [
            pl.BlockSpec((1, w, dv), lambda i, c: (i, c, 0)),
    ] + [state_spec(a, b) for a, b in state_shapes]
    if save_chunk_states:
        out_shape += [
            jax.ShapeDtypeStruct((BH, nc) + s, jnp.float32)
            for s in state_shapes
        ]
        out_specs += [
            pl.BlockSpec((1, 1) + s, lambda i, c: (i, c, 0, 0))
            for s in state_shapes
        ]
    scratch_shapes = [pltpu.VMEM(s, jnp.float32) for s in state_shapes]
    outs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=scratch_shapes,
        interpret=interpret,
        compiler_params=_compiler_params(interpret),
    )(*inputs)
    o, S, C, m, G, h = outs[:6]
    o = o[:, :n]
    state = _unscale_padded_state((S, C, m[:, 0], G, h[:, 0]), gamma, pad)
    if save_chunk_states:
        return o, state, tuple(outs[6:])
    return o, state


# --------------------------------------------------------------------------
# backward
# --------------------------------------------------------------------------


def _hla2_chunk_bwd_kernel(
    # inputs
    gamma_ref,  # (1, 1) f32
    q_ref,  # (1, w, d)   — chunk nc-1-c (reversed walk)
    k_ref,
    v_ref,
    Sc_ref,  # (1, 1, d, d)   checkpointed incoming state of this chunk
    Cc_ref,  # (1, 1, d, dv)
    mc_ref,  # (1, 1, 1, d)
    Gc_ref,  # (1, 1, d, dv)
    hc_ref,  # (1, 1, 1, d)
    do_ref,  # (1, w, dv)
    # outputs
    dq_ref,  # (1, w, d)
    dk_ref,
    dv_ref,
    dg_ref,  # (1, 1) f32
    # scratch: reverse-mode state cotangents + dgamma accumulator
    dS,  # (d, d) f32
    dC,  # (d, dv)
    dm,  # (1, d)
    dG,  # (d, dv)
    dh,  # (1, d)
    dg_acc,  # (1, 1)
    *,
    w: int,
    normalize: bool,
    eps: float,
    lam: float,
    has_decay: bool,
    n_chunks: int,
):
    c = pl.program_id(1)  # grid step; actual chunk index is nc-1-c
    f32 = jnp.float32

    @pl.when(c == 0)
    def _init():
        # the forward discards the final carry, so its cotangent is zero
        dS[...] = jnp.zeros_like(dS)
        dC[...] = jnp.zeros_like(dC)
        dm[...] = jnp.zeros_like(dm)
        dG[...] = jnp.zeros_like(dG)
        dh[...] = jnp.zeros_like(dh)
        dg_acc[...] = jnp.zeros_like(dg_acc)

    Q = q_ref[0].astype(f32)
    K = k_ref[0].astype(f32)
    V = v_ref[0].astype(f32)
    dO = do_ref[0].astype(f32)
    state0 = (Sc_ref[0, 0], Cc_ref[0, 0], mc_ref[0, 0], Gc_ref[0, 0],
              hc_ref[0, 0])
    dstate1 = (dS[...], dC[...], dm[...], dG[...], dh[...])

    if has_decay:
        g = gamma_ref[0, 0].astype(f32)
        _, vjp = jax.vjp(
            functools.partial(
                hla2_chunk_math, normalize=normalize, eps=eps, lam=lam
            ),
            Q, K, V, state0, g,
        )
        dQ, dK, dV, dstate0, dgc = vjp((dO, dstate1))
        dg_acc[0, 0] += dgc
    else:
        one = jnp.ones((), f32)
        _, vjp = jax.vjp(
            lambda q_, k_, v_, st_: hla2_chunk_math(
                q_, k_, v_, st_, one, normalize=normalize, eps=eps, lam=lam
            ),
            Q, K, V, state0,
        )
        dQ, dK, dV, dstate0 = vjp((dO, dstate1))

    dq_ref[0] = dQ.astype(dq_ref.dtype)
    dk_ref[0] = dK.astype(dk_ref.dtype)
    dv_ref[0] = dV.astype(dv_ref.dtype)
    dS[...], dC[...], dm[...], dG[...], dh[...] = dstate0

    @pl.when(c == n_chunks - 1)
    def _write_dg():
        dg_ref[0, 0] = dg_acc[0, 0]


def hla2_chunk_bwd_pallas(
    q: jax.Array,  # (BH, n, d)
    k: jax.Array,
    v: jax.Array,  # (BH, n, dv)
    gamma: jax.Array | None,
    do: jax.Array,  # (BH, n, dv) output cotangent
    chunk_states,  # per-chunk incoming states from the forward (padded nc)
    *,
    chunk: int = 128,
    normalize: bool = False,
    eps: float = 1e-6,
    lam: float = 0.0,
    interpret: bool | None = None,
):
    """Fused backward: reverse chunk walk with checkpointed states.

    Returns ``(dq, dk, dv, dgamma)`` (``dgamma`` is None iff gamma is None).
    """
    BH, n, d = q.shape
    dv_ = v.shape[-1]
    w = min(chunk, n)
    pad = (-n) % w
    if pad:
        q, k, v, do = _pad_chunk_multiple(n, w, q, k, v, do)
    np_ = n + pad
    nc = np_ // w
    assert chunk_states[0].shape[1] == nc, (
        "chunk_states do not match the (padded) chunk grid; pass the tuple "
        "returned by hla2_chunk_pallas(save_chunk_states=True) unchanged"
    )
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    has_decay = gamma is not None
    gamma_in = (
        jnp.ones((BH, 1), jnp.float32)
        if gamma is None
        else gamma.reshape(BH, 1).astype(jnp.float32)
    )

    kernel = functools.partial(
        _hla2_chunk_bwd_kernel,
        w=w,
        normalize=normalize,
        eps=eps,
        lam=lam,
        has_decay=has_decay,
        n_chunks=nc,
    )
    state_shapes = _state_shapes(d, dv_)
    grid = (BH, nc)
    rev_blk = lambda i, c: (i, nc - 1 - c, 0)  # noqa: E731
    rev_st = lambda i, c: (i, nc - 1 - c, 0, 0)  # noqa: E731
    in_specs = [
        pl.BlockSpec((1, 1), lambda i, c: (i, 0)),  # gamma
        pl.BlockSpec((1, w, d), rev_blk),
        pl.BlockSpec((1, w, d), rev_blk),
        pl.BlockSpec((1, w, dv_), rev_blk),
    ] + [
        pl.BlockSpec((1, 1) + s, rev_st) for s in state_shapes
    ] + [
        pl.BlockSpec((1, w, dv_), rev_blk),  # do
    ]
    out_specs = [
        pl.BlockSpec((1, w, d), rev_blk),
        pl.BlockSpec((1, w, d), rev_blk),
        pl.BlockSpec((1, w, dv_), rev_blk),
        pl.BlockSpec((1, 1), lambda i, c: (i, 0)),  # dgamma
    ]
    out_shape = [
        jax.ShapeDtypeStruct((BH, np_, d), q.dtype),
        jax.ShapeDtypeStruct((BH, np_, d), k.dtype),
        jax.ShapeDtypeStruct((BH, np_, dv_), v.dtype),
        jax.ShapeDtypeStruct((BH, 1), jnp.float32),
    ]
    scratch_shapes = [pltpu.VMEM(s, jnp.float32) for s in state_shapes]
    scratch_shapes.append(pltpu.VMEM((1, 1), jnp.float32))
    dq, dk, dv, dg = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=scratch_shapes,
        interpret=interpret,
        compiler_params=_compiler_params(interpret),
    )(gamma_in, q, k, v, *chunk_states, do)
    dq, dk, dv = dq[:, :n], dk[:, :n], dv[:, :n]
    dgamma = dg[:, 0] if has_decay else None
    return dq, dk, dv, dgamma
