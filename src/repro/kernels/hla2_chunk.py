"""Fused chunkwise masked second-order HLA forward — Pallas TPU kernel.

Design (DESIGN.md §2, hardware adaptation):

* Grid ``(BH, n_chunks)`` with ``dimension_semantics=("parallel",
  "arbitrary")``: the batch×head axis parallelizes across cores, the chunk
  axis is sequential and carries the running state tuple
  ``(S, C, m, G, h)`` in **VMEM scratch** — the state never round-trips to
  HBM between chunks (the main win over the XLA-scheduled jnp version).
* Every intra-chunk contraction is an MXU-shaped matmul on ``(w, d)`` /
  ``(w, w)`` tiles: choose ``w`` and ``d`` multiples of 128 on real TPUs.
* bf16/fp32 inputs; all accumulation in fp32 via ``preferred_element_type``.
* Per-(batch,head) scalar decay ``gamma``; masks are built in-kernel with
  ``broadcasted_iota`` (no host-side (w, w) constants shipped per head).

VMEM budget at d = dv = 128, w = 256, fp32:
  state 3*(128*128) + 2*128 floats ~ 197 KB; blocks q/k/v/o 4*(256*128)
  ~ 512 KB; intra tiles (w,w) 3*(256*256) ~ 768 KB  => well under 16 MB.

The container is CPU-only: tests run this kernel with ``interpret=True``
(the kernel body executes in Python) against ``ref.py``; on TPU hardware
the same ``pl.pallas_call`` lowers natively.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _decay_mats(w: int, g, dtype):
    """In-kernel L_gamma, g^(t+1), g^(w-1-t) from scalar g (g=1 => plain L)."""
    t = jax.lax.broadcasted_iota(jnp.int32, (w, w), 0)
    j = jax.lax.broadcasted_iota(jnp.int32, (w, w), 1)
    diff = (t - j).astype(dtype)
    mask = t >= j
    logg = jnp.log(g)
    Lg = jnp.where(mask, jnp.exp(diff * logg), jnp.zeros((), dtype))
    tv = jax.lax.iota(dtype, w)
    pow_t = jnp.exp((tv + 1.0) * logg)  # g^t for t=1..w
    pow_rev = jnp.exp((w - 1.0 - tv) * logg)  # g^(w-t) for t=1..w
    return Lg, pow_t, pow_rev, mask


def _hla2_chunk_kernel(
    # inputs
    gamma_ref,  # (1, 1) f32
    q_ref,  # (1, w, d)
    k_ref,  # (1, w, d)
    v_ref,  # (1, w, dv)
    # outputs
    o_ref,  # (1, w, dv)
    S_out,  # (1, d, d)
    C_out,  # (1, d, dv)
    m_out,  # (1, 1, d)
    G_out,  # (1, d, dv)
    h_out,  # (1, 1, d)
    # scratch (persist across the sequential chunk axis)
    S,  # (d, d) f32
    C,  # (d, dv) f32
    m,  # (1, d) f32
    G,  # (d, dv) f32
    h,  # (1, d) f32
    *,
    w: int,
    normalize: bool,
    eps: float,
    lam: float,
    has_decay: bool,
    n_chunks: int,
):
    c = pl.program_id(1)
    f32 = jnp.float32

    @pl.when(c == 0)
    def _init():
        S[...] = jnp.zeros_like(S)
        C[...] = jnp.zeros_like(C)
        m[...] = jnp.zeros_like(m)
        G[...] = jnp.zeros_like(G)
        h[...] = jnp.zeros_like(h)

    Q = q_ref[0].astype(f32)  # (w, d)
    K = k_ref[0].astype(f32)
    V = v_ref[0].astype(f32)

    if has_decay:
        g = gamma_ref[0, 0].astype(f32)
    else:
        g = jnp.ones((), f32)
    Lg, pow_t, pow_rev, mask = _decay_mats(w, g, f32)
    t = jax.lax.broadcasted_iota(jnp.int32, (w, w), 0)
    j = jax.lax.broadcasted_iota(jnp.int32, (w, w), 1)
    U = (t <= j).astype(f32)  # i <= j (upper incl)
    Ls = (t > j).astype(f32)  # strict lower

    S0, C0, m0, G0, h0 = S[...], C[...], m[...], G[...], h[...]

    dot = functools.partial(jax.lax.dot_general, preferred_element_type=f32)
    mm = lambda a, b: dot(a, b, (((1,), (0,)), ((), ())))  # noqa: E731
    mmT = lambda a, b: dot(a, b, (((1,), (1,)), ((), ())))  # noqa: E731  a @ b.T

    A = mmT(Q, K) * Lg  # (w, w)   (QK^T) . Lg
    Bm = mmT(K, Q) * U  # B[i, j] = (k_i . q_j) masked i<=j
    M3 = mm(A, Bm) * Lg
    QS0 = mm(Q, S0)  # (w, d)
    QS0Q = mmT(QS0, Q) * Lg

    D0 = mm(S0, C0) - G0  # (d, dv)
    T1 = (pow_t**2)[:, None] * mm(Q, D0)
    T2 = pow_t[:, None] * mm(QS0Q, V)
    T3 = mm(M3, V)
    num = T1 + T2 + T3
    if lam:
        Wqq = mmT(Q, Q) * Lg
        num = num + lam * (pow_t[:, None] * mm(Q, C0) + mm(Wqq, V))
    if normalize:
        d0v = mm(S0, m0.T) - h0.T  # (d, 1)
        den = (
            (pow_t**2)[:, None] * mm(Q, d0v)
            + pow_t[:, None] * jnp.sum(QS0Q, -1, keepdims=True)
            + jnp.sum(M3, -1, keepdims=True)
        )
        if lam:
            den = den + lam * (
                pow_t[:, None] * mm(Q, m0.T) + jnp.sum(Wqq, -1, keepdims=True)
            )
        o = num / (den + eps)
    else:
        o = num
    o_ref[0, :, :] = o.astype(o_ref.dtype)

    # ---- carry update (monoid, B = whole chunk) ----
    rho = jnp.exp(jnp.log(g) * w)
    Kg = pow_rev[:, None] * K
    Qg = pow_rev[:, None] * Q
    Sw = dot(Kg, K, (((0,), (0,)), ((), ())))  # (d, d)
    Cw = dot(Qg, V, (((0,), (0,)), ((), ())))  # (d, dv)
    mw = jnp.sum(Qg, 0, keepdims=True)  # (1, d)
    N = mmT(K, Q) * Ls
    Vg = pow_rev[:, None] * V
    NVg = mm(N, Vg)
    Gw = dot(Kg, NVg, (((0,), (0,)), ((), ())))
    Nmg = jnp.sum(N * pow_rev[None, :], -1, keepdims=True)  # (w, 1)
    hw = dot(Nmg, Kg, (((0,), (0,)), ((), ())))  # (1, d)

    S[...] = rho * S0 + Sw
    C[...] = rho * C0 + Cw
    m[...] = rho * m0 + mw
    G[...] = rho**2 * G0 + Gw + rho * mm(Sw, C0)
    h[...] = rho**2 * h0 + hw + rho * mm(m0, Sw.T)

    @pl.when(c == n_chunks - 1)
    def _write_state():
        S_out[0] = S[...].astype(S_out.dtype)
        C_out[0] = C[...].astype(C_out.dtype)
        m_out[0] = m[...].astype(m_out.dtype)
        G_out[0] = G[...].astype(G_out.dtype)
        h_out[0] = h[...].astype(h_out.dtype)


def hla2_chunk_pallas(
    q: jax.Array,  # (BH, n, d)
    k: jax.Array,  # (BH, n, d)
    v: jax.Array,  # (BH, n, dv)
    gamma: jax.Array | None = None,  # (BH,) or None
    *,
    chunk: int = 128,
    normalize: bool = False,
    eps: float = 1e-6,
    lam: float = 0.0,
    interpret: bool | None = None,
):
    """Fused forward.  Returns (o, (S, C, m, G, h)) final state per row."""
    BH, n, d = q.shape
    dv = v.shape[-1]
    w = min(chunk, n)
    assert n % w == 0, "pad sequences to a multiple of the chunk width"
    nc = n // w
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    has_decay = gamma is not None
    if gamma is None:
        gamma_in = jnp.ones((BH, 1), jnp.float32)
    else:
        gamma_in = gamma.reshape(BH, 1).astype(jnp.float32)

    kernel = functools.partial(
        _hla2_chunk_kernel,
        w=w,
        normalize=normalize,
        eps=eps,
        lam=lam,
        has_decay=has_decay,
        n_chunks=nc,
    )
    out_shape = (
        jax.ShapeDtypeStruct((BH, n, dv), v.dtype),
        jax.ShapeDtypeStruct((BH, d, d), jnp.float32),
        jax.ShapeDtypeStruct((BH, d, dv), jnp.float32),
        jax.ShapeDtypeStruct((BH, 1, d), jnp.float32),
        jax.ShapeDtypeStruct((BH, d, dv), jnp.float32),
        jax.ShapeDtypeStruct((BH, 1, d), jnp.float32),
    )
    state_spec = lambda a, b: pl.BlockSpec(  # noqa: E731
        (1, a, b), lambda i, c: (i, 0, 0)
    )
    grid = (BH, nc)
    in_specs = [
            pl.BlockSpec((1, 1), lambda i, c: (i, 0)),  # gamma
            pl.BlockSpec((1, w, d), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, w, d), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, w, dv), lambda i, c: (i, c, 0)),
    ]
    out_specs = [
            pl.BlockSpec((1, w, dv), lambda i, c: (i, c, 0)),
            state_spec(d, d),
            state_spec(d, dv),
            state_spec(1, d),
            state_spec(d, dv),
            state_spec(1, d),
    ]
    scratch_shapes = [
        pltpu.VMEM((d, d), jnp.float32),
        pltpu.VMEM((d, dv), jnp.float32),
        pltpu.VMEM((1, d), jnp.float32),
        pltpu.VMEM((d, dv), jnp.float32),
        pltpu.VMEM((1, d), jnp.float32),
    ]
    compiler_params = None
    if not interpret:
        _CP = getattr(pltpu, "CompilerParams", None) or getattr(
            pltpu, "TPUCompilerParams"
        )
        compiler_params = _CP(dimension_semantics=("parallel", "arbitrary"))
    o, S, C, m, G, h = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=scratch_shapes,
        interpret=interpret,
        compiler_params=compiler_params,
    )(gamma_in, q, k, v)
    return o, (S, C, m[:, 0], G, h[:, 0])
