"""Fused chunkwise AHLA — Pallas TPU kernels (fwd + bwd).

AHLA = LinAttn o LinAttn (DESIGN.md §2): both passes are fused in one
kernel so the intermediate first-order outputs ``r`` never leave VMEM.
The carry ``(P | m, E | n)`` (den columns augmented) persists in VMEM
scratch across the sequential chunk axis.  Grid/BlockSpec layout mirrors
``hla2_chunk.py``, as does the training path (DESIGN.md §3): the forward
can checkpoint each chunk's incoming ``(P, E)`` to HBM and
``ahla_chunk_bwd_pallas`` walks the chunks in reverse, recomputing the
intra-chunk tiles via ``jax.vjp`` of the shared per-chunk math while the
state cotangents live in VMEM scratch.  Arbitrary sequence lengths are
handled by zero-padding to a chunk multiple in the wrappers.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .chunk_math import ahla_chunk_math
from .hla2_chunk import _compiler_params, _pad_chunk_multiple


def _unscale_padded_state(Pa, Ea, gamma, pad: int):
    """Phantom zero-tokens only decay the AHLA carry (all at rate gamma):
    divide the spurious gamma^pad back out."""
    if gamma is None or pad == 0:
        return Pa, Ea
    inv = jnp.power(gamma.astype(jnp.float32), -float(pad))[:, None, None]
    return Pa * inv, Ea * inv


# --------------------------------------------------------------------------
# forward
# --------------------------------------------------------------------------


def _ahla_chunk_kernel(
    gamma_ref,  # (1, 1)
    q_ref,  # (1, w, d)
    k_ref,  # (1, w, d)
    v_ref,  # (1, w, dv)
    *rest,  # [P0_in, E0_in iff has_init], o, P_out, E_out,
    #         [Pc_out, Ec_out iff save_states], scratch P, E
    w: int,
    normalize: bool,
    eps: float,
    has_decay: bool,
    has_init: bool,
    n_chunks: int,
    save_states: bool,
):
    if has_init:
        P0_in, E0_in = rest[:2]
        rest = rest[2:]
    o_ref, P_out, E_out = rest[:3]
    rest = rest[3:]
    if save_states:
        Pc_out, Ec_out, P, E = rest
    else:
        P, E = rest
    c = pl.program_id(1)
    f32 = jnp.float32

    @pl.when(c == 0)
    def _init():
        if has_init:
            P[...] = P0_in[0].astype(f32)
            E[...] = E0_in[0].astype(f32)
        else:
            P[...] = jnp.zeros_like(P)
            E[...] = jnp.zeros_like(E)

    Q = q_ref[0].astype(f32)
    K = k_ref[0].astype(f32)
    V = v_ref[0].astype(f32)
    g = gamma_ref[0, 0].astype(f32) if has_decay else jnp.ones((), f32)

    state0 = (P[...], E[...])
    if save_states:
        Pc_out[0, 0] = state0[0]
        Ec_out[0, 0] = state0[1]

    o, state1 = ahla_chunk_math(
        Q, K, V, state0, g, normalize=normalize, eps=eps
    )
    o_ref[0, :, :] = o.astype(o_ref.dtype)
    P[...], E[...] = state1

    @pl.when(c == n_chunks - 1)
    def _write_state():
        P_out[0] = P[...].astype(P_out.dtype)
        E_out[0] = E[...].astype(E_out.dtype)


def ahla_chunk_pallas(
    q: jax.Array,  # (BH, n, d)
    k: jax.Array,
    v: jax.Array,
    gamma: jax.Array | None = None,
    *,
    chunk: int = 128,
    normalize: bool = False,
    eps: float = 1e-6,
    interpret: bool | None = None,
    save_chunk_states: bool = False,
    initial_state=None,
):
    """Fused AHLA forward.  Returns ``(o, (P, m, E, n))``, plus the
    per-chunk incoming ``([P|m], [E|n])`` checkpoints (``(BH, nc, d, dv+1)``)
    when ``save_chunk_states=True``.  Arbitrary ``n`` is zero-padded to a
    chunk multiple and sliced back.

    ``initial_state`` is an optional ``(P, m, E, n)`` carry per row
    (``(BH, d, dv) / (BH, d) / (BH, d, dv) / (BH, d)``) the chunk walk
    resumes from — one chunk-parallel call prefills a whole prompt exactly
    (serving engine prefill path)."""
    BH, n, d = q.shape
    dv = v.shape[-1]
    w = min(chunk, n)
    pad = (-n) % w
    if pad:
        q, k, v = _pad_chunk_multiple(n, w, q, k, v)
    np_ = n + pad
    nc = np_ // w
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    has_decay = gamma is not None
    has_init = initial_state is not None
    gamma_in = (
        jnp.ones((BH, 1), jnp.float32)
        if gamma is None
        else gamma.reshape(BH, 1).astype(jnp.float32)
    )
    kernel = functools.partial(
        _ahla_chunk_kernel,
        w=w,
        normalize=normalize,
        eps=eps,
        has_decay=has_decay,
        has_init=has_init,
        n_chunks=nc,
        save_states=save_chunk_states,
    )
    out_shape = [
        jax.ShapeDtypeStruct((BH, np_, dv), v.dtype),
        jax.ShapeDtypeStruct((BH, d, dv + 1), jnp.float32),
        jax.ShapeDtypeStruct((BH, d, dv + 1), jnp.float32),
    ]
    grid = (BH, nc)
    in_specs = [
            pl.BlockSpec((1, 1), lambda i, c: (i, 0)),
            pl.BlockSpec((1, w, d), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, w, d), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, w, dv), lambda i, c: (i, c, 0)),
    ]
    inputs = [gamma_in, q, k, v]
    if has_init:
        P0, m0, E0, n0 = initial_state
        f32 = jnp.float32
        Pbar = jnp.concatenate(
            [P0.astype(f32), m0.astype(f32)[..., None]], axis=-1
        )
        Ebar = jnp.concatenate(
            [E0.astype(f32), n0.astype(f32)[..., None]], axis=-1
        )
        inputs += [Pbar, Ebar]
        in_specs += [
            pl.BlockSpec((1, d, dv + 1), lambda i, c: (i, 0, 0))
            for _ in range(2)
        ]
    out_specs = [
            pl.BlockSpec((1, w, dv), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, d, dv + 1), lambda i, c: (i, 0, 0)),
            pl.BlockSpec((1, d, dv + 1), lambda i, c: (i, 0, 0)),
    ]
    if save_chunk_states:
        out_shape += [
            jax.ShapeDtypeStruct((BH, nc, d, dv + 1), jnp.float32)
            for _ in range(2)
        ]
        out_specs += [
            pl.BlockSpec((1, 1, d, dv + 1), lambda i, c: (i, c, 0, 0))
            for _ in range(2)
        ]
    scratch_shapes = [
        pltpu.VMEM((d, dv + 1), jnp.float32),
        pltpu.VMEM((d, dv + 1), jnp.float32),
    ]
    outs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=scratch_shapes,
        interpret=interpret,
        compiler_params=_compiler_params(interpret),
    )(*inputs)
    o, Pa, Ea = outs[:3]
    o = o[:, :n]
    Pa, Ea = _unscale_padded_state(Pa, Ea, gamma, pad)
    state = (Pa[..., :dv], Pa[..., dv], Ea[..., :dv], Ea[..., dv])
    if save_chunk_states:
        return o, state, tuple(outs[3:])
    return o, state


# --------------------------------------------------------------------------
# backward
# --------------------------------------------------------------------------


def _ahla_chunk_bwd_kernel(
    gamma_ref,  # (1, 1)
    q_ref,  # (1, w, d)  — chunk nc-1-c (reversed walk)
    k_ref,
    v_ref,
    Pc_ref,  # (1, 1, d, dv+1) checkpointed incoming [P|m]
    Ec_ref,  # (1, 1, d, dv+1) checkpointed incoming [E|n]
    do_ref,  # (1, w, dv)
    dq_ref,
    dk_ref,
    dv_ref,
    dg_ref,  # (1, 1)
    dP,  # scratch (d, dv+1) f32 — state cotangents
    dE,  # scratch (d, dv+1) f32
    dg_acc,  # scratch (1, 1) f32
    *,
    w: int,
    normalize: bool,
    eps: float,
    has_decay: bool,
    n_chunks: int,
):
    c = pl.program_id(1)
    f32 = jnp.float32

    @pl.when(c == 0)
    def _init():
        dP[...] = jnp.zeros_like(dP)
        dE[...] = jnp.zeros_like(dE)
        dg_acc[...] = jnp.zeros_like(dg_acc)

    Q = q_ref[0].astype(f32)
    K = k_ref[0].astype(f32)
    V = v_ref[0].astype(f32)
    dO = do_ref[0].astype(f32)
    state0 = (Pc_ref[0, 0], Ec_ref[0, 0])
    dstate1 = (dP[...], dE[...])

    if has_decay:
        g = gamma_ref[0, 0].astype(f32)
        _, vjp = jax.vjp(
            functools.partial(ahla_chunk_math, normalize=normalize, eps=eps),
            Q, K, V, state0, g,
        )
        dQ, dK, dV, dstate0, dgc = vjp((dO, dstate1))
        dg_acc[0, 0] += dgc
    else:
        one = jnp.ones((), f32)
        _, vjp = jax.vjp(
            lambda q_, k_, v_, st_: ahla_chunk_math(
                q_, k_, v_, st_, one, normalize=normalize, eps=eps
            ),
            Q, K, V, state0,
        )
        dQ, dK, dV, dstate0 = vjp((dO, dstate1))

    dq_ref[0] = dQ.astype(dq_ref.dtype)
    dk_ref[0] = dK.astype(dk_ref.dtype)
    dv_ref[0] = dV.astype(dv_ref.dtype)
    dP[...], dE[...] = dstate0

    @pl.when(c == n_chunks - 1)
    def _write_dg():
        dg_ref[0, 0] = dg_acc[0, 0]


def ahla_chunk_bwd_pallas(
    q: jax.Array,  # (BH, n, d)
    k: jax.Array,
    v: jax.Array,
    gamma: jax.Array | None,
    do: jax.Array,  # (BH, n, dv)
    chunk_states,  # ([P|m], [E|n]) checkpoints from the forward
    *,
    chunk: int = 128,
    normalize: bool = False,
    eps: float = 1e-6,
    interpret: bool | None = None,
):
    """Fused AHLA backward (reverse chunk walk).  Returns (dq, dk, dv, dgamma)."""
    BH, n, d = q.shape
    dv_ = v.shape[-1]
    w = min(chunk, n)
    pad = (-n) % w
    if pad:
        q, k, v, do = _pad_chunk_multiple(n, w, q, k, v, do)
    np_ = n + pad
    nc = np_ // w
    assert chunk_states[0].shape[1] == nc, (
        "chunk_states do not match the (padded) chunk grid; pass the tuple "
        "returned by ahla_chunk_pallas(save_chunk_states=True) unchanged"
    )
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    has_decay = gamma is not None
    gamma_in = (
        jnp.ones((BH, 1), jnp.float32)
        if gamma is None
        else gamma.reshape(BH, 1).astype(jnp.float32)
    )
    kernel = functools.partial(
        _ahla_chunk_bwd_kernel,
        w=w,
        normalize=normalize,
        eps=eps,
        has_decay=has_decay,
        n_chunks=nc,
    )
    grid = (BH, nc)
    rev_blk = lambda i, c: (i, nc - 1 - c, 0)  # noqa: E731
    rev_st = lambda i, c: (i, nc - 1 - c, 0, 0)  # noqa: E731
    in_specs = [
        pl.BlockSpec((1, 1), lambda i, c: (i, 0)),
        pl.BlockSpec((1, w, d), rev_blk),
        pl.BlockSpec((1, w, d), rev_blk),
        pl.BlockSpec((1, w, dv_), rev_blk),
        pl.BlockSpec((1, 1, d, dv_ + 1), rev_st),
        pl.BlockSpec((1, 1, d, dv_ + 1), rev_st),
        pl.BlockSpec((1, w, dv_), rev_blk),
    ]
    out_specs = [
        pl.BlockSpec((1, w, d), rev_blk),
        pl.BlockSpec((1, w, d), rev_blk),
        pl.BlockSpec((1, w, dv_), rev_blk),
        pl.BlockSpec((1, 1), lambda i, c: (i, 0)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((BH, np_, d), q.dtype),
        jax.ShapeDtypeStruct((BH, np_, d), k.dtype),
        jax.ShapeDtypeStruct((BH, np_, dv_), v.dtype),
        jax.ShapeDtypeStruct((BH, 1), jnp.float32),
    ]
    scratch_shapes = [
        pltpu.VMEM((d, dv_ + 1), jnp.float32),
        pltpu.VMEM((d, dv_ + 1), jnp.float32),
        pltpu.VMEM((1, 1), jnp.float32),
    ]
    dq, dk, dv, dg = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=scratch_shapes,
        interpret=interpret,
        compiler_params=_compiler_params(interpret),
    )(gamma_in, q, k, v, *chunk_states, do)
    dq, dk, dv = dq[:, :n], dk[:, :n], dv[:, :n]
    dgamma = dg[:, 0] if has_decay else None
    return dq, dk, dv, dgamma
