"""Fused chunkwise AHLA forward — Pallas TPU kernel.

AHLA = LinAttn o LinAttn (DESIGN.md §2): both passes are fused in one
kernel so the intermediate first-order outputs ``r`` never leave VMEM.
The carry ``(P | m, E | n)`` (den columns augmented) persists in VMEM
scratch across the sequential chunk axis.  Grid/BlockSpec layout mirrors
``hla2_chunk.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .hla2_chunk import _decay_mats


def _ahla_chunk_kernel(
    gamma_ref,  # (1, 1)
    q_ref,  # (1, w, d)
    k_ref,  # (1, w, d)
    v_ref,  # (1, w, dv)
    o_ref,  # (1, w, dv)
    P_out,  # (1, d, dv+1)   [P | m]
    E_out,  # (1, d, dv+1)   [E | n]
    P,  # scratch (d, dv+1)
    E,  # scratch (d, dv+1)
    *,
    w: int,
    normalize: bool,
    eps: float,
    has_decay: bool,
    n_chunks: int,
):
    c = pl.program_id(1)
    f32 = jnp.float32

    @pl.when(c == 0)
    def _init():
        P[...] = jnp.zeros_like(P)
        E[...] = jnp.zeros_like(E)

    Q = q_ref[0].astype(f32)
    K = k_ref[0].astype(f32)
    V = v_ref[0].astype(f32)
    Vb = jnp.concatenate([V, jnp.ones((w, 1), f32)], axis=-1)

    g = gamma_ref[0, 0].astype(f32) if has_decay else jnp.ones((), f32)
    Lg, pow_t, pow_rev, mask = _decay_mats(w, g, f32)

    dot = functools.partial(jax.lax.dot_general, preferred_element_type=f32)
    mm = lambda a, b: dot(a, b, (((1,), (0,)), ((), ())))  # noqa: E731
    mmT = lambda a, b: dot(a, b, (((1,), (1,)), ((), ())))  # noqa: E731

    P0, E0 = P[...], E[...]
    A = mmT(Q, K) * Lg
    AV = mm(A, Vb)  # local first-order outputs
    r = pow_t[:, None] * mm(Q, P0) + AV  # carry-inclusive r_t | s_t
    o_aug = pow_t[:, None] * mm(Q, E0) + mm(A, r)
    if normalize:
        o = o_aug[:, :-1] / (o_aug[:, -1:] + eps)
    else:
        o = o_aug[:, :-1]
    o_ref[0, :, :] = o.astype(o_ref.dtype)

    rho = jnp.exp(jnp.log(g) * w)
    Kg = pow_rev[:, None] * K
    KgT_ = lambda X: dot(Kg, X, (((0,), (0,)), ((), ())))  # noqa: E731
    R = dot(K, Q, (((0,), (0,)), ((), ())))  # (d, d) = sum_t k_t q_t^T (undecayed)
    P_new = rho * P0 + KgT_(Vb)
    E_new = rho * E0 + KgT_(AV) + rho * mm(R, P0)
    P[...] = P_new
    E[...] = E_new

    @pl.when(c == n_chunks - 1)
    def _write_state():
        P_out[0] = P[...].astype(P_out.dtype)
        E_out[0] = E[...].astype(E_out.dtype)


def ahla_chunk_pallas(
    q: jax.Array,  # (BH, n, d)
    k: jax.Array,
    v: jax.Array,
    gamma: jax.Array | None = None,
    *,
    chunk: int = 128,
    normalize: bool = False,
    eps: float = 1e-6,
    interpret: bool | None = None,
):
    """Fused AHLA forward.  Returns (o, (P, m, E, n))."""
    BH, n, d = q.shape
    dv = v.shape[-1]
    w = min(chunk, n)
    assert n % w == 0, "pad sequences to a multiple of the chunk width"
    nc = n // w
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    has_decay = gamma is not None
    gamma_in = (
        jnp.ones((BH, 1), jnp.float32)
        if gamma is None
        else gamma.reshape(BH, 1).astype(jnp.float32)
    )
    kernel = functools.partial(
        _ahla_chunk_kernel,
        w=w,
        normalize=normalize,
        eps=eps,
        has_decay=has_decay,
        n_chunks=nc,
    )
    out_shape = (
        jax.ShapeDtypeStruct((BH, n, dv), v.dtype),
        jax.ShapeDtypeStruct((BH, d, dv + 1), jnp.float32),
        jax.ShapeDtypeStruct((BH, d, dv + 1), jnp.float32),
    )
    grid = (BH, nc)
    in_specs = [
            pl.BlockSpec((1, 1), lambda i, c: (i, 0)),
            pl.BlockSpec((1, w, d), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, w, d), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, w, dv), lambda i, c: (i, c, 0)),
    ]
    out_specs = [
            pl.BlockSpec((1, w, dv), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, d, dv + 1), lambda i, c: (i, 0, 0)),
            pl.BlockSpec((1, d, dv + 1), lambda i, c: (i, 0, 0)),
    ]
    scratch_shapes = [
        pltpu.VMEM((d, dv + 1), jnp.float32),
        pltpu.VMEM((d, dv + 1), jnp.float32),
    ]
    compiler_params = None
    if not interpret:
        _CP = getattr(pltpu, "CompilerParams", None) or getattr(
            pltpu, "TPUCompilerParams"
        )
        compiler_params = _CP(dimension_semantics=("parallel", "arbitrary"))
    o, Pa, Ea = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=scratch_shapes,
        interpret=interpret,
        compiler_params=compiler_params,
    )(gamma_in, q, k, v)
    return o, (Pa[..., :dv], Pa[..., dv], Ea[..., :dv], Ea[..., dv])
