"""Fused batched decode steps — Pallas TPU kernels for the serving hot path.

One token of the streaming recurrence (paper Fig. 1(A) / Theorem 3.1 for
HLA2, Algorithm 2 for AHLA) applied to **every slot in one launch**:

* Grid ``(BH,)`` with ``dimension_semantics=("parallel",)`` — each program
  owns one (batch*head) row; there is no sequential axis, so all slots'
  state updates and outputs happen in a single kernel dispatch instead of
  the einsum chain in ``core/hla2.py`` (each einsum a separate HBM
  round-trip of the state under XLA).
* ``input_output_aliases`` alias every state operand to its output — the
  O(1) decode state is updated in place in HBM, never copied.
* All math in fp32 (matches the jnp steps bit-for-bit up to reassociation);
  the jnp fallback (``core.hla2.hla2_step`` / ``core.ahla.ahla_step``)
  stays the CPU path and the exactness oracle.

The container is CPU-only: tests run these kernels with ``interpret=True``;
on TPU the same ``pl.pallas_call`` lowers natively.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .hla2_chunk import _state_shapes


def _step_compiler_params(interpret: bool):
    if interpret:
        return None
    from jax.experimental.pallas import tpu as pltpu

    _CP = getattr(pltpu, "CompilerParams", None) or getattr(
        pltpu, "TPUCompilerParams"
    )
    return _CP(dimension_semantics=("parallel",))


def _dot(a, b):
    return jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )


def _dot_t(a, b):  # a @ b.T
    return jax.lax.dot_general(
        a, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )


def _outer(a, b):  # a.T @ b  with a (1, d), b (1, e) -> (d, e)
    return jax.lax.dot_general(
        a, b, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )


# --------------------------------------------------------------------------
# HLA2
# --------------------------------------------------------------------------


def _hla2_step_kernel(
    gamma_ref,  # (1, 1) f32
    q_ref,  # (1, 1, d)
    k_ref,  # (1, 1, d)
    v_ref,  # (1, 1, dv)
    S_ref,  # (1, d, d)   aliased in/out
    C_ref,  # (1, d, dv)
    m_ref,  # (1, 1, d)
    G_ref,  # (1, d, dv)
    h_ref,  # (1, 1, d)
    o_ref,  # (1, 1, dv)
    S_out,
    C_out,
    m_out,
    G_out,
    h_out,
    *,
    normalize: bool,
    eps: float,
    lam: float,
    has_decay: bool,
):
    f32 = jnp.float32
    q = q_ref[0].astype(f32)  # (1, d)
    k = k_ref[0].astype(f32)
    v = v_ref[0].astype(f32)
    g = gamma_ref[0, 0].astype(f32) if has_decay else jnp.ones((), f32)

    S0, C0, m0, G0, h0 = (
        S_ref[0], C_ref[0], m_ref[0], G_ref[0], h_ref[0]
    )

    # cross summaries first: strict causality consumes the *previous* C, m
    kC = _dot(k, C0)  # (1, dv)
    km = _dot_t(k, m0)  # (1, 1)
    G1 = g * g * G0 + g * _outer(k, kC)
    h1 = g * g * h0 + g * km * k
    S1 = g * S0 + _outer(k, k)
    C1 = g * C0 + _outer(q, v)
    m1 = g * m0 + q

    u = _dot(q, S1)  # (1, d)
    num = _dot(u, C1) - _dot(q, G1)
    if lam:
        num = num + lam * _dot(q, C1)
    if normalize:
        den = _dot_t(u, m1) - _dot_t(q, h1)
        if lam:
            den = den + lam * _dot_t(q, m1)
        o = num / (den + eps)
    else:
        o = num

    o_ref[0] = o.astype(o_ref.dtype)
    S_out[0] = S1
    C_out[0] = C1
    m_out[0] = m1
    G_out[0] = G1
    h_out[0] = h1


def hla2_step_pallas(
    state,  # (S, C, m, G, h) with leading (..., d, ...) batch dims
    q_t: jax.Array,  # (..., d)
    k_t: jax.Array,
    v_t: jax.Array,  # (..., dv)
    gamma=None,  # broadcastable to the batch dims, or None
    *,
    normalize: bool = False,
    eps: float = 1e-6,
    lam: float = 0.0,
    interpret: bool | None = None,
):
    """One fused decode step for all rows.  Returns ``(new_state, o_t)``
    (same order as ``core.hla2.hla2_step``)."""
    S, C, m, G, h = state
    batch_shape = q_t.shape[:-1]
    d = q_t.shape[-1]
    dv = v_t.shape[-1]
    BH = 1
    for s in batch_shape:
        BH *= s
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    has_decay = gamma is not None
    f32 = jnp.float32
    gamma_in = (
        jnp.ones((BH, 1), f32)
        if gamma is None
        else jnp.broadcast_to(
            jnp.asarray(gamma, f32), batch_shape
        ).reshape(BH, 1)
    )
    qf = q_t.reshape(BH, 1, d)
    kf = k_t.reshape(BH, 1, d)
    vf = v_t.reshape(BH, 1, dv)
    Sf = S.reshape(BH, d, d).astype(f32)
    Cf = C.reshape(BH, d, dv).astype(f32)
    mf = m.reshape(BH, 1, d).astype(f32)
    Gf = G.reshape(BH, d, dv).astype(f32)
    hf = h.reshape(BH, 1, d).astype(f32)

    kernel = functools.partial(
        _hla2_step_kernel,
        normalize=normalize,
        eps=eps,
        lam=lam,
        has_decay=has_decay,
    )
    st_shapes = _state_shapes(d, dv)
    row = lambda a, b: pl.BlockSpec((1, a, b), lambda i: (i, 0, 0))  # noqa: E731
    in_specs = [
        pl.BlockSpec((1, 1), lambda i: (i, 0)),  # gamma
        row(1, d), row(1, d), row(1, dv),
    ] + [row(a, b) for a, b in st_shapes]
    out_specs = [row(1, dv)] + [row(a, b) for a, b in st_shapes]
    out_shape = [jax.ShapeDtypeStruct((BH, 1, dv), v_t.dtype)] + [
        jax.ShapeDtypeStruct((BH,) + s, f32) for s in st_shapes
    ]
    o, S1, C1, m1, G1, h1 = pl.pallas_call(
        kernel,
        grid=(BH,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        # state operands update in place in HBM (operand 4..8 -> output 1..5)
        input_output_aliases={4: 1, 5: 2, 6: 3, 7: 4, 8: 5},
        interpret=interpret,
        compiler_params=_step_compiler_params(interpret),
    )(gamma_in, qf, kf, vf, Sf, Cf, mf, Gf, hf)
    new_state = (
        S1.reshape(S.shape).astype(S.dtype),
        C1.reshape(C.shape).astype(C.dtype),
        m1.reshape(m.shape).astype(m.dtype),
        G1.reshape(G.shape).astype(G.dtype),
        h1.reshape(h.shape).astype(h.dtype),
    )
    return new_state, o.reshape(batch_shape + (dv,)).astype(v_t.dtype)


# --------------------------------------------------------------------------
# AHLA
# --------------------------------------------------------------------------


def _ahla_step_kernel(
    gamma_ref,  # (1, 1)
    q_ref,  # (1, 1, d)
    k_ref,  # (1, 1, d)
    vb_ref,  # (1, 1, dv+1)  ones-augmented value
    R_ref,  # (1, d, d)      aliased in/out (undecayed cross moment)
    P_ref,  # (1, d, dv+1)   [P | m]
    E_ref,  # (1, d, dv+1)   [E | n]
    o_ref,  # (1, 1, dv+1)   augmented output [num | den]
    R_out,
    P_out,
    E_out,
    *,
    normalize: bool,
    eps: float,
    has_decay: bool,
):
    f32 = jnp.float32
    q = q_ref[0].astype(f32)
    k = k_ref[0].astype(f32)
    vb = vb_ref[0].astype(f32)
    g = gamma_ref[0, 0].astype(f32) if has_decay else jnp.ones((), f32)

    P1 = g * P_ref[0] + _outer(k, vb)  # [P | m] update (Algorithm 2)
    rbar = _dot(q, P1)  # [r_t | s_t], inclusive P (Thm 6.1)
    E1 = g * E_ref[0] + _outer(k, rbar)  # [E | n] update
    obar = _dot(q, E1)  # (1, dv+1)
    if normalize:
        dv = obar.shape[-1] - 1
        o = obar[:, :dv] / (obar[:, dv:] + eps)
        obar = jnp.concatenate([o, obar[:, dv:]], axis=-1)
    R1 = R_ref[0] + _outer(k, q)

    o_ref[0] = obar.astype(o_ref.dtype)
    R_out[0] = R1
    P_out[0] = P1
    E_out[0] = E1


def ahla_step_pallas(
    state,  # (R, P, m, E, n) with leading batch dims
    q_t: jax.Array,  # (..., d)
    k_t: jax.Array,
    v_t: jax.Array,  # (..., dv)
    gamma=None,
    *,
    normalize: bool = False,
    eps: float = 1e-6,
    interpret: bool | None = None,
):
    """One fused AHLA decode step for all rows.  Returns ``(new_state, o_t)``."""
    R, P, m, E, n = state
    batch_shape = q_t.shape[:-1]
    d = q_t.shape[-1]
    dv = v_t.shape[-1]
    BH = 1
    for s in batch_shape:
        BH *= s
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    has_decay = gamma is not None
    f32 = jnp.float32
    gamma_in = (
        jnp.ones((BH, 1), f32)
        if gamma is None
        else jnp.broadcast_to(
            jnp.asarray(gamma, f32), batch_shape
        ).reshape(BH, 1)
    )
    qf = q_t.reshape(BH, 1, d)
    kf = k_t.reshape(BH, 1, d)
    vb = jnp.concatenate(
        [v_t.reshape(BH, 1, dv), jnp.ones((BH, 1, 1), v_t.dtype)], axis=-1
    )
    Rf = R.reshape(BH, d, d).astype(f32)
    Pbar = jnp.concatenate(
        [P.reshape(BH, d, dv).astype(f32),
         m.reshape(BH, d, 1).astype(f32)], axis=-1
    )
    Ebar = jnp.concatenate(
        [E.reshape(BH, d, dv).astype(f32),
         n.reshape(BH, d, 1).astype(f32)], axis=-1
    )

    kernel = functools.partial(
        _ahla_step_kernel, normalize=normalize, eps=eps, has_decay=has_decay
    )
    row = lambda a, b: pl.BlockSpec((1, a, b), lambda i: (i, 0, 0))  # noqa: E731
    in_specs = [
        pl.BlockSpec((1, 1), lambda i: (i, 0)),
        row(1, d), row(1, d), row(1, dv + 1),
        row(d, d), row(d, dv + 1), row(d, dv + 1),
    ]
    out_specs = [row(1, dv + 1), row(d, d), row(d, dv + 1), row(d, dv + 1)]
    out_shape = [
        jax.ShapeDtypeStruct((BH, 1, dv + 1), v_t.dtype),
        jax.ShapeDtypeStruct((BH, d, d), f32),
        jax.ShapeDtypeStruct((BH, d, dv + 1), f32),
        jax.ShapeDtypeStruct((BH, d, dv + 1), f32),
    ]
    obar, R1, P1, E1 = pl.pallas_call(
        kernel,
        grid=(BH,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        input_output_aliases={4: 1, 5: 2, 6: 3},
        interpret=interpret,
        compiler_params=_step_compiler_params(interpret),
    )(gamma_in, qf, kf, vb, Rf, Pbar, Ebar)
    new_state = (
        R1.reshape(R.shape).astype(R.dtype),
        P1[..., :dv].reshape(P.shape).astype(P.dtype),
        P1[..., dv].reshape(m.shape).astype(m.dtype),
        E1[..., :dv].reshape(E.shape).astype(E.dtype),
        E1[..., dv].reshape(n.shape).astype(n.dtype),
    )
    o = obar[..., 0, :dv].reshape(batch_shape + (dv,)).astype(v_t.dtype)
    return new_state, o
