"""Per-chunk HLA2 / AHLA math shared by the Pallas kernels and references.

One chunk of the chunkwise scheme (DESIGN.md §2) as a *pure function* of
``(Q, K, V, state_in, gamma) -> (o, state_out)`` on single-head 2D tiles:

* the **forward** kernels call it once per grid step, carrying ``state`` in
  VMEM scratch;
* the **backward** kernels (DESIGN.md §3) call ``jax.vjp`` on it — the
  linearization recomputes the intra-chunk tiles from ``q/k/v`` plus the
  checkpointed incoming state and emits only transposed MXU-shaped
  contractions, so the reverse pass is exactly the adjoint of the forward
  math with no hand-derivation drift;
* the pure-jnp backward oracle in ``ref.py`` is the same function ``vmap``-ed
  over the batch×head axis — kernel and oracle are bit-identical by
  construction.

Everything here must stay Pallas-traceable: 2D tiles, ``broadcasted_iota``
masks, ``dot_general`` with fp32 accumulation, no data-dependent shapes.
The decay masks clamp the exponent *before* ``exp`` so the VJP is free of
``0 * inf`` NaNs at masked positions.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def decay_mats(w: int, g, dtype):
    """In-kernel L_gamma, g^(t+1), g^(w-1-t) from scalar g (g=1 => plain L).

    Returns ``(Lg, pow_t, pow_rev, mask)``.  The masked exponent is clamped
    to 0 before ``exp`` so reverse-mode AD never sees an overflowed branch.
    """
    t = jax.lax.broadcasted_iota(jnp.int32, (w, w), 0)
    j = jax.lax.broadcasted_iota(jnp.int32, (w, w), 1)
    mask = t >= j
    diff = jnp.where(mask, t - j, 0).astype(dtype)
    logg = jnp.log(g)
    Lg = jnp.where(mask, jnp.exp(diff * logg), jnp.zeros((), dtype))
    tv = jax.lax.iota(dtype, w)
    pow_t = jnp.exp((tv + 1.0) * logg)  # g^t for t=1..w
    pow_rev = jnp.exp((w - 1.0 - tv) * logg)  # g^(w-t) for t=1..w
    return Lg, pow_t, pow_rev, mask


def hla2_chunk_math(
    Q,  # (w, d) f32
    K,  # (w, d) f32
    V,  # (w, dv) f32
    state,  # (S0 (d,d), C0 (d,dv), m0 (1,d), G0 (d,dv), h0 (1,d)) f32
    g,  # scalar f32 decay (1.0 = no decay)
    *,
    normalize: bool,
    eps: float,
    lam: float,
):
    """One HLA2 chunk: outputs + monoid carry update (DESIGN.md §2).

    For local tokens 1..w with carry (S0, C0, m0, G0, h0), D0 = S0 C0 - G0:

        num_t = g^{2t} q_t D0                              (T1: Q @ D0)
              + g^t   row_t[(Q S0 Q^T . Lg) V]             (T2)
              + row_t[((A B) . Lg) V]                      (T3, intra)
        A = (Q K^T) . Lg,  B = (K Q^T) . U  (U = upper incl diag)
    """
    f32 = jnp.float32
    w = Q.shape[0]
    S0, C0, m0, G0, h0 = state

    Lg, pow_t, pow_rev, mask = decay_mats(w, g, f32)
    t = jax.lax.broadcasted_iota(jnp.int32, (w, w), 0)
    j = jax.lax.broadcasted_iota(jnp.int32, (w, w), 1)
    U = (t <= j).astype(f32)  # i <= j (upper incl)
    Ls = (t > j).astype(f32)  # strict lower

    dot = functools.partial(jax.lax.dot_general, preferred_element_type=f32)
    mm = lambda a, b: dot(a, b, (((1,), (0,)), ((), ())))  # noqa: E731
    mmT = lambda a, b: dot(a, b, (((1,), (1,)), ((), ())))  # noqa: E731  a @ b.T

    A = mmT(Q, K) * Lg  # (w, w)   (QK^T) . Lg
    Bm = mmT(K, Q) * U  # B[i, j] = (k_i . q_j) masked i<=j
    M3 = mm(A, Bm) * Lg
    QS0 = mm(Q, S0)  # (w, d)
    QS0Q = mmT(QS0, Q) * Lg

    D0 = mm(S0, C0) - G0  # (d, dv)
    T1 = (pow_t**2)[:, None] * mm(Q, D0)
    T2 = pow_t[:, None] * mm(QS0Q, V)
    T3 = mm(M3, V)
    num = T1 + T2 + T3
    if lam:
        Wqq = mmT(Q, Q) * Lg
        num = num + lam * (pow_t[:, None] * mm(Q, C0) + mm(Wqq, V))
    if normalize:
        d0v = mm(S0, m0.T) - h0.T  # (d, 1)
        den = (
            (pow_t**2)[:, None] * mm(Q, d0v)
            + pow_t[:, None] * jnp.sum(QS0Q, -1, keepdims=True)
            + jnp.sum(M3, -1, keepdims=True)
        )
        if lam:
            den = den + lam * (
                pow_t[:, None] * mm(Q, m0.T) + jnp.sum(Wqq, -1, keepdims=True)
            )
        o = num / (den + eps)
    else:
        o = num

    # ---- carry update (monoid, B = whole chunk) ----
    rho = jnp.exp(jnp.log(g) * w)
    Kg = pow_rev[:, None] * K
    Qg = pow_rev[:, None] * Q
    Sw = dot(Kg, K, (((0,), (0,)), ((), ())))  # (d, d)
    Cw = dot(Qg, V, (((0,), (0,)), ((), ())))  # (d, dv)
    mw = jnp.sum(Qg, 0, keepdims=True)  # (1, d)
    N = mmT(K, Q) * Ls
    Vg = pow_rev[:, None] * V
    NVg = mm(N, Vg)
    Gw = dot(Kg, NVg, (((0,), (0,)), ((), ())))
    Nmg = jnp.sum(N * pow_rev[None, :], -1, keepdims=True)  # (w, 1)
    hw = dot(Nmg, Kg, (((0,), (0,)), ((), ())))  # (1, d)

    S1 = rho * S0 + Sw
    C1 = rho * C0 + Cw
    m1 = rho * m0 + mw
    G1 = rho**2 * G0 + Gw + rho * mm(Sw, C0)
    h1 = rho**2 * h0 + hw + rho * mm(m0, Sw.T)
    return o, (S1, C1, m1, G1, h1)


def ahla_chunk_math(
    Q,  # (w, d) f32
    K,  # (w, d) f32
    V,  # (w, dv) f32
    state,  # (P0 (d, dv+1), E0 (d, dv+1)) f32 — den columns augmented
    g,  # scalar f32
    *,
    normalize: bool,
    eps: float,
):
    """One AHLA chunk: fused inner+outer linear-attention passes.

    The intermediate first-order outputs ``r`` never materialize outside the
    chunk; the carry is ``(P | m, E | n)`` with the ones-augmented V trick.
    """
    f32 = jnp.float32
    w = Q.shape[0]
    P0, E0 = state
    Vb = jnp.concatenate([V, jnp.ones((w, 1), f32)], axis=-1)

    Lg, pow_t, pow_rev, mask = decay_mats(w, g, f32)

    dot = functools.partial(jax.lax.dot_general, preferred_element_type=f32)
    mm = lambda a, b: dot(a, b, (((1,), (0,)), ((), ())))  # noqa: E731
    mmT = lambda a, b: dot(a, b, (((1,), (1,)), ((), ())))  # noqa: E731

    A = mmT(Q, K) * Lg
    AV = mm(A, Vb)  # local first-order outputs
    r = pow_t[:, None] * mm(Q, P0) + AV  # carry-inclusive r_t | s_t
    o_aug = pow_t[:, None] * mm(Q, E0) + mm(A, r)
    if normalize:
        o = o_aug[:, :-1] / (o_aug[:, -1:] + eps)
    else:
        o = o_aug[:, :-1]

    rho = jnp.exp(jnp.log(g) * w)
    Kg = pow_rev[:, None] * K
    KgT_ = lambda X: dot(Kg, X, (((0,), (0,)), ((), ())))  # noqa: E731
    R = dot(K, Q, (((0,), (0,)), ((), ())))  # (d, d) sum_t k_t q_t^T (undecayed)
    P1 = rho * P0 + KgT_(Vb)
    E1 = rho * E0 + KgT_(AV) + rho * mm(R, P0)
    return o, (P1, E1)
