"""Pure-jnp oracles for the Pallas kernels.

Forward: the kernels compute the same chunkwise math as ``repro.core`` —
these wrappers pin the exact reference semantics (shapes ``(BH, n, d)``)
used by the per-kernel allclose tests.

Backward: ``hla2_chunk_bwd_ref`` / ``ahla_chunk_bwd_ref`` mirror the fused
backward kernels *structurally*: a forward ``lax.scan`` collects each
chunk's incoming state (the checkpoints the kernel spills to HBM), then a
reverse scan applies ``jax.vjp`` of the **same** shared per-chunk math
(``chunk_math.py``) the kernels trace — so oracle and kernel are
bit-identical by construction, vmapped over the batch×head axis instead of
gridded over it.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..core.ahla import ahla_chunkwise
from ..core.hla2 import hla2_chunkwise
from .chunk_math import ahla_chunk_math, hla2_chunk_math


def hla2_chunk_ref(
    q, k, v, gamma=None, *, chunk=128, normalize=False, eps=1e-6, lam=0.0
):
    """Reference for kernels.hla2_chunk — returns (o, (S, C, m, G, h))."""
    o, st = hla2_chunkwise(
        q, k, v, gamma, chunk=chunk, normalize=normalize, eps=eps, lam=lam
    )
    return o, tuple(jnp.asarray(x) for x in st)


def ahla_chunk_ref(q, k, v, gamma=None, *, chunk=128, normalize=False, eps=1e-6):
    """Reference for kernels.ahla_chunk — returns (o, (P, m, E, n))."""
    o, st = ahla_chunkwise(
        q, k, v, gamma, chunk=chunk, normalize=normalize, eps=eps
    )
    return o, (st.P, st.m, st.E, st.n)


# --------------------------------------------------------------------------
# chunk-level backward oracles (mirror the fused bwd kernels)
# --------------------------------------------------------------------------


def _chunked(x, nc, w):
    return x.reshape(x.shape[0], nc, w, x.shape[-1])


def _chunk_bwd_row(chunk_fn, init_state, zero_cotangent, has_decay):
    """Per-(batch,head) chunk-level VJP: forward state collection + reverse
    vjp walk.  ``chunk_fn(Q, K, V, state, g) -> (o, state')``."""

    def row(q_r, k_r, v_r, do_r, g_r):  # (nc, w, ·) stacks, scalar g
        def fwd_body(st, qkv):
            o, st1 = chunk_fn(*qkv, st, g_r)
            return st1, st  # carry the update, emit the *incoming* state

        _, st_in = jax.lax.scan(fwd_body, init_state, (q_r, k_r, v_r))

        def bwd_body(dst, args):
            Q, K, V, dO, st0 = args
            if has_decay:
                _, vjp = jax.vjp(chunk_fn, Q, K, V, st0, g_r)
                dQ, dK, dV, dst0, dg = vjp((dO, dst))
            else:
                _, vjp = jax.vjp(
                    lambda a, b, c_, s: chunk_fn(a, b, c_, s, g_r), Q, K, V, st0
                )
                dQ, dK, dV, dst0 = vjp((dO, dst))
                dg = jnp.zeros((), jnp.float32)
            return dst0, (dQ, dK, dV, dg)

        _, (dq_r, dk_r, dv_r, dg_parts) = jax.lax.scan(
            bwd_body, zero_cotangent, (q_r, k_r, v_r, do_r, st_in),
            reverse=True,
        )
        return dq_r, dk_r, dv_r, jnp.sum(dg_parts)

    return row


def hla2_chunk_bwd_ref(
    q, k, v, gamma, do, *, chunk=128, normalize=False, eps=1e-6, lam=0.0
):
    """Chunk-level backward oracle for ``hla2_chunk_bwd_pallas``.

    Shapes ``(BH, n, d)`` with ``n`` a chunk multiple.  Returns
    ``(dq, dk, dv, dgamma)``; ``dgamma`` is None iff ``gamma`` is None.
    """
    BH, n, d = q.shape
    dv = v.shape[-1]
    w = min(chunk, n)
    assert n % w == 0, "oracle expects pre-padded chunk-multiple sequences"
    nc = n // w
    f32 = jnp.float32
    qc = _chunked(q.astype(f32), nc, w)
    kc = _chunked(k.astype(f32), nc, w)
    vc = _chunked(v.astype(f32), nc, w)
    doc = _chunked(do.astype(f32), nc, w)
    has_decay = gamma is not None
    g = (
        gamma.reshape(BH).astype(f32)
        if has_decay
        else jnp.ones((BH,), f32)
    )
    z = functools.partial(jnp.zeros, dtype=f32)
    state0 = (z((d, d)), z((d, dv)), z((1, d)), z((d, dv)), z((1, d)))
    chunk_fn = functools.partial(
        hla2_chunk_math, normalize=normalize, eps=eps, lam=lam
    )
    row = _chunk_bwd_row(chunk_fn, state0, state0, has_decay)
    dq, dk, dv_, dg = jax.vmap(row)(qc, kc, vc, doc, g)
    dq = dq.reshape(BH, n, d).astype(q.dtype)
    dk = dk.reshape(BH, n, d).astype(k.dtype)
    dv_ = dv_.reshape(BH, n, dv).astype(v.dtype)
    dgamma = dg.astype(gamma.dtype) if has_decay else None
    return dq, dk, dv_, dgamma


def ahla_chunk_bwd_ref(
    q, k, v, gamma, do, *, chunk=128, normalize=False, eps=1e-6
):
    """Chunk-level backward oracle for ``ahla_chunk_bwd_pallas``."""
    BH, n, d = q.shape
    dv = v.shape[-1]
    w = min(chunk, n)
    assert n % w == 0, "oracle expects pre-padded chunk-multiple sequences"
    nc = n // w
    f32 = jnp.float32
    qc = _chunked(q.astype(f32), nc, w)
    kc = _chunked(k.astype(f32), nc, w)
    vc = _chunked(v.astype(f32), nc, w)
    doc = _chunked(do.astype(f32), nc, w)
    has_decay = gamma is not None
    g = (
        gamma.reshape(BH).astype(f32)
        if has_decay
        else jnp.ones((BH,), f32)
    )
    z = functools.partial(jnp.zeros, dtype=f32)
    state0 = (z((d, dv + 1)), z((d, dv + 1)))
    chunk_fn = functools.partial(ahla_chunk_math, normalize=normalize, eps=eps)
    row = _chunk_bwd_row(chunk_fn, state0, state0, has_decay)
    dq, dk, dv_, dg = jax.vmap(row)(qc, kc, vc, doc, g)
    dq = dq.reshape(BH, n, d).astype(q.dtype)
    dk = dk.reshape(BH, n, d).astype(k.dtype)
    dv_ = dv_.reshape(BH, n, dv).astype(v.dtype)
    dgamma = dg.astype(gamma.dtype) if has_decay else None
    return dq, dk, dv_, dgamma
