"""Pure-jnp oracles for the Pallas kernels.

The kernels compute the same chunkwise math as ``repro.core`` — these
wrappers pin the exact reference semantics (shapes ``(BH, n, d)``) used by
the per-kernel allclose tests and by the custom-VJP backward pass.
"""

from __future__ import annotations

import jax.numpy as jnp

from ..core.ahla import ahla_chunkwise
from ..core.hla2 import hla2_chunkwise


def hla2_chunk_ref(
    q, k, v, gamma=None, *, chunk=128, normalize=False, eps=1e-6, lam=0.0
):
    """Reference for kernels.hla2_chunk — returns (o, (S, C, m, G, h))."""
    o, st = hla2_chunkwise(
        q, k, v, gamma, chunk=chunk, normalize=normalize, eps=eps, lam=lam
    )
    return o, tuple(jnp.asarray(x) for x in st)


def ahla_chunk_ref(q, k, v, gamma=None, *, chunk=128, normalize=False, eps=1e-6):
    """Reference for kernels.ahla_chunk — returns (o, (P, m, E, n))."""
    o, st = ahla_chunkwise(
        q, k, v, gamma, chunk=chunk, normalize=normalize, eps=eps
    )
    return o, (st.P, st.m, st.E, st.n)
