"""Fused Pallas kernels for the chunk-parallel HLA operators.

Layout:

* ``chunk_math.py`` — per-chunk forward math as pure functions, shared by
  the forward kernels, the backward kernels (via ``jax.vjp``), and the
  pure-jnp oracles;
* ``hla2_chunk.py`` / ``ahla_chunk.py`` — Pallas forward + backward
  kernels with chunk-level state checkpointing;
* ``ops.py`` — jit'd ``(B, H, n, d)`` wrappers with ``custom_vjp`` wiring
  (the public API below);
* ``ref.py`` — reference semantics / test oracles.
"""

from .ops import ahla_attention, hla2_attention

__all__ = ["ahla_attention", "hla2_attention"]
