"""Jit'd public wrappers around the Pallas kernels.

``hla2_attention`` / ``ahla_attention`` take model-layout tensors
``(B, H, n, d)`` and dispatch to the fused Pallas kernels for **both**
passes of training:

* **Forward**: the chunkwise kernel carries the inter-chunk state in VMEM
  scratch; under differentiation it additionally spills each chunk's
  *incoming* state tuple to HBM (``nc ×`` constant-size state — the
  chunk-level checkpointing trade: O(n/w · d·dv) extra memory buys back a
  full unfused recompute forward).
* **Backward** (``fused_bwd=True``, the default): a second Pallas kernel
  walks the chunk axis in reverse, recomputes the intra-chunk tiles from
  ``q/k/v`` plus the checkpointed state, and accumulates ``dq/dk/dv/dgamma``
  with the reverse-mode state cotangents living in VMEM scratch.  Gradients
  are exact: the backward differentiates the *same* per-chunk math
  (``chunk_math.py``) the forward kernel executes.
* ``fused_bwd=False`` restores the legacy recompute-in-backward design
  (``jax.vjp`` over the pure-jnp chunkwise reference — a second,
  XLA-scheduled forward whose carried state round-trips through HBM).
* ``use_pallas=False`` falls back to the reference end to end (used on CPU
  training runs; the kernels themselves are exercised in interpret mode by
  the tests).

Arbitrary sequence lengths are supported: the kernel wrappers zero-pad to
a chunk multiple and slice the results back.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref as _ref
from .ahla_chunk import ahla_chunk_bwd_pallas, ahla_chunk_pallas
from .hla2_chunk import hla2_chunk_bwd_pallas, hla2_chunk_pallas


def _merge_bh(x):
    B, H = x.shape[:2]
    return x.reshape((B * H,) + x.shape[2:]), B, H


# --------------------------------------------------------------------------
# HLA2
# --------------------------------------------------------------------------


@functools.partial(
    jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7, 8, 9)
)
def _hla2_fwd_core(
    q, k, v, gamma, chunk, normalize, eps, lam, use_pallas, fused_bwd
):
    if use_pallas:
        qf, B, H = _merge_bh(q)
        kf, _, _ = _merge_bh(k)
        vf, _, _ = _merge_bh(v)
        gf = None if gamma is None else gamma.reshape(B * H)
        o, _ = hla2_chunk_pallas(
            qf, kf, vf, gf, chunk=chunk, normalize=normalize, eps=eps, lam=lam
        )
        return o.reshape(q.shape[:2] + o.shape[1:])
    o, _ = _ref.hla2_chunk_ref(
        q, k, v, gamma, chunk=chunk, normalize=normalize, eps=eps, lam=lam
    )
    return o


def _hla2_vjp_fwd(
    q, k, v, gamma, chunk, normalize, eps, lam, use_pallas, fused_bwd
):
    if use_pallas and fused_bwd:
        # fused training path: forward checkpoints per-chunk incoming states
        qf, B, H = _merge_bh(q)
        kf, _, _ = _merge_bh(k)
        vf, _, _ = _merge_bh(v)
        gf = None if gamma is None else gamma.reshape(B * H)
        o, _, chunk_states = hla2_chunk_pallas(
            qf, kf, vf, gf, chunk=chunk, normalize=normalize, eps=eps,
            lam=lam, save_chunk_states=True,
        )
        out = o.reshape(q.shape[:2] + o.shape[1:])
        return out, (q, k, v, gamma, chunk_states)
    out = _hla2_fwd_core(
        q, k, v, gamma, chunk, normalize, eps, lam, use_pallas, fused_bwd
    )
    return out, (q, k, v, gamma, None)


def _hla2_vjp_bwd(chunk, normalize, eps, lam, use_pallas, fused_bwd, res, g):
    q, k, v, gamma, chunk_states = res

    if use_pallas and fused_bwd:
        qf, B, H = _merge_bh(q)
        kf, _, _ = _merge_bh(k)
        vf, _, _ = _merge_bh(v)
        gof, _, _ = _merge_bh(g)
        gf = None if gamma is None else gamma.reshape(B * H)
        dq, dk, dv, dgamma = hla2_chunk_bwd_pallas(
            qf, kf, vf, gf, gof, chunk_states, chunk=chunk,
            normalize=normalize, eps=eps, lam=lam,
        )
        unmerge = lambda x, p: x.reshape(p.shape).astype(p.dtype)  # noqa: E731
        dgamma = (
            None if gamma is None
            else dgamma.reshape(gamma.shape).astype(gamma.dtype)
        )
        return unmerge(dq, q), unmerge(dk, k), unmerge(dv, v), dgamma

    # legacy recompute-in-backward: differentiate the jnp chunkwise reference
    def f(q_, k_, v_, gamma_):
        o, _ = _ref.hla2_chunk_ref(
            q_, k_, v_, gamma_, chunk=chunk, normalize=normalize, eps=eps,
            lam=lam,
        )
        return o

    if gamma is None:
        _, vjp = jax.vjp(lambda a, b, c: f(a, b, c, None), q, k, v)
        return (*vjp(g), None)
    _, vjp = jax.vjp(f, q, k, v, gamma)
    return vjp(g)


_hla2_fwd_core.defvjp(_hla2_vjp_fwd, _hla2_vjp_bwd)


def hla2_attention(
    q, k, v, gamma=None, *, chunk: int = 128, normalize: bool = False,
    eps: float = 1e-6, lam: float = 0.0, use_pallas: bool = True,
    fused_bwd: bool = True,
):
    """Masked second-order HLA over (B, H, n, d) tensors (fused fwd + bwd)."""
    return _hla2_fwd_core(
        q, k, v, gamma, chunk, normalize, eps, lam, use_pallas, fused_bwd
    )


# --------------------------------------------------------------------------
# AHLA
# --------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7, 8))
def _ahla_fwd_core(q, k, v, gamma, chunk, normalize, eps, use_pallas,
                   fused_bwd):
    if use_pallas:
        qf, B, H = _merge_bh(q)
        kf, _, _ = _merge_bh(k)
        vf, _, _ = _merge_bh(v)
        gf = None if gamma is None else gamma.reshape(B * H)
        o, _ = ahla_chunk_pallas(
            qf, kf, vf, gf, chunk=chunk, normalize=normalize, eps=eps
        )
        return o.reshape(q.shape[:2] + o.shape[1:])
    o, _ = _ref.ahla_chunk_ref(
        q, k, v, gamma, chunk=chunk, normalize=normalize, eps=eps
    )
    return o


def _ahla_vjp_fwd(q, k, v, gamma, chunk, normalize, eps, use_pallas,
                  fused_bwd):
    if use_pallas and fused_bwd:
        qf, B, H = _merge_bh(q)
        kf, _, _ = _merge_bh(k)
        vf, _, _ = _merge_bh(v)
        gf = None if gamma is None else gamma.reshape(B * H)
        o, _, chunk_states = ahla_chunk_pallas(
            qf, kf, vf, gf, chunk=chunk, normalize=normalize, eps=eps,
            save_chunk_states=True,
        )
        out = o.reshape(q.shape[:2] + o.shape[1:])
        return out, (q, k, v, gamma, chunk_states)
    out = _ahla_fwd_core(
        q, k, v, gamma, chunk, normalize, eps, use_pallas, fused_bwd
    )
    return out, (q, k, v, gamma, None)


def _ahla_vjp_bwd(chunk, normalize, eps, use_pallas, fused_bwd, res, g):
    q, k, v, gamma, chunk_states = res

    if use_pallas and fused_bwd:
        qf, B, H = _merge_bh(q)
        kf, _, _ = _merge_bh(k)
        vf, _, _ = _merge_bh(v)
        gof, _, _ = _merge_bh(g)
        gf = None if gamma is None else gamma.reshape(B * H)
        dq, dk, dv, dgamma = ahla_chunk_bwd_pallas(
            qf, kf, vf, gf, gof, chunk_states, chunk=chunk,
            normalize=normalize, eps=eps,
        )
        unmerge = lambda x, p: x.reshape(p.shape).astype(p.dtype)  # noqa: E731
        dgamma = (
            None if gamma is None
            else dgamma.reshape(gamma.shape).astype(gamma.dtype)
        )
        return unmerge(dq, q), unmerge(dk, k), unmerge(dv, v), dgamma

    def f(q_, k_, v_, gamma_):
        o, _ = _ref.ahla_chunk_ref(
            q_, k_, v_, gamma_, chunk=chunk, normalize=normalize, eps=eps
        )
        return o

    if gamma is None:
        _, vjp = jax.vjp(lambda a, b, c: f(a, b, c, None), q, k, v)
        return (*vjp(g), None)
    _, vjp = jax.vjp(f, q, k, v, gamma)
    return vjp(g)


_ahla_fwd_core.defvjp(_ahla_vjp_fwd, _ahla_vjp_bwd)


def ahla_attention(
    q, k, v, gamma=None, *, chunk: int = 128, normalize: bool = False,
    eps: float = 1e-6, use_pallas: bool = True, fused_bwd: bool = True,
):
    """AHLA over (B, H, n, d) tensors (fused fwd + bwd)."""
    return _ahla_fwd_core(
        q, k, v, gamma, chunk, normalize, eps, use_pallas, fused_bwd
    )
