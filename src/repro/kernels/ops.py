"""Jit'd public wrappers around the Pallas kernels.

``hla2_attention`` / ``ahla_attention`` take model-layout tensors
``(B, H, n, d)`` and dispatch to the fused Pallas kernel for the forward
pass.  The backward pass is a ``custom_vjp`` that differentiates the
bit-identical pure-jnp chunkwise reference (recompute-in-backward): the
kernel and the reference compute the same math, so gradients are exact
while the hot forward path stays fused.  ``use_pallas=False`` falls back to
the reference end to end (used on CPU training runs; the kernel itself is
exercised in interpret mode by the tests).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref as _ref
from .ahla_chunk import ahla_chunk_pallas
from .hla2_chunk import hla2_chunk_pallas


def _merge_bh(x):
    B, H = x.shape[:2]
    return x.reshape((B * H,) + x.shape[2:]), B, H


@functools.partial(
    jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7, 8)
)
def _hla2_fwd_core(q, k, v, gamma, chunk, normalize, eps, lam, use_pallas):
    if use_pallas:
        qf, B, H = _merge_bh(q)
        kf, _, _ = _merge_bh(k)
        vf, _, _ = _merge_bh(v)
        gf = None if gamma is None else gamma.reshape(B * H)
        o, _ = hla2_chunk_pallas(
            qf, kf, vf, gf, chunk=chunk, normalize=normalize, eps=eps, lam=lam
        )
        return o.reshape(q.shape[:2] + o.shape[1:])
    o, _ = _ref.hla2_chunk_ref(
        q, k, v, gamma, chunk=chunk, normalize=normalize, eps=eps, lam=lam
    )
    return o


def _hla2_vjp_fwd(q, k, v, gamma, chunk, normalize, eps, lam, use_pallas):
    out = _hla2_fwd_core(q, k, v, gamma, chunk, normalize, eps, lam, use_pallas)
    return out, (q, k, v, gamma)


def _hla2_vjp_bwd(chunk, normalize, eps, lam, use_pallas, res, g):
    q, k, v, gamma = res

    def f(q_, k_, v_, gamma_):
        o, _ = _ref.hla2_chunk_ref(
            q_, k_, v_, gamma_, chunk=chunk, normalize=normalize, eps=eps,
            lam=lam,
        )
        return o

    if gamma is None:
        _, vjp = jax.vjp(lambda a, b, c: f(a, b, c, None), q, k, v)
        return (*vjp(g), None)
    _, vjp = jax.vjp(f, q, k, v, gamma)
    return vjp(g)


_hla2_fwd_core.defvjp(_hla2_vjp_fwd, _hla2_vjp_bwd)


def hla2_attention(
    q, k, v, gamma=None, *, chunk: int = 128, normalize: bool = False,
    eps: float = 1e-6, lam: float = 0.0, use_pallas: bool = True,
):
    """Masked second-order HLA over (B, H, n, d) tensors (fused forward)."""
    return _hla2_fwd_core(
        q, k, v, gamma, chunk, normalize, eps, lam, use_pallas
    )


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7))
def _ahla_fwd_core(q, k, v, gamma, chunk, normalize, eps, use_pallas):
    if use_pallas:
        qf, B, H = _merge_bh(q)
        kf, _, _ = _merge_bh(k)
        vf, _, _ = _merge_bh(v)
        gf = None if gamma is None else gamma.reshape(B * H)
        o, _ = ahla_chunk_pallas(
            qf, kf, vf, gf, chunk=chunk, normalize=normalize, eps=eps
        )
        return o.reshape(q.shape[:2] + o.shape[1:])
    o, _ = _ref.ahla_chunk_ref(
        q, k, v, gamma, chunk=chunk, normalize=normalize, eps=eps
    )
    return o


def _ahla_vjp_fwd(q, k, v, gamma, chunk, normalize, eps, use_pallas):
    out = _ahla_fwd_core(q, k, v, gamma, chunk, normalize, eps, use_pallas)
    return out, (q, k, v, gamma)


def _ahla_vjp_bwd(chunk, normalize, eps, use_pallas, res, g):
    q, k, v, gamma = res

    def f(q_, k_, v_, gamma_):
        o, _ = _ref.ahla_chunk_ref(
            q_, k_, v_, gamma_, chunk=chunk, normalize=normalize, eps=eps
        )
        return o

    if gamma is None:
        _, vjp = jax.vjp(lambda a, b, c: f(a, b, c, None), q, k, v)
        return (*vjp(g), None)
    _, vjp = jax.vjp(f, q, k, v, gamma)
    return vjp(g)


_ahla_fwd_core.defvjp(_ahla_vjp_fwd, _ahla_vjp_bwd)


def ahla_attention(
    q, k, v, gamma=None, *, chunk: int = 128, normalize: bool = False,
    eps: float = 1e-6, use_pallas: bool = True,
):
    """AHLA over (B, H, n, d) tensors (fused forward)."""
    return _ahla_fwd_core(q, k, v, gamma, chunk, normalize, eps, use_pallas)
