"""Jit'd public wrappers around the Pallas kernels.

``hla2_attention`` / ``ahla_attention`` take model-layout tensors
``(B, H, n, d)`` and dispatch to the fused Pallas kernels for **both**
passes of training:

* **Forward**: the chunkwise kernel carries the inter-chunk state in VMEM
  scratch; under differentiation it additionally spills each chunk's
  *incoming* state tuple to HBM (``nc ×`` constant-size state — the
  chunk-level checkpointing trade: O(n/w · d·dv) extra memory buys back a
  full unfused recompute forward).
* **Backward** (``fused_bwd=True``, the default): a second Pallas kernel
  walks the chunk axis in reverse, recomputes the intra-chunk tiles from
  ``q/k/v`` plus the checkpointed state, and accumulates ``dq/dk/dv/dgamma``
  with the reverse-mode state cotangents living in VMEM scratch.  Gradients
  are exact: the backward differentiates the *same* per-chunk math
  (``chunk_math.py``) the forward kernel executes.
* ``fused_bwd=False`` restores the legacy recompute-in-backward design
  (``jax.vjp`` over the pure-jnp chunkwise reference — a second,
  XLA-scheduled forward whose carried state round-trips through HBM).
* ``use_pallas=False`` falls back to the reference end to end (used on CPU
  training runs; the kernels themselves are exercised in interpret mode by
  the tests).

Arbitrary sequence lengths are supported: the kernel wrappers zero-pad to
a chunk multiple and slice the results back.
"""

from __future__ import annotations

import collections
import functools

import jax
import jax.numpy as jnp

from . import ref as _ref
from ..core.ahla import AHLAState, ahla_chunkwise
from ..core.hla2 import HLA2State, hla2_chunkwise
from .ahla_chunk import ahla_chunk_bwd_pallas, ahla_chunk_pallas
from .decode_step import ahla_step_pallas, hla2_step_pallas
from .hla2_chunk import hla2_chunk_bwd_pallas, hla2_chunk_pallas


# Trace-time dispatch counters: incremented whenever a Pallas path is
# *traced* (wrapper Python runs under jit/shard_map tracing).  The
# distributed tests use these to assert the sharded train step really
# lowered the fused kernels rather than the jnp fallback.
TRACE_COUNTS = collections.Counter()


def _merge_bh(x):
    B, H = x.shape[:2]
    return x.reshape((B * H,) + x.shape[2:]), B, H


# --------------------------------------------------------------------------
# HLA2
# --------------------------------------------------------------------------


@functools.partial(
    jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7, 8, 9)
)
def _hla2_fwd_core(
    q, k, v, gamma, chunk, normalize, eps, lam, use_pallas, fused_bwd
):
    if use_pallas:
        qf, B, H = _merge_bh(q)
        kf, _, _ = _merge_bh(k)
        vf, _, _ = _merge_bh(v)
        gf = None if gamma is None else gamma.reshape(B * H)
        o, _ = hla2_chunk_pallas(
            qf, kf, vf, gf, chunk=chunk, normalize=normalize, eps=eps, lam=lam
        )
        return o.reshape(q.shape[:2] + o.shape[1:])
    o, _ = _ref.hla2_chunk_ref(
        q, k, v, gamma, chunk=chunk, normalize=normalize, eps=eps, lam=lam
    )
    return o


def _hla2_vjp_fwd(
    q, k, v, gamma, chunk, normalize, eps, lam, use_pallas, fused_bwd
):
    if use_pallas and fused_bwd:
        # fused training path: forward checkpoints per-chunk incoming states
        TRACE_COUNTS["hla2_fwd_fused"] += 1
        qf, B, H = _merge_bh(q)
        kf, _, _ = _merge_bh(k)
        vf, _, _ = _merge_bh(v)
        gf = None if gamma is None else gamma.reshape(B * H)
        o, _, chunk_states = hla2_chunk_pallas(
            qf, kf, vf, gf, chunk=chunk, normalize=normalize, eps=eps,
            lam=lam, save_chunk_states=True,
        )
        out = o.reshape(q.shape[:2] + o.shape[1:])
        return out, (q, k, v, gamma, chunk_states)
    out = _hla2_fwd_core(
        q, k, v, gamma, chunk, normalize, eps, lam, use_pallas, fused_bwd
    )
    return out, (q, k, v, gamma, None)


def _hla2_vjp_bwd(chunk, normalize, eps, lam, use_pallas, fused_bwd, res, g):
    q, k, v, gamma, chunk_states = res

    if use_pallas and fused_bwd:
        TRACE_COUNTS["hla2_bwd_fused"] += 1
        qf, B, H = _merge_bh(q)
        kf, _, _ = _merge_bh(k)
        vf, _, _ = _merge_bh(v)
        gof, _, _ = _merge_bh(g)
        gf = None if gamma is None else gamma.reshape(B * H)
        dq, dk, dv, dgamma = hla2_chunk_bwd_pallas(
            qf, kf, vf, gf, gof, chunk_states, chunk=chunk,
            normalize=normalize, eps=eps, lam=lam,
        )
        unmerge = lambda x, p: x.reshape(p.shape).astype(p.dtype)  # noqa: E731
        dgamma = (
            None if gamma is None
            else dgamma.reshape(gamma.shape).astype(gamma.dtype)
        )
        return unmerge(dq, q), unmerge(dk, k), unmerge(dv, v), dgamma

    # legacy recompute-in-backward: differentiate the jnp chunkwise reference
    def f(q_, k_, v_, gamma_):
        o, _ = _ref.hla2_chunk_ref(
            q_, k_, v_, gamma_, chunk=chunk, normalize=normalize, eps=eps,
            lam=lam,
        )
        return o

    if gamma is None:
        _, vjp = jax.vjp(lambda a, b, c: f(a, b, c, None), q, k, v)
        return (*vjp(g), None)
    _, vjp = jax.vjp(f, q, k, v, gamma)
    return vjp(g)


_hla2_fwd_core.defvjp(_hla2_vjp_fwd, _hla2_vjp_bwd)


def hla2_attention(
    q, k, v, gamma=None, *, chunk: int = 128, normalize: bool = False,
    eps: float = 1e-6, lam: float = 0.0, use_pallas: bool = True,
    fused_bwd: bool = True,
):
    """Masked second-order HLA over (B, H, n, d) tensors (fused fwd + bwd)."""
    return _hla2_fwd_core(
        q, k, v, gamma, chunk, normalize, eps, lam, use_pallas, fused_bwd
    )


# --------------------------------------------------------------------------
# AHLA
# --------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7, 8))
def _ahla_fwd_core(q, k, v, gamma, chunk, normalize, eps, use_pallas,
                   fused_bwd):
    if use_pallas:
        qf, B, H = _merge_bh(q)
        kf, _, _ = _merge_bh(k)
        vf, _, _ = _merge_bh(v)
        gf = None if gamma is None else gamma.reshape(B * H)
        o, _ = ahla_chunk_pallas(
            qf, kf, vf, gf, chunk=chunk, normalize=normalize, eps=eps
        )
        return o.reshape(q.shape[:2] + o.shape[1:])
    o, _ = _ref.ahla_chunk_ref(
        q, k, v, gamma, chunk=chunk, normalize=normalize, eps=eps
    )
    return o


def _ahla_vjp_fwd(q, k, v, gamma, chunk, normalize, eps, use_pallas,
                  fused_bwd):
    if use_pallas and fused_bwd:
        TRACE_COUNTS["ahla_fwd_fused"] += 1
        qf, B, H = _merge_bh(q)
        kf, _, _ = _merge_bh(k)
        vf, _, _ = _merge_bh(v)
        gf = None if gamma is None else gamma.reshape(B * H)
        o, _, chunk_states = ahla_chunk_pallas(
            qf, kf, vf, gf, chunk=chunk, normalize=normalize, eps=eps,
            save_chunk_states=True,
        )
        out = o.reshape(q.shape[:2] + o.shape[1:])
        return out, (q, k, v, gamma, chunk_states)
    out = _ahla_fwd_core(
        q, k, v, gamma, chunk, normalize, eps, use_pallas, fused_bwd
    )
    return out, (q, k, v, gamma, None)


def _ahla_vjp_bwd(chunk, normalize, eps, use_pallas, fused_bwd, res, g):
    q, k, v, gamma, chunk_states = res

    if use_pallas and fused_bwd:
        TRACE_COUNTS["ahla_bwd_fused"] += 1
        qf, B, H = _merge_bh(q)
        kf, _, _ = _merge_bh(k)
        vf, _, _ = _merge_bh(v)
        gof, _, _ = _merge_bh(g)
        gf = None if gamma is None else gamma.reshape(B * H)
        dq, dk, dv, dgamma = ahla_chunk_bwd_pallas(
            qf, kf, vf, gf, gof, chunk_states, chunk=chunk,
            normalize=normalize, eps=eps,
        )
        unmerge = lambda x, p: x.reshape(p.shape).astype(p.dtype)  # noqa: E731
        dgamma = (
            None if gamma is None
            else dgamma.reshape(gamma.shape).astype(gamma.dtype)
        )
        return unmerge(dq, q), unmerge(dk, k), unmerge(dv, v), dgamma

    def f(q_, k_, v_, gamma_):
        o, _ = _ref.ahla_chunk_ref(
            q_, k_, v_, gamma_, chunk=chunk, normalize=normalize, eps=eps
        )
        return o

    if gamma is None:
        _, vjp = jax.vjp(lambda a, b, c: f(a, b, c, None), q, k, v)
        return (*vjp(g), None)
    _, vjp = jax.vjp(f, q, k, v, gamma)
    return vjp(g)


_ahla_fwd_core.defvjp(_ahla_vjp_fwd, _ahla_vjp_bwd)


def ahla_attention(
    q, k, v, gamma=None, *, chunk: int = 128, normalize: bool = False,
    eps: float = 1e-6, use_pallas: bool = True, fused_bwd: bool = True,
):
    """AHLA over (B, H, n, d) tensors (fused fwd + bwd)."""
    return _ahla_fwd_core(
        q, k, v, gamma, chunk, normalize, eps, use_pallas, fused_bwd
    )


# --------------------------------------------------------------------------
# Inference: chunk-parallel prefill + fused batched decode steps
# --------------------------------------------------------------------------
#
# ``*_prefill`` runs a whole prompt through ONE chunk-parallel kernel call,
# optionally resuming from a carry, and returns the final streaming state —
# exactly the serial recurrence by the paper's Section-4 identity (no
# per-token loop, no approximation).  ``*_decode_step`` applies one token of
# the streaming recurrence to every (batch, head) row in a single fused
# launch with in-place state update.  Both are inference-only (no VJP) and
# keep a pure-jnp fallback (CPU / correctness oracle).


def hla2_prefill(
    q, k, v, gamma=None, *, state: HLA2State | None = None,
    chunk: int = 128, normalize: bool = False, eps: float = 1e-6,
    lam: float = 0.0, use_pallas: bool = True,
):
    """Chunk-parallel HLA2 prefill over (B, H, n, d).  Returns
    ``(o, HLA2State)`` — the state decodes onward via ``hla2_decode_step``."""
    if not use_pallas:
        return hla2_chunkwise(
            q, k, v, gamma, chunk=chunk, normalize=normalize, eps=eps,
            lam=lam, state=state,
        )
    TRACE_COUNTS["hla2_prefill"] += 1
    qf, B, H = _merge_bh(q)
    kf, _, _ = _merge_bh(k)
    vf, _, _ = _merge_bh(v)
    gf = None if gamma is None else (
        jnp.broadcast_to(jnp.asarray(gamma), (B, H)).reshape(B * H)
    )
    init = None
    if state is not None:
        init = tuple(
            x.astype(jnp.float32).reshape((B * H,) + x.shape[2:])
            for x in state
        )
    o, (S, C, m, G, h) = hla2_chunk_pallas(
        qf, kf, vf, gf, chunk=chunk, normalize=normalize, eps=eps, lam=lam,
        initial_state=init,
    )
    o = o.reshape(q.shape[:2] + o.shape[1:])
    unm = lambda x: x.reshape((B, H) + x.shape[1:])  # noqa: E731
    return o, HLA2State(unm(S), unm(C), unm(m), unm(G), unm(h))


def ahla_prefill(
    q, k, v, gamma=None, *, state: AHLAState | None = None,
    chunk: int = 128, normalize: bool = False, eps: float = 1e-6,
    use_pallas: bool = True,
):
    """Chunk-parallel AHLA prefill over (B, H, n, d).  Returns
    ``(o, AHLAState)``.  The undecayed cross moment ``R`` (scan-only
    bookkeeping, unused by decode outputs) accumulates outside the kernel."""
    if not use_pallas:
        return ahla_chunkwise(
            q, k, v, gamma, chunk=chunk, normalize=normalize, eps=eps,
            state=state,
        )
    TRACE_COUNTS["ahla_prefill"] += 1
    qf, B, H = _merge_bh(q)
    kf, _, _ = _merge_bh(k)
    vf, _, _ = _merge_bh(v)
    gf = None if gamma is None else (
        jnp.broadcast_to(jnp.asarray(gamma), (B, H)).reshape(B * H)
    )
    init = None
    R0 = None
    if state is not None:
        R0 = state.R
        init = tuple(
            x.astype(jnp.float32).reshape((B * H,) + x.shape[2:])
            for x in (state.P, state.m, state.E, state.n)
        )
    o, (P, m, E, n) = ahla_chunk_pallas(
        qf, kf, vf, gf, chunk=chunk, normalize=normalize, eps=eps,
        initial_state=init,
    )
    o = o.reshape(q.shape[:2] + o.shape[1:])
    unm = lambda x: x.reshape((B, H) + x.shape[1:])  # noqa: E731
    f32 = jnp.float32
    R = jnp.einsum(
        "bhtd,bhte->bhde", k.astype(f32), q.astype(f32)
    )
    if R0 is not None:
        R = R + R0.astype(f32)
    return o, AHLAState(R, unm(P), unm(m), unm(E), unm(n))


def hla2_decode_step(
    state: HLA2State, q_t, k_t, v_t, gamma=None, *,
    normalize: bool = False, eps: float = 1e-6, lam: float = 0.0,
    use_pallas: bool = True,
):
    """One fused decode token over (..., d) rows.  Returns ``(state, o_t)``."""
    if not use_pallas:
        from ..core.hla2 import hla2_step

        return hla2_step(
            state, q_t, k_t, v_t, gamma, normalize=normalize, eps=eps,
            lam=lam,
        )
    TRACE_COUNTS["hla2_decode_step"] += 1
    new_state, o = hla2_step_pallas(
        tuple(state), q_t, k_t, v_t, gamma, normalize=normalize, eps=eps,
        lam=lam,
    )
    return HLA2State(*new_state), o


def ahla_decode_step(
    state: AHLAState, q_t, k_t, v_t, gamma=None, *,
    normalize: bool = False, eps: float = 1e-6, use_pallas: bool = True,
):
    """One fused AHLA decode token.  Returns ``(state, o_t)``."""
    if not use_pallas:
        from ..core.ahla import ahla_step

        return ahla_step(
            state, q_t, k_t, v_t, gamma, normalize=normalize, eps=eps
        )
    TRACE_COUNTS["ahla_decode_step"] += 1
    new_state, o = ahla_step_pallas(
        tuple(state), q_t, k_t, v_t, gamma, normalize=normalize, eps=eps
    )
    return AHLAState(*new_state), o
