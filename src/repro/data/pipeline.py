"""Deterministic synthetic data pipeline (host-sharded, restart-safe).

Every batch is a pure function of (seed, step, host_shard) — after a
restart the stream resumes exactly, and multi-host launches read disjoint
global-batch slices with no coordination (the production property that
matters; the token *distribution* is synthetic: Zipf-ish LM stream plus
task generators used by the examples/benchmarks).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    kind: str = "zipf"  # zipf | copy | recall


class SyntheticStream:
    """Iterator of {tokens, labels} for one host's slice of the batch."""

    def __init__(self, cfg: DataConfig, host_index: int = 0, host_count: int = 1):
        assert cfg.global_batch % host_count == 0
        self.cfg = cfg
        self.host_index = host_index
        self.host_count = host_count
        self.local_batch = cfg.global_batch // host_count

    def batch(self, step: int):
        cfg = self.cfg
        seq = np.random.SeedSequence(
            entropy=(cfg.seed, step, self.host_index)
        )
        rng = np.random.Generator(np.random.Philox(seq))
        B, n, V = self.local_batch, cfg.seq_len, cfg.vocab
        if cfg.kind == "zipf":
            # zipf-distributed ids with short-range structure (bigram-ish
            # repeats) so a real model can actually reduce loss.
            base = rng.zipf(1.3, size=(B, n + 1)).astype(np.int64) % V
            rep = rng.random((B, n + 1)) < 0.3
            base[:, 1:][rep[:, 1:]] = base[:, :-1][rep[:, 1:]]
            tokens = base[:, :-1].astype(np.int32)
            labels = base[:, 1:].astype(np.int32)
        elif cfg.kind == "copy":
            half = n // 2
            pattern = rng.integers(2, V, size=(B, half), dtype=np.int32)
            tokens = np.concatenate(
                [pattern, np.full((B, n - half), 1, np.int32)], axis=1
            )
            labels = np.concatenate(
                [np.full((B, half), -1, np.int32),
                 pattern[:, : n - half]], axis=1
            )
        elif cfg.kind == "recall":
            # associative recall: k1 v1 k2 v2 ... query k_i -> predict v_i
            pairs = (n - 2) // 2
            keys = rng.integers(2, V // 2, size=(B, pairs), dtype=np.int32)
            vals = rng.integers(V // 2, V, size=(B, pairs), dtype=np.int32)
            inter = np.stack([keys, vals], axis=-1).reshape(B, -1)
            qidx = rng.integers(0, pairs, size=(B,))
            qk = keys[np.arange(B), qidx]
            qv = vals[np.arange(B), qidx]
            tokens = np.concatenate(
                [inter, qk[:, None],
                 np.full((B, n - inter.shape[1] - 1), 1, np.int32)], axis=1
            )[:, :n]
            labels = np.full((B, n), -1, np.int32)
            labels[:, inter.shape[1]] = qv  # predict value right after query
        else:
            raise ValueError(cfg.kind)
        return {"tokens": tokens, "labels": labels}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1
