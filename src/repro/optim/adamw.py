"""AdamW + cosine schedule + global-norm clipping — built from scratch.

Pure-pytree implementation (no optax in the image).  Master weights and
moments in fp32; works with ZeRO-1 sharded optimizer state (the sharding
is decided by ``distributed.sharding.opt_state_shardings``).
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    betas: tuple = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1


class OptState(NamedTuple):
    step: jax.Array  # () int32
    mu: Any  # pytree like params (fp32)
    nu: Any  # pytree like params (fp32)


def init_opt_state(params, moment_dtype=jnp.float32) -> OptState:
    z = lambda p: jnp.zeros(p.shape, moment_dtype)  # noqa: E731
    return OptState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(z, params),
        nu=jax.tree.map(z, params),
    )


def cosine_lr(step, cfg: OptConfig):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps)
        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree):
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def adamw_update(params, grads, state: OptState, cfg: OptConfig):
    """Returns (new_params, new_state, metrics)."""
    grads = jax.tree.map(
        lambda g: g.astype(jnp.promote_types(g.dtype, jnp.float32)), grads
    )
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = cosine_lr(step, cfg)
    b1, b2 = cfg.betas
    mu = jax.tree.map(
        lambda m, g: (b1 * m.astype(g.dtype) + (1 - b1) * g).astype(m.dtype),
        state.mu, grads,
    )
    nu = jax.tree.map(
        lambda v, g: (b2 * v.astype(g.dtype) + (1 - b2) * g * g).astype(v.dtype),
        state.nu, grads,
    )
    bc1 = 1 - b1**step.astype(jnp.float32)
    bc2 = 1 - b2**step.astype(jnp.float32)

    def upd(p, m, v):
        ct = jnp.promote_types(p.dtype, jnp.float32)
        mhat = m.astype(ct) / bc1
        vhat = v.astype(ct) / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        p32 = p.astype(ct)
        if cfg.weight_decay and p.ndim >= 2:  # no decay on norms/biases
            delta = delta + cfg.weight_decay * p32
        return (p32 - lr * delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, OptState(step, mu, nu), {"lr": lr, "grad_norm": gnorm}
