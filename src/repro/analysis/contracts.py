"""Lowered-HLO trace contracts: what the compiled program must look like.

The AST linter (``repro.analysis.checks``) verifies the *source*; this
module verifies the *artifact*.  The four hot entry points — train step,
prefill, decode block, speculative round — are lowered and compiled on
CPU for a small config (**never executed**: every argument is a
``ShapeDtypeStruct``) and the optimized HLO is checked, via the
loop-aware parser in :mod:`repro.analysis.hlo_analysis`, against the
contracts the paper's efficiency claims rest on:

* **no-f64** — no op computes in or produces ``f64``: a silent float64
  upcast doubles state bytes and halves the roofline;
* **donation** — every buffer the entry point declares donated is
  actually aliased by XLA (``input_output_alias``): a dropped donation
  means a second copy of params/opt-state/decode-state lives through
  the step;
* **no-host-transfers** — no infeed/outfeed/send/recv or host-callback
  custom-calls inside the step: the engine's one-sync-per-block
  discipline (RPR004) is meaningless if the compiled program phones
  home mid-step;
* **bounded-collectives** — at most ``max_collectives`` collective ops
  (0 for the single-device contract config);
* **stable-HLO** (recompilation hazard) — prompt lengths that pad to
  the same chunk bucket must produce byte-identical normalized HLO:
  if shape-identical inputs ever lower differently, every admission
  risks a recompile.

CLI: ``python -m repro.analysis.contracts [--arch hla-1b] [--json]``.
Exit 1 on any violated contract.  The tier-1 pytest wiring lives in
``tests/test_contracts.py``.
"""

from __future__ import annotations

import dataclasses
import hashlib
import re
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from .hlo_analysis import analyze, parse_hlo

# --------------------------------------------------------------------------
# HLO-level predicates
# --------------------------------------------------------------------------

_ALIAS_ENTRY_RE = re.compile(
    r"\{([\d,\s]*)\}:\s*\((\d+),\s*\{[\d,\s]*\},\s*(?:may|must)-alias\)"
)

# custom-call targets that move data to/from the host mid-program
_HOST_CALL_MARKERS = ("callback", "xla_python", "host")
_TRANSFER_KINDS = ("infeed", "outfeed", "send", "recv",
                   "send-done", "recv-done")


def f64_ops(hlo_text: str) -> List[str]:
    """Names of ops whose output or operands are f64."""
    comps, _ = parse_hlo(hlo_text)
    out = []
    for comp in comps.values():
        for op in comp.ops:
            if "f64[" in op.out_shapes or "f64[" in op.rhs:
                out.append(f"{comp.name}/{op.name} = {op.kind}")
    return out


def host_transfer_ops(hlo_text: str) -> List[str]:
    """Names of host-transfer ops (infeed/outfeed/send/recv/callbacks)."""
    comps, _ = parse_hlo(hlo_text)
    out = []
    for comp in comps.values():
        for op in comp.ops:
            if op.kind in _TRANSFER_KINDS:
                out.append(f"{comp.name}/{op.name} = {op.kind}")
            elif op.kind == "custom-call" and any(
                m in op.rhs for m in _HOST_CALL_MARKERS
            ):
                out.append(f"{comp.name}/{op.name} = {op.rhs[:80]}")
    return out


def donated_aliases(hlo_text: str) -> Dict[int, str]:
    """``input_output_alias`` of the compiled module:
    parameter number -> output tuple index (as text)."""
    m = re.search(r"input_output_alias=\{(.*?)\}(?:,\s*[a-z_]+=|\s*$)",
                  hlo_text)
    if not m:
        return {}
    return {
        int(param): out_idx
        for out_idx, param, in (
            e[:2] for e in _ALIAS_ENTRY_RE.findall("{" + m.group(1) + "}")
        )
    }


def hlo_fingerprint(hlo_text: str) -> str:
    """sha256 of the HLO with comment lines stripped — two lowerings are
    "the same program" iff their fingerprints match."""
    lines = [
        ln.rstrip() for ln in hlo_text.splitlines()
        if ln.strip() and not ln.strip().startswith("//")
    ]
    return hashlib.sha256("\n".join(lines).encode()).hexdigest()


# --------------------------------------------------------------------------
# contract evaluation
# --------------------------------------------------------------------------


@dataclasses.dataclass
class ContractReport:
    """One entry point's verdict.  ``violations`` empty means the
    compiled artifact honors every contract."""

    name: str
    violations: List[str]
    n_aliased: int
    collective_total: int
    fingerprint: str

    @property
    def ok(self) -> bool:
        return not self.violations

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["ok"] = self.ok
        return d


def check_hlo(
    name: str,
    hlo_text: str,
    *,
    expected_donations: int = 0,
    max_collectives: int = 0,
) -> ContractReport:
    """Evaluate every per-module contract on one compiled HLO text."""
    violations: List[str] = []
    bad_f64 = f64_ops(hlo_text)
    if bad_f64:
        violations.append(
            f"f64 ops in compiled program ({len(bad_f64)}): "
            + "; ".join(bad_f64[:5])
        )
    transfers = host_transfer_ops(hlo_text)
    if transfers:
        violations.append(
            f"host transfers inside the step ({len(transfers)}): "
            + "; ".join(transfers[:5])
        )
    aliases = donated_aliases(hlo_text)
    if len(aliases) != expected_donations:
        violations.append(
            f"donation contract: {expected_donations} buffer(s) declared "
            f"donated but {len(aliases)} aliased by XLA — a dropped "
            "donation keeps a dead copy live through the step"
        )
    stats = analyze(hlo_text)
    total_coll = sum(stats["collective_counts"].values())
    if total_coll > max_collectives:
        violations.append(
            f"collective count {total_coll} exceeds bound "
            f"{max_collectives}: {stats['collective_counts']}"
        )
    return ContractReport(
        name=name,
        violations=violations,
        n_aliased=len(aliases),
        collective_total=total_coll,
        fingerprint=hlo_fingerprint(hlo_text),
    )


def lower_compiled_text(fn, args, *, donate_argnums=()) -> str:
    """Compile ``fn`` on abstract args (no execution) -> optimized HLO.

    ``lowered.as_text()`` would be StableHLO MLIR, which parse_hlo cannot
    read — the contracts run on the *compiled* module, which is also the
    only place ``input_output_alias`` exists.
    """
    jitted = jax.jit(fn, donate_argnums=donate_argnums)
    return jitted.lower(*args).compile().as_text()


def pad_to_bucket(n: int, chunk: int) -> int:
    """The serving admission bucket: lengths are padded up to a chunk
    multiple, so only the bucket — never the raw length — may key a
    compilation."""
    return max(chunk, -(-n // chunk) * chunk)


# --------------------------------------------------------------------------
# the four hot entry points, as abstract-arg factories
# --------------------------------------------------------------------------


def default_config():
    """Small CPU-lowerable config: reduced hla-1b with a small chunk so
    the padded-length set stays cheap to compile."""
    from ..configs import get_config

    cfg = get_config("hla-1b", reduced=True)
    return cfg.replace(hla=dataclasses.replace(cfg.hla, chunk=16))


def _abstract_params(cfg):
    from ..distributed import steps as steps_mod
    from ..models.param import abstract_params

    return abstract_params(steps_mod.model_specs(cfg))


def _abstract_opt_state(cfg, params_abs):
    from ..optim import adamw

    md = jnp.dtype(getattr(cfg, "moment_dtype", "float32"))
    mom = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, md), params_abs
    )
    return adamw.OptState(
        step=jax.ShapeDtypeStruct((), jnp.int32),
        mu=mom,
        nu=jax.tree.map(lambda a: a, mom),
    )


def _abstract_states(cfg, slots: int, max_len: int):
    from ..models import lm

    return jax.eval_shape(lambda: lm.lm_init_states(cfg, slots, max_len))


def _n_leaves(tree) -> int:
    return len(jax.tree.leaves(tree))


def _key_struct():
    return jax.eval_shape(lambda: jax.random.PRNGKey(0))


def train_step_hlo(cfg, *, batch: int = 2, seq: int = 32
                   ) -> Tuple[str, int]:
    """Train step with (params, opt_state) donated.

    Returns (compiled HLO, number of donated leaves)."""
    from ..distributed import steps as steps_mod
    from ..optim import adamw

    step = steps_mod.make_train_step(cfg, adamw.OptConfig())
    params = _abstract_params(cfg)
    opt_state = _abstract_opt_state(cfg, params)
    batch_abs = {
        "tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
    }
    hlo = lower_compiled_text(
        step, (params, opt_state, batch_abs), donate_argnums=(0, 1)
    )
    return hlo, _n_leaves(params) + _n_leaves(opt_state)


def prefill_hlo(cfg, *, batch: int = 2, prompt_len: int = 32) -> str:
    """Admission prefill.  Declares NO donations (the prompt batch and
    params are both reused), so the contract asserts an empty alias map."""
    from ..distributed import steps as steps_mod

    step = steps_mod.make_prefill_step(cfg)
    params = _abstract_params(cfg)
    batch_abs = {
        "tokens": jax.ShapeDtypeStruct((batch, prompt_len), jnp.int32),
    }
    return lower_compiled_text(step, (params, batch_abs))


def make_decode_block(cfg, scfg, n_steps: int):
    """The contract mirror of ``Engine._decode_block``: a lax.scan of
    single-token ``lm_apply`` decode steps with on-device sampling.
    Kept structurally minimal — the contract is about what XLA does to
    a scan-of-decode-steps, not about engine bookkeeping."""
    from ..models import lm
    from ..serving.sampling import sample

    def decode_block(params, states, tokens, positions, active, key):
        def body(carry, _):
            states, tok, pos, key = carry
            logits, states, _ = lm.lm_apply(
                params, tok, cfg, states=states, positions=pos,
                mode="decode",
            )
            key, sub = jax.random.split(key)
            nxt = sample(logits[:, -1], sub, scfg)
            tok = jnp.where(active[:, None], nxt[:, None], tok)
            pos = pos + active[:, None].astype(pos.dtype)
            return (states, tok, pos, key), nxt

        (states, tok, pos, _), toks = jax.lax.scan(
            body, (states, tokens, positions, key), length=n_steps
        )
        return states, tok, pos, toks

    return decode_block


def decode_block_hlo(cfg, *, slots: int = 2, block: int = 4,
                     max_len: int = 64) -> Tuple[str, int]:
    """Decode block with (states, tokens, positions) donated — the
    in-place state update the O(1)-state claim depends on."""
    from ..serving.sampling import SamplingConfig

    fn = make_decode_block(cfg, SamplingConfig(), block)
    states = _abstract_states(cfg, slots, max_len)
    tokens = jax.ShapeDtypeStruct((slots, 1), jnp.int32)
    positions = jax.ShapeDtypeStruct((slots, 1), jnp.int32)
    active = jax.ShapeDtypeStruct((slots,), jnp.bool_)
    hlo = lower_compiled_text(
        fn,
        (_abstract_params(cfg), states, tokens, positions, active,
         _key_struct()),
        donate_argnums=(1, 2, 3),
    )
    return hlo, _n_leaves(states) + 2


def spec_round_hlo(cfg, *, slots: int = 2, k: int = 4,
                   max_len: int = 64) -> Tuple[str, int]:
    """Speculative round (verify + commit) with decode state donated."""
    from ..serving.sampling import SamplingConfig
    from ..serving.spec.verify import make_spec_round

    fn = make_spec_round(cfg, SamplingConfig())
    states = _abstract_states(cfg, slots, max_len)
    tokens = jax.ShapeDtypeStruct((slots, 1), jnp.int32)
    positions = jax.ShapeDtypeStruct((slots, 1), jnp.int32)
    active = jax.ShapeDtypeStruct((slots,), jnp.bool_)
    drafts = jax.ShapeDtypeStruct((slots, k), jnp.int32)
    hlo = lower_compiled_text(
        fn,
        (_abstract_params(cfg), states, tokens, positions, active,
         drafts, _key_struct()),
        donate_argnums=(1, 2, 3),
    )
    return hlo, _n_leaves(states) + 2


# --------------------------------------------------------------------------
# the full contract run
# --------------------------------------------------------------------------


def check_entry_points(
    cfg=None,
    *,
    max_collectives: int = 0,
    prompt_lengths: Sequence[int] = (5, 11, 16),
) -> List[ContractReport]:
    """Lower all four entry points and evaluate every contract.

    ``prompt_lengths`` drives the recompilation-hazard check: all
    lengths padding to the same chunk bucket must fingerprint
    identically (the default set pads to one 16-bucket)."""
    if cfg is None:
        cfg = default_config()
    reports: List[ContractReport] = []

    hlo, n_don = train_step_hlo(cfg)
    reports.append(check_hlo(
        "train_step", hlo, expected_donations=n_don,
        max_collectives=max_collectives,
    ))

    hlo = prefill_hlo(cfg, prompt_len=pad_to_bucket(
        prompt_lengths[0], cfg.hla.chunk
    ))
    prefill_report = check_hlo(
        "prefill", hlo, expected_donations=0,
        max_collectives=max_collectives,
    )

    # recompilation hazard: same bucket -> byte-identical program
    by_bucket: Dict[int, Dict[int, str]] = {}
    for n in prompt_lengths:
        bucket = pad_to_bucket(n, cfg.hla.chunk)
        fp = hlo_fingerprint(prefill_hlo(cfg, prompt_len=bucket))
        by_bucket.setdefault(bucket, {})[n] = fp
    for bucket, fps in sorted(by_bucket.items()):
        if len(set(fps.values())) > 1:
            prefill_report.violations.append(
                f"recompilation hazard: prompt lengths {sorted(fps)} all "
                f"pad to bucket {bucket} but lower to "
                f"{len(set(fps.values()))} distinct programs"
            )
    reports.append(prefill_report)

    hlo, n_don = decode_block_hlo(cfg)
    reports.append(check_hlo(
        "decode_block", hlo, expected_donations=n_don,
        max_collectives=max_collectives,
    ))

    hlo, n_don = spec_round_hlo(cfg)
    reports.append(check_hlo(
        "spec_round", hlo, expected_donations=n_don,
        max_collectives=max_collectives,
    ))
    return reports


def main(argv: Optional[List[str]] = None) -> int:
    import argparse
    import json as _json

    p = argparse.ArgumentParser(
        prog="python -m repro.analysis.contracts",
        description="Lower-only HLO trace contracts for the four hot "
                    "entry points (CPU, no execution).",
    )
    p.add_argument("--arch", default=None,
                   help="config name (default: reduced hla-1b)")
    p.add_argument("--max-collectives", type=int, default=0)
    p.add_argument("--json", action="store_true")
    args = p.parse_args(argv)

    cfg = None
    if args.arch:
        from ..configs import get_config

        cfg = get_config(args.arch, reduced=True)
        cfg = cfg.replace(hla=dataclasses.replace(cfg.hla, chunk=16))
    reports = check_entry_points(cfg, max_collectives=args.max_collectives)
    if args.json:
        print(_json.dumps(
            {"schema": "repro.contracts/v1",
             "reports": [r.to_dict() for r in reports]}, indent=2,
        ))
    else:
        for r in reports:
            status = "ok" if r.ok else "VIOLATED"
            print(f"{r.name:14s} {status}  aliased={r.n_aliased} "
                  f"collectives={r.collective_total} "
                  f"fp={r.fingerprint[:12]}")
            for v in r.violations:
                print(f"    - {v}")
    return 0 if all(r.ok for r in reports) else 1


if __name__ == "__main__":
    import sys

    sys.exit(main())
