"""Offline analysis of the lowered program: the loop-aware HLO parser
(:mod:`repro.analysis.hlo_analysis`), the invariant linter
(:mod:`repro.analysis.checks`), and the lower-only trace contracts
(:mod:`repro.analysis.contracts`)."""
