"""Loop-aware HLO analysis for the roofline terms.

``compiled.cost_analysis()`` counts each while-loop *body once* — a
scan-over-layers model therefore under-reports FLOPs/bytes by ~n_layers x,
and collectives inside the loop likewise (verified empirically; see
EXPERIMENTS.md §Roofline "methodology").  XLA however annotates every while
with ``backend_config={"known_trip_count": {"n": ...}}``, so an exact
loop-aware account is possible from the compiled text:

* computations are parsed into per-op defs (symbol -> shape);
* execution multipliers propagate ENTRY=1, while body/cond x trip_count,
  fusions/calls inherit the caller's multiplier;
* FLOPs: 2 * prod(out_shape) * prod(lhs contracting dims) per dot
  (fusion-internal dots included);
* bytes: per-op operand+output bytes in non-fusion computations (fusion
  internals live in registers — matches XLA's own bytes_accessed model);
* collective bytes: output-shape bytes per collective op (the gathered /
  reduced size — wire-bytes upper bound per device), tracked per kind.

All numbers are per-device (the HLO is the SPMD-partitioned module).
"""

from __future__ import annotations

import json
import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SKIP_BYTES_OPS = {
    # SSA bookkeeping / no HBM traffic of their own (loop bodies are
    # accounted separately via multipliers):
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "iota", "while", "conditional", "call", "partition-id",
    "replica-id", "optimization-barrier", "reshape",
}

_shape_re = re.compile(r"(\w+)\[([\d,]*)\]")
_def_re = re.compile(r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*(.*)$")
_header_re = re.compile(r"^(?:ENTRY\s+)?(%[\w.\-]+)\s*\(.*\)\s*->")


def _shapes_bytes(text):
    """Sum bytes over every typed shape literal in `text`."""
    total = 0
    for dt, dims in _shape_re.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class Op:
    name: str
    kind: str
    out_bytes: int
    out_shapes: str  # raw text of the output type
    operands: list
    rhs: str


@dataclass
class Computation:
    name: str
    ops: list = field(default_factory=list)
    defs: dict = field(default_factory=dict)  # name -> out type text
    is_entry: bool = False
    param_order: list = field(default_factory=list)  # parameter(i) -> name

    def slice_like_param_bytes(self):
        """For each parameter index: if every in-computation use is a
        slicing op (dynamic-slice/slice/gather), the fusion only reads the
        slice — return {idx: slice_out_bytes}; else omit the index."""
        uses = {name: [] for name in self.param_order}
        for op in self.ops:
            for o in op.operands:
                if o in uses:
                    uses[o].append(op)
        out = {}
        for idx, name in enumerate(self.param_order):
            ops = uses.get(name, [])
            if ops and all(
                u.kind in ("dynamic-slice", "slice", "gather") for u in ops
            ):
                out[idx] = sum(u.out_bytes for u in ops)
        return out


_KIND_RE = re.compile(
    r"\b([a-z][a-z0-9\-]*)\("
)


def parse_hlo(text: str):
    comps = {}
    cur = None
    entry_name = None
    for raw in text.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if not s or s.startswith("//"):
            continue
        hm = _header_re.match(s)
        if hm and s.endswith("{"):
            cur = Computation(hm.group(1))
            cur.is_entry = s.startswith("ENTRY")
            comps[cur.name] = cur
            if cur.is_entry:
                entry_name = cur.name
            continue
        if s == "}" or cur is None:
            continue
        dm = _def_re.match(s)
        if not dm:
            continue
        name, rhs = dm.group(1), dm.group(2)
        # output type: everything before the op kind token
        km = None
        for m in _KIND_RE.finditer(rhs):
            tok = m.group(1)
            if tok in ("metadata", "backend_config", "calls", "f32", "bf16"):
                continue
            km = m
            break
        op_kind = km.group(1) if km else "unknown"
        out_text = rhs[: km.start()] if km else rhs
        # operands: inside the first (...) after the op kind
        operands = []
        if km:
            depth = 0
            buf = ""
            for ch in rhs[km.end() - 1:]:
                if ch == "(":
                    depth += 1
                    if depth == 1:
                        continue
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        break
                if depth >= 1:
                    buf += ch
            operands = re.findall(r"%[\w.\-]+", buf)
        cur.defs[name] = out_text
        if op_kind == "parameter":
            pm = re.search(r"parameter\((\d+)\)", rhs)
            if pm:
                idx = int(pm.group(1))
                while len(cur.param_order) <= idx:
                    cur.param_order.append(None)
                cur.param_order[idx] = name
        cur.ops.append(Op(name, op_kind, _shapes_bytes(out_text), out_text,
                          operands, rhs))
    return comps, entry_name


_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_BODY_RE = re.compile(r"body=(%[\w.\-]+)")
_COND_RE = re.compile(r"condition=(%[\w.\-]+)")
_CALLS_RE = re.compile(r"calls=(%[\w.\-]+)")


def _dot_flops(op: Op, defs):
    out = 1
    m = _shape_re.search(op.out_shapes)
    if not m:
        return 0
    for d in m.group(2).split(","):
        if d:
            out *= int(d)
    cdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rhs)
    if not cdims or not op.operands:
        return 2 * out  # dot with scalar contraction
    lhs_type = defs.get(op.operands[0], "")
    lm = _shape_re.search(lhs_type)
    if not lm:
        return 2 * out
    ldims = [int(x) for x in lm.group(2).split(",") if x]
    k = 1
    for idx in cdims.group(1).split(","):
        if idx and int(idx) < len(ldims):
            k *= ldims[int(idx)]
    return 2 * out * k


def analyze(text: str, details: bool = False):
    """Loop-aware per-device totals: flops, bytes, collective bytes/counts."""
    comps, entry = parse_hlo(text)
    by_kind = defaultdict(float)

    # multipliers: BFS from entry
    mult = defaultdict(float)
    mult[entry] = 1.0
    order = [entry]
    seen = {entry}
    i = 0
    while i < len(order):
        cname = order[i]
        i += 1
        comp = comps.get(cname)
        if comp is None:
            continue
        m = mult[cname]
        for op in comp.ops:
            if op.kind == "while":
                trip = 1
                tm = _TRIP_RE.search(op.rhs)
                if tm:
                    trip = int(tm.group(1))
                for rx in (_BODY_RE, _COND_RE):
                    bm = rx.search(op.rhs)
                    if bm:
                        child = bm.group(1)
                        mult[child] += m * trip
                        if child not in seen:
                            seen.add(child)
                            order.append(child)
            else:
                for cm in _CALLS_RE.finditer(op.rhs):
                    child = cm.group(1)
                    mult[child] += m
                    if child not in seen:
                        seen.add(child)
                        order.append(child)
                # conditional branches
                for bm in re.finditer(
                    r"(?:true_computation|false_computation|branch_computations)="
                    r"\{?([%\w.\-, ]+)\}?", op.rhs
                ):
                    for child in re.findall(r"%[\w.\-]+", bm.group(1)):
                        mult[child] += m
                        if child not in seen:
                            seen.add(child)
                            order.append(child)

    flops = 0.0
    bytes_acc = 0.0
    coll_bytes = {k: 0.0 for k in _COLLECTIVES}
    coll_counts = {k: 0 for k in _COLLECTIVES}
    fusion_names = set()
    for comp in comps.values():
        for op in comp.ops:
            if op.kind == "fusion":
                for cm in _CALLS_RE.finditer(op.rhs):
                    fusion_names.add(cm.group(1))

    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        in_fusion = cname in fusion_names
        for op in comp.ops:
            if op.kind in ("dot", "convolution"):
                flops += m * _dot_flops(op, comp.defs)
            if in_fusion:
                continue  # fusion internals: registers, no HBM traffic
            if op.kind in _SKIP_BYTES_OPS:
                continue
            if op.kind.endswith("-done"):
                continue
            # XLA-style special cases: slicing ops touch only the slice,
            # not the sliced-into buffer.
            if op.kind in ("dynamic-slice", "slice", "gather"):
                nbytes = 2 * op.out_bytes
            elif op.kind == "dynamic-update-slice":
                upd = (
                    _shapes_bytes(comp.defs.get(op.operands[1], ""))
                    if len(op.operands) > 1
                    else op.out_bytes
                )
                nbytes = 2 * upd
            elif op.kind == "scatter":
                upd = (
                    _shapes_bytes(comp.defs.get(op.operands[-1], ""))
                    if op.operands
                    else op.out_bytes
                )
                nbytes = 2 * upd + op.out_bytes
            elif op.kind == "fusion":
                nbytes = op.out_bytes
                callee = None
                cm = _CALLS_RE.search(op.rhs)
                if cm:
                    callee = comps.get(cm.group(1))
                sliced = callee.slice_like_param_bytes() if callee else {}
                for i, o in enumerate(op.operands):
                    if i in sliced:
                        nbytes += sliced[i]
                        continue
                    t = comp.defs.get(o)
                    if t:
                        nbytes += _shapes_bytes(t)
            else:
                nbytes = op.out_bytes
                for o in op.operands:
                    t = comp.defs.get(o)
                    if t:
                        nbytes += _shapes_bytes(t)
            bytes_acc += m * nbytes
            if details:
                by_kind[op.kind] += m * nbytes
            base = op.kind[:-6] if op.kind.endswith("-start") else op.kind
            if base in _COLLECTIVES:
                coll_bytes[base] += m * op.out_bytes
                coll_counts[base] += int(m)

    out = {
        "flops": flops,
        "bytes": bytes_acc,
        "collective_bytes": coll_bytes,
        "collective_counts": coll_counts,
        "collective_total": sum(coll_bytes.values()),
    }
    if details:
        out["bytes_by_kind"] = dict(
            sorted(by_kind.items(), key=lambda kv: -kv[1])[:15]
        )
    return out


def main(argv=None) -> int:
    """``python -m repro.analysis.hlo_analysis dump.hlo [--details]`` —
    the loop-aware FLOPs/bytes/collectives account of a compiled module
    (replaces the old ``benchmarks/hlo_analysis.py`` wrapper)."""
    import argparse
    import sys

    p = argparse.ArgumentParser(
        prog="python -m repro.analysis.hlo_analysis",
        description="Loop-aware FLOPs/bytes/collective analysis of a "
                    "compiled HLO text dump.",
    )
    p.add_argument("hlo", help="path to compiled HLO text "
                               "(compiled.as_text()), or - for stdin")
    p.add_argument("--details", action="store_true",
                   help="include the per-op-kind bytes breakdown")
    args = p.parse_args(argv)
    text = sys.stdin.read() if args.hlo == "-" else open(args.hlo).read()
    try:
        print(json.dumps(analyze(text, details=args.details), indent=2))
    except BrokenPipeError:  # `... | head` closed the pipe; not an error
        sys.stderr.close()
    return 0


if __name__ == "__main__":
    import sys

    sys.exit(main())
