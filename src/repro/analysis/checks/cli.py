"""CLI: ``python -m repro.analysis.checks src/repro [options]``.

Exit status is the contract CI keys on: 0 when every finding is
baselined (or there are none), 1 when any NEW finding exists, 2 on
usage errors.  ``--write-baseline`` accepts the current state so the
linter can land on an imperfect tree without weakening the rules.
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional

from .findings import Baseline, to_json
from .runner import make_baseline, run_checks, select_rules
from .rules import ALL_RULES


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis.checks",
        description="Rule-based invariant linter (RPR rules) for the "
                    "repro tree.",
    )
    p.add_argument("paths", nargs="*", default=["src/repro"],
                   help="files or directories to lint "
                        "(default: src/repro)")
    p.add_argument("--format", choices=("console", "json"),
                   default="console", dest="fmt")
    p.add_argument("--baseline", metavar="FILE",
                   help="baseline JSON; matching findings do not fail "
                        "the run")
    p.add_argument("--write-baseline", metavar="FILE",
                   help="fingerprint all current findings into FILE and "
                        "exit 0")
    p.add_argument("--rules", metavar="RPR001,RPR004",
                   help="comma-separated subset of rules to run")
    p.add_argument("--list-rules", action="store_true",
                   help="print the rule table and exit")
    return p


def main(argv: Optional[List[str]] = None) -> int:
    args = build_parser().parse_args(argv)
    if args.list_rules:
        for rule in ALL_RULES:
            print(f"{rule.code}  {rule.name}")
            print(f"        {rule.description}")
        return 0

    codes = None
    if args.rules:
        codes = [c.strip() for c in args.rules.split(",") if c.strip()]
        try:
            select_rules(codes)
        except KeyError as e:
            print(f"error: {e.args[0]}", file=sys.stderr)
            return 2

    paths = args.paths or ["src/repro"]

    if args.write_baseline:
        bl = make_baseline(paths, rules=codes)
        bl.save(args.write_baseline)
        print(f"wrote {len(bl.fingerprints)} fingerprint(s) to "
              f"{args.write_baseline}")
        return 0

    baseline = None
    if args.baseline:
        try:
            baseline = Baseline.load(args.baseline)
        except (OSError, ValueError) as e:
            print(f"error: cannot load baseline: {e}", file=sys.stderr)
            return 2

    findings = run_checks(paths, rules=codes, baseline=baseline)
    new = [f for f in findings if not f.baselined]

    if args.fmt == "json":
        print(json.dumps(to_json(findings), indent=2))
    else:
        for f in findings:
            print(f.render())
        checked = ", ".join(paths)
        if new:
            print(f"\n{len(new)} new finding(s) "
                  f"({len(findings) - len(new)} baselined) in {checked}")
        else:
            print(f"clean: 0 new findings "
                  f"({len(findings)} baselined) in {checked}")
    return 1 if new else 0


if __name__ == "__main__":
    sys.exit(main())
