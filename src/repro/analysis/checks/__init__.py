"""Machine-checked repo invariants: the RPR rule set (DESIGN.md §14).

``python -m repro.analysis.checks src/repro`` lints the tree; library
use goes through :func:`run_checks`.  The compiled-artifact
counterpart (lowered-HLO trace contracts) lives in
``repro.analysis.contracts``.
"""

from .findings import Baseline, Finding, fingerprint, to_json  # noqa: F401
from .rules import ALL_RULES, RULES_BY_CODE  # noqa: F401
from .runner import collect_modules, make_baseline, run_checks  # noqa: F401
