"""The RPR rule set: machine-checked forms of the repo's invariants.

Every rule is a ``Rule`` subclass with a stable code (``RPR0xx``).  Rules
see parsed modules (``runner.Module``: path + text + ast) and yield
``Finding``s; the runner applies ``# noqa: RPR0xx`` suppressions and the
baseline afterwards.  Rules that accept a semantic annotation (RPR004's
``# sync-point: <reason>``) check it themselves — an annotation
documents the invariant at the site, a noqa merely silences it.

Scoping uses ``Module.pkg_path`` — the path relative to the ``repro``
package root (``serving/engine.py``) — so the rules work identically on
the real tree and on test fixture trees.

| code   | invariant                                                    |
|--------|--------------------------------------------------------------|
| RPR001 | library code never calls bare ``print()`` (obs is the output)|
| RPR002 | no ``variant ==`` / ``kind ==`` dispatch outside seq_op.py   |
| RPR003 | ``Engine.run``'s drive loop never raises                     |
| RPR004 | host syncs in hot paths are explicit (``# sync-point:``)     |
| RPR005 | jit/Pallas-traced functions are pure (no time/random)        |
| RPR006 | fault points: firing sites <-> ``FAULT_POINTS`` catalog      |
| RPR007 | metric/event names follow the ``repro.obs`` naming schema    |
"""

from __future__ import annotations

import ast
import re
from typing import Dict, Iterable, Iterator, List, Optional, Set, Tuple

from .findings import Finding, line_annotation


def _root_name(node: ast.AST) -> Optional[str]:
    """Leftmost Name of a Name/Attribute/Subscript/Call chain."""
    while isinstance(node, (ast.Attribute, ast.Subscript, ast.Call)):
        node = node.func if isinstance(node, ast.Call) else node.value
    return node.id if isinstance(node, ast.Name) else None


def _dotted(node: ast.AST) -> Optional[str]:
    """``jax.device_get`` for Attribute chains, ``print`` for Names."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    parts.append(node.id)
    return ".".join(reversed(parts))


_FN_NODES = (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)


def _walk_own(root: ast.AST) -> List[ast.AST]:
    """Nodes belonging to ``root`` itself, NOT to functions nested in it.

    Scoping name-taint to a function's own statements keeps e.g. a
    ``key = jax.random...`` inside one method from poisoning the name
    ``key`` in every other method of the module.
    """
    out: List[ast.AST] = [root]

    def rec(n: ast.AST) -> None:
        for c in ast.iter_child_nodes(n):
            if isinstance(c, _FN_NODES):
                continue
            out.append(c)
            rec(c)

    rec(root)
    return out


class Rule:
    """Base: one invariant, one stable code."""

    code: str = "RPR000"
    name: str = "base"
    description: str = ""

    def check_module(self, mod) -> Iterator[Finding]:
        return iter(())

    def check_tree(self, mods) -> Iterator[Finding]:
        """Cross-file pass; runs once over all modules."""
        return iter(())

    def finding(self, mod, node: ast.AST, message: str) -> Finding:
        line = getattr(node, "lineno", 1)
        col = getattr(node, "col_offset", 0)
        snippet = ""
        if 1 <= line <= len(mod.lines):
            snippet = mod.lines[line - 1].strip()
        return Finding(rule=self.code, path=mod.report_path, line=line,
                       col=col, message=message, snippet=snippet)


# --------------------------------------------------------------------------
# RPR001 — no bare print() in library code
# --------------------------------------------------------------------------


class BarePrintRule(Rule):
    code = "RPR001"
    name = "no-bare-print"
    description = (
        "Library code reports through repro.obs (metrics/events) or a "
        "log= callable, never bare print().  CLIs under launch/ and "
        "analysis/, plus the obs validator and perfcheck CLIs, are "
        "user-facing and exempt."
    )

    EXEMPT_DIRS = ("launch/", "analysis/")
    EXEMPT_FILES = ("obs/validate.py", "obs/perfcheck.py")

    def check_module(self, mod):
        p = mod.pkg_path
        if p.startswith(self.EXEMPT_DIRS) or p in self.EXEMPT_FILES:
            return
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Call) and \
                    isinstance(node.func, ast.Name) and \
                    node.func.id == "print":
                yield self.finding(
                    mod, node,
                    "bare print() in library code — emit through repro.obs "
                    "or take a log= callable",
                )


# --------------------------------------------------------------------------
# RPR002 — operator dispatch lives in the SequenceOp registry only
# --------------------------------------------------------------------------


class DispatchLadderRule(Rule):
    code = "RPR002"
    name = "no-dispatch-ladder"
    description = (
        "The SequenceOp registry (models/seq_op.py) is the ONE place "
        "operator dispatch may live: comparing a bare `variant` or "
        "`kind` name anywhere else is a hand-synced ladder.  Attribute "
        "access (`shape_cfg.kind ==`) is config/HLO metadata and stays "
        "allowed."
    )

    EXEMPT_FILES = ("models/seq_op.py",)
    NAMES = ("variant", "kind")

    def check_module(self, mod):
        if mod.pkg_path in self.EXEMPT_FILES:
            return
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Compare):
                continue
            # flag `variant == ...` / `kind == ...`: a bare name as the
            # LEFT operand of an ==/!= (matches the retired shell guard;
            # `x == kind` filter-style comparisons stay allowed)
            operands = [node.left] + list(node.comparators)
            for i, op in enumerate(node.ops):
                if not isinstance(op, (ast.Eq, ast.NotEq)):
                    continue
                left = operands[i]
                if isinstance(left, ast.Name) and left.id in self.NAMES:
                    yield self.finding(
                        mod, node,
                        f"operator dispatch on bare `{left.id}` outside "
                        "models/seq_op.py — register a SequenceOp "
                        "instead",
                    )


# --------------------------------------------------------------------------
# RPR003 — Engine.run's drive loop never raises
# --------------------------------------------------------------------------


class EngineRunNoRaiseRule(Rule):
    code = "RPR003"
    name = "engine-run-no-raise"
    description = (
        "Engine.run converts per-request failures into GenResult "
        "statuses; a `raise` inside its while drive loop would kill "
        "every in-flight request (DESIGN.md §12)."
    )

    TARGET = "serving/engine.py"

    def check_module(self, mod):
        if mod.pkg_path != self.TARGET:
            return
        run_fn = None
        for cls in ast.walk(mod.tree):
            if isinstance(cls, ast.ClassDef) and cls.name == "Engine":
                for n in cls.body:
                    if isinstance(n, (ast.FunctionDef,
                                      ast.AsyncFunctionDef)) \
                            and n.name == "run":
                        run_fn = n
        if run_fn is None:
            yield self.finding(
                mod, mod.tree,
                "Engine.run not found — the no-raise drive-loop contract "
                "has lost its anchor (rename it together with this rule)",
            )
            return
        loops = [n for n in ast.walk(run_fn) if isinstance(n, ast.While)]
        if not loops:
            yield self.finding(
                mod, run_fn, "Engine.run has no while drive loop"
            )
            return
        for loop in loops:
            for n in ast.walk(loop):
                if isinstance(n, ast.Raise):
                    yield self.finding(
                        mod, n,
                        "raise inside Engine.run's drive loop — "
                        "per-request failures must become GenResult "
                        "statuses",
                    )


# --------------------------------------------------------------------------
# RPR004 — host-sync discipline in the hot paths
# --------------------------------------------------------------------------


class _Taint:
    """Conservative per-function device/host classification of names.

    * device evidence: assigned from a jnp/jax/lax expression, or ever
      passed through ``jax.device_get`` (if it needed a fetch, it lived
      on device);
    * host evidence: assigned from ``jax.device_get``, ``np.*``,
      ``time.*``, ``len``/``int``/``float`` results, or constants.

    Host evidence wins (``toks_host = np.asarray(toks_host)`` patterns):
    a name is *device* only with device evidence and no host evidence —
    unknown names never produce findings.
    """

    DEVICE_ROOTS = ("jnp", "jax", "lax", "pl", "pltpu")
    HOST_CALLS = ("jax.device_get", "len", "int", "float", "bool", "str",
                  "repr", "round", "sorted", "list", "tuple", "range")
    HOST_ROOTS = ("np", "numpy", "time", "math", "os")

    def __init__(self, nodes: Iterable[ast.AST]):
        self.device: Set[str] = set()
        self.host: Set[str] = set()
        for node in nodes:
            if isinstance(node, ast.Call):
                if _dotted(node.func) == "jax.device_get":
                    for arg in node.args:
                        for n in ast.walk(arg):
                            if isinstance(n, ast.Name):
                                self.device.add(n.id)
            if isinstance(node, ast.Assign):
                names = self._target_names(node.targets)
                if not names:
                    continue
                if self._host_expr(node.value):
                    self.host.update(names)
                elif self.expr_on_device(node.value):
                    self.device.update(names)

    @staticmethod
    def _target_names(targets) -> List[str]:
        out = []
        for t in targets:
            if isinstance(t, ast.Name):
                out.append(t.id)
            elif isinstance(t, (ast.Tuple, ast.List)):
                out.extend(e.id for e in t.elts if isinstance(e, ast.Name))
        return out

    def _host_expr(self, e: ast.AST) -> bool:
        if isinstance(e, ast.Call):
            d = _dotted(e.func)
            if d in self.HOST_CALLS:
                return True
            if d is not None and d.split(".")[0] in self.HOST_ROOTS:
                return True
        if isinstance(e, ast.Tuple):
            return all(self._host_expr(x) for x in e.elts) and bool(e.elts)
        return isinstance(e, ast.Constant)

    def expr_on_device(self, e: ast.AST) -> bool:
        """True when the expression visibly involves device values."""
        for n in ast.walk(e):
            if isinstance(n, ast.Call):
                d = _dotted(n.func)
                if d == "jax.device_get":
                    continue
                if d is not None and d.split(".")[0] in self.DEVICE_ROOTS:
                    return True
            if isinstance(n, ast.Name) and n.id in self.device \
                    and n.id not in self.host:
                return True
        return False


class HostSyncRule(Rule):
    code = "RPR004"
    name = "host-sync-discipline"
    description = (
        "serving/, kernels/ and models/ promise ONE host sync per decode "
        "block/round (DESIGN.md §8).  Every blocking transfer — "
        "jax.device_get / .block_until_ready() / .item() — and every "
        "int()/float()/np.asarray() of a device value must carry a "
        "`# sync-point: <reason>` annotation on its line, so the "
        "intended once-per-block syncs are self-documenting and a stray "
        "per-token sync cannot land silently."
    )

    SCOPES = ("serving/", "kernels/", "models/")
    ANNOTATION = "sync-point"
    CAST_FUNCS = ("int", "float")
    CAST_METHODS = ("np.asarray", "numpy.asarray")

    def _annotated(self, mod, node: ast.AST) -> bool:
        """Annotation may sit on any line of the flagged call's span."""
        lo = getattr(node, "lineno", 1)
        hi = getattr(node, "end_lineno", lo) or lo
        for i in range(lo, hi + 1):
            if i <= len(mod.lines) and line_annotation(
                mod.lines[i - 1], self.ANNOTATION
            ):
                return True
        return False

    def _scopes(self, mod):
        """Own-node lists for every function plus the module top level."""
        fns = [n for n in ast.walk(mod.tree)
               if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda))]
        return [_walk_own(fn) for fn in fns] + [_walk_own(mod.tree)]

    def check_module(self, mod):
        if not mod.pkg_path.startswith(self.SCOPES):
            return
        reported: Set[int] = set()
        for nodes in self._scopes(mod):
            taint = _Taint(nodes)
            for node in nodes:
                if not isinstance(node, ast.Call) or id(node) in reported:
                    continue
                d = _dotted(node.func)
                if d in ("jax.device_get", "jax.block_until_ready") or (
                    isinstance(node.func, ast.Attribute)
                    and node.func.attr in ("item", "block_until_ready")
                ):
                    if not self._annotated(mod, node):
                        what = d or f".{node.func.attr}()"
                        reported.add(id(node))
                        yield self.finding(
                            mod, node,
                            f"blocking host sync `{what}` without a "
                            "`# sync-point: <reason>` annotation — hot "
                            "paths promise one explicit sync per "
                            "block/round",
                        )
                    continue
                is_cast = (
                    isinstance(node.func, ast.Name)
                    and node.func.id in self.CAST_FUNCS
                ) or d in self.CAST_METHODS
                if is_cast and node.args and \
                        taint.expr_on_device(node.args[0]):
                    if not self._annotated(mod, node):
                        reported.add(id(node))
                        name = d or node.func.id
                        yield self.finding(
                            mod, node,
                            f"`{name}(...)` of a device value forces a "
                            "per-call host sync — hoist it onto the "
                            "block's one device_get, or annotate with "
                            "`# sync-point: <reason>`",
                        )


# --------------------------------------------------------------------------
# RPR005 — traced functions are pure
# --------------------------------------------------------------------------


class JitPurityRule(Rule):
    code = "RPR005"
    name = "jit-purity"
    description = (
        "Functions traced by jax.jit / pallas_call / lax control flow "
        "bake call-time values into the compiled program: time.* and "
        "random/np.random calls inside them are silent correctness bugs "
        "(fixed at trace time, ignored at run time).  Use jax.random "
        "with threaded keys; keep wall-clock on the host."
    )

    TRACERS = ("jit", "pmap", "vmap", "pallas_call", "scan", "cond",
               "while_loop", "fori_loop", "shard_map", "checkpoint",
               "remat", "custom_vjp", "custom_jvp", "grad",
               "value_and_grad", "eval_shape")
    BANNED_ROOTS = ("random",)
    BANNED_PREFIXES = ("time.", "np.random.", "numpy.random.",
                       "random.")

    def _traced_functions(self, mod) -> List[ast.AST]:
        # name -> innermost def(s) with that name (module-order)
        defs: Dict[str, List[ast.AST]] = {}
        for n in ast.walk(mod.tree):
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)):
                defs.setdefault(n.name, []).append(n)
        traced: List[ast.AST] = []

        def _is_tracer(func: ast.AST) -> bool:
            d = _dotted(func)
            if d is None:
                return False
            return d.split(".")[-1] in self.TRACERS

        # decorated defs
        for ns in defs.values():
            for fn in ns:
                for dec in fn.decorator_list:
                    target = dec.func if isinstance(dec, ast.Call) else dec
                    if _is_tracer(target) or (
                        isinstance(dec, ast.Call) and any(
                            _is_tracer(a) for a in dec.args
                        )
                    ):
                        traced.append(fn)
        # functions passed to tracer calls (by name or inline lambda)
        for n in ast.walk(mod.tree):
            if isinstance(n, ast.Call) and _is_tracer(n.func):
                for arg in n.args:
                    if isinstance(arg, ast.Name):
                        traced.extend(defs.get(arg.id, ()))
                    elif isinstance(arg, ast.Lambda):
                        traced.append(arg)
        # anything defined inside a traced function is traced too
        out, seen = [], set()
        stack = list(traced)
        while stack:
            fn = stack.pop()
            if id(fn) in seen:
                continue
            seen.add(id(fn))
            out.append(fn)
            for n in ast.walk(fn):
                if n is not fn and isinstance(
                    n, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)
                ):
                    stack.append(n)
        return out

    def check_module(self, mod):
        reported: Set[int] = set()
        for fn in self._traced_functions(mod):
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call) or id(node) in reported:
                    continue
                d = _dotted(node.func)
                if d is None:
                    continue
                if any(d.startswith(p) for p in self.BANNED_PREFIXES) or \
                        d in self.BANNED_ROOTS:
                    reported.add(id(node))
                    fname = getattr(fn, "name", "<lambda>")
                    yield self.finding(
                        mod, node,
                        f"impure call `{d}(...)` inside traced function "
                        f"`{fname}` — its value is baked in at trace "
                        "time; thread a jax.random key / host timestamp "
                        "in as an argument instead",
                    )


# --------------------------------------------------------------------------
# RPR006 — fault-point catalog <-> firing sites cross-check
# --------------------------------------------------------------------------


class FaultPointRule(Rule):
    code = "RPR006"
    name = "fault-point-crosscheck"
    description = (
        "Every FaultPlan firing site must name a point in "
        "runtime/faults.py FAULT_POINTS, and every catalog entry must "
        "have a live firing site — otherwise `--inject` can silently "
        "target a dead point (the schedule parses, nothing ever fires)."
    )

    CATALOG_FILE = "runtime/faults.py"
    CATALOG_NAME = "FAULT_POINTS"
    FIRE_METHODS = ("hit", "raise_if", "_raise_fault")

    def _catalog(self, mod) -> Optional[Dict[str, int]]:
        """point name -> lineno, from the FAULT_POINTS dict literal."""
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Assign):
                targets = node.targets
            elif isinstance(node, ast.AnnAssign):  # FAULT_POINTS: Dict[...]
                targets = [node.target]
            else:
                continue
            if any(
                isinstance(t, ast.Name) and t.id == self.CATALOG_NAME
                for t in targets
            ) and isinstance(node.value, ast.Dict):
                out = {}
                for k in node.value.keys:
                    if isinstance(k, ast.Constant) and isinstance(
                        k.value, str
                    ):
                        out[k.value] = k.lineno
                return out
        return None

    def _firing_sites(self, mod) -> Iterable[Tuple[str, ast.Call]]:
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Call) and \
                    isinstance(node.func, ast.Attribute) and \
                    node.func.attr in self.FIRE_METHODS and node.args and \
                    isinstance(node.args[0], ast.Constant) and \
                    isinstance(node.args[0].value, str):
                yield node.args[0].value, node

    def check_tree(self, mods):
        catalog_mod = next(
            (m for m in mods if m.pkg_path == self.CATALOG_FILE), None
        )
        if catalog_mod is None:
            return  # linting a subtree without the catalog: nothing to do
        catalog = self._catalog(catalog_mod)
        if catalog is None:
            yield self.finding(
                catalog_mod, catalog_mod.tree,
                f"{self.CATALOG_NAME} dict literal not found in "
                f"{self.CATALOG_FILE} — the fault-point contract lost "
                "its catalog",
            )
            return
        fired: Set[str] = set()
        for mod in mods:
            if mod.pkg_path == self.CATALOG_FILE:
                continue
            for point, node in self._firing_sites(mod):
                fired.add(point)
                if point not in catalog:
                    yield self.finding(
                        mod, node,
                        f"firing site names unregistered fault point "
                        f"{point!r} — add it to FAULT_POINTS or fix the "
                        "typo (registered: "
                        f"{sorted(catalog)})",
                    )
        for point, lineno in sorted(catalog.items()):
            if point not in fired:
                anchor = ast.Module(body=[], type_ignores=[])
                anchor.lineno, anchor.col_offset = lineno, 0
                yield self.finding(
                    catalog_mod, anchor,
                    f"catalog entry {point!r} has no live firing site — "
                    "--inject would accept it and never fire (delete the "
                    "entry or wire plan.hit/raise_if at the owner)",
                )


# --------------------------------------------------------------------------
# RPR007 — obs naming schema
# --------------------------------------------------------------------------


class ObsNamingRule(Rule):
    code = "RPR007"
    name = "obs-naming"
    description = (
        "Metric names are `<subsystem>_<what>[_<unit>]` snake_case; "
        "counters end `_total`, histograms end in a unit "
        "(_seconds/_bytes/_tokens/_ratio), gauges carry neither.  "
        "Event/span names are dotted `<component>.<event>`.  Bench "
        "history rows (`bench_row`, repro.obs.perf) are slash-separated "
        "snake_case paths `<bench>/<row>[/<metric>]` — perfcheck and the "
        "report trend column key rows by these names.  Dashboards "
        "and the CI validator key on these shapes (DESIGN.md §13/§15)."
    )

    METRIC_RE = re.compile(r"^[a-z][a-z0-9]*(_[a-z0-9]+)+$")
    EVENT_RE = re.compile(r"^[a-z][a-z0-9_]*(\.[a-z][a-z0-9_]*)+$")
    BENCH_RE = re.compile(r"^[a-z][a-z0-9_]*(/[a-z0-9_]+)+$")
    HIST_UNITS = ("_seconds", "_bytes", "_tokens", "_ratio")
    METRIC_METHODS = ("counter", "gauge", "histogram")
    EVENT_METHODS = ("event", "span", "timer")
    BENCH_METHODS = ("bench_row",)

    def _bad_metric(self, family: str, name: str) -> Optional[str]:
        if not self.METRIC_RE.match(name):
            return (f"{family} name {name!r} is not "
                    "`<subsystem>_<what>` snake_case")
        if family == "counter" and not name.endswith("_total"):
            return f"counter name {name!r} must end `_total`"
        if family != "counter" and name.endswith("_total"):
            return (f"{family} name {name!r} ends `_total` — that suffix "
                    "is reserved for counters")
        if family == "histogram" and not name.endswith(self.HIST_UNITS):
            return (f"histogram name {name!r} must end in a unit "
                    f"({'/'.join(self.HIST_UNITS)})")
        return None

    def check_module(self, mod):
        for node in ast.walk(mod.tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.args
                    and isinstance(node.args[0], ast.Constant)
                    and isinstance(node.args[0].value, str)):
                continue
            meth, name = node.func.attr, node.args[0].value
            if meth in self.METRIC_METHODS:
                msg = self._bad_metric(meth, name)
                if msg:
                    yield self.finding(mod, node, msg)
            elif meth in self.EVENT_METHODS:
                if not self.EVENT_RE.match(name):
                    yield self.finding(
                        mod, node,
                        f"{meth} name {name!r} is not dotted "
                        "`<component>.<event>` lowercase",
                    )
            elif meth in self.BENCH_METHODS:
                if not self.BENCH_RE.match(name):
                    yield self.finding(
                        mod, node,
                        f"{meth} name {name!r} is not a slash-separated "
                        "`<bench>/<row>[/<metric>]` snake_case path",
                    )


ALL_RULES: List[Rule] = [
    BarePrintRule(),
    DispatchLadderRule(),
    EngineRunNoRaiseRule(),
    HostSyncRule(),
    JitPurityRule(),
    FaultPointRule(),
    ObsNamingRule(),
]

RULES_BY_CODE: Dict[str, Rule] = {r.code: r for r in ALL_RULES}
