"""Findings schema, per-line suppressions, and the baseline file.

One ``Finding`` per rule violation.  Three mechanisms keep the linter
adoptable without weakening it:

* **Suppressions** — ``# noqa: RPR004`` (comma-separated codes) on the
  *flagged line* silences exactly those codes at exactly that site.  A
  bare ``# noqa`` (no codes) is deliberately NOT honored: every
  suppression names what it suppresses.
* **Annotations** — some rules accept a semantic annotation instead of a
  suppression (RPR004's ``# sync-point: <reason>``): the annotation both
  silences the finding and documents the invariant at the site.  Rules
  own their annotation grammar; this module only provides the line-level
  comment scanner.
* **Baseline** — a JSON file of finding fingerprints.  ``--baseline``
  findings are reported as ``baselined`` and do not fail the run; new
  findings do.  Fingerprints hash (rule, path, line *content*, the
  occurrence index of that content in the file) — renumbering lines by
  editing elsewhere in the file does not invalidate the baseline, while
  a new copy of the same bad pattern does fail.

The JSON export schema is ``repro.checks.findings/v1``:

    {"schema": "repro.checks.findings/v1",
     "findings": [{"rule": "RPR004", "path": "serving/engine.py",
                   "line": 478, "col": 36, "message": "...",
                   "snippet": "...", "baselined": false}, ...],
     "counts": {"RPR004": 1, ...}}
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import re
from typing import Dict, Iterable, List, Optional

SCHEMA = "repro.checks.findings/v1"
BASELINE_SCHEMA = "repro.checks.baseline/v1"

_NOQA_RE = re.compile(r"#\s*noqa:\s*([A-Z]{3}\d{3}(?:\s*,\s*[A-Z]{3}\d{3})*)")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at one source location.

    ``path`` is the path as reported (relative to the scan root);
    ``line``/``col`` are 1-based/0-based per the ast convention.
    ``baselined`` is stamped by the runner, never by rules.
    """

    rule: str
    path: str
    line: int
    col: int
    message: str
    snippet: str = ""
    baselined: bool = False

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def render(self) -> str:
        tag = " (baselined)" if self.baselined else ""
        return f"{self.path}:{self.line}:{self.col}: " \
               f"{self.rule} {self.message}{tag}"


def suppressed_codes(line_text: str) -> List[str]:
    """Codes named by a ``# noqa: RPR0xx[, ...]`` comment on this line."""
    m = _NOQA_RE.search(line_text)
    if not m:
        return []
    return [c.strip() for c in m.group(1).split(",")]


def line_annotation(line_text: str, key: str) -> Optional[str]:
    """Value of a ``# <key>: <reason>`` comment on this line (stripped),
    or None.  An empty reason returns None — annotations must say why."""
    m = re.search(rf"#\s*{re.escape(key)}:\s*(\S.*)", line_text)
    if not m:
        return None
    reason = m.group(1).strip()
    return reason or None


def fingerprint(finding: Finding, file_lines: List[str]) -> str:
    """Stable identity for baselining: rule + path + the flagged line's
    stripped content + which occurrence of that content this is."""
    idx = finding.line - 1
    content = file_lines[idx].strip() if 0 <= idx < len(file_lines) else ""
    occurrence = sum(
        1 for i in range(min(idx, len(file_lines)))
        if file_lines[i].strip() == content
    )
    h = hashlib.sha256(
        f"{finding.rule}\x00{finding.path}\x00{content}\x00{occurrence}"
        .encode()
    )
    return h.hexdigest()[:16]


class Baseline:
    """Set of accepted finding fingerprints, persisted as JSON."""

    def __init__(self, fingerprints: Iterable[str] = ()):
        self.fingerprints = set(fingerprints)

    @classmethod
    def load(cls, path: str) -> "Baseline":
        with open(path) as f:
            data = json.load(f)
        if data.get("schema") != BASELINE_SCHEMA:
            raise ValueError(
                f"{path}: unknown baseline schema {data.get('schema')!r}"
            )
        return cls(data.get("fingerprints", []))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(
                {"schema": BASELINE_SCHEMA,
                 "fingerprints": sorted(self.fingerprints)},
                f, indent=2,
            )
            f.write("\n")

    def __contains__(self, fp: str) -> bool:
        return fp in self.fingerprints


def to_json(findings: List[Finding]) -> dict:
    counts: Dict[str, int] = {}
    for f in findings:
        if not f.baselined:
            counts[f.rule] = counts.get(f.rule, 0) + 1
    return {
        "schema": SCHEMA,
        "findings": [f.to_dict() for f in findings],
        "counts": dict(sorted(counts.items())),
    }
