"""Collect modules, run rules, apply suppressions and the baseline.

The unit of work is a ``Module``: one parsed Python file plus the two
paths the rules need — ``report_path`` (relative to the scan root, for
humans and baselines) and ``pkg_path`` (relative to the ``repro``
package root, for scoping).  On the real tree they differ
(``src/repro/serving/engine.py`` vs ``serving/engine.py``); on a test
fixture tree whose root *is* the package root they coincide, which is
what lets every rule be exercised against tiny synthetic trees.
"""

from __future__ import annotations

import ast
import dataclasses
import os
from typing import Iterable, List, Optional, Sequence

from .findings import Baseline, Finding, fingerprint, suppressed_codes
from .rules import ALL_RULES, RULES_BY_CODE, Rule


@dataclasses.dataclass
class Module:
    path: str          # absolute
    report_path: str   # relative to the scan root (or as given)
    pkg_path: str      # relative to the repro package root
    text: str
    lines: List[str]
    tree: ast.AST


def _pkg_path(report_path: str) -> str:
    """Path relative to the ``repro`` package root.

    If a ``repro`` component appears in the path, everything after its
    last occurrence; otherwise the report path itself (fixture trees are
    their own package root).
    """
    parts = report_path.replace(os.sep, "/").split("/")
    if "repro" in parts:
        idx = len(parts) - 1 - parts[::-1].index("repro")
        tail = parts[idx + 1:]
        if tail:
            return "/".join(tail)
    return "/".join(parts)


def collect_modules(paths: Sequence[str]) -> List[Module]:
    """Parse every ``.py`` under ``paths`` (files or directories).

    A file that does not parse yields a Module with ``tree=None``; the
    runner turns that into an RPR000 finding rather than crashing.
    """
    files: List[tuple] = []  # (abspath, report_path)
    for root in paths:
        root = os.path.normpath(root)
        if os.path.isfile(root):
            files.append((os.path.abspath(root), root))
            continue
        for dirpath, dirnames, filenames in os.walk(root):
            dirnames[:] = sorted(
                d for d in dirnames
                if d not in ("__pycache__", ".git")
            )
            for fn in sorted(filenames):
                if not fn.endswith(".py"):
                    continue
                full = os.path.join(dirpath, fn)
                rel = os.path.relpath(full, root)
                files.append((os.path.abspath(full), rel))
    mods: List[Module] = []
    for full, rel in files:
        with open(full, encoding="utf-8") as f:
            text = f.read()
        try:
            tree = ast.parse(text, filename=rel)
        except SyntaxError:
            tree = None
        mods.append(Module(
            path=full,
            report_path=rel.replace(os.sep, "/"),
            pkg_path=_pkg_path(rel),
            text=text,
            lines=text.splitlines(),
            tree=tree,
        ))
    return mods


def select_rules(codes: Optional[Iterable[str]] = None) -> List[Rule]:
    if codes is None:
        return list(ALL_RULES)
    out = []
    for code in codes:
        if code not in RULES_BY_CODE:
            raise KeyError(
                f"unknown rule {code!r}; known: {sorted(RULES_BY_CODE)}"
            )
        out.append(RULES_BY_CODE[code])
    return out


def run_checks(
    paths: Sequence[str],
    *,
    rules: Optional[Iterable[str]] = None,
    baseline: Optional[Baseline] = None,
) -> List[Finding]:
    """Run the rule set; return ALL findings (suppressed ones removed,
    baselined ones kept but stamped ``baselined=True``).

    The exit-status question — "any NEW findings?" — is then just
    ``any(not f.baselined for f in findings)``.
    """
    mods = collect_modules(paths)
    active = select_rules(rules)
    raw: List[Finding] = []
    for mod in mods:
        if mod.tree is None:
            err = "file does not parse — rules skipped"
            raw.append(Finding(rule="RPR000", path=mod.report_path,
                               line=1, col=0, message=err))
            continue
        for rule in active:
            raw.extend(rule.check_module(mod))
    parsed = [m for m in mods if m.tree is not None]
    for rule in active:
        raw.extend(rule.check_tree(parsed))

    lines_by_path = {m.report_path: m.lines for m in mods}
    out: List[Finding] = []
    for f in sorted(raw, key=lambda f: (f.path, f.line, f.col, f.rule)):
        file_lines = lines_by_path.get(f.path, [])
        if 1 <= f.line <= len(file_lines) and \
                f.rule in suppressed_codes(file_lines[f.line - 1]):
            continue
        if baseline is not None and fingerprint(f, file_lines) in baseline:
            f = dataclasses.replace(f, baselined=True)
        out.append(f)
    return out


def make_baseline(paths: Sequence[str],
                  rules: Optional[Iterable[str]] = None) -> Baseline:
    """Fingerprint every current (unsuppressed) finding."""
    mods = collect_modules(paths)
    lines_by_path = {m.report_path: m.lines for m in mods}
    findings = run_checks(paths, rules=rules)
    return Baseline(
        fingerprint(f, lines_by_path.get(f.path, [])) for f in findings
    )
