"""repro — Higher-order Linear Attention, production-scale jax/pallas.

Importing the package configures jax for sharding-invariant numerics:

* ``jax_threefry_partitionable=True`` — without it, ``jax.random.*`` values
  drawn under ``jit`` depend on the *output sharding* XLA assigns (the
  legacy threefry lowering materializes per-shard counters), so the same
  init key produced different parameters on a (2, 4) mesh than on a single
  device — the root cause of the pjit-vs-single-device training divergence
  (tests/test_distributed.py).  The partitionable form makes every draw a
  pure function of (key, position), identical under any mesh.
"""

try:
    import jax as _jax
except ModuleNotFoundError:
    # stdlib-only tools (obs.perfcheck, obs.validate) run on bare CI
    # python with no jax; everything else fails at its own jax import
    pass
else:
    _jax.config.update("jax_threefry_partitionable", True)
