"""Analytic per-op cost model for every registered ``SequenceOp``.

One question, answered without running anything: *how many FLOPs and how
many HBM bytes does operator X move per token* on each of its execution
paths — ``train_fwd`` / ``train_bwd`` (full-sequence chunkwise),
``prefill`` (same chunk math, one call), and ``decode_step`` (the O(1)
state recurrence)?  ``benchmarks/run.py`` divides measured tok/s by these
numbers to get achieved FLOP/s, and ``repro.obs.perf`` turns that into
roofline utilization — the figure of merit the fused-kernel and
distributed ROADMAP items are driven by.

Derivation (DESIGN.md §15):

* **Projections** come from the record's own ``specs(cfg)``: every dense
  weight performs one multiply-accumulate per token, so the projection
  term is ``2 * param_count(specs)`` FLOPs/token — exact for the
  matmul-dominated sublayers, and automatically correct for any new
  operator the registry gains.
* **State math** is per family, from the paper's §5 complexity analysis
  and the chunkwise formulation in DESIGN.md §2: linear attention carries
  an O(d·dv) state (2 matvecs/token), HLA2 adds the O(d²) second-moment
  update plus the intra-chunk masked ``(c×c)·(c×c)`` product, AHLA is two
  first-order passes, HLA3 composes LinAttn∘HLA2, and the paper-faithful
  HLA3 additionally carries the ⊗3 cross terms.  Chunk width enters as
  ``c = min(cfg chunk, seq_len)``.
* **State bytes** are *measured abstractly*: ``jax.eval_shape`` over the
  record's ``init_state`` — exact, allocation-free, and the paper's
  O(1)-in-n constant-state claim is a testable property of the result
  (tests/test_costs.py).
* A record may override the state-math term via the optional
  ``SequenceOp.cost_model`` hook (see ``models/gla.py``); projections and
  state bytes always come from the registry record itself.

Cross-check: ``xla_cost`` compiles a callable and reports both the raw
``compiled.cost_analysis()`` numbers and the loop-aware account from
``repro.analysis.hlo_analysis`` (which multiplies while-bodies by their
trip counts — the raw numbers undercount scan-over-chunk paths).
tests/test_costs.py holds every registered op's analytic FLOPs within a
factor-of-2 band of the measured dot FLOPs on small shapes.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

MODES = ("train_fwd", "train_bwd", "train_step", "prefill", "decode_step")

#: forward-activation HBM round-trips per token, in units of
#: d_model * 4 bytes (residual in/out, q/k/v/o tiles, norm scratch).
_ACT_ROUNDTRIPS = 12.0


@dataclasses.dataclass(frozen=True)
class OpCost:
    """Per-token cost of one SequenceOp path (one batch-element token)."""

    op: str
    mode: str
    flops_per_token: float
    bytes_per_token: float
    state_bytes: int  # decode-state bytes per sequence
    breakdown: Dict[str, float]

    def as_dict(self) -> dict:
        return {
            "op": self.op, "mode": self.mode,
            "flops_per_token": self.flops_per_token,
            "bytes_per_token": self.bytes_per_token,
            "state_bytes": self.state_bytes,
            "breakdown": dict(self.breakdown),
        }


def _dims(cfg):
    """(heads, key dim, value dim) for the projection-style families."""
    return cfg.n_heads, cfg.head_dim, cfg.head_dim


def _chunk(cfg, seq_len: int) -> int:
    return max(1, min(int(cfg.hla.chunk), int(seq_len)))


# --------------------------------------------------------------------------
# family state-math tables: FLOPs/token beyond the projections
# --------------------------------------------------------------------------


def _fwd_linattn(cfg, c, n):
    """One chunkwise first-order pass: intra-chunk masked matmul
    (scores + apply) + per-chunk carry update and state readout."""
    H, d, dv = _dims(cfg)
    return H * (2 * c * (d + dv) + 4 * d * dv)


def _fwd_hla2(cfg, c, n):
    """DESIGN.md §2 masked-matmul form: QK^T/KQ^T scores, the (c×c)·(c×c)
    second-order product, S/C/G carries and the S·C cross term."""
    H, d, dv = _dims(cfg)
    intra = 8 * c * d + 2 * c * c + 6 * c * dv
    carry = 4 * d * d + 6 * d * dv
    cross = 4.0 * d * d * dv / c  # S@C-type products, once per chunk
    return H * (intra + carry + cross)


def _fwd_ahla(cfg, c, n):
    return 2.0 * _fwd_linattn(cfg, c, n)


def _fwd_hla3(cfg, c, n):
    # exact factorization HLA2_masked(Q, K, LinAttn(Q, K, V))
    return _fwd_linattn(cfg, c, n) + _fwd_hla2(cfg, c, n)


def _fwd_hla3_paper(cfg, c, n):
    # Alg 4 chunkwise: HLA2-shaped masked matmuls + the ⊗3 cross terms
    # applied to the (S^K, S^Q, P) carry (never materialized).
    H, d, dv = _dims(cfg)
    return 1.5 * _fwd_hla2(cfg, c, n) + H * (4.0 * d * d * dv / c)


def _fwd_gla(cfg, c, n):
    # fixed GLA_CHUNK intra window; gate LoRA lives in specs already
    H, d, dv = _dims(cfg)
    c = min(32, n)
    return H * (2 * c * (d + dv) + 6 * d * dv)


def _fwd_attn(cfg, c, n):
    # softmax attention: scores + apply over the causal context (~n/2
    # average, counted full-n as the kernels compute the padded tile)
    H, d, dv = _dims(cfg)
    return H * (2 * n * d + 2 * n * dv)


def _fwd_rwkv6(cfg, c, n):
    d = cfg.d_model
    dh = cfg.rwkv_head_dim
    c = min(32, n)  # RWKV_CHUNK
    return (d // dh) * (2 * c * (dh + dh) + 8 * dh * dh)


def _fwd_mamba(cfg, c, n):
    mc = cfg.mamba
    d_in = mc.expand * cfg.d_model
    return 6.0 * d_in * mc.d_state + 2.0 * mc.d_conv * d_in


def _dec_linattn(cfg, L):
    H, d, dv = _dims(cfg)
    return H * (4 * d * dv + 2 * d)


def _dec_hla2(cfg, L):
    H, d, dv = _dims(cfg)
    return H * (4 * d * d + 10 * d * dv)


def _dec_ahla(cfg, L):
    H, d, dv = _dims(cfg)
    return H * (10 * d * dv + 4 * d)


def _dec_hla3(cfg, L):
    return _dec_linattn(cfg, L) + _dec_hla2(cfg, L)


def _dec_hla3_paper(cfg, L):
    return 1.5 * _dec_hla2(cfg, L)


def _dec_gla(cfg, L):
    H, d, dv = _dims(cfg)
    return H * 5 * d * dv


def _dec_attn(cfg, L):
    # reads the whole KV cache: O(L) per step — the paper's contrast case
    H, d, dv = _dims(cfg)
    return H * (2 * L * d + 2 * L * dv)


def _dec_rwkv6(cfg, L):
    d = cfg.d_model
    dh = cfg.rwkv_head_dim
    return (d // dh) * 8 * dh * dh


def _dec_mamba(cfg, L):
    return _fwd_mamba(cfg, 1, 1)


_FWD_STATE_FLOPS: Dict[str, Callable] = {
    "linattn": _fwd_linattn, "hla2": _fwd_hla2, "ahla": _fwd_ahla,
    "hla3": _fwd_hla3, "hla3_paper": _fwd_hla3_paper, "gla": _fwd_gla,
    "attn": _fwd_attn, "rwkv6": _fwd_rwkv6, "mamba": _fwd_mamba,
}

_DEC_STATE_FLOPS: Dict[str, Callable] = {
    "linattn": _dec_linattn, "hla2": _dec_hla2, "ahla": _dec_ahla,
    "hla3": _dec_hla3, "hla3_paper": _dec_hla3_paper, "gla": _dec_gla,
    "attn": _dec_attn, "rwkv6": _dec_rwkv6, "mamba": _dec_mamba,
}


# --------------------------------------------------------------------------
# registry-record plumbing
# --------------------------------------------------------------------------


def record_param_stats(op, cfg):
    """(param_count, param_bytes) of the record's own specs."""
    from ..models.param import param_bytes, param_count

    specs = op.specs(cfg)
    return param_count(specs), param_bytes(specs)


def record_state_bytes(op, cfg, *, max_len: int = 64) -> int:
    """Decode-state bytes per sequence, measured abstractly (no alloc)."""
    import functools

    import jax
    import numpy as np

    abstract = jax.eval_shape(
        functools.partial(op.init_state, cfg, 1, max_len=max_len)
    )
    return int(sum(
        int(np.prod(leaf.shape)) * np.dtype(leaf.dtype).itemsize
        for leaf in jax.tree.leaves(abstract)
    ))


def _generic_state_flops(op, cfg, mode, n):
    """Fallback for ops without a family entry or a cost_model hook:
    read+update+readout of every state element, once per token."""
    elems = record_state_bytes(op, cfg, max_len=n) / 4.0
    return 6.0 * elems


def record_cost(op, cfg, *, mode: str = "train_fwd",
                seq_len: Optional[int] = None, batch: int = 1) -> OpCost:
    """Cost of one path of a ``SequenceOp`` record (see module docstring).

    ``seq_len`` is the per-call sequence length for train/prefill (chunk
    width saturates at it) and the *context length* for ``decode_step``
    (only attention's growing KV cache depends on it).
    """
    if mode not in MODES:
        raise ValueError(f"mode {mode!r} not in {MODES}")
    n = int(seq_len if seq_len is not None else 512)
    c = _chunk(cfg, n)
    n_params, p_bytes = record_param_stats(op, cfg)
    decode = mode == "decode_step"
    sbytes = record_state_bytes(op, cfg, max_len=max(n, 1))

    proj = 2.0 * n_params
    hook = op.cost_model(cfg, mode=mode, seq_len=n, batch=batch) \
        if op.cost_model is not None else {}
    if "state_flops_per_token" in hook:
        state_flops = float(hook["state_flops_per_token"])
    elif decode:
        fam = _DEC_STATE_FLOPS.get(op.name)
        state_flops = fam(cfg, n) if fam else _generic_state_flops(
            op, cfg, mode, n
        )
    else:
        fam = _FWD_STATE_FLOPS.get(op.name)
        state_flops = fam(cfg, c, n) if fam else _generic_state_flops(
            op, cfg, mode, n
        )
    flops = proj + state_flops

    # bytes/token: weights amortize over the call's tokens; activations
    # round-trip a few d_model rows; the state carry streams once per
    # chunk (train/prefill) or once per token (decode).
    tokens_per_call = max(1, batch * (1 if decode else n))
    weight_traffic = p_bytes / tokens_per_call
    act_traffic = _ACT_ROUNDTRIPS * cfg.d_model * 4.0
    if "state_bytes_per_token" in hook:
        state_traffic = float(hook["state_bytes_per_token"])
    else:
        state_traffic = 2.0 * sbytes * (1.0 if decode else 1.0 / c)
    bytes_pt = weight_traffic + act_traffic + state_traffic

    scale = {"train_fwd": 1.0, "prefill": 1.0, "decode_step": 1.0,
             "train_bwd": 2.0, "train_step": 3.0}[mode]
    return OpCost(
        op=op.name, mode=mode,
        flops_per_token=scale * flops,
        bytes_per_token=scale * bytes_pt,
        state_bytes=sbytes,
        breakdown={
            "proj_flops": scale * proj,
            "state_flops": scale * state_flops,
            "weight_bytes": scale * weight_traffic,
            "act_bytes": scale * act_traffic,
            "state_traffic_bytes": scale * state_traffic,
            "chunk": c,
        },
    )


def op_cost(name: str, cfg, *, mode: str = "train_fwd",
            seq_len: Optional[int] = None, batch: int = 1) -> OpCost:
    """Cost of registered operator ``name`` under ``cfg`` (main entry)."""
    from ..models import seq_op

    return record_cost(seq_op.get_op(name), cfg, mode=mode,
                       seq_len=seq_len, batch=batch)


def model_cost(cfg, *, mode: str = "train_fwd",
               seq_len: Optional[int] = None, batch: int = 1) -> OpCost:
    """Whole-LM cost per token around ``cfg``'s operator.

    Benches measure the FULL model's tok/s (embeddings, every layer's
    mixer + FFN, the unembed head), so utilization must divide by the
    full model's FLOPs: ``2 * total-param`` projection FLOPs per token
    (every dense weight is one MAC/token) plus ``n_layers x`` the op's
    state math.  Used by ``benchmarks/run.py bench_ops`` for the
    §Utilization table.
    """
    from ..models import lm, seq_op
    from ..models.param import param_bytes, param_count

    op = seq_op.op_for(cfg)
    opc = record_cost(op, cfg, mode=mode, seq_len=seq_len, batch=batch)
    specs = lm.lm_specs(cfg)
    n = int(seq_len if seq_len is not None else 512)
    decode = mode == "decode_step"
    scale = {"train_fwd": 1.0, "prefill": 1.0, "decode_step": 1.0,
             "train_bwd": 2.0, "train_step": 3.0}[mode]
    n_params, p_bytes = param_count(specs), param_bytes(specs)
    # breakdown terms of `opc` are already mode-scaled
    state_flops = opc.breakdown["state_flops"] * cfg.n_layers
    state_traffic = opc.breakdown["state_traffic_bytes"] * cfg.n_layers
    flops = scale * 2.0 * n_params + state_flops
    tokens_per_call = max(1, batch * (1 if decode else n))
    act = scale * cfg.n_layers * _ACT_ROUNDTRIPS * cfg.d_model * 4.0
    bytes_pt = scale * p_bytes / tokens_per_call + act + state_traffic
    return OpCost(
        op=f"lm/{op.name}", mode=mode,
        flops_per_token=flops, bytes_per_token=bytes_pt,
        state_bytes=opc.state_bytes * cfg.n_layers,
        breakdown={
            "proj_flops": scale * 2.0 * n_params,
            "state_flops": state_flops,
            "weight_bytes": scale * p_bytes / tokens_per_call,
            "act_bytes": act,
            "state_traffic_bytes": state_traffic,
            "chunk": opc.breakdown["chunk"],
        },
    )


# --------------------------------------------------------------------------
# XLA cross-check
# --------------------------------------------------------------------------


def xla_cost(fn, *args, loop_aware: bool = True) -> dict:
    """Compile ``fn(*args)`` and report its FLOPs/bytes two ways.

    ``raw_*`` is ``compiled.cost_analysis()`` (counts while-loop bodies
    ONCE — undercounts scan-over-chunk paths); ``flops``/``bytes`` are
    the loop-aware account from ``repro.analysis.hlo_analysis`` when
    ``loop_aware`` (dot/convolution FLOPs only, multiplied by trip
    counts), else the raw numbers.
    """
    import jax

    compiled = jax.jit(fn).lower(*args).compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):  # older jax: per-device list
        ca = ca[0] if ca else {}
    raw_flops = float(ca.get("flops", 0.0) or 0.0)
    raw_bytes = float(ca.get("bytes accessed", 0.0) or 0.0)
    out = {"raw_flops": raw_flops, "raw_bytes": raw_bytes,
           "flops": raw_flops, "bytes": raw_bytes}
    if loop_aware:
        from ..analysis.hlo_analysis import analyze

        acc = analyze(compiled.as_text())
        out["flops"] = acc["flops"]
        out["bytes"] = acc["bytes"]
    return out


def measured_op_flops(name: str, cfg, *, seq_len: int = 64,
                      batch: int = 1) -> dict:
    """Compile the registered op's full-sequence forward on a small shape
    and return its XLA cost (the tests' factor-of-2 reference)."""
    import jax
    import jax.numpy as jnp

    from ..models import seq_op
    from ..models.param import init_params

    op = seq_op.get_op(name)
    params = init_params(op.specs(cfg), jax.random.key(0))
    x = jax.random.normal(
        jax.random.key(1), (batch, seq_len, cfg.d_model), jnp.float32
    )
    positions = jnp.arange(seq_len, dtype=jnp.int32)[None, :]
    positions = jnp.broadcast_to(positions, (batch, seq_len))

    def fwd(p, x, positions):
        y, _ = op.forward(p, x, cfg, state=None, want_state=False,
                          positions=positions)
        return y

    cost = xla_cost(fwd, params, x, positions)
    cost["per_token"] = cost["flops"] / max(1, batch * seq_len)
    return cost
