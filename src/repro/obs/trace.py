"""Low-overhead span tracing into a bounded ring buffer.

A ``Span`` is a named wall-clock interval with sparse labels; an
``Event`` is an instantaneous point (request lifecycle transitions,
fired fault injections).  Both become one plain-dict record in a
bounded ring buffer (``collections.deque(maxlen=...)`` — old records
fall off, memory never grows) and are optionally written through to
attached sinks as they complete.

Overhead rules (DESIGN.md §13):

* **No device syncs.**  Timestamps are ``time.perf_counter()`` only.
  Spans around jitted calls therefore measure *dispatch + whatever sync
  the caller already performs inside the span* — the engine opens its
  block span before dispatch and closes it after the block's one
  existing ``device_get``, so the span is accurate without adding a
  single transfer.  Nothing here imports jax eagerly.
* **Cheap when idle.**  A span enter/exit is two ``perf_counter`` calls,
  one dict build, one deque append — no locks on the hot path (deque
  appends are atomic under the GIL; sinks that need synchronization do
  it internally).
* **Optional accelerator forwarding.**  ``annotate=True`` (or
  ``"auto"``, which enables it only on a TPU backend) additionally wraps
  each span in ``jax.profiler.TraceAnnotation`` so engine/train spans
  show up on the device timeline in xprof traces.  Import failures
  degrade silently to host-only tracing.

Record schema (``repro.obs.events/v1`` — shared with the JSONL sink and
the CI validator)::

    {"kind": "span",  "name": "engine.decode_block", "ts": <t0>,
     "dur_s": <wall>, "seq": <n>, "depth": <nesting>, ...labels}
    {"kind": "event", "name": "request.done", "ts": <t>, "seq": <n>,
     ...labels}

``ts`` is ``perf_counter``-relative (monotonic within a process, not an
epoch) — events are for *ordering and duration*, wall-clock anchoring is
the sink's job (``JsonlSink`` stamps an epoch offset in its header).
"""

from __future__ import annotations

import collections
import contextlib
import itertools
import threading
import time
from typing import Dict, List, Optional


def _trace_annotation(enabled) -> Optional[type]:
    """Resolve jax.profiler.TraceAnnotation lazily; None = disabled."""
    if not enabled:
        return None
    try:
        import jax
        from jax.profiler import TraceAnnotation
    except Exception:
        return None
    if enabled == "auto" and jax.default_backend() != "tpu":
        return None
    return TraceAnnotation


class Tracer:
    """Bounded ring buffer of span/event records + write-through sinks."""

    def __init__(self, ring: int = 4096, sinks=(), annotate="auto"):
        if ring < 1:
            raise ValueError(f"ring must be >= 1, got {ring}")
        self.ring_size = ring
        self._ring: collections.deque = collections.deque(maxlen=ring)
        self._sinks: List = list(sinks)
        self._seq = itertools.count()  # next() is atomic: thread-safe seq
        self._annotation = _trace_annotation(annotate)
        # per-thread span stack: nesting depth without cross-thread races
        self._local = threading.local()

    # -- recording ----------------------------------------------------------

    def _record(self, rec: dict) -> None:
        rec["seq"] = next(self._seq)
        self._ring.append(rec)
        for sink in self._sinks:
            sink.emit(rec)

    def event(self, name: str, **labels) -> None:
        """Record an instantaneous point event."""
        rec = {"kind": "event", "name": name, "ts": time.perf_counter()}
        rec.update(labels)
        self._record(rec)

    @contextlib.contextmanager
    def span(self, name: str, **labels):
        """Record a wall-clock interval; nests (``depth`` = enclosing
        spans on this thread).  Exceptions propagate — the span is still
        recorded, flagged ``error=True``."""
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        ann = self._annotation(name) if self._annotation else None
        stack.append(name)
        t0 = time.perf_counter()
        if ann is not None:
            ann.__enter__()
        try:
            yield
        except BaseException:
            self._close_span(name, t0, labels, len(stack) - 1, error=True)
            raise
        else:
            self._close_span(name, t0, labels, len(stack) - 1)
        finally:
            if ann is not None:
                ann.__exit__(None, None, None)
            stack.pop()

    def _close_span(self, name, t0, labels, depth, error=False):
        rec = {"kind": "span", "name": name, "ts": t0,
               "dur_s": time.perf_counter() - t0, "depth": depth}
        if error:
            rec["error"] = True
        rec.update(labels)
        self._record(rec)

    # -- consumption --------------------------------------------------------

    def events(self, name: Optional[str] = None,
               kind: Optional[str] = None) -> List[dict]:
        """Current ring contents (oldest first), optionally filtered."""
        out = list(self._ring)
        if name is not None:
            out = [e for e in out if e["name"] == name]
        if kind is not None:
            out = [e for e in out if e["kind"] == kind]
        return out

    def attach(self, sink) -> None:
        """Write-through every future record to ``sink`` (e.g. attach the
        JSONL sink after a warmup run so the log starts at the measured
        traffic)."""
        self._sinks.append(sink)

    def detach(self, sink) -> None:
        self._sinks.remove(sink)

    def clear(self) -> None:
        """Drop ring contents (fresh epoch); sinks keep what they wrote."""
        self._ring.clear()

    def flush(self) -> None:
        for sink in self._sinks:
            if hasattr(sink, "flush"):
                sink.flush()


class SpanTimer:
    """Manual open/close span for intervals that cross function
    boundaries (e.g. admission -> first token).  Prefer ``Tracer.span``
    when a ``with`` block fits."""

    def __init__(self, tracer: Tracer, name: str, **labels):
        self.tracer = tracer
        self.name = name
        self.labels = labels
        self.t0 = time.perf_counter()

    def close(self, **extra) -> float:
        dur = time.perf_counter() - self.t0
        rec = {"kind": "span", "name": self.name, "ts": self.t0,
               "dur_s": dur, "depth": 0}
        rec.update(self.labels)
        rec.update(extra)
        self.tracer._record(rec)
        return dur


_NESTING_DOC: Dict[str, str] = {
    # the span/event catalog each subsystem emits — kept here so the
    # timeline module and the docs have one source of truth
    "request.queued": "request entered run()'s pending queue",
    "request.admitted": "slot assigned, prefill done, first token sampled",
    "request.first_token": "TTFT endpoint (dur rides request.admitted)",
    "request.done": "terminal: status in ok|error|timeout|cancelled",
    "engine.prefill": "chunk-parallel admission prefill (span)",
    "engine.decode_block": "one step-locked decode block (span)",
    "engine.spec_round": "one draft->verify->accept round (span)",
    "train.step": "one optimizer step (span)",
    "train.resumed": "checkpoint auto-resume on loop entry",
    "ckpt.save": "one checkpoint save (span, async thread)",
    "ckpt.restore": "one checkpoint restore (span)",
    "fault.fired": "a runtime.faults injection point fired",
    "profile.start": "jax.profiler trace capture opened (perf.py; "
                     "wall_ns correlates the XLA timeline)",
    "profile.stop": "jax.profiler trace capture closed",
    "bench.run": "one bench function in benchmarks/run.py (span)",
}
