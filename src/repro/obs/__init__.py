"""Unified observability: metrics registry, span tracer, sinks, timelines.

One substrate for every number the stack reports (DESIGN.md §13):

* ``registry``  — counters / gauges / fixed-bucket histograms with
  labeled series, bounded memory, one snapshot schema
  (``repro.obs.metrics/v1``);
* ``trace``     — wall-clock spans + point events into a bounded ring
  buffer (``repro.obs.events/v1``), optional
  ``jax.profiler.TraceAnnotation`` forwarding on TPU, never a device
  sync;
* ``sinks``     — JSONL event log, Prometheus text exposition, console
  summaries;
* ``timeline``  — per-request lifecycle reconstruction + completeness
  checks;
* ``validate``  — CLI schema validator for CI
  (``python -m repro.obs.validate``);
* ``perf``      — roofline utilization, ``jax.profiler`` capture,
  append-only bench history (``repro.obs.bench/v1``);
* ``perfcheck`` — noise-aware bench regression gate
  (``python -m repro.obs.perfcheck old new --tol ...``);
* ``costs``     — analytic per-SequenceOp FLOPs/bytes cost model
  (NOT imported here: it pulls in jax eagerly, while this package —
  like ``registry``/``validate``/``perfcheck`` — stays importable from
  bare-stdlib CI contexts).

``Obs`` bundles one registry + one tracer, which is what components
take (``Engine(obs=...)``, ``FaultTolerantLoop(obs=...)``,
``CheckpointManager(obs=...)``); each constructs a private ``Obs()``
when not given one, so tests never share state accidentally and a CLI
can thread one bundle through the whole stack.
"""

from __future__ import annotations

from .registry import (  # noqa: F401
    LATENCY_BUCKETS,
    Counter,
    Gauge,
    Histogram,
    Registry,
)
from .sinks import (  # noqa: F401
    JsonlSink,
    console_summary,
    prometheus_text,
    read_jsonl,
    write_metrics,
    write_prometheus,
)
from .perf import (  # noqa: F401
    BENCH_SCHEMA,
    BenchHistory,
    env_fingerprint,
    profile_capture,
    read_bench,
)
from .timeline import (  # noqa: F401
    check_timelines,
    render_timeline,
    request_timelines,
    terminal_events,
)
from .trace import SpanTimer, Tracer  # noqa: F401


class Obs:
    """One registry + one tracer: the bundle components program against.

    >>> obs = Obs()
    >>> ttft = obs.histogram("serving_ttft_seconds")
    >>> with obs.span("engine.prefill", rid=3):
    ...     pass
    """

    def __init__(self, *, ring: int = 4096, sinks=(), annotate="auto"):
        self.registry = Registry()
        self.tracer = Tracer(ring=ring, sinks=sinks, annotate=annotate)

    # metric declaration passes through to the registry
    def counter(self, name, help=""):
        return self.registry.counter(name, help)

    def gauge(self, name, help=""):
        return self.registry.gauge(name, help)

    def histogram(self, name, help="", buckets=LATENCY_BUCKETS,
                  sample_cap=1024):
        return self.registry.histogram(name, help, buckets=buckets,
                                       sample_cap=sample_cap)

    # tracing passes through to the tracer
    def span(self, name, **labels):
        return self.tracer.span(name, **labels)

    def event(self, name, **labels):
        self.tracer.event(name, **labels)

    def timer(self, name, **labels) -> SpanTimer:
        return SpanTimer(self.tracer, name, **labels)

    def snapshot(self) -> dict:
        return self.registry.snapshot()

    def events(self, name=None, kind=None):
        return self.tracer.events(name=name, kind=kind)

    def attach(self, sink) -> None:
        self.tracer.attach(sink)

    def reset(self) -> None:
        """Fresh epoch: zero every metric series and drop the event ring
        (post-warmup resets in CLIs/benches).  Attached sinks keep what
        they already wrote."""
        self.registry.reset()
        self.tracer.clear()

    def flush(self) -> None:
        self.tracer.flush()
