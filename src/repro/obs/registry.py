"""Dependency-free metrics registry: counters, gauges, histograms.

Design rules (DESIGN.md §13):

* **Bounded memory.**  Every instrument stores a fixed amount of state
  per labeled series: counters/gauges one float, histograms a fixed
  bucket-count vector plus count/sum/min/max and a bounded reservoir of
  recent samples.  Nothing grows with traffic — the unbounded
  ``stats["ttft_s"]`` list this replaces grew one float per request
  forever.
* **No device syncs.**  Instruments take plain Python numbers; callers
  observe values they already hold on the host (wall-clock deltas, token
  counts fetched at the engine's existing once-per-block sync).  Nothing
  in this module imports jax.
* **Thread-safe.**  The checkpoint manager observes save durations from
  its async thread; all mutation goes through one registry lock (the
  hot-path cost is one uncontended lock acquire per observation).

Naming convention: ``<subsystem>_<what>_<unit>`` with counters suffixed
``_total`` (``serving_ttft_seconds``, ``train_steps_total``,
``ckpt_save_seconds``).  Labels are sparse key=value pairs
(``status="timeout"``, ``point="engine.nan_state"``); a metric's series
are keyed by the sorted label tuple.

``Registry.snapshot()`` is the one export format — a plain-dict,
JSON-able view consumed by the JSONL/console/Prometheus sinks, the CLI
``--metrics-out`` dumps, and ``benchmarks/report.py``.  ``merge``
folds one snapshot into another (multi-process aggregation: counters and
histogram buckets add, gauges last-write-wins).
"""

from __future__ import annotations

import threading
from typing import Dict, List, Optional, Sequence, Tuple

LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labels: Dict[str, object]) -> LabelKey:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


#: default latency bucket edges (seconds): 100us .. ~105s, x2 per bucket.
LATENCY_BUCKETS = tuple(1e-4 * 2 ** i for i in range(21))


class Metric:
    """Base: one named instrument holding labeled series."""

    kind = "metric"

    def __init__(self, name: str, help: str, lock: threading.Lock):
        self.name = name
        self.help = help
        self._lock = lock
        self._series: Dict[LabelKey, object] = {}

    def labels(self) -> List[Dict[str, str]]:
        return [dict(k) for k in self._series]


class Counter(Metric):
    """Monotonic (float) accumulator, optionally labeled."""

    kind = "counter"

    def inc(self, value: float = 1.0, **labels) -> None:
        if value < 0:
            raise ValueError(f"counter {self.name}: negative inc {value}")
        key = _label_key(labels)
        with self._lock:
            self._series[key] = self._series.get(key, 0.0) + value

    def value(self, **labels) -> float:
        return float(self._series.get(_label_key(labels), 0.0))

    def total(self) -> float:
        """Sum over every labeled series."""
        with self._lock:
            return float(sum(self._series.values()))

    def _set(self, value: float, **labels) -> None:
        """Compat-shim backdoor (``Engine.stats`` writes); not public API."""
        with self._lock:
            self._series[_label_key(labels)] = float(value)

    def reset(self) -> None:
        with self._lock:
            self._series.clear()

    def snapshot_series(self):
        with self._lock:
            return [
                {"labels": dict(k), "value": v}
                for k, v in sorted(self._series.items())
            ]

    def merge_series(self, series) -> None:
        for s in series:
            self.inc(s["value"], **s["labels"])


class Gauge(Metric):
    """Point-in-time value (queue depth, slot occupancy, last loss)."""

    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        with self._lock:
            self._series[_label_key(labels)] = float(value)

    def inc(self, value: float = 1.0, **labels) -> None:
        key = _label_key(labels)
        with self._lock:
            self._series[key] = self._series.get(key, 0.0) + value

    def value(self, **labels) -> float:
        return float(self._series.get(_label_key(labels), 0.0))

    def reset(self) -> None:
        with self._lock:
            self._series.clear()

    def snapshot_series(self):
        with self._lock:
            return [
                {"labels": dict(k), "value": v}
                for k, v in sorted(self._series.items())
            ]

    def merge_series(self, series) -> None:
        for s in series:  # last-write-wins
            self.set(s["value"], **s["labels"])


class _HistSeries:
    """Fixed-bucket histogram state: bucket counts + count/sum/min/max +
    a bounded ring of recent raw samples (for exact small-N quantiles and
    the ``stats["ttft_s"]`` compat view)."""

    __slots__ = ("counts", "count", "sum", "min", "max", "samples", "_cap",
                 "_next")

    def __init__(self, n_buckets: int, sample_cap: int):
        self.counts = [0] * (n_buckets + 1)  # +1 = overflow bucket
        self.count = 0
        self.sum = 0.0
        self.min = None
        self.max = None
        self._cap = sample_cap
        self._next = 0
        self.samples: List[float] = []


class Histogram(Metric):
    """Fixed-bucket-edge histogram with bounded sample reservoir.

    ``observe`` is O(log n_buckets).  Quantiles come from the raw sample
    ring while the series has seen <= ``sample_cap`` values (exact), and
    from linear interpolation inside the cumulative bucket counts after
    that (bounded error = bucket width).
    """

    kind = "histogram"

    def __init__(self, name, help, lock, buckets: Sequence[float],
                 sample_cap: int = 1024):
        super().__init__(name, help, lock)
        edges = tuple(float(b) for b in buckets)
        if not edges or list(edges) != sorted(set(edges)):
            raise ValueError(
                f"histogram {name}: bucket edges must be non-empty, "
                f"sorted, unique; got {buckets}"
            )
        self.buckets = edges
        self.sample_cap = int(sample_cap)

    def _get(self, key: LabelKey) -> _HistSeries:
        s = self._series.get(key)
        if s is None:
            s = self._series[key] = _HistSeries(
                len(self.buckets), self.sample_cap
            )
        return s

    def observe(self, value: float, **labels) -> None:
        import bisect

        value = float(value)
        key = _label_key(labels)
        with self._lock:
            s = self._get(key)
            s.counts[bisect.bisect_left(self.buckets, value)] += 1
            s.count += 1
            s.sum += value
            s.min = value if s.min is None else min(s.min, value)
            s.max = value if s.max is None else max(s.max, value)
            if len(s.samples) < s._cap:
                s.samples.append(value)
            else:  # overwrite oldest: a ring, never growth
                s.samples[s._next] = value
                s._next = (s._next + 1) % s._cap

    def count(self, **labels) -> int:
        s = self._series.get(_label_key(labels))
        return 0 if s is None else s.count

    def sum(self, **labels) -> float:
        s = self._series.get(_label_key(labels))
        return 0.0 if s is None else s.sum

    def recent(self, **labels) -> List[float]:
        """The bounded reservoir of recent samples (compat view)."""
        s = self._series.get(_label_key(labels))
        return [] if s is None else list(s.samples)

    def quantile(self, q: float, **labels) -> Optional[float]:
        """Estimate the q-quantile (q in [0, 1]) of one series."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile {q} outside [0, 1]")
        s = self._series.get(_label_key(labels))
        if s is None or s.count == 0:
            return None
        if s.count <= len(s.samples):  # reservoir still exact
            xs = sorted(s.samples)
            return xs[min(int(q * len(xs)), len(xs) - 1)]
        rank = q * s.count
        cum = 0
        for i, c in enumerate(s.counts):
            if cum + c >= rank and c > 0:
                lo = self.buckets[i - 1] if i > 0 else (
                    s.min if s.min is not None else 0.0
                )
                hi = self.buckets[i] if i < len(self.buckets) else s.max
                frac = (rank - cum) / c
                return lo + (hi - lo) * frac
            cum += c
        return s.max

    def reset(self) -> None:
        with self._lock:
            self._series.clear()

    def snapshot_series(self):
        with self._lock:
            out = []
            for k, s in sorted(self._series.items()):
                out.append({
                    "labels": dict(k),
                    "count": s.count, "sum": round(s.sum, 9),
                    "min": s.min, "max": s.max,
                    "bucket_counts": list(s.counts),
                })
            return out

    def merge_series(self, series) -> None:
        with self._lock:
            for other in series:
                key = _label_key(other["labels"])
                s = self._get(key)
                bc = other["bucket_counts"]
                if len(bc) != len(s.counts):
                    raise ValueError(
                        f"histogram {self.name}: merging series with "
                        f"{len(bc)} buckets into {len(s.counts)}"
                    )
                s.counts = [a + b for a, b in zip(s.counts, bc)]
                s.count += other["count"]
                s.sum += other["sum"]
                for field, pick in (("min", min), ("max", max)):
                    ov = other.get(field)
                    if ov is not None:
                        cur = getattr(s, field)
                        setattr(s, field,
                                ov if cur is None else pick(cur, ov))


class Registry:
    """A named collection of instruments with one shared lock."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: Dict[str, Metric] = {}

    def _declare(self, cls, name, help, **kw) -> Metric:
        m = self._metrics.get(name)
        if m is not None:
            if not isinstance(m, cls):
                raise ValueError(
                    f"metric {name!r} already registered as {m.kind}"
                )
            return m
        m = cls(name, help, self._lock, **kw)
        self._metrics[name] = m
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._declare(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._declare(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets: Sequence[float] = LATENCY_BUCKETS,
                  sample_cap: int = 1024) -> Histogram:
        return self._declare(Histogram, name, help, buckets=tuple(buckets),
                             sample_cap=sample_cap)

    def get(self, name: str) -> Optional[Metric]:
        return self._metrics.get(name)

    def reset(self) -> None:
        """Zero every series (fresh traffic epoch, e.g. post-warmup);
        metric declarations survive."""
        for m in self._metrics.values():
            m.reset()

    def snapshot(self) -> dict:
        """Plain-dict JSON-able view of every metric — THE export schema
        (sinks, ``--metrics-out``, benchmarks, the CI validator)."""
        out = {"schema": "repro.obs.metrics/v1", "metrics": {}}
        for name, m in sorted(self._metrics.items()):
            entry = {"kind": m.kind, "help": m.help,
                     "series": m.snapshot_series()}
            if isinstance(m, Histogram):
                entry["buckets"] = list(m.buckets)
            out["metrics"][name] = entry
        return out

    def merge(self, snapshot: dict) -> None:
        """Fold a ``snapshot()`` from another registry/process into this
        one: counters and histogram buckets add, gauges last-write-wins."""
        if snapshot.get("schema") != "repro.obs.metrics/v1":
            raise ValueError(
                f"unknown metrics schema {snapshot.get('schema')!r}"
            )
        kinds = {"counter": self.counter, "gauge": self.gauge}
        for name, entry in snapshot["metrics"].items():
            if entry["kind"] == "histogram":
                m = self.histogram(name, entry.get("help", ""),
                                   buckets=entry["buckets"])
            else:
                m = kinds[entry["kind"]](name, entry.get("help", ""))
            m.merge_series(entry["series"])
