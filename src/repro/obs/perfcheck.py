"""Noise-aware bench regression gate over ``repro.obs.bench/v1`` history.

``python -m repro.obs.perfcheck OLD NEW [--tol 0.25] [--noise-mult 3.0]``
compares the *latest run* in each history file row-by-row and exits
nonzero iff any shared row regressed significantly.

Significance (DESIGN.md §15): a row regresses iff it moved in the bad
direction (per the row's recorded ``direction``) by more than

    max(tol * old.value,
        min(noise_mult * (old.dispersion + new.dispersion),
            max_rel * old.value))

i.e. the change must clear BOTH a relative tolerance and a multiple of
the two runs' combined IQRs — a wide-IQR noisy row needs a bigger move
to fail than a tight one, which is what makes the gate usable on shared
CI runners.  The noise allowance is CAPPED at ``max_rel`` (default
0.75) of the old value: an IQR comparable to the median means the
measurement is junk, but a 2x shift of the median is still a
regression — without the cap, the noisiest benches could never fail.
Rows present in only one file are reported but never fail the gate
(benches come and go across PRs).

Pure stdlib (imports only ``repro.obs.perf``, itself stdlib at import):
runs anywhere, including bare CI python with no jax.
"""

from __future__ import annotations

import argparse
import json
import sys

from .perf import read_bench


def compare_rows(old_row: dict, new_row: dict, *, tol: float,
                 noise_mult: float, max_rel: float = 0.75) -> dict:
    """Compare one row across runs; see module docstring for the rule."""
    old_v, new_v = old_row["value"], new_row["value"]
    direction = new_row.get("direction", old_row.get("direction", "lower"))
    delta = new_v - old_v
    bad = delta < 0 if direction == "higher" else delta > 0
    noise = noise_mult * (old_row.get("dispersion", 0.0)
                          + new_row.get("dispersion", 0.0))
    threshold = max(tol * abs(old_v), min(noise, max_rel * abs(old_v)))
    regressed = bad and abs(delta) > threshold
    return {
        "name": new_row["name"], "old": old_v, "new": new_v,
        "unit": new_row.get("unit", ""), "direction": direction,
        "delta": delta,
        "ratio": (new_v / old_v) if old_v else float("inf"),
        "threshold": threshold, "regressed": regressed,
        "improved": (not bad) and abs(delta) > threshold,
    }


def compare_runs(old_run: dict, new_run: dict, *, tol: float = 0.25,
                 noise_mult: float = 3.0, max_rel: float = 0.75) -> dict:
    """Row-by-row comparison of two parsed runs (``perf.read_bench``
    elements).  Also used by ``benchmarks/report.py`` for the trend
    column."""
    old_rows, new_rows = old_run["rows"], new_run["rows"]
    shared = [n for n in new_rows if n in old_rows]
    results = [
        compare_rows(old_rows[n], new_rows[n],
                     tol=tol, noise_mult=noise_mult, max_rel=max_rel)
        for n in shared
    ]
    return {
        "compared": results,
        "regressions": [r for r in results if r["regressed"]],
        "improvements": [r for r in results if r["improved"]],
        "only_old": sorted(set(old_rows) - set(new_rows)),
        "only_new": sorted(set(new_rows) - set(old_rows)),
        "old_env": old_run.get("env", {}), "new_env": new_run.get("env", {}),
    }


def _latest_run(path: str) -> dict:
    runs = read_bench(path)
    if not runs:
        raise ValueError(f"{path}: no runs")
    return runs[-1]


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.obs.perfcheck",
        description="Compare the latest runs of two repro.obs.bench/v1 "
                    "history files; exit 1 on significant regressions.",
    )
    p.add_argument("old", help="baseline history file (JSONL)")
    p.add_argument("new", help="candidate history file (JSONL)")
    p.add_argument("--tol", type=float, default=0.25,
                   help="relative tolerance (default 0.25 = 25%%)")
    p.add_argument("--noise-mult", type=float, default=3.0,
                   help="multiple of combined IQRs a change must also "
                        "clear (default 3.0)")
    p.add_argument("--max-rel", type=float, default=0.75,
                   help="cap on the noise allowance as a fraction of the "
                        "old value (default 0.75) — keeps very noisy "
                        "rows fail-able")
    p.add_argument("--json", action="store_true",
                   help="emit the full comparison as JSON on stdout")
    args = p.parse_args(argv)

    try:
        old_run = _latest_run(args.old)
        new_run = _latest_run(args.new)
    except (OSError, ValueError) as e:
        print(f"perfcheck: {e}", file=sys.stderr)
        return 2

    cmp = compare_runs(old_run, new_run, tol=args.tol,
                       noise_mult=args.noise_mult, max_rel=args.max_rel)
    if args.json:
        print(json.dumps(cmp, indent=2, sort_keys=True))
    else:
        oe, ne = cmp["old_env"], cmp["new_env"]
        print(f"perfcheck: {args.old} ({oe.get('git_sha')}) -> "
              f"{args.new} ({ne.get('git_sha')}), "
              f"{len(cmp['compared'])} shared rows, "
              f"tol={args.tol} noise_mult={args.noise_mult}")
        if oe.get("backend") != ne.get("backend") or \
                oe.get("device_kind") != ne.get("device_kind"):
            print(f"perfcheck: WARNING: env mismatch "
                  f"({oe.get('backend')}/{oe.get('device_kind')} vs "
                  f"{ne.get('backend')}/{ne.get('device_kind')}) — "
                  f"numbers may not be comparable")
        for r in cmp["compared"]:
            tag = "REGRESSED" if r["regressed"] else (
                "improved" if r["improved"] else "ok")
            print(f"  {tag:9s} {r['name']}: {r['old']:.6g} -> "
                  f"{r['new']:.6g} {r['unit']} "
                  f"(x{r['ratio']:.3f}, {r['direction']}-is-better)")
        for name in cmp["only_new"]:
            print(f"  new       {name} (no baseline)")
        for name in cmp["only_old"]:
            print(f"  dropped   {name} (baseline only)")
    n_reg = len(cmp["regressions"])
    if n_reg:
        print(f"perfcheck: FAIL — {n_reg} significant regression(s)",
              file=sys.stderr)
        return 1
    print(f"perfcheck: OK — no significant regressions "
          f"({len(cmp['improvements'])} improvement(s))")
    return 0


if __name__ == "__main__":
    sys.exit(main())
