"""Schema validator for obs artifacts — the CI metrics-smoke gate.

    python -m repro.obs.validate --metrics M.json --events E.jsonl \
        --bench history.jsonl \
        --expect-counter serving_quarantined_total=1 \
        --expect-terminal-statuses ok,error \
        --expect-requests 3

Checks (exit non-zero with a message naming the first violation):

* the metrics JSON is a well-formed ``repro.obs.metrics/v1`` snapshot
  (kinds, series shapes, histogram bucket-count lengths);
* the events JSONL is a well-formed ``repro.obs.events/v1`` log (header
  line, per-record required fields);
* ``--bench PATH`` — the file is a well-formed ``repro.obs.bench/v1``
  history (run headers with env fingerprints, typed rows attached to a
  known run; see ``repro.obs.perf``);
* ``--expect-counter NAME=V`` — the counter's total (summed over label
  series) equals ``V``;
* ``--expect-counter-min NAME=V`` — the counter's total is at least
  ``V`` (for inherently trace-dependent tallies like cache hits, where
  the exact count is policy but "it happened" is the contract);
* ``--expect-requests N`` — at least N distinct rids have a terminal
  ``request.done`` event, every terminal status is one of the four
  legal ones, and every rid with ANY lifecycle event also has a
  terminal event (no request ever vanishes from the log);
* ``--expect-terminal-statuses a,b`` — the SET of statuses present
  equals exactly this set.

Pure stdlib: runs anywhere the artifacts can be copied, no jax import.
"""

from __future__ import annotations

import argparse
import json
import sys

from .perf import read_bench
from .sinks import read_jsonl
from .timeline import TERMINAL_STATUSES, request_timelines, terminal_events

_KINDS = ("counter", "gauge", "histogram")


def validate_metrics(snapshot: dict) -> None:
    if snapshot.get("schema") != "repro.obs.metrics/v1":
        raise ValueError(
            f"metrics schema is {snapshot.get('schema')!r}, expected "
            "repro.obs.metrics/v1"
        )
    for name, entry in snapshot.get("metrics", {}).items():
        if entry.get("kind") not in _KINDS:
            raise ValueError(f"metric {name}: bad kind {entry.get('kind')!r}")
        series = entry.get("series")
        if not isinstance(series, list):
            raise ValueError(f"metric {name}: series must be a list")
        for s in series:
            if not isinstance(s.get("labels"), dict):
                raise ValueError(f"metric {name}: series without labels dict")
            if entry["kind"] == "histogram":
                edges = entry.get("buckets")
                if not isinstance(edges, list) or not edges:
                    raise ValueError(f"metric {name}: histogram needs buckets")
                if len(s.get("bucket_counts", [])) != len(edges) + 1:
                    raise ValueError(
                        f"metric {name}: bucket_counts length "
                        f"{len(s.get('bucket_counts', []))} != "
                        f"len(buckets)+1 = {len(edges) + 1}"
                    )
                if s.get("count") != sum(s["bucket_counts"]):
                    raise ValueError(
                        f"metric {name}: count {s.get('count')} != sum of "
                        f"bucket_counts {sum(s['bucket_counts'])}"
                    )
            elif not isinstance(s.get("value"), (int, float)):
                raise ValueError(f"metric {name}: series without value")


def validate_events(events) -> None:
    for e in events:
        for field in ("kind", "name", "ts", "seq"):
            if field not in e:
                raise ValueError(f"event missing {field!r}: {e}")
        if e["kind"] not in ("span", "event"):
            raise ValueError(f"bad event kind {e['kind']!r}: {e}")
        if e["kind"] == "span" and "dur_s" not in e:
            raise ValueError(f"span without dur_s: {e}")


def counter_total(snapshot: dict, name: str) -> float:
    entry = snapshot["metrics"].get(name)
    if entry is None:
        raise ValueError(f"counter {name!r} not in snapshot")
    if entry["kind"] != "counter":
        raise ValueError(f"{name!r} is a {entry['kind']}, not a counter")
    return sum(s["value"] for s in entry["series"])


def check_requests(events, min_requests: int) -> None:
    done = terminal_events(events)
    if len(done) < min_requests:
        raise ValueError(
            f"{len(done)} requests with terminal events, expected >= "
            f"{min_requests} (rids: {sorted(done)})"
        )
    for rid, e in done.items():
        if e.get("status") not in TERMINAL_STATUSES:
            raise ValueError(
                f"request {rid}: terminal status {e.get('status')!r} not in "
                f"{TERMINAL_STATUSES}"
            )
    for rid in request_timelines(events):
        if rid not in done:
            raise ValueError(
                f"request {rid} has lifecycle events but no request.done — "
                "a request vanished from the log"
            )


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--metrics", default=None,
                    help="registry snapshot JSON (--metrics-out artifact)")
    ap.add_argument("--events", default=None,
                    help="JSONL event log (--events-out artifact)")
    ap.add_argument("--bench", default=None,
                    help="repro.obs.bench/v1 history JSONL "
                         "(benchmarks/run.py --history artifact)")
    ap.add_argument("--expect-counter", action="append", default=[],
                    metavar="NAME=VALUE")
    ap.add_argument("--expect-counter-min", action="append", default=[],
                    metavar="NAME=VALUE")
    ap.add_argument("--expect-requests", type=int, default=None)
    ap.add_argument("--expect-terminal-statuses", default=None,
                    metavar="S1,S2,...")
    args = ap.parse_args(argv)
    if not args.metrics and not args.events and not args.bench:
        ap.error("nothing to validate: pass --metrics, --events and/or "
                 "--bench")
    try:
        snapshot = None
        if args.metrics:
            with open(args.metrics) as f:
                snapshot = json.load(f)
            validate_metrics(snapshot)
            print(f"[obs.validate] {args.metrics}: "
                  f"{len(snapshot['metrics'])} metrics ok")
        events = None
        if args.events:
            events = read_jsonl(args.events)
            validate_events(events)
            print(f"[obs.validate] {args.events}: {len(events)} events ok")
        if args.bench:
            runs = read_bench(args.bench)  # raises on schema violations
            if not runs:
                raise ValueError(f"{args.bench}: no bench runs")
            nrows = sum(len(r["rows"]) for r in runs)
            print(f"[obs.validate] {args.bench}: {len(runs)} run(s), "
                  f"{nrows} rows ok")
        for spec in args.expect_counter:
            if snapshot is None:
                raise ValueError("--expect-counter needs --metrics")
            name, want = spec.split("=", 1)
            got = counter_total(snapshot, name)
            if got != float(want):
                raise ValueError(
                    f"counter {name} total = {got}, expected {want}"
                )
            print(f"[obs.validate] counter {name} == {want} ok")
        for spec in args.expect_counter_min:
            if snapshot is None:
                raise ValueError("--expect-counter-min needs --metrics")
            name, want = spec.split("=", 1)
            got = counter_total(snapshot, name)
            if got < float(want):
                raise ValueError(
                    f"counter {name} total = {got}, expected >= {want}"
                )
            print(f"[obs.validate] counter {name} >= {want} ok "
                  f"(got {got})")
        if args.expect_requests is not None:
            if events is None:
                raise ValueError("--expect-requests needs --events")
            check_requests(events, args.expect_requests)
            print(f"[obs.validate] >= {args.expect_requests} requests with "
                  "terminal events ok")
        if args.expect_terminal_statuses is not None:
            if events is None:
                raise ValueError("--expect-terminal-statuses needs --events")
            want = set(args.expect_terminal_statuses.split(","))
            got = {e.get("status") for e in terminal_events(events).values()}
            if got != want:
                raise ValueError(
                    f"terminal statuses {sorted(got)} != expected "
                    f"{sorted(want)}"
                )
            print(f"[obs.validate] terminal statuses == {sorted(want)} ok")
    except (ValueError, OSError, json.JSONDecodeError) as e:
        print(f"[obs.validate] FAIL: {e}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
