"""Pluggable sinks: JSONL event log, console summary, Prometheus text.

Sinks consume the two schemas the obs layer exports:

* ``repro.obs.events/v1`` — span/event records from ``trace.Tracer``
  (one JSON object per line via ``JsonlSink``);
* ``repro.obs.metrics/v1`` — ``Registry.snapshot()`` dicts
  (``write_metrics`` JSON dump, ``prometheus_text`` exposition,
  ``console_summary`` one-liners).

Everything is host-side file/string work — sinks never touch jax.
"""

from __future__ import annotations

import io
import json
import time
from typing import Optional, TextIO


class JsonlSink:
    """Write-through JSONL event log.

    The first line is a header record carrying the schema id and the
    ``perf_counter`` -> epoch offset, so consumers can anchor the
    monotonic ``ts`` fields to wall-clock time::

        {"kind": "header", "schema": "repro.obs.events/v1",
         "epoch_offset": <time.time() - perf_counter()>}

    ``emit`` is called on the tracer's hot path: one ``json.dumps`` and
    one buffered ``write`` per record, flushed on ``flush``/``close``
    (and optionally every ``flush_every`` records so tailing a live run
    works).
    """

    def __init__(self, path_or_file, flush_every: int = 64):
        if isinstance(path_or_file, (str, bytes)):
            self._f: TextIO = open(path_or_file, "w")
            self._owns = True
        else:
            self._f = path_or_file
            self._owns = False
        self.flush_every = flush_every
        self._n = 0
        self.emit({
            "kind": "header", "schema": "repro.obs.events/v1",
            "epoch_offset": time.time() - time.perf_counter(),
        })

    def emit(self, rec: dict) -> None:
        self._f.write(json.dumps(rec, default=float) + "\n")
        self._n += 1
        if self.flush_every and self._n % self.flush_every == 0:
            self._f.flush()

    def flush(self) -> None:
        self._f.flush()

    def close(self) -> None:
        self._f.flush()
        if self._owns:
            self._f.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


def read_jsonl(path: str):
    """Load a JSONL event log, validating and dropping the header."""
    events = []
    with open(path) as f:
        for i, line in enumerate(f):
            line = line.strip()
            if not line:
                continue
            rec = json.loads(line)
            if i == 0:
                if rec.get("schema") != "repro.obs.events/v1":
                    raise ValueError(
                        f"{path}: expected repro.obs.events/v1 header, "
                        f"got {rec!r}"
                    )
                continue
            events.append(rec)
    return events


# -- metrics snapshot sinks -------------------------------------------------


def write_metrics(snapshot: dict, path: str) -> None:
    """Dump a ``Registry.snapshot()`` as JSON (``--metrics-out``)."""
    with open(path, "w") as f:
        json.dump(snapshot, f, indent=1, default=float)
        f.write("\n")


def _fmt_labels(labels: dict) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in sorted(labels.items()))
    return "{" + inner + "}"


def prometheus_text(snapshot: dict) -> str:
    """Prometheus text exposition (v0.0.4) of a metrics snapshot —
    written to a file for a node-exporter-style textfile collector; no
    HTTP server, no client library dependency."""
    if snapshot.get("schema") != "repro.obs.metrics/v1":
        raise ValueError(f"unknown schema {snapshot.get('schema')!r}")
    out = io.StringIO()
    for name, entry in snapshot["metrics"].items():
        kind = entry["kind"]
        if entry.get("help"):
            out.write(f"# HELP {name} {entry['help']}\n")
        out.write(f"# TYPE {name} {kind}\n")
        if kind in ("counter", "gauge"):
            for s in entry["series"]:
                out.write(f"{name}{_fmt_labels(s['labels'])} {s['value']}\n")
            continue
        edges = entry["buckets"]
        for s in entry["series"]:
            base = dict(s["labels"])
            cum = 0
            for edge, c in zip(edges, s["bucket_counts"]):
                cum += c
                lab = _fmt_labels({**base, "le": repr(float(edge))})
                out.write(f"{name}_bucket{lab} {cum}\n")
            lab = _fmt_labels({**base, "le": "+Inf"})
            out.write(f"{name}_bucket{lab} {s['count']}\n")
            out.write(f"{name}_sum{_fmt_labels(base)} {s['sum']}\n")
            out.write(f"{name}_count{_fmt_labels(base)} {s['count']}\n")
    return out.getvalue()


def write_prometheus(snapshot: dict, path: str) -> None:
    with open(path, "w") as f:
        f.write(prometheus_text(snapshot))


def console_summary(snapshot: dict, prefix: Optional[str] = None) -> str:
    """Human one-liners from a metrics snapshot: one line per metric,
    totals for counters, last value for gauges, count/mean for
    histograms.  ``prefix`` filters by metric-name prefix."""
    lines = []
    for name, entry in snapshot["metrics"].items():
        if prefix and not name.startswith(prefix):
            continue
        if entry["kind"] in ("counter", "gauge"):
            parts = [
                f"{_fmt_labels(s['labels']) or 'total'}={s['value']:g}"
                for s in entry["series"]
            ]
            if parts:
                lines.append(f"{name}: " + " ".join(parts))
            continue
        for s in entry["series"]:
            if not s["count"]:
                continue
            mean = s["sum"] / s["count"]
            lines.append(
                f"{name}{_fmt_labels(s['labels'])}: count={s['count']} "
                f"mean={mean:.4g} min={s['min']:.4g} max={s['max']:.4g}"
            )
    return "\n".join(lines)
