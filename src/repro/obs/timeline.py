"""Per-request lifecycle timelines derived from span/event records.

The engine emits one event per lifecycle transition (DESIGN.md §13)::

    request.queued  ->  request.admitted  ->  request.first_token
        ->  (engine.decode_block / engine.spec_round spans, shared)
        ->  request.done {status: ok|error|timeout|cancelled}

``request_timelines`` groups the per-request events by ``rid`` (block
and round spans are engine-wide, not per-request, so they are not part
of a timeline); ``check_timelines`` asserts the completeness contract
the chaos tests and the CI validator rely on: every terminal
``GenResult`` has exactly one matching ``request.done`` event whose
``status`` label agrees.
"""

from __future__ import annotations

from typing import Dict, Iterable, List

#: events that belong to one request (carry a ``rid`` label)
REQUEST_EVENTS = (
    "request.queued", "request.admitted", "request.first_token",
    "request.done",
)

TERMINAL_STATUSES = ("ok", "error", "timeout", "cancelled")


def request_timelines(events: Iterable[dict]) -> Dict[int, List[dict]]:
    """Group request lifecycle events by rid, each ordered by ``seq``."""
    out: Dict[int, List[dict]] = {}
    for e in events:
        if e.get("name") in REQUEST_EVENTS and "rid" in e:
            out.setdefault(int(e["rid"]), []).append(e)
    for tl in out.values():
        tl.sort(key=lambda e: e.get("seq", 0))
    return out


def terminal_events(events: Iterable[dict]) -> Dict[int, dict]:
    """rid -> its LAST ``request.done`` event (re-used rids — e.g. a
    warmup run sharing an engine — keep the latest terminal)."""
    out: Dict[int, dict] = {}
    for e in events:
        if e.get("name") == "request.done" and "rid" in e:
            out[int(e["rid"])] = e
    return out


def check_timelines(events: Iterable[dict], results) -> None:
    """Assert timeline completeness against engine results.

    ``results``: iterable of ``GenResult`` (or any object with ``rid``
    and ``status``).  Raises ``AssertionError`` naming the first broken
    contract:

    * every result has a ``request.done`` event;
    * the event's ``status`` label equals the result's status;
    * the status is one of the four terminal statuses.
    """
    events = list(events)
    done = terminal_events(events)
    for r in results:
        rid = int(r.rid)
        assert rid in done, (
            f"request {rid} (status={r.status}) has no request.done event"
        )
        got = done[rid].get("status")
        assert got == r.status, (
            f"request {rid}: terminal event status {got!r} != result "
            f"status {r.status!r}"
        )
        assert got in TERMINAL_STATUSES, (
            f"request {rid}: unknown terminal status {got!r}"
        )


def render_timeline(events: Iterable[dict], rid: int) -> str:
    """Human-readable one-request timeline (relative milliseconds)."""
    tl = request_timelines(events).get(rid, [])
    if not tl:
        return f"rid={rid}: no events"
    t0 = tl[0]["ts"]
    lines = [f"rid={rid}:"]
    for e in tl:
        extra = {k: v for k, v in e.items()
                 if k not in ("kind", "name", "ts", "seq", "rid", "depth")}
        detail = " ".join(f"{k}={v}" for k, v in sorted(extra.items()))
        lines.append(
            f"  +{1e3 * (e['ts'] - t0):9.2f}ms  {e['name']}"
            + (f"  {detail}" if detail else "")
        )
    return "\n".join(lines)
