"""Perf observability: roofline utilization, profiler capture, bench history.

Three pieces, all consumed by ``benchmarks/run.py`` and CI:

* **Roofline** — ``device_peak()`` (known-accelerator table, calibrated
  matmul fallback) and ``roofline_utilization(tok_per_s, cost, peak)``
  which turns a measured throughput plus a ``repro.obs.costs`` OpCost
  into achieved FLOP/s, achieved GB/s, utilization fractions and the
  bound (compute vs memory) — the §Utilization table in
  ``benchmarks/report.py``.

* **Profiler capture** — ``profile_capture(profile_dir, obs=...)``
  wraps a region in ``jax.profiler.start_trace/stop_trace`` and mirrors
  the boundaries as ``profile.start`` / ``profile.stop`` events on the
  obs tracer, so the XLA trace timeline can be lined up against the
  ``repro.obs.events/v1`` spans (both carry wall-clock stamps).  No-op
  when ``profile_dir`` is falsy.  Exposed as ``--profile-dir`` on
  ``launch/train.py``, ``launch/serve.py`` and ``benchmarks/run.py``.

* **Bench history** — an append-only JSONL (schema
  ``repro.obs.bench/v1``): each bench invocation appends one ``run``
  header record carrying the env fingerprint (git sha, jax version,
  backend, device count/kind) followed by one ``row`` record per metric
  (name, value, unit, direction, dispersion, sample count).  Rows are
  compared across runs by ``repro.obs.perfcheck`` (the noise-aware
  regression gate) and rendered as the trend column in the report.

Import-purity contract (mirrors ``registry.py``): importing this module
must NOT import jax — ``perfcheck`` and ``validate`` run in bare-stdlib
contexts.  All jax use is inside functions.
"""

from __future__ import annotations

import contextlib
import json
import os
import subprocess
import time
import uuid
from typing import Optional

BENCH_SCHEMA = "repro.obs.bench/v1"

#: direction of goodness for a bench row
DIRECTIONS = ("higher", "lower")

#: peak dense-f32 FLOP/s and HBM GB/s for accelerators we run on, keyed
#: by substrings of ``device.device_kind``.  bf16/f32 matmul peak on TPU
#: (MXU); conservative public numbers.
_KNOWN_PEAKS = (
    ("v6", 918e12, 1640e9),      # TPU v6e (Trillium)
    ("v5p", 459e12, 2765e9),
    ("v5 lite", 197e12, 819e9),  # v5e reports "TPU v5 lite"
    ("v5e", 197e12, 819e9),
    ("v4", 275e12, 1228e9),
    ("v3", 123e12, 900e9),
    ("v2", 46e12, 700e9),
)

_cpu_peak_cache: dict = {}


def _calibrate_cpu_peak(d: int = 1024, copy_mb: int = 32, repeats: int = 3):
    """Measure an achievable matmul FLOP/s + copy-bandwidth on this host.

    CPU 'peak' is meaningless from spec sheets under pytest-grade noise;
    a short calibration gives a *reachable* ceiling so CPU utilization
    numbers are comparable across runs on the same host.  FLOP/s comes
    from a BLAS matmul (best-of-N); bytes/s from a large memcpy (read +
    write counted) — the two ceilings are measured independently because
    a compute-bound matmul says nothing about memory bandwidth.
    """
    import numpy as np

    a = np.random.default_rng(0).standard_normal((d, d), dtype=np.float32)
    b = np.random.default_rng(1).standard_normal((d, d), dtype=np.float32)
    a @ b  # warm
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        a @ b
        best = min(best, time.perf_counter() - t0)
    flops = 2.0 * d ** 3 / best

    src = np.zeros(copy_mb * (1 << 20) // 4, dtype=np.float32)
    dst = np.empty_like(src)
    np.copyto(dst, src)  # warm
    best_cp = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        np.copyto(dst, src)
        best_cp = min(best_cp, time.perf_counter() - t0)
    membw = 2.0 * src.nbytes / best_cp
    return flops, membw


def device_peak(device=None) -> dict:
    """``{"flops_per_s", "bytes_per_s", "kind", "source"}`` for a device.

    Known accelerators come from the table; anything else (CPU, unknown
    kinds) falls back to a calibrated matmul, marked ``source:
    "calibrated"`` so readers know the ceiling is achievable-not-peak.
    """
    import jax

    if device is None:
        device = jax.devices()[0]
    kind = getattr(device, "device_kind", "unknown") or "unknown"
    low = kind.lower()
    for key, flops, membw in _KNOWN_PEAKS:
        if key in low:
            return {"flops_per_s": flops, "bytes_per_s": membw,
                    "kind": kind, "source": "table"}
    if kind not in _cpu_peak_cache:
        _cpu_peak_cache[kind] = _calibrate_cpu_peak()
    flops, membw = _cpu_peak_cache[kind]
    return {"flops_per_s": flops, "bytes_per_s": membw,
            "kind": kind, "source": "calibrated"}


def roofline_utilization(tok_per_s: float, cost, peak: Optional[dict] = None
                         ) -> dict:
    """Achieved-vs-roofline for one (throughput, OpCost) pair.

    ``cost`` is a ``repro.obs.costs.OpCost`` (or any object with
    ``flops_per_token`` / ``bytes_per_token``).  Utilization is measured
    against whichever resource the cost model says binds (the roofline
    ridge): ``bound`` is "compute" when the arithmetic intensity
    exceeds the device's ridge intensity, else "memory".
    """
    if peak is None:
        peak = device_peak()
    achieved_flops = tok_per_s * cost.flops_per_token
    achieved_bytes = tok_per_s * cost.bytes_per_token
    compute_util = achieved_flops / peak["flops_per_s"]
    memory_util = achieved_bytes / peak["bytes_per_s"]
    intensity = cost.flops_per_token / max(cost.bytes_per_token, 1e-9)
    ridge = peak["flops_per_s"] / peak["bytes_per_s"]
    bound = "compute" if intensity >= ridge else "memory"
    return {
        "tok_per_s": tok_per_s,
        "flops_per_token": cost.flops_per_token,
        "bytes_per_token": cost.bytes_per_token,
        "achieved_flops_per_s": achieved_flops,
        "achieved_bytes_per_s": achieved_bytes,
        "compute_util": compute_util,
        "memory_util": memory_util,
        "utilization": compute_util if bound == "compute" else memory_util,
        "bound": bound,
        "peak": dict(peak),
    }


@contextlib.contextmanager
def profile_capture(profile_dir, obs=None):
    """``jax.profiler`` trace of the wrapped region, or no-op if falsy.

    Emits ``profile.start`` / ``profile.stop`` events (with wall-clock
    ``wall_ns`` payloads) on ``obs.trace`` so the captured XLA timeline
    can be correlated with the obs span stream.
    """
    if not profile_dir:
        yield None
        return
    import jax

    os.makedirs(profile_dir, exist_ok=True)
    jax.profiler.start_trace(profile_dir)
    if obs is not None:
        obs.event("profile.start", profile_dir=str(profile_dir),
                  wall_ns=time.time_ns())
    try:
        yield profile_dir
    finally:
        if obs is not None:
            obs.event("profile.stop", profile_dir=str(profile_dir),
                      wall_ns=time.time_ns())
        jax.profiler.stop_trace()


# --------------------------------------------------------------------------
# env fingerprint + bench history
# --------------------------------------------------------------------------


def env_fingerprint() -> dict:
    """Where a bench number came from: git sha, jax version, backend,
    device count and kind.  Every field degrades to a sentinel rather
    than raising — history must be writable from bare CI runners."""
    fp = {"git_sha": "unknown", "jax_version": "unavailable",
          "backend": "none", "device_count": 0, "device_kind": "unknown"}
    try:
        sha = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=os.path.dirname(os.path.abspath(__file__)),
        )
        if sha.returncode == 0:
            fp["git_sha"] = sha.stdout.strip()
    except Exception:
        pass
    try:
        import jax

        fp["jax_version"] = jax.__version__
        fp["backend"] = jax.default_backend()
        devs = jax.devices()
        fp["device_count"] = len(devs)
        fp["device_kind"] = getattr(devs[0], "device_kind", "unknown")
    except Exception:
        pass
    return fp


class BenchHistory:
    """Append-only ``repro.obs.bench/v1`` writer for one bench run.

    One instance == one run: the ``run`` header (env fingerprint) is
    written lazily on the first ``bench_row``, so pointing ``--history``
    at a bench that produces no rows leaves the file untouched.
    """

    def __init__(self, path, env: Optional[dict] = None,
                 run_id: Optional[str] = None):
        self.path = str(path)
        self.run_id = run_id or uuid.uuid4().hex[:12]
        self._env = env
        self._started = False
        self.rows_written = 0

    def _append(self, rec: dict):
        parent = os.path.dirname(os.path.abspath(self.path))
        os.makedirs(parent, exist_ok=True)
        with open(self.path, "a") as f:
            f.write(json.dumps(rec, sort_keys=True) + "\n")

    def _start(self):
        if self._started:
            return
        self._started = True
        self._append({
            "kind": "run", "schema": BENCH_SCHEMA, "run_id": self.run_id,
            "ts": time.time(), "env": self._env or env_fingerprint(),
        })

    def bench_row(self, name: str, value: float, *, unit: str,
                  direction: str = "lower", dispersion: float = 0.0,
                  n: int = 1, **extra):
        """Append one metric row.  ``direction`` says which way is good
        ("higher" for tok/s, "lower" for latency); ``dispersion`` is the
        IQR (same unit as ``value``) from the adaptive timer."""
        if direction not in DIRECTIONS:
            raise ValueError(f"direction {direction!r} not in {DIRECTIONS}")
        self._start()
        rec = {
            "kind": "row", "run_id": self.run_id, "name": name,
            "value": float(value), "unit": unit, "direction": direction,
            "dispersion": float(dispersion), "n": int(n),
        }
        if extra:
            rec["extra"] = extra
        self._append(rec)
        self.rows_written += 1


def read_bench(path) -> list:
    """Parse a ``repro.obs.bench/v1`` file into a list of runs, oldest
    first: ``[{"run_id", "ts", "env", "rows": {name: row}}, ...]``.
    Raises ValueError on malformed records (perfcheck wants hard
    failures, not silent skips)."""
    runs = []
    by_id = {}
    with open(path) as f:
        for i, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError as e:
                raise ValueError(f"{path}:{i}: not JSON: {e}") from None
            err = validate_bench_record(rec)
            if err:
                raise ValueError(f"{path}:{i}: {err}")
            if rec["kind"] == "run":
                run = {"run_id": rec["run_id"], "ts": rec.get("ts"),
                       "env": rec.get("env", {}), "rows": {}}
                runs.append(run)
                by_id[rec["run_id"]] = run
            else:
                run = by_id.get(rec["run_id"])
                if run is None:
                    raise ValueError(
                        f"{path}:{i}: row for unknown run_id "
                        f"{rec['run_id']!r} (missing run header?)"
                    )
                run["rows"][rec["name"]] = rec
    return runs


def validate_bench_record(rec) -> Optional[str]:
    """One-record schema check; returns an error string or None.
    Stdlib-only — shared by ``read_bench`` and ``repro.obs.validate``."""
    if not isinstance(rec, dict):
        return "record is not an object"
    rec_kind = rec.get("kind")
    if rec_kind == "run":
        if rec.get("schema") != BENCH_SCHEMA:
            return f"run.schema != {BENCH_SCHEMA!r}: {rec.get('schema')!r}"
        if not isinstance(rec.get("run_id"), str) or not rec["run_id"]:
            return "run.run_id missing"
        env = rec.get("env")
        if not isinstance(env, dict):
            return "run.env missing"
        for key in ("git_sha", "jax_version", "backend", "device_count"):
            if key not in env:
                return f"run.env.{key} missing"
        return None
    if rec_kind == "row":
        for key, typ in (("run_id", str), ("name", str), ("unit", str),
                         ("value", (int, float)),
                         ("dispersion", (int, float)), ("n", int)):
            if not isinstance(rec.get(key), typ) or (
                typ is str and not rec[key]
            ):
                return f"row.{key} missing or mistyped"
            if typ == (int, float) and isinstance(rec[key], bool):
                return f"row.{key} missing or mistyped"
        if rec.get("direction") not in DIRECTIONS:
            return f"row.direction not in {DIRECTIONS}: " \
                   f"{rec.get('direction')!r}"
        return None
    return f"record.kind not in ('run', 'row'): {rec_kind!r}"
