"""Unified fault injection: named, deterministic injection points.

One registry serves every failure domain in the stack (DESIGN.md §12).
A component that owns an injection point calls ``plan.hit(point)`` (or
``plan.raise_if(point)``) exactly once per occurrence of the event the
point names; a ``FaultPlan`` decides — purely from a per-point hit
counter, never from wall clock or randomness — whether that occurrence
fires.  The same plan therefore produces the same failure schedule on
every run, which is what lets the chaos suite assert byte-identical
output for uninjected requests.

This replaces the ad-hoc ``FaultTolerantLoop.fail_at_step`` knob: the
training loop's step failure is now just one point (``train.step``) in
the same catalog the serving engine and checkpoint manager consume.

Catalog (``FAULT_POINTS``: point name -> owner's contract):

* ``drafter.propose``  — ``Engine._spec_round`` raises ``InjectedFault``
  in place of calling the drafter (a drafter crash; trips the engine's
  circuit breaker into plain block decode).
* ``engine.prefill``   — ``Engine.admit`` raises ``InjectedFault``
  before the prefill call (a per-request admission failure; ``run()``
  converts it to a ``GenResult.status == "error"``).
* ``engine.nan_state`` — ``Engine.step_block`` writes NaN into one
  slot's decode state before the block (``arg`` = slot index, default
  0); exercises poisoned-state quarantine.
* ``engine.slow_block``— ``Engine.step_block`` sleeps ``arg`` seconds
  (default 0.05) before the block; exercises request deadlines.
* ``cache.corrupt``    — the prefix/state cache flips bytes in one
  leaf of the entry a lookup is about to return; its checksum check
  must drop the entry and fall back to cold prefill
  (``serving/cache.py``).
* ``sched.stall``      — the scheduler refuses every admission for one
  drive-loop tick (``serving/scheduler.py``); exercises queue growth
  and queued-deadline expiry under scheduler pressure.
* ``ckpt.save``        — ``CheckpointManager``'s save work raises
  ``InjectedFault`` (in the async thread: surfaced on the next
  ``wait()``/``save()``).
* ``ckpt.corrupt``     — after an otherwise-successful save, bytes are
  flipped in one published leaf file; exercises manifest checksum
  verification on restore.
* ``train.step``       — ``FaultTolerantLoop`` raises ``InjectedFault``
  at the top of a training step (hit index == step index for a run
  starting from step 0 — the old ``fail_at_step`` semantics).
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Dict, List, Optional, Tuple

FAULT_POINTS: Dict[str, str] = {
    "drafter.propose": "drafter crash during a speculative round",
    "engine.prefill": "admission prefill failure for one request",
    "engine.nan_state": "NaN written into one slot's decode state "
                        "(arg = slot index)",
    "engine.slow_block": "slow decode block (arg = sleep seconds)",
    "cache.corrupt": "byte corruption of a prefix-cache entry at lookup",
    "sched.stall": "scheduler admits nothing for one drive-loop tick",
    "ckpt.save": "checkpoint save failure (async thread)",
    "ckpt.corrupt": "byte corruption of a saved checkpoint leaf",
    "train.step": "training step failure (the old fail_at_step)",
}


class InjectedFault(RuntimeError):
    """Raised by a firing injection point.  Deliberately a plain runtime
    error: consumers must survive it through the same isolation paths
    that handle organic failures, not by catching this type specially."""

    def __init__(self, point: str, hit: int):
        super().__init__(f"injected fault at point {point!r} (hit {hit})")
        self.point = point
        self.hit = hit


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault: fire at hits ``at .. at + times - 1`` of
    ``point`` (``times=None`` = every hit from ``at`` on).  ``arg`` is
    the point-specific payload (slot index, sleep seconds, ...)."""

    point: str
    at: int = 0
    times: Optional[int] = 1
    arg: Optional[float] = None

    def __post_init__(self):
        if self.point not in FAULT_POINTS:
            raise ValueError(
                f"unknown fault point {self.point!r}; registered points: "
                f"{sorted(FAULT_POINTS)}"
            )
        if self.at < 0 or (self.times is not None and self.times < 1):
            raise ValueError(f"need at >= 0 and times >= 1 (or None): {self}")

    def covers(self, hit: int) -> bool:
        return hit >= self.at and (
            self.times is None or hit < self.at + self.times
        )


def parse_fault(text: str) -> FaultSpec:
    """Parse the CLI syntax ``point[@at[+]][:arg]``.

    ``engine.nan_state@1:0``  — 2nd block, poison slot 0;
    ``drafter.propose@0+``    — crash every round from the first;
    ``engine.slow_block:0.2`` — sleep 0.2s at the first block only.
    """
    arg: Optional[float] = None
    if ":" in text:
        text, raw = text.split(":", 1)
        arg = float(raw)
    at, times = 0, 1
    if "@" in text:
        text, raw = text.split("@", 1)
        if raw.endswith("+"):
            times, raw = None, raw[:-1]
        at = int(raw)
    return FaultSpec(point=text, at=at, times=times, arg=arg)


class FaultPlan:
    """A deterministic failure schedule over the registered points.

    ``hit(point)`` records one occurrence and returns the ``FaultSpec``
    that fires at it (or None).  ``fired`` counts fires per point for
    test assertions.  Hitting (or scheduling) an unregistered point is a
    ``ValueError`` — typos fail loudly on both sides of the contract.

    When a component binds its ``obs`` bundle onto the plan (the engine
    and the training loop both do), every firing self-documents as a
    ``fault.fired`` event and a ``faults_fired_total{point=...}``
    counter — a chaos run's event log shows exactly which injections
    interleaved with which request lifecycles.
    """

    def __init__(self, *specs: FaultSpec, obs=None):
        self._by_point: Dict[str, List[FaultSpec]] = {}
        for spec in specs:
            if not isinstance(spec, FaultSpec):
                raise TypeError(f"expected FaultSpec, got {spec!r}")
            self._by_point.setdefault(spec.point, []).append(spec)
        self._hits: collections.Counter = collections.Counter()
        self.fired: collections.Counter = collections.Counter()
        self.obs = obs  # bound lazily by the consuming component

    def hit(self, point: str) -> Optional[FaultSpec]:
        if point not in FAULT_POINTS:
            raise ValueError(
                f"hit on unregistered fault point {point!r}; registered: "
                f"{sorted(FAULT_POINTS)}"
            )
        i = self._hits[point]
        self._hits[point] = i + 1
        for spec in self._by_point.get(point, ()):
            if spec.covers(i):
                self.fired[point] += 1
                if self.obs is not None:
                    self.obs.event("fault.fired", point=point, hit=i,
                                   arg=spec.arg)
                    self.obs.counter(
                        "faults_fired_total", "fired fault injections"
                    ).inc(point=point)
                return spec
        return None

    def raise_if(self, point: str) -> None:
        """``hit`` + raise ``InjectedFault`` when the hit fires."""
        if self.hit(point) is not None:
            raise InjectedFault(point, self._hits[point] - 1)

    def hits(self, point: str) -> int:
        return self._hits[point]

    def __repr__(self):
        scheduled: List[Tuple[str, int]] = [
            (p, len(s)) for p, s in sorted(self._by_point.items())
        ]
        return f"FaultPlan({scheduled}, fired={dict(self.fired)})"
