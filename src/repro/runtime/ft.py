"""Fault-tolerant training runtime.

``FaultTolerantLoop`` wraps a jitted train step with:

* auto-resume from the latest checkpoint (params + optimizer + data step);
* periodic async checkpoints with keep-N rotation;
* SIGTERM/SIGINT preemption handler — save-and-exit cleanly (maintenance
  events on cloud TPU pods deliver SIGTERM);
* a straggler/ hang watchdog: EWMA step time; a step slower than
  ``straggler_factor`` x EWMA logs a warning, and ``hang_timeout_s`` aborts
  the process non-zero so the cluster scheduler reschedules it;
* deterministic fault injection via ``runtime.faults`` (the
  ``train.step`` point — the old ad-hoc ``fail_at_step`` knob — plus the
  ``ckpt.*`` points, which the loop forwards to its
  ``CheckpointManager``), used by the restart and chaos tests;
* jsonl metrics logging.

Elastic rescale: on resume the checkpoint is re-placed under the *current*
mesh's shardings (see checkpoint.manager), so a job restarted on fewer /
more chips continues from the same logical state.
"""

from __future__ import annotations

import json
import os
import signal
import threading
import time
from typing import Callable, Optional

import jax
import numpy as np

from ..checkpoint.manager import CheckpointManager
from ..obs import Obs
from .faults import FaultPlan


class StragglerWatchdog:
    def __init__(self, factor: float = 3.0, hang_timeout_s: float = 1800.0,
                 log=print):
        self.factor = factor
        self.hang_timeout_s = hang_timeout_s
        self.ewma = None
        self.log = log
        self._timer: Optional[threading.Timer] = None

    def arm(self, step: int):
        self.disarm()

        def _abort():
            self.log(
                f"[watchdog] step {step} exceeded hang timeout "
                f"{self.hang_timeout_s}s — aborting for reschedule"
            )
            os._exit(42)

        self._timer = threading.Timer(self.hang_timeout_s, _abort)
        self._timer.daemon = True
        self._timer.start()

    def disarm(self):
        if self._timer is not None:
            self._timer.cancel()
            self._timer = None

    def observe(self, step: int, dt: float):
        self.disarm()
        if self.ewma is None:
            self.ewma = dt
        elif dt > self.factor * self.ewma:
            self.log(
                f"[watchdog] step {step} took {dt:.2f}s "
                f"(> {self.factor:.1f}x EWMA {self.ewma:.2f}s) — straggler"
            )
        self.ewma = 0.9 * self.ewma + 0.1 * dt if self.ewma else dt


class FaultTolerantLoop:
    def __init__(
        self,
        train_step: Callable,  # (params, opt_state, batch) -> (p, o, metrics)
        data_stream,  # has .batch(step) -> host batch dict
        ckpt_dir: str,
        *,
        ckpt_every: int = 100,
        keep: int = 3,
        metrics_path: Optional[str] = None,
        faults: Optional[FaultPlan] = None,
        log=print,
        place_batch: Optional[Callable] = None,
        obs: Optional[Obs] = None,
    ):
        self.train_step = train_step
        self.data = data_stream
        self.faults = faults
        # one obs bundle threads through the whole training stack: the
        # loop, its checkpoint manager, and the fault plan all report
        # into the same registry/tracer (DESIGN.md §13)
        self.obs = obs if obs is not None else Obs()
        if faults is not None and faults.obs is None:
            faults.obs = self.obs
        self.manager = CheckpointManager(ckpt_dir, keep=keep, faults=faults,
                                         obs=self.obs)
        self._m_step_s = self.obs.histogram(
            "train_step_seconds", "wall-clock per optimizer step")
        self._m_steps = self.obs.counter(
            "train_steps_total", "completed optimizer steps")
        self._m_tokens = self.obs.counter(
            "train_tokens_total", "tokens consumed by completed steps")
        self._m_loss = self.obs.gauge("train_loss", "last step's loss")
        self._m_restarts = self.obs.counter(
            "train_restarts_total", "checkpoint auto-resumes on entry")
        self.ckpt_every = ckpt_every
        self.metrics_path = metrics_path
        self.log = log
        self.place_batch = place_batch or (lambda b: b)
        self.watchdog = StragglerWatchdog(log=log)
        self._preempted = False

    def _install_signal_handlers(self):
        def handler(signum, frame):
            self.log(f"[ft] received signal {signum}: checkpoint-and-exit")
            self._preempted = True

        for sig in (signal.SIGTERM, signal.SIGINT):
            try:
                signal.signal(sig, handler)
            except ValueError:
                pass  # non-main thread (tests)

    def run(self, params, opt_state, num_steps: int):
        self._install_signal_handlers()
        start = 0
        latest = self.manager.latest_step()
        if latest is not None:
            (params, opt_state), manifest = self.manager.restore(
                (params, opt_state)
            )
            start = manifest["step"] + 1
            self._m_restarts.inc()
            self.obs.event("train.resumed", step=manifest["step"])
            self.log(f"[ft] resumed from step {manifest['step']}")

        mf = open(self.metrics_path, "a") if self.metrics_path else None
        step = start
        try:
            for step in range(start, num_steps):
                # hit index == step index on a fresh run from step 0;
                # after a resume, hits restart at 0 while steps don't, so
                # FaultSpec(at=N) means "the Nth step THIS process runs"
                if self.faults is not None:
                    self.faults.raise_if("train.step")
                host_batch = self.data.batch(step)
                batch = self.place_batch(host_batch)
                self.watchdog.arm(step)
                t0 = time.time()
                # the span closes on the device_get the loop already
                # performs to read the step's metrics — no extra sync
                with self.obs.span("train.step", step=step):
                    params, opt_state, metrics = self.train_step(
                        params, opt_state, batch
                    )
                    metrics = {
                        k: float(v)
                        for k, v in jax.device_get(metrics).items()
                    }
                dt = time.time() - t0
                self.watchdog.observe(step, dt)
                self._m_step_s.observe(dt)
                self._m_steps.inc()
                if isinstance(host_batch, dict) and "tokens" in host_batch:
                    self._m_tokens.inc(
                        int(np.asarray(host_batch["tokens"]).size)
                    )
                if "loss" in metrics:
                    self._m_loss.set(metrics["loss"])
                metrics.update(step=step, step_time_s=round(dt, 4))
                if mf:
                    mf.write(json.dumps(metrics) + "\n")
                    mf.flush()
                if step % 10 == 0:
                    self.log(
                        f"[train] step {step} loss {metrics.get('loss', 0):.4f} "
                        f"({dt:.2f}s)"
                    )
                if (step + 1) % self.ckpt_every == 0 or self._preempted:
                    self.manager.save(step, (params, opt_state))
                if self._preempted:
                    self.log("[ft] preemption checkpoint written; exiting")
                    break
        finally:
            self.watchdog.disarm()
            self.manager.wait()
            if mf:
                mf.close()
        return params, opt_state, step
