"""Continuous-batching inference engine over the streaming-state models.

The serving pattern the paper's O(1)-state decode enables (DESIGN.md §8):

* **Admission = chunk-parallel prefill.**  A new prompt runs through
  ``lm.lm_prefill`` — per layer ONE chunkwise kernel call (the stateful
  Pallas kernel on TPU) that returns the exact streaming state by the
  Section-4 identity — then the state is scatter-written into its slot.
  No per-token Python loop, no device round-trip per prompt token, and no
  touching of other slots' states (the pool write is a single
  ``dynamic_update_slice`` per leaf).
* **Decode = step-locked device blocks.**  All slots advance together
  through a jitted ``lax.scan`` of ``block`` fused decode steps with
  device-side sampling; generated tokens accumulate on device and transfer
  to the host ONCE per block (vs. one ``int(...)`` sync per slot per step).
  Inactive slots ride along masked (their sampled tokens are discarded and
  their positions frozen); their stale states are overwritten at the next
  admission.
* **Speculative decode (``spec=``)** swaps the block for a
  draft -> verify -> accept round (DESIGN.md §10): a ``Drafter`` proposes
  k tokens per active slot (batched), then ONE jitted round
  (``spec.verify.make_spec_round``) scores all of them chunk-parallel,
  commits accepted tokens in bulk — up to k+1 tokens per round for the
  serial cost of one wide prefill — and, on rejection only (a
  ``lax.cond`` arm), rolls the pool back to the pre-verify states
  advanced by each slot's accepted prefix, so speculative greedy decode
  is token-for-token identical to plain greedy decode.
  ``StatePool.snapshot_slot``/``restore_slot`` expose the same O(state)
  rollback primitive at the host level (external schedulers,
  preemption, tests).  One host sync per round, as in the plain block
  path.
* **Failure domains (DESIGN.md §12).**  The slot is the unit of failure,
  exactly because the paper's state is constant-size: quarantining a
  poisoned request is one O(1) scatter (``StatePool.reset_slot``), not a
  paged-KV reconstruction.  Every per-request failure — invalid
  admission, a non-finite slot state (detected by a fused finiteness
  reduction riding the block's existing host sync), an expired
  ``deadline_s``, an ``Engine.cancel`` — frees only its own slot and
  becomes a ``GenResult.status`` (``ok``/``error``/``timeout``/
  ``cancelled``); ``run()`` never raises out of its drive loop (a CI
  guard enforces this).  Drafter failures trip a circuit breaker from
  speculative to plain block decode — which preserves greedy output
  token-for-token, since both paths emit the same argmax stream — with a
  cooldown/half-open re-probe to recover.  All failure modes are
  injectable deterministically via ``runtime.faults.FaultPlan``.

KV-cache (softmax / hybrid) archs are rejected: their pooled cache keeps a
*shared* scalar ``length``, so per-slot admission would need per-slot
lengths threaded through attention — a follow-up, not a serving-engine
concern (the HLA family is the paper's point).
"""

from __future__ import annotations

import collections
import collections.abc
import contextlib
import dataclasses
import math
import time
from typing import Dict, List, Optional, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..models import lm, seq_op
from ..obs import Obs
from ..runtime.faults import FaultPlan
from .cache import PrefixCache
from .sampling import SamplingConfig, sample
from .scheduler import Scheduler, SchedulerConfig
from .spec import SpecConfig, build_drafter
from .spec.verify import make_spec_round
from .state_pool import StatePool, tree_finite, tree_finite_host

#: legacy ``Engine.stats`` keys -> unlabeled registry counters
_STATS_COUNTERS = {
    "prefill_s": "serving_prefill_seconds_total",
    "decode_s": "serving_decode_seconds_total",
    "prompt_tokens": "serving_prompt_tokens_total",
    "generated_tokens": "serving_generated_tokens_total",
    "spec_rounds": "serving_spec_rounds_total",
    "spec_drafted": "serving_spec_drafted_total",
    "spec_accepted": "serving_spec_accepted_total",
    "spec_replays": "serving_spec_replay_rounds_total",
    "quarantined": "serving_quarantined_total",
    "breaker_trips": "serving_breaker_trips_total",
}
#: legacy keys that were request-status tallies -> the status label on
#: ``serving_requests_total``
_STATS_STATUS = {"errors": "error", "timeouts": "timeout",
                 "cancelled": "cancelled"}
#: legacy keys holding float seconds (everything else was an int count)
_STATS_FLOAT = frozenset(("prefill_s", "decode_s"))


class _StatsShim(collections.abc.MutableMapping):
    """DEPRECATED dict view of the engine's metrics (DESIGN.md §13).

    The old ad-hoc ``Engine.stats`` dict is now backed by the obs
    registry: reads compute from the live metric series, writes forward
    to them (``stats.update(decode_s=0.0, ...)`` resets, as the old
    warmup code relied on).  ``stats["ttft_s"]`` returns the TTFT
    histogram's BOUNDED recent-sample reservoir, not an unbounded list —
    under sustained traffic it holds the newest ``sample_cap`` values.
    New code should use ``engine.obs`` directly.
    """

    def __init__(self, obs: Obs):
        self._obs = obs

    def _keys(self):
        return list(_STATS_COUNTERS) + list(_STATS_STATUS) + ["ttft_s"]

    def __getitem__(self, key):
        if key == "ttft_s":
            return self._obs.registry.get("serving_ttft_seconds").recent()
        if key in _STATS_STATUS:
            return int(self._obs.registry.get("serving_requests_total")
                       .value(status=_STATS_STATUS[key]))
        name = _STATS_COUNTERS[key]
        total = self._obs.registry.get(name).total()
        return total if key in _STATS_FLOAT else int(total)

    def __setitem__(self, key, value):
        if key == "ttft_s":
            hist = self._obs.registry.get("serving_ttft_seconds")
            hist.reset()
            for v in value:
                hist.observe(float(v))
            return
        if key in _STATS_STATUS:
            self._obs.registry.get("serving_requests_total")._set(
                float(value), status=_STATS_STATUS[key]
            )
            return
        self._obs.registry.get(_STATS_COUNTERS[key])._set(float(value))

    def __delitem__(self, key):
        raise TypeError("Engine.stats keys are fixed")

    def __iter__(self):
        return iter(self._keys())

    def __len__(self):
        return len(self._keys())

    def __repr__(self):
        return f"EngineStats({dict(self)})"


@dataclasses.dataclass
class GenRequest:
    rid: int
    prompt: np.ndarray  # (L,) int token ids
    max_new: int = 32
    eos_id: Optional[int] = None
    # wall-clock budget in seconds, measured from submission (run() entry
    # or direct admit()).  Checked once per block on the host — expiry
    # finishes the slot with status="timeout" and the partial stream.
    deadline_s: Optional[float] = None
    # per-request sampling override (None = the engine's default).  The
    # decode block re-traces when the SET of distinct configs across slots
    # changes; homogeneous traffic stays at one trace.
    sampling: Optional[SamplingConfig] = None
    # scheduler policy inputs (DESIGN.md §16): lower priority numbers
    # drain first; tenants within a priority class share slots fairly.
    priority: int = 1
    tenant: str = "default"


@dataclasses.dataclass
class GenResult:
    rid: int
    tokens: List[int]
    ttft_s: float  # admission -> first sampled token
    prompt_len: int
    # "ok" | "error" | "timeout" | "cancelled".  Non-ok results keep the
    # partial stream committed before the failure (possibly empty).
    status: str = "ok"
    error: Optional[str] = None


class Engine:
    """Slot-based continuous batching over a ``StatePool``."""

    def __init__(
        self,
        cfg,
        params,
        *,
        slots: int = 4,
        max_len: int = 4096,
        sampling: SamplingConfig = SamplingConfig(),
        block: int = 8,
        seed: int = 0,
        mesh=None,
        spec: Optional[SpecConfig] = None,
        faults: Optional[FaultPlan] = None,
        obs: Optional[Obs] = None,
        cache: Optional[PrefixCache] = None,
        sched: Optional[SchedulerConfig] = None,
    ):
        # serveability is a REGISTRY capability, not a hardcoded tuple:
        # any op registered with streaming=True (O(1) decode state) admits
        # per-slot continuous batching; KV-cache ops (attn) and hybrid
        # stacks share a pooled scalar length across slots and cannot.
        op = seq_op.op_for(cfg)
        if not op.streaming or cfg.group_size:
            raise ValueError(
                "Engine serves streaming-state ops "
                f"{seq_op.streaming_op_names()}; op {op.name!r} "
                f"(group_size={cfg.group_size}) decodes from a KV cache "
                "whose pooled scalar length is shared across slots — "
                "continuous batching needs per-slot lengths"
            )
        if spec is not None and not op.spec_decodable:
            raise ValueError(
                f"op {op.name!r} is not registered spec_decodable: its "
                "state cannot be snapshot/rolled back for speculative "
                "verification"
            )
        self.cfg = cfg
        self.params = params
        self.sampling = sampling
        self.block = block
        self.max_len = max_len
        self.mesh = mesh
        self.spec = spec
        self.faults = faults
        # slot-count autoscaling (DESIGN.md §16): the pool is allocated
        # at the scheduler's max_slots once; the autoscaler varies how
        # many of those physical slots admissions may fill.  Without a
        # scheduler config the engine behaves exactly as before: a
        # fixed-``slots`` FIFO (same-priority single-tenant ordering is
        # arrival order).
        if sched is not None:
            slots = sched.max_slots
        self.sched_cfg = sched if sched is not None else SchedulerConfig(
            min_slots=slots, max_slots=slots
        )
        # sharded serving: slot states get explicit shardings (slots on
        # the data axis, heads on the model axis) from the same source of
        # truth the train/dry-run steps use — never a replicated tree.
        pool_shardings = None
        if mesh is not None:
            from ..distributed import steps as steps_mod

            abstract = jax.eval_shape(
                lambda: lm.lm_init_states(cfg, slots, max_len)
            )
            pool_shardings = steps_mod.state_shardings_for(
                cfg, mesh, abstract
            )
        self.pool = StatePool(
            lambda n: lm.lm_init_states(cfg, n, max_len), slots,
            shardings=pool_shardings,
        )
        self.tokens = jnp.zeros((slots, 1), jnp.int32)
        self.positions = jnp.zeros((slots, 1), jnp.int32)
        self.active = np.zeros(slots, bool)
        self._slot_req: List[Optional[GenRequest]] = [None] * slots
        self._slot_out: List[List[int]] = [[] for _ in range(slots)]
        self._slot_ttft: List[float] = [0.0] * slots
        self._slot_scfg: List[SamplingConfig] = [sampling] * slots
        self._slot_deadline: List[float] = [math.inf] * slots
        self._enqueue_t: Dict[int, float] = {}
        self._cancelled: Set[int] = set()
        self._popped: Set[int] = set()  # rids holding a fair-share ticket
        self.results: Dict[int, GenResult] = {}
        # per-token streaming hook (serving/server.py): called on the
        # drive loop with (rid, new_tokens, result-or-None) after every
        # commit and once at the terminal result.  Must not raise.
        self.on_stream = None
        self.key = jax.random.key(seed)
        # spec circuit breaker: closed (speculating) -> open (plain
        # blocks, counting down cooldown) -> half_open (one probe round)
        self.breaker = {"state": "closed", "cooldown": 0, "zero_rounds": 0,
                        "reason": None}
        # observability (DESIGN.md §13): every number the engine reports
        # goes through one registry + tracer bundle.  All timings are
        # host wall-clock taken at syncs the engine already performs
        # (admission TTFT fetch, the once-per-block token transfer) — the
        # obs layer never adds a device round trip.
        self.obs = obs if obs is not None else Obs()
        m = self.obs
        self._m_ttft = m.histogram(
            "serving_ttft_seconds", "admission -> first sampled token")
        self._m_itl = m.histogram(
            "serving_inter_token_seconds",
            "decode block wall-clock / tokens stepped (one observation "
            "per block/round — never per-token host timing)")
        self._m_prefill_s = m.counter(
            "serving_prefill_seconds_total", "wall-clock in admissions")
        self._m_decode_s = m.counter(
            "serving_decode_seconds_total",
            "wall-clock in decode blocks / spec rounds")
        self._m_prompt_toks = m.counter(
            "serving_prompt_tokens_total", "prompt tokens prefilled")
        self._m_gen_toks = m.counter(
            "serving_generated_tokens_total", "tokens in terminal streams")
        self._m_requests = m.counter(
            "serving_requests_total", "terminal results by status label")
        self._m_quarantined = m.counter(
            "serving_quarantined_total", "slots reset on non-finite state")
        self._m_breaker = m.counter(
            "serving_breaker_trips_total", "spec -> plain breaker trips")
        self._m_spec_rounds = m.counter(
            "serving_spec_rounds_total", "completed speculative rounds")
        self._m_spec_drafted = m.counter(
            "serving_spec_drafted_total", "draft tokens proposed")
        self._m_spec_accepted = m.counter(
            "serving_spec_accepted_total", "draft tokens accepted")
        self._m_spec_replays = m.counter(
            "serving_spec_replay_rounds_total", "rounds with a rollback")
        self._m_queue = m.gauge(
            "serving_queue_depth", "requests waiting for a slot")
        self._m_slots = m.gauge(
            "serving_slots_active", "slots currently decoding")
        self.stats = _StatsShim(self.obs)  # legacy dict view (DEPRECATED)
        # serving front-end (DESIGN.md §16): the admission scheduler owns
        # queue order + the slot target; the optional prefix/state cache
        # turns shared prompt prefixes into O(1) snapshot resumes.  Build
        # the cache with THIS engine's obs bundle so its hit/miss/bytes
        # counters land in the same registry snapshot.
        self.scheduler = Scheduler(self.sched_cfg, obs=self.obs,
                                   faults=faults)
        self.cache = cache
        if cache is not None and cache._own_obs:
            cache.bind_obs(self.obs)
        self._m_ttft_cold = m.histogram(
            "serving_ttft_cold_seconds", "TTFT of cache-miss admissions")
        self._m_ttft_hit = m.histogram(
            "serving_ttft_hit_seconds",
            "TTFT of admissions resumed from a cached prefix snapshot")
        self._m_ttft_saved = m.histogram(
            "serving_cache_ttft_saved_seconds",
            "estimated prefill wall-clock avoided per cache hit "
            "(cached prefix tokens x EWMA cold prefill s/token)")
        # EWMA of cold prefill seconds/token — the TTFT-saved estimator
        self._prefill_s_per_tok: Optional[float] = None

        pool = self.pool

        def _prefill(params, prompt, key, scfg):
            last_logits, states = lm.lm_prefill(params, prompt, cfg)
            tok = sample(last_logits, key, scfg)
            # admission health check: rides the sync that already fetches
            # the first sampled token (no extra round trip)
            finite = tree_finite(states) & jnp.all(
                jnp.isfinite(last_logits)
            )
            return tok, states, finite

        def _prefill_from(params, prompt, positions, states, key, scfg):
            # suffix prefill resumed from a cached prefix snapshot: exact
            # by the chunkwise carry identity (DESIGN.md §8/§16) — the
            # same ``lm_prefill(states=...)`` carry the spec verifier and
            # the incremental-prefill tests already rely on
            last_logits, states = lm.lm_prefill(
                params, prompt, cfg, states=states, positions=positions
            )
            tok = sample(last_logits, key, scfg)
            finite = tree_finite(states) & jnp.all(
                jnp.isfinite(last_logits)
            )
            return tok, states, finite

        def _carry_cold(params, prompt):
            # prompt[:aligned] -> the chunk-boundary state the cache keeps
            _, states = lm.lm_prefill(params, prompt, cfg)
            return states

        def _carry_from(params, prompt, positions, states):
            _, states = lm.lm_prefill(
                params, prompt, cfg, states=states, positions=positions
            )
            return states

        def _decode_block(params, states, tokens, positions, active, key,
                          sel, n_steps, scfgs):
            # scfgs: the (static) canonically-ordered DISTINCT sampling
            # configs; sel: traced (slots,) index into them.  Sampling once
            # per distinct config keeps homogeneous traffic at the old
            # single-sampler cost, and keying the jit on the distinct SET
            # (not the per-slot assignment) means slot churn never
            # recompiles — only genuinely new configs do.
            def body(carry, _):
                states, tok, pos, key = carry
                logits, states, _ = lm.lm_apply(
                    params, tok, cfg, states=states, positions=pos,
                    mode="decode",
                )
                key, *subs = jax.random.split(key, len(scfgs) + 1)
                cand = jnp.stack(
                    [sample(logits[:, -1], sk, c)
                     for c, sk in zip(scfgs, subs)]
                )  # (n_uniq, slots)
                nxt = jnp.take_along_axis(cand, sel[None, :], axis=0)[0]
                tok = jnp.where(active[:, None], nxt[:, None], tok)
                pos = pos + active[:, None].astype(pos.dtype)
                return (states, tok, pos, key), nxt

            (states, tok, pos, _), toks = jax.lax.scan(
                body, (states, tokens, positions, key), length=n_steps
            )
            if pool_shardings is not None:
                # pin the block's state output to the pool layout — the
                # scatter writes pin admissions, this pins the hot path,
                # so GSPMD never drifts the pool and re-lowers
                states = jax.tree.map(
                    jax.lax.with_sharding_constraint, states, pool_shardings
                )
            # fused per-slot finiteness reduction over the post-block
            # states: the quarantine flags ride the block's one host sync
            finite = pool.finite_mask(states)
            return states, tok, pos, toks, finite  # toks: (n_steps, slots)

        self._prefill = jax.jit(_prefill, static_argnames="scfg")
        self._prefill_from = jax.jit(_prefill_from, static_argnames="scfg")
        self._carry_cold = jax.jit(_carry_cold)
        self._carry_from = jax.jit(_carry_from)
        self._decode_block = jax.jit(
            _decode_block, static_argnames=("n_steps", "scfgs")
        )

        if spec is not None:
            self.drafter = build_drafter(
                spec, slots=slots, max_len=max_len, sampling=sampling,
                mesh=mesh, target_cfg=cfg,
            )
            if self.drafter.vocab is not None and \
                    self.drafter.vocab != cfg.vocab:
                raise ValueError(
                    f"drafter vocab {self.drafter.vocab} != target vocab "
                    f"{cfg.vocab}: draft ids would index the target "
                    "embedding out of range"
                )
            self._spec_step = jax.jit(make_spec_round(
                cfg, sampling, draft_probs=self.drafter.emits_probs,
                pool_shardings=pool_shardings,
            ))
        else:
            self.drafter = None

    def _mesh_ctx(self):
        """Activate the engine's mesh (mixer shard_map dispatch + logical
        sharding constraints resolve against the ambient mesh)."""
        return self.mesh if self.mesh is not None else (
            contextlib.nullcontext()
        )

    # -- fault injection ----------------------------------------------------

    def _bind_faults(self) -> Optional[FaultPlan]:
        """Fired injections self-document through the engine's tracer
        (the plan may be attached after construction, e.g. post-warmup).
        The scheduler (``sched.stall``) and prefix cache
        (``cache.corrupt``) share the engine's plan so one ``--inject``
        schedule covers the whole front-end."""
        if self.faults is not None and self.faults.obs is None:
            self.faults.obs = self.obs
        self.scheduler.faults = self.faults
        if self.cache is not None and self.cache.faults is None:
            self.cache.faults = self.faults
        return self.faults

    def _raise_fault(self, point: str) -> None:
        if self._bind_faults() is not None:
            self.faults.raise_if(point)

    def _inject_block_faults(self) -> None:
        """Hit the once-per-block injection points (no-ops without a plan)."""
        if self._bind_faults() is None:
            return
        slow = self.faults.hit("engine.slow_block")
        if slow is not None:
            time.sleep(slow.arg if slow.arg is not None else 0.05)
        nan = self.faults.hit("engine.nan_state")
        if nan is not None:
            slot = int(nan.arg) if nan.arg is not None else 0
            poison = jax.tree.map(
                lambda x: jnp.full_like(x, jnp.nan)
                if jnp.issubdtype(x.dtype, jnp.inexact) else x,
                self.pool.read_slot(slot),
            )
            self.pool.write_slot(slot, poison)

    # -- admission ----------------------------------------------------------

    def free_slots(self) -> List[int]:
        return [s for s in range(self.pool.slots) if not self.active[s]]

    def _validate(self, req: GenRequest) -> np.ndarray:
        """Admission control: reject malformed requests before they touch
        the pool.  Returns the prompt as an int32 array."""
        prompt = np.asarray(req.prompt)
        if prompt.ndim != 1 or prompt.size == 0:
            raise ValueError(
                f"request {req.rid}: prompt must be a non-empty 1-D token "
                f"array, got shape {prompt.shape}"
            )
        if not np.issubdtype(prompt.dtype, np.integer):
            raise ValueError(
                f"request {req.rid}: prompt dtype {prompt.dtype} is not "
                "integer token ids"
            )
        lo, hi = int(prompt.min()), int(prompt.max())
        if lo < 0 or hi >= self.cfg.vocab:
            raise ValueError(
                f"request {req.rid}: token ids [{lo}, {hi}] outside the "
                f"vocab [0, {self.cfg.vocab})"
            )
        if req.max_new < 1:
            raise ValueError(f"request {req.rid}: max_new must be >= 1")
        if len(prompt) + req.max_new > self.max_len:
            raise ValueError(
                f"request {req.rid}: prompt ({len(prompt)}) + max_new "
                f"({req.max_new}) exceeds the engine's max_len "
                f"{self.max_len}"
            )
        return prompt.astype(np.int32)

    def admit(self, slot: int, req: GenRequest) -> int:
        """Prefill ``req`` into ``slot``; returns the first sampled token.

        Cold path: ONE chunk-parallel prefill call + one scatter write.
        With a prefix cache attached (DESIGN.md §16) admission becomes:
        longest-prefix lookup -> resume from the cached O(1) snapshot
        and prefill only the uncached suffix (exact by the chunkwise
        carry identity) -> snapshot the longest chunk-aligned prompt
        boundary for future requests.  Live slots are never read or
        written.  Raises on invalid requests and on prefill failure —
        everything that can raise happens BEFORE the slot is activated,
        so a failed admission leaves the engine untouched (``run()``
        converts the raise into a ``status="error"`` result).
        """
        if self.active[slot]:
            raise ValueError(f"slot {slot} is busy")
        prompt_np = self._validate(req)
        scfg = req.sampling if req.sampling is not None else self.sampling
        if self.spec is not None and scfg != self.sampling:
            raise ValueError(
                "speculative mode verifies against ONE sampling law; "
                "per-request overrides would need per-slot accept rules "
                f"(engine={self.sampling}, request={scfg})"
            )
        t0 = time.perf_counter()
        L = len(prompt_np)
        hit_len = 0
        insert_at = 0
        carry_state = None
        with self.obs.span("engine.prefill", rid=req.rid, slot=slot,
                           prompt_len=L):
            self._raise_fault("engine.prefill")
            self.key, sub = jax.random.split(self.key)
            prompt = jnp.asarray(prompt_np[None])
            done = 0  # tokens already summarized into carry_state
            if self.cache is not None:
                self._bind_faults()  # cache.corrupt may fire in lookup
                found = self.cache.lookup(prompt_np, max_prefix=L - 1)
                if found is not None:
                    hit_len, host_snap = found
                    done, carry_state = hit_len, host_snap
                aligned = self.cache.aligned_len(L)
                if aligned > done:
                    # advance to the chunk-aligned boundary first so its
                    # state can be cached for future shared prefixes;
                    # still chunk-parallel (one extra kernel call, both
                    # calls together cover the prompt exactly once)
                    seg = prompt[:, done:aligned]
                    with self._mesh_ctx():
                        if done == 0:
                            carry_state = self._carry_cold(self.params, seg)
                        else:
                            carry_state = self._carry_from(
                                self.params, seg,
                                jnp.arange(done, aligned)[None],
                                carry_state,
                            )
                    done, insert_at = aligned, aligned
            with self._mesh_ctx():
                if done == 0:
                    first, state1, finite = self._prefill(
                        self.params, prompt, sub, scfg
                    )
                else:
                    first, state1, finite = self._prefill_from(
                        self.params, prompt[:, done:],
                        jnp.arange(done, L)[None], carry_state, sub, scfg,
                    )
                self.pool.write_slot(slot, state1)
            # one sync per admission (TTFT endpoint); the health flag —
            # and, on insertion admissions, the host copy of the
            # boundary snapshot — ride it, and the span closes right
            # after this existing sync
            fetch = (first[0], finite) if insert_at == 0 else (
                first[0], finite, carry_state)
            got = jax.device_get(fetch)  # sync-point: admission TTFT endpoint
            first_host, finite_host = got[0], got[1]
        if not bool(finite_host):
            self._m_quarantined.inc()
            self.pool.reset_slot(slot)
            raise RuntimeError(
                f"request {req.rid}: admission prefill produced a "
                "non-finite state — slot quarantined"
            )
        if insert_at and tree_finite_host(got[2]):
            # insert-on-prefill-complete, AFTER the health gate: a
            # poisoned boundary state must never become a cache entry
            self.cache.insert(prompt_np[:insert_at], got[2])
        first_tok = int(first_host)
        ttft = time.perf_counter() - t0
        if hit_len:
            self._m_ttft_hit.observe(ttft)
            if self._prefill_s_per_tok is not None:
                self._m_ttft_saved.observe(
                    hit_len * self._prefill_s_per_tok)
        else:
            self._m_ttft_cold.observe(ttft)
            rate = ttft / L
            self._prefill_s_per_tok = rate if \
                self._prefill_s_per_tok is None else (
                    0.9 * self._prefill_s_per_tok + 0.1 * rate)
        self.tokens = self.tokens.at[slot, 0].set(first_tok)
        self.positions = self.positions.at[slot, 0].set(len(prompt_np))
        self.active[slot] = True
        self._slot_req[slot] = req
        self._slot_out[slot] = []
        self._slot_ttft[slot] = ttft
        self._slot_scfg[slot] = scfg
        t_start = self._enqueue_t.pop(req.rid, t0)
        self._slot_deadline[slot] = (
            t_start + req.deadline_s if req.deadline_s is not None
            else math.inf
        )
        self._m_prefill_s.inc(ttft)
        self._m_prompt_toks.inc(len(prompt_np))
        self._m_ttft.observe(ttft)
        self._m_slots.set(float(self.active.sum()))
        self.obs.event("request.admitted", rid=req.rid, slot=slot,
                       prompt_len=len(prompt_np), cached_prefix=hit_len)
        self.obs.event("request.first_token", rid=req.rid,
                       ttft_s=round(ttft, 6))
        # the admission token goes through the ONE commit path, so a
        # first-token EOS or max_new=1 finishes here instead of wasting a
        # full decode block on an already-complete request
        finished = self._commit(slot, [first_tok])
        if not finished and self.drafter is not None \
                and self.breaker["state"] == "closed":
            try:
                self.drafter.admit(
                    slot, [int(t) for t in prompt_np] + [first_tok]
                )
            except Exception as e:  # drafter failure never fails admission
                self._trip_breaker(f"drafter.admit failed: {e!r}")
        return first_tok

    def _commit(self, slot: int, toks) -> bool:
        """Append generated tokens to ``slot``'s stream with max_new/eos
        truncation; finish the slot when its stop condition hits.  The
        ONE place commit semantics live — plain blocks and speculative
        rounds must truncate identically or their streams diverge.
        Returns True when the slot finished (and was freed)."""
        req = self._slot_req[slot]
        out = self._slot_out[slot]
        n_before = len(out)
        for t in toks:
            if len(out) >= req.max_new or (
                req.eos_id is not None and out and out[-1] == req.eos_id
            ):
                break
            out.append(int(t))
        self._emit_stream(req.rid, out[n_before:], None)
        if len(out) >= req.max_new or (
            req.eos_id is not None and req.eos_id in out
        ):
            self._finish(slot)
            return True
        return False

    def _emit_stream(self, rid: int, toks: List[int],
                     result: Optional[GenResult]) -> None:
        """Feed the per-token streaming hook (serving/server.py).  A
        broken hook must not poison the drive loop: its error is logged
        as an event and streaming is disabled for the rest of the run."""
        if self.on_stream is None:
            return
        try:
            self.on_stream(rid, toks, result)
        except Exception as e:  # pragma: no cover - defensive
            self.obs.event("stream.hook_error", rid=rid, error=repr(e))
            self.on_stream = None

    def _finish(self, slot: int, status: str = "ok",
                error: Optional[str] = None) -> None:
        req = self._slot_req[slot]
        out = self._slot_out[slot][: req.max_new]
        if req.eos_id is not None and req.eos_id in out:
            out = out[: out.index(req.eos_id) + 1]
        self.results[req.rid] = GenResult(
            rid=req.rid, tokens=out, ttft_s=self._slot_ttft[slot],
            prompt_len=len(req.prompt), status=status, error=error,
        )
        self._m_requests.inc(status=status)
        self._m_gen_toks.inc(len(out))
        self.obs.event("request.done", rid=req.rid, status=status,
                       tokens=len(out),
                       ttft_s=round(self._slot_ttft[slot], 6))
        if req.rid in self._popped:
            self.scheduler.release(req)  # return the tenant's fair share
            self._popped.discard(req.rid)
        self._emit_stream(req.rid, [], self.results[req.rid])
        self.active[slot] = False
        self._m_slots.set(float(self.active.sum()))
        self._slot_req[slot] = None
        self._slot_deadline[slot] = math.inf
        # drop any per-request sampling override so the freed slot stops
        # contributing a stale config to the decode block's distinct set
        self._slot_scfg[slot] = self.sampling
        if self.drafter is not None:
            self.drafter.evict(slot)

    def _fail(self, req: GenRequest, status: str, error: str) -> None:
        """Record a terminal result for a request that never held a slot
        (failed admission / pre-admission expiry / queued cancellation)."""
        self._enqueue_t.pop(req.rid, None)
        self.results[req.rid] = GenResult(
            rid=req.rid, tokens=[], ttft_s=0.0,
            prompt_len=len(np.atleast_1d(np.asarray(req.prompt))),
            status=status, error=error,
        )
        self._m_requests.inc(status=status)
        self.obs.event("request.done", rid=req.rid, status=status,
                       tokens=0, ttft_s=0.0)
        if req.rid in self._popped:
            self.scheduler.release(req)
            self._popped.discard(req.rid)
        self._emit_stream(req.rid, [], self.results[req.rid])

    def _quarantine(self, slot: int) -> None:
        """A slot's state went non-finite: reset the state (O(state), one
        scatter — untouched neighbours keep decoding) and fail only that
        request.  The paper's constant-size state is what makes this the
        cheap path: recovery never reconstructs a KV arena."""
        self._m_quarantined.inc()
        self.pool.reset_slot(slot)
        self._finish(
            slot, status="error",
            error="non-finite decode state: slot quarantined and reset",
        )

    # -- lifecycle ----------------------------------------------------------

    def cancel(self, rid: int) -> bool:
        """Cancel a request: a live slot finishes immediately with
        ``status="cancelled"`` and its partial stream; a scheduler-queued
        rid is dropped from the queue and finalized at once; an unknown
        rid is marked and rejected at its admission attempt.  Returns
        False when the request already finished (nothing to cancel)."""
        for s in range(self.pool.slots):
            req = self._slot_req[s]
            if self.active[s] and req is not None and req.rid == rid:
                self._finish(s, status="cancelled",
                             error="cancelled while decoding")
                return True
        queued = self.scheduler.cancel(rid)
        if queued is not None:
            self._fail(queued, "cancelled", "cancelled while queued")
            return True
        if rid in self.results:
            return False
        self._cancelled.add(rid)
        return True

    def _expired(self, req: GenRequest) -> bool:
        if req.deadline_s is None:
            return False
        t0 = self._enqueue_t.get(req.rid)
        return t0 is not None and \
            time.perf_counter() - t0 > req.deadline_s

    def _sweep_deadlines(self) -> None:
        """Once-per-block deadline enforcement (host side, no sync)."""
        now = time.perf_counter()
        for s in range(self.pool.slots):
            if self.active[s] and now >= self._slot_deadline[s]:
                req = self._slot_req[s]
                self._finish(
                    s, status="timeout",
                    error=f"deadline_s={req.deadline_s} exceeded",
                )

    # -- circuit breaker (speculative -> plain fallback) --------------------

    def _trip_breaker(self, reason: str) -> None:
        cooldown = (self.spec.breaker_cooldown_blocks
                    if self.spec is not None else 0)
        self.breaker.update(state="open", cooldown=cooldown,
                            zero_rounds=0, reason=reason)
        self._m_breaker.inc()
        self.obs.event("breaker.tripped", reason=reason)

    def reset_breaker(self) -> None:
        """Re-close the breaker for a fresh traffic epoch.  Benchmarks and
        the serve CLI call this together with their post-warmup stats
        reset: a random-weights warmup can legitimately trip on
        zero-acceptance rounds, and the measured run should start from
        the closed state."""
        self.breaker.update(state="closed", cooldown=0, zero_rounds=0,
                            reason=None)

    def _breaker_gate(self) -> bool:
        """Advance the breaker state machine once per block; True when
        this block may run a speculative round."""
        b = self.breaker
        if b["state"] == "closed":
            return True
        if b["state"] == "open":
            if b["cooldown"] > 0:
                b["cooldown"] -= 1
                return False
            b["state"] = "half_open"
        return True  # half_open: probe this block

    def _resync_drafter(self) -> None:
        """Re-admit every live slot's committed context into the drafter
        (it went stale while the breaker was open)."""
        for s in range(self.pool.slots):
            if self.active[s]:
                req = self._slot_req[s]
                ctx = [int(t) for t in req.prompt] + self._slot_out[s]
                self.drafter.admit(s, ctx)

    def _try_spec_round(self) -> bool:
        """One breaker-supervised speculative round.  Returns True when
        the round ran (or nothing was active); False means the breaker
        tripped before any state mutation and the caller must fall back
        to a plain block for this step."""
        b = self.breaker
        if b["state"] == "half_open":
            try:
                self._resync_drafter()
            except Exception as e:
                self._trip_breaker(f"drafter resync failed: {e!r}")
                return False
        try:
            ran, accepted = self._spec_round()
        except Exception as e:
            # propose-phase failure: nothing was mutated yet, a plain
            # block this step keeps the stream exact
            self._trip_breaker(f"drafter crashed: {e!r}")
            return False
        if not ran:
            return True  # no active slots: nothing to decode either way
        if b["state"] == "half_open":
            if accepted > 0:
                b.update(state="closed", zero_rounds=0, reason=None)
            else:
                self._trip_breaker("half-open probe round accepted nothing")
        elif b["state"] == "closed":
            if accepted == 0:
                b["zero_rounds"] += 1
                if b["zero_rounds"] >= self.spec.breaker_zero_rounds:
                    self._trip_breaker(
                        f"{b['zero_rounds']} consecutive zero-acceptance "
                        "rounds"
                    )
            else:
                b["zero_rounds"] = 0
        return True

    # -- decode -------------------------------------------------------------

    def step_block(self, n_steps: Optional[int] = None) -> None:
        """Advance every active slot: ``n_steps`` plain decode tokens, or
        ONE draft->verify->accept round (up to ``spec.k + 1`` tokens) in
        speculative mode.  Either way: one host transfer.  With the
        circuit breaker open (or tripping on this very call) speculative
        engines degrade to plain blocks — greedy output is unchanged."""
        self._inject_block_faults()
        if self.spec is not None and self._breaker_gate():
            if self._try_spec_round():
                self._sweep_deadlines()
                return
        n_steps = self.block if n_steps is None else n_steps
        if n_steps <= 0:
            return
        self.key, sub = jax.random.split(self.key)
        active_dev = jnp.asarray(self.active)
        uniq = tuple(sorted(set(self._slot_scfg), key=repr))
        sel = jnp.asarray([uniq.index(c) for c in self._slot_scfg])
        t0 = time.perf_counter()
        with self.obs.span("engine.decode_block", steps=n_steps,
                           slots_active=int(self.active.sum())):
            with self._mesh_ctx():
                states, tok, pos, toks, finite = self._decode_block(
                    self.params, self.pool.states, self.tokens,
                    self.positions, active_dev, sub, sel, n_steps=n_steps,
                    scfgs=uniq,
                )
            self.pool.states = states
            self.tokens, self.positions = tok, pos
            # the block sync: tokens + quarantine flags in ONE transfer —
            # the span (and the timing below) closes on this existing
            # sync, never adding one
            toks_host, finite_host = jax.device_get(
                (toks, finite))  # sync-point: the once-per-block transfer
        toks_host = np.asarray(toks_host)
        dt = time.perf_counter() - t0
        self._m_decode_s.inc(dt)
        self._m_itl.observe(dt / n_steps)
        for s in range(self.pool.slots):
            if not self.active[s]:
                continue
            if not bool(finite_host[s]):
                self._quarantine(s)
                continue
            self._commit(s, toks_host[:, s])
        self._sweep_deadlines()

    # -- speculative decode -------------------------------------------------

    def _spec_round(self) -> Tuple[bool, int]:
        """draft -> verify -> accept for every active slot.

        The drafter proposes k tokens per slot (batched across slots);
        then ONE jitted call (``spec.verify.make_spec_round``) scores the
        k+1-wide block chunk-parallel for all slots, computes per-slot
        acceptance, rolls rejected continuations back to the pre-verify
        state advanced by only their accepted prefix (a ``lax.cond`` arm
        that executes exclusively on rejection rounds — full-acceptance
        rounds keep the verify pass's own final states for free), and
        advances tokens/positions on device.  One host transfer per round
        (the packed accept/commit array + quarantine flags), like the
        plain block path.

        Returns ``(ran, accepted)``: whether any slot was active, and the
        total number of accepted draft tokens (the breaker's health
        signal).  Drafter exceptions in the propose phase propagate (the
        caller trips the breaker — nothing was mutated); commit-phase
        drafter exceptions trip the breaker here but never lose verified
        tokens.
        """
        k = self.spec.k
        slots_active = [s for s in range(self.pool.slots) if self.active[s]]
        if not slots_active:
            return False, 0
        t0 = time.perf_counter()
        # manual span: a propose-phase crash propagates to the breaker
        # before the round completes, so only completed rounds record
        timer = self.obs.timer("engine.spec_round", k=k,
                               slots_active=len(slots_active))
        self._raise_fault("drafter.propose")
        drafts, qp = self.drafter.propose(slots_active, k)
        if self.drafter.full_width:
            # device drafter, rows for every slot: feed straight through
            draft_full, q_full = drafts.astype(jnp.int32), qp
        elif isinstance(drafts, np.ndarray):  # host drafter: host scatter
            draft_full = np.zeros((self.pool.slots, k), np.int32)
            draft_full[slots_active] = drafts
            draft_full, q_full = jnp.asarray(draft_full), None
            if qp is not None:
                vocab = self.cfg.vocab
                q_np = np.full((self.pool.slots, k, vocab), 1.0 / vocab,
                               np.float32)
                q_np[slots_active] = np.asarray(qp, np.float32)
                q_full = jnp.asarray(q_np)
        else:  # device drafter with active-row output: device scatter
            ids = jnp.asarray(np.asarray(slots_active, np.int32))
            draft_full = jnp.zeros((self.pool.slots, k), jnp.int32)
            draft_full = draft_full.at[ids].set(drafts.astype(jnp.int32))
            q_full = None
            if qp is not None:
                vocab = self.cfg.vocab
                q_full = jnp.full(
                    (self.pool.slots, k, vocab), 1.0 / vocab, jnp.float32
                ).at[ids].set(jnp.asarray(qp, jnp.float32))
        self.key, sub = jax.random.split(self.key)
        args = (self.params, self.pool.states, self.tokens, self.positions,
                jnp.asarray(self.active), draft_full, sub)
        if self.drafter.emits_probs:
            args = args + (q_full,)
        with self._mesh_ctx():
            packed, finite, new_states, new_tokens, new_positions = \
                self._spec_step(*args)
        self.pool.states = new_states
        self.tokens, self.positions = new_tokens, new_positions
        # ONE host transfer per round: commits + quarantine flags together
        packed_h, finite_h = jax.device_get(
            (packed, finite))  # sync-point: one transfer per spec round
        packed_h = np.asarray(packed_h)
        self._m_spec_rounds.inc()
        healthy = [s for s in slots_active if bool(finite_h[s])]
        if any(int(packed_h[s, 0]) < k for s in healthy):
            self._m_spec_replays.inc()  # the rollback arm ran
        accepted_total = 0
        stepped = 0  # tokens the round advanced (accepted + bonus)
        for s in slots_active:
            if not bool(finite_h[s]):
                self._quarantine(s)
                continue
            m = int(packed_h[s, 0])
            committed = [int(t) for t in packed_h[s, 1:m + 2]]
            self._m_spec_drafted.inc(k)
            self._m_spec_accepted.inc(m)
            accepted_total += m
            stepped += m + 1
            if self._commit(s, committed):
                continue  # finished: state is stale but the slot is free
            if self.breaker["state"] != "closed":
                continue  # drafter already failed: skip its bookkeeping
            try:
                self.drafter.commit(s, committed)
            except Exception as e:
                self._trip_breaker(f"drafter.commit failed: {e!r}")
        dt = timer.close(accepted=accepted_total)
        self._m_decode_s.inc(time.perf_counter() - t0)
        self._m_itl.observe(dt / max(stepped, 1))
        return True, accepted_total

    # -- driver -------------------------------------------------------------

    def submit(self, req: GenRequest) -> None:
        """Queue one request with the admission scheduler.  Safe to call
        between drive ticks (the async server submits as traffic
        arrives); order of service is the scheduler's policy — priority
        class, deadline slack, tenant fair share — not call order."""
        now = time.perf_counter()
        self._enqueue_t.setdefault(req.rid, now)
        self.scheduler.submit(req, now=now)
        self.obs.event("request.queued", rid=req.rid,
                       priority=req.priority, tenant=req.tenant)

    def _drive_tick(self) -> None:
        """One drive-loop iteration: expire queued deadlines, honor a
        ``sched.stall``, autoscale the usable slot count, admit scheduler
        winners into free slots, advance one decode block.  Never raises
        — every failure becomes a per-request status (the ``run()``
        while-loop's no-raise contract, CI-enforced, lives here)."""
        self._bind_faults()
        # queued-deadline expiry FIRST: an expired request must never
        # consume a prefill, and learns its fate THIS tick even when no
        # slot is free (starvation regression test)
        for req in self.scheduler.expire():
            self._fail(
                req, "timeout",
                f"deadline_s={req.deadline_s} expired before admission",
            )
        self._m_queue.set(float(len(self.scheduler)))
        if not self.scheduler.stalled():
            target = self.scheduler.target_slots()
            for s in self.free_slots():
                if int(self.active.sum()) >= target:
                    break
                admitted = False
                while len(self.scheduler) and not admitted:
                    req = self.scheduler.pop()
                    if req is None:
                        break
                    self._popped.add(req.rid)
                    if req.rid in self._cancelled:
                        self._cancelled.discard(req.rid)
                        self._fail(req, "cancelled",
                                   "cancelled before admission")
                        continue
                    if self._expired(req):
                        self._fail(
                            req, "timeout",
                            f"deadline_s={req.deadline_s} expired before "
                            "admission",
                        )
                        continue
                    try:
                        self.admit(s, req)
                        admitted = True
                    except Exception as e:
                        self._fail(req, "error", f"admission failed: {e}")
        if self.active.any():
            try:
                self.step_block()
            except Exception as e:
                # a failed block leaves every live slot's device state
                # suspect: fail them all (keeping partial streams) and
                # let the queue drain through fresh admissions
                for s in range(self.pool.slots):
                    if self.active[s]:
                        self._finish(
                            s, status="error",
                            error=f"decode block failed: {e!r}",
                        )

    def run(self, requests: List[GenRequest]) -> List[GenResult]:
        """Serve ``requests`` to completion with continuous batching.

        Every request gets a terminal ``GenResult`` — per-request
        failures (invalid admission, poisoned state, expired deadline,
        cancellation, even a decode-block crash) become non-``ok``
        statuses on their own results while unaffected slots keep
        decoding; the drive loop itself never raises (CI-enforced).
        Admission order is the scheduler's: equal-priority single-tenant
        no-deadline traffic drains in arrival order (the old FIFO), and
        priorities/deadlines/tenants reorder beyond that."""
        rids = [r.rid for r in requests]
        if len(set(rids)) != len(rids):
            raise ValueError("request rids must be unique")
        for r in requests:
            self.submit(r)
        while len(self.scheduler) or self.active.any():
            self._drive_tick()
        self._m_queue.set(0.0)
        return [self.results[r.rid] for r in requests]
