"""Continuous-batching inference engine over the streaming-state models.

The serving pattern the paper's O(1)-state decode enables (DESIGN.md §8):

* **Admission = chunk-parallel prefill.**  A new prompt runs through
  ``lm.lm_prefill`` — per layer ONE chunkwise kernel call (the stateful
  Pallas kernel on TPU) that returns the exact streaming state by the
  Section-4 identity — then the state is scatter-written into its slot.
  No per-token Python loop, no device round-trip per prompt token, and no
  touching of other slots' states (the pool write is a single
  ``dynamic_update_slice`` per leaf).
* **Decode = step-locked device blocks.**  All slots advance together
  through a jitted ``lax.scan`` of ``block`` fused decode steps with
  device-side sampling; generated tokens accumulate on device and transfer
  to the host ONCE per block (vs. one ``int(...)`` sync per slot per step).
  Inactive slots ride along masked (their sampled tokens are discarded and
  their positions frozen); their stale states are overwritten at the next
  admission.

KV-cache (softmax / hybrid) archs are rejected: their pooled cache keeps a
*shared* scalar ``length``, so per-slot admission would need per-slot
lengths threaded through attention — a follow-up, not a serving-engine
concern (the HLA family is the paper's point).
"""

from __future__ import annotations

import collections
import contextlib
import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models import lm
from .sampling import SamplingConfig, sample
from .state_pool import StatePool

STREAMING_MIXERS = ("hla2", "ahla", "hla3", "hla3_paper", "linattn", "rwkv6")


@dataclasses.dataclass
class GenRequest:
    rid: int
    prompt: np.ndarray  # (L,) int token ids
    max_new: int = 32
    eos_id: Optional[int] = None


@dataclasses.dataclass
class GenResult:
    rid: int
    tokens: List[int]
    ttft_s: float  # admission -> first sampled token
    prompt_len: int


class Engine:
    """Slot-based continuous batching over a ``StatePool``."""

    def __init__(
        self,
        cfg,
        params,
        *,
        slots: int = 4,
        max_len: int = 4096,
        sampling: SamplingConfig = SamplingConfig(),
        block: int = 8,
        seed: int = 0,
        mesh=None,
    ):
        if cfg.mixer not in STREAMING_MIXERS or cfg.group_size:
            raise ValueError(
                f"Engine serves streaming-state archs {STREAMING_MIXERS}; "
                f"mixer={cfg.mixer!r} (group_size={cfg.group_size}) decodes "
                "from a KV cache whose pooled scalar length is shared across "
                "slots — continuous batching needs per-slot lengths"
            )
        self.cfg = cfg
        self.params = params
        self.sampling = sampling
        self.block = block
        self.mesh = mesh
        # sharded serving: slot states get explicit shardings (slots on
        # the data axis, heads on the model axis) from the same source of
        # truth the train/dry-run steps use — never a replicated tree.
        pool_shardings = None
        if mesh is not None:
            from ..distributed import steps as steps_mod

            abstract = jax.eval_shape(
                lambda: lm.lm_init_states(cfg, slots, max_len)
            )
            pool_shardings = steps_mod.state_shardings_for(
                cfg, mesh, abstract
            )
        self.pool = StatePool(
            lambda n: lm.lm_init_states(cfg, n, max_len), slots,
            shardings=pool_shardings,
        )
        self.tokens = jnp.zeros((slots, 1), jnp.int32)
        self.positions = jnp.zeros((slots, 1), jnp.int32)
        self.active = np.zeros(slots, bool)
        self._slot_req: List[Optional[GenRequest]] = [None] * slots
        self._slot_out: List[List[int]] = [[] for _ in range(slots)]
        self._slot_ttft: List[float] = [0.0] * slots
        self.results: Dict[int, GenResult] = {}
        self.key = jax.random.key(seed)
        self.stats = {
            "prefill_s": 0.0, "decode_s": 0.0,
            "prompt_tokens": 0, "generated_tokens": 0, "ttft_s": [],
        }

        scfg = self.sampling

        def _prefill(params, prompt, key):
            last_logits, states = lm.lm_prefill(params, prompt, cfg)
            tok = sample(last_logits, key, scfg)
            return tok, states

        def _decode_block(params, states, tokens, positions, active, key,
                          n_steps):
            def body(carry, _):
                states, tok, pos, key = carry
                logits, states, _ = lm.lm_apply(
                    params, tok, cfg, states=states, positions=pos,
                    mode="decode",
                )
                key, sub = jax.random.split(key)
                nxt = sample(logits[:, -1], sub, scfg)
                tok = jnp.where(active[:, None], nxt[:, None], tok)
                pos = pos + active[:, None].astype(pos.dtype)
                return (states, tok, pos, key), nxt

            (states, tok, pos, _), toks = jax.lax.scan(
                body, (states, tokens, positions, key), length=n_steps
            )
            if pool_shardings is not None:
                # pin the block's state output to the pool layout — the
                # scatter writes pin admissions, this pins the hot path,
                # so GSPMD never drifts the pool and re-lowers
                states = jax.tree.map(
                    jax.lax.with_sharding_constraint, states, pool_shardings
                )
            return states, tok, pos, toks  # toks: (n_steps, slots)

        self._prefill = jax.jit(_prefill)
        self._decode_block = jax.jit(
            _decode_block, static_argnames="n_steps"
        )

    def _mesh_ctx(self):
        """Activate the engine's mesh (mixer shard_map dispatch + logical
        sharding constraints resolve against the ambient mesh)."""
        return self.mesh if self.mesh is not None else (
            contextlib.nullcontext()
        )

    # -- admission ----------------------------------------------------------

    def free_slots(self) -> List[int]:
        return [s for s in range(self.pool.slots) if not self.active[s]]

    def admit(self, slot: int, req: GenRequest) -> int:
        """Prefill ``req`` into ``slot``; returns the first sampled token.

        One chunk-parallel prefill call + one scatter write; live slots are
        never read or written.
        """
        if self.active[slot]:
            raise ValueError(f"slot {slot} is busy")
        t0 = time.perf_counter()
        self.key, sub = jax.random.split(self.key)
        prompt = jnp.asarray(np.asarray(req.prompt, np.int32)[None])
        with self._mesh_ctx():
            first, state1 = self._prefill(self.params, prompt, sub)
            self.pool.write_slot(slot, state1)
        first_tok = int(first[0])  # one sync per admission: TTFT endpoint
        ttft = time.perf_counter() - t0
        self.tokens = self.tokens.at[slot, 0].set(first_tok)
        self.positions = self.positions.at[slot, 0].set(len(req.prompt))
        self.active[slot] = True
        self._slot_req[slot] = req
        self._slot_out[slot] = [first_tok]
        self._slot_ttft[slot] = ttft
        self.stats["prefill_s"] += ttft
        self.stats["prompt_tokens"] += len(req.prompt)
        self.stats["ttft_s"].append(ttft)
        return first_tok

    def _finish(self, slot: int) -> None:
        req = self._slot_req[slot]
        out = self._slot_out[slot][: req.max_new]
        if req.eos_id is not None and req.eos_id in out:
            out = out[: out.index(req.eos_id) + 1]
        self.results[req.rid] = GenResult(
            rid=req.rid, tokens=out, ttft_s=self._slot_ttft[slot],
            prompt_len=len(req.prompt),
        )
        self.stats["generated_tokens"] += len(out)
        self.active[slot] = False
        self._slot_req[slot] = None

    # -- decode -------------------------------------------------------------

    def step_block(self, n_steps: Optional[int] = None) -> None:
        """Advance every active slot ``n_steps`` tokens; ONE host transfer."""
        n_steps = self.block if n_steps is None else n_steps
        if n_steps <= 0:
            return
        self.key, sub = jax.random.split(self.key)
        active_dev = jnp.asarray(self.active)
        t0 = time.perf_counter()
        with self._mesh_ctx():
            states, tok, pos, toks = self._decode_block(
                self.params, self.pool.states, self.tokens, self.positions,
                active_dev, sub, n_steps=n_steps,
            )
        self.pool.states = states
        self.tokens, self.positions = tok, pos
        toks_host = np.asarray(toks)  # (n_steps, slots) — the block sync
        self.stats["decode_s"] += time.perf_counter() - t0
        for s in range(self.pool.slots):
            if not self.active[s]:
                continue
            req = self._slot_req[s]
            out = self._slot_out[s]
            for i in range(n_steps):
                if len(out) >= req.max_new or (
                    req.eos_id is not None and out and out[-1] == req.eos_id
                ):
                    break
                out.append(int(toks_host[i, s]))
            if len(out) >= req.max_new or (
                req.eos_id is not None and req.eos_id in out
            ):
                self._finish(s)

    # -- driver -------------------------------------------------------------

    def run(self, requests: List[GenRequest]) -> List[GenResult]:
        """Serve ``requests`` to completion with continuous batching."""
        rids = [r.rid for r in requests]
        if len(set(rids)) != len(rids):
            raise ValueError("request rids must be unique")
        pending = collections.deque(requests)
        while pending or self.active.any():
            for s in self.free_slots():
                if not pending:
                    break
                self.admit(s, pending.popleft())
            if self.active.any():
                self.step_block()
        return [self.results[r.rid] for r in requests]
