"""Continuous-batching inference engine over the streaming-state models.

The serving pattern the paper's O(1)-state decode enables (DESIGN.md §8):

* **Admission = chunk-parallel prefill.**  A new prompt runs through
  ``lm.lm_prefill`` — per layer ONE chunkwise kernel call (the stateful
  Pallas kernel on TPU) that returns the exact streaming state by the
  Section-4 identity — then the state is scatter-written into its slot.
  No per-token Python loop, no device round-trip per prompt token, and no
  touching of other slots' states (the pool write is a single
  ``dynamic_update_slice`` per leaf).
* **Decode = step-locked device blocks.**  All slots advance together
  through a jitted ``lax.scan`` of ``block`` fused decode steps with
  device-side sampling; generated tokens accumulate on device and transfer
  to the host ONCE per block (vs. one ``int(...)`` sync per slot per step).
  Inactive slots ride along masked (their sampled tokens are discarded and
  their positions frozen); their stale states are overwritten at the next
  admission.
* **Speculative decode (``spec=``)** swaps the block for a
  draft -> verify -> accept round (DESIGN.md §10): a ``Drafter`` proposes
  k tokens per active slot (batched), then ONE jitted round
  (``spec.verify.make_spec_round``) scores all of them chunk-parallel,
  commits accepted tokens in bulk — up to k+1 tokens per round for the
  serial cost of one wide prefill — and, on rejection only (a
  ``lax.cond`` arm), rolls the pool back to the pre-verify states
  advanced by each slot's accepted prefix, so speculative greedy decode
  is token-for-token identical to plain greedy decode.
  ``StatePool.snapshot_slot``/``restore_slot`` expose the same O(state)
  rollback primitive at the host level (external schedulers,
  preemption, tests).  One host sync per round, as in the plain block
  path.

KV-cache (softmax / hybrid) archs are rejected: their pooled cache keeps a
*shared* scalar ``length``, so per-slot admission would need per-slot
lengths threaded through attention — a follow-up, not a serving-engine
concern (the HLA family is the paper's point).
"""

from __future__ import annotations

import collections
import contextlib
import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models import lm, seq_op
from .sampling import SamplingConfig, sample
from .spec import SpecConfig, build_drafter
from .spec.verify import make_spec_round
from .state_pool import StatePool


@dataclasses.dataclass
class GenRequest:
    rid: int
    prompt: np.ndarray  # (L,) int token ids
    max_new: int = 32
    eos_id: Optional[int] = None
    # per-request sampling override (None = the engine's default).  The
    # decode block re-traces when the SET of distinct configs across slots
    # changes; homogeneous traffic stays at one trace.
    sampling: Optional[SamplingConfig] = None


@dataclasses.dataclass
class GenResult:
    rid: int
    tokens: List[int]
    ttft_s: float  # admission -> first sampled token
    prompt_len: int


class Engine:
    """Slot-based continuous batching over a ``StatePool``."""

    def __init__(
        self,
        cfg,
        params,
        *,
        slots: int = 4,
        max_len: int = 4096,
        sampling: SamplingConfig = SamplingConfig(),
        block: int = 8,
        seed: int = 0,
        mesh=None,
        spec: Optional[SpecConfig] = None,
    ):
        # serveability is a REGISTRY capability, not a hardcoded tuple:
        # any op registered with streaming=True (O(1) decode state) admits
        # per-slot continuous batching; KV-cache ops (attn) and hybrid
        # stacks share a pooled scalar length across slots and cannot.
        op = seq_op.op_for(cfg)
        if not op.streaming or cfg.group_size:
            raise ValueError(
                "Engine serves streaming-state ops "
                f"{seq_op.streaming_op_names()}; op {op.name!r} "
                f"(group_size={cfg.group_size}) decodes from a KV cache "
                "whose pooled scalar length is shared across slots — "
                "continuous batching needs per-slot lengths"
            )
        if spec is not None and not op.spec_decodable:
            raise ValueError(
                f"op {op.name!r} is not registered spec_decodable: its "
                "state cannot be snapshot/rolled back for speculative "
                "verification"
            )
        self.cfg = cfg
        self.params = params
        self.sampling = sampling
        self.block = block
        self.mesh = mesh
        self.spec = spec
        # sharded serving: slot states get explicit shardings (slots on
        # the data axis, heads on the model axis) from the same source of
        # truth the train/dry-run steps use — never a replicated tree.
        pool_shardings = None
        if mesh is not None:
            from ..distributed import steps as steps_mod

            abstract = jax.eval_shape(
                lambda: lm.lm_init_states(cfg, slots, max_len)
            )
            pool_shardings = steps_mod.state_shardings_for(
                cfg, mesh, abstract
            )
        self.pool = StatePool(
            lambda n: lm.lm_init_states(cfg, n, max_len), slots,
            shardings=pool_shardings,
        )
        self.tokens = jnp.zeros((slots, 1), jnp.int32)
        self.positions = jnp.zeros((slots, 1), jnp.int32)
        self.active = np.zeros(slots, bool)
        self._slot_req: List[Optional[GenRequest]] = [None] * slots
        self._slot_out: List[List[int]] = [[] for _ in range(slots)]
        self._slot_ttft: List[float] = [0.0] * slots
        self._slot_scfg: List[SamplingConfig] = [sampling] * slots
        self.results: Dict[int, GenResult] = {}
        self.key = jax.random.key(seed)
        self.stats = {
            "prefill_s": 0.0, "decode_s": 0.0,
            "prompt_tokens": 0, "generated_tokens": 0, "ttft_s": [],
            "spec_rounds": 0, "spec_drafted": 0, "spec_accepted": 0,
            "spec_replays": 0,
        }

        def _prefill(params, prompt, key, scfg):
            last_logits, states = lm.lm_prefill(params, prompt, cfg)
            tok = sample(last_logits, key, scfg)
            return tok, states

        def _decode_block(params, states, tokens, positions, active, key,
                          sel, n_steps, scfgs):
            # scfgs: the (static) canonically-ordered DISTINCT sampling
            # configs; sel: traced (slots,) index into them.  Sampling once
            # per distinct config keeps homogeneous traffic at the old
            # single-sampler cost, and keying the jit on the distinct SET
            # (not the per-slot assignment) means slot churn never
            # recompiles — only genuinely new configs do.
            def body(carry, _):
                states, tok, pos, key = carry
                logits, states, _ = lm.lm_apply(
                    params, tok, cfg, states=states, positions=pos,
                    mode="decode",
                )
                key, *subs = jax.random.split(key, len(scfgs) + 1)
                cand = jnp.stack(
                    [sample(logits[:, -1], sk, c)
                     for c, sk in zip(scfgs, subs)]
                )  # (n_uniq, slots)
                nxt = jnp.take_along_axis(cand, sel[None, :], axis=0)[0]
                tok = jnp.where(active[:, None], nxt[:, None], tok)
                pos = pos + active[:, None].astype(pos.dtype)
                return (states, tok, pos, key), nxt

            (states, tok, pos, _), toks = jax.lax.scan(
                body, (states, tokens, positions, key), length=n_steps
            )
            if pool_shardings is not None:
                # pin the block's state output to the pool layout — the
                # scatter writes pin admissions, this pins the hot path,
                # so GSPMD never drifts the pool and re-lowers
                states = jax.tree.map(
                    jax.lax.with_sharding_constraint, states, pool_shardings
                )
            return states, tok, pos, toks  # toks: (n_steps, slots)

        self._prefill = jax.jit(_prefill, static_argnames="scfg")
        self._decode_block = jax.jit(
            _decode_block, static_argnames=("n_steps", "scfgs")
        )

        if spec is not None:
            self.drafter = build_drafter(
                spec, slots=slots, max_len=max_len, sampling=sampling,
                mesh=mesh, target_cfg=cfg,
            )
            if self.drafter.vocab is not None and \
                    self.drafter.vocab != cfg.vocab:
                raise ValueError(
                    f"drafter vocab {self.drafter.vocab} != target vocab "
                    f"{cfg.vocab}: draft ids would index the target "
                    "embedding out of range"
                )
            self._spec_step = jax.jit(make_spec_round(
                cfg, sampling, draft_probs=self.drafter.emits_probs,
                pool_shardings=pool_shardings,
            ))
        else:
            self.drafter = None

    def _mesh_ctx(self):
        """Activate the engine's mesh (mixer shard_map dispatch + logical
        sharding constraints resolve against the ambient mesh)."""
        return self.mesh if self.mesh is not None else (
            contextlib.nullcontext()
        )

    # -- admission ----------------------------------------------------------

    def free_slots(self) -> List[int]:
        return [s for s in range(self.pool.slots) if not self.active[s]]

    def admit(self, slot: int, req: GenRequest) -> int:
        """Prefill ``req`` into ``slot``; returns the first sampled token.

        One chunk-parallel prefill call + one scatter write; live slots are
        never read or written.
        """
        if self.active[slot]:
            raise ValueError(f"slot {slot} is busy")
        scfg = req.sampling if req.sampling is not None else self.sampling
        if self.spec is not None and scfg != self.sampling:
            raise ValueError(
                "speculative mode verifies against ONE sampling law; "
                "per-request overrides would need per-slot accept rules "
                f"(engine={self.sampling}, request={scfg})"
            )
        t0 = time.perf_counter()
        self.key, sub = jax.random.split(self.key)
        prompt = jnp.asarray(np.asarray(req.prompt, np.int32)[None])
        with self._mesh_ctx():
            first, state1 = self._prefill(self.params, prompt, sub, scfg)
            self.pool.write_slot(slot, state1)
        first_tok = int(first[0])  # one sync per admission: TTFT endpoint
        ttft = time.perf_counter() - t0
        self.tokens = self.tokens.at[slot, 0].set(first_tok)
        self.positions = self.positions.at[slot, 0].set(len(req.prompt))
        self.active[slot] = True
        self._slot_req[slot] = req
        self._slot_out[slot] = [first_tok]
        self._slot_ttft[slot] = ttft
        self._slot_scfg[slot] = scfg
        if self.drafter is not None:
            self.drafter.admit(
                slot, [int(t) for t in req.prompt] + [first_tok]
            )
        self.stats["prefill_s"] += ttft
        self.stats["prompt_tokens"] += len(req.prompt)
        self.stats["ttft_s"].append(ttft)
        return first_tok

    def _commit(self, slot: int, toks) -> bool:
        """Append generated tokens to ``slot``'s stream with max_new/eos
        truncation; finish the slot when its stop condition hits.  The
        ONE place commit semantics live — plain blocks and speculative
        rounds must truncate identically or their streams diverge.
        Returns True when the slot finished (and was freed)."""
        req = self._slot_req[slot]
        out = self._slot_out[slot]
        for t in toks:
            if len(out) >= req.max_new or (
                req.eos_id is not None and out and out[-1] == req.eos_id
            ):
                break
            out.append(int(t))
        if len(out) >= req.max_new or (
            req.eos_id is not None and req.eos_id in out
        ):
            self._finish(slot)
            return True
        return False

    def _finish(self, slot: int) -> None:
        req = self._slot_req[slot]
        out = self._slot_out[slot][: req.max_new]
        if req.eos_id is not None and req.eos_id in out:
            out = out[: out.index(req.eos_id) + 1]
        self.results[req.rid] = GenResult(
            rid=req.rid, tokens=out, ttft_s=self._slot_ttft[slot],
            prompt_len=len(req.prompt),
        )
        self.stats["generated_tokens"] += len(out)
        self.active[slot] = False
        self._slot_req[slot] = None
        # drop any per-request sampling override so the freed slot stops
        # contributing a stale config to the decode block's distinct set
        self._slot_scfg[slot] = self.sampling
        if self.drafter is not None:
            self.drafter.evict(slot)

    # -- decode -------------------------------------------------------------

    def step_block(self, n_steps: Optional[int] = None) -> None:
        """Advance every active slot: ``n_steps`` plain decode tokens, or
        ONE draft->verify->accept round (up to ``spec.k + 1`` tokens) in
        speculative mode.  Either way: one host transfer."""
        if self.spec is not None:
            self._spec_round()
            return
        n_steps = self.block if n_steps is None else n_steps
        if n_steps <= 0:
            return
        self.key, sub = jax.random.split(self.key)
        active_dev = jnp.asarray(self.active)
        uniq = tuple(sorted(set(self._slot_scfg), key=repr))
        sel = jnp.asarray([uniq.index(c) for c in self._slot_scfg])
        t0 = time.perf_counter()
        with self._mesh_ctx():
            states, tok, pos, toks = self._decode_block(
                self.params, self.pool.states, self.tokens, self.positions,
                active_dev, sub, sel, n_steps=n_steps, scfgs=uniq,
            )
        self.pool.states = states
        self.tokens, self.positions = tok, pos
        toks_host = np.asarray(toks)  # (n_steps, slots) — the block sync
        self.stats["decode_s"] += time.perf_counter() - t0
        for s in range(self.pool.slots):
            if not self.active[s]:
                continue
            self._commit(s, toks_host[:, s])

    # -- speculative decode -------------------------------------------------

    def _spec_round(self) -> None:
        """draft -> verify -> accept for every active slot.

        The drafter proposes k tokens per slot (batched across slots);
        then ONE jitted call (``spec.verify.make_spec_round``) scores the
        k+1-wide block chunk-parallel for all slots, computes per-slot
        acceptance, rolls rejected continuations back to the pre-verify
        state advanced by only their accepted prefix (a ``lax.cond`` arm
        that executes exclusively on rejection rounds — full-acceptance
        rounds keep the verify pass's own final states for free), and
        advances tokens/positions on device.  One host transfer per round
        (the packed accept/commit array), like the plain block path.
        """
        k = self.spec.k
        slots_active = [s for s in range(self.pool.slots) if self.active[s]]
        if not slots_active:
            return
        t0 = time.perf_counter()
        drafts, qp = self.drafter.propose(slots_active, k)
        if self.drafter.full_width:
            # device drafter, rows for every slot: feed straight through
            draft_full, q_full = drafts.astype(jnp.int32), qp
        elif isinstance(drafts, np.ndarray):  # host drafter: host scatter
            draft_full = np.zeros((self.pool.slots, k), np.int32)
            draft_full[slots_active] = drafts
            draft_full, q_full = jnp.asarray(draft_full), None
            if qp is not None:
                vocab = self.cfg.vocab
                q_np = np.full((self.pool.slots, k, vocab), 1.0 / vocab,
                               np.float32)
                q_np[slots_active] = np.asarray(qp, np.float32)
                q_full = jnp.asarray(q_np)
        else:  # device drafter with active-row output: device scatter
            ids = jnp.asarray(np.asarray(slots_active, np.int32))
            draft_full = jnp.zeros((self.pool.slots, k), jnp.int32)
            draft_full = draft_full.at[ids].set(drafts.astype(jnp.int32))
            q_full = None
            if qp is not None:
                vocab = self.cfg.vocab
                q_full = jnp.full(
                    (self.pool.slots, k, vocab), 1.0 / vocab, jnp.float32
                ).at[ids].set(jnp.asarray(qp, jnp.float32))
        self.key, sub = jax.random.split(self.key)
        args = (self.params, self.pool.states, self.tokens, self.positions,
                jnp.asarray(self.active), draft_full, sub)
        if self.drafter.emits_probs:
            args = args + (q_full,)
        with self._mesh_ctx():
            packed, new_states, new_tokens, new_positions = \
                self._spec_step(*args)
        self.pool.states = new_states
        self.tokens, self.positions = new_tokens, new_positions
        packed_h = np.asarray(packed)  # ONE host transfer per round
        self.stats["spec_rounds"] += 1
        if any(int(packed_h[s, 0]) < k for s in slots_active):
            self.stats["spec_replays"] += 1  # the rollback arm ran
        for s in slots_active:
            m = int(packed_h[s, 0])
            committed = [int(t) for t in packed_h[s, 1:m + 2]]
            self.stats["spec_drafted"] += k
            self.stats["spec_accepted"] += m
            if self._commit(s, committed):
                continue  # finished: state is stale but the slot is free
            self.drafter.commit(s, committed)
        self.stats["decode_s"] += time.perf_counter() - t0

    # -- driver -------------------------------------------------------------

    def run(self, requests: List[GenRequest]) -> List[GenResult]:
        """Serve ``requests`` to completion with continuous batching."""
        rids = [r.rid for r in requests]
        if len(set(rids)) != len(rids):
            raise ValueError("request rids must be unique")
        pending = collections.deque(requests)
        while pending or self.active.any():
            for s in self.free_slots():
                if not pending:
                    break
                self.admit(s, pending.popleft())
            if self.active.any():
                self.step_block()
        return [self.results[r.rid] for r in requests]
