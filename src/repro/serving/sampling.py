"""Seeded device-side token sampling (greedy / temperature / top-k / top-p).

Shared by the serving engine's decode blocks, the speculative-decoding
verifier, and the examples — replaces the ad-hoc ``jnp.argmax`` calls.
``sample`` is jit-friendly: the ``SamplingConfig`` is a frozen (hashable)
dataclass, so jitted callers close over it statically and the device never
round-trips a decision to the host.

``probs`` exposes the *warped* next-token distribution (temperature /
top-k / top-p applied, then softmax) as an explicit probability vector.
Speculative sampling needs this: the accept/residual rule of
Leviathan et al. operates on the target distribution p and the draft
distribution q, and it only preserves the output law if both are the same
warped distributions the plain sampler would draw from.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplingConfig:
    method: str = "greedy"  # greedy | temperature | top_k | top_p
    temperature: float = 1.0
    top_k: int = 0  # only read when method == "top_k"
    top_p: float = 1.0  # only read when method == "top_p" (nucleus)


def _warped_logits(logits: jax.Array, cfg: SamplingConfig) -> jax.Array:
    """Temperature/top-k/top-p warping in logit space (-inf = masked)."""
    lg = logits.astype(jnp.float32) / max(cfg.temperature, 1e-6)
    if cfg.method == "top_k":
        if cfg.top_k <= 0:
            raise ValueError("top_k sampling needs top_k > 0")
        kth = jax.lax.top_k(lg, cfg.top_k)[0][..., -1:]
        lg = jnp.where(lg < kth, -jnp.inf, lg)
    elif cfg.method == "top_p":
        if not 0.0 < cfg.top_p <= 1.0:
            raise ValueError("top_p sampling needs 0 < top_p <= 1")
        # nucleus: keep the smallest prefix of the sorted distribution whose
        # cumulative mass reaches top_p (the token that crosses the
        # threshold is kept, so the set is never empty)
        srt = jnp.sort(lg, axis=-1)[..., ::-1]
        cum = jnp.cumsum(jax.nn.softmax(srt, axis=-1), axis=-1)
        keep = cum - jax.nn.softmax(srt, axis=-1) < cfg.top_p
        # threshold = smallest kept logit (keep is a sorted prefix mask)
        thr = jnp.min(jnp.where(keep, srt, jnp.inf), axis=-1, keepdims=True)
        lg = jnp.where(lg < thr, -jnp.inf, lg)
    elif cfg.method not in ("temperature", "greedy"):
        raise ValueError(f"unknown sampling method {cfg.method!r}")
    return lg


def probs(logits: jax.Array, cfg: SamplingConfig) -> jax.Array:
    """Warped next-token distribution over ``(..., vocab)`` logits (fp32).

    greedy -> a delta at the argmax; otherwise softmax of the warped
    logits.  This is exactly the law ``sample`` draws from, which is what
    makes it usable as p (target) and q (draft) in speculative sampling.
    """
    if cfg.method == "greedy":
        top = jnp.argmax(logits, axis=-1)
        return jax.nn.one_hot(top, logits.shape[-1], dtype=jnp.float32)
    return jax.nn.softmax(_warped_logits(logits, cfg), axis=-1)


def sample(logits: jax.Array, key, cfg: SamplingConfig) -> jax.Array:
    """Sample next tokens from ``(..., vocab)`` logits -> ``(...,)`` int32.

    ``key`` is unused for greedy (pass any key; keeps call sites uniform).
    """
    if cfg.method == "greedy":
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    lg = _warped_logits(logits, cfg)
    return jax.random.categorical(key, lg, axis=-1).astype(jnp.int32)
