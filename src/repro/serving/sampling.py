"""Seeded device-side token sampling (greedy / temperature / top-k).

Shared by the serving engine's decode blocks and the examples — replaces
the ad-hoc ``jnp.argmax`` calls.  ``sample`` is jit-friendly: the
``SamplingConfig`` is a frozen (hashable) dataclass, so jitted callers
close over it statically and the device never round-trips a decision to
the host.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplingConfig:
    method: str = "greedy"  # greedy | temperature | top_k
    temperature: float = 1.0
    top_k: int = 0  # only read when method == "top_k"


def sample(logits: jax.Array, key, cfg: SamplingConfig) -> jax.Array:
    """Sample next tokens from ``(..., vocab)`` logits -> ``(...,)`` int32.

    ``key`` is unused for greedy (pass any key; keeps call sites uniform).
    """
    if cfg.method == "greedy":
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    lg = logits.astype(jnp.float32) / max(cfg.temperature, 1e-6)
    if cfg.method == "top_k":
        if cfg.top_k <= 0:
            raise ValueError("top_k sampling needs top_k > 0")
        kth = jax.lax.top_k(lg, cfg.top_k)[0][..., -1:]
        lg = jnp.where(lg < kth, -jnp.inf, lg)
    elif cfg.method != "temperature":
        raise ValueError(f"unknown sampling method {cfg.method!r}")
    return jax.random.categorical(key, lg, axis=-1).astype(jnp.int32)
