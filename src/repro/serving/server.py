"""Async streaming front-end over the synchronous drive loop.

The engine is deliberately synchronous — one thread owns the device, and
every block is ONE host sync (DESIGN.md §8).  ``AsyncServer`` puts an
asyncio facade on that loop without changing its discipline:

* **Submission** — ``generate(req)`` queues the request with the
  engine's admission scheduler and returns an async iterator of token
  ids.  Arrival order is irrelevant; service order is the scheduler's
  policy (priority / deadline slack / tenant fair share, DESIGN.md §16).
* **Streaming** — the engine's ``on_stream`` hook fires on the drive
  thread after every commit (once per block/round, NEVER per token) and
  the server marshals the block's tokens onto the event loop with
  ``call_soon_threadsafe``; the async iterator then yields them one at a
  time.  Per-token latency to the consumer stays once-per-block — the
  async layer adds no device syncs.
* **Drive loop** — ``serve()`` (started by ``async with``) runs
  ``engine._drive_tick`` in a worker thread via ``asyncio.to_thread``,
  so the event loop keeps serving consumers during a device block.  One
  tick at a time: the single-owner engine contract is preserved.
* **Backpressure** — tokens buffered but not yet consumed are counted;
  past ``max_buffered_tokens`` the drive loop PAUSES (no admissions, no
  blocks) until consumers drain below the watermark.  Slow readers
  throttle generation instead of growing unbounded queues.
* **Graceful drain** — leaving the ``async with`` scope (or calling
  ``drain()``) stops new submissions, finishes every in-flight and
  queued request, flushes their streams, then stops the drive task.
"""

from __future__ import annotations

import asyncio
from typing import AsyncIterator, Dict, List, Optional, Tuple

from .engine import Engine, GenRequest, GenResult


class AsyncServer:
    """Asyncio streaming facade over one ``Engine``.

    Usage::

        async with AsyncServer(engine) as srv:
            async for tok in srv.generate(req):
                ...
            result = srv.result(req.rid)

    Single event loop, single engine owner: ``generate`` may be called
    from many tasks concurrently, but all engine mutation happens on the
    drive task's worker thread, one tick at a time.
    """

    def __init__(self, engine: Engine, *, max_buffered_tokens: int = 4096):
        if max_buffered_tokens < 1:
            raise ValueError(
                f"max_buffered_tokens must be >= 1: {max_buffered_tokens}"
            )
        self.engine = engine
        self.max_buffered_tokens = max_buffered_tokens
        self._queues: Dict[int, asyncio.Queue] = {}
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._task: Optional[asyncio.Task] = None
        self._buffered = 0  # tokens pushed to consumers, not yet read
        self._draining = False
        self._wake: Optional[asyncio.Event] = None
        self._drained: Optional[asyncio.Event] = None
        m = engine.obs
        self._m_streams = m.counter(
            "server_streams_total", "streams opened via generate()")
        self._m_stream_toks = m.counter(
            "server_stream_tokens_total", "tokens yielded to consumers")
        self._m_bp = m.counter(
            "server_backpressure_waits_total",
            "drive-loop pauses waiting for slow consumers")
        self._m_open = m.gauge("server_open_streams", "live streams")

    # -- lifecycle ----------------------------------------------------------

    async def __aenter__(self) -> "AsyncServer":
        self.start()
        return self

    async def __aexit__(self, *exc) -> None:
        await self.drain()

    def start(self) -> None:
        """Install the stream hook and start the drive task on the
        running event loop."""
        if self._task is not None:
            raise RuntimeError("server already started")
        self._loop = asyncio.get_running_loop()
        self._wake = asyncio.Event()
        self._drained = asyncio.Event()
        self._drained.set()
        self.engine.on_stream = self._on_stream
        self._task = self._loop.create_task(self.serve())
        self.engine.obs.event("server.start")

    async def drain(self) -> None:
        """Graceful shutdown: refuse new submissions, serve everything
        queued or in flight to a terminal result, then stop the drive
        task.  Idempotent."""
        self._draining = True
        self.engine.obs.event(
            "server.drain",
            queued=len(self.engine.scheduler),
            live=int(self.engine.active.sum()),
        )
        if self._wake is not None:
            self._wake.set()
        if self._drained is not None:
            self._drained.set()  # drain must not hang on a gone consumer
        if self._task is not None:
            await self._task
            self._task = None

    # -- submission / consumption -------------------------------------------

    async def generate(self, req: GenRequest) -> AsyncIterator[int]:
        """Submit ``req`` and yield its generated token ids as the drive
        loop produces them.  The stream ends at the terminal result —
        inspect ``result(req.rid)`` for status/error; a failed request
        simply yields whatever partial stream it committed."""
        if self._draining:
            raise RuntimeError("server is draining: submission refused")
        if self._task is None:
            raise RuntimeError("server not started (use `async with`)")
        q: asyncio.Queue = asyncio.Queue()
        self._queues[req.rid] = q
        self._m_streams.inc()
        self._m_open.set(float(len(self._queues)))
        try:
            self.engine.submit(req)
            self._wake.set()
            while True:
                toks, result = await q.get()
                for t in toks:
                    self._buffered -= 1
                    if self._buffered <= self.max_buffered_tokens:
                        self._drained.set()
                    self._m_stream_toks.inc()
                    yield int(t)
                if result is not None:
                    return
        finally:
            self._queues.pop(req.rid, None)
            self._m_open.set(float(len(self._queues)))

    def result(self, rid: int) -> Optional[GenResult]:
        """Terminal result for a finished stream (None while running)."""
        return self.engine.results.get(rid)

    # -- engine-side hook (drive thread) ------------------------------------

    def _on_stream(self, rid: int, toks: List[int],
                   result: Optional[GenResult]) -> None:
        # called on the drive worker thread: marshal onto the event loop
        # (queues + the backpressure counter are loop-thread-only state)
        self._loop.call_soon_threadsafe(self._push, rid, list(toks), result)

    def _push(self, rid: int, toks: List[int],
              result: Optional[GenResult]) -> None:
        q = self._queues.get(rid)
        if q is None:
            return  # not a server-submitted request (e.g. direct admit)
        if toks or result is not None:
            self._buffered += len(toks)
            if self._buffered > self.max_buffered_tokens:
                self._drained.clear()
            q.put_nowait((toks, result))

    # -- drive task ---------------------------------------------------------

    def _idle(self) -> bool:
        return not (len(self.engine.scheduler) or self.engine.active.any())

    async def serve(self) -> None:
        """Drive the engine until drained: one ``_drive_tick`` per
        iteration in a worker thread, pausing while consumers lag."""
        while True:
            if self._idle():
                if self._draining:
                    break
                self._wake.clear()
                if self._idle():  # re-check: submit() may have raced
                    await self._wake.wait()
                continue
            if not self._draining and \
                    self._buffered > self.max_buffered_tokens:
                # backpressure: consumers are behind by more than the
                # watermark — generating more would just grow queues
                # (drain overrides: terminal results must still land)
                self._m_bp.inc()
                self._drained.clear()
                await self._drained.wait()
                continue
            await asyncio.to_thread(self.engine._drive_tick)
        self.engine.on_stream = None
        self.engine.obs.event("server.stop")


async def collect(server: AsyncServer, req: GenRequest
                  ) -> Tuple[List[int], Optional[GenResult]]:
    """Consume one stream to completion (tests / CLI convenience)."""
    toks = [t async for t in server.generate(req)]
    return toks, server.result(req.rid)
