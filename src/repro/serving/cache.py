"""Content-addressed prefix/state cache — the HLA serving advantage.

Softmax prefix caching is a memory-management problem: a cached prompt
is a paged KV arena that grows with its length, so production servers
build radix trees over block tables.  For the paper's streaming ops the
entire prefix is summarized by a **constant-size sufficient statistic**
(PAPER §2–3): a cached prefix is ONE O(1) state snapshot — a few small
tensors per layer, independent of prefix length — so the cache is a
dict of host arrays with a byte budget, not an allocator.

Mechanics (DESIGN.md §16):

* **Keying** — a polynomial rolling hash over the prompt token ids,
  materialized at **chunk-granularity** prefix lengths (``granularity``
  tokens, default the op's chunk width).  The key is pure token
  content + the cache's ``namespace`` (model/params fingerprint), so
  two tenants sharing a system prompt share the entry.  Hash collisions
  cannot produce wrong tokens: every probe verifies the stored token
  ids before hitting.
* **Lookup** — longest-prefix: probe chunk-aligned prefix lengths from
  the longest candidate down; the first verified entry wins.  Exactness
  of resuming from the snapshot is the chunkwise carry identity the
  prefill kernels already guarantee (``lm_prefill(states=...)``,
  DESIGN.md §8) — tested token-for-token against cold decode.
* **Insertion** — on prefill completion the engine snapshots the state
  at the longest chunk-aligned prompt boundary and inserts it here.
  Snapshots are HOST trees (``StatePool.snapshot_slot(host=True)``
  semantics): hundreds of cached prefixes consume RAM, never HBM.
* **Eviction** — LRU under an explicit byte budget.  Per-entry bytes
  are measured from the actual leaves and cross-checked against the
  analytic ``repro.obs.costs`` state-bytes model
  (``state_bytes_for(cfg)``), which is also how a budget is sized
  ("N cached prefixes" -> bytes).
* **Integrity** — every entry carries a crc32 over its leaf bytes,
  verified on every hit; a corrupt entry (``cache.corrupt`` fault
  point, or a real bit flip) is dropped and the lookup falls through
  to shorter prefixes / cold prefill.  A corrupt cache can cost
  latency, never correctness.
"""

from __future__ import annotations

import collections
import dataclasses
import zlib
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from ..obs import Obs

# polynomial rolling hash over token ids: h_{i+1} = h_i * _BASE + tok
# mod 2^61-1.  Deterministic across processes (unlike hash()), cheap to
# extend one token at a time, and collision-checked by token comparison.
_MOD = (1 << 61) - 1
_BASE = 1_000_003


def rolling_hashes(tokens: np.ndarray, lengths: List[int]) -> List[int]:
    """Hashes of ``tokens[:n]`` for each n in ``lengths`` (ascending),
    in one O(len) pass."""
    out, h, done = [], 0, 0
    for n in lengths:
        for t in tokens[done:n]:
            h = (h * _BASE + int(t) + 1) % _MOD
        done = n
        out.append(h)
    return out


def tree_bytes(tree) -> int:
    """Total bytes of a host state snapshot's leaves."""
    import jax

    return int(sum(  # sync-point: host snapshot leaves, nbytes never syncs
        np.asarray(leaf).nbytes for leaf in jax.tree.leaves(tree)))


def tree_checksum(tree) -> int:
    """crc32 over every leaf's raw bytes (order = tree leaf order)."""
    import jax

    crc = 0
    for leaf in jax.tree.leaves(tree):
        crc = zlib.crc32(np.ascontiguousarray(leaf).tobytes(), crc)
    return crc


def state_bytes_for(cfg, *, max_len: int = 64) -> int:
    """Per-entry byte estimate from the analytic cost model: the whole
    LM's decode-state bytes for one sequence (``repro.obs.costs``).
    Sizing a budget as ``n_entries * state_bytes_for(cfg)`` caches
    about n_entries prefixes regardless of their token lengths — the
    O(1)-state property that makes this cache a dict, not an arena."""
    from ..obs.costs import model_cost

    return int(model_cost(cfg, mode="decode_step", seq_len=max_len)
               .state_bytes)


@dataclasses.dataclass
class CacheEntry:
    key: Tuple[int, int]          # (prefix_len, rolling hash)
    tokens: np.ndarray            # the exact prefix ids (collision guard)
    state: Any                    # host state pytree (numpy leaves)
    nbytes: int
    checksum: int
    hits: int = 0


class PrefixCache:
    """Longest-prefix -> state-snapshot cache with LRU byte budgeting.

    ``granularity`` is the chunk width prefixes are keyed at; the engine
    passes its op's chunk so cache boundaries coincide with the chunkwise
    kernels' natural resume points.  ``budget_bytes`` bounds HOST memory
    (entries are numpy trees); inserting past it evicts least-recently-
    used entries first.  ``namespace`` scopes keys to one model+params
    identity — always set it when one process serves several models.

    Thread-compat: all mutation happens on the engine drive loop; the
    async server shares that loop, so no lock is needed (same contract
    as ``Engine`` itself).
    """

    def __init__(self, *, granularity: int = 256,
                 budget_bytes: int = 1 << 30, namespace: str = "",
                 obs: Optional[Obs] = None, faults=None):
        if granularity < 1:
            raise ValueError(f"granularity must be >= 1: {granularity}")
        if budget_bytes < 0:
            raise ValueError(f"budget_bytes must be >= 0: {budget_bytes}")
        self.granularity = granularity
        self.budget_bytes = budget_bytes
        self.namespace = namespace
        self.faults = faults
        # key -> entry, ordered oldest-used first (OrderedDict LRU)
        self._entries: "collections.OrderedDict[Tuple[int, int], CacheEntry]" \
            = collections.OrderedDict()
        self._lengths: collections.Counter = collections.Counter()
        self.bytes = 0
        self._own_obs = obs is None
        self._declare_metrics(obs if obs is not None else Obs())

    def bind_obs(self, obs: Obs) -> None:
        """Re-home the cache's metric series into ``obs``.  The engine
        calls this for caches built without an explicit bundle, so one
        ``--metrics-out`` snapshot carries engine + scheduler + cache
        counters together."""
        self._own_obs = False
        self._declare_metrics(obs)

    def _declare_metrics(self, obs: Obs) -> None:
        self.obs = obs
        m = obs
        self._m_hits = m.counter(
            "cache_hits_total", "lookups that resumed from a snapshot")
        self._m_misses = m.counter(
            "cache_misses_total", "lookups with no usable prefix")
        self._m_inserts = m.counter(
            "cache_insertions_total", "entries inserted")
        self._m_evicted = m.counter(
            "cache_evicted_bytes_total", "bytes LRU-evicted over budget")
        self._m_corrupt = m.counter(
            "cache_corrupt_dropped_total",
            "entries dropped on checksum mismatch")
        self._m_entries = m.gauge("cache_entries", "live entries")
        self._m_bytes = m.gauge("cache_bytes", "live host bytes")
        self._m_hit_toks = m.histogram(
            "cache_hit_prefix_tokens", "prefix tokens served from cache",
            buckets=(16, 64, 256, 1024, 4096, 16384))

    def __len__(self) -> int:
        return len(self._entries)

    def clear(self) -> None:
        """Drop every entry (warmup epochs, tests).  Counters are
        cumulative and unaffected; the entry/byte gauges go to zero."""
        self._entries.clear()
        self._lengths.clear()
        self.bytes = 0
        self._m_entries.set(0.0)
        self._m_bytes.set(0.0)

    # -- keying -------------------------------------------------------------

    def _ns_seed(self) -> int:
        return zlib.crc32(self.namespace.encode()) % _MOD

    def _candidate_lengths(self, n_tokens: int,
                           max_prefix: Optional[int]) -> List[int]:
        """Chunk-aligned prefix lengths to probe, ascending.  Only
        lengths that exist in the cache are worth hashing."""
        cap = n_tokens if max_prefix is None else min(n_tokens, max_prefix)
        return [n for n in sorted(self._lengths)
                if n <= cap and self._lengths[n] > 0]

    def aligned_len(self, n_tokens: int) -> int:
        """Longest chunk-aligned prefix length strictly usable for a
        prompt of ``n_tokens`` (at least one token must remain to
        produce the first sampled logits)."""
        return ((n_tokens - 1) // self.granularity) * self.granularity

    # -- lookup / insert ----------------------------------------------------

    def _drop(self, entry: CacheEntry) -> None:
        self._entries.pop(entry.key, None)
        self._lengths[entry.key[0]] -= 1
        self.bytes -= entry.nbytes
        self._m_entries.set(float(len(self._entries)))
        self._m_bytes.set(float(self.bytes))

    def _corrupt_if_injected(self, entry: CacheEntry) -> None:
        """The ``cache.corrupt`` fault point: flip bytes in one leaf of
        the entry the lookup is about to return."""
        if self.faults is None:
            return
        import jax

        if self.faults.hit("cache.corrupt") is None:
            return
        # snapshot leaves may be read-only (jax.device_get): corrupt a
        # writable copy and splice it back into the entry's tree
        flat, treedef = jax.tree.flatten(entry.state)
        leaf = np.array(flat[0])
        buf = leaf.view(np.uint8).reshape(-1)
        buf[: max(1, buf.size // 16)] ^= 0xFF
        flat[0] = leaf
        entry.state = jax.tree.unflatten(treedef, flat)

    def lookup(self, tokens, *, max_prefix: Optional[int] = None
               ) -> Optional[Tuple[int, Any]]:
        """Longest verified cached prefix of ``tokens``.

        Returns ``(prefix_len, host_state)`` or None.  ``max_prefix``
        caps the usable length (the engine passes ``len(prompt) - 1`` so
        at least one suffix token remains to sample from).  Corrupt or
        hash-colliding entries are dropped/skipped and the next-shorter
        candidate is tried — a damaged cache degrades to cold prefill,
        never to wrong tokens.
        """
        toks = np.asarray(tokens).reshape(-1)
        lengths = self._candidate_lengths(len(toks), max_prefix)
        if not lengths:
            self._m_misses.inc()
            return None
        hashes = rolling_hashes(toks, lengths)
        seed = self._ns_seed()
        for n, h in zip(reversed(lengths), reversed(hashes)):
            entry = self._entries.get((n, (h + seed) % _MOD))
            if entry is None:
                continue
            if not np.array_equal(entry.tokens, toks[:n]):
                continue  # hash collision: content mismatch, keep probing
            self._corrupt_if_injected(entry)
            if tree_checksum(entry.state) != entry.checksum:
                self._drop(entry)
                self._m_corrupt.inc()
                self.obs.event("cache.corrupt_dropped", prefix_len=n)
                continue
            self._entries.move_to_end(entry.key)  # LRU touch
            entry.hits += 1
            self._m_hits.inc()
            self._m_hit_toks.observe(float(n))
            self.obs.event("cache.hit", prefix_len=n, hits=entry.hits)
            return n, entry.state
        self._m_misses.inc()
        return None

    def insert(self, tokens, state) -> bool:
        """Insert a host state snapshot for the chunk-aligned prefix
        ``tokens`` (insert-on-prefill-complete).  Refreshes LRU on
        re-insertion of a live key.  Returns False when the entry was
        rejected (misaligned length or larger than the whole budget)."""
        toks = np.asarray(tokens).reshape(-1).astype(np.int64)
        n = len(toks)
        if n == 0 or n % self.granularity != 0:
            return False
        nbytes = tree_bytes(state)
        if nbytes > self.budget_bytes:
            return False
        h = (rolling_hashes(toks, [n])[0] + self._ns_seed()) % _MOD
        key = (n, h)
        old = self._entries.get(key)
        if old is not None and np.array_equal(old.tokens, toks):
            self._entries.move_to_end(key)
            return True  # already cached: refresh recency, keep the entry
        if old is not None:
            self._drop(old)  # same key, different tokens: collision — replace
        entry = CacheEntry(key=key, tokens=toks, state=state, nbytes=nbytes,
                           checksum=tree_checksum(state))
        self._entries[key] = entry
        self._lengths[n] += 1
        self.bytes += nbytes
        self._m_inserts.inc()
        while self.bytes > self.budget_bytes and len(self._entries) > 1:
            _, lru = next(iter(self._entries.items()))
            if lru is entry:
                break
            self._drop(lru)
            self._m_evicted.inc(lru.nbytes)
            self.obs.event("cache.evicted", prefix_len=lru.key[0],
                           nbytes=lru.nbytes)
        self._m_entries.set(float(len(self._entries)))
        self._m_bytes.set(float(self.bytes))
        return True

    def stats(self) -> Dict[str, float]:
        hits = self._m_hits.total()
        misses = self._m_misses.total()
        return {
            "entries": float(len(self._entries)),
            "bytes": float(self.bytes),
            "hits": hits,
            "misses": misses,
            "hit_rate": hits / max(hits + misses, 1.0),
            "evicted_bytes": self._m_evicted.total(),
        }
