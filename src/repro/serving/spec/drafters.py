"""Drafters: who proposes the k tokens the target model verifies.

A ``Drafter`` mirrors the engine's slot lifecycle (``admit`` / ``commit``
/ ``evict``) and produces, per round, ``k`` draft tokens for a batch of
active slots (``propose``).  Two implementations:

* ``NGramDrafter`` — model-free prompt-lookup (Saxena; HF
  "prompt lookup decoding"): match the trailing n-gram of the committed
  context against its own history and propose the continuation of the
  most recent earlier occurrence.  Zero FLOPs, surprisingly strong on
  repetitive / extractive workloads, and the baseline every learned
  drafter must beat.
* ``HLADrafter`` — a small HLA draft LM with its OWN parameters and its
  OWN ``StatePool`` slots (one per engine slot), loadable from any
  ``configs/`` registry entry.  Drafting is one jitted device call per
  round batched over all slots: first a masked scan consumes the tokens
  the verifier committed since the last round (so the draft state tracks
  the committed context without ever keeping speculative tokens — the
  draft model's OWN rollback is simply "don't commit the draft-time
  states"), then k greedy/sampled single-token steps propose the block.
  Under a mesh the draft pool's states are placed by the same per-module
  ``*_state_axes`` declarations the target uses
  (``distributed.steps.state_shardings_for``), and its kernel calls go
  through ``shard_ops.call_sharded`` exactly like the target's.

``propose`` may return jax arrays (device-resident; the engine feeds them
straight into the verify block without a host sync) or numpy arrays.
"""

from __future__ import annotations

import abc
import contextlib
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ...models import lm, seq_op
from ...models.param import init_params
from ..sampling import SamplingConfig, probs, sample
from ..state_pool import StatePool
from .verify import make_replay


class Drafter(abc.ABC):
    """Slot-parallel draft-token source for speculative decoding."""

    #: True when ``propose`` returns per-token draft distributions (the
    #: warped q of speculative sampling); False means deterministic drafts
    #: (q = one-hot) — the verifier's accept rule adapts accordingly.
    emits_probs: bool = False
    #: Token-id space drafts come from, or None when proposals are always
    #: drawn from the committed context (n-gram) and thus always valid.
    #: The engine rejects drafters whose vocab differs from the target's —
    #: out-of-range draft ids would index the target embedding OOB.
    vocab: Optional[int] = None
    #: True when ``propose`` returns rows for EVERY pool slot (device
    #: drafters batched over the whole pool; inactive rows are garbage the
    #: verify round masks out).  Saves the engine a gather-then-scatter
    #: round trip per round on the latency-critical path.  False (host
    #: drafters) means rows align with ``slot_ids``.
    full_width: bool = False

    @abc.abstractmethod
    def admit(self, slot: int, tokens: Sequence[int]) -> None:
        """A request entered ``slot``; ``tokens`` = prompt + first sampled
        token (the committed context so far)."""

    @abc.abstractmethod
    def commit(self, slot: int, tokens: Sequence[int]) -> None:
        """The verifier committed ``tokens`` (accepted prefix + the
        corrected/bonus token) to a live slot."""

    @abc.abstractmethod
    def propose(self, slot_ids: Sequence[int], k: int) -> Tuple:
        """Draft ``k`` tokens for each slot in ``slot_ids``.

        Returns ``(drafts, q)``: drafts ``(len(slot_ids), k)`` int32 (jax
        or numpy; ``(pool_slots, k)`` when ``full_width``), and ``q``
        either ``None`` (deterministic) or the matching
        ``(..., k, vocab)`` draft distributions.
        """

    def evict(self, slot: int) -> None:  # optional cleanup
        return None


# --------------------------------------------------------------------------
# model-free: prompt-lookup n-gram drafter
# --------------------------------------------------------------------------


class NGramDrafter(Drafter):
    """Propose the continuation of the last earlier occurrence of the
    trailing n-gram (n from ``max_n`` down to ``min_n``) of the committed
    context; fall back to repeating the last token.  O(len(ctx) * n) per
    proposal on the host — negligible next to a model forward at serving
    block sizes.
    """

    emits_probs = False

    def __init__(self, max_n: int = 3, min_n: int = 1):
        if min_n < 1 or max_n < min_n:
            raise ValueError("need max_n >= min_n >= 1")
        self.max_n, self.min_n = max_n, min_n
        self._ctx = {}

    def admit(self, slot, tokens):
        self._ctx[slot] = list(int(t) for t in tokens)

    def commit(self, slot, tokens):
        self._ctx[slot].extend(int(t) for t in tokens)

    def evict(self, slot):
        self._ctx.pop(slot, None)

    def _draft_one(self, ctx: List[int], k: int) -> List[int]:
        for n in range(self.max_n, self.min_n - 1, -1):
            if len(ctx) < n + 1:
                continue
            pat = ctx[-n:]
            # rightmost earlier occurrence = most recent evidence (the
            # search range excludes the trailing n-gram itself, so every
            # match has a nonempty continuation)
            for i in range(len(ctx) - n - 1, -1, -1):
                if ctx[i:i + n] == pat:
                    cont = ctx[i + n:i + n + k]
                    while len(cont) < k:
                        cont.append(cont[-1])
                    return cont
        return [ctx[-1]] * k

    def propose(self, slot_ids, k):
        drafts = np.asarray(
            [self._draft_one(self._ctx[s], k) for s in slot_ids], np.int32
        )
        return drafts, None


# --------------------------------------------------------------------------
# model drafter: small HLA LM over its own state pool
# --------------------------------------------------------------------------


class HLADrafter(Drafter):
    """A small streaming-state draft LM sharing the engine's slot layout.

    ``cfg`` is any streaming-mixer ``ModelConfig`` (resolve one with
    ``configs.get_config(name, reduced=...)``); ``params`` its weights
    (randomly initialized from ``seed`` when omitted — fine for plumbing
    tests, useless acceptance: load trained draft weights for real
    serving).  ``sampling`` controls the draft law; non-greedy drafters
    emit their warped q so the verifier can run distribution-preserving
    speculative sampling.

    ``full_width``: proposals stay device-resident for ALL pool slots —
    the engine feeds them straight into the verify block with no host
    sync and no gather/scatter round trip.
    """

    full_width = True

    def __init__(self, cfg, params=None, *, slots: int, max_len: int,
                 k: int, sampling: SamplingConfig = SamplingConfig(),
                 seed: int = 0, mesh=None):
        op = seq_op.op_for(cfg)
        if not op.streaming or cfg.group_size:
            raise ValueError(
                f"HLADrafter needs a streaming-state op "
                f"{seq_op.streaming_op_names()}, got "
                f"op={op.name!r} group_size={cfg.group_size}"
            )
        self.cfg = cfg
        self.k = k
        self.sampling = sampling
        self.emits_probs = sampling.method != "greedy"
        self.vocab = cfg.vocab
        self.mesh = mesh
        if params is None:
            params = init_params(lm.lm_specs(cfg), jax.random.key(seed))
        self.params = params
        pool_shardings = None
        if mesh is not None:
            # draft-model states declared through the SAME per-module
            # *_state_axes scheme as the target's (DESIGN.md §9)
            from ...distributed import steps as steps_mod

            abstract = jax.eval_shape(
                lambda: lm.lm_init_states(cfg, slots, max_len)
            )
            pool_shardings = steps_mod.state_shardings_for(
                cfg, mesh, abstract
            )
        self.pool = StatePool(
            lambda n: lm.lm_init_states(cfg, n, max_len), slots,
            shardings=pool_shardings,
        )
        self.positions = jnp.zeros((slots, 1), jnp.int32)
        self.last = np.zeros(slots, np.int64)
        # committed tokens the draft state has not consumed yet (<= k+1
        # per slot between rounds: one round commits at most k+1 tokens)
        self._pending: List[List[int]] = [[] for _ in range(slots)]
        self.key = jax.random.key(seed + 1)

        scfg = sampling
        consume = make_replay(cfg)  # same masked scan as verify rollback

        def _propose(params, states, pending, pend_len, last_tok,
                     positions, key):
            # 1) masked consume of the last round's committed tokens
            states, positions = consume(
                params, states, pending, positions, pend_len
            )
            if pool_shardings is not None:
                states = jax.tree.map(
                    jax.lax.with_sharding_constraint, states, pool_shardings
                )

            # 2) k draft steps; the drafted-token state updates are NEVER
            # committed back (speculative state lives only in this scan —
            # the draft model's rollback is free)
            def draft(carry, key_j):
                st, pos, tok = carry
                logits, new_st, _ = lm.lm_apply(
                    params, tok, cfg, states=st, positions=pos,
                    mode="decode",
                )
                lg = logits[:, -1]
                nxt = sample(lg, key_j, scfg)
                qp = (probs(lg, scfg) if scfg.method != "greedy"
                      else jnp.zeros((lg.shape[0], 0), jnp.float32))
                return (new_st, pos + 1, nxt[:, None]), (nxt, qp)

            keys = jax.random.split(key, k)
            _, (drafts, qps) = jax.lax.scan(
                draft, (states, positions, last_tok), keys
            )
            # drafts: (k, slots) -> (slots, k); qps -> (slots, k, vocab)
            return states, positions, drafts.T, jnp.moveaxis(qps, 0, 1)

        self._propose = jax.jit(_propose)
        self._prefill = jax.jit(
            lambda params, prompt: lm.lm_prefill(params, prompt, cfg)[1]
        )

    def _mesh_ctx(self):
        return self.mesh if self.mesh is not None else (
            contextlib.nullcontext()
        )

    def admit(self, slot, tokens):
        toks = [int(t) for t in tokens]
        prompt = jnp.asarray(np.asarray(toks[:-1], np.int32)[None])
        with self._mesh_ctx():
            state1 = self._prefill(self.params, prompt)
            self.pool.write_slot(slot, state1)
        self.positions = self.positions.at[slot, 0].set(len(toks) - 1)
        self.last[slot] = toks[-1]
        self._pending[slot] = []

    def commit(self, slot, tokens):
        toks = [int(t) for t in tokens]
        # the draft state must end up having consumed everything except
        # the newest committed token (that token is the next model input)
        self._pending[slot].extend([int(self.last[slot])] + toks[:-1])
        if len(self._pending[slot]) > self.k + 1:
            raise RuntimeError(
                "draft state fell behind: propose() must run between "
                "commits (pending > k+1 tokens)"
            )
        self.last[slot] = toks[-1]

    def evict(self, slot):
        self._pending[slot] = []
        self.last[slot] = 0

    def propose(self, slot_ids, k):
        if k != self.k:
            raise ValueError(f"drafter built for k={self.k}, asked for {k}")
        slots = self.pool.slots
        width = self.k + 1
        pending = np.zeros((slots, width), np.int32)
        pend_len = np.zeros(slots, np.int32)
        for s in range(slots):
            p = self._pending[s]
            pending[s, :len(p)] = p
            pend_len[s] = len(p)
            self._pending[s] = []
        self.key, sub = jax.random.split(self.key)
        with self._mesh_ctx():
            states, positions, drafts, qps = self._propose(
                self.params, self.pool.states, jnp.asarray(pending),
                jnp.asarray(pend_len),
                jnp.asarray(self.last[:, None].astype(np.int32)),
                self.positions, sub,
            )
        self.pool.states = states
        self.positions = positions
        return drafts, (qps if self.emits_probs else None)


# --------------------------------------------------------------------------
# factory
# --------------------------------------------------------------------------


def build_drafter(spec, *, slots: int, max_len: int,
                  sampling: SamplingConfig, mesh=None,
                  target_cfg=None) -> Drafter:
    """Resolve ``SpecConfig.drafter`` to an instance.

    Accepts a ready ``Drafter`` instance, ``"ngram"``, or ``"lm"`` (loads
    ``spec.draft_arch`` from the configs registry; random params unless
    the caller hands the engine a prebuilt drafter).  ``target_cfg``
    enables the not-actually-smaller draft-model warning.
    """
    if isinstance(spec.drafter, Drafter):
        return spec.drafter
    if spec.drafter == "ngram":
        return NGramDrafter(max_n=spec.ngram_max, min_n=spec.ngram_min)
    if spec.drafter == "lm":
        from ...configs import get_config

        cfg = get_config(spec.draft_arch, reduced=spec.draft_reduced)
        if target_cfg is not None:
            draft_cost = cfg.n_layers * cfg.d_model**2
            target_cost = target_cfg.n_layers * target_cfg.d_model**2
            if draft_cost >= target_cost:
                import warnings

                warnings.warn(
                    f"draft model {cfg.name!r} "
                    f"({cfg.n_layers}L x {cfg.d_model}d) is not smaller "
                    f"than the target ({target_cfg.n_layers}L x "
                    f"{target_cfg.d_model}d): drafting costs as much as "
                    "decoding, so speculative decode cannot win — point "
                    "draft_arch at a smaller registry entry",
                    stacklevel=2,
                )
        return HLADrafter(
            cfg, params=None, slots=slots, max_len=max_len, k=spec.k,
            sampling=sampling, seed=spec.draft_seed, mesh=mesh,
        )
    raise ValueError(f"unknown drafter {spec.drafter!r}")
