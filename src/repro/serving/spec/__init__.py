"""Speculative decoding for the state-pool serving engine.

HLA's constant-size recurrent state makes it an unusually good target
substrate for speculative decoding (DESIGN.md §10):

* **verify is one prefill** — scoring k draft tokens is a single
  chunk-parallel ``lm_score_block`` call on the existing stateful
  kernels, not k serial decode steps;
* **rollback is one small tensor** — rejecting a continuation restores a
  per-slot state snapshot in O(state) (``StatePool.snapshot_slot`` /
  ``restore_slot``), instead of truncating a context-length KV cache.

Layering:

* ``drafters`` — the ``Drafter`` interface + ``NGramDrafter``
  (model-free prompt lookup) and ``HLADrafter`` (small HLA draft LM with
  its own params and ``StatePool`` slots);
* ``verify``   — chunk-parallel scoring, greedy and
  distribution-preserving speculative-sampling acceptance, and the
  masked-scan rollback replay;
* ``SpecConfig`` — the ``Engine(spec=...)`` knob.
"""

from __future__ import annotations

import dataclasses
from typing import Union

from .drafters import Drafter, HLADrafter, NGramDrafter, build_drafter
from .verify import make_replay, make_spec_round, make_verify


@dataclasses.dataclass(frozen=True)
class SpecConfig:
    """Speculative-decoding configuration for ``serving.Engine``."""

    k: int = 4  # draft tokens per round (the literature's gamma)
    drafter: Union[str, Drafter] = "ngram"  # "ngram" | "lm" | instance
    # "lm" drafter: any streaming-mixer entry of the configs registry
    draft_arch: str = "hla-1b"
    draft_reduced: bool = True
    draft_seed: int = 0
    # "ngram" drafter: trailing n-gram sizes tried, longest first
    ngram_max: int = 3
    ngram_min: int = 1
    # circuit breaker (DESIGN.md §12): a drafter exception — or
    # ``breaker_zero_rounds`` consecutive rounds in which NO draft token
    # was accepted — trips the engine from speculative to plain block
    # decode (greedy output is token-for-token unchanged either way).
    # After ``breaker_cooldown_blocks`` plain blocks the breaker goes
    # half-open: the drafter is resynced with each live slot's committed
    # context and probed for one round; success re-closes it, another
    # failure re-opens it for a fresh cooldown.
    breaker_zero_rounds: int = 4
    breaker_cooldown_blocks: int = 8


__all__ = [
    "Drafter", "HLADrafter", "NGramDrafter", "SpecConfig",
    "build_drafter", "make_replay", "make_spec_round", "make_verify",
]
