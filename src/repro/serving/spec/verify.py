"""Chunk-parallel exact verification + state rollback for speculative decode.

Verification reuses the serving prefill machinery (``lm.lm_score_block`` ->
``mode="prefill"`` -> one chunkwise call per layer: the fused Pallas
prefill for hla2/ahla on TPU via ``shard_ops.call_sharded``, the jnp
chunkwise path for hla3/hla3_paper/linattn/rwkv6) so scoring k draft
tokens costs ONE wide forward instead of k serial decode steps.  The block
fed to the target is

    [t_last, d_1, ..., d_k]          (k+1 tokens, per slot)

where ``t_last`` is the newest committed token.  ``logits[:, j]`` is then
the target's next-token distribution after consuming the committed context
plus ``d_1..d_j`` — the distribution plain decode would have sampled
``d_{j+1}`` from.  By the paper's Section-4 identity the chunkwise pass
reproduces the serial recurrence's activations, so these are the SAME
logits non-speculative decode produces (exactly in exact arithmetic).

Acceptance rules
----------------
* **greedy** — accept the longest prefix with ``argmax(logits[:, j]) ==
  d_{j+1}``; the token at the first mismatch (or the bonus token after a
  fully-accepted block) is ``argmax`` itself, so every committed token is
  by construction the one plain greedy decode emits: speculative greedy is
  token-for-token identical to plain greedy.
* **speculative sampling** (Leviathan et al. / Chen et al.) — accept
  ``d_j`` with probability ``min(1, p(d_j)/q(d_j))``; on the first
  rejection sample from the residual ``norm(max(p - q, 0))``; after a full
  acceptance sample the bonus from ``p``.  ``p`` and ``q`` are the WARPED
  distributions from ``serving.sampling.probs`` (temperature / top-k /
  top-p applied), which is required for the marginal law of every emitted
  token to equal plain sampling's.  A deterministic drafter (n-gram) is
  the ``q = one-hot`` special case: accept with probability ``p(d_j)``,
  residual = ``p`` with the draft token zeroed, renormalized.

State rollback
--------------
The prefill's returned states have consumed the WHOLE block — exactly the
post-round state when all k drafts are accepted (the common case on
drafter-friendly text), so full acceptance costs zero extra state work.
On rejection the round restores the pre-verify states (O(state): the pool
tree is immutable, the snapshot is a reference — ``StatePool.snapshot_slot``
/ ``restore_slot`` expose the same primitive to host-level callers) and
replays only each slot's accepted prefix with ``make_replay`` below: a
fixed-length masked scan of the SAME fused decode steps plain decode runs,
so the rolled-back state is bit-identical to non-speculative decode's.
``make_spec_round`` fuses verify + acceptance + rollback (a ``lax.cond``
arm that executes only on rejection rounds) + token/position advance into
one jitted call.  This is the payoff of the paper's constant-size state:
rollback never touches a KV cache, never grows with context, and costs
O(k) small steps only on the (rare) rejection path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ...models import lm
from ..sampling import SamplingConfig, probs


def _leading_run(ok: jax.Array) -> jax.Array:
    """Length of the leading all-True run per row.  ok: (B, k) bool."""
    return jnp.sum(jnp.cumprod(ok.astype(jnp.int32), axis=1), axis=1)


def select_slots(take, new_tree, old_tree):
    """Per-slot select over stacked LM decode states.

    Leaves are ``(layers, slots, ...)`` — slot axis 1 for every streaming
    arch — so ``take`` ``(slots,)`` broadcasts as ``(1, slots, 1, ...)``.
    """

    def sel(a, b):
        m = take.reshape((1, -1) + (1,) * (a.ndim - 2))
        return jnp.where(m, b, a)

    return jax.tree.map(sel, old_tree, new_tree)


def _pin(states, pool_shardings):
    if pool_shardings is None:
        return states
    return jax.tree.map(
        jax.lax.with_sharding_constraint, states, pool_shardings
    )


def make_verify(cfg, scfg: SamplingConfig, *, draft_probs: bool = False,
                pool_shardings=None):
    """Build the (jit-friendly) verify step for ``Engine``.

    Returns ``verify(params, states, tok_block, positions, key[, q_probs])
    -> (packed, new_states)`` where ``tok_block`` is ``(slots, k+1)`` =
    ``[last committed, drafts]``, ``positions`` is ``(slots, 1)``, and
    ``packed`` is ``(slots, k+2)`` int32: column 0 the number of accepted
    drafts ``m``, columns 1..k+2 the committed tokens (only the first
    ``m+1`` are meaningful) — one array so the engine does ONE host
    transfer per round.  ``new_states`` have consumed the full block
    (valid as-is only for fully-accepted slots; the engine rolls the rest
    back).  ``q_probs`` (``(slots, k, vocab)``, the drafter's warped
    distributions) is only taken when ``draft_probs=True``.
    """

    def _score(params, states, tok_block, positions):
        kp1 = tok_block.shape[1]
        pos = positions + jnp.arange(kp1, dtype=positions.dtype)[None, :]
        logits, new_states = lm.lm_score_block(
            params, tok_block, cfg, states=states, positions=pos
        )
        return logits, _pin(new_states, pool_shardings)

    if scfg.method == "greedy":

        def verify(params, states, tok_block, positions, key, *q):
            # greedy acceptance never consults the draft law — accept and
            # ignore a trailing q from probs-emitting drafters (e.g. a
            # sampling HLADrafter paired with a greedy engine)
            logits, new_states = _score(params, states, tok_block, positions)
            preds = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            # accepted drafts ARE the argmax predictions, so `preds` doubles
            # as the committed-token array: position j <= m holds exactly
            # the token plain greedy decode emits there.
            n_acc = _leading_run(preds[:, :-1] == tok_block[:, 1:])
            packed = jnp.concatenate([n_acc[:, None], preds], axis=1)
            return packed, new_states

        return verify

    def verify(params, states, tok_block, positions, key, q_probs=None):
        logits, new_states = _score(params, states, tok_block, positions)
        drafts = tok_block[:, 1:]  # (slots, k)
        p = probs(logits, scfg)  # (slots, k+1, vocab) warped target law
        pk = p[:, :-1]
        p_d = jnp.take_along_axis(pk, drafts[..., None], axis=-1)[..., 0]
        if q_probs is None:  # deterministic drafter: q = one-hot(draft)
            q_d = jnp.ones_like(p_d)
            resid = pk * (1.0 - jax.nn.one_hot(drafts, pk.shape[-1]))
        else:
            q_d = jnp.take_along_axis(
                q_probs, drafts[..., None], axis=-1
            )[..., 0]
            resid = jnp.maximum(pk - q_probs, 0.0)
        k_acc, k_res = jax.random.split(key)
        u = jax.random.uniform(k_acc, drafts.shape)
        # u*q <= p  <=>  u <= p/q without the 0/0 hazard
        n_acc = _leading_run(u * q_d <= p_d)
        # residual law at the rejection index; a zero residual means p == q
        # there (rejection probability 0) — any fallback works, use p
        rs = jnp.sum(resid, axis=-1, keepdims=True)
        resid = jnp.where(rs > 0.0, resid / jnp.maximum(rs, 1e-30), pk)
        dist = jnp.concatenate([resid, p[:, -1:]], axis=1)
        dist_m = jnp.take_along_axis(
            dist, n_acc[:, None, None], axis=1
        )[:, 0]
        corr = jax.random.categorical(
            k_res, jnp.log(dist_m + 1e-30), axis=-1
        ).astype(jnp.int32)
        jpos = jnp.arange(drafts.shape[1] + 1, dtype=jnp.int32)[None, :]
        drafts_pad = jnp.concatenate([drafts, drafts[:, -1:]], axis=1)
        committed = jnp.where(jpos == n_acc[:, None], corr[:, None],
                              drafts_pad)
        packed = jnp.concatenate([n_acc[:, None], committed], axis=1)
        return packed, new_states

    if draft_probs:
        return verify
    return lambda params, states, tok_block, positions, key: verify(
        params, states, tok_block, positions, key, None
    )


def make_replay(cfg):
    """Build the masked serial consume used for rollback AND for the
    draft model's committed-context catch-up.

    ``replay(params, states, toks, positions, n_consume) -> (states,
    positions)`` runs a fixed-length masked scan of single-token decode
    steps (the same fused ``mixer_step`` path plain decode uses —
    bit-identical states) over ``toks`` ``(slots, W)``, committing only
    each slot's first ``n_consume[slot]`` tokens' updates.  Fixed shapes
    => one trace regardless of where rejection landed; per-slot masking
    means one batched scan serves the whole pool (a single rolled-back
    slot passes slot-dim-1 trees and ``n_consume=(1,)``).
    """

    def replay(params, states, toks, positions, n_consume):
        def body(carry, j):
            st, pos = carry
            tok = jax.lax.dynamic_slice_in_dim(toks, j, 1, axis=1)
            _, new_st, _ = lm.lm_apply(
                params, tok, cfg, states=st, positions=pos, mode="decode"
            )
            take = j < n_consume  # (slots,)
            st = select_slots(take, new_st, st)
            pos = pos + take[:, None].astype(pos.dtype)
            return (st, pos), None

        (states, positions), _ = jax.lax.scan(
            body, (states, positions), jnp.arange(toks.shape[1])
        )
        return states, positions

    return replay


def make_spec_round(cfg, scfg: SamplingConfig, *, draft_probs: bool = False,
                    pool_shardings=None):
    """Fuse draft-scoring, acceptance, rollback, and bookkeeping advance
    into ONE jittable round — the engine's speculative hot path.

    ``round(params, states, tokens, positions, active, drafts, key[, q])
    -> (packed, finite, new_states, new_tokens, new_positions)``

    * ``packed`` — the verify output (``(slots, k+2)``: accepted count +
      committed tokens), the round's single host transfer;
    * ``finite`` — ``(slots,)`` bool, True where every inexact leaf of
      the slot's post-round state is fully finite (slot axis 1, the same
      layout contract ``select_slots`` relies on).  Fetched together
      with ``packed`` so poisoned-state quarantine (DESIGN.md §12) rides
      the round's existing host sync;
    * ``new_states`` — the verify pass's own final states when EVERY
      active slot accepted its whole block (they consumed exactly the
      committed tokens: rollback is free), else — under a ``lax.cond``
      that only executes on rejection rounds — the ``make_replay`` masked
      scan from the pre-verify states, each slot advanced by exactly its
      committed prefix;
    * ``new_tokens`` / ``new_positions`` — per-slot newest committed token
      and position advance, computed on device so the host never issues
      per-slot updates (inactive slots frozen).
    """
    verify = make_verify(cfg, scfg, draft_probs=draft_probs,
                         pool_shardings=None)
    replay = make_replay(cfg)

    def round_fn(params, states, tokens, positions, active, drafts, key,
                 *q):
        k = drafts.shape[1]
        tok_block = jnp.concatenate([tokens, drafts], axis=1)
        packed, ver_states = verify(
            params, states, tok_block, positions, key, *q
        )
        n_acc = packed[:, 0]
        n_comm = jnp.where(active, n_acc + 1, 0)
        any_reject = jnp.any(active & (n_acc < k))
        new_states = jax.lax.cond(
            any_reject,
            lambda _: replay(params, states, tok_block, positions,
                             n_comm)[0],
            lambda _: ver_states,
            operand=None,
        )
        if pool_shardings is not None:
            new_states = _pin(new_states, pool_shardings)
        # fused finiteness reduction over the post-round states: every
        # streaming state leaf is (layers, slots, ...) (the select_slots
        # contract), so reducing all axes but 1 yields per-slot flags
        finite = jnp.ones((tokens.shape[0],), bool)
        for leaf in jax.tree.leaves(new_states):
            if jnp.issubdtype(leaf.dtype, jnp.inexact):
                finite = finite & jnp.all(
                    jnp.isfinite(leaf),
                    axis=tuple(i for i in range(leaf.ndim) if i != 1),
                )
        last = jnp.take_along_axis(packed, (n_acc + 1)[:, None], axis=1)
        new_tokens = jnp.where(active[:, None], last.astype(tokens.dtype),
                               tokens)
        new_positions = positions + n_comm[:, None].astype(positions.dtype)
        return packed, finite, new_states, new_tokens, new_positions

    return round_fn
