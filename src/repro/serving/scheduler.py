"""Admission scheduler: priority classes, fair share, expiry, autoscaling.

Replaces the engine's plain FIFO deque (DESIGN.md §16).  The queue can
hold thousands of requests; a slot admission is a chunk-parallel prefill
(expensive), so WHAT gets the next slot is policy, not arrival order:

* **Priority order** — requests are drained by ``(priority class,
  absolute deadline, arrival)``.  Lower ``priority`` numbers drain
  first; within a class, the request whose deadline expires soonest
  (deadline *slack* ordering: all slacks shrink at the same rate, so
  the absolute deadline is a stable heap key); no-deadline requests
  rank last in their class and fall back to arrival order.
* **Per-tenant fair share** — within the winning priority class, the
  tenant with the fewest slots currently held is served first, so one
  chatty tenant cannot starve the rest of its class.  The engine calls
  ``release(tenant)`` on every terminal result to return the share.
* **Queued-deadline expiry** — ``expire()`` returns every queued
  request whose deadline has already passed; the engine finalizes them
  as ``status="timeout"`` on EVERY drive-loop tick.  A slot is never
  spent prefilling an already-expired request (regression-tested) and
  an expired request never waits for a slot to free to learn its fate.
* **Slot autoscaling** — ``target_slots()`` moves the engine's usable
  slot count between ``min_slots`` and ``max_slots``: queue depth
  scales up immediately (latency is at stake), emptiness scales down
  one slot per ``scale_down_ticks`` consecutive idle ticks
  (hysteresis — a burst arriving right after a scale-down would pay
  recompile-sized latency), and quarantine pressure (poisoned-state
  resets since the last tick) caps the target to contain a poisoning
  workload while it is investigated.

``sched.stall`` (``runtime.faults``) suppresses every admission for the
tick it fires on (``stalled()``, hit once per engine drive tick) —
deterministic pressure for expiry/backlog tests.
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
import math
import time
from typing import Dict, List, Optional

from ..obs import Obs


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    min_slots: int = 1
    max_slots: int = 4
    # consecutive empty-queue ticks before the target shrinks by one
    scale_down_ticks: int = 4
    # quarantines within the last tick that cap the target at min_slots
    quarantine_cap: int = 2

    def __post_init__(self):
        if not 1 <= self.min_slots <= self.max_slots:
            raise ValueError(
                f"need 1 <= min_slots <= max_slots: {self}"
            )
        if self.scale_down_ticks < 1 or self.quarantine_cap < 1:
            raise ValueError(
                f"need scale_down_ticks >= 1 and quarantine_cap >= 1: {self}"
            )


class Scheduler:
    """Priority admission queue + slot-count autoscaler.

    Requests enter via ``submit`` (tenant/priority/deadline read off the
    ``GenRequest``); the engine drains with ``expire`` -> ``pop`` each
    tick.  Pure host-side data structure: no jax, no device syncs.
    """

    def __init__(self, cfg: SchedulerConfig = SchedulerConfig(), *,
                 obs: Optional[Obs] = None, faults=None,
                 clock=time.perf_counter):
        self.cfg = cfg
        self.faults = faults
        self._clock = clock
        self._seq = itertools.count()
        # (priority, deadline_abs, seq) heap per tenant, plus one global
        # deadline heap for O(log n) expiry sweeps.  Entries are lazily
        # invalidated (rid -> None) instead of re-heapified.
        self._q: Dict[str, List] = {}
        self._by_rid: Dict[int, object] = {}
        self._deadlines: List = []
        self._arrivals: List = []  # (seq, item): oldest-live-arrival peek
        self._inflight: Dict[str, int] = {}
        self._idle_ticks = 0
        self._quarantines_last_tick = 0
        self._target = cfg.min_slots
        self.obs = obs if obs is not None else Obs()
        m = self.obs
        self._m_wait = m.histogram(
            "sched_queue_wait_seconds", "submit -> admission wall-clock")
        self._m_expired = m.counter(
            "sched_expired_total", "queued requests expired by deadline")
        self._m_promoted = m.counter(
            "sched_promotions_total",
            "admissions that jumped at least one earlier arrival")
        self._m_stalled = m.counter(
            "sched_stall_ticks_total", "ticks the stall fault suppressed")
        self._m_depth = m.gauge("sched_queue_depth", "queued requests")
        self._m_target = m.gauge("sched_slots_target",
                                 "autoscaler slot target")
        self._m_target.set(float(self._target))

    def __len__(self) -> int:
        return len(self._by_rid)

    # -- queue --------------------------------------------------------------

    @staticmethod
    def _tenant(req) -> str:
        return getattr(req, "tenant", None) or "default"

    def submit(self, req, *, now: Optional[float] = None) -> None:
        """Enqueue; priority/deadline/tenant come off the request."""
        if req.rid in self._by_rid:
            raise ValueError(f"request {req.rid} is already queued")
        now = self._clock() if now is None else now
        deadline = (now + req.deadline_s if req.deadline_s is not None
                    else math.inf)
        seq = next(self._seq)
        item = [int(getattr(req, "priority", 1)), deadline, seq, now, req]
        self._by_rid[req.rid] = item
        heapq.heappush(self._q.setdefault(self._tenant(req), []), item)
        heapq.heappush(self._arrivals, (seq, item))
        if deadline != math.inf:
            heapq.heappush(self._deadlines, (deadline, seq, item))
        self._m_depth.set(float(len(self._by_rid)))

    def cancel(self, rid: int):
        """Drop a queued request; returns it (or None if not queued).
        Lazy removal: the heap entry is tombstoned in place."""
        item = self._by_rid.pop(rid, None)
        if item is None:
            return None
        req, item[4] = item[4], None
        self._m_depth.set(float(len(self._by_rid)))
        return req

    def expire(self, *, now: Optional[float] = None) -> List:
        """Pop every queued request whose deadline has passed.  The
        engine finalizes these as ``timeout`` on the SAME tick — before
        any admission — so an expired request never consumes a prefill
        and never waits for a free slot to be discovered."""
        now = self._clock() if now is None else now
        out = []
        while self._deadlines and self._deadlines[0][0] <= now:
            _, _, item = heapq.heappop(self._deadlines)
            req = item[4]
            if req is None or req.rid not in self._by_rid:
                continue  # tombstone: already admitted/cancelled
            del self._by_rid[req.rid]
            item[4] = None
            out.append(req)
            self._m_expired.inc()
            self.obs.event("sched.expired", rid=req.rid,
                           priority=item[0])
        if out:
            self._m_depth.set(float(len(self._by_rid)))
        return out

    def stalled(self) -> bool:
        """The ``sched.stall`` fault point: the engine hits it ONCE per
        drive-loop tick; a firing suppresses every admission that tick
        (expiry still runs — a stalled scheduler must not hide expired
        requests)."""
        if self.faults is not None and \
                self.faults.hit("sched.stall") is not None:
            self._m_stalled.inc()
            self.obs.event("sched.stall", depth=len(self._by_rid))
            return True
        return False

    def _peek(self, tenant: str):
        """Live head of a tenant heap (drops tombstones)."""
        heap = self._q.get(tenant)
        while heap:
            item = heap[0]
            if item[4] is None:
                heapq.heappop(heap)
                continue
            return item
        if heap is not None and not heap:
            del self._q[tenant]
        return None

    def pop(self, *, now: Optional[float] = None):
        """Next request to admit, or None.

        Picks the best (priority, deadline, arrival) head among tenants,
        breaking priority ties toward the tenant holding the fewest
        slots (fair share).  Emits ``sched.promote`` + a counter when
        the winner jumped an earlier arrival — the audit trail for
        "why did my request wait".
        """
        while self._arrivals and self._arrivals[0][1][4] is None:
            heapq.heappop(self._arrivals)  # tombstones
        oldest_seq = self._arrivals[0][0] if self._arrivals else None
        best = None
        for tenant in list(self._q):
            item = self._peek(tenant)
            if item is None:
                continue
            share = self._inflight.get(tenant, 0)
            # order: priority class, then fair share, then deadline
            # urgency, then arrival
            rank = (item[0], share, item[1], item[2])
            if best is None or rank < best[0]:
                best = (rank, tenant, item)
        if best is None:
            return None
        _, tenant, item = best
        heapq.heappop(self._q[tenant])
        priority, _, seq, t_submit, req = item
        del self._by_rid[req.rid]
        item[4] = None
        now = self._clock() if now is None else now
        self._inflight[tenant] = self._inflight.get(tenant, 0) + 1
        self._m_wait.observe(max(now - t_submit, 0.0))
        self._m_depth.set(float(len(self._by_rid)))
        if seq != oldest_seq:
            self._m_promoted.inc()
            self.obs.event("sched.promote", rid=req.rid, priority=priority,
                           tenant=tenant)
        return req

    def release(self, req) -> None:
        """A request admitted via ``pop`` reached a terminal result:
        return its tenant's fair-share slot."""
        tenant = self._tenant(req)
        held = self._inflight.get(tenant, 0)
        if held > 1:
            self._inflight[tenant] = held - 1
        else:
            self._inflight.pop(tenant, None)

    # -- autoscaler ---------------------------------------------------------

    def note_quarantine(self, n: int = 1) -> None:
        """The engine reports poisoned-state resets; heavy quarantine
        pressure caps the slot target until a clean tick passes."""
        self._quarantines_last_tick += n

    def target_slots(self) -> int:
        """One autoscaler tick -> the engine's usable slot count.

        Scale-up is immediate (queued work is waiting); scale-down needs
        ``scale_down_ticks`` consecutive idle ticks per step (hysteresis
        against burst arrival); ``quarantine_cap`` or more quarantines
        since the last tick clamp to ``min_slots``.
        """
        c = self.cfg
        depth = len(self._by_rid)
        if self._quarantines_last_tick >= c.quarantine_cap:
            self._target = c.min_slots
            self._idle_ticks = 0
        elif depth > 0:
            self._target = min(c.max_slots,
                               max(self._target, c.min_slots) + depth)
            self._idle_ticks = 0
        else:
            self._idle_ticks += 1
            if self._idle_ticks >= c.scale_down_ticks:
                self._idle_ticks = 0
                self._target = max(c.min_slots, self._target - 1)
        self._quarantines_last_tick = 0
        self._m_target.set(float(self._target))
        return self._target
