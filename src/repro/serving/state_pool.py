"""Per-slot decode-state pool with structural slot-axis detection.

The old serving loop snapshotted the whole state tree and "restored" other
slots with a shape heuristic (``leaf.shape[1] == slots``) — which misfires
whenever an unrelated state dimension happens to equal the slot count, and
silently skips leaves without a slot axis at position 1.  ``StatePool``
instead *derives* each leaf's slot axis structurally: it abstractly
evaluates the state template at ``slots`` and ``slots + 1`` and takes the
(unique) axis whose extent changed.  Leaves whose shape does not depend on
the slot count (e.g. the KV cache's shared scalar ``length``) get no slot
axis and are left untouched by per-slot writes.

Admission and eviction are **scatter-based**: one
``lax.dynamic_update_slice`` per leaf at the detected axis — no full-tree
snapshot/restore, no host round-trips.

``snapshot_slot``/``restore_slot`` expose per-slot O(state) checkpointing
for speculative-decoding rollback (DESIGN.md §10): with the paper's
constant-size streaming states the rollback unit is a few small tensors
per layer, independent of context length — not a KV-cache truncation.
"""

from __future__ import annotations

from typing import Any, Callable, List, Optional

import jax
import jax.numpy as jnp


def tree_finite(tree) -> jnp.ndarray:
    """Scalar bool: every inexact leaf of ``tree`` is fully finite.

    Jit-safe; used by the engine's prefill to reject a poisoned admission
    on the sync that already fetches the first sampled token."""
    ok = jnp.asarray(True)
    for leaf in jax.tree.leaves(tree):
        if jnp.issubdtype(leaf.dtype, jnp.inexact):
            ok = ok & jnp.all(jnp.isfinite(leaf))
    return ok


def tree_finite_host(tree) -> bool:
    """Host-side ``tree_finite`` over an already-fetched (numpy) snapshot
    — no device work.  Gates prefix-cache insertion: a poisoned boundary
    state must never become a cache entry.  bf16 leaves are upcast for
    the check (ml_dtypes arrays are not numpy-``inexact``)."""
    import numpy as np

    for leaf in jax.tree.leaves(tree):
        arr = np.asarray(leaf)
        if jnp.issubdtype(arr.dtype, jnp.inexact) and \
                not np.isfinite(arr.astype(np.float32)).all():
            return False
    return True


def _slot_axis(shape_a, shape_b, slots: int) -> Optional[int]:
    """Axis along which ``shape_b`` (slots+1) grew out of ``shape_a`` (slots)."""
    if tuple(shape_a) == tuple(shape_b):
        return None
    if len(shape_a) != len(shape_b):
        raise ValueError(
            f"state leaf rank depends on the slot count: {shape_a} vs {shape_b}"
        )
    diffs = [i for i, (x, y) in enumerate(zip(shape_a, shape_b)) if x != y]
    if len(diffs) != 1 or shape_b[diffs[0]] != shape_a[diffs[0]] + 1:
        raise ValueError(
            f"ambiguous slot axis for state leaf {shape_a} -> {shape_b}"
        )
    return diffs[0]


class StatePool:
    """Owns the pooled decode states for ``slots`` concurrent requests.

    ``template_fn(n)`` builds the state pytree for ``n`` slots (e.g.
    ``lambda n: lm.lm_init_states(cfg, n, max_len)``).  It is evaluated
    abstractly (``jax.eval_shape``) at ``slots`` and ``slots + 1`` to
    detect slot axes, and concretely once at ``slots`` for the pool.

    ``shardings`` (optional NamedSharding pytree matching the pooled state
    tree, from ``distributed.steps.state_shardings_for``) places the pool
    explicitly on a mesh — slots over the data axis, heads over the model
    axis — and pins every scatter write's output layout so admissions
    never let GSPMD drift the pool back to replicated.
    """

    def __init__(self, template_fn: Callable[[int], Any], slots: int,
                 shardings=None):
        if slots < 1:
            raise ValueError("need at least one slot")
        self.slots = slots
        self._template_fn = template_fn
        self.shardings = shardings
        if shardings is None:
            self.states = template_fn(slots)
        else:
            # born sharded: never materialize the full pool replicated on
            # one device (the transient could exceed a single device's HBM
            # even when the sharded steady state fits)
            self.states = jax.jit(
                lambda: template_fn(slots), out_shardings=shardings
            )()
        shapes_n = jax.eval_shape(lambda: template_fn(slots))
        shapes_n1 = jax.eval_shape(lambda: template_fn(slots + 1))
        leaves_n, self._treedef = jax.tree.flatten(shapes_n)
        leaves_n1 = jax.tree.leaves(shapes_n1)
        self.slot_axes: List[Optional[int]] = [
            _slot_axis(a.shape, b.shape, slots)
            for a, b in zip(leaves_n, leaves_n1)
        ]

        axes = self.slot_axes

        def _write(pool_leaves, new_leaves, slot):
            zero = jnp.zeros_like(slot)
            out = []
            for ax, pooled, new in zip(axes, pool_leaves, new_leaves):
                if ax is None:
                    out.append(pooled)
                    continue
                starts = [zero] * pooled.ndim
                starts[ax] = slot
                out.append(
                    jax.lax.dynamic_update_slice(
                        pooled, new.astype(pooled.dtype), tuple(starts)
                    )
                )
            return out

        def _read(pool_leaves, slot):
            zero = jnp.zeros_like(slot)
            out = []
            for ax, pooled in zip(axes, pool_leaves):
                if ax is None:
                    out.append(pooled)
                    continue
                starts = [zero] * pooled.ndim
                starts[ax] = slot
                sizes = list(pooled.shape)
                sizes[ax] = 1
                out.append(
                    jax.lax.dynamic_slice(pooled, tuple(starts), tuple(sizes))
                )
            return out

        if shardings is None:
            self._write = jax.jit(_write)
        else:
            self._write = jax.jit(
                _write, out_shardings=jax.tree.leaves(shardings)
            )
        self._read = jax.jit(_read)

    # -- tree plumbing ------------------------------------------------------

    def _flatten(self, tree):
        leaves, td = jax.tree.flatten(tree)
        if td != self._treedef:
            raise ValueError(
                "state tree structure does not match the pool template"
            )
        return leaves

    def empty_slot_state(self):
        """A fresh single-slot state (what an admitted request starts from)."""
        return self._template_fn(1)

    # -- scatter admit / evict ---------------------------------------------

    def write_slot(self, slot: int, state) -> None:
        """Scatter a single-slot state (slot-dim 1 leaves) into ``slot``.

        Only the target slot's data changes; leaves without a slot axis
        (shared across slots) are left as-is.
        """
        new_leaves = self._write(
            self._flatten(self.states), self._flatten(state),
            jnp.int32(slot),
        )
        self.states = jax.tree.unflatten(self._treedef, new_leaves)

    def read_slot(self, slot: int, states=None):
        """Gather ``slot``'s state as a single-slot tree (slot dims = 1).

        ``states`` reads from an alternate pooled tree with the pool's
        structure (e.g. a snapshot taken before a speculative-verify
        round) instead of the live pool.
        """
        src = self.states if states is None else states
        leaves = self._read(self._flatten(src), jnp.int32(slot))
        return jax.tree.unflatten(self._treedef, leaves)

    def reset_slot(self, slot: int) -> None:
        """Zero a slot (eviction)."""
        zeros = jax.tree.map(jnp.zeros_like, self.empty_slot_state())
        self.write_slot(slot, zeros)

    # -- health -------------------------------------------------------------

    def finite_mask(self, states=None) -> jnp.ndarray:
        """``(slots,)`` bool: True where every inexact state leaf of that
        slot is fully finite — the fused device-side reduction behind
        poisoned-state quarantine (DESIGN.md §12).

        Jit-safe: the engine computes it INSIDE the decode block so the
        flags ride the block's existing once-per-block host transfer —
        detecting a NaN-poisoned slot costs zero extra round trips.
        Leaves without a slot axis are shared across slots, so a
        non-finite shared leaf poisons every slot (there is no smaller
        recovery unit).  Integer leaves cannot be non-finite and are
        skipped.
        """
        src = self.states if states is None else states
        ok = jnp.ones((self.slots,), bool)
        for ax, leaf in zip(self.slot_axes, self._flatten(src)):
            if not jnp.issubdtype(leaf.dtype, jnp.inexact):
                continue
            fin = jnp.isfinite(leaf)
            if ax is None:
                ok = ok & jnp.all(fin)
            else:
                ok = ok & jnp.all(
                    fin, axis=tuple(i for i in range(leaf.ndim) if i != ax)
                )
        return ok

    # -- snapshot / rollback (speculative decoding) -------------------------

    def snapshot_slot(self, slot: int, *, host: bool = False):
        """O(state) snapshot of one slot's decode state.

        This is what makes rejection in speculative decoding cheap for
        constant-state architectures: the entire rollback unit is one
        small state tuple per layer (KiB-scale), gathered with a
        ``dynamic_slice`` per leaf — no KV-cache truncation, no tree
        surgery, no growth with context length.

        ``host=True`` returns numpy leaves instead of device arrays:
        long-lived snapshots (the prefix/state cache holds hundreds of
        them) then live in host RAM and consume zero HBM — and they stay
        valid across pool resharding.  The transfer is a deliberate
        device sync; callers on the hot path should keep ``host=False``.
        """
        snap = self.read_slot(slot)
        if not host:
            return snap
        return jax.device_get(snap)  # sync-point: host-RAM state snapshot

    def restore_slot(self, slot: int, snapshot) -> None:
        """Roll ``slot`` back to ``snapshot`` (from ``snapshot_slot`` — host
        or device — or a replayed correction) in O(state): one scatter
        write per leaf.  Host (numpy) snapshots are re-placed as part of
        the write: the jitted scatter's ``out_shardings`` pin the result
        to the pool's NamedShardings, so a cache entry snapshotted from
        one mesh layout restores correctly onto the pool's current one.
        Other slots' states are untouched, so a rejected continuation
        never perturbs concurrently-decoding requests.
        """
        self.write_slot(slot, snapshot)
