"""Inference engine: chunk-parallel prefill, fused decode, state-pool
continuous batching (DESIGN.md §8).

Layering:

* ``sampling``   — seeded device-side token sampling (greedy / temperature
                   / top-k), shared by the engine and the examples;
* ``state_pool`` — per-slot decode-state ownership with *structural*
                   slot-axis detection and scatter-based admit/evict;
* ``engine``     — the continuous-batching loop: admissions prefill whole
                   prompts in one chunk-parallel kernel call per layer,
                   decode runs in step-locked device blocks with one host
                   sync per block;
* ``spec``       — speculative decoding: drafters (n-gram / small HLA
                   LM), chunk-parallel exact verification, and
                   state-snapshot rollback (DESIGN.md §10);
* ``cache``      — content-addressed prefix/state cache: a cached
                   prompt prefix is ONE O(1) state snapshot, looked up
                   by rolling hash at chunk granularity and resumed
                   exactly via the chunkwise carry identity
                   (DESIGN.md §16);
* ``scheduler``  — priority admission queue (priority class / deadline
                   slack / tenant fair share), queued-deadline expiry,
                   and slot-count autoscaling with hysteresis;
* ``server``     — asyncio streaming facade: per-token async
                   generators over the once-per-block sync, with
                   consumer backpressure and graceful drain.

The engine is also a failure-domain boundary (DESIGN.md §12): per-request
statuses (``ok``/``error``/``timeout``/``cancelled``), deadline/cancel
lifecycle, poisoned-state quarantine via fused finiteness checks, and a
circuit breaker degrading speculative decode to plain blocks — all
deterministically testable through ``runtime.faults``.

``launch.serve`` is a thin CLI over ``engine.Engine``.
"""

from .cache import PrefixCache, state_bytes_for  # noqa: F401
from .engine import Engine, GenRequest, GenResult  # noqa: F401
from .sampling import SamplingConfig, probs, sample  # noqa: F401
from .scheduler import Scheduler, SchedulerConfig  # noqa: F401
from .server import AsyncServer  # noqa: F401
from .spec import (  # noqa: F401
    Drafter,
    HLADrafter,
    NGramDrafter,
    SpecConfig,
)
from .state_pool import StatePool  # noqa: F401
