"""Gated Linear Attention (GLA): per-token, per-channel gated decay.

The registry's worked example (DESIGN.md §11): a NEW causal streaming
mixer added purely through the public ``seq_op.register_op`` entry point
— it trains, chunk-parallel prefills, continuously-batch decodes, and
shards with ZERO edits to ``models/lm.py``, ``serving/engine.py`` or
``distributed/steps.py``.

The operator (Yang et al., "Gated Linear Attention Transformers with
Hardware-Efficient Training"; PAPERS.md) generalizes the HLA family's
scalar per-head decay to a data-dependent per-channel gate:

    S_t = diag(a_t) S_{t-1} + k_t v_t^T          a_t in (0, 1)^{d_k}
    o_t = S_t^T q_t

with ``a_t = sigmoid(low_rank(x_t))^(1/tau)`` (tau keeps the gate near 1
at init so early training does not forget).  Chunk-parallel form, exactly
the two-level skeleton of the HLA scans (intra-chunk masked matmul in
cumulative log-gate space, sequential carry across chunks):

    o_t = (q_t ⊙ e^{c_t}) S_0
        + sum_{j<=t} <q_t ⊙ e^{c_t - c_j}, k_j> v_j,   c_t = sum_{i<=t} log a_i
    S_w = e^{c_w} ⊙_rows S_0 + sum_j (k_j ⊙ e^{c_w - c_j}) v_j^T

The ``exp(±c)`` factorization is kept in fp32 range by clamping the
per-token log-gate at ``LOG_A_MIN`` and fixing the chunk width at
``GLA_CHUNK`` (|c| <= 32 * 2.5 = 80 < log(fp32 max) ~ 88 — same bound as
the RWKV-6 chunk path).
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..distributed.sharding import constrain
from .blocks import dense_apply, dense_specs
from .param import Axes, Spec
from . import seq_op

LOG_A_MIN = -2.5  # per-token floor: a_t >= e^-2.5 ~ 0.08 already "forget"
GLA_CHUNK = 32  # fixed: bounds |cumsum(log a)| for the exp factorization
GATE_TAU = 16.0  # gate temperature (GLA paper): a = sigmoid(z)^(1/tau)


class GLAState(NamedTuple):
    S: jax.Array  # (B, H, dk, dv)


def gla_init_state(batch_shape, d, dv, dtype=jnp.float32) -> GLAState:
    return GLAState(S=jnp.zeros(batch_shape + (d, dv), dtype))


def gla_specs(cfg):
    d, H, dh = cfg.d_model, cfg.n_heads, cfg.head_dim
    lora = max(16, d // 16)
    return {
        "wq": dense_specs(d, H * dh, axes=("embed", "q_heads_flat")),
        "wk": dense_specs(d, H * dh, axes=("embed", "q_heads_flat")),
        "wv": dense_specs(d, H * dh, axes=("embed", "q_heads_flat")),
        # low-rank data-dependent gate; a0 ~ 4 => a ~ sigmoid(4)^(1/16)
        # ~ 0.9989 per token at init (slow forgetting)
        "wa_a": dense_specs(d, lora, axes=("embed", None)),
        "wa_b": dense_specs(lora, H * dh, axes=(None, "q_heads_flat")),
        "a0": Spec((H * dh,), ("q_heads_flat",), init="constant", const=4.0),
        "out_scale": Spec((H, dh), ("q_heads", "head_dim"), init="ones"),
        "wo": dense_specs(H * dh, d, axes=("q_heads_flat", "embed")),
    }


def _project(p, x, cfg):
    """(q, k, v, log_a), each (B, H, n, dh) fp32, row layout like HLA."""
    B, n, _ = x.shape
    H, dh = cfg.n_heads, cfg.head_dim

    def heads(name):
        return dense_apply(p[name], x).reshape(B, n, H, dh).swapaxes(1, 2)

    spec = ("batch", "q_heads", None, None)
    q = constrain(heads("wq").astype(jnp.float32) * (dh**-0.5), spec)
    k = constrain(heads("wk").astype(jnp.float32), spec)
    v = constrain(heads("wv").astype(jnp.float32), spec)
    z = dense_apply(p["wa_b"], dense_apply(p["wa_a"], x)).astype(jnp.float32)
    z = z + p["a0"].astype(jnp.float32)[None, None]
    # log a = log sigmoid(z) / tau, clamped into the chunk-stable range
    log_a = jnp.clip(
        jax.nn.log_sigmoid(z) / GATE_TAU, LOG_A_MIN, -1e-6
    ).reshape(B, n, H, dh)
    return q, k, v, constrain(log_a.swapaxes(1, 2), spec)


def gla_chunkwise(q, k, v, log_a, *, chunk: int = GLA_CHUNK,
                  state: Optional[GLAState] = None):
    """Chunk-parallel gated linear attention.  Returns (o, final_state).

    Zero-padding the tail chunk is exact: padded log-gates are 0 (a = 1,
    no decay) and padded keys are 0 (no state contribution).
    """
    B, H, n, dk = q.shape
    dv = v.shape[-1]
    w = min(chunk, n)
    pad = (w - n % w) % w
    if pad:
        pads = ((0, 0), (0, 0), (0, pad), (0, 0))
        q, k, v = (jnp.pad(t, pads) for t in (q, k, v))
        log_a = jnp.pad(log_a, pads)
    npad = n + pad
    nc = npad // w

    def chunks(t):
        return jnp.moveaxis(t.reshape(B, H, nc, w, t.shape[-1]), 2, 0)

    qc, kc, vc, lac = map(chunks, (q, k, v, log_a))
    S0 = (
        state.S.astype(jnp.float32)
        if state is not None
        else jnp.zeros((B, H, dk, dv), jnp.float32)
    )
    tril = jnp.tril(jnp.ones((w, w), jnp.float32))  # j <= t (diag incl.)

    def body(S, inp):
        q_, k_, v_, la_ = inp  # (B, H, w, .)
        c = jnp.cumsum(la_, axis=2)  # inclusive cumulative log-gates
        qs = q_ * jnp.exp(c)
        scores = jnp.einsum("bhtd,bhjd->bhtj", qs, k_ * jnp.exp(-c))
        y = jnp.einsum("bhtj,bhje->bhte", scores * tril, v_)
        y = y + jnp.einsum("bhtd,bhde->bhte", qs, S)
        c_end = c[..., -1:, :]  # (B, H, 1, dk)
        Snew = jnp.exp(c_end[..., 0, :])[..., None] * S + jnp.einsum(
            "bhjd,bhje->bhde", k_ * jnp.exp(c_end - c), v_
        )
        return Snew, y

    Sf, ys = jax.lax.scan(body, S0, (qc, kc, vc, lac))
    o = jnp.moveaxis(ys, 0, 2).reshape(B, H, npad, dv)[:, :, :n]
    return o, GLAState(S=Sf)


def gla_step(state: GLAState, q_t, k_t, v_t, log_a_t):
    """One-token recurrence.  q_t/k_t/v_t/log_a_t: (B, H, dh)."""
    S = state.S.astype(jnp.float32)
    S = jnp.exp(log_a_t.astype(jnp.float32))[..., None] * S + (
        k_t.astype(jnp.float32)[..., :, None]
        * v_t.astype(jnp.float32)[..., None, :]
    )
    o = jnp.einsum("bhd,bhde->bhe", q_t.astype(jnp.float32), S)
    return GLAState(S=S.astype(state.S.dtype)), o


def _out_norm(p, o, cfg, eps=1e-6):
    """Per-head RMS norm + learned scale (as the HLA mixer sublayer)."""
    o32 = o.astype(jnp.float32)
    var = jnp.mean(o32 * o32, axis=-1, keepdims=True)
    o32 = o32 * jax.lax.rsqrt(var + eps)
    return o32 * p["out_scale"][None, :, None, :]


def _gla_forward(p, x, cfg, *, state=None, want_state=False, positions=None):
    B, n, _ = x.shape
    q, k, v, log_a = _project(p, x, cfg)
    o, st = gla_chunkwise(q, k, v, log_a, state=state)
    o = _out_norm(p, o, cfg).astype(x.dtype)
    o = o.swapaxes(1, 2).reshape(B, n, cfg.n_heads * cfg.head_dim)
    o = constrain(o, ("batch", None, "q_heads_flat"))
    return dense_apply(p["wo"], o), st


def _gla_step(p, x_t, state, cfg, *, positions=None):
    B = x_t.shape[0]
    q, k, v, log_a = _project(p, x_t, cfg)  # (B, H, 1, dh)
    state, o = gla_step(
        state, q[..., 0, :], k[..., 0, :], v[..., 0, :], log_a[..., 0, :]
    )
    o = _out_norm(p, o[..., None, :], cfg).astype(x_t.dtype)
    o = o.swapaxes(1, 2).reshape(B, 1, cfg.n_heads * cfg.head_dim)
    return dense_apply(p["wo"], o), state


def _gla_cost_model(cfg, *, mode, seq_len, batch):
    """Analytic state-math costs (the registry's ``cost_model`` worked
    example; contract in ``seq_op.SequenceOp`` + DESIGN.md §15).

    The chunk width is FIXED at ``GLA_CHUNK`` (the exp-factorization
    range bound), not ``cfg.hla.chunk`` — which is exactly why this op
    carries its own hook instead of relying on the generic family table.
    Per token per head: intra-chunk scores + apply cost ``2c(dk+dv)``,
    the gated carry update/readout ``6·dk·dv``; decode is the O(1)
    recurrence ``5·dk·dv`` (gate-decay, outer product, readout).
    """
    H, dk, dv = cfg.n_heads, cfg.head_dim, cfg.head_dim
    if mode == "decode_step":
        return {"state_flops_per_token": H * 5.0 * dk * dv}
    c = min(GLA_CHUNK, seq_len)
    return {"state_flops_per_token": H * (2.0 * c * (dk + dv)
                                          + 6.0 * dk * dv)}


seq_op.register_op(seq_op.SequenceOp(
    name="gla",
    specs=gla_specs,
    forward=_gla_forward,
    step=_gla_step,
    cost_model=_gla_cost_model,
    init_state=lambda cfg, B, *, max_len=0, dtype=None: gla_init_state(
        (B, cfg.n_heads), cfg.head_dim, cfg.head_dim,
        jnp.float32 if dtype is None else dtype,
    ),
    state_axes=lambda cfg: GLAState(
        S=Axes(("batch", "q_heads", None, None))
    ),
    streaming=True,
    spec_decodable=True,
))
