"""Mixture-of-Experts FFN: top-k routing, sort-based capacity dispatch.

Scale-aware design (DESIGN.md §4): no (T, E, C) one-hot tensors, and no
*global* sort — dispatch is vmapped per batch row, so each DP shard sorts
only its local tokens (a global argsort would force GSPMD to all-gather
the whole token set; found via the dry-run memory analysis).  Tokens are
argsorted by expert id within the row, positioned inside their expert
segment via a searchsorted offset, and scattered into a dense
(B, E, C_row, d) buffer; expert weights live on the "experts" axis (mesh
"model") so the (batch x experts) einsum materializes as all-to-all-style
collectives.  Capacity is per-row: C_row = ceil(k * n * cf / E)
(Switch-style; the dropped fraction is controlled by capacity_factor).

Aux load-balance loss (Switch-style) is returned for the train loss.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..distributed.sharding import constrain
from .blocks import dense_specs
from .param import Spec


def moe_specs(cfg):
    d = cfg.d_model
    mc = cfg.moe
    E, ff = mc.n_experts, mc.d_ff
    if cfg.mlp == "swiglu":
        expert = {
            "wi_gate": Spec((E, d, ff), ("experts", "embed", "expert_ff")),
            "wi_up": Spec((E, d, ff), ("experts", "embed", "expert_ff")),
            "wo": Spec((E, ff, d), ("experts", "expert_ff", "embed")),
        }
    else:
        expert = {
            "wi": Spec((E, d, ff), ("experts", "embed", "expert_ff")),
            "wo": Spec((E, ff, d), ("experts", "expert_ff", "embed")),
        }
    return {"router": dense_specs(d, E, axes=("embed", "experts")), **expert}


def _expert_ffn(p, x, act):
    """x: (B, E, C, d) -> (B, E, C, d) with per-expert weights."""
    if act == "swiglu":
        g = jnp.einsum("becd,edf->becf", x, p["wi_gate"].astype(x.dtype))
        u = jnp.einsum("becd,edf->becf", x, p["wi_up"].astype(x.dtype))
        h = jax.nn.silu(g) * u
    else:
        h = jnp.einsum("becd,edf->becf", x, p["wi"].astype(x.dtype))
        if act == "squared_relu":
            h = jnp.square(jax.nn.relu(h))
        else:
            h = jax.nn.gelu(h)
    return jnp.einsum("becf,efd->becd", h, p["wo"].astype(x.dtype))


def _dispatch_row(xt, gate_e, gate_w, E, C):
    """One row, GATHER-only (scatters of d-wide rows lower terribly —
    found via dry-run memory analysis).  xt (n, d); gate_e/w (n, K).

    Returns (buf (E*C, d), dest_tok (n*K,) slot id per token-k in original
    order, E*C = dropped)."""
    n, d = xt.shape
    K = gate_e.shape[-1]
    nK = n * K
    e_flat = gate_e.reshape(-1)
    tok = jnp.repeat(jnp.arange(n), K)
    order = jnp.argsort(e_flat, stable=True)
    se, stok = e_flat[order], tok[order]
    starts = jnp.searchsorted(se, jnp.arange(E), side="left")
    ends = jnp.concatenate([starts[1:], jnp.array([nK])])
    # slot (e, c) <- sorted position starts[e] + c (valid while < ends[e])
    slot_pos = starts[:, None] + jnp.arange(C)[None, :]  # (E, C)
    slot_valid = slot_pos < ends[:, None]
    slot_tok = stok[jnp.clip(slot_pos, 0, nK - 1)]
    buf = xt[slot_tok.reshape(-1)] * slot_valid.reshape(-1, 1).astype(xt.dtype)
    # per token-k slot id (original order) for the combine gather
    pos = jnp.arange(nK) - starts[se]
    keep = pos < C
    dest_sorted = jnp.where(keep, se * C + pos, E * C)
    inv = jnp.argsort(order, stable=True)
    dest_tok = dest_sorted[inv]  # (n*K,)
    return buf, dest_tok


def _combine_row(y, dest_tok, gate_w, n, dtype):
    """y (E*C, d); dest_tok (n*K,); gate_w (n, K) -> (n, d).  Gather-only."""
    K = gate_w.shape[-1]
    valid = (dest_tok < y.shape[0])[:, None]
    rows = y[jnp.clip(dest_tok, 0, y.shape[0] - 1)] * valid.astype(y.dtype)
    rows = rows.reshape(n, K, -1)
    return jnp.einsum("nkd,nk->nd", rows, gate_w.astype(rows.dtype)).astype(dtype)


def moe_apply(p, x, cfg):
    """x: (B, n, d).  Returns (y, aux_loss)."""
    B, n, d = x.shape
    mc = cfg.moe
    E, K = mc.n_experts, mc.top_k

    logits = jnp.einsum(
        "bnd,de->bne", x.astype(jnp.float32),
        p["router"]["kernel"].astype(jnp.float32),
    )
    probs = jax.nn.softmax(logits, axis=-1)  # (B, n, E)
    gate_w, gate_e = jax.lax.top_k(probs, K)
    gate_w = gate_w / jnp.maximum(jnp.sum(gate_w, -1, keepdims=True), 1e-9)

    # Switch aux loss over all tokens
    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.mean(
        jax.nn.one_hot(gate_e[..., 0], E, dtype=jnp.float32), axis=(0, 1)
    )
    aux = mc.aux_loss_coef * E * jnp.sum(me * ce)

    C = max(1, int(-(-K * n * mc.capacity_factor // E)))  # per-row capacity

    buf, dest_tok = jax.vmap(
        lambda xr, er, wr: _dispatch_row(xr, er, wr, E, C)
    )(x, gate_e, gate_w)
    buf = constrain(
        buf.reshape(B, E, C, d), ("batch", "experts", None, None)
    )
    y = _expert_ffn(p, buf, cfg.mlp)
    y = constrain(y, ("batch", "experts", None, None)).reshape(B, E * C, d)
    out = jax.vmap(
        lambda yr, dr, wr: _combine_row(yr, dr, wr, n, x.dtype)
    )(y, dest_tok, gate_w)
    return out, aux


def moe_dense_oracle(p, x, cfg):
    """O(T*E) reference: every expert on every token, then top-k combine.

    Test-only — verifies routing/dispatch/combine for small shapes.
    """
    B, n, d = x.shape
    mc = cfg.moe
    E, K = mc.n_experts, mc.top_k
    logits = jnp.einsum(
        "bnd,de->bne", x.astype(jnp.float32),
        p["router"]["kernel"].astype(jnp.float32),
    )
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, gate_e = jax.lax.top_k(probs, K)
    gate_w = gate_w / jnp.maximum(jnp.sum(gate_w, -1, keepdims=True), 1e-9)
    # run every expert on every token: (B, E, n, d)
    xb = jnp.broadcast_to(x[:, None], (B, E, n, d))
    all_out = _expert_ffn(p, xb, cfg.mlp)  # (B, E, n, d)
    out = jnp.zeros((B, n, d), jnp.float32)
    for kk in range(K):
        idx = gate_e[..., kk]  # (B, n)
        sel = jnp.take_along_axis(
            all_out, idx[:, None, :, None], axis=1
        )[:, 0]
        out = out + gate_w[..., kk : kk + 1] * sel.astype(jnp.float32)
    return out.astype(x.dtype)
