"""Model configuration dataclasses (the framework's config system)."""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int  # per-expert hidden size
    capacity_factor: float = 1.25
    every: int = 1  # every-th layer is MoE (jamba: 2); 1 = all layers
    aux_loss_coef: float = 0.01


@dataclasses.dataclass(frozen=True)
class HLAConfig:
    """Options for the paper's mixer (Sections 3-7)."""

    variant: str = "hla2"  # hla2 | ahla | hla3 | hla3_paper | linattn
    impl: str = "chunkwise"  # chunkwise (TPU-adapted) | scan (paper-faithful
    #   token-level Blelloch associative scan; the §Perf baseline)
    chunk: int = 256  # §Perf sweep: 256 beats 128/64 on the memory term
    #   (state carry I/O amortizes over the chunk; VMEM-bounded on TPU)
    normalize: bool = False  # paper default: unnormalized
    decay: str = "learned"  # none | fixed | learned  (per-head sigmoid)
    fixed_gamma: float = 0.99
    lam: float = 0.0  # ridge (Alg 1)
    share_kv_state: bool = False  # §5.2 MQA/GQA S^K sharing
    use_pallas: bool = True  # fused kernel on TPU; jnp path on CPU
    fused_bwd: bool = True  # fused Pallas backward with chunk-level state
    #   checkpointing (DESIGN.md §3); False = legacy recompute-in-backward
    #   (second unfused forward under jax.vjp — slower, slightly less HBM)
    force_pallas: bool = False  # run the Pallas kernels even off-TPU
    #   (interpret mode) — used by the distributed tests/CI to exercise the
    #   shard_map'd fused path on host devices; never the perf default


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 => d_model // 16


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0  # 0 => d_model // n_heads
    mixer: str = "softmax"  # softmax | hla2 | ahla | hla3 | linattn | rwkv6
    mlp: str = "swiglu"  # swiglu | squared_relu | gelu
    moe: Optional[MoEConfig] = None
    hla: HLAConfig = dataclasses.field(default_factory=HLAConfig)
    mamba: Optional[MambaConfig] = None
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 1e4
    norm_eps: float = 1e-5
    # hybrid pattern (jamba): layers come in groups; within a group, layer
    # `attn_index` is attention(/HLA) and the rest are mamba; every
    # `moe.every`-th layer of the group carries an MoE FFN.
    group_size: int = 0  # 0 = uniform stack
    attn_index: int = 0
    # encoder-decoder (whisper): enc_layers > 0 activates the encoder
    enc_layers: int = 0
    enc_frames: int = 1500  # precomputed frame embeddings (stub frontend)
    # vlm: number of precomputed patch-embedding tokens (stub frontend)
    vis_tokens: int = 0
    # rwkv6
    rwkv_head_dim: int = 64
    # numerics / runtime
    dtype: str = "bfloat16"  # activation/compute dtype
    param_dtype: str = "float32"  # storage dtype (jamba-scale: bfloat16)
    moment_dtype: str = "float32"  # AdamW mu/nu (jamba-scale: bfloat16)
    grad_accum_dtype: str = "float32"  # microbatch grad accumulator
    gather_dtype: str = "float32"  # layer-scan param gathers (bf16 = half
    #   the FSDP all-gather bytes; §Perf lever A)
    remat: str = "none"  # none | full | dots
    scan_layers: bool = True

    @property
    def head_dim(self) -> int:
        return self.d_head or (self.d_model // max(1, self.n_heads))

    @property
    def attn_free(self) -> bool:
        return self.mixer in ("rwkv6",)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """An assigned (input-shape) cell."""

    name: str  # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4096, 256, "train"),
    ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    ShapeConfig("decode_32k", 32768, 128, "decode"),
    ShapeConfig("long_500k", 524288, 1, "decode"),
)


def get_shape(name: str) -> ShapeConfig:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)
