"""Elementary blocks: norms, dense, rope, MLPs, embedding — pure functional.

Every ``*_specs`` returns a Spec pytree; every ``*_apply`` consumes the
matching params.  Sharding constraints are applied by the caller via
``repro.distributed.sharding.constrain`` on activations.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .param import Spec


# ------------------------------ norms --------------------------------------


def rmsnorm_specs(d: int):
    return {"scale": Spec((d,), ("embed",), init="ones")}


def rmsnorm_apply(p, x, eps: float = 1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(dt)


def layernorm_specs(d: int):
    return {
        "scale": Spec((d,), ("embed",), init="ones"),
        "bias": Spec((d,), ("embed",), init="zeros"),
    }


def layernorm_apply(p, x, eps: float = 1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"] + p["bias"]).astype(dt)


# ------------------------------ dense --------------------------------------


def dense_specs(d_in: int, d_out: int, axes=("embed", "ff"), bias: bool = False):
    s = {"kernel": Spec((d_in, d_out), axes)}
    if bias:
        s["bias"] = Spec((d_out,), (axes[1],), init="zeros")
    return s


def dense_apply(p, x):
    y = jnp.einsum("...d,df->...f", x, p["kernel"].astype(x.dtype))
    if "bias" in p:
        y = y + p["bias"].astype(x.dtype)
    return y


# ------------------------------ embedding ----------------------------------


def embed_specs(vocab: int, d: int):
    return {"embedding": Spec((vocab, d), ("vocab", "embed"), init="embed", scale=0.02)}


def embed_apply(p, ids):
    """Embedding lookup, sharding-aware.

    Large vocab tables are sharded ("vocab" -> model); a plain gather
    makes GSPMD all-gather the whole table, and a one-hot einsum
    materializes a (tokens, V) buffer (385 GiB/device at prefill_32k —
    dry-run finding).  Inside a mesh we therefore do the classic
    shard_map lookup: local take on the vocab shard with out-of-range
    masking, then psum over "model".  Falls back to jnp.take off-mesh or
    when the vocab doesn't divide the model axis.
    """
    from ..distributed.sharding import _current_mesh

    table = p["embedding"]
    V, D = table.shape
    mesh = _current_mesh()
    if (
        mesh is None
        or V <= 8192
        or "model" not in mesh.axis_names
        or V % mesh.shape["model"] != 0
    ):
        return jnp.take(table, ids, axis=0)

    import functools

    from jax.sharding import PartitionSpec as P

    from ..distributed.compat import shard_map
    from ..distributed.sharding import spec_for

    # adaptive batch spec: shard_map in_specs are strict about
    # divisibility (B=1 long-context decode, small per-microbatch
    # batches), so resolve through the same divisibility-aware rules
    # as everything else.
    idspec = spec_for(
        ("batch",) + (None,) * (ids.ndim - 1), ids.shape, mesh
    )
    bspec = idspec[0] if len(idspec) else None

    @functools.partial(
        shard_map,
        mesh=mesh,
        in_specs=(P("model", None), idspec),
        out_specs=P(*((bspec,) + (None,) * ids.ndim)),
    )
    def lookup(tbl, ids_):
        vloc = tbl.shape[0]
        off = jax.lax.axis_index("model") * vloc
        loc = ids_ - off
        ok = (loc >= 0) & (loc < vloc)
        out = jnp.take(tbl, jnp.clip(loc, 0, vloc - 1), axis=0)
        out = jnp.where(ok[..., None], out, 0)
        return jax.lax.psum(out, "model")

    return lookup(table, ids)


def unembed_apply(p, x):
    """Logits; shares the embedding table when tied."""
    return jnp.einsum("...d,vd->...v", x, p["embedding"].astype(x.dtype))


# ------------------------------ RoPE ----------------------------------------


def rope(x: jax.Array, positions: jax.Array, theta: float = 1e4):
    """x: (..., n, h, dh) or (..., n, dh); positions: (..., n)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = 1.0 / (
        theta ** (jnp.arange(0, half, dtype=jnp.float32) / half)
    )
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., n, half)
    if x.ndim == ang.ndim + 1:  # extra heads dim
        ang = ang[..., None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def sinusoidal_pos(n: int, d: int, dtype=jnp.float32):
    pos = jnp.arange(n, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


# ------------------------------ MLPs ----------------------------------------


def mlp_specs(d: int, d_ff: int, act: str):
    if act == "swiglu":
        return {
            "wi_gate": dense_specs(d, d_ff),
            "wi_up": dense_specs(d, d_ff),
            "wo": dense_specs(d_ff, d, axes=("ff", "embed")),
        }
    if act in ("squared_relu", "gelu", "relu"):
        return {
            "wi": dense_specs(d, d_ff),
            "wo": dense_specs(d_ff, d, axes=("ff", "embed")),
        }
    raise ValueError(act)


def mlp_apply(p, x, act: str):
    from ..distributed.sharding import constrain

    if act == "swiglu":
        g = constrain(dense_apply(p["wi_gate"], x), ("batch", None, "ff"))
        u = constrain(dense_apply(p["wi_up"], x), ("batch", None, "ff"))
        return dense_apply(p["wo"], jax.nn.silu(g) * u)
    h = constrain(dense_apply(p["wi"], x), ("batch", None, "ff"))
    if act == "squared_relu":
        h = jnp.square(jax.nn.relu(h))
    elif act == "gelu":
        h = jax.nn.gelu(h)
    else:
        h = jax.nn.relu(h)
    return dense_apply(p["wo"], h)
