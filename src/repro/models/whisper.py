"""Whisper-style encoder-decoder backbone (audio arch, conv frontend stub).

Per the assignment, the modality frontend is a STUB: ``input_specs()``
supplies precomputed frame embeddings (B, enc_frames, d_model) — the conv
subsampler is out of scope.  Encoder is bidirectional (softmax; HLA is
strictly causal — DESIGN.md §Arch-applicability), decoder supports either
softmax or an HLA mixer for causal self-attention; cross-attention stays
softmax.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from . import attention as attn_mod
from . import seq_op
from .blocks import (
    embed_apply,
    layernorm_apply,
    layernorm_specs,
    mlp_apply,
    mlp_specs,
    sinusoidal_pos,
    unembed_apply,
)
from .lm import _maybe_remat, _stack_specs
from ..distributed.sharding import constrain as _constrain
from .param import Spec


def _enc_layer_specs(cfg):
    return {
        "ln1": layernorm_specs(cfg.d_model),
        "attn": attn_mod.attention_specs(cfg),
        "ln2": layernorm_specs(cfg.d_model),
        "mlp": mlp_specs(cfg.d_model, cfg.d_ff, "gelu"),
    }


def _self_op(cfg) -> seq_op.SequenceOp:
    """The decoder's causal self-mixing op (registry-resolved).  Softmax
    stays a whisper-local attention call (no RoPE — learned positional
    embeddings); any STREAMING registered op drops in via its record.
    Self-contained ops (rwkv6) own their norms/FFN and cannot slot into
    the encoder-decoder block structure."""
    op = seq_op.op_for(cfg)
    if op.self_contained:
        raise seq_op.SequenceOpError(
            f"whisper decoder cannot host self-contained op {op.name!r} "
            "(it replaces the whole block; the decoder needs a sublayer)"
        )
    return op


def _self_key(op) -> str:
    # param-tree key kept stable for existing checkpoints
    return "self" if not op.streaming else "self_mixer"


def _dec_layer_specs(cfg):
    op = _self_op(cfg)
    return {
        "ln1": layernorm_specs(cfg.d_model),
        "ln_x": layernorm_specs(cfg.d_model),
        "cross_q": attn_mod.attention_specs(cfg),  # wq/wo used; wk/wv unused
        "cross_kv": attn_mod.cross_kv_specs(cfg),
        "ln2": layernorm_specs(cfg.d_model),
        "mlp": mlp_specs(cfg.d_model, cfg.d_ff, "gelu"),
        _self_key(op): op.specs(cfg),
    }


def whisper_specs(cfg):
    return {
        "embed": {
            "embedding": Spec(
                (cfg.vocab, cfg.d_model), ("vocab", "embed"), init="embed",
                scale=0.02,
            )
        },
        "pos_embed": Spec(
            (4096, cfg.d_model), (None, "embed"), init="embed", scale=0.01
        ),
        "enc_layers": _stack_specs(_enc_layer_specs(cfg), cfg.enc_layers),
        "enc_norm": layernorm_specs(cfg.d_model),
        "dec_layers": _stack_specs(_dec_layer_specs(cfg), cfg.n_layers),
        "dec_norm": layernorm_specs(cfg.d_model),
    }


def whisper_encode(params, frames, cfg):
    """frames: (B, ne, d_model) precomputed embeddings (stub frontend)."""
    act = jnp.dtype(cfg.dtype)
    B, ne, _ = frames.shape
    x = frames.astype(act) + sinusoidal_pos(ne, cfg.d_model, act)[None]

    def body(carry, p):
        x = carry
        x = _constrain(x, ("batch", "seq", "embed"))
        h = layernorm_apply(p["ln1"], x, cfg.norm_eps)
        y, _ = attn_mod.attention_apply(
            p["attn"], h, cfg, causal=False, use_rope=False
        )
        x = x + y
        h = layernorm_apply(p["ln2"], x, cfg.norm_eps)
        x = x + mlp_apply(p["mlp"], h, "gelu")
        return x, 0.0

    body = _maybe_remat(body, cfg)
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return layernorm_apply(params["enc_norm"], x, cfg.norm_eps)


def whisper_decode(
    params, tokens, enc_out, cfg, *, states=None, positions=None,
    mode: str = "train",
):
    """Decoder over tokens; cross-attends to enc_out.  Returns
    (logits, new_states, aux)."""
    act = jnp.dtype(cfg.dtype)
    B, n = tokens.shape
    if positions is None:
        positions = jnp.arange(n)[None, :]
    x = embed_apply(params["embed"], tokens).astype(act)
    # clip into the learned table (long_500k decode wraps the stub table)
    pos_idx = jnp.clip(positions[0], 0, params["pos_embed"].shape[0] - 1)
    pos = jnp.take(params["pos_embed"], pos_idx, axis=0).astype(act)
    x = x + pos[None]

    collect = mode in ("prefill", "decode")
    op = _self_op(cfg)
    key = _self_key(op)

    def body(carry, inp):
        x = carry
        x = _constrain(x, ("batch", "seq", "embed"))
        p = inp["params"]
        st = inp.get("state")
        h = layernorm_apply(p["ln1"], x, cfg.norm_eps)
        if not op.streaming:  # softmax: whisper-local, no RoPE
            cache = st["self"] if st is not None else None
            y, new_self = attn_mod.attention_apply(
                p[key], h, cfg, positions=positions, cache=cache,
                use_rope=False,
            )
        elif mode == "decode":
            y, new_self = op.step(p[key], h, st["self"], cfg)
        else:
            y, new_self = op.forward(
                p[key], h, cfg, want_state=(mode == "prefill")
            )
        x = x + y
        # cross attention (non-causal over encoder output); at prefill the
        # cross K/V are computed fresh from the encoder (the passed state
        # holds zeros) and RETURNED for decode
        h = layernorm_apply(p["ln_x"], x, cfg.norm_eps)
        if mode == "decode":
            ck, cv = st["cross_k"], st["cross_v"]
        else:
            ck, cv = attn_mod.cross_kv_apply(p["cross_kv"], enc_out, cfg)
        y, _ = attn_mod.attention_apply(
            p["cross_q"], h, cfg, cross_kv=(ck, cv), use_rope=False
        )
        x = x + y
        h = layernorm_apply(p["ln2"], x, cfg.norm_eps)
        x = x + mlp_apply(p["mlp"], h, "gelu")
        ys = (
            {"self": new_self, "cross_k": ck, "cross_v": cv} if collect else 0.0
        )
        return x, ys

    body = _maybe_remat(body, cfg)
    xs = {"params": params["dec_layers"]}
    if states is not None:
        xs["state"] = states
    x, new_states = jax.lax.scan(body, x, xs)
    x = layernorm_apply(params["dec_norm"], x, cfg.norm_eps)
    logits = unembed_apply(params["embed"], x)  # tied
    return logits, (new_states if collect else None), jnp.zeros((), jnp.float32)


def whisper_apply(
    params, tokens, frames, cfg, *, states=None, positions=None, mode="train",
    prefill_cache_margin: int = 64,
):
    if mode == "decode":
        # frames unused: encoder K/V live in states
        return whisper_decode(
            params, tokens, None, cfg, states=states, positions=positions,
            mode=mode,
        )
    if mode == "prefill" and states is None:
        # allocate self KV caches (+ margin for subsequent decode) so the
        # prefill actually fills them
        states = whisper_init_states(
            cfg, tokens.shape[0], tokens.shape[1] + prefill_cache_margin
        )
    enc_out = whisper_encode(params, frames, cfg)
    return whisper_decode(
        params, tokens, enc_out, cfg, states=states, positions=positions,
        mode=mode,
    )


def whisper_init_states(cfg, B, max_len):
    """Decode states: self state from the op record (KV cache for attn,
    streaming state otherwise) + cross K/V buffers."""
    one = {
        "self": _self_op(cfg).init_state(cfg, B, max_len=max_len),
        "cross_k": jnp.zeros(
            (B, cfg.n_kv_heads, cfg.enc_frames, cfg.head_dim), jnp.bfloat16
        ),
        "cross_v": jnp.zeros(
            (B, cfg.n_kv_heads, cfg.enc_frames, cfg.head_dim), jnp.bfloat16
        ),
    }
    L = cfg.n_layers
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (L,) + x.shape), one
    )


def whisper_state_axes(cfg):
    """Logical axes matching ``whisper_init_states`` (incl. the "layers"
    stacking dim) — see ``lm.lm_state_axes``."""
    from .param import Axes

    one = {
        "self": _self_op(cfg).state_axes(cfg),
        "cross_k": Axes(("batch", "kv_heads", None, None)),
        "cross_v": Axes(("batch", "kv_heads", None, None)),
    }
    return jax.tree.map(
        lambda ax: Axes(("layers",) + tuple(ax)), one,
        is_leaf=lambda x: isinstance(x, Axes),
    )


def whisper_loss(params, tokens, labels, frames, cfg, *, denom=None,
                 aux_weight: float = 1.0):
    """Mean next-token CE over valid labels.

    ``denom`` overrides the normalizer (default: this batch's valid-token
    count) — microbatched gradient accumulation passes the GLOBAL count so
    summed microbatch gradients equal the full-batch mean gradient exactly
    (mean-of-means over unevenly masked microbatches is biased).
    ``aux_weight`` scales the aux term (1/microbatches under accumulation).
    """
    logits, _, aux = whisper_apply(params, tokens, frames, cfg, mode="train")
    logits = logits.astype(jnp.float32)
    mask = (labels >= 0).astype(jnp.float32)
    safe = jnp.maximum(labels, 0)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    d = jnp.maximum(jnp.sum(mask), 1.0) if denom is None else denom
    ce = jnp.sum((lse - ll) * mask) / d
    return ce + aux_weight * aux, (ce, aux)
