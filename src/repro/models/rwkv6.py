"""RWKV-6 (Finch) time-mix + channel-mix — attn-free arch (rwkv6-7b).

Faithful core: matrix-valued per-head state with **data-dependent
per-channel decay** w_t (low-rank MLP, the Finch hallmark), bonus ``u``
for the current token, token-shift lerps, per-head GroupNorm, silu gate.
Simplification (DESIGN.md §5): token-shift mix ratios are static
(Eagle-style) except for the decay channel, which carries the full
data-dependent low-rank path.  Chunk-parallel in log-decay space:
cumulative log-decays inside a chunk, sequential carry across chunks —
the same two-level skeleton as the HLA scans.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..distributed.sharding import constrain
from .blocks import dense_apply, dense_specs
from .param import Spec


LOGW_MIN = -2.5  # see decay clamp note in rwkv6_time_mix
RWKV_CHUNK = 32  # |lc| <= w * |LOGW_MIN| = 80 < log(fp32 max) ~ 88


class RWKVState(NamedTuple):
    x_prev_t: jax.Array  # (B, 1, d) last token (time-mix shift)
    x_prev_c: jax.Array  # (B, 1, d) last token (channel-mix shift)
    S: jax.Array  # (B, H, dk, dv) wkv state


def rwkv6_specs(cfg):
    d = cfg.d_model
    dh = cfg.rwkv_head_dim
    H = d // dh
    lora = max(32, d // 64)
    from .blocks import layernorm_specs

    return {
        "ln1": layernorm_specs(d),
        "ln2": layernorm_specs(d),
        "tm": {  # time mix
            "mu_r": Spec((d,), ("embed",), init="constant", const=0.5),
            "mu_k": Spec((d,), ("embed",), init="constant", const=0.5),
            "mu_v": Spec((d,), ("embed",), init="constant", const=0.5),
            "mu_g": Spec((d,), ("embed",), init="constant", const=0.5),
            "mu_w": Spec((d,), ("embed",), init="constant", const=0.5),
            "wr": dense_specs(d, d, axes=("embed", "q_heads_flat")),
            "wk": dense_specs(d, d, axes=("embed", "q_heads_flat")),
            "wv": dense_specs(d, d, axes=("embed", "q_heads_flat")),
            "wg": dense_specs(d, d, axes=("embed", "q_heads_flat")),
            "w_lora_a": dense_specs(d, lora, axes=("embed", None)),
            "w_lora_b": dense_specs(lora, d, axes=(None, "q_heads_flat")),
            "w0": Spec((d,), ("q_heads_flat",), init="constant", const=-5.0),
            "u": Spec((H, dh), ("q_heads", "head_dim"), init="normal", scale=0.5),
            "gn_scale": Spec((H, dh), ("q_heads", "head_dim"), init="ones"),
            "gn_bias": Spec((H, dh), ("q_heads", "head_dim"), init="zeros"),
            "wo": dense_specs(d, d, axes=("q_heads_flat", "embed")),
        },
        "cm": {  # channel mix
            "mu_k": Spec((d,), ("embed",), init="constant", const=0.5),
            "mu_r": Spec((d,), ("embed",), init="constant", const=0.5),
            "wk": dense_specs(d, cfg.d_ff, axes=("embed", "ff")),
            "wv": dense_specs(cfg.d_ff, d, axes=("ff", "embed")),
            "wr": dense_specs(d, d, axes=("embed", "embed_out")),
        },
    }


def _shift(x, x_prev):
    """Token shift: returns previous-token tensor aligned with x."""
    if x_prev is None:
        x_prev = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([x_prev, x[:, :-1]], axis=1)


def _lerp(x, xs, mu):
    return x + (xs - x) * mu.astype(x.dtype)


def rwkv6_time_mix(p, x, cfg, state: RWKVState | None, chunk: int = RWKV_CHUNK):
    B, n, d = x.shape
    dh = cfg.rwkv_head_dim
    H = d // dh
    xs = _shift(x, state.x_prev_t if state is not None else None)

    r = dense_apply(p["wr"], _lerp(x, xs, p["mu_r"])).reshape(B, n, H, dh)
    k = dense_apply(p["wk"], _lerp(x, xs, p["mu_k"])).reshape(B, n, H, dh)
    v = dense_apply(p["wv"], _lerp(x, xs, p["mu_v"])).reshape(B, n, H, dh)
    g = dense_apply(p["wg"], _lerp(x, xs, p["mu_g"]))
    xw = _lerp(x, xs, p["mu_w"])
    # data-dependent decay (Finch): logw in (-inf, 0)
    dd = dense_apply(p["w_lora_b"], jnp.tanh(dense_apply(p["w_lora_a"], xw)))
    logw = -jnp.exp(p["w0"].astype(jnp.float32) + dd.astype(jnp.float32))
    # clamp keeps the chunk matmul factorization in fp32 range (and a
    # per-token decay of exp(-2.5) ~ 0.08 already means "forget"):
    logw = jnp.clip(logw, LOGW_MIN, -1e-6).reshape(B, n, H, dh)

    hspec = ("batch", "q_heads", None, None)
    r = constrain(jnp.swapaxes(r, 1, 2).astype(jnp.float32), hspec)
    k = constrain(jnp.swapaxes(k, 1, 2).astype(jnp.float32), hspec)
    v = constrain(jnp.swapaxes(v, 1, 2).astype(jnp.float32), hspec)
    logw = constrain(jnp.swapaxes(logw, 1, 2), hspec)  # (B, H, n, dk)
    u = p["u"].astype(jnp.float32)

    S0 = (
        state.S.astype(jnp.float32)
        if state is not None
        else jnp.zeros((B, H, dh, dh), jnp.float32)
    )

    w_ = min(chunk, n)
    pad = (w_ - n % w_) % w_
    if pad:
        r = jnp.pad(r, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        logw = jnp.pad(logw, ((0, 0), (0, 0), (0, pad), (0, 0)))
    npad = n + pad
    nc = npad // w_

    def reshape_chunks(t):
        return jnp.moveaxis(
            t.reshape(B, H, nc, w_, dh), 2, 0
        )  # (nc, B, H, w, dh)

    rc, kc, vc, wc = map(reshape_chunks, (r, k, v, logw))

    def body(S, inp):
        r_, k_, v_, lw_ = inp  # (B, H, w, dh)
        lc = jnp.cumsum(lw_, axis=2)  # inclusive sum of log-decays
        lc_ex = lc - lw_  # exclusive
        # A[t, j] = sum_c r_t[c] k_j[c] exp(lc_ex[t,c] - lc[j,c]) for j < t.
        # Exponent <= 0 always; the matmul factorization exp(lc_ex) x
        # exp(-lc) individually can overflow, bounded by the logw clamp
        # (>= LOGW_MIN) and the chunk width (see module docstring).
        scores = jnp.einsum(
            "bhtd,bhjd->bhtj", r_ * jnp.exp(lc_ex), k_ * jnp.exp(-lc)
        )
        tidx = jnp.arange(w_)
        mask = (tidx[:, None] > tidx[None, :]).astype(jnp.float32)
        A = scores * mask
        y = jnp.einsum("bhtj,bhje->bhte", A, v_)
        # current-token bonus (diag u): (r_t . (u ⊙ k_t)) v_t
        bonus = jnp.sum(r_ * u[None, :, None] * k_, -1, keepdims=True) * v_
        y = y + bonus
        # carry term: r_t ⊙ exp(lc_ex[t]) applied to S0
        y = y + jnp.einsum("bhtd,bhde->bhte", r_ * jnp.exp(lc_ex), S)
        # state update: S' = exp(lc[end]) ⊙_rows S + sum_j exp(lc_end - lc_j) k_j v_j^T
        lc_end = lc[..., -1:, :]  # (B, H, 1, dk)
        Snew = jnp.exp(lc_end[..., 0, :])[..., :, None] * S + jnp.einsum(
            "bhjd,bhje->bhde", k_ * jnp.exp(lc_end - lc), v_
        )
        return Snew, y

    Sf, ys = jax.lax.scan(body, S0, (rc, kc, vc, wc))
    y = jnp.moveaxis(ys, 0, 2).reshape(B, H, npad, dh)[:, :, :n]

    # per-head GroupNorm + gate
    mu = jnp.mean(y, -1, keepdims=True)
    var = jnp.var(y, -1, keepdims=True)
    yn = (y - mu) * jax.lax.rsqrt(var + 1e-5)
    yn = yn * p["gn_scale"][None, :, None] + p["gn_bias"][None, :, None]
    yn = jnp.swapaxes(yn, 1, 2).reshape(B, n, d).astype(x.dtype)
    out = dense_apply(p["wo"], yn * jax.nn.silu(g))
    new_state = RWKVState(
        x_prev_t=x[:, -1:],
        x_prev_c=state.x_prev_c if state is not None else jnp.zeros_like(x[:, :1]),
        S=Sf,
    )
    return out, new_state


def rwkv6_channel_mix(p, x, cfg, state: RWKVState | None):
    xs = _shift(x, state.x_prev_c if state is not None else None)
    kk = dense_apply(p["wk"], _lerp(x, xs, p["mu_k"]))
    kk = jnp.square(jax.nn.relu(kk))
    rr = jax.nn.sigmoid(dense_apply(p["wr"], _lerp(x, xs, p["mu_r"])))
    return rr * dense_apply(p["wv"], kk), x[:, -1:]


def rwkv6_layer_apply(p, x, cfg, state: RWKVState | None = None, chunk: int = RWKV_CHUNK):
    """One self-contained RWKV6 layer: ln1 + time-mix + ln2 + channel-mix.

    Token-shift state crosses both sublayers, so the layer owns its norms.
    Returns (x_out, new_state).
    """
    from .blocks import layernorm_apply

    xn = layernorm_apply(p["ln1"], x, cfg.norm_eps)
    y, st = rwkv6_time_mix(p["tm"], xn, cfg, state, chunk=chunk)
    x = x + y
    xn2 = layernorm_apply(p["ln2"], x, cfg.norm_eps)
    y2, x_prev_c = rwkv6_channel_mix(p["cm"], xn2, cfg, state)
    x = x + y2
    return x, RWKVState(x_prev_t=st.x_prev_t, x_prev_c=x_prev_c, S=st.S)


def rwkv6_init_state(cfg, B, dtype=jnp.float32) -> RWKVState:
    d = cfg.d_model
    dh = cfg.rwkv_head_dim
    H = d // dh
    # token-shift leaves hold raw activations, so they must carry the
    # ACTIVATION dtype: the layer writes x_prev_t = x[:, -1:] back, and a
    # decode scan whose carry-in (init) dtype differs from its carry-out
    # (cfg.dtype) is a trace error — hardcoded bf16 here broke serving for
    # every fp32-activation config.
    act = jnp.dtype(cfg.dtype)
    return RWKVState(
        x_prev_t=jnp.zeros((B, 1, d), act),
        x_prev_c=jnp.zeros((B, 1, d), act),
        S=jnp.zeros((B, H, dh, dh), dtype),
    )


def rwkv6_state_axes() -> RWKVState:
    """Logical axes per state leaf (wkv heads shard like query heads —
    divisibility fallback replicates when d/rwkv_head_dim doesn't divide
    the model axis)."""
    from .param import Axes

    return RWKVState(
        x_prev_t=Axes(("batch", None, None)),
        x_prev_c=Axes(("batch", None, None)),
        S=Axes(("batch", "q_heads", None, None)),
    )


# --------------------------------------------------------------------------
# SequenceOp registration: rwkv6 is SELF-CONTAINED (owns its norms and the
# channel mix — token-shift state crosses both sublayers), so its record
# replaces the whole pre-norm block rather than just the token mixer.
# --------------------------------------------------------------------------


def _rwkv6_forward(p, x, cfg, *, state=None, want_state=False,
                   positions=None):
    return rwkv6_layer_apply(p, x, cfg, state)


def _rwkv6_step(p, x_t, state, cfg, *, positions=None):
    return rwkv6_layer_apply(p, x_t, cfg, state)


from . import seq_op as _seq_op  # noqa: E402

_seq_op.register_op(_seq_op.SequenceOp(
    name="rwkv6",
    specs=rwkv6_specs,
    forward=_rwkv6_forward,
    step=_rwkv6_step,
    init_state=lambda cfg, B, *, max_len=0, dtype=None: rwkv6_init_state(
        cfg, B, jnp.float32 if dtype is None else dtype
    ),
    state_axes=lambda cfg: rwkv6_state_axes(),
    streaming=True,
    spec_decodable=True,
    self_contained=True,
))
