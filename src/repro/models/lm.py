"""Decoder-only LM assembly: uniform / hybrid stacks, train / prefill / decode.

Layers are stacked (leading "layers" axis on every param leaf) and applied
with ``lax.scan`` — one layer body in the HLO regardless of depth (fast
compiles, pipeline-friendly).  Hybrid (jamba) stacks scan over *groups* of
``group_size`` layers (1 attention/HLA + rest mamba, MoE on alternate
positions), unrolled inside the scan body.

Decode states are stacked pytrees matching the scan structure:
softmax -> KVCache, hla*/linattn -> core state tuples, mamba -> MambaState,
rwkv6 -> RWKVState.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from . import attention as attn_mod
from . import mixer as mixer_mod
from . import moe as moe_mod
from . import rwkv6 as rwkv_mod
from . import ssm as ssm_mod
from .blocks import (
    embed_apply,
    embed_specs,
    mlp_apply,
    mlp_specs,
    rmsnorm_apply,
    rmsnorm_specs,
    unembed_apply,
)
from .param import Spec, is_spec
from ..distributed.sharding import constrain


# --------------------------------------------------------------------------
# per-layer specs / apply
# --------------------------------------------------------------------------


def _mixer_kind(cfg) -> str:
    if cfg.mixer == "softmax":
        return "attn"
    if cfg.mixer == "rwkv6":
        return "rwkv6"
    return "mixer"  # hla2 | ahla | hla3 | hla3_paper | linattn


def layer_specs(cfg, kind: str, use_moe: bool):
    if kind == "rwkv6":
        return rwkv_mod.rwkv6_specs(cfg)  # self-contained (owns norms)
    s = {"ln1": rmsnorm_specs(cfg.d_model), "ln2": rmsnorm_specs(cfg.d_model)}
    if kind == "attn":
        s["attn"] = attn_mod.attention_specs(cfg)
    elif kind == "mixer":
        s["mixer"] = mixer_mod.mixer_specs(cfg)
    elif kind == "mamba":
        s["mamba"] = ssm_mod.mamba_specs(cfg)
    else:
        raise ValueError(kind)
    if use_moe:
        s["moe"] = moe_mod.moe_specs(cfg)
    else:
        s["mlp"] = mlp_specs(cfg.d_model, cfg.d_ff, cfg.mlp)
    return s


def layer_apply(
    p, x, cfg, kind: str, use_moe: bool, *,
    positions=None, state=None, mode: str = "train",
):
    """Returns (x, new_state, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if kind == "rwkv6":
        x, new_state = rwkv_mod.rwkv6_layer_apply(p, x, cfg, state)
        return x, new_state, aux

    h = rmsnorm_apply(p["ln1"], x, cfg.norm_eps)
    if kind == "attn":
        if mode == "decode":
            y, new_state = attn_mod.attention_apply(
                p["attn"], h, cfg, positions=positions, cache=state
            )
        elif mode == "prefill":
            # fill the cache while computing outputs
            y, new_state = attn_mod.attention_apply(
                p["attn"], h, cfg, positions=positions, cache=state
            )
        else:
            y, new_state = attn_mod.attention_apply(
                p["attn"], h, cfg, positions=positions
            )
    elif kind == "mixer":
        if mode == "decode":
            y, new_state = mixer_mod.mixer_step(p["mixer"], h, state, cfg)
        else:
            y, st = mixer_mod.mixer_apply(
                p["mixer"], h, cfg, want_state=(mode == "prefill"),
                state=state if mode == "prefill" else None,
            )
            new_state = st if mode == "prefill" else None
    elif kind == "mamba":
        y, new_state = ssm_mod.mamba_apply(p["mamba"], h, cfg, state=state)
        if mode == "train":
            new_state = None
    else:
        raise ValueError(kind)
    x = x + y

    h = rmsnorm_apply(p["ln2"], x, cfg.norm_eps)
    if use_moe:
        y, aux = moe_mod.moe_apply(p["moe"], h, cfg)
    else:
        y = mlp_apply(p["mlp"], h, cfg.mlp)
    x = x + y
    return x, new_state, aux


def layer_init_state(cfg, kind: str, B: int, max_len: int):
    if kind == "attn":
        return attn_mod.init_kv_cache(
            B, cfg.n_kv_heads, max_len, cfg.head_dim
        )
    if kind == "mixer":
        return mixer_mod.mixer_init_state(cfg, B)
    if kind == "mamba":
        return ssm_mod.mamba_init_state(cfg, B)
    if kind == "rwkv6":
        return rwkv_mod.rwkv6_init_state(cfg, B)
    raise ValueError(kind)


def layer_state_axes(cfg, kind: str):
    """Logical axes matching ``layer_init_state``'s tree (per-module
    source of truth; ``lm_state_axes`` adds the "layers" stacking dim)."""
    if kind == "attn":
        return attn_mod.kv_cache_axes()
    if kind == "mixer":
        return mixer_mod.mixer_state_axes(cfg)
    if kind == "mamba":
        return ssm_mod.mamba_state_axes()
    if kind == "rwkv6":
        return rwkv_mod.rwkv6_state_axes()
    raise ValueError(kind)


# --------------------------------------------------------------------------
# stacks
# --------------------------------------------------------------------------


def _stack_specs(specs, L: int):
    return jax.tree.map(
        lambda s: dataclasses.replace(
            s, shape=(L,) + s.shape, axes=("layers",) + s.axes
        ),
        specs,
        is_leaf=is_spec,
    )


def _group_layout(cfg):
    """Hybrid (jamba) group layout: list of (kind, use_moe) per position."""
    out = []
    for i in range(cfg.group_size):
        kind = "attn" if i == cfg.attn_index else "mamba"
        if cfg.mixer in ("hla2", "ahla", "hla3", "hla3_paper", "linattn") and i == cfg.attn_index:
            kind = "mixer"
        use_moe = cfg.moe is not None and (i % cfg.moe.every == cfg.moe.every - 1)
        out.append((kind, use_moe))
    return out


def lm_specs(cfg):
    specs = {"embed": embed_specs(cfg.vocab, cfg.d_model)}
    if cfg.group_size:
        n_groups = cfg.n_layers // cfg.group_size
        group = {
            f"pos{i}": layer_specs(cfg, kind, use_moe)
            for i, (kind, use_moe) in enumerate(_group_layout(cfg))
        }
        specs["groups"] = _stack_specs(group, n_groups)
    else:
        kind = _mixer_kind(cfg)
        use_moe = cfg.moe is not None
        specs["layers"] = _stack_specs(
            layer_specs(cfg, kind, use_moe), cfg.n_layers
        )
    specs["final_norm"] = rmsnorm_specs(cfg.d_model)
    if not cfg.tie_embeddings:
        specs["unembed"] = {
            "kernel": Spec((cfg.d_model, cfg.vocab), ("embed", "vocab"))
        }
    return specs


def _cast_stack(params, cfg):
    """Optionally cast the stacked layer params before the scan: the FSDP
    all-gather then moves bf16 instead of fp32 (half the collective bytes;
    §Perf lever A).  Norm scales stay fp32 (they are recast to fp32 inside
    the norm anyway; keeping them bf16 is also fine numerically)."""
    gd = jnp.dtype(getattr(cfg, "gather_dtype", "float32"))
    if gd == jnp.float32:
        return params
    return jax.tree.map(
        lambda x: x.astype(gd) if x.dtype == jnp.float32 else x, params
    )


def _maybe_remat(f, cfg):
    if cfg.remat == "full":
        return jax.checkpoint(f)
    if cfg.remat == "dots":
        return jax.checkpoint(
            f, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        )
    return f


def lm_init_states(cfg, B: int, max_len: int):
    """Stacked decode states matching the scan layout."""
    if cfg.group_size:
        n_groups = cfg.n_layers // cfg.group_size
        one = {
            f"pos{i}": layer_init_state(cfg, kind, B, max_len)
            for i, (kind, _) in enumerate(_group_layout(cfg))
        }
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (n_groups,) + x.shape), one
        )
    kind = _mixer_kind(cfg)
    one = layer_init_state(cfg, kind, B, max_len)
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (cfg.n_layers,) + x.shape), one
    )


def lm_state_axes(cfg):
    """Pytree of ``Axes`` matching ``lm_init_states`` leaf-for-leaf — the
    single sharding source of truth for decode/serving states
    (``distributed.steps.state_specs`` resolves these against a mesh).
    """
    from .param import Axes

    if cfg.group_size:
        one = {
            f"pos{i}": layer_state_axes(cfg, kind)
            for i, (kind, _) in enumerate(_group_layout(cfg))
        }
    else:
        one = layer_state_axes(cfg, _mixer_kind(cfg))
    return jax.tree.map(
        lambda ax: Axes(("layers",) + tuple(ax)), one,
        is_leaf=lambda x: isinstance(x, Axes),
    )


def lm_apply(
    params,
    tokens: jax.Array,  # (B, n) int32
    cfg,
    *,
    states=None,
    positions: Optional[jax.Array] = None,
    mode: str = "train",  # train | prefill | decode
    vis_embed: Optional[jax.Array] = None,  # (B, nv, d) VLM stub frontend
    return_hidden: bool = False,
):
    """Returns (logits, new_states, aux_loss)."""
    B, n = tokens.shape
    act_dtype = jnp.dtype(cfg.dtype)
    x = embed_apply(params["embed"], tokens).astype(act_dtype)
    if vis_embed is not None:
        x = jnp.concatenate([vis_embed.astype(act_dtype), x], axis=1)
        n = x.shape[1]
    if positions is None:
        positions = jnp.arange(n)[None, :]

    collect_state = mode in ("prefill", "decode")
    if (
        mode == "prefill"
        and states is None
        and (cfg.mixer == "softmax" or cfg.group_size)
    ):
        # softmax/hybrid archs need KV caches allocated to be filled
        # (+ margin for subsequent decode); streaming archs build state
        # from scratch.
        states = lm_init_states(cfg, B, n + 64)

    if cfg.group_size:
        layout = _group_layout(cfg)

        def group_body(carry, inp):
            x, aux = carry
            x = constrain(x, ("batch", "seq", "embed"))
            gp = inp["params"]
            gst = inp.get("state")
            new_states = {}
            for i, (kind, use_moe) in enumerate(layout):
                st_i = gst[f"pos{i}"] if gst is not None else None
                x, new_st, a = layer_apply(
                    gp[f"pos{i}"], x, cfg, kind, use_moe,
                    positions=positions, state=st_i, mode=mode,
                )
                new_states[f"pos{i}"] = new_st
                aux = aux + a
            ys = new_states if collect_state else 0.0
            return (x, aux), ys

        body = _maybe_remat(group_body, cfg)
        xs = {"params": _cast_stack(params["groups"], cfg)}
        if states is not None:
            xs["state"] = states
        (x, aux), new_states = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)), xs
        )
    else:
        kind = _mixer_kind(cfg)
        use_moe = cfg.moe is not None

        def layer_body(carry, inp):
            x, aux = carry
            x = constrain(x, ("batch", "seq", "embed"))
            st = inp.get("state")
            x, new_st, a = layer_apply(
                inp["params"], x, cfg, kind, use_moe,
                positions=positions, state=st, mode=mode,
            )
            ys = new_st if collect_state else 0.0
            return (x, aux + a), ys

        body = _maybe_remat(layer_body, cfg)
        xs = {"params": _cast_stack(params["layers"], cfg)}
        if states is not None:
            xs["state"] = states
        (x, aux), new_states = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)), xs
        )

    x = rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    if return_hidden:
        return x, (new_states if collect_state else None), aux
    if cfg.tie_embeddings:
        logits = unembed_apply(params["embed"], x)
    else:
        logits = jnp.einsum(
            "...d,dv->...v", x, params["unembed"]["kernel"].astype(x.dtype)
        )
    return logits, (new_states if collect_state else None), aux


def lm_prefill(params, tokens, cfg, *, states=None, positions=None):
    """Chunk-parallel prompt prefill for serving admission.

    Runs the whole prompt through ``mode="prefill"`` — for streaming mixers
    (hla2/ahla/...) each layer is ONE chunkwise call (the Pallas stateful
    kernel on TPU, jnp chunkwise on CPU), never a per-token Python loop —
    and returns ``(last_logits, states)``: the logits of the final prompt
    position (to sample the first generated token) plus the decode states.
    """
    logits, states, _ = lm_apply(
        params, tokens, cfg, states=states, positions=positions,
        mode="prefill",
    )
    return logits[:, -1], states


def lm_score_block(params, tokens, cfg, *, states, positions):
    """Score a short token block against streaming states — the target-model
    side of speculative verification.

    One ``mode="prefill"`` pass (per layer ONE chunkwise call — the same
    chunk-parallel path as prompt admission) over ``tokens``
    ``(B, k+1) = [last committed token, draft_1..draft_k]`` resumed from
    ``states``.  Returns ``(logits, new_states)`` with logits for EVERY
    position: ``logits[:, j]`` is the target's next-token distribution
    after consuming ``tokens[:, :j+1]``, i.e. the distribution that judges
    ``draft_{j+1}`` (and, at ``j == k``, the bonus token).  ``new_states``
    have consumed the whole block — exactly the post-acceptance state when
    every draft is accepted; on rejection the caller rolls back instead
    (serving/spec/verify.py).
    """
    logits, new_states, _ = lm_apply(
        params, tokens, cfg, states=states, positions=positions,
        mode="prefill",
    )
    return logits, new_states


def lm_loss(params, tokens, labels, cfg, *, vis_embed=None, denom=None,
            aux_weight: float = 1.0):
    """Mean next-token CE (labels < 0 are ignored) + MoE aux.  fp32 loss.

    ``denom`` overrides the CE normalizer (default: this batch's valid-token
    count).  Microbatched gradient accumulation passes the GLOBAL
    valid-token count so summed microbatch gradients equal the full-batch
    mean gradient exactly — averaging per-microbatch means is biased when
    masking gives microbatches different valid counts.  ``aux_weight``
    scales the aux term (1/microbatches under accumulation).
    """
    logits, _, aux = lm_apply(
        params, tokens, cfg, mode="train", vis_embed=vis_embed
    )
    if vis_embed is not None:
        logits = logits[:, vis_embed.shape[1]:]
    logits = logits.astype(jnp.float32)
    mask = (labels >= 0).astype(jnp.float32)
    safe = jnp.maximum(labels, 0)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    d = jnp.maximum(jnp.sum(mask), 1.0) if denom is None else denom
    ce = jnp.sum((lse - ll) * mask) / d
    return ce + aux_weight * aux, (ce, aux)
