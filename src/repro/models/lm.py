"""Decoder-only LM assembly: uniform / hybrid stacks, train / prefill / decode.

Layers are stacked (leading "layers" axis on every param leaf) and applied
with ``lax.scan`` — one layer body in the HLO regardless of depth (fast
compiles, pipeline-friendly).  Hybrid (jamba) stacks scan over *groups* of
``group_size`` layers (1 attention/HLA + rest mamba, MoE on alternate
positions), unrolled inside the scan body.

Every sequence-mixing sublayer is a registered ``seq_op.SequenceOp``
(DESIGN.md §11): this module resolves ``cfg`` to op records ONCE and then
programs purely against the record interface — specs / forward / step /
init_state / state_axes plus capability flags.  There is no per-kind
dispatch here; registering a new operator (see ``models/gla.py``) makes it
train, prefill and decode through this file with zero edits.

Decode states are stacked pytrees matching the scan structure — each
leaf's layout is whatever the op's ``init_state`` returns (KVCache for
attn, core state tuples for the HLA family, MambaState, RWKVState, ...).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from . import moe as moe_mod
from . import seq_op
from .blocks import (
    embed_apply,
    embed_specs,
    mlp_apply,
    mlp_specs,
    rmsnorm_apply,
    rmsnorm_specs,
    unembed_apply,
)
from .param import Spec, is_spec
from ..distributed.sharding import constrain


# --------------------------------------------------------------------------
# per-layer specs / apply (SequenceOp-generic)
# --------------------------------------------------------------------------


def layer_specs(cfg, op: seq_op.SequenceOp, use_moe: bool):
    if op.self_contained:  # e.g. rwkv6: owns norms + channel mix
        return op.specs(cfg)
    s = {"ln1": rmsnorm_specs(cfg.d_model), "ln2": rmsnorm_specs(cfg.d_model)}
    s[op.param_key] = op.specs(cfg)
    if use_moe:
        s["moe"] = moe_mod.moe_specs(cfg)
    else:
        s["mlp"] = mlp_specs(cfg.d_model, cfg.d_ff, cfg.mlp)
    return s


def layer_apply(
    p, x, cfg, op: seq_op.SequenceOp, use_moe: bool, *,
    positions=None, state=None, mode: str = "train",
):
    """Returns (x, new_state, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if op.self_contained:
        x, new_state = op.forward(p, x, cfg, state=state)
        return x, (None if mode == "train" else new_state), aux

    h = rmsnorm_apply(p["ln1"], x, cfg.norm_eps)
    sub = p[op.param_key]
    if mode == "decode" and op.streaming:
        y, new_state = op.step(sub, h, state, cfg, positions=positions)
    else:
        # train: state is None, want_state False -> pure training path;
        # prefill: one chunkwise/cache-filling call returning the decode
        # state; decode for non-streaming ops (attn) is a cache-append
        # forward over the single new token.
        y, new_state = op.forward(
            sub, h, cfg, state=state,
            want_state=(mode != "train"), positions=positions,
        )
    if mode == "train":
        new_state = None
    x = x + y

    h = rmsnorm_apply(p["ln2"], x, cfg.norm_eps)
    if use_moe:
        y, aux = moe_mod.moe_apply(p["moe"], h, cfg)
    else:
        y = mlp_apply(p["mlp"], h, cfg.mlp)
    x = x + y
    return x, new_state, aux


def layer_init_state(cfg, op: seq_op.SequenceOp, B: int, max_len: int):
    return op.init_state(cfg, B, max_len=max_len)


def layer_state_axes(cfg, op: seq_op.SequenceOp):
    """Logical axes matching ``layer_init_state``'s tree (per-op source of
    truth; ``lm_state_axes`` adds the "layers" stacking dim)."""
    return op.state_axes(cfg)


# --------------------------------------------------------------------------
# stacks
# --------------------------------------------------------------------------


def _stack_specs(specs, L: int):
    return jax.tree.map(
        lambda s: dataclasses.replace(
            s, shape=(L,) + s.shape, axes=("layers",) + s.axes
        ),
        specs,
        is_leaf=is_spec,
    )


def _group_layout(cfg):
    """Hybrid (jamba) group layout: list of (op, use_moe) per position —
    the configured mixer op at ``attn_index``, mamba elsewhere."""
    mix_op = seq_op.op_for(cfg)
    mamba_op = seq_op.get_op("mamba")
    out = []
    for i in range(cfg.group_size):
        op = mix_op if i == cfg.attn_index else mamba_op
        use_moe = cfg.moe is not None and (i % cfg.moe.every == cfg.moe.every - 1)
        out.append((op, use_moe))
    return out


def needs_prealloc_states(cfg) -> bool:
    """True when prefill must write into preallocated states (KV caches /
    hybrid stacks) rather than building streaming state from scratch —
    derived from the ops' ``prealloc_state`` capability flag."""
    if cfg.group_size:
        return any(op.prealloc_state for op, _ in _group_layout(cfg))
    return seq_op.op_for(cfg).prealloc_state


def lm_specs(cfg):
    specs = {"embed": embed_specs(cfg.vocab, cfg.d_model)}
    if cfg.group_size:
        n_groups = cfg.n_layers // cfg.group_size
        group = {
            f"pos{i}": layer_specs(cfg, op, use_moe)
            for i, (op, use_moe) in enumerate(_group_layout(cfg))
        }
        specs["groups"] = _stack_specs(group, n_groups)
    else:
        op = seq_op.op_for(cfg)
        use_moe = cfg.moe is not None
        specs["layers"] = _stack_specs(
            layer_specs(cfg, op, use_moe), cfg.n_layers
        )
    specs["final_norm"] = rmsnorm_specs(cfg.d_model)
    if not cfg.tie_embeddings:
        specs["unembed"] = {
            "kernel": Spec((cfg.d_model, cfg.vocab), ("embed", "vocab"))
        }
    return specs


def _cast_stack(params, cfg):
    """Optionally cast the stacked layer params before the scan: the FSDP
    all-gather then moves bf16 instead of fp32 (half the collective bytes;
    §Perf lever A).  Norm scales stay fp32 (they are recast to fp32 inside
    the norm anyway; keeping them bf16 is also fine numerically)."""
    gd = jnp.dtype(getattr(cfg, "gather_dtype", "float32"))
    if gd == jnp.float32:
        return params
    return jax.tree.map(
        lambda x: x.astype(gd) if x.dtype == jnp.float32 else x, params
    )


def _maybe_remat(f, cfg):
    if cfg.remat == "full":
        return jax.checkpoint(f)
    if cfg.remat == "dots":
        return jax.checkpoint(
            f, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        )
    return f


def lm_init_states(cfg, B: int, max_len: int):
    """Stacked decode states matching the scan layout."""
    if cfg.group_size:
        n_groups = cfg.n_layers // cfg.group_size
        one = {
            f"pos{i}": layer_init_state(cfg, op, B, max_len)
            for i, (op, _) in enumerate(_group_layout(cfg))
        }
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (n_groups,) + x.shape), one
        )
    one = layer_init_state(cfg, seq_op.op_for(cfg), B, max_len)
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (cfg.n_layers,) + x.shape), one
    )


def lm_state_axes(cfg):
    """Pytree of ``Axes`` matching ``lm_init_states`` leaf-for-leaf — the
    single sharding source of truth for decode/serving states
    (``distributed.steps.state_specs`` resolves these against a mesh).
    """
    from .param import Axes

    if cfg.group_size:
        one = {
            f"pos{i}": layer_state_axes(cfg, op)
            for i, (op, _) in enumerate(_group_layout(cfg))
        }
    else:
        one = layer_state_axes(cfg, seq_op.op_for(cfg))
    return jax.tree.map(
        lambda ax: Axes(("layers",) + tuple(ax)), one,
        is_leaf=lambda x: isinstance(x, Axes),
    )


def lm_apply(
    params,
    tokens: jax.Array,  # (B, n) int32
    cfg,
    *,
    states=None,
    positions: Optional[jax.Array] = None,
    mode: str = "train",  # train | prefill | decode
    vis_embed: Optional[jax.Array] = None,  # (B, nv, d) VLM stub frontend
    return_hidden: bool = False,
):
    """Returns (logits, new_states, aux_loss)."""
    B, n = tokens.shape
    act_dtype = jnp.dtype(cfg.dtype)
    x = embed_apply(params["embed"], tokens).astype(act_dtype)
    if vis_embed is not None:
        x = jnp.concatenate([vis_embed.astype(act_dtype), x], axis=1)
        n = x.shape[1]
    if positions is None:
        positions = jnp.arange(n)[None, :]

    collect_state = mode in ("prefill", "decode")
    if mode == "prefill" and states is None and needs_prealloc_states(cfg):
        # KV-cache/hybrid archs need states allocated to be filled
        # (+ margin for subsequent decode); streaming ops build state
        # from scratch.
        states = lm_init_states(cfg, B, n + 64)

    if cfg.group_size:
        layout = _group_layout(cfg)

        def group_body(carry, inp):
            x, aux = carry
            x = constrain(x, ("batch", "seq", "embed"))
            gp = inp["params"]
            gst = inp.get("state")
            new_states = {}
            for i, (op, use_moe) in enumerate(layout):
                st_i = gst[f"pos{i}"] if gst is not None else None
                x, new_st, a = layer_apply(
                    gp[f"pos{i}"], x, cfg, op, use_moe,
                    positions=positions, state=st_i, mode=mode,
                )
                new_states[f"pos{i}"] = new_st
                aux = aux + a
            ys = new_states if collect_state else 0.0
            return (x, aux), ys

        body = _maybe_remat(group_body, cfg)
        xs = {"params": _cast_stack(params["groups"], cfg)}
        if states is not None:
            xs["state"] = states
        (x, aux), new_states = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)), xs
        )
    else:
        op = seq_op.op_for(cfg)
        use_moe = cfg.moe is not None

        def layer_body(carry, inp):
            x, aux = carry
            x = constrain(x, ("batch", "seq", "embed"))
            st = inp.get("state")
            x, new_st, a = layer_apply(
                inp["params"], x, cfg, op, use_moe,
                positions=positions, state=st, mode=mode,
            )
            ys = new_st if collect_state else 0.0
            return (x, aux + a), ys

        body = _maybe_remat(layer_body, cfg)
        xs = {"params": _cast_stack(params["layers"], cfg)}
        if states is not None:
            xs["state"] = states
        (x, aux), new_states = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)), xs
        )

    x = rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    if return_hidden:
        return x, (new_states if collect_state else None), aux
    if cfg.tie_embeddings:
        logits = unembed_apply(params["embed"], x)
    else:
        logits = jnp.einsum(
            "...d,dv->...v", x, params["unembed"]["kernel"].astype(x.dtype)
        )
    return logits, (new_states if collect_state else None), aux


def lm_prefill(params, tokens, cfg, *, states=None, positions=None):
    """Chunk-parallel prompt prefill for serving admission.

    Runs the whole prompt through ``mode="prefill"`` — for streaming ops
    (hla2/ahla/gla/...) each layer is ONE chunkwise call (the Pallas
    stateful kernel on TPU, jnp chunkwise on CPU), never a per-token
    Python loop — and returns ``(last_logits, states)``: the logits of the
    final prompt position (to sample the first generated token) plus the
    decode states.
    """
    logits, states, _ = lm_apply(
        params, tokens, cfg, states=states, positions=positions,
        mode="prefill",
    )
    return logits[:, -1], states


def lm_score_block(params, tokens, cfg, *, states, positions):
    """Score a short token block against streaming states — the target-model
    side of speculative verification.

    One ``mode="prefill"`` pass (per layer ONE chunkwise call — the same
    chunk-parallel path as prompt admission) over ``tokens``
    ``(B, k+1) = [last committed token, draft_1..draft_k]`` resumed from
    ``states``.  Returns ``(logits, new_states)`` with logits for EVERY
    position: ``logits[:, j]`` is the target's next-token distribution
    after consuming ``tokens[:, :j+1]``, i.e. the distribution that judges
    ``draft_{j+1}`` (and, at ``j == k``, the bonus token).  ``new_states``
    have consumed the whole block — exactly the post-acceptance state when
    every draft is accepted; on rejection the caller rolls back instead
    (serving/spec/verify.py).
    """
    logits, new_states, _ = lm_apply(
        params, tokens, cfg, states=states, positions=positions,
        mode="prefill",
    )
    return logits, new_states


def lm_loss(params, tokens, labels, cfg, *, vis_embed=None, denom=None,
            aux_weight: float = 1.0):
    """Mean next-token CE (labels < 0 are ignored) + MoE aux.  fp32 loss.

    ``denom`` overrides the CE normalizer (default: this batch's valid-token
    count).  Microbatched gradient accumulation passes the GLOBAL
    valid-token count so summed microbatch gradients equal the full-batch
    mean gradient exactly — averaging per-microbatch means is biased when
    masking gives microbatches different valid counts.  ``aux_weight``
    scales the aux term (1/microbatches under accumulation).
    """
    logits, _, aux = lm_apply(
        params, tokens, cfg, mode="train", vis_embed=vis_embed
    )
    if vis_embed is not None:
        logits = logits[:, vis_embed.shape[1]:]
    logits = logits.astype(jnp.float32)
    mask = (labels >= 0).astype(jnp.float32)
    safe = jnp.maximum(labels, 0)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    d = jnp.maximum(jnp.sum(mask), 1.0) if denom is None else denom
    ce = jnp.sum((lse - ll) * mask) / d
    return ce + aux_weight * aux, (ce, aux)
