"""Single-source-of-truth parameter specs (MaxText-style logical axes).

A model defines ``param_specs(cfg) -> pytree of Spec`` once; everything
else derives from it:

* ``init_params(specs, rng)``      — materialize arrays (per-leaf folded rng)
* ``abstract_params(specs)``       — ShapeDtypeStructs (dry-run, no alloc)
* ``logical_axes(specs)``          — pytree of logical-axis tuples
* (distributed/sharding.py)        — logical axes -> PartitionSpecs

Logical axis vocabulary: "vocab", "embed", "q_heads", "kv_heads",
"head_dim", "ff", "experts", "expert_ff", "layers", "state", "conv",
plus None for replicated dims.
"""

from __future__ import annotations

import dataclasses
import zlib
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class Spec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"  # normal | zeros | ones | embed | constant
    scale: Optional[float] = None  # override; default fan-in scaling
    dtype: Any = jnp.float32
    const: float = 0.0  # for init == "constant"

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_spec(x) -> bool:
    return isinstance(x, Spec)


class Axes(tuple):
    """A logical-axes tuple that is a pytree *leaf* (plain tuples flatten).

    Used by the ``*_state_axes`` helpers so state-axis trees can be
    ``jax.tree.map``-ed against state templates without descending into the
    axis names themselves.  Being a tuple subclass it feeds straight into
    ``distributed.sharding.spec_for``.
    """

    __slots__ = ()


def is_axes(x) -> bool:
    return isinstance(x, Axes)


def _leaf_paths(tree, prefix=()):
    if is_spec(tree):
        yield prefix, tree
        return
    for key in sorted(tree):
        yield from _leaf_paths(tree[key], prefix + (key,))


def _init_leaf(spec: Spec, rng: jax.Array) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    if spec.init == "constant":
        return jnp.full(spec.shape, spec.const, spec.dtype)
    if spec.init == "embed":
        scale = spec.scale if spec.scale is not None else 1.0
        return scale * jax.random.normal(rng, spec.shape, spec.dtype)
    if spec.init == "normal":
        # fan-in scaled truncated normal (sum over all but last dim)
        fan_in = int(np.prod(spec.shape[:-1])) if len(spec.shape) > 1 else 1
        scale = (
            spec.scale
            if spec.scale is not None
            else 1.0 / max(1.0, np.sqrt(fan_in))
        )
        return scale * jax.random.truncated_normal(
            rng, -2.0, 2.0, spec.shape
        ).astype(spec.dtype)
    raise ValueError(spec.init)


def init_params(specs, rng: jax.Array):
    """Materialize a param pytree; rng folded per leaf path (stable).

    The per-path fold-in uses ``zlib.crc32`` — NOT builtin ``hash``, which
    is salted per process (PYTHONHASHSEED) and made "identical seed"
    initializations differ across launches/restarts.
    """
    out = {}
    for path, spec in _leaf_paths(specs):
        key = rng
        for p in path:
            key = jax.random.fold_in(key, zlib.crc32(p.encode()) % (2**31))
        node = out
        for p in path[:-1]:
            node = node.setdefault(p, {})
        node[path[-1]] = _init_leaf(spec, key)
    return out


def abstract_params(specs):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), specs,
        is_leaf=is_spec,
    )


def logical_axes(specs):
    return jax.tree.map(lambda s: s.axes, specs, is_leaf=is_spec)


def param_count(specs) -> int:
    return sum(int(np.prod(s.shape)) for _, s in _leaf_paths(specs))


def param_bytes(specs) -> int:
    return sum(
        int(np.prod(s.shape)) * jnp.dtype(s.dtype).itemsize
        for _, s in _leaf_paths(specs)
    )
