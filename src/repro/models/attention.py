"""Softmax attention: blockwise (flash-style) GQA + KV-cache decode.

``flash_attention`` never materializes the (n, n) score matrix: it scans
over KV blocks carrying (acc, row_max, row_sum) — O(n * block) memory, so
prefill_32k fits HBM without a fused kernel (the paper's contribution is
the HLA mixer; softmax stays pure JAX).
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..distributed.sharding import constrain
from .blocks import dense_apply, dense_specs, rope
from .param import Spec

NEG_INF = -1e30


def attention_specs(cfg):
    d, H, Hk, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    return {
        "wq": dense_specs(d, H * dh, axes=("embed", "q_heads_flat"), bias=cfg.qkv_bias),
        "wk": dense_specs(d, Hk * dh, axes=("embed", "kv_heads_flat"), bias=cfg.qkv_bias),
        "wv": dense_specs(d, Hk * dh, axes=("embed", "kv_heads_flat"), bias=cfg.qkv_bias),
        "wo": dense_specs(H * dh, d, axes=("q_heads_flat", "embed")),
    }


def flash_attention(
    q: jax.Array,  # (B, H, nq, dh)
    k: jax.Array,  # (B, Hk, nk, dh)
    v: jax.Array,  # (B, Hk, nk, dh)
    *,
    causal: bool = True,
    kv_block: int = 512,
    q_offset: int = 0,  # absolute position of q[0] (for causal masking)
    kv_len: Optional[jax.Array] = None,  # valid kv length (decode masking)
    score_dtype=None,  # stored score/prob dtype; defaults to the input
    # dtype (bf16 models store bf16 scores — §Perf lever D: fp32 score
    # round-trips dominated the attention memory roofline term);
    # accumulation is always fp32.
):
    """Blockwise softmax attention with online renormalization."""
    if score_dtype is None:
        score_dtype = (
            jnp.bfloat16 if q.dtype == jnp.bfloat16 else jnp.float32
        )
    B, H, nq, dh = q.shape
    Hk, nk = k.shape[1], k.shape[2]
    G = H // Hk
    qg = q.reshape(B, Hk, G, nq, dh).astype(score_dtype)
    scale = jnp.asarray(1.0 / np.sqrt(dh), jnp.float32)

    blk = min(kv_block, nk)
    if nk % blk != 0:  # pad keys (masked out below)
        pad = blk - nk % blk
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        pad_len = nk + pad
    else:
        pad_len = nk
    nblk = pad_len // blk
    kb = jnp.moveaxis(k.reshape(B, Hk, nblk, blk, dh), 2, 0).astype(score_dtype)
    vb = jnp.moveaxis(v.reshape(B, Hk, nblk, blk, dh), 2, 0).astype(score_dtype)

    q_pos = q_offset + jnp.arange(nq)

    def body(carry, inp):
        acc, mx, sm = carry
        kblk, vblk, bidx = inp
        kv_pos = bidx * blk + jnp.arange(blk)
        s = jnp.einsum(
            "bhgqd,bhkd->bhgqk", qg, kblk,
            preferred_element_type=jnp.float32,
        ) * scale
        mask = kv_pos[None, :] < (kv_len if kv_len is not None else nk)
        if causal:
            mask = mask & (kv_pos[None, :] <= q_pos[:, None])
        else:
            mask = jnp.broadcast_to(mask, (nq, blk))
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        new_mx = jnp.maximum(mx, jnp.max(s, axis=-1))
        # probs stored in score_dtype (HBM); sums/acc accumulate fp32
        p = jnp.exp((s - new_mx[..., None])).astype(score_dtype)
        corr = jnp.exp(mx - new_mx)
        sm = sm * corr + jnp.sum(p.astype(jnp.float32), axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bhgqk,bhkd->bhgqd", p, vblk,
            preferred_element_type=jnp.float32,
        )
        return (acc, new_mx, sm), None

    acc0 = jnp.zeros((B, Hk, G, nq, dh), jnp.float32)
    mx0 = jnp.full((B, Hk, G, nq), NEG_INF, jnp.float32)
    sm0 = jnp.zeros((B, Hk, G, nq), jnp.float32)
    (acc, mx, sm), _ = jax.lax.scan(
        body, (acc0, mx0, sm0), (kb, vb, jnp.arange(nblk))
    )
    out = acc / jnp.maximum(sm[..., None], 1e-30)
    return out.reshape(B, H, nq, dh).astype(q.dtype)


class KVCache(NamedTuple):
    k: jax.Array  # (B, Hk, max_len, dh)
    v: jax.Array  # (B, Hk, max_len, dh)
    length: jax.Array  # () int32 — tokens currently valid


def init_kv_cache(B, Hk, max_len, dh, dtype=jnp.bfloat16) -> KVCache:
    return KVCache(
        k=jnp.zeros((B, Hk, max_len, dh), dtype),
        v=jnp.zeros((B, Hk, max_len, dh), dtype),
        length=jnp.zeros((), jnp.int32),
    )


def kv_cache_axes() -> KVCache:
    """Logical axes per cache leaf (state-sharding source of truth):
    batch over data, KV heads over model, time/feature replicated; the
    shared ``length`` scalar is replicated."""
    from .param import Axes

    return KVCache(
        k=Axes(("batch", "kv_heads", None, None)),
        v=Axes(("batch", "kv_heads", None, None)),
        length=Axes(()),
    )


def attention_apply(
    p,
    x: jax.Array,  # (B, n, d)
    cfg,
    *,
    positions: Optional[jax.Array] = None,
    cache: Optional[KVCache] = None,
    cross_kv: Optional[tuple] = None,  # (k, v) for cross-attention
    causal: bool = True,
    use_rope: bool = True,
):
    """Self- or cross-attention sublayer.  Returns (out, new_cache)."""
    B, n, _ = x.shape
    H, Hk, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = dense_apply(p["wq"], x).reshape(B, n, H, dh)
    if positions is None:
        positions = jnp.arange(n)[None, :]

    if cross_kv is None:
        k = dense_apply(p["wk"], x).reshape(B, n, Hk, dh)
        v = dense_apply(p["wv"], x).reshape(B, n, Hk, dh)
        if use_rope:
            q = rope(q, positions, cfg.rope_theta)
            k = rope(k, positions, cfg.rope_theta)
        q = constrain(jnp.swapaxes(q, 1, 2), ("batch", "q_heads", None, None))
        k = constrain(jnp.swapaxes(k, 1, 2), ("batch", "kv_heads", None, None))
        v = constrain(jnp.swapaxes(v, 1, 2), ("batch", "kv_heads", None, None))
        new_cache = None
        if cache is not None:
            zero = jnp.zeros((), cache.length.dtype)
            idx = (zero, zero, cache.length, zero)
            k = jax.lax.dynamic_update_slice(
                cache.k, k.astype(cache.k.dtype), idx
            )
            v = jax.lax.dynamic_update_slice(
                cache.v, v.astype(cache.v.dtype), idx
            )
            new_cache = KVCache(k, v, cache.length + n)
            out = flash_attention(
                q, k, v, causal=causal, q_offset=cache.length,
                kv_len=cache.length + n,
            )
        else:
            out = flash_attention(q, k, v, causal=causal)
    else:
        kc, vc = cross_kv  # precomputed encoder K/V: (B, Hk, ne, dh)
        q = jnp.swapaxes(q, 1, 2)
        out = flash_attention(q, kc, vc, causal=False)
        new_cache = None

    out = jnp.swapaxes(out, 1, 2).reshape(B, n, H * dh)
    out = constrain(out, ("batch", None, "q_heads_flat"))
    return dense_apply(p["wo"], out), new_cache


# --------------------------------------------------------------------------
# SequenceOp registration: softmax attention as "attn"
# --------------------------------------------------------------------------


def _attn_forward(p, x, cfg, *, state=None, want_state=False, positions=None,
                  use_rope=True):
    """Train (state=None) or prefill/decode (state=KVCache, filled in
    place at ``state.length``).  ``want_state`` is implied by ``state``."""
    return attention_apply(
        p, x, cfg, positions=positions, cache=state, use_rope=use_rope
    )


def _attn_step(p, x_t, state, cfg, *, positions=None):
    return attention_apply(p, x_t, cfg, positions=positions, cache=state)


def _attn_init_state(cfg, B, *, max_len=0, dtype=None):
    return init_kv_cache(B, cfg.n_kv_heads, max_len, cfg.head_dim)


from . import seq_op as _seq_op  # noqa: E402  (import cycle: none — seq_op
#   imports this module lazily, after its own module body has run)

_seq_op.register_op(_seq_op.SequenceOp(
    name="attn",
    specs=attention_specs,
    forward=_attn_forward,
    step=_attn_step,
    init_state=_attn_init_state,
    state_axes=lambda cfg: kv_cache_axes(),
    streaming=False,  # KV cache grows with context; its pooled scalar
    #   ``length`` is shared across slots, so the serving engine's
    #   per-slot continuous batching cannot admit it (engine.py)
    spec_decodable=False,
    needs_positions=True,
    prealloc_state=True,  # prefill fills a preallocated cache
))


def cross_kv_specs(cfg):
    d, Hk, dh = cfg.d_model, cfg.n_kv_heads, cfg.head_dim
    return {
        "wk": dense_specs(d, Hk * dh, axes=("embed", "kv_heads_flat")),
        "wv": dense_specs(d, Hk * dh, axes=("embed", "kv_heads_flat")),
    }


def cross_kv_apply(p, enc_out, cfg):
    B, ne, _ = enc_out.shape
    Hk, dh = cfg.n_kv_heads, cfg.head_dim
    k = dense_apply(p["wk"], enc_out).reshape(B, ne, Hk, dh)
    v = dense_apply(p["wv"], enc_out).reshape(B, ne, Hk, dh)
    return jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2)
