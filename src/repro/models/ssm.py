"""Selective SSM (Mamba) block — pure JAX, chunked scan, decode state.

The recurrence h_t = a_t ⊙ h_{t-1} + b_t (diagonal, data-dependent) shares
the chunk-parallel skeleton with the HLA monoids (paper §4 "connection to
linear attention"): intra-chunk ``associative_scan``, inter-chunk ``lax.scan``
carry.  The 4-D (B, w, d_inner, d_state) tensors are only ever materialized
per chunk (DESIGN.md §4 memory note).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..distributed.sharding import constrain
from .blocks import dense_apply, dense_specs
from .param import Spec


def _first_order_op(x, y):
    a1, b1 = x
    a2, b2 = y
    return a2 * a1, a2 * b1 + b2


def chunked_linear_recurrence(a, b, h0, chunk: int = 128):
    """h_t = a_t * h_{t-1} + b_t along axis 1.  a, b: (B, n, ...).

    Returns (h (B, n, ...), h_final).  Exact; intra-chunk associative scan,
    inter-chunk sequential carry.
    """
    B, n = a.shape[:2]
    w = min(chunk, n)
    assert n % w == 0
    nc = n // w
    ac = jnp.moveaxis(a.reshape((B, nc, w) + a.shape[2:]), 1, 0)
    bc = jnp.moveaxis(b.reshape((B, nc, w) + b.shape[2:]), 1, 0)

    def body(h, ab):
        a_, b_ = ab  # (B, w, ...)
        A, Bv = jax.lax.associative_scan(_first_order_op, (a_, b_), axis=1)
        h_t = A * h[:, None] + Bv
        return h_t[:, -1], h_t

    hf, hs = jax.lax.scan(body, h0, (ac, bc))
    h = jnp.moveaxis(hs, 0, 1).reshape((B, n) + a.shape[2:])
    return h, hf


class MambaState(NamedTuple):
    conv: jax.Array  # (B, d_conv-1, d_inner) rolling conv inputs
    h: jax.Array  # (B, d_inner, d_state)


def mamba_specs(cfg):
    d = cfg.d_model
    mc = cfg.mamba
    d_in = mc.expand * d
    dt_rank = mc.dt_rank or max(1, d // 16)
    return {
        "in_proj": dense_specs(d, 2 * d_in, axes=("embed", "inner")),
        "conv_w": Spec((mc.d_conv, d_in), ("conv", "inner"), init="normal"),
        "conv_b": Spec((d_in,), ("inner",), init="zeros"),
        "x_proj": dense_specs(d_in, dt_rank + 2 * mc.d_state, axes=("inner", None)),
        "dt_proj": {
            "kernel": Spec((dt_rank, d_in), (None, "inner")),
            "bias": Spec((d_in,), ("inner",), init="constant", const=0.54),
        },
        "A_log": Spec((d_in, mc.d_state), ("inner", "state"), init="constant", const=0.0),
        "D": Spec((d_in,), ("inner",), init="ones"),
        "out_proj": dense_specs(d_in, d, axes=("inner", "embed")),
    }


def _causal_depthwise_conv(x, w, b, prepend=None):
    """x: (B, n, D); w: (K, D) depthwise.  Causal (left) padding."""
    K = w.shape[0]
    if prepend is None:
        prepend = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([prepend, x], axis=1)
    out = jnp.zeros_like(x)
    for i in range(K):  # K is tiny (4): unrolled taps
        out = out + xp[:, i : i + x.shape[1]] * w[i][None, None].astype(x.dtype)
    return out + b.astype(x.dtype), xp[:, -(K - 1) :] if K > 1 else prepend


def mamba_apply(p, x, cfg, state: MambaState | None = None, chunk: int = 128):
    """x: (B, n, d).  Returns (y, new_state)."""
    B, n, d = x.shape
    mc = cfg.mamba
    d_in = mc.expand * d
    ds = mc.d_state

    xz = constrain(dense_apply(p["in_proj"], x), ("batch", None, "inner"))
    xin = constrain(xz[..., :d_in], ("batch", None, "inner"))
    z = constrain(xz[..., d_in:], ("batch", None, "inner"))
    conv_prepend = state.conv if state is not None else None
    xc, conv_tail = _causal_depthwise_conv(
        xin, p["conv_w"], p["conv_b"], prepend=conv_prepend
    )
    xc = constrain(jax.nn.silu(xc), ("batch", None, "inner"))

    proj = dense_apply(p["x_proj"], xc)
    dt_rank = p["dt_proj"]["kernel"].shape[0]
    dt = proj[..., :dt_rank]
    Bc = proj[..., dt_rank : dt_rank + ds].astype(jnp.float32)
    Cc = proj[..., dt_rank + ds :].astype(jnp.float32)
    dt = jax.nn.softplus(
        dense_apply(p["dt_proj"], dt).astype(jnp.float32)
    )  # (B, n, d_in)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))  # (d_in, ds)

    w = min(chunk, n)
    pad = 0
    if n % w:
        pad = w - n % w
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bc = jnp.pad(Bc, ((0, 0), (0, pad), (0, 0)))
        Cc = jnp.pad(Cc, ((0, 0), (0, pad), (0, 0)))
        xcp = jnp.pad(xc.astype(jnp.float32), ((0, 0), (0, pad), (0, 0)))
    else:
        xcp = xc.astype(jnp.float32)
    npad = n + pad
    nc = npad // w

    h0 = (
        state.h.astype(jnp.float32)
        if state is not None
        else jnp.zeros((B, d_in, ds), jnp.float32)
    )

    dtc = jnp.moveaxis(dt.reshape(B, nc, w, d_in), 1, 0)
    Bcc = jnp.moveaxis(Bc.reshape(B, nc, w, ds), 1, 0)
    Ccc = jnp.moveaxis(Cc.reshape(B, nc, w, ds), 1, 0)
    xcc = jnp.moveaxis(xcp.reshape(B, nc, w, d_in), 1, 0)

    def body(h, inp):
        dt_, B_, C_, x_ = inp  # (B, w, .)
        decay = jnp.exp(dt_[..., None] * A[None, None])  # (B, w, d_in, ds)
        bu = (dt_ * x_)[..., None] * B_[:, :, None, :]
        Acum, Bcum = jax.lax.associative_scan(_first_order_op, (decay, bu), axis=1)
        hseq = Acum * h[:, None] + Bcum
        y = jnp.einsum("bwds,bws->bwd", hseq, C_)
        return hseq[:, -1], y

    hf, ys = jax.lax.scan(body, h0, (dtc, Bcc, Ccc, xcc))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, npad, d_in)[:, :n]
    y = y + xc.astype(jnp.float32) * p["D"].astype(jnp.float32)[None, None]
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    out = dense_apply(p["out_proj"], y)
    new_state = MambaState(conv=conv_tail.astype(x.dtype), h=hf)
    return out, new_state


def mamba_init_state(cfg, B, dtype=jnp.float32) -> MambaState:
    mc = cfg.mamba
    d_in = mc.expand * cfg.d_model
    return MambaState(
        conv=jnp.zeros((B, mc.d_conv - 1, d_in), jnp.bfloat16),
        h=jnp.zeros((B, d_in, mc.d_state), dtype),
    )


def mamba_state_axes() -> MambaState:
    """Logical axes per state leaf: d_inner shards with the "inner" rule."""
    from .param import Axes

    return MambaState(
        conv=Axes(("batch", None, "inner")),
        h=Axes(("batch", "inner", None)),
    )


# --------------------------------------------------------------------------
# SequenceOp registration
# --------------------------------------------------------------------------


def _mamba_forward(p, x, cfg, *, state=None, want_state=False,
                   positions=None):
    return mamba_apply(p, x, cfg, state=state)


def _mamba_step(p, x_t, state, cfg, *, positions=None):
    return mamba_apply(p, x_t, cfg, state=state)


from . import seq_op as _seq_op  # noqa: E402

_seq_op.register_op(_seq_op.SequenceOp(
    name="mamba",
    specs=mamba_specs,
    forward=_mamba_forward,
    step=_mamba_step,
    init_state=lambda cfg, B, *, max_len=0, dtype=None: mamba_init_state(
        cfg, B, jnp.float32 if dtype is None else dtype
    ),
    state_axes=lambda cfg: mamba_state_axes(),
    streaming=True,
    spec_decodable=True,
    prealloc_state=True,  # hybrid (jamba) prefill preallocates the whole
    #   stacked state tree so the group scan has a uniform carry
))
