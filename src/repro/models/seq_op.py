"""The ``SequenceOp`` registry — ONE operator API across the whole stack.

The paper positions higher-order attention as one of several
interchangeable causal streaming mixers (§5.2, "drop-in attention
replacement").  This module makes that interchangeability structural:
every sequence-mixing operator — the HLA family, softmax attention,
Mamba, RWKV-6, GLA, and whatever comes next — registers **one record**
describing everything the rest of the system needs:

* ``specs(cfg)``         — parameter specs for the sublayer;
* ``forward(p, x, cfg, *, state, want_state, positions)``
                         — full-sequence apply (train / chunk-parallel
                           prefill); returns ``(y, new_state)``;
* ``step(p, x_t, state, cfg, *, positions)``
                         — one-token decode; returns ``(y, new_state)``;
* ``init_state(cfg, B, *, max_len, dtype)`` / ``state_axes(cfg)`` /
  ``state_ndims(cfg)``   — the decode-state tree, its logical sharding
                           axes (the single source of truth consumed by
                           ``distributed.steps`` and the serving
                           ``StatePool``), and per-leaf ranks (for
                           ``shard_ops.call_sharded`` without an
                           ``eval_shape`` re-trace);
* capability flags       — ``streaming`` (O(1)-state decode; the serving
                           engine derives admissibility from this, not a
                           hardcoded tuple), ``has_fused_kernels``
                           (Pallas train/prefill/decode paths — selected
                           INSIDE the record, callers never see
                           Pallas-vs-jnp), ``spec_decodable``
                           (snapshot/rollback-safe state, required for
                           speculative decoding), ``needs_positions``
                           (consumes absolute positions, e.g. RoPE),
                           ``self_contained`` (owns its norms + channel
                           mix, replacing the whole block — RWKV-6),
                           ``prealloc_state`` (prefill must write into a
                           preallocated state, e.g. a KV cache).

``models/lm.py``, ``models/whisper.py``, ``serving/engine.py``,
``serving/spec/*`` and ``distributed/steps.py`` program against this
interface only.  Before this registry the repo carried five hand-synced
``variant ==`` / ``kind ==`` ladders; two PR-4 serving crashes
(hla3_paper state-tree mismatch, rwkv6 dtype carry) came from exactly
those ladders drifting apart.  A CI grep-guard now keeps dispatch out of
every other module.

Adding an operator is a one-file change: write the module, call
``register_op`` at import time (see ``models/gla.py`` for the worked
example), and list it in ``_BUILTIN_MODULES`` (or import it from your
launcher).  ``lm``/``engine``/``steps`` pick it up untouched.
"""

from __future__ import annotations

import dataclasses
import difflib
import functools
import importlib
from typing import Any, Callable, Dict, Optional, Tuple

import jax


class SequenceOpError(KeyError):
    """Unknown / duplicate operator — message lists the registry contents."""


@dataclasses.dataclass(frozen=True, eq=False)
class SequenceOp:
    """One registered sequence-mixing operator (see module docstring).

    ``forward``/``step`` receive the operator's OWN param subtree (what
    ``specs(cfg)`` declared), the residual-stream input, and the model
    config; state trees are whatever ``init_state`` returns — opaque to
    every caller.
    """

    name: str
    specs: Callable[[Any], Any]
    forward: Callable[..., Tuple[jax.Array, Any]]
    init_state: Callable[..., Any]
    state_axes: Callable[[Any], Any]
    step: Optional[Callable[..., Tuple[jax.Array, Any]]] = None
    state_ndims: Optional[Callable[[Any], Any]] = None
    # capability flags
    streaming: bool = False
    has_fused_kernels: bool = False
    spec_decodable: bool = False
    needs_positions: bool = False
    self_contained: bool = False
    prealloc_state: bool = False
    # optional analytic-cost override consumed by ``repro.obs.costs``:
    # ``cost_model(cfg, *, mode, seq_len, batch) -> dict`` may return
    # ``state_flops_per_token`` and/or ``state_bytes_per_token`` to
    # replace the builtin family formula for this op's state math
    # (projection FLOPs and state bytes always derive from the record's
    # own specs/init_state).  See ``models/gla.py`` for the worked
    # example and ``docs/DESIGN.md`` §15 for the contract.
    cost_model: Optional[Callable[..., Dict[str, float]]] = None
    # key the operator's params live under inside a layer's param dict
    # (kept stable for existing checkpoints: HLA family -> "mixer")
    param_key: Optional[str] = None

    def __post_init__(self):
        if self.param_key is None:
            object.__setattr__(self, "param_key", self.name)
        if self.streaming and self.step is None:
            raise SequenceOpError(
                f"op {self.name!r}: streaming=True requires a step()"
            )

    def resolve_state_ndims(self, cfg):
        """Per-leaf ranks of the state tree (``state_ndims`` override, or
        derived abstractly from ``init_state`` — no allocation)."""
        if self.state_ndims is not None:
            return self.state_ndims(cfg)
        abstract = jax.eval_shape(
            functools.partial(self.init_state, cfg, 1, max_len=8)
        )
        return jax.tree.map(lambda leaf: leaf.ndim, abstract)


_REGISTRY: Dict[str, SequenceOp] = {}

# Modules imported (lazily, on first registry access) for their
# ``register_op`` side effect.  Each entry is the whole integration of an
# operator: lm / serving / distributed never name them.
_BUILTIN_MODULES = ("attention", "mixer", "ssm", "rwkv6", "gla")
_loaded_modules: set = set()
_loading = False


def register_op(op: SequenceOp) -> SequenceOp:
    """Register ``op`` under ``op.name`` (the public extension point).

    Raises ``SequenceOpError`` on duplicate names — two records for one
    name is exactly the drift the registry exists to prevent.
    """
    if not isinstance(op, SequenceOp):
        raise TypeError(f"register_op expects a SequenceOp, got {type(op)}")
    if op.name in _REGISTRY:
        raise SequenceOpError(
            f"sequence op {op.name!r} is already registered; "
            f"registered ops: {sorted(_REGISTRY)}"
        )
    _REGISTRY[op.name] = op
    return op


def _ensure_builtins() -> None:
    """Import the builtin operator modules for their ``register_op`` side
    effect.  Per-module success tracking: a failed import raises NOW and
    is retried on the next registry access — never silently leaving a
    partial registry behind an 'unknown op' error.  ``_loading`` guards
    re-entrancy (a builtin module calling back into the registry while
    its siblings are still importing)."""
    global _loading
    if _loading or len(_loaded_modules) == len(_BUILTIN_MODULES):
        return
    _loading = True
    try:
        for mod in _BUILTIN_MODULES:
            if mod not in _loaded_modules:
                importlib.import_module(f".{mod}", __package__)
                _loaded_modules.add(mod)
    finally:
        _loading = False


def _unknown(name: str) -> SequenceOpError:
    known = sorted(_REGISTRY)
    close = difflib.get_close_matches(str(name), known, n=1)
    hint = f" (did you mean {close[0]!r}?)" if close else ""
    return SequenceOpError(
        f"unknown sequence op {name!r}{hint}; registered ops: {known}"
    )


def get_op(name: str) -> SequenceOp:
    """Look up a registered operator; unknown names fail with the full
    registry listing and the closest match (config typos are actionable)."""
    _ensure_builtins()
    if name not in _REGISTRY:
        raise _unknown(name)
    return _REGISTRY[name]


def registered_op_names() -> Tuple[str, ...]:
    _ensure_builtins()
    return tuple(sorted(_REGISTRY))


def streaming_op_names() -> Tuple[str, ...]:
    _ensure_builtins()
    return tuple(
        sorted(n for n, op in _REGISTRY.items() if op.streaming)
    )


def op_name_for(cfg) -> str:
    """The operator a ``ModelConfig`` requests.

    ``cfg.mixer`` names it directly ("softmax" is the legacy spelling of
    "attn").  There is deliberately NO silent fallback: a typo'd mixer
    used to fall through to ``cfg.hla.variant`` and train hla2 under a
    wrong name (the identical-losses bug noted in the old mixer module).
    """
    _ensure_builtins()
    name = "attn" if cfg.mixer == "softmax" else cfg.mixer
    if name not in _REGISTRY:
        raise _unknown(cfg.mixer)
    return name


def op_for(cfg) -> SequenceOp:
    """Resolve ``cfg`` to its registered ``SequenceOp``."""
    return _REGISTRY[op_name_for(cfg)]
