"""Composable model substrate (pure functional JAX)."""
