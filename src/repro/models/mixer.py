"""The HLA mixer sublayer — the paper's drop-in attention replacement (§5.2).

Multi-head projections around the core operators (hla2 / ahla / hla3 /
hla3_paper / linattn), with:

* per-head learnable decay gamma = sigmoid(a)  (cfg.hla.decay = "learned"),
  or a fixed scalar ("fixed"), or none ("none");
* GQA/MQA: K, V projected at n_kv_heads and broadcast to q heads — with
  ``share_kv_state`` the decode state stores S^K once per KV group (§5.2);
* optional ratio normalization (Eq. 3.4) and ridge lam (Alg. 1);
* per-head RMS output norm (standard practice for unnormalized linear
  attention outputs; paper is silent on output scaling — documented in
  DESIGN.md §7);
* training path: fused Pallas kernels for forward AND backward (TPU; the
  backward walks checkpointed chunk states in reverse — cfg.hla.fused_bwd,
  DESIGN.md §3) or jnp chunkwise (CPU);
* decode path: O(1)-state streaming steps (view A).
"""

from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

# NOTE: ``repro.core.__init__`` re-exports functions named like the
# submodules (``hla2``...), so module-level imports would grab the
# function.  Bind the submodules through sys.modules instead.
import importlib

core_ahla = importlib.import_module("repro.core.ahla")
core_hla2 = importlib.import_module("repro.core.hla2")
core_hla3 = importlib.import_module("repro.core.hla3")
core_lin = importlib.import_module("repro.core.linear_attn")
from ..kernels import ops as kops
from ..distributed import shard_ops
from ..distributed.sharding import constrain
from .blocks import dense_apply, dense_specs
from .param import Axes, Spec


class MixerState(NamedTuple):
    """Per-layer streaming state for decode."""

    kind: Any  # pytree payload (core state NamedTuple)


def mixer_specs(cfg):
    d, H, Hk, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    s = {
        "wq": dense_specs(d, H * dh, axes=("embed", "q_heads_flat"), bias=cfg.qkv_bias),
        "wk": dense_specs(d, Hk * dh, axes=("embed", "kv_heads_flat"), bias=cfg.qkv_bias),
        "wv": dense_specs(d, Hk * dh, axes=("embed", "kv_heads_flat"), bias=cfg.qkv_bias),
        "wo": dense_specs(H * dh, d, axes=("q_heads_flat", "embed")),
        "out_scale": Spec((H, dh), ("q_heads", "head_dim"), init="ones"),
    }
    if cfg.hla.decay == "learned":
        s["decay_a"] = Spec((H,), ("q_heads",), init="constant", const=3.0)
    return s


def _gamma(p, cfg, B):
    if cfg.hla.decay == "none":
        return None
    if cfg.hla.decay == "fixed":
        g = jnp.full((cfg.n_heads,), cfg.hla.fixed_gamma, jnp.float32)
    else:
        g = jax.nn.sigmoid(p["decay_a"].astype(jnp.float32))
    return jnp.broadcast_to(g[None], (B, cfg.n_heads))


def _project(p, x, cfg):
    B, n, _ = x.shape
    H, Hk, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = dense_apply(p["wq"], x).reshape(B, n, H, dh).swapaxes(1, 2)
    k = dense_apply(p["wk"], x).reshape(B, n, Hk, dh).swapaxes(1, 2)
    v = dense_apply(p["wv"], x).reshape(B, n, Hk, dh).swapaxes(1, 2)
    q = q * (dh**-0.5)
    if Hk != H:  # GQA: broadcast KV heads to query heads
        rep = H // Hk
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    spec = ("batch", "q_heads", None, None)
    return (constrain(q, spec), constrain(k, spec), constrain(v, spec))


def _out_norm(p, o, cfg, eps=1e-6):
    """Per-head RMS norm + learned scale (stabilizes unnormalized HLA)."""
    o32 = o.astype(jnp.float32)
    var = jnp.mean(o32 * o32, axis=-1, keepdims=True)
    o32 = o32 * jax.lax.rsqrt(var + eps)
    return (o32 * p["out_scale"][None, :, None, :]).astype(o.dtype)


def _pallas_enabled(hc) -> bool:
    """Fused Pallas kernels: native on TPU; elsewhere only when
    ``force_pallas`` opts into interpret mode (distributed tests/CI)."""
    return hc.use_pallas and (
        jax.default_backend() == "tpu" or hc.force_pallas
    )


# output ranks for shard_ops.call_sharded (avoids an eval_shape re-trace
# of the kernel per compile): state leaves are (B, H, d, d)/(B, H, d, dv)
# rank 4 and (B, H, d) rank 3; o is (B, H, n, dv); o_t is (B, H, dv).
_HLA2_STATE_NDIMS = core_hla2.HLA2State(4, 4, 3, 4, 3)
_AHLA_STATE_NDIMS = core_ahla.AHLAState(4, 4, 3, 4, 3)


def _variant(cfg):
    """The operator actually requested: cfg.mixer names it when it is an
    HLA-family mixer (the config override path sets cfg.mixer, not
    cfg.hla.variant — a silent-hla2-everywhere bug caught by the recall
    example producing identical losses for 'different' variants)."""
    if cfg.mixer in ("hla2", "ahla", "hla3", "hla3_paper", "linattn"):
        return cfg.mixer
    return cfg.hla.variant


def mixer_apply(p, x, cfg, want_state: bool = False, state=None):
    """Training/prefill path over a full sequence.  Returns (out, final_state).

    ``state`` is an optional streaming carry to resume from (incremental
    prefill); every path below threads it through.
    """
    B, n, _ = x.shape
    hc = cfg.hla
    q, k, v = _project(p, x, cfg)
    gamma = _gamma(p, cfg, B)
    # hla2/ahla prefill (want_state) rides the stateful kernel API
    # (kops.*_prefill returns the final carry); other variants still fall
    # back to the jnp chunkwise path when states are needed.  Inside a mesh
    # the kernel calls go through ``shard_ops.call_sharded``: each device
    # runs the fused kernel on its local (batch x head) row block
    # (batch -> "pod"/"data", heads -> "model"; DESIGN.md §9).
    use_pallas = _pallas_enabled(hc)
    kw = dict(normalize=hc.normalize, eps=1e-6)
    variant = _variant(cfg)

    if variant == "hla2":
        if hc.impl == "scan":  # paper-faithful token-level Blelloch
            o, st = core_hla2.hla2_scan(
                q, k, v, gamma, lam=hc.lam, state=state, **kw
            )
        elif use_pallas and (want_state or state is not None):
            # one chunk-parallel kernel call prefills the whole prompt and
            # hands back the exact streaming state (Section-4 identity)
            fn = functools.partial(
                kops.hla2_prefill, chunk=hc.chunk, lam=hc.lam, **kw
            )
            o, st = shard_ops.call_sharded(
                lambda q_, k_, v_, g_, s_: fn(q_, k_, v_, g_, state=s_),
                q, k, v, gamma, state,
                out_ndims=(4, _HLA2_STATE_NDIMS),
            )
        elif use_pallas:
            o = shard_ops.call_sharded(
                functools.partial(
                    kops.hla2_attention, chunk=hc.chunk, lam=hc.lam,
                    fused_bwd=hc.fused_bwd, **kw
                ),
                q, k, v, gamma, out_ndims=4,
            )
            st = None
        else:
            o, st = core_hla2.hla2_chunkwise(
                q, k, v, gamma, chunk=hc.chunk, lam=hc.lam, state=state, **kw
            )
    elif variant == "ahla":
        if hc.impl == "scan":
            o, st = core_ahla.ahla_scan(q, k, v, gamma, state=state, **kw)
        elif use_pallas and (want_state or state is not None):
            fn = functools.partial(kops.ahla_prefill, chunk=hc.chunk, **kw)
            o, st = shard_ops.call_sharded(
                lambda q_, k_, v_, g_, s_: fn(q_, k_, v_, g_, state=s_),
                q, k, v, gamma, state,
                out_ndims=(4, _AHLA_STATE_NDIMS),
            )
        elif use_pallas:
            o = shard_ops.call_sharded(
                functools.partial(
                    kops.ahla_attention, chunk=hc.chunk,
                    fused_bwd=hc.fused_bwd, **kw
                ),
                q, k, v, gamma, out_ndims=4,
            )
            st = None
        else:
            o, st = core_ahla.ahla_chunkwise(
                q, k, v, gamma, chunk=hc.chunk, state=state, **kw
            )
    elif variant == "hla3":
        o, st = core_hla3.hla3_exact_chunkwise(
            q, k, v, gamma, chunk=hc.chunk, state=state, **kw
        )
    elif variant == "hla3_paper":
        o, st = core_hla3.hla3_paper_chunkwise(
            q, k, v, chunk=hc.chunk, state=state, **kw
        )
    elif variant == "linattn":
        o, st = core_lin.linattn_chunkwise(
            q, k, v, gamma, chunk=hc.chunk, state=state, **kw
        )
    else:
        raise ValueError(variant)

    o = _out_norm(p, o.astype(x.dtype), cfg)
    o = o.swapaxes(1, 2).reshape(B, n, cfg.n_heads * cfg.head_dim)
    o = constrain(o, ("batch", None, "q_heads_flat"))
    return dense_apply(p["wo"], o), st


# Per-variant state-axes registry: every HLA-family decode-state leaf is a
# ``(batch, heads, ...feature)`` row tensor, declared field-by-field below
# so each variant is REGISTERED explicitly (hla3/hla3_paper included — the
# old rank-based inference silently depended on every future state leaf
# happening to follow the row layout).  Heads shard on "model" exactly like
# the kernel row grid; this is the sharding source of truth for decode
# states, consumed by ``distributed.steps.state_specs`` and the serving
# ``StatePool``.
_ROW_MAT = Axes(("batch", "q_heads", None, None))
_ROW_VEC = Axes(("batch", "q_heads", None))

_HLA2_AXES = core_hla2.HLA2State(
    S=_ROW_MAT, C=_ROW_MAT, m=_ROW_VEC, G=_ROW_MAT, h=_ROW_VEC
)
_LINATTN_AXES = core_lin.LinAttnState(P=_ROW_MAT, m=_ROW_VEC)

_STATE_AXES = {
    "hla2": _HLA2_AXES,
    "ahla": core_ahla.AHLAState(
        R=_ROW_MAT, P=_ROW_MAT, m=_ROW_VEC, E=_ROW_MAT, n=_ROW_VEC
    ),
    "hla3": core_hla3.HLA3ExactState(inner=_LINATTN_AXES, outer=_HLA2_AXES),
    "hla3_paper": core_hla3.HLA3ChunkState(
        SK=_ROW_MAT, SQ=_ROW_MAT, P=_ROW_MAT, m=_ROW_VEC,
        F=_ROW_MAT, eta=_ROW_VEC,
    ),
    "linattn": _LINATTN_AXES,
}


def mixer_state_axes(cfg):
    """Logical axes pytree matching ``mixer_init_state`` leaf-for-leaf,
    from the explicit per-variant registry above."""
    variant = _variant(cfg)
    if variant not in _STATE_AXES:
        raise ValueError(
            f"mixer variant {variant!r} has no state-axes registration"
        )
    return _STATE_AXES[variant]


def mixer_init_state(cfg, B, dtype=jnp.float32):
    H, dh = cfg.n_heads, cfg.head_dim
    variant = _variant(cfg)
    if variant == "hla2":
        return core_hla2.hla2_init_state((B, H), dh, dh, dtype)
    if variant == "ahla":
        return core_ahla.ahla_init_state((B, H), dh, dh, dtype)
    if variant == "hla3":
        return core_hla3.hla3_exact_init_state((B, H), dh, dh, dtype)
    if variant == "hla3_paper":
        # chunk-state layout: prefill (hla3_paper_chunkwise) and decode
        # (hla3_paper_chunk_step) share it; the Algorithm-3 10-field state
        # only serves the serial/scan fidelity paths.  Using it here made
        # serving impossible: prefill handed back a 6-field carry that
        # could never be scattered into a 10-field pool.
        return core_hla3.hla3_chunk_init_state((B, H), dh, dh, dtype)
    if variant == "linattn":
        return core_lin.linattn_init_state((B, H), dh, dh, dtype)
    raise ValueError(variant)


def mixer_step(p, x_t, state, cfg):
    """One-token decode.  x_t: (B, 1, d).  Returns (out, new_state).

    On TPU the hla2/ahla state update runs as ONE fused Pallas launch over
    all (batch, head) rows with in-place state I/O (kernels/decode_step.py)
    instead of the per-summary einsum chain; jnp steps remain the CPU path.
    """
    B = x_t.shape[0]
    hc = cfg.hla
    q, k, v = _project(p, x_t, cfg)  # (B, H, 1, dh)
    q1, k1, v1 = q[..., 0, :], k[..., 0, :], v[..., 0, :]
    gamma = _gamma(p, cfg, B)
    kw = dict(normalize=hc.normalize, eps=1e-6)
    fused_step = _pallas_enabled(hc)
    variant = _variant(cfg)
    if variant == "hla2":
        if fused_step:
            state, o = shard_ops.call_sharded(
                functools.partial(kops.hla2_decode_step, lam=hc.lam, **kw),
                state, q1, k1, v1, gamma,
                out_ndims=(_HLA2_STATE_NDIMS, 3),
            )
        else:
            state, o = core_hla2.hla2_step(
                state, q1, k1, v1, gamma, lam=hc.lam, **kw
            )
    elif variant == "ahla":
        if fused_step:
            state, o = shard_ops.call_sharded(
                functools.partial(kops.ahla_decode_step, **kw),
                state, q1, k1, v1, gamma,
                out_ndims=(_AHLA_STATE_NDIMS, 3),
            )
        else:
            state, o = core_ahla.ahla_step(state, q1, k1, v1, gamma, **kw)
    elif variant == "hla3":
        state, o = core_hla3.hla3_exact_step(state, q1, k1, v1, gamma, **kw)
    elif variant == "hla3_paper":
        # n=1 chunkwise call: same state layout AND same gamma=1 semantics
        # as the prefill path (the Alg.-3 step applied learned decay that
        # the chunk path never saw — prefill-then-decode diverged)
        state, o = core_hla3.hla3_paper_chunk_step(state, q1, k1, v1, **kw)
    elif variant == "linattn":
        state, o = core_lin.linattn_step(state, q1, k1, v1, gamma, **kw)
    else:
        raise ValueError(variant)
    o = o[..., None, :]  # (B, H, 1, dh)
    o = _out_norm(p, o.astype(x_t.dtype), cfg)
    o = o.swapaxes(1, 2).reshape(B, 1, cfg.n_heads * cfg.head_dim)
    return dense_apply(p["wo"], o), state
