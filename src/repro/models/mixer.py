"""The HLA mixer sublayer — the paper's drop-in attention replacement (§5.2).

Multi-head projections around the core operators (hla2 / ahla / hla3 /
hla3_paper / linattn), with:

* per-head learnable decay gamma = sigmoid(a)  (cfg.hla.decay = "learned"),
  or a fixed scalar ("fixed"), or none ("none");
* GQA/MQA: K, V projected at n_kv_heads and broadcast to q heads — with
  ``share_kv_state`` the decode state stores S^K once per KV group (§5.2);
* optional ratio normalization (Eq. 3.4) and ridge lam (Alg. 1);
* per-head RMS output norm (standard practice for unnormalized linear
  attention outputs; paper is silent on output scaling — documented in
  DESIGN.md §7);
* training path: fused Pallas kernels for forward AND backward (TPU; the
  backward walks checkpointed chunk states in reverse — cfg.hla.fused_bwd,
  DESIGN.md §3) or jnp chunkwise (CPU);
* decode path: O(1)-state streaming steps (view A).

Each variant is registered as ONE ``seq_op.SequenceOp`` record
(DESIGN.md §11); the old five ``variant ==`` ladders are gone.  The
Pallas-vs-jnp selection and the ``shard_ops.call_sharded`` mesh dispatch
live inside each record's forward/step — lm / serving / distributed
callers never see them.  ``mixer_apply``/``mixer_step``/
``mixer_init_state``/``mixer_state_axes`` remain as thin registry-backed
wrappers for direct (test / example) callers.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

# NOTE: ``repro.core.__init__`` re-exports functions named like the
# submodules (``hla2``...), so module-level imports would grab the
# function.  Bind the submodules through sys.modules instead.
import importlib

core_ahla = importlib.import_module("repro.core.ahla")
core_hla2 = importlib.import_module("repro.core.hla2")
core_hla3 = importlib.import_module("repro.core.hla3")
core_lin = importlib.import_module("repro.core.linear_attn")
from ..kernels import ops as kops
from ..distributed import shard_ops
from ..distributed.sharding import constrain
from . import seq_op
from .blocks import dense_apply, dense_specs
from .param import Axes, Spec


def mixer_specs(cfg):
    d, H, Hk, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    s = {
        "wq": dense_specs(d, H * dh, axes=("embed", "q_heads_flat"), bias=cfg.qkv_bias),
        "wk": dense_specs(d, Hk * dh, axes=("embed", "kv_heads_flat"), bias=cfg.qkv_bias),
        "wv": dense_specs(d, Hk * dh, axes=("embed", "kv_heads_flat"), bias=cfg.qkv_bias),
        "wo": dense_specs(H * dh, d, axes=("q_heads_flat", "embed")),
        "out_scale": Spec((H, dh), ("q_heads", "head_dim"), init="ones"),
    }
    if cfg.hla.decay == "learned":
        s["decay_a"] = Spec((H,), ("q_heads",), init="constant", const=3.0)
    return s


def _gamma(p, cfg, B):
    if cfg.hla.decay == "none":
        return None
    if cfg.hla.decay == "fixed":
        g = jnp.full((cfg.n_heads,), cfg.hla.fixed_gamma, jnp.float32)
    else:
        g = jax.nn.sigmoid(p["decay_a"].astype(jnp.float32))
    return jnp.broadcast_to(g[None], (B, cfg.n_heads))


def _project(p, x, cfg):
    B, n, _ = x.shape
    H, Hk, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = dense_apply(p["wq"], x).reshape(B, n, H, dh).swapaxes(1, 2)
    k = dense_apply(p["wk"], x).reshape(B, n, Hk, dh).swapaxes(1, 2)
    v = dense_apply(p["wv"], x).reshape(B, n, Hk, dh).swapaxes(1, 2)
    q = q * (dh**-0.5)
    if Hk != H:  # GQA: broadcast KV heads to query heads
        rep = H // Hk
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    spec = ("batch", "q_heads", None, None)
    return (constrain(q, spec), constrain(k, spec), constrain(v, spec))


def _out_norm(p, o, cfg, eps=1e-6):
    """Per-head RMS norm + learned scale (stabilizes unnormalized HLA)."""
    o32 = o.astype(jnp.float32)
    var = jnp.mean(o32 * o32, axis=-1, keepdims=True)
    o32 = o32 * jax.lax.rsqrt(var + eps)
    return (o32 * p["out_scale"][None, :, None, :]).astype(o.dtype)


def _pallas_enabled(hc) -> bool:
    """Fused Pallas kernels: native on TPU; elsewhere only when
    ``force_pallas`` opts into interpret mode (distributed tests/CI)."""
    return hc.use_pallas and (
        jax.default_backend() == "tpu" or hc.force_pallas
    )


# output ranks for shard_ops.call_sharded (avoids an eval_shape re-trace
# of the kernel per compile): state leaves are (B, H, d, d)/(B, H, d, dv)
# rank 4 and (B, H, d) rank 3; o is (B, H, n, dv); o_t is (B, H, dv).
_HLA2_STATE_NDIMS = core_hla2.HLA2State(4, 4, 3, 4, 3)
_AHLA_STATE_NDIMS = core_ahla.AHLAState(4, 4, 3, 4, 3)


# --------------------------------------------------------------------------
# per-variant cores: full-sequence forward + one-token step over projected
# (B, H, n, dh) rows.  Pallas/jnp/mesh selection is sealed in here.
# --------------------------------------------------------------------------


def _hla2_fwd(q, k, v, gamma, hc, *, state, want_state, kw):
    if hc.impl == "scan":  # paper-faithful token-level Blelloch
        return core_hla2.hla2_scan(q, k, v, gamma, lam=hc.lam, state=state, **kw)
    if _pallas_enabled(hc) and (want_state or state is not None):
        # one chunk-parallel kernel call prefills the whole prompt and
        # hands back the exact streaming state (Section-4 identity)
        fn = functools.partial(
            kops.hla2_prefill, chunk=hc.chunk, lam=hc.lam, **kw
        )
        return shard_ops.call_sharded(
            lambda q_, k_, v_, g_, s_: fn(q_, k_, v_, g_, state=s_),
            q, k, v, gamma, state,
            out_ndims=(4, _HLA2_STATE_NDIMS),
        )
    if _pallas_enabled(hc):
        o = shard_ops.call_sharded(
            functools.partial(
                kops.hla2_attention, chunk=hc.chunk, lam=hc.lam,
                fused_bwd=hc.fused_bwd, **kw
            ),
            q, k, v, gamma, out_ndims=4,
        )
        return o, None
    return core_hla2.hla2_chunkwise(
        q, k, v, gamma, chunk=hc.chunk, lam=hc.lam, state=state, **kw
    )


def _hla2_step(state, q1, k1, v1, gamma, hc, kw):
    if _pallas_enabled(hc):
        return shard_ops.call_sharded(
            functools.partial(kops.hla2_decode_step, lam=hc.lam, **kw),
            state, q1, k1, v1, gamma,
            out_ndims=(_HLA2_STATE_NDIMS, 3),
        )
    return core_hla2.hla2_step(state, q1, k1, v1, gamma, lam=hc.lam, **kw)


def _ahla_fwd(q, k, v, gamma, hc, *, state, want_state, kw):
    if hc.impl == "scan":
        return core_ahla.ahla_scan(q, k, v, gamma, state=state, **kw)
    if _pallas_enabled(hc) and (want_state or state is not None):
        fn = functools.partial(kops.ahla_prefill, chunk=hc.chunk, **kw)
        return shard_ops.call_sharded(
            lambda q_, k_, v_, g_, s_: fn(q_, k_, v_, g_, state=s_),
            q, k, v, gamma, state,
            out_ndims=(4, _AHLA_STATE_NDIMS),
        )
    if _pallas_enabled(hc):
        o = shard_ops.call_sharded(
            functools.partial(
                kops.ahla_attention, chunk=hc.chunk,
                fused_bwd=hc.fused_bwd, **kw
            ),
            q, k, v, gamma, out_ndims=4,
        )
        return o, None
    return core_ahla.ahla_chunkwise(
        q, k, v, gamma, chunk=hc.chunk, state=state, **kw
    )


def _ahla_step(state, q1, k1, v1, gamma, hc, kw):
    if _pallas_enabled(hc):
        return shard_ops.call_sharded(
            functools.partial(kops.ahla_decode_step, **kw),
            state, q1, k1, v1, gamma,
            out_ndims=(_AHLA_STATE_NDIMS, 3),
        )
    return core_ahla.ahla_step(state, q1, k1, v1, gamma, **kw)


def _hla3_fwd(q, k, v, gamma, hc, *, state, want_state, kw):
    return core_hla3.hla3_exact_chunkwise(
        q, k, v, gamma, chunk=hc.chunk, state=state, **kw
    )


def _hla3_step(state, q1, k1, v1, gamma, hc, kw):
    return core_hla3.hla3_exact_step(state, q1, k1, v1, gamma, **kw)


def _hla3_paper_fwd(q, k, v, gamma, hc, *, state, want_state, kw):
    return core_hla3.hla3_paper_chunkwise(
        q, k, v, chunk=hc.chunk, state=state, **kw
    )


def _hla3_paper_step(state, q1, k1, v1, gamma, hc, kw):
    # n=1 chunkwise call: same state layout AND same gamma=1 semantics
    # as the prefill path (the Alg.-3 step applied learned decay that
    # the chunk path never saw — prefill-then-decode diverged)
    return core_hla3.hla3_paper_chunk_step(state, q1, k1, v1, **kw)


def _linattn_fwd(q, k, v, gamma, hc, *, state, want_state, kw):
    return core_lin.linattn_chunkwise(
        q, k, v, gamma, chunk=hc.chunk, state=state, **kw
    )


def _linattn_step(state, q1, k1, v1, gamma, hc, kw):
    return core_lin.linattn_step(state, q1, k1, v1, gamma, **kw)


# --------------------------------------------------------------------------
# record assembly: shared projection/out-norm wrapper around each core
# --------------------------------------------------------------------------


def _sublayer_forward(core_fwd):
    def forward(p, x, cfg, *, state=None, want_state=False, positions=None):
        """Training/prefill path over a full sequence.  Returns
        (out, final_state); ``state`` is an optional streaming carry to
        resume from (incremental prefill)."""
        B, n, _ = x.shape
        hc = cfg.hla
        q, k, v = _project(p, x, cfg)
        gamma = _gamma(p, cfg, B)
        kw = dict(normalize=hc.normalize, eps=1e-6)
        o, st = core_fwd(q, k, v, gamma, hc, state=state,
                         want_state=want_state, kw=kw)
        o = _out_norm(p, o.astype(x.dtype), cfg)
        o = o.swapaxes(1, 2).reshape(B, n, cfg.n_heads * cfg.head_dim)
        o = constrain(o, ("batch", None, "q_heads_flat"))
        return dense_apply(p["wo"], o), st

    return forward


def _sublayer_step(core_step):
    def step(p, x_t, state, cfg, *, positions=None):
        """One-token decode.  x_t: (B, 1, d).  Returns (out, new_state).

        On TPU the hla2/ahla state update runs as ONE fused Pallas launch
        over all (batch, head) rows with in-place state I/O
        (kernels/decode_step.py); jnp steps remain the CPU path.
        """
        B = x_t.shape[0]
        hc = cfg.hla
        q, k, v = _project(p, x_t, cfg)  # (B, H, 1, dh)
        q1, k1, v1 = q[..., 0, :], k[..., 0, :], v[..., 0, :]
        gamma = _gamma(p, cfg, B)
        kw = dict(normalize=hc.normalize, eps=1e-6)
        state, o = core_step(state, q1, k1, v1, gamma, hc, kw)
        o = o[..., None, :]  # (B, H, 1, dh)
        o = _out_norm(p, o.astype(x_t.dtype), cfg)
        o = o.swapaxes(1, 2).reshape(B, 1, cfg.n_heads * cfg.head_dim)
        return dense_apply(p["wo"], o), state

    return step


def _mixer_init(core_init):
    def init_state(cfg, B, *, max_len=0, dtype=None):
        dh = cfg.head_dim
        return core_init(
            (B, cfg.n_heads), dh, dh,
            jnp.float32 if dtype is None else dtype,
        )

    return init_state


# Per-variant state axes: every HLA-family decode-state leaf is a
# ``(batch, heads, ...feature)`` row tensor, declared field-by-field so
# each variant's layout is EXPLICIT (hla3/hla3_paper included — rank-based
# inference silently depended on every future state leaf happening to
# follow the row layout).  Heads shard on "model" exactly like the kernel
# row grid; consumed via ``SequenceOp.state_axes`` by
# ``distributed.steps.state_specs`` and the serving ``StatePool``.
_ROW_MAT = Axes(("batch", "q_heads", None, None))
_ROW_VEC = Axes(("batch", "q_heads", None))

_HLA2_AXES = core_hla2.HLA2State(
    S=_ROW_MAT, C=_ROW_MAT, m=_ROW_VEC, G=_ROW_MAT, h=_ROW_VEC
)
_LINATTN_AXES = core_lin.LinAttnState(P=_ROW_MAT, m=_ROW_VEC)


def _register(name, core_fwd, core_step, core_init, axes, ndims=None,
              fused=False):
    seq_op.register_op(seq_op.SequenceOp(
        name=name,
        specs=mixer_specs,
        forward=_sublayer_forward(core_fwd),
        step=_sublayer_step(core_step),
        init_state=_mixer_init(core_init),
        state_axes=lambda cfg, _axes=axes: _axes,
        state_ndims=(None if ndims is None else (lambda cfg, _n=ndims: _n)),
        streaming=True,
        has_fused_kernels=fused,
        spec_decodable=True,
        param_key="mixer",
    ))


_register("hla2", _hla2_fwd, _hla2_step, core_hla2.hla2_init_state,
          _HLA2_AXES, ndims=_HLA2_STATE_NDIMS, fused=True)
_register("ahla", _ahla_fwd, _ahla_step, core_ahla.ahla_init_state,
          core_ahla.AHLAState(R=_ROW_MAT, P=_ROW_MAT, m=_ROW_VEC,
                              E=_ROW_MAT, n=_ROW_VEC),
          ndims=_AHLA_STATE_NDIMS, fused=True)
_register("hla3", _hla3_fwd, _hla3_step, core_hla3.hla3_exact_init_state,
          core_hla3.HLA3ExactState(inner=_LINATTN_AXES, outer=_HLA2_AXES))
# chunk-state layout: prefill (hla3_paper_chunkwise) and decode
# (hla3_paper_chunk_step) share it; the Algorithm-3 10-field state only
# serves the serial/scan fidelity paths.  Using it here made serving
# impossible: prefill handed back a 6-field carry that could never be
# scattered into a 10-field pool.
_register("hla3_paper", _hla3_paper_fwd, _hla3_paper_step,
          core_hla3.hla3_chunk_init_state,
          core_hla3.HLA3ChunkState(SK=_ROW_MAT, SQ=_ROW_MAT, P=_ROW_MAT,
                                   m=_ROW_VEC, F=_ROW_MAT, eta=_ROW_VEC))
_register("linattn", _linattn_fwd, _linattn_step,
          core_lin.linattn_init_state, _LINATTN_AXES)


# --------------------------------------------------------------------------
# registry-backed wrappers (direct callers: tests, examples, whisper compat)
# --------------------------------------------------------------------------


def mixer_apply(p, x, cfg, want_state: bool = False, state=None):
    """Full-sequence apply through the registered record for ``cfg``."""
    return seq_op.op_for(cfg).forward(
        p, x, cfg, state=state, want_state=want_state
    )


def mixer_step(p, x_t, state, cfg):
    """One-token decode through the registered record for ``cfg``."""
    return seq_op.op_for(cfg).step(p, x_t, state, cfg)


def mixer_init_state(cfg, B, dtype=jnp.float32):
    return seq_op.op_for(cfg).init_state(cfg, B, dtype=dtype)


def mixer_state_axes(cfg):
    """Logical axes pytree matching ``mixer_init_state`` leaf-for-leaf."""
    return seq_op.op_for(cfg).state_axes(cfg)
