"""Second-order Higher-order Linear Attention (HLA2).

Implements the paper's Section 3 / 4 / 5 in four exactly-equivalent forms:

* ``hla2_naive``     — view (B): materializes the n x n masked second-order
                       weights.  O(n^2).  Test oracle only.
* ``hla2_serial``    — view (A): the streaming recurrence of Theorem 3.1 /
                       Section 4.3 (``lax.scan`` over tokens).  Decode path.
* ``hla2_scan``      — view (C), paper-faithful: token-level associative
                       (Blelloch) scan with the masked semidirect-product
                       monoid of Eq. (4.1) (decay-aware variant included).
* ``hla2_chunkwise`` — view (C), TPU-adapted: intra-chunk masked *matmul*
                       form + sequential inter-chunk carry.  This is the
                       beyond-paper reformulation described in DESIGN.md §2;
                       it computes bit-identical math on MXU-aligned tiles.

Decay erratum (documented in DESIGN.md §7): the paper's printed decay-aware
masked monoid (Section 4.2) composes ``G`` as ``rho_B G_A + ... + S_B (rho_B
C_A)`` which is *not associative* (direct 3-segment expansion disagrees by a
factor ``rho``).  The consistent algebra — the one for which
``q_t^T (S_t C_t - G_t)`` equals the strictly-causal part of the doubly
decayed product — decays the cross summaries at rate ``gamma**2``:

    S_t = g S_{t-1} + k_t k_t^T          C_t = g C_{t-1} + q_t v_t^T
    m_t = g m_{t-1} + q_t
    G_t = g^2 G_{t-1} + g * k_t (k_t^T C_{t-1})
    h_t = g^2 h_{t-1} + g * k_t (k_t^T m_{t-1})

with segment composition (A then B, attenuation rho = gamma^len):

    S = rB S_A + S_B            C = rB C_A + C_B        m = rB m_A + m_B
    G = rB^2 G_A + G_B + rB S_B C_A
    h = rB^2 h_A + h_B + rB S_B m_A
    rho = rA rB

At ``gamma == 1`` this is exactly Eq. (4.1).  The masked output weight it
realizes is

    num_t = sum_{i<=j<=t} g^{(t-i)+(t-j)} (q_t.k_i)(k_i.q_j) v_j

i.e. every pairwise interaction decays toward the *current* horizon t
(retention-style), which is the unique streaming-homogeneous choice.

All functions take ``q, k: (..., n, d)`` and ``v: (..., n, dv)`` with any
leading batch dims, and a ``gamma`` broadcastable to the leading dims
(per-head decay).  State math runs in fp32 regardless of input dtype.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


class HLA2State(NamedTuple):
    """Constant-size per-head state tuple (Fig. 1(A))."""

    S: jax.Array  # (..., d, d)   prefix key second moment
    C: jax.Array  # (..., d, dv)  query-value accumulator
    m: jax.Array  # (..., d)      query mass
    G: jax.Array  # (..., d, dv)  masked cross summary (Thm 3.1)
    h: jax.Array  # (..., d)      masked cross summary (Thm 3.1)


def hla2_init_state(batch_shape, d: int, dv: int, dtype=jnp.float32) -> HLA2State:
    z = functools.partial(jnp.zeros, dtype=dtype)
    return HLA2State(
        S=z(batch_shape + (d, d)),
        C=z(batch_shape + (d, dv)),
        m=z(batch_shape + (d,)),
        G=z(batch_shape + (d, dv)),
        h=z(batch_shape + (d,)),
    )


def _gamma_arr(gamma, batch_shape, dtype):
    if gamma is None:
        return jnp.ones(batch_shape, dtype)
    g = jnp.asarray(gamma, dtype)
    return jnp.broadcast_to(g, batch_shape)


def _compute_dtype(x: jax.Array):
    """State/accumulation dtype: at least fp32, fp64 if inputs are fp64."""
    return jnp.promote_types(x.dtype, jnp.float32)


# --------------------------------------------------------------------------
# View (A): streaming recurrence — Theorem 3.1 online updates + Section 4.3.
# --------------------------------------------------------------------------


def hla2_step(
    state: HLA2State,
    q_t: jax.Array,  # (..., d)
    k_t: jax.Array,  # (..., d)
    v_t: jax.Array,  # (..., dv)
    gamma=None,
    *,
    normalize: bool = False,
    eps: float = 1e-6,
    lam: float = 0.0,
):
    """One token of the masked streaming recurrence.  Returns (state, o_t).

    Per-token cost O(d^2 + d dv); no n x n objects (Theorem 3.1).
    """
    dtype = state.S.dtype
    q_t = q_t.astype(dtype)
    k_t = k_t.astype(dtype)
    v_t = v_t.astype(dtype)
    g = _gamma_arr(gamma, q_t.shape[:-1], dtype)  # (batch,)
    gv = g[..., None]  # for (..., d) vectors
    gm = g[..., None, None]  # for (..., d, d') matrices

    # Cross summaries first: they consume the *previous* C, m (strict
    # causality), with the gamma**2 / gamma corrected decay (see module doc).
    kC = jnp.einsum("...d,...de->...e", k_t, state.C)  # k^T C_{t-1}
    km = jnp.einsum("...d,...d->...", k_t, state.m)  # k^T m_{t-1}
    G = gm**2 * state.G + gm * k_t[..., :, None] * kC[..., None, :]
    h = gv**2 * state.h + gv * k_t * km[..., None]

    S = gm * state.S + k_t[..., :, None] * k_t[..., None, :]
    C = gm * state.C + q_t[..., :, None] * v_t[..., None, :]
    m = gv * state.m + q_t

    u = jnp.einsum("...d,...de->...e", q_t, S)  # q^T S   (O(d^2) matvec)
    num = jnp.einsum("...d,...de->...e", u, C) - jnp.einsum(
        "...d,...de->...e", q_t, G
    )
    if lam:
        num = num + lam * jnp.einsum("...d,...de->...e", q_t, C)
    o = num
    if normalize:
        den = jnp.einsum("...d,...d->...", u, m) - jnp.einsum(
            "...d,...d->...", q_t, h
        )
        if lam:
            den = den + lam * jnp.einsum("...d,...d->...", q_t, m)
        o = num / (den[..., None] + eps)
    return HLA2State(S, C, m, G, h), o


def hla2_serial(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    gamma=None,
    *,
    normalize: bool = False,
    eps: float = 1e-6,
    lam: float = 0.0,
    state: Optional[HLA2State] = None,
):
    """Serial recurrence over the whole sequence (view A).  Returns (o, state)."""
    batch_shape = q.shape[:-2]
    n, d = q.shape[-2], q.shape[-1]
    dv = v.shape[-1]
    if state is None:
        state = hla2_init_state(batch_shape, d, dv, _compute_dtype(q))

    def body(st, qkv):
        q_t, k_t, v_t = qkv
        st, o_t = hla2_step(
            st, q_t, k_t, v_t, gamma, normalize=normalize, eps=eps, lam=lam
        )
        return st, o_t

    # scan over time: move time to axis 0
    qs = jnp.moveaxis(q, -2, 0)
    ks = jnp.moveaxis(k, -2, 0)
    vs = jnp.moveaxis(v, -2, 0)
    state, os_ = jax.lax.scan(body, state, (qs, ks, vs))
    return jnp.moveaxis(os_, 0, -2).astype(v.dtype), state


# --------------------------------------------------------------------------
# View (B): O(n^2) oracle.
# --------------------------------------------------------------------------


def hla2_naive(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    gamma=None,
    *,
    normalize: bool = False,
    eps: float = 1e-6,
    lam: float = 0.0,
):
    """Materialized masked second-order attention (Section 3.1), test oracle.

    gamma == None:  o_t = row_t[ ((W W^T) . L) V ],  W = L . (Q K^T).
    gamma != None:  num_t = sum_{i<=j<=t} g^{(t-i)+(t-j)} (q_t.k_i)(k_i.q_j) v_j
    (the streaming-homogeneous decayed form; see module docstring).
    """
    dtype = _compute_dtype(q)
    q32, k32, v32 = q.astype(dtype), k.astype(dtype), v.astype(dtype)
    n = q.shape[-2]
    batch_shape = q.shape[:-2]
    t_idx = jnp.arange(n)
    L = (t_idx[:, None] >= t_idx[None, :]).astype(dtype)  # lower incl diag
    g = _gamma_arr(gamma, batch_shape, dtype)[..., None, None]

    qk = jnp.einsum("...td,...jd->...tj", q32, k32)  # Q K^T
    if gamma is None:
        W = qk * L
        T2 = jnp.einsum("...ti,...ji->...tj", W, W) * L
        num = jnp.einsum("...tj,...je->...te", T2, v32)
        den = jnp.sum(T2, axis=-1)
    else:
        # weight(t,j) = sum_{i<=j} g^{(t-i)+(t-j)} (q_t.k_i)(k_i.q_j), j<=t
        kq = jnp.einsum("...id,...jd->...ij", k32, q32)  # k_i . q_j
        # inner(t,j) = sum_{i<=j} g^{t-i} qk[t,i] * kq[i,j]
        Ui = (t_idx[:, None] <= t_idx[None, :]).astype(dtype)  # i<=j
        pow_t_i = jnp.power(g, (t_idx[:, None] - t_idx[None, :]).astype(dtype))
        A = qk * L * pow_t_i  # g^{t-i} masked
        B = kq * Ui
        inner = jnp.einsum("...ti,...ij->...tj", A, B)
        pow_t_j = jnp.power(g, (t_idx[:, None] - t_idx[None, :]).astype(dtype))
        T2 = inner * L * pow_t_j
        num = jnp.einsum("...tj,...je->...te", T2, v32)
        den = jnp.sum(T2, axis=-1)
    if lam:
        # ridge: + lam * first-order (q,q,v) masked linear attention, decayed
        if gamma is None:
            Wqq = jnp.einsum("...td,...jd->...tj", q32, q32) * L
        else:
            pw = jnp.power(g, (t_idx[:, None] - t_idx[None, :]).astype(dtype))
            Wqq = jnp.einsum("...td,...jd->...tj", q32, q32) * L * pw
        num = num + lam * jnp.einsum("...tj,...je->...te", Wqq, v32)
        den = den + lam * jnp.sum(Wqq, axis=-1)
    if normalize:
        return (num / (den[..., None] + eps)).astype(v.dtype)
    return num.astype(v.dtype)


# --------------------------------------------------------------------------
# View (C) paper-faithful: token-level associative scan, Eq. (4.1) monoid.
# --------------------------------------------------------------------------


def masked_op(a: HLA2State, b: HLA2State) -> HLA2State:
    """Undecayed masked semidirect product, Eq. (4.1).  A then B."""
    return HLA2State(
        S=a.S + b.S,
        C=a.C + b.C,
        m=a.m + b.m,
        G=a.G + b.G + jnp.einsum("...ij,...je->...ie", b.S, a.C),
        h=a.h + b.h + jnp.einsum("...ij,...j->...i", b.S, a.m),
    )


class HLA2DecayState(NamedTuple):
    S: jax.Array
    C: jax.Array
    m: jax.Array
    G: jax.Array
    h: jax.Array
    rho: jax.Array  # (...,) segment attenuation gamma^len


def masked_op_decay(a: HLA2DecayState, b: HLA2DecayState) -> HLA2DecayState:
    """Corrected decay-aware masked monoid (associative; see module doc)."""
    rB = b.rho[..., None, None]
    rBv = b.rho[..., None]
    return HLA2DecayState(
        S=rB * a.S + b.S,
        C=rB * a.C + b.C,
        m=rBv * a.m + b.m,
        G=rB**2 * a.G + b.G + rB * jnp.einsum("...ij,...je->...ie", b.S, a.C),
        h=rBv**2 * a.h + b.h + rBv * jnp.einsum("...ij,...j->...i", b.S, a.m),
        rho=a.rho * b.rho,
    )


def masked_op_decay_paper(a: HLA2DecayState, b: HLA2DecayState) -> HLA2DecayState:
    """The paper's printed decay-aware masked concatenation (Section 4.2).

    Kept verbatim for the property test demonstrating it is NOT associative
    (DESIGN.md §7 erratum).  Do not use for computation.
    """
    rB = b.rho[..., None, None]
    rBv = b.rho[..., None]
    return HLA2DecayState(
        S=rB * a.S + b.S,
        C=rB * a.C + b.C,
        m=rBv * a.m + b.m,
        G=rB * a.G + b.G + jnp.einsum("...ij,...je->...ie", b.S, rB * a.C),
        h=rBv * a.h + b.h + jnp.einsum("...ij,...j->...i", b.S, rBv * a.m),
        rho=a.rho * b.rho,
    )


def hla2_scan(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    gamma=None,
    *,
    normalize: bool = False,
    eps: float = 1e-6,
    lam: float = 0.0,
    state: Optional[HLA2State] = None,
):
    """Token-level Blelloch scan (paper view (C), Theorem 4.1).

    Builds single-token segments and runs ``jax.lax.associative_scan`` with
    the masked monoid; inclusive per-token states then produce outputs via
    Theorem 3.1.  This is the paper-faithful baseline path: it materializes
    (n, ..., d, d) prefix tensors, trading memory for span O(log n).
    """
    dtype = _compute_dtype(q)
    batch_shape = q.shape[:-2]
    n, d = q.shape[-2], q.shape[-1]
    dv = v.shape[-1]
    q32 = jnp.moveaxis(q.astype(dtype), -2, 0)  # (n, ..., d)
    k32 = jnp.moveaxis(k.astype(dtype), -2, 0)
    v32 = jnp.moveaxis(v.astype(dtype), -2, 0)

    dS = k32[..., :, None] * k32[..., None, :]  # (n, ..., d, d)
    dC = q32[..., :, None] * v32[..., None, :]
    dm = q32
    zG = jnp.zeros((n,) + batch_shape + (d, dv), dtype)
    zh = jnp.zeros((n,) + batch_shape + (d,), dtype)

    if gamma is None:
        elems = HLA2State(dS, dC, dm, zG, zh)
        inc = jax.lax.associative_scan(masked_op, elems, axis=0)
        S, C, m, G, h = inc
    else:
        g = jnp.broadcast_to(
            _gamma_arr(gamma, batch_shape, dtype)[None], (n,) + batch_shape
        )
        elems = HLA2DecayState(dS, dC, dm, zG, zh, g)
        inc = jax.lax.associative_scan(masked_op_decay, elems, axis=0)
        S, C, m, G, h = inc.S, inc.C, inc.m, inc.G, inc.h

    if state is not None:
        # fold a carry-in state (prefix from previous segment) into every
        # inclusive state via one extra monoid application.
        rho_seg = (
            jnp.cumprod(
                jnp.broadcast_to(
                    _gamma_arr(gamma, batch_shape, dtype)[None],
                    (n,) + batch_shape,
                ),
                axis=0,
            )
            if gamma is not None
            else jnp.ones((n,) + batch_shape, dtype)
        )
        a = HLA2DecayState(
            state.S, state.C, state.m, state.G, state.h,
            jnp.ones(batch_shape, dtype),
        )
        b = HLA2DecayState(S, C, m, G, h, rho_seg)
        merged = masked_op_decay(a, b)
        S, C, m, G, h = merged.S, merged.C, merged.m, merged.G, merged.h

    u = jnp.einsum("n...d,n...de->n...e", q32, S)
    num = jnp.einsum("n...e,n...ef->n...f", u, C) - jnp.einsum(
        "n...d,n...df->n...f", q32, G
    )
    if lam:
        num = num + lam * jnp.einsum("n...d,n...df->n...f", q32, C)
    o = num
    if normalize:
        den = jnp.einsum("n...e,n...e->n...", u, m) - jnp.einsum(
            "n...d,n...d->n...", q32, h
        )
        if lam:
            den = den + lam * jnp.einsum("n...d,n...d->n...", q32, m)
        o = num / (den[..., None] + eps)
    out = jnp.moveaxis(o, 0, -2).astype(v.dtype)
    final = HLA2State(S[-1], C[-1], m[-1], G[-1], h[-1])
    return out, final


# --------------------------------------------------------------------------
# View (C) TPU-adapted: chunkwise masked-matmul form (DESIGN.md §2).
# --------------------------------------------------------------------------


def _decay_matrices(n: int, g: jax.Array, dtype):
    """L_gamma[t, j] = g^(t-j) for j <= t else 0, and power vectors.

    ``g`` has shape ``batch_shape``; the result broadcasts as
    (..., n, n) / (..., n).
    """
    t_idx = jnp.arange(n)
    diff = (t_idx[:, None] - t_idx[None, :]).astype(dtype)
    mask = t_idx[:, None] >= t_idx[None, :]
    gb = g[..., None, None]
    Lg = jnp.where(mask, jnp.power(jnp.maximum(gb, 1e-30), diff), 0.0)
    pow_t = jnp.power(g[..., None], (t_idx + 1).astype(dtype))  # g^t, t=1..n
    return Lg, pow_t


def hla2_chunkwise(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    gamma=None,
    *,
    chunk: int = 64,
    normalize: bool = False,
    eps: float = 1e-6,
    lam: float = 0.0,
    state: Optional[HLA2State] = None,
):
    """Chunkwise masked second-order HLA — intra-chunk matmuls, carried state.

    For local tokens 1..w with carry (S0, C0, m0, G0, h0) and D0 = S0 C0 - G0:

        num_t = g^{2t} q_t D0                              (T1: Q @ D0)
              + g^t   row_t[(Q S0 Q^T . Lg) V]             (T2)
              + row_t[((A B) . Lg) V]                      (T3, intra)
        A = (Q K^T) . Lg,  B = (K Q^T) . U  (U = upper incl diag)

    with all masked matmuls MXU-shaped (w x w / w x d).  Identical math to
    the serial recurrence (tested to fp32 tolerance; exact at gamma=1).
    """
    dtype = _compute_dtype(q)
    batch_shape = q.shape[:-2]
    n, d = q.shape[-2], q.shape[-1]
    dv = v.shape[-1]
    w = min(chunk, n)
    if n % w != 0:
        pad = w - n % w
        zq = jnp.zeros(batch_shape + (pad, d), q.dtype)
        zv = jnp.zeros(batch_shape + (pad, dv), v.dtype)
        out, st = hla2_chunkwise(
            jnp.concatenate([q, zq], -2),
            jnp.concatenate([k, zq], -2),
            jnp.concatenate([v, zv], -2),
            gamma,
            chunk=w,
            normalize=normalize,
            eps=eps,
            lam=lam,
            state=state,
        )
        # zero padding tokens only *decay* the state (their deltas vanish);
        # undo the spurious gamma^pad (gamma^2pad on G, h) attenuation.
        if gamma is not None:
            gpad = jnp.power(
                _gamma_arr(gamma, batch_shape, _compute_dtype(q)), float(pad)
            )
            inv = 1.0 / gpad
            st = HLA2State(
                S=st.S * inv[..., None, None],
                C=st.C * inv[..., None, None],
                m=st.m * inv[..., None],
                G=st.G * (inv**2)[..., None, None],
                h=st.h * (inv**2)[..., None],
            )
        return out[..., :n, :], st
    nc = n // w

    g = _gamma_arr(gamma, batch_shape, dtype)
    has_decay = gamma is not None
    Lg, pow_t = _decay_matrices(w, g if has_decay else jnp.ones_like(g), dtype)
    t_idx = jnp.arange(w)
    U = (t_idx[:, None] <= t_idx[None, :]).astype(dtype)  # i <= j
    Ls = (t_idx[:, None] > t_idx[None, :]).astype(dtype)  # strictly lower
    # g^(w-i), i = 1..w  (used for chunk-summary weighting)
    pow_rev = jnp.power(g[..., None], (w - t_idx - 1).astype(dtype))
    rho_w = jnp.power(g, float(w))  # gamma^w

    if state is None:
        state = hla2_init_state(batch_shape, d, dv)
    st0 = HLA2State(*(x.astype(dtype) for x in state))

    # reshape to chunks: (..., nc, w, d) -> scan over nc
    qc = jnp.moveaxis(q.astype(dtype).reshape(batch_shape + (nc, w, d)), -3, 0)
    kc = jnp.moveaxis(k.astype(dtype).reshape(batch_shape + (nc, w, d)), -3, 0)
    vc = jnp.moveaxis(v.astype(dtype).reshape(batch_shape + (nc, w, dv)), -3, 0)

    def chunk_body(carry: HLA2State, qkv):
        Q, K, V = qkv  # (..., w, d/dv)
        S0, C0, m0, G0, h0 = carry

        A = jnp.einsum("...td,...id->...ti", Q, K) * Lg  # (QK^T).Lg
        Bm = jnp.einsum("...id,...jd->...ij", K, Q) * U  # (KQ^T).U
        M3 = jnp.einsum("...ti,...ij->...tj", A, Bm) * Lg
        ones = jnp.ones(batch_shape + (w, 1), dtype)

        # T1: carry-only term, row-scaled by g^{2t}
        D0 = jnp.einsum("...ij,...je->...ie", S0, C0) - G0
        T1 = (pow_t**2)[..., None] * jnp.einsum("...td,...de->...te", Q, D0)
        # T2: carry metric x local pairs
        QS0Q = jnp.einsum("...td,...de,...je->...tj", Q, S0, Q) * Lg
        T2 = pow_t[..., None] * jnp.einsum("...tj,...je->...te", QS0Q, V)
        T3 = jnp.einsum("...tj,...je->...te", M3, V)
        num = T1 + T2 + T3

        if lam:
            Wqq = jnp.einsum("...td,...jd->...tj", Q, Q) * Lg
            qC0 = jnp.einsum("...td,...de->...te", Q, C0)
            num = num + lam * (
                pow_t[..., None] * qC0
                + jnp.einsum("...tj,...je->...te", Wqq, V)
            )

        if normalize:
            d0v = jnp.einsum("...ij,...j->...i", S0, m0) - h0
            den = (
                (pow_t**2) * jnp.einsum("...td,...d->...t", Q, d0v)
                + pow_t * jnp.einsum("...tj->...t", QS0Q)
                + jnp.sum(M3, -1)
            )
            if lam:
                qm0 = jnp.einsum("...td,...d->...t", Q, m0)
                den = den + lam * (pow_t * qm0 + jnp.sum(Wqq, -1))
            o = num / (den[..., None] + eps)
        else:
            o = num

        # ---- chunk summary & carry update (monoid with B = whole chunk) ----
        Kg = pow_rev[..., None] * K  # g^{w-t} k_t
        Vg = pow_rev[..., None] * V
        Sw = jnp.einsum("...ti,...tj->...ij", Kg, K)  # sum g^{w-t} k k^T
        Cw = jnp.einsum("...ti,...te->...ie", pow_rev[..., None] * Q, V)
        mw = jnp.einsum("...ti->...i", pow_rev[..., None] * Q)
        N = jnp.einsum("...td,...jd->...tj", K, Q) * Ls  # (KQ^T).Lstrict
        NVg = jnp.einsum("...tj,...je->...te", N, Vg)  # sum_{j<t}(k_t.q_j)g^{w-j}v_j
        Gw = jnp.einsum("...td,...te->...de", Kg, NVg)
        Nmg = jnp.einsum("...tj,...j->...t", N, pow_rev)
        hw = jnp.einsum("...td,...t->...d", Kg, Nmg)

        rw = rho_w[..., None, None]
        rwv = rho_w[..., None]
        new = HLA2State(
            S=rw * S0 + Sw,
            C=rw * C0 + Cw,
            m=rwv * m0 + mw,
            G=rw**2 * G0 + Gw + rw * jnp.einsum("...ij,...je->...ie", Sw, C0),
            h=rwv**2 * h0 + hw + rwv * jnp.einsum("...ij,...j->...i", Sw, m0),
        )
        return new, o

    final, outs = jax.lax.scan(chunk_body, st0, (qc, kc, vc))
    out = jnp.moveaxis(outs, 0, -3).reshape(batch_shape + (n, dv))
    return out.astype(v.dtype), final


def hla2(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    gamma=None,
    *,
    impl: str = "chunkwise",
    chunk: int = 64,
    normalize: bool = False,
    eps: float = 1e-6,
    lam: float = 0.0,
    state: Optional[HLA2State] = None,
):
    """Dispatch front-end.  Returns (outputs, final_state)."""
    if impl == "chunkwise":
        return hla2_chunkwise(
            q, k, v, gamma, chunk=chunk, normalize=normalize, eps=eps,
            lam=lam, state=state,
        )
    if impl == "scan":
        return hla2_scan(
            q, k, v, gamma, normalize=normalize, eps=eps, lam=lam, state=state
        )
    if impl == "serial":
        return hla2_serial(
            q, k, v, gamma, normalize=normalize, eps=eps, lam=lam, state=state
        )
    if impl == "naive":
        return hla2_naive(
            q, k, v, gamma, normalize=normalize, eps=eps, lam=lam
        ), None
    raise ValueError(f"unknown impl {impl!r}")
