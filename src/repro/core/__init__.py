"""Core HLA operators (the paper's contribution) in composable JAX.

Four exactly-equivalent computation paths per operator (serial recurrence,
materialized oracle, token-level associative scan, chunkwise masked-matmul)
— see DESIGN.md §1–2.
"""

from .ahla import (
    AHLAState,
    ahla,
    ahla_chunkwise,
    ahla_init_state,
    ahla_naive,
    ahla_scan,
    ahla_serial,
    ahla_step,
)
from .hla2 import (
    HLA2State,
    hla2,
    hla2_chunkwise,
    hla2_init_state,
    hla2_naive,
    hla2_scan,
    hla2_serial,
    hla2_step,
)
from .hla3 import (
    HLA3ChunkState,
    HLA3ExactState,
    HLA3PaperState,
    hla3,
    hla3_exact_chunkwise,
    hla3_exact_init_state,
    hla3_exact_naive,
    hla3_exact_serial,
    hla3_exact_step,
    hla3_paper_chunkwise,
    hla3_paper_init_state,
    hla3_paper_naive,
    hla3_paper_scan,
    hla3_paper_serial,
    hla3_paper_step,
)
from .linear_attn import (
    LinAttnState,
    linattn,
    linattn_chunkwise,
    linattn_init_state,
    linattn_naive,
    linattn_step,
)
